"""Communication-load table (paper §Case Study text): per-token bytes of C2C vs
T2T for the real case-study zoo (88 KB vs 16 B claim) and for every assigned
architecture (what federating THOSE models would cost)."""
from __future__ import annotations

from repro.configs.base import ARCH_IDS, get_config
from repro.core import commload


def run() -> dict:
    from repro.core.quant import c2c_bytes_per_token_quantized
    paper = commload.paper_case_study_bytes(dtype_bytes=2)
    archs = {a: commload.c2c_bytes_per_token(get_config(a), 2)
             for a in ARCH_IDS if get_config(a).attention_layers}
    int8 = {a: int(c2c_bytes_per_token_quantized(get_config(a)))
            for a in ARCH_IDS if get_config(a).attention_layers}
    return {"paper": paper, "assigned": archs, "assigned_int8": int8}


def main() -> None:
    r = run()
    p = r["paper"]
    for name, b in p["per_transmitter_bytes"].items():
        print(f"comm,case_study,{name},{b},B/token")
    print(f"comm,case_study,TOTAL_C2C,{p['c2c_total_per_token']},B/token"
          f"  (paper: ~88 KB)")
    print(f"comm,case_study,TOTAL_T2T,{p['t2t_total_per_token']},B/token"
          f"  (paper: 16 B)")
    for a, b in r["assigned"].items():
        print(f"comm,assigned,{a},{b},B/token")
    for a, b in r["assigned_int8"].items():
        print(f"comm,assigned_int8,{a},{b},B/token  (beyond-paper 2x)")


if __name__ == "__main__":
    main()
