"""Fig. 3(c): end-to-end latency of C2C (original + rephrased) vs T2T.

Two complementary measurements:
  1. MEASURED wall-clock of the tiny-zoo pipeline stages on this host (the
     relative structure — C2C skips the receiver-side re-prefill — is hardware
     independent);
  2. the ANALYTIC link+compute model (core/protocol.py) on the paper's real
     case-study dims (Qwen3-0.6B receiver etc.) over a WiFi-class link, which is
     the configuration Fig. 3(c) describes.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import build_case_study
from repro.configs.case_study import ZOO
from repro.core import c2c, protocol
from repro.models import transformer as T


def _timed(fn, *args, repeat=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeat


def run_measured(gen_steps: int = 8) -> dict:
    cs = build_case_study()
    system, rx = cs["system"], cs["receiver"]
    tx = cs["transmitters"][0]
    world = cs["world"]
    rng = np.random.default_rng(3)
    prompts = jnp.asarray(world.eval_batch(rng, 8)["prompt"])
    S = prompts.shape[1]
    fz = system.registry.get(tx.name, rx.name)

    def c2c_pipeline(p):
        _, cache = T.prefill(tx.cfg, tx.params, p, max_seq=S, cache_dtype=jnp.float32)
        stack = cache.export_stack(tx.cfg, length=S)
        fused = c2c.fused_prefix([fz], [tx.cfg], rx.cfg, [stack])
        return c2c.generate(rx.cfg, rx.params, p, gen_steps, fused=fused)

    def c2c_rephrased(p):
        return c2c_pipeline(system.channel.rephrase(p, jax.random.PRNGKey(1)))

    def t2t_pipeline(p):
        shared = c2c.generate(tx.cfg, tx.params, p, gen_steps)  # tx generates
        combined = jnp.concatenate([shared, p], axis=1)  # rx re-prefills ALL
        return c2c.generate(rx.cfg, rx.params, combined, gen_steps)

    return {
        "c2c_original_s": _timed(c2c_pipeline, prompts),
        "c2c_rephrased_s": _timed(c2c_rephrased, prompts),
        "t2t_s": _timed(t2t_pipeline, prompts),
    }


def run_analytic(seq: int = 64, gen_steps: int = 128) -> dict:
    """Paper-dims analytic latency over a 100 Mbit/s edge link (QA-length
    queries, matching the OpenBookQA workload of Fig. 3c)."""
    rx = ZOO["receiver"]
    txs = ZOO["transmitters"]
    link = protocol.LinkModel(bandwidth_bps=12.5e6, rtt_s=0.02)
    return {
        "standalone_s": protocol.latency_standalone(rx, seq, gen_steps),
        "c2c_s": protocol.latency_c2c(txs, rx, seq, gen_steps, link),
        "t2t_s": protocol.latency_t2t(txs, rx, seq, gen_steps, link,
                                      shared_tokens=gen_steps),
    }


def main() -> None:
    m = run_measured()
    for k, v in m.items():
        print(f"fig3c_measured,{k},{v*1e3:.1f}ms")
    a = run_analytic()
    for k, v in a.items():
        print(f"fig3c_analytic,{k},{v:.3f}s")


if __name__ == "__main__":
    main()
