"""Compare a fresh engine-bench JSON against the committed baseline.

Guards the serving engine against silent performance regressions in CI.
Absolute tokens/s is machine-dependent (CI runners vary wildly), so the
throughput gate compares the *machine-normalized* ratio of the engine to the
lockstep baseline measured in the same process on the same machine: a >10%
drop in that ratio fails. Structural properties (byte-identity, capacity and
slot ratios, fused-prefix amortisation, one decode trace) are compared
exactly — they are hardware-independent and must never regress.

The chunked-prefill latency gates follow the same normalization: the p99
decode-step and TTFT ratios (chunked engine / monolithic engine, measured in
the same process) must not regress past the baseline's ratio times a
tolerance headroom, and the chunked p99 must stay strictly below monolithic.

Run:  python benchmarks/compare_bench.py BENCH_engine.json \
          [--baseline benchmarks/BENCH_engine_baseline.json] \
          [--tolerance 0.10]
"""
import argparse
import json
import sys


def normalized_throughput(report: dict) -> float:
    t = report["throughput"]
    return t["engine_tokens_per_s"] / max(t["lockstep_tokens_per_s"], 1e-9)


def structural_gates(report: dict):
    """Hardware-independent properties that must hold in every run."""
    cap = report["capacity"]
    pk = report["paged_kernel"]
    sp = report["shared_prefix"]
    ck = report["chunked_prefill"]
    ra = report["ragged_prefill"]
    au = report["audited"]
    stats = report["throughput"]["engine_stats"]
    return [
        ("bench self-reported pass", bool(report["pass"])),
        ("one decode trace across the mix", stats["decode_traces"] == 1),
        ("paged == dense outputs", bool(cap["byte_identical_outputs"])),
        ("paged capacity >= 2x dense", cap["capacity_ratio"] >= 2.0),
        ("kernel == gather outputs", bool(pk["byte_identical_outputs"])),
        ("kernel path gathers no dense view",
         pk["kernel"]["decode_view_gathers"] == 0),
        ("kernel reduces KV HBM bytes", pk["hbm_bytes_ratio"] < 1.0),
        ("shared-prefix == unshared outputs",
         bool(sp["byte_identical_outputs"])),
        ("prefix sharing >= 2x concurrent slots", sp["slot_ratio"] >= 2.0),
        ("prefix sharing reduces prefill tokens",
         sp["prefill_token_ratio"] < 1.0),
        ("fused prefix inserted once per digest",
         sp["fused_inserts"] == 1 and sp["fused_digest_hits"] >= 1),
        ("chunked == monolithic outputs",
         bool(ck["byte_identical_outputs"])),
        ("one chunk-prefill trace across the mix",
         ck["chunked"]["prefill_traces"] == 1),
        ("chunked p99 step latency below monolithic",
         ck["p99_step_ratio"] < 1.0),
        ("ragged packing cuts padded-bucket FLOPs",
         ra["flops_ratio"] < 1.0),
        ("ragged packing cuts padded-bucket HBM bytes",
         ra["hbm_bytes_ratio"] < 1.0),
        ("wire audit report empty", au["audit_findings"] == 0),
        ("wire auditor saw traffic", au["audited_messages"] > 0),
        ("audited == unaudited outputs",
         bool(au["byte_identical_outputs"])),
        ("audited wire_bytes match unaudited",
         bool(au["wire_bytes_match"])),
    ]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("current", help="freshly produced BENCH_engine.json")
    ap.add_argument("--baseline",
                    default="benchmarks/BENCH_engine_baseline.json")
    ap.add_argument("--tolerance", type=float, default=0.10,
                    help="allowed fractional drop in normalized throughput")
    args = ap.parse_args()

    with open(args.current) as f:
        cur = json.load(f)
    with open(args.baseline) as f:
        base = json.load(f)

    ok = True
    cur_r, base_r = normalized_throughput(cur), normalized_throughput(base)
    floor = base_r * (1.0 - args.tolerance)
    print(f"normalized throughput (engine/lockstep tokens/s): "
          f"current {cur_r:.2f} vs baseline {base_r:.2f} "
          f"(floor {floor:.2f}, tolerance {args.tolerance:.0%})")
    if cur_r < floor:
        print(f"FAIL: normalized throughput regressed "
              f"{1 - cur_r / base_r:.1%} > {args.tolerance:.0%}")
        ok = False

    # chunked-prefill latency: gate the machine-normalized chunked/monolithic
    # ratios, never absolute seconds; wall-clock ratios are noisier than the
    # throughput ratio, so the ceiling gets 3x the throughput tolerance.
    # Headroom is multiplicative — the TTFT ratio sits far above 1 by design
    # (chunked longs trade first-token latency for a flat decode p99), so an
    # additive margin would be meaninglessly tight there and slack at 1.
    ckc, ckb = cur["chunked_prefill"], base["chunked_prefill"]
    for label, key in (("p99 decode-step", "p99_step_ratio"),
                       ("TTFT p99", "ttft_p99_ratio")):
        cur_x, base_x = ckc[key], ckb[key]
        ceil = base_x * (1.0 + max(3 * args.tolerance, 0.15))
        print(f"chunked/monolithic {label} ratio: current {cur_x:.3f} vs "
              f"baseline {base_x:.3f} (ceiling {ceil:.3f})")
        if cur_x > ceil:
            print(f"FAIL: chunked {label} ratio regressed past baseline "
                  f"headroom")
            ok = False

    for name, passed in structural_gates(cur):
        print(f"{'ok  ' if passed else 'FAIL'}: {name}")
        ok = ok and passed

    print("PASS" if ok else "FAIL")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
