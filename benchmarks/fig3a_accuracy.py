"""Fig. 3(a): accuracy vs number of participating transmitters, for
{C2C ("KV"), T2T ("Token")} × {Original, Rephrased}.

Paper's claims this reproduces qualitatively (simulated case study, see
DESIGN.md §1): (i) accuracy rises with transmitter count; (ii) C2C > T2T;
(iii) rephrasing costs only a small accuracy delta; (iv) every federated
variant beats the standalone receiver.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (answer_accuracy_c2c, answer_accuracy_t2t,
                               build_case_study)


def run() -> list:
    cs = build_case_study()
    system = cs["system"]
    tx_all = [t.name for t in cs["transmitters"]]
    rng = np.random.default_rng(7)
    rows = []
    base = answer_accuracy_c2c(cs, [], rng)
    rows.append(("standalone", 0, "none", base))
    for n in range(1, len(tx_all) + 1):
        names = tx_all[:n]
        for proto, fn in (("KV", answer_accuracy_c2c), ("Token", answer_accuracy_t2t)):
            for variant, reph in (("original", False), ("rephrased", True)):
                rng_e = np.random.default_rng(7)  # same eval set everywhere
                acc = fn(cs, names, rng_e, rephrased=reph)
                rows.append((proto, n, variant, acc))
    return rows


def main() -> None:
    for proto, n, variant, acc in run():
        print(f"fig3a,{proto},{n},{variant},{acc:.4f}")


if __name__ == "__main__":
    main()
