"""Micro-benchmarks of the Pallas kernels (interpret mode on CPU — numbers are
for relative tracking only; real perf comes from the dry-run roofline) and of
their pure-jnp twins at case-study sizes."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _timed(fn, *args, repeat=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeat * 1e6  # us


def ragged_prefill_analytics(prompt_lens, *, bucket, H, Hkv, hd, page_size,
                             block_q=8, itemsize=4):
    """Padded-bucket vs ragged-packed prefill: analytic FLOPs and KV HBM
    bytes (dataflow accounting, not measurement — interpret mode has no
    hardware counters; engine_bench embeds this in BENCH_engine.json).

    Both sides are costed with the SAME causal-flash block model — block_q
    query rows per grid step, each step DMAing the page-aligned keys at or
    before its last query — so the comparison isolates exactly one thing:
    the padded kernel runs that model over ``bucket`` rows per prompt (pad
    rows execute, pad keys get DMAed), while ragged packing
    (kernels/prefill_attention.py) runs only live query blocks and only the
    pages holding real keys. Costing the padded side as a single monolithic
    K/V stream instead would compare two different kernels, not padding vs
    packing."""
    att = lambda sq, sk: 4 * H * hd * sq * sk  # QK^T + AV, 2 ops per MAC
    row_q = H * hd * itemsize                  # one q read + one o write
    row_kv = 2 * Hkv * hd * itemsize           # one k + one v row

    def flash_cost(S):
        """(flops, bytes) of a causal flash prefill over S rows."""
        flops = bytes_ = 0
        for b in range(-(-S // block_q)):
            nq = min(block_q, S - b * block_q)
            pages = -(-(b * block_q + nq) // page_size)
            flops += att(block_q, pages * page_size)
            bytes_ += pages * page_size * row_kv + block_q * 2 * row_q
        return flops, bytes_

    flops_pad = flops_rag = bytes_pad = bytes_rag = 0
    for S in prompt_lens:
        f, by = flash_cost(bucket)
        flops_pad += f
        bytes_pad += by
        f, by = flash_cost(S)
        flops_rag += f
        bytes_rag += by
    return {
        "prompt_lens": list(prompt_lens), "bucket": bucket,
        "page_size": page_size, "block_q": block_q,
        "flops_padded_bucket": flops_pad,
        "flops_ragged_packed": flops_rag,
        "flops_ratio": flops_rag / max(flops_pad, 1),
        "hbm_bytes_padded_bucket": bytes_pad,
        "hbm_bytes_ragged_packed": bytes_rag,
        "hbm_bytes_ratio": bytes_rag / max(bytes_pad, 1),
    }


def run() -> list:
    key = jax.random.PRNGKey(0)
    rows = []
    # fuser MLP at a 1k-token cache projection size
    T_, d = 1024, 256
    x = jax.random.normal(key, (T_, d), jnp.float32)
    p = {f"w{i}": {"w": jax.random.normal(jax.random.fold_in(key, i),
                                          (d, d), jnp.float32) * 0.05,
                   "b": jnp.zeros((d,), jnp.float32)} for i in (1, 2, 3)}
    rows.append(("fuser_mlp_pallas_interp", _timed(ops.fuser_mlp, p, x)))
    rows.append(("fuser_mlp_jnp", _timed(
        jax.jit(lambda xx: ref.fuser_mlp_ref(
            xx, p["w1"]["w"], p["w1"]["b"], p["w2"]["w"], p["w2"]["b"],
            p["w3"]["w"], p["w3"]["b"])), x)))
    # decode attention at 4k cache
    B, H, Hkv, S, hd = 2, 8, 2, 4096, 64
    q = jax.random.normal(key, (B, H, hd), jnp.float32)
    k = jax.random.normal(key, (B, Hkv, S, hd), jnp.float32)
    v = jax.random.normal(key, (B, Hkv, S, hd), jnp.float32)
    bias = jnp.zeros((B, S))
    rows.append(("decode_attn_pallas_interp", _timed(ops.decode_attention, q, k, v, bias)))
    rows.append(("decode_attn_jnp", _timed(
        jax.jit(lambda *a: ref.decode_attention_ref(
            a[0].reshape(B, Hkv, H // Hkv, hd), *a[1:])), q, k, v, bias)))
    # int8-KV decode (quantised C2C serving path)
    from repro.core import quant
    qs = quant.quantize_stack({"k": k[None], "v": v[None]})
    qstack = {kk: qs[kk][0] for kk in ("k_q", "v_q", "k_scale", "v_scale")}
    rows.append(("decode_attn_q8_pallas_interp",
                 _timed(lambda: ops.decode_attention_q8(q, qstack, bias))))
    # paged flash-decode: in-place page-map walk vs gather-then-attend oracle
    # (half-occupied slots: the in-place walk touches half the pool pages)
    pg, pps, slots = 64, S // 64, B
    num_pages = slots * pps
    k_pool = k.transpose(0, 2, 1, 3).reshape(num_pages, pg, Hkv, hd
                                             ).transpose(0, 2, 1, 3)
    v_pool = v.transpose(0, 2, 1, 3).reshape(num_pages, pg, Hkv, hd
                                             ).transpose(0, 2, 1, 3)
    pm = jnp.arange(num_pages, dtype=jnp.int32).reshape(slots, pps)
    pm = jnp.where(jnp.arange(pps)[None, :] < pps // 2, pm, num_pages)
    lengths = jnp.full((slots,), S // 2, jnp.int32)
    rows.append(("paged_decode_pallas_interp",
                 _timed(lambda: ops.paged_decode_attention(
                     q, k_pool, v_pool, pm, lengths))))
    rows.append(("paged_decode_gather_jnp", _timed(
        jax.jit(lambda qq: ref.paged_decode_attention_ref(
            qq.reshape(B, Hkv, H // Hkv, hd), k_pool, v_pool, pm, lengths)),
        q)))
    # ragged varlen prefill over a paged pool vs padded-bucket dense prefill
    Hkv_r, G_r, hd_r, pg_r, bq_r = 2, 4, 32, 32, 8
    H_r = Hkv_r * G_r
    lens = [64, 17, 40]
    bucket = max(lens)
    pps_r = -(-bucket // pg_r)
    n_pages_r = sum(-(-s // pg_r) for s in lens)
    kq = jax.random.split(jax.random.fold_in(key, 7), 4)
    pm_r = jnp.full((len(lens), pps_r), n_pages_r, jnp.int32)
    nxt = 0
    bs_r, bp_r, bl_r, qs = [], [], [], []
    for i, s in enumerate(lens):
        np_i = -(-s // pg_r)
        pm_r = pm_r.at[i, :np_i].set(jnp.arange(nxt, nxt + np_i))
        nxt += np_i
        nb = -(-s // bq_r)
        qs.append(jnp.pad(jax.random.normal(jax.random.fold_in(kq[0], i),
                                            (s, H_r, hd_r), jnp.float32),
                          ((0, nb * bq_r - s), (0, 0), (0, 0))))
        for b in range(nb):
            bs_r.append(i)
            bp_r.append(b * bq_r)
            bl_r.append(min(bq_r, s - b * bq_r))
    q_r = jnp.concatenate(qs)
    k_pool_r = jax.random.normal(kq[1], (n_pages_r, Hkv_r, pg_r, hd_r))
    v_pool_r = jax.random.normal(kq[2], (n_pages_r, Hkv_r, pg_r, hd_r))
    mk = lambda xs: jnp.asarray(xs, jnp.int32)
    bs_r, bp_r, bl_r = mk(bs_r), mk(bp_r), mk(bl_r)
    rows.append(("ragged_prefill_pallas_interp",
                 _timed(lambda: ops.ragged_prefill_attention(
                     q_r, k_pool_r, v_pool_r, bs_r, bp_r, bl_r, pm_r,
                     block_q=bq_r))))
    # padded-bucket twin: every prompt padded to the bucket, dense causal
    qp = jax.random.normal(kq[3], (len(lens), bucket, H_r, hd_r))
    kp = jax.random.normal(kq[3], (len(lens), bucket, Hkv_r, hd_r))
    vp = kp * 0.5

    def _padded_prefill(qq, kk, vv):
        qg = qq.reshape(qq.shape[0], bucket, Hkv_r, G_r, hd_r)
        s = jnp.einsum("nqkgd,ntkd->nkgqt", qg, kk) * (hd_r ** -0.5)
        causal = jnp.tril(jnp.ones((bucket, bucket), bool))
        s = jnp.where(causal[None, None, None], s, -1e30)
        return jnp.einsum("nkgqt,ntkd->nkgqd", jax.nn.softmax(s, -1), vv)

    rows.append(("prefill_padded_bucket_dense_jnp",
                 _timed(jax.jit(_padded_prefill), qp, kp, vp)))
    # banded SWA prefill vs dense-masked reference at window << S
    Sb, w = 2048, 256
    qb = jax.random.normal(key, (1, 4, Sb, 64), jnp.float32)
    kb = jax.random.normal(key, (1, 4, Sb, 64), jnp.float32)
    vb = jax.random.normal(key, (1, 4, Sb, 64), jnp.float32)
    rows.append(("banded_swa_pallas_interp",
                 _timed(lambda: ops.banded_attention(qb, kb, vb, window=w,
                                                     block=256))))
    rows.append(("swa_dense_masked_jnp", _timed(
        jax.jit(lambda a, b, c: ref.banded_attention_ref(
            a.reshape(4, Sb, 64), b.reshape(4, Sb, 64), c.reshape(4, Sb, 64),
            window=w)), qb, kb, vb)))
    return rows


def main() -> None:
    for name, us in run():
        print(f"kernel,{name},{us:.0f},us_per_call")
    # serving-scale dataflow accounting for the ragged prefill packing
    ra = ragged_prefill_analytics([512, 64, 384, 48, 256, 9], bucket=512,
                                  H=32, Hkv=8, hd=128, page_size=64)
    print(f"kernel,ragged_prefill_flops_vs_padded,{ra['flops_ratio']:.3f},ratio")
    print(f"kernel,ragged_prefill_hbm_bytes_vs_padded,"
          f"{ra['hbm_bytes_ratio']:.3f},ratio")


if __name__ == "__main__":
    main()
