"""Micro-benchmarks of the Pallas kernels (interpret mode on CPU — numbers are
for relative tracking only; real perf comes from the dry-run roofline) and of
their pure-jnp twins at case-study sizes."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _timed(fn, *args, repeat=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeat * 1e6  # us


def run() -> list:
    key = jax.random.PRNGKey(0)
    rows = []
    # fuser MLP at a 1k-token cache projection size
    T_, d = 1024, 256
    x = jax.random.normal(key, (T_, d), jnp.float32)
    p = {f"w{i}": {"w": jax.random.normal(jax.random.fold_in(key, i),
                                          (d, d), jnp.float32) * 0.05,
                   "b": jnp.zeros((d,), jnp.float32)} for i in (1, 2, 3)}
    rows.append(("fuser_mlp_pallas_interp", _timed(ops.fuser_mlp, p, x)))
    rows.append(("fuser_mlp_jnp", _timed(
        jax.jit(lambda xx: ref.fuser_mlp_ref(
            xx, p["w1"]["w"], p["w1"]["b"], p["w2"]["w"], p["w2"]["b"],
            p["w3"]["w"], p["w3"]["b"])), x)))
    # decode attention at 4k cache
    B, H, Hkv, S, hd = 2, 8, 2, 4096, 64
    q = jax.random.normal(key, (B, H, hd), jnp.float32)
    k = jax.random.normal(key, (B, Hkv, S, hd), jnp.float32)
    v = jax.random.normal(key, (B, Hkv, S, hd), jnp.float32)
    bias = jnp.zeros((B, S))
    rows.append(("decode_attn_pallas_interp", _timed(ops.decode_attention, q, k, v, bias)))
    rows.append(("decode_attn_jnp", _timed(
        jax.jit(lambda *a: ref.decode_attention_ref(
            a[0].reshape(B, Hkv, H // Hkv, hd), *a[1:])), q, k, v, bias)))
    # int8-KV decode (quantised C2C serving path)
    from repro.core import quant
    qs = quant.quantize_stack({"k": k[None], "v": v[None]})
    qstack = {kk: qs[kk][0] for kk in ("k_q", "v_q", "k_scale", "v_scale")}
    rows.append(("decode_attn_q8_pallas_interp",
                 _timed(lambda: ops.decode_attention_q8(q, qstack, bias))))
    # paged flash-decode: in-place page-map walk vs gather-then-attend oracle
    # (half-occupied slots: the in-place walk touches half the pool pages)
    pg, pps, slots = 64, S // 64, B
    num_pages = slots * pps
    k_pool = k.transpose(0, 2, 1, 3).reshape(num_pages, pg, Hkv, hd
                                             ).transpose(0, 2, 1, 3)
    v_pool = v.transpose(0, 2, 1, 3).reshape(num_pages, pg, Hkv, hd
                                             ).transpose(0, 2, 1, 3)
    pm = jnp.arange(num_pages, dtype=jnp.int32).reshape(slots, pps)
    pm = jnp.where(jnp.arange(pps)[None, :] < pps // 2, pm, num_pages)
    lengths = jnp.full((slots,), S // 2, jnp.int32)
    rows.append(("paged_decode_pallas_interp",
                 _timed(lambda: ops.paged_decode_attention(
                     q, k_pool, v_pool, pm, lengths))))
    rows.append(("paged_decode_gather_jnp", _timed(
        jax.jit(lambda qq: ref.paged_decode_attention_ref(
            qq.reshape(B, Hkv, H // Hkv, hd), k_pool, v_pool, pm, lengths)),
        q)))
    # banded SWA prefill vs dense-masked reference at window << S
    Sb, w = 2048, 256
    qb = jax.random.normal(key, (1, 4, Sb, 64), jnp.float32)
    kb = jax.random.normal(key, (1, 4, Sb, 64), jnp.float32)
    vb = jax.random.normal(key, (1, 4, Sb, 64), jnp.float32)
    rows.append(("banded_swa_pallas_interp",
                 _timed(lambda: ops.banded_attention(qb, kb, vb, window=w,
                                                     block=256))))
    rows.append(("swa_dense_masked_jnp", _timed(
        jax.jit(lambda a, b, c: ref.banded_attention_ref(
            a.reshape(4, Sb, 64), b.reshape(4, Sb, 64), c.reshape(4, Sb, 64),
            window=w)), qb, kb, vb)))
    return rows


def main() -> None:
    for name, us in run():
        print(f"kernel,{name},{us:.0f},us_per_call")


if __name__ == "__main__":
    main()
