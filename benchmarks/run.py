"""Benchmark suite entry point: one module per paper table/figure.

  fig3a_accuracy — Fig. 3(a): accuracy vs #transmitters (KV/Token × orig/reph)
  fig3b_sharers  — Fig. 3(b): per-sharer contribution (in- vs off-domain)
  fig3c_latency  — Fig. 3(c): latency C2C vs T2T (measured + analytic)
  comm_table     — §Case Study byte counts (88 KB vs 16 B) + assigned archs
  kernel_bench   — Pallas kernel micro-bench (interpret mode)

Output: CSV-ish lines ``name,...`` on stdout. The case-study build (zoo +
fuser training) runs once and is shared across the fig3* modules. Roofline
numbers live in EXPERIMENTS.md §Roofline (produced by repro.launch.dryrun,
not here).

Usage: PYTHONPATH=src python -m benchmarks.run [--fast]
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    import benchmarks.comm_table as comm
    import benchmarks.kernel_bench as kb

    t0 = time.time()
    print("# comm_table")
    comm.main()
    print("# kernel_bench")
    kb.main()

    if "--fast" not in sys.argv:
        import benchmarks.fig3a_accuracy as f3a
        import benchmarks.fig3b_sharers as f3b
        import benchmarks.fig3c_latency as f3c
        print("# fig3a_accuracy (builds + trains the case study once)")
        f3a.main()
        print("# fig3b_sharers")
        f3b.main()
        print("# fig3c_latency")
        f3c.main()
    print(f"# total {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
