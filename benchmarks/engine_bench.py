"""Continuous-batching engine vs lockstep BatchedServer under Poisson traffic.

Simulates the serving regime the federation targets: requests with mixed
protocols (standalone + C2C-fused) arriving at staggered (Poisson) times.

- **Engine** (launch/engine.py): requests join mid-flight, finished slots free
  immediately, one decode trace covers every request mix.
- **Lockstep** (launch/serve.py BatchedServer): requests wait to be grouped,
  each group must share one protocol (a lockstep batch has a single fused
  prefix), the whole group decodes for the longest member, and the fused path
  re-jits its serve step per call.

Both run on the same wall-clock timeline (arrivals are real waits); reported
are sustained tokens/s and request-latency p50/p99.

Run:  PYTHONPATH=src python benchmarks/engine_bench.py [--smoke]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.case_study import tiny_zoo
from repro.core import c2c, fuser as F
from repro.launch.engine import ContinuousBatchingEngine
from repro.launch.serve import BatchedServer
from repro.models import transformer as T
from repro.models.cache import attn_kv_stack


def build_world(vocab: int = 64):
    zoo = tiny_zoo(vocab_size=vocab)
    rx, tx = zoo["receiver"], zoo["transmitters"][0]
    key = jax.random.PRNGKey(0)
    p_rx = T.init_params(rx, key, jnp.float32)
    p_tx = T.init_params(tx, jax.random.fold_in(key, 1), jnp.float32)
    fz = F.init_fuser(tx, rx, jax.random.fold_in(key, 2))
    return rx, p_rx, tx, p_tx, fz


def make_requests(n: int, prompt_len: int, rate: float, vocab: int, seed=0):
    """Poisson arrivals: exponential inter-arrival gaps at ``rate`` req/s."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n)
    arrivals = np.cumsum(gaps)
    key = jax.random.PRNGKey(7)
    reqs = []
    for i in range(n):
        prompt = jax.random.randint(jax.random.fold_in(key, i),
                                    (1, prompt_len), 0, vocab)
        reqs.append({"arrival": float(arrivals[i]), "prompt": prompt,
                     "protocol": "c2c" if i % 2 else "standalone"})
    return reqs


def make_tx_fused(tx, p_tx, fz, rx):
    """Jitted transmitter-prefill + fuser-projection for (B, P) prompts (the
    transmit/fuse hot path a real deployment compiles once)."""

    @jax.jit
    def fused(prompts):
        S = prompts.shape[1]
        _, cache = T.prefill(tx, p_tx, prompts, max_seq=S,
                             cache_dtype=jnp.float32)
        stack = attn_kv_stack(tx, cache, length=S)
        return c2c.fused_prefix([fz], [tx], rx, [stack])

    return fused


def percentiles(lat):
    lat = np.asarray(sorted(lat))
    return float(np.percentile(lat, 50)), float(np.percentile(lat, 99))


def run_engine(rx, p_rx, tx, p_tx, fz, reqs, gen, *, max_slots, max_seq,
               max_prefix):
    eng = ContinuousBatchingEngine(rx, p_rx, max_slots=max_slots,
                                   max_seq=max_seq, max_prefix=max_prefix)
    tx_fused = make_tx_fused(tx, p_tx, fz, rx)
    # warm the traces (prefill + decode + fuser path) outside the clock
    eng.submit(reqs[0]["prompt"], 2, fused=tx_fused(reqs[0]["prompt"]))
    eng.submit(reqs[0]["prompt"], 2)
    eng.drain()

    pending = list(reqs)
    arrival = {}
    done_at = {}
    t0 = time.perf_counter()
    while pending or eng.num_queued or eng.num_active:
        now = time.perf_counter() - t0
        while pending and pending[0]["arrival"] <= now:
            r = pending.pop(0)
            fused = (tx_fused(r["prompt"])
                     if r["protocol"] == "c2c" else None)
            rid = eng.submit(r["prompt"], gen, fused=fused,
                             protocol=r["protocol"])
            arrival[rid] = r["arrival"]
        if not (eng.num_queued or eng.num_active):
            time.sleep(max(0.0, pending[0]["arrival"] - now))
            continue
        for c in eng.step():
            done_at[c.rid] = time.perf_counter() - t0
    lat = [done_at[r] - arrival[r] for r in done_at]
    span = max(done_at.values()) - reqs[0]["arrival"]
    toks = len(done_at) * gen
    return {"tokens_per_s": toks / span, "latency": lat, "stats": eng.stats}


def run_lockstep(rx, p_rx, tx, p_tx, fz, reqs, gen, *, max_batch, max_seq):
    srv = BatchedServer(rx, p_rx, max_batch=max_batch, max_seq=max_seq)
    tx_fused = make_tx_fused(tx, p_tx, fz, rx)
    pad = jnp.tile(reqs[0]["prompt"], (max_batch, 1))
    srv.serve(pad, 2)  # warm the standalone traces
    srv.serve(pad, 2, fused=tx_fused(pad))

    pending = list(reqs)
    done_at, arrival = {}, {}
    t0 = time.perf_counter()
    while pending:
        now = time.perf_counter() - t0
        avail = [r for r in pending if r["arrival"] <= now]
        if not avail:
            time.sleep(max(0.0, pending[0]["arrival"] - now))
            continue
        # lockstep constraint: one protocol (one shared fused prefix) per batch
        proto = avail[0]["protocol"]
        batch = [r for r in avail if r["protocol"] == proto][:max_batch]
        for r in batch:
            pending.remove(r)
        prompts = jnp.concatenate([r["prompt"] for r in batch], axis=0)
        n_real = prompts.shape[0]
        if n_real < max_batch:  # pad to the compiled batch width
            prompts = jnp.concatenate(
                [prompts, jnp.tile(prompts[-1:], (max_batch - n_real, 1))], 0)
        fused = tx_fused(prompts) if proto == "c2c" else None
        out = srv.serve(prompts, gen, fused=fused)
        jax.block_until_ready(out)
        t_done = time.perf_counter() - t0
        for i, r in enumerate(batch):
            rid = len(done_at)
            done_at[rid] = t_done
            arrival[rid] = r["arrival"]
    lat = [done_at[r] - arrival[r] for r in done_at]
    span = max(done_at.values()) - reqs[0]["arrival"]
    toks = len(done_at) * gen
    return {"tokens_per_s": toks / span, "latency": lat}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes + invariant checks (CI); overrides "
                         "--requests/--gen/--slots/--rate")
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--rate", type=float, default=8.0,
                    help="Poisson arrival rate (req/s)")
    ap.add_argument("--slots", type=int, default=8)
    args = ap.parse_args()
    if args.smoke:
        args.requests, args.gen, args.slots = 10, 8, 4
        args.rate = 50.0
    if args.rate <= 0:
        ap.error("--rate must be > 0")

    vocab = 64
    rx, p_rx, tx, p_tx, fz = build_world(vocab)
    max_seq = args.prompt_len + args.gen + 8
    reqs = make_requests(args.requests, args.prompt_len, args.rate, vocab)

    eng = run_engine(rx, p_rx, tx, p_tx, fz, reqs, args.gen,
                     max_slots=args.slots, max_seq=max_seq,
                     max_prefix=args.prompt_len)
    lck = run_lockstep(rx, p_rx, tx, p_tx, fz, reqs, args.gen,
                       max_batch=args.slots, max_seq=max_seq)

    ep50, ep99 = percentiles(eng["latency"])
    lp50, lp99 = percentiles(lck["latency"])
    print(f"\n{args.requests} requests, Poisson rate {args.rate}/s, "
          f"gen {args.gen} tok, {args.slots} slots, mixed standalone+C2C")
    print(f"{'':22s}{'tokens/s':>10s}{'p50 (s)':>10s}{'p99 (s)':>10s}")
    print(f"{'continuous (engine)':22s}{eng['tokens_per_s']:>10.1f}"
          f"{ep50:>10.3f}{ep99:>10.3f}")
    print(f"{'lockstep (Batched)':22s}{lck['tokens_per_s']:>10.1f}"
          f"{lp50:>10.3f}{lp99:>10.3f}")
    print(f"engine stats: {eng['stats']}")

    ok = True
    if eng["stats"]["decode_traces"] != 1:
        print("FAIL: decode step traced more than once across the mix")
        ok = False
    # smoke (CI, shared runners): allow wall-clock noise a generous margin so
    # a noisy-neighbour hiccup can't fail an unrelated PR; full runs are strict
    margin = 0.8 if args.smoke else 1.0
    if eng["tokens_per_s"] < margin * lck["tokens_per_s"]:
        print("FAIL: engine slower than lockstep baseline")
        ok = False
    print("PASS" if ok else "FAIL")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
