"""Continuous-batching engine vs lockstep BatchedServer under Poisson traffic,
plus the paged-vs-dense slot-capacity comparison.

Simulates the serving regime the federation targets: requests with mixed
protocols (standalone + C2C-fused) arriving at staggered (Poisson) times.

- **Engine** (launch/engine.py): requests join mid-flight, finished slots free
  immediately, one decode trace covers every request mix.
- **Lockstep** (launch/serve.py BatchedServer): requests wait to be grouped,
  each group must share one protocol (a lockstep batch has a single fused
  prefix), the whole group decodes for the longest member, and the fused path
  re-jits its serve step per call.

Both run on the same wall-clock timeline (arrivals are real waits); reported
are sustained tokens/s and request-latency p50/p99.

The **capacity section** pits the paged slot table (models/cache.SlotTable)
against the dense reference at EQUAL KV HBM budget: the paged engine gets a
page pool of exactly the dense table's byte size but twice the slots, and a
burst of short requests must (a) decode byte-identically to the dense engine
and (b) sustain ≥2× the dense engine's concurrent slots — the win paging buys
when requests are shorter than max_seq.

The **sanitized section** reruns the sharing/CoW workload with the engine's
page-lifecycle sanitizer on (``sanitize=True``, repro.analysis.sanitizer):
the run must finish every per-step cross-check, drain with an empty leak
report, and emit byte-identical tokens — CI fails on any finding.

Results are also written as JSON (``--json BENCH_engine.json``; CI uploads it
as an artifact on main so the bench trajectory accumulates).

Run:  PYTHONPATH=src python benchmarks/engine_bench.py [--smoke] [--json PATH]
"""
import argparse
import gc
import json
import os
import sys
import time
from contextlib import nullcontext

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import jax
import jax.numpy as jnp
import numpy as np

from kernel_bench import ragged_prefill_analytics
from repro.analysis import TraceGuard
from repro.configs.case_study import tiny_zoo
from repro.core import c2c, fuser as F
from repro.core.fedrefine import FedRefineSystem, Participant
from repro.launch.engine import ContinuousBatchingEngine
from repro.launch.serve import BatchedServer
from repro.models import transformer as T


def build_world(vocab: int = 64):
    zoo = tiny_zoo(vocab_size=vocab)
    rx, tx = zoo["receiver"], zoo["transmitters"][0]
    key = jax.random.PRNGKey(0)
    p_rx = T.init_params(rx, key, jnp.float32)
    p_tx = T.init_params(tx, jax.random.fold_in(key, 1), jnp.float32)
    fz = F.init_fuser(tx, rx, jax.random.fold_in(key, 2))
    return rx, p_rx, tx, p_tx, fz


def make_requests(n: int, prompt_len: int, rate: float, vocab: int, seed=0):
    """Poisson arrivals: exponential inter-arrival gaps at ``rate`` req/s."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n)
    arrivals = np.cumsum(gaps)
    key = jax.random.PRNGKey(7)
    reqs = []
    for i in range(n):
        prompt = jax.random.randint(jax.random.fold_in(key, i),
                                    (1, prompt_len), 0, vocab)
        reqs.append({"arrival": float(arrivals[i]), "prompt": prompt,
                     "protocol": "c2c" if i % 2 else "standalone"})
    return reqs


def make_tx_fused(tx, p_tx, fz, rx):
    """Jitted transmitter-prefill + fuser-projection for (B, P) prompts (the
    transmit/fuse hot path a real deployment compiles once)."""

    @jax.jit
    def fused(prompts):
        S = prompts.shape[1]
        _, cache = T.prefill(tx, p_tx, prompts, max_seq=S,
                             cache_dtype=jnp.float32)
        stack = cache.export_stack(tx, length=S)
        return c2c.fused_prefix([fz], [tx], rx, [stack])

    return fused


def percentiles(lat):
    lat = np.asarray(sorted(lat))
    return float(np.percentile(lat, 50)), float(np.percentile(lat, 99))


def run_engine(rx, p_rx, tx, p_tx, fz, reqs, gen, *, max_slots, max_seq,
               max_prefix, retrace_guard=False):
    eng = ContinuousBatchingEngine(rx, p_rx, max_slots=max_slots,
                                   max_seq=max_seq, max_prefix=max_prefix)
    tx_fused = make_tx_fused(tx, p_tx, fz, rx)
    # warm the traces (prefill + decode + fuser path) outside the clock
    eng.submit(reqs[0]["prompt"], 2, fused=tx_fused(reqs[0]["prompt"]))
    eng.submit(reqs[0]["prompt"], 2)
    eng.drain()

    # smoke gate: after warmup the serving loop must never re-lower the
    # decode or prefill step — a retrace fails the bench with the avals
    guard = (TraceGuard(max_traces={"decode": 0, "prefill": 0})
             if retrace_guard else nullcontext())
    pending = list(reqs)
    arrival = {}
    done_at = {}
    t0 = time.perf_counter()
    with guard:
        while pending or eng.num_queued or eng.num_active:
            now = time.perf_counter() - t0
            while pending and pending[0]["arrival"] <= now:
                r = pending.pop(0)
                fused = (tx_fused(r["prompt"])
                         if r["protocol"] == "c2c" else None)
                rid = eng.submit(r["prompt"], gen, fused=fused,
                                 protocol=r["protocol"])
                arrival[rid] = r["arrival"]
            if not (eng.num_queued or eng.num_active):
                time.sleep(max(0.0, pending[0]["arrival"] - now))
                continue
            for c in eng.step():
                done_at[c.rid] = time.perf_counter() - t0
    lat = [done_at[r] - arrival[r] for r in done_at]
    span = max(done_at.values()) - reqs[0]["arrival"]
    toks = len(done_at) * gen
    return {"tokens_per_s": toks / span, "latency": lat, "stats": eng.stats}


def run_lockstep(rx, p_rx, tx, p_tx, fz, reqs, gen, *, max_batch, max_seq):
    srv = BatchedServer(rx, p_rx, max_batch=max_batch, max_seq=max_seq)
    tx_fused = make_tx_fused(tx, p_tx, fz, rx)
    pad = jnp.tile(reqs[0]["prompt"], (max_batch, 1))
    srv.serve(pad, 2)  # warm the standalone traces
    srv.serve(pad, 2, fused=tx_fused(pad))

    pending = list(reqs)
    done_at, arrival = {}, {}
    t0 = time.perf_counter()
    while pending:
        now = time.perf_counter() - t0
        avail = [r for r in pending if r["arrival"] <= now]
        if not avail:
            time.sleep(max(0.0, pending[0]["arrival"] - now))
            continue
        # lockstep constraint: one protocol (one shared fused prefix) per batch
        proto = avail[0]["protocol"]
        batch = [r for r in avail if r["protocol"] == proto][:max_batch]
        for r in batch:
            pending.remove(r)
        prompts = jnp.concatenate([r["prompt"] for r in batch], axis=0)
        n_real = prompts.shape[0]
        if n_real < max_batch:  # pad to the compiled batch width
            prompts = jnp.concatenate(
                [prompts, jnp.tile(prompts[-1:], (max_batch - n_real, 1))], 0)
        fused = tx_fused(prompts) if proto == "c2c" else None
        out = srv.serve(prompts, gen, fused=fused)
        jax.block_until_ready(out)
        t_done = time.perf_counter() - t0
        for i, r in enumerate(batch):
            rid = len(done_at)
            done_at[rid] = t_done
            arrival[rid] = r["arrival"]
    lat = [done_at[r] - arrival[r] for r in done_at]
    span = max(done_at.values()) - reqs[0]["arrival"]
    toks = len(done_at) * gen
    return {"tokens_per_s": toks / span, "latency": lat}


# ------------------------------------------------------- paged kernel


def run_paged_kernel(rx, p_rx, *, dense_slots, max_seq, page_size, prompt_len,
                     gen, vocab):
    """In-place paged-attention kernel vs the dense_view() gather reference.

    Two identical paged engines — one decoding through the Pallas kernel that
    walks page maps in place (the default), one through the old
    gathered-view path — serve the same burst at ≥2× dense-equivalent slot
    occupancy. The structural gates are (a) token-for-token identical
    outputs and (b) the kernel engine never gathering a dense view
    (``decode_view_gathers == 0``). The per-step KV HBM bytes are *analytic
    dataflow accounting* (kv_read_bytes_per_step: live pool pages the
    kernel's BlockSpec index map DMAs vs the slots·view_seq rows dense_view
    materialises by construction), sampled at peak occupancy — interpret
    mode has no hardware counters to measure against."""
    slots = 2 * dense_slots
    pages_per_slot = max_seq // page_size
    mk = lambda mode: ContinuousBatchingEngine(
        rx, p_rx, max_slots=slots, max_seq=max_seq, paged=True,
        page_size=page_size, num_pages=dense_slots * pages_per_slot,
        paged_attention=mode)
    key = jax.random.PRNGKey(13)
    prompts = [jax.random.randint(jax.random.fold_in(key, i),
                                  (1, prompt_len), 0, vocab)
               for i in range(slots)]

    outs = {}
    for mode in ("kernel", "gather"):
        eng = mk(mode)
        rids = [eng.submit(p, gen) for p in prompts]  # burst: all at once
        t0 = time.perf_counter()
        first = eng.step()  # all admitted: sample HBM traffic at peak occupancy
        bytes_per_step = eng.kv_read_bytes_per_step()
        occupancy = eng.num_active
        done = {c.rid: c.tokens for c in first + eng.drain()}
        dt = time.perf_counter() - t0
        outs[mode] = {
            "tokens": [done[r] for r in rids],
            "peak_active": eng.stats["peak_active"],
            "occupancy_at_sample": occupancy,
            "kv_read_bytes_per_step": bytes_per_step["paged_kernel"]
            if mode == "kernel" else bytes_per_step["dense_gather"],
            "decode_view_gathers": eng.stats["decode_view_gathers"],
            "tokens_per_s": len(prompts) * gen / dt,
        }

    identical = all(
        np.array_equal(a, b)
        for a, b in zip(outs["kernel"]["tokens"], outs["gather"]["tokens"]))
    section = {
        m: {kk: vv for kk, vv in v.items() if kk != "tokens"}
        for m, v in outs.items()
    }
    section["byte_identical_outputs"] = bool(identical)
    section["kernel_bytes_per_step"] = outs["kernel"]["kv_read_bytes_per_step"]
    section["gather_bytes_per_step"] = outs["gather"]["kv_read_bytes_per_step"]
    section["hbm_bytes_ratio"] = (section["kernel_bytes_per_step"]
                                  / max(section["gather_bytes_per_step"], 1))
    section["page_size"] = page_size
    section["occupancy_ratio_vs_dense"] = (
        outs["kernel"]["occupancy_at_sample"] / max(dense_slots, 1))
    return section


# ------------------------------------------------------- paged-vs-dense


def run_capacity(rx, p_rx, *, dense_slots, max_seq, page_size, prompt_len,
                 gen, n_requests, vocab):
    """Equal-HBM capacity comparison: dense table (dense_slots × max_seq rows)
    vs a paged pool of exactly the same byte size serving 2× the slots.

    Returns the per-section dict for the JSON report; the byte-identity
    verdict is returned as ``byte_identical_outputs`` (the paged table must
    be a pure layout change, never a numerics change — main() turns a False
    into a failing exit code). Only the equal-budget precondition asserts."""
    key = jax.random.PRNGKey(11)
    prompts = [jax.random.randint(jax.random.fold_in(key, i),
                                  (1, prompt_len), 0, vocab)
               for i in range(n_requests)]

    dense = ContinuousBatchingEngine(rx, p_rx, max_slots=dense_slots,
                                     max_seq=max_seq)
    pages_per_slot = max_seq // page_size
    paged = ContinuousBatchingEngine(
        rx, p_rx, max_slots=2 * dense_slots, max_seq=max_seq, paged=True,
        page_size=page_size, num_pages=dense_slots * pages_per_slot)
    assert paged.kv_table_bytes <= dense.kv_table_bytes, (
        paged.kv_table_bytes, dense.kv_table_bytes)

    outs = {}
    for name, eng in (("dense", dense), ("paged", paged)):
        rids = [eng.submit(p, gen) for p in prompts]  # burst: all at once
        t0 = time.perf_counter()
        done = {c.rid: c.tokens for c in eng.drain()}
        dt = time.perf_counter() - t0
        outs[name] = {
            "tokens": [done[r] for r in rids],
            "max_slots": eng.max_slots,
            "peak_active": eng.stats["peak_active"],
            "kv_table_bytes": eng.kv_table_bytes,
            "tokens_per_s": n_requests * gen / dt,
            "decode_traces": eng.stats["decode_traces"],
        }

    identical = all(
        np.array_equal(a, b)
        for a, b in zip(outs["dense"]["tokens"], outs["paged"]["tokens"]))
    section = {
        k: {kk: vv for kk, vv in v.items() if kk != "tokens"}
        for k, v in outs.items()
    }
    section["byte_identical_outputs"] = bool(identical)
    section["capacity_ratio"] = (outs["paged"]["peak_active"]
                                 / max(outs["dense"]["peak_active"], 1))
    section["page_size"] = page_size
    section["request_tokens"] = prompt_len + gen
    section["max_seq"] = max_seq
    return section


# ------------------------------------------------------- shared prefix


def run_shared_prefix(rx, p_rx, tx, p_tx, fz, *, vocab, n_requests=13,
                      shared_len=48, tail_len=8, gen=8, page_size=16,
                      num_pages=16):
    """Shared-system-prompt workload: every request carries the same
    ``shared_len``-token prefix plus a unique tail.

    With the radix prefix cache + CoW page sharing, only the first request
    prefills (and stores) the shared pages; every later admission shares them
    read-only and prefills just its tail. At a fixed page pool this multiplies
    the sustainable concurrent slots (each sharer needs 1 fresh page instead
    of 4 here) and divides prefill compute — while decode outputs must stay
    byte-identical to the unshared engine. A C2C sub-check pins fused-prefix
    amortisation: one transmitted prefix is inserted into the fused row table
    once and reused by digest for every later request."""
    key = jax.random.PRNGKey(17)
    shared = jax.random.randint(key, (1, shared_len), 0, vocab)
    prompts = []
    for i in range(n_requests):
        tail = jax.random.randint(jax.random.fold_in(key, i),
                                  (1, tail_len), 0, vocab)
        tail = tail.at[0, 0].set(i % vocab)  # tails diverge at token 0
        prompts.append(jnp.concatenate([shared, tail], axis=1))
    S = shared_len + tail_len
    max_seq = S + gen  # 4 pages per request at page_size=16

    outs = {}
    for name, pc in (("shared", True), ("unshared", False)):
        eng = ContinuousBatchingEngine(
            rx, p_rx, max_slots=n_requests + 1, max_seq=max_seq, paged=True,
            page_size=page_size, num_pages=num_pages, prefix_cache=pc)
        rids = [eng.submit(p, gen) for p in prompts]
        t0 = time.perf_counter()
        done = {c.rid: c.tokens for c in eng.drain()}
        dt = time.perf_counter() - t0
        st = eng.stats
        outs[name] = {
            "tokens": [done[r] for r in rids],
            "peak_active": st["peak_active"],
            "prefill_tokens": st["prefill_tokens"],
            "radix_hits": st["radix_hits"],
            "cow_copies": st["cow_copies"],
            "decode_traces": st["decode_traces"],
            "tokens_per_s": n_requests * gen / dt,
        }

    identical = all(
        np.array_equal(a, b)
        for a, b in zip(outs["shared"]["tokens"], outs["unshared"]["tokens"]))

    # fused-digest amortisation: one transmitted prefix, many requests
    tx_fused = make_tx_fused(tx, p_tx, fz, rx)
    fused = tx_fused(prompts[0][:, :8])
    feng = ContinuousBatchingEngine(
        rx, p_rx, max_slots=4, max_seq=max_seq, max_prefix=8, paged=True,
        page_size=page_size, num_pages=num_pages)
    for p in prompts[:4]:
        feng.submit(p, 4, fused=fused)
    feng.drain()

    section = {
        name: {kk: vv for kk, vv in v.items() if kk != "tokens"}
        for name, v in outs.items()
    }
    section["byte_identical_outputs"] = bool(identical)
    section["slot_ratio"] = (outs["shared"]["peak_active"]
                             / max(outs["unshared"]["peak_active"], 1))
    section["prefill_token_ratio"] = (outs["shared"]["prefill_tokens"]
                                      / max(outs["unshared"]["prefill_tokens"],
                                            1))
    section["fused_inserts"] = feng.stats["fused_inserts"]
    section["fused_digest_hits"] = feng.stats["fused_digest_hits"]
    section["shared_len"] = shared_len
    section["tail_len"] = tail_len
    section["page_size"] = page_size
    section["num_pages"] = num_pages
    return section


def run_sanitized(rx, p_rx, *, vocab, n_requests=6, shared_len=26,
                  tail_len=6, gen=6, page_size=8, num_pages=32):
    """Page-lifecycle sanitizer gate: the shared-prefix/CoW workload under
    ``sanitize=True`` must (a) finish — every step's allocator/shadow/device
    cross-check passes and drain()'s leak report is empty — and (b) emit
    byte-identical tokens to the unsanitized engine. The shared prefix
    straddles a page boundary so the CoW fault path is on the audited
    route too."""
    key = jax.random.PRNGKey(23)
    shared = jax.random.randint(key, (1, shared_len), 0, vocab)
    prompts = []
    for i in range(n_requests):
        tail = jax.random.randint(jax.random.fold_in(key, i),
                                  (1, tail_len), 0, vocab)
        tail = tail.at[0, 0].set(i % vocab)
        prompts.append(jnp.concatenate([shared, tail], axis=1))
    need = shared_len + tail_len + gen
    max_seq = -(-need // page_size) * page_size  # page-aligned

    outs = {}
    for name, sanitize in (("sanitized", True), ("plain", False)):
        eng = ContinuousBatchingEngine(
            rx, p_rx, max_slots=n_requests, max_seq=max_seq, paged=True,
            page_size=page_size, num_pages=num_pages, sanitize=sanitize)
        rids = [eng.submit(p, gen) for p in prompts]
        done = {c.rid: c.tokens for c in eng.drain()}  # raises on violations
        outs[name] = {"tokens": [done[r] for r in rids],
                      "leaks": len(eng.sanitizer_report()),
                      "cow_copies": eng.stats["cow_copies"],
                      "shared_admits": eng.stats["shared_admits"]}

    return {
        "leak_report_findings": outs["sanitized"]["leaks"],
        "shared_admits": outs["sanitized"]["shared_admits"],
        "cow_copies": outs["sanitized"]["cow_copies"],
        "byte_identical_outputs": bool(all(
            np.array_equal(a, b)
            for a, b in zip(outs["sanitized"]["tokens"],
                            outs["plain"]["tokens"]))),
    }


def run_audited(*, vocab, n_requests=6, prompt_len=6, gen=6):
    """Wire-audit gate: mixed C2C/T2T traffic through
    ``FedRefineSystem.build(audit_wire=True)`` must (a) finish — every
    transmitted message passes the protocol's WireSchema check (media,
    dtypes, codec stages, commload byte accounting) — with an empty audit
    report, and (b) emit byte-identical tokens and identical per-request
    wire_bytes to the unaudited system. CI fails on any finding."""
    zoo = tiny_zoo(vocab_size=vocab)
    key = jax.random.PRNGKey(29)
    members = [
        Participant(cfg.name, cfg,
                    T.init_params(cfg, jax.random.fold_in(key, i),
                                  jnp.float32))
        for i, cfg in enumerate([zoo["receiver"], *zoo["transmitters"]])]
    rx = members[0].name
    prompts = [jax.random.randint(jax.random.fold_in(key, 100 + i),
                                  (1, prompt_len), 0, vocab)
               for i in range(n_requests)]

    outs = {}
    auditor = None
    for name, audit in (("audited", True), ("plain", False)):
        sys_ = FedRefineSystem.build(members, audit_wire=audit)
        rids = [sys_.submit(rx, p, gen,
                            protocol="c2c" if i % 2 else "t2t",
                            key=jax.random.PRNGKey(7))
                for i, p in enumerate(prompts)]
        done = sys_.drain(rx)  # raises WireAuditError on violations
        outs[name] = {
            "tokens": [np.asarray(done[r]["tokens"]) for r in rids],
            "wire_bytes": [done[r].get("wire_bytes", 0) for r in rids]}
        if audit:
            auditor = sys_.wire

    return {
        "audit_findings": len(auditor.report()),
        "audited_messages": len(auditor.records),
        "audited_protocols": sorted({r.protocol for r in auditor.records}),
        "audited_wire_bytes": int(sum(r.measured_bytes
                                      for r in auditor.records)),
        "byte_identical_outputs": bool(all(
            np.array_equal(a, b)
            for a, b in zip(outs["audited"]["tokens"],
                            outs["plain"]["tokens"]))),
        "wire_bytes_match": bool(
            outs["audited"]["wire_bytes"] == outs["plain"]["wire_bytes"]),
    }


# ------------------------------------------------------- chunked prefill


def run_chunked(rx, p_rx, *, vocab, budget=16, n_short=24, short_len=8,
                short_every=7, n_long=4, long_len=144, gen=28, slots=6,
                page_size=64, repeats=5, retrace_guard=False):
    """Mixed long-prompt + decode workload: bucketed monolithic prefill vs
    chunked prefill.

    The baseline is the engine's own pre-chunking admission mode —
    bucketed-and-padded monolithic prefill (``prompt_bucket`` sized to the
    long prompts, the configuration that keeps prefill traces O(#buckets)):
    every admission pays a full bucket-wide forward in the step that admits
    it, so when a burst of long prompts lands mid-decode that step stalls
    every decoding slot for the full prefills — that stall IS the p99 step
    latency. The chunked engine (``prefill_token_budget=budget``) spends at
    most ``budget`` prefill tokens per step through the ragged kernel (no
    pad rows) and interleaves them with decode, bounding the hiccup
    in-flight decodes see. Both engines serve the same step-indexed
    schedule (shorts arrive at a steady ``short_every``-step spacing across
    the whole run, so the span is arrival-limited and slots keep decoding
    through both engines' tails; the long prompts arrive in a burst spread
    over two adjacent steps, so the monolithic stall occupies the top order
    statistics rather than one interpolated-away sample). The schedule is
    deterministic in *step index*, so every pass visits identical engine
    states step for step: per-step wall latency is the element-wise MIN
    across ``repeats`` passes (best-of-N per measurement point — a one-off
    OS hiccup in any pass cannot fake a stall), and p99/TTFT/span all
    derive from that de-noised step-time vector (TTFT of a request =
    summed step times from its submit step to its first-token step).
    Outputs must stay byte-identical — chunking is a scheduling change,
    never a numerics change — and the chunk path must compile exactly
    once."""
    bucket = -(-long_len // page_size) * page_size
    max_seq = -(-(bucket + gen + 4) // page_size) * page_size
    pps = max_seq // page_size
    key = jax.random.PRNGKey(31)
    shorts = [jax.random.randint(jax.random.fold_in(key, i),
                                 (1, short_len), 0, vocab)
              for i in range(n_short)]
    longs = [jax.random.randint(jax.random.fold_in(key, 100 + j),
                                (1, long_len), 0, vocab)
             for j in range(n_long)]
    submits = {}
    for i, p in enumerate(shorts):
        submits.setdefault(i * short_every, []).append(p)
    for j, p in enumerate(longs):  # burst over two adjacent steps
        submits.setdefault(3 + (j % 2), []).append(p)

    def one_pass(eng):
        sched = {k: list(v) for k, v in submits.items()}
        step_times, outs, rids = [], {}, []
        sub_step, first_step = {}, {}
        i = 0
        while sched or eng.num_queued or eng.num_active or eng.num_partial:
            for p in sched.pop(i, []):
                rid = eng.submit(p, gen)
                rids.append(rid)
                sub_step[rid] = i
            s0 = time.perf_counter()
            for c in eng.step():
                outs[c.rid] = np.asarray(c.tokens)
            step_times.append(time.perf_counter() - s0)
            for rid in rids:
                if rid not in first_step and (rid in outs
                                              or eng.first_token_ready(rid)):
                    first_step[rid] = i
            i += 1
        return step_times, sub_step, first_step, outs, rids

    def make(budget_):
        eng = ContinuousBatchingEngine(
            rx, p_rx, max_slots=slots, max_seq=max_seq, paged=True,
            page_size=page_size, num_pages=slots * pps, prefix_cache=False,
            prompt_bucket=None if budget_ else bucket,
            prefill_token_budget=budget_)
        # warm every trace outside the clock: one bucketed prefill signature
        # for the monolithic engine / one chunk signature, adopt, decode
        eng.submit(shorts[0], 2)
        eng.submit(longs[0], 2)
        eng.drain()
        return eng

    engines = {"monolithic": make(None), "chunked": make(budget)}
    guard = (TraceGuard(max_traces={"decode": 0, "prefill": 0,
                                    "cprefill": 0})
             if retrace_guard else nullcontext())
    passes = {n: [] for n in engines}
    gc.collect()
    gc.disable()
    try:
        with guard:
            for _ in range(repeats):  # interleaved passes: slow machine
                for n, eng in engines.items():  # drift hits both engines
                    passes[n].append(one_pass(eng))
    finally:
        gc.enable()

    res = {}
    for name, eng in engines.items():
        ps = passes[name]
        _, sub_step, first_step, outs, rids = ps[0]
        assert all(len(p[0]) == len(ps[0][0]) for p in ps), \
            "step schedule must be deterministic across passes"
        # element-wise min across passes: the schedule is step-deterministic,
        # so step i does identical work in every pass and the min is the
        # clean cost of that step (OS noise is one-sided)
        st = np.min([p[0] for p in ps], axis=0)
        cum = np.cumsum(st)
        ttft = {r: float(cum[first_step[r]]
                         - (cum[sub_step[r] - 1] if sub_step[r] else 0.0))
                for r in rids}
        total = float(st.sum())
        p50, p99 = percentiles(list(st))
        tp50, tp99 = percentiles(list(ttft.values()))
        res[name] = {"tokens": [outs[r] for r in rids],
                     "p50_step_s": p50, "p99_step_s": p99,
                     "ttft_p50_s": tp50, "ttft_p99_s": tp99,
                     "tokens_per_s": len(rids) * gen / total,
                     "steps": len(st),
                     "prefill_traces": eng.stats["prefill_traces"],
                     "prefill_chunks": eng.stats["prefill_chunks"]}

    identical = all(np.array_equal(a, b) for a, b in
                    zip(res["monolithic"]["tokens"], res["chunked"]["tokens"]))
    section = {n: {k: v for k, v in r.items() if k != "tokens"}
               for n, r in res.items()}
    section["byte_identical_outputs"] = bool(identical)
    section["budget"] = budget
    section["bucket"] = bucket
    section["short_len"] = short_len
    section["short_every"] = short_every
    section["long_len"] = long_len
    section["gen"] = gen
    section["p99_step_ratio"] = (res["chunked"]["p99_step_s"]
                                 / max(res["monolithic"]["p99_step_s"], 1e-9))
    section["ttft_p99_ratio"] = (res["chunked"]["ttft_p99_s"]
                                 / max(res["monolithic"]["ttft_p99_s"], 1e-9))
    section["tokens_per_s_ratio"] = (res["chunked"]["tokens_per_s"]
                                     / max(res["monolithic"]["tokens_per_s"],
                                           1e-9))
    return section


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes + invariant checks (CI); overrides "
                         "--requests/--gen/--slots/--rate")
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--rate", type=float, default=8.0,
                    help="Poisson arrival rate (req/s)")
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--json", type=str, default="BENCH_engine.json",
                    help="write results JSON here ('' disables)")
    args = ap.parse_args()
    if args.smoke:
        args.requests, args.gen, args.slots = 10, 8, 4
        args.rate = 50.0
    if args.rate <= 0:
        ap.error("--rate must be > 0")

    vocab = 64
    rx, p_rx, tx, p_tx, fz = build_world(vocab)
    max_seq = args.prompt_len + args.gen + 8
    reqs = make_requests(args.requests, args.prompt_len, args.rate, vocab)

    eng = run_engine(rx, p_rx, tx, p_tx, fz, reqs, args.gen,
                     max_slots=args.slots, max_seq=max_seq,
                     max_prefix=args.prompt_len, retrace_guard=args.smoke)
    lck = run_lockstep(rx, p_rx, tx, p_tx, fz, reqs, args.gen,
                       max_batch=args.slots, max_seq=max_seq)

    ep50, ep99 = percentiles(eng["latency"])
    lp50, lp99 = percentiles(lck["latency"])
    print(f"\n{args.requests} requests, Poisson rate {args.rate}/s, "
          f"gen {args.gen} tok, {args.slots} slots, mixed standalone+C2C")
    print(f"{'':22s}{'tokens/s':>10s}{'p50 (s)':>10s}{'p99 (s)':>10s}")
    print(f"{'continuous (engine)':22s}{eng['tokens_per_s']:>10.1f}"
          f"{ep50:>10.3f}{ep99:>10.3f}")
    print(f"{'lockstep (Batched)':22s}{lck['tokens_per_s']:>10.1f}"
          f"{lp50:>10.3f}{lp99:>10.3f}")
    print(f"engine stats: {eng['stats']}")

    # --- paged-vs-dense capacity at equal HBM (short requests, long rows) ---
    cap_seq = 128  # dense row length; requests use ~1/4 of it
    dense_slots = max(2, args.slots // 2)
    cap = run_capacity(rx, p_rx, dense_slots=dense_slots, max_seq=cap_seq,
                       page_size=16, prompt_len=args.prompt_len,
                       gen=args.gen, n_requests=4 * dense_slots, vocab=vocab)
    print(f"\npaged-vs-dense capacity at equal KV HBM "
          f"({cap['dense']['kv_table_bytes'] / 1e6:.1f} MB pool, "
          f"requests of {cap['request_tokens']} tok in max_seq={cap_seq}):")
    print(f"{'':22s}{'slots':>8s}{'peak act':>10s}{'tok/s':>10s}{'KV MB':>8s}")
    for name in ("dense", "paged"):
        r = cap[name]
        print(f"{name:22s}{r['max_slots']:>8d}{r['peak_active']:>10d}"
              f"{r['tokens_per_s']:>10.1f}"
              f"{r['kv_table_bytes'] / 1e6:>8.1f}")
    print(f"capacity ratio (paged/dense peak slots): "
          f"{cap['capacity_ratio']:.2f}×; byte-identical outputs: "
          f"{cap['byte_identical_outputs']}")

    # --- in-place paged kernel vs dense_view gather (per-step HBM bytes) ---
    pk = run_paged_kernel(rx, p_rx, dense_slots=dense_slots, max_seq=cap_seq,
                          page_size=16, prompt_len=args.prompt_len,
                          gen=args.gen, vocab=vocab)
    print(f"\npaged decode: in-place kernel vs dense_view gather "
          f"({pk['occupancy_ratio_vs_dense']:.1f}x dense-equivalent "
          f"occupancy):")
    print(f"{'':22s}{'KV B/step':>12s}{'gathers':>9s}{'tok/s':>10s}")
    for mode in ("kernel", "gather"):
        r = pk[mode]
        print(f"{mode:22s}{r['kv_read_bytes_per_step']:>12d}"
              f"{r['decode_view_gathers']:>9d}{r['tokens_per_s']:>10.1f}")
    print(f"HBM bytes ratio (kernel/gather): {pk['hbm_bytes_ratio']:.3f}; "
          f"byte-identical outputs: {pk['byte_identical_outputs']}")

    # --- shared-prefix page sharing (radix cache + CoW) at a fixed pool ---
    sp = run_shared_prefix(rx, p_rx, tx, p_tx, fz, vocab=vocab)
    print(f"\nshared-prefix workload ({sp['shared_len']}-token shared prefix "
          f"+ {sp['tail_len']}-token tails, {sp['num_pages']}-page pool):")
    print(f"{'':22s}{'peak act':>10s}{'prefill tok':>13s}{'tok/s':>10s}")
    for name in ("unshared", "shared"):
        r = sp[name]
        print(f"{name:22s}{r['peak_active']:>10d}{r['prefill_tokens']:>13d}"
              f"{r['tokens_per_s']:>10.1f}")
    print(f"slot ratio (shared/unshared peak): {sp['slot_ratio']:.2f}×; "
          f"prefill tokens ratio: {sp['prefill_token_ratio']:.2f}; "
          f"byte-identical outputs: {sp['byte_identical_outputs']}; "
          f"fused inserts {sp['fused_inserts']} "
          f"(+{sp['fused_digest_hits']} digest hits)")

    # --- page-lifecycle sanitizer over the sharing/CoW paths -------------
    sz = run_sanitized(rx, p_rx, vocab=vocab)
    print(f"\nsanitized run: {sz['shared_admits']} shared admits, "
          f"{sz['cow_copies']} CoW copies, "
          f"{sz['leak_report_findings']} leak-report finding(s), "
          f"byte-identical outputs: {sz['byte_identical_outputs']}")

    # --- wire-contract audit over mixed C2C/T2T federation traffic -------
    au = run_audited(vocab=vocab)
    print(f"\naudited run: {au['audited_messages']} message(s) "
          f"({'/'.join(au['audited_protocols'])}) totalling "
          f"{au['audited_wire_bytes']} B on wire, "
          f"{au['audit_findings']} audit finding(s), "
          f"byte-identical outputs: {au['byte_identical_outputs']}, "
          f"wire-bytes match: {au['wire_bytes_match']}")

    # --- chunked prefill vs monolithic under mixed long-prompt traffic ----
    if args.smoke:
        ck = run_chunked(rx, p_rx, vocab=vocab, n_short=8, short_every=8,
                         n_long=4, long_len=128, gen=16, slots=6,
                         retrace_guard=True)
    else:
        ck = run_chunked(rx, p_rx, vocab=vocab, retrace_guard=True)
    print(f"\nchunked prefill (budget {ck['budget']} tok/step) vs monolithic, "
          f"{ck['long_len']}-token long prompts over {ck['short_len']}-token "
          f"decode traffic:")
    print(f"{'':22s}{'p50 step':>10s}{'p99 step':>10s}{'TTFT p99':>10s}"
          f"{'tok/s':>10s}")
    for name in ("monolithic", "chunked"):
        r = ck[name]
        print(f"{name:22s}{r['p50_step_s'] * 1e3:>9.1f}m"
              f"{r['p99_step_s'] * 1e3:>9.1f}m"
              f"{r['ttft_p99_s'] * 1e3:>9.1f}m{r['tokens_per_s']:>10.1f}")
    print(f"p99 step ratio (chunked/monolithic): {ck['p99_step_ratio']:.3f}; "
          f"TTFT p99 ratio: {ck['ttft_p99_ratio']:.3f}; "
          f"tokens/s ratio: {ck['tokens_per_s_ratio']:.3f}; "
          f"byte-identical outputs: {ck['byte_identical_outputs']}; "
          f"{ck['chunked']['prefill_chunks']} chunks / "
          f"{ck['chunked']['prefill_traces']} trace")

    # --- ragged packing vs padded buckets: analytic dataflow accounting ---
    ra = ragged_prefill_analytics(
        [ck["long_len"]] * 2 + [ck["short_len"]] * 6,
        bucket=-(-ck["long_len"] // 8) * 8, H=rx.num_heads,
        Hkv=rx.num_kv_heads, hd=rx.head_dim, page_size=16)
    print(f"\nragged prefill packing vs {ra['bucket']}-token padded buckets "
          f"(analytic): FLOPs x{ra['flops_ratio']:.3f}, "
          f"KV HBM bytes x{ra['hbm_bytes_ratio']:.3f}")

    ok = True
    if eng["stats"]["decode_traces"] != 1:
        print("FAIL: decode step traced more than once across the mix")
        ok = False
    # smoke (CI, shared runners): allow wall-clock noise a generous margin so
    # a noisy-neighbour hiccup can't fail an unrelated PR; full runs are strict
    margin = 0.8 if args.smoke else 1.0
    if eng["tokens_per_s"] < margin * lck["tokens_per_s"]:
        print("FAIL: engine slower than lockstep baseline")
        ok = False
    if not cap["byte_identical_outputs"]:
        print("FAIL: paged decode outputs differ from dense reference")
        ok = False
    if cap["capacity_ratio"] < 2.0:
        print("FAIL: paged table sustained < 2x dense concurrent slots")
        ok = False
    if not pk["byte_identical_outputs"]:
        print("FAIL: in-place paged kernel outputs differ from the "
              "dense_view gather path")
        ok = False
    if pk["kernel"]["decode_view_gathers"] != 0:
        print("FAIL: kernel-path decode still gathered a dense view")
        ok = False
    if pk["kernel_bytes_per_step"] >= pk["gather_bytes_per_step"]:
        print("FAIL: in-place kernel did not reduce per-step KV HBM bytes")
        ok = False
    if not sp["byte_identical_outputs"]:
        print("FAIL: shared-prefix decode outputs differ from the unshared "
              "engine")
        ok = False
    if sp["slot_ratio"] < 2.0:
        print("FAIL: page sharing sustained < 2x the unshared concurrent "
              "slots at the same pool")
        ok = False
    if sp["shared"]["prefill_tokens"] >= sp["unshared"]["prefill_tokens"]:
        print("FAIL: prefix cache did not reduce prefill tokens")
        ok = False
    if sp["fused_inserts"] != 1 or sp["fused_digest_hits"] != 3:
        print("FAIL: fused prefix not amortised across same-digest requests")
        ok = False
    if sz["leak_report_findings"] != 0:
        print("FAIL: sanitizer leak report is non-empty after drain")
        ok = False
    if not sz["byte_identical_outputs"]:
        print("FAIL: sanitize=True changed decode outputs")
        ok = False
    if au["audit_findings"] != 0:
        print("FAIL: wire audit report is non-empty after drain")
        ok = False
    if au["audited_messages"] == 0:
        print("FAIL: audited run transmitted no messages — the auditor "
              "was not on the wire path")
        ok = False
    if not au["byte_identical_outputs"]:
        print("FAIL: audit_wire=True changed decode outputs")
        ok = False
    if not au["wire_bytes_match"]:
        print("FAIL: audit_wire=True changed per-request wire_bytes")
        ok = False
    if not ck["byte_identical_outputs"]:
        print("FAIL: chunked prefill changed decode outputs")
        ok = False
    if ck["chunked"]["prefill_traces"] != 1:
        print("FAIL: chunk prefill traced more than once across the mix")
        ok = False
    if ck["p99_step_ratio"] >= 1.0:
        print("FAIL: chunked prefill did not cut p99 step latency")
        ok = False
    tok_floor = 0.8 if args.smoke else 0.95
    if ck["tokens_per_s_ratio"] < tok_floor:
        print(f"FAIL: chunked prefill dropped tokens/s below "
              f"{tok_floor:.2f}x monolithic")
        ok = False
    if ra["flops_ratio"] >= 1.0 or ra["hbm_bytes_ratio"] >= 1.0:
        print("FAIL: ragged packing does not beat padded buckets analytically")
        ok = False

    if args.json:
        report = {
            "bench": "engine",
            "config": {"requests": args.requests,
                       "prompt_len": args.prompt_len, "gen": args.gen,
                       "rate": args.rate, "slots": args.slots,
                       "smoke": bool(args.smoke)},
            "throughput": {
                "engine_tokens_per_s": eng["tokens_per_s"],
                "engine_p50_s": ep50, "engine_p99_s": ep99,
                "lockstep_tokens_per_s": lck["tokens_per_s"],
                "lockstep_p50_s": lp50, "lockstep_p99_s": lp99,
                "engine_stats": eng["stats"],
            },
            "capacity": cap,
            "paged_kernel": pk,
            "shared_prefix": sp,
            "sanitized": sz,
            "audited": au,
            "chunked_prefill": ck,
            "ragged_prefill": ra,
            "pass": ok,
        }
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
        print(f"wrote {args.json}")

    print("PASS" if ok else "FAIL")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
