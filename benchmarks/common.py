"""Shared case-study harness for the paper-figure benchmarks.

Builds the simulated case study (DESIGN.md §1, repro band 2): the five-member
heterogeneous tiny zoo, knowledge-partitioned synthetic world, trained
transmitters (each on its own domain), a weak generalist receiver, trained
fusers, and the evaluation loop. All benchmarks share one cached build so
``python -m benchmarks.run`` trains everything exactly once.
"""
from __future__ import annotations

import functools
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.case_study import tiny_zoo
from repro.core import c2c, fuser as F
from repro.core.fedrefine import FedRefineSystem, Participant
from repro.core.fuser_training import train_fuser
from repro.data.synthetic import World, WorldSpec, lm_stream
from repro.launch.train import train_loop
from repro.models.cache import FusedPrefix
from repro.models import transformer as T

CKPT = os.path.join(os.path.dirname(__file__), "..", "experiments", "case_study")

# sized for single-core CPU: enough training to separate the curves, not more.
# Epistemic setup (paper's regime): transmitters master their own domain (all
# facts); the receiver masters the task FORMAT + the 30% receiver-known fact
# subset; evaluation asks receiver-UNSEEN facts, so standalone ≈ chance and the
# knowledge must arrive over the federation medium.
TRAIN_STEPS = int(os.environ.get("CS_TRAIN_STEPS", 300))
RX_STEPS = int(os.environ.get("CS_RX_STEPS", 300))
FUSER_STEPS = int(os.environ.get("CS_FUSER_STEPS", 400))
BATCH, SEQ = 8, 24
EVAL_N = int(os.environ.get("CS_EVAL_N", 128))
EVAL_KNOWN = None if os.environ.get("CS_EVAL_ALL") else False


@functools.lru_cache(maxsize=1)
def build_case_study():
    """Train the zoo + fusers once; returns a dict with everything benchmarks need."""
    t0 = time.time()
    world = World(WorldSpec(seed=0))
    zoo = tiny_zoo(vocab_size=world.spec.vocab_size)
    rx_cfg = zoo["receiver"]
    tx_cfgs = zoo["transmitters"]

    # --- transmitters: each an expert on its own knowledge domain -----------
    participants = []
    for d, cfg in enumerate(tx_cfgs):
        stream = lm_stream(world, 100 + d, BATCH, SEQ, domain=d)
        params, losses = train_loop(cfg, stream, TRAIN_STEPS, lr=1e-3,
                                    seed=d, verbose=False)
        participants.append(Participant(cfg.name, cfg, params))
        print(f"  [build] {cfg.name}: domain {d} loss "
              f"{losses[0]:.3f}->{losses[-1]:.3f} ({time.time()-t0:.0f}s)")

    # --- receiver: task-format expert on the receiver-known fact subset -----
    stream = lm_stream(world, 999, BATCH, SEQ, domain=None, known=True)
    rx_params, losses = train_loop(rx_cfg, stream, RX_STEPS, lr=1e-3,
                                   seed=42, verbose=False)
    receiver = Participant(rx_cfg.name, rx_cfg, rx_params)
    print(f"  [build] {rx_cfg.name} (receiver): loss "
          f"{losses[0]:.3f}->{losses[-1]:.3f}")

    system = FedRefineSystem.build([receiver, *participants])
    system.channel = world.synonym_channel()

    # --- fusers: one per transmitter -> receiver link ------------------------
    channel = system.channel
    for d, tx in enumerate(participants):
        def batches(dd=d):
            # transport task: QUESTION-ONLY rows (answers live solely in the
            # transmitter's weights — question_batch docstring explains the
            # cheating failure mode this prevents) whose answers the receiver
            # does NOT know. tx and rx see DIFFERENT rephrasings (the privacy
            # regime of Fig. 2).
            rng = np.random.default_rng(500 + dd)
            i = 0
            while True:
                # seq=4 single-question rows: EXACTLY the eval prompt shape
                # (packed longer rows train fine but eval at len 4 is then
                # out-of-distribution — pilot-2 lesson)
                b = world.question_batch(rng, 4 * BATCH, 4, domain=dd,
                                         known=False)
                toks = jnp.asarray(b["tokens"])
                k1 = jax.random.PRNGKey(2 * i)
                k2 = jax.random.PRNGKey(2 * i + 1)
                i += 1
                yield {"tx_tokens": channel.rephrase(toks, k1),
                       "rx_tokens": channel.rephrase(toks, k2),
                       "labels": jnp.asarray(b["labels"])}
        fz, _, hist = train_fuser(tx.cfg, rx_cfg, tx.params, rx_params,
                                  batches(), steps=FUSER_STEPS, lr=2e-3)
        system.registry.fusers[(tx.name, rx_cfg.name)] = fz
        print(f"  [build] fuser {tx.name}->rx: loss {hist[0]:.3f}->{hist[-1]:.3f}")

    # --- gating network: learn to SELECT the right transmitter per question --
    # (paper: "a gating network is required for each LLM to select the data
    # from its own model or other fusers"). Individual fusers transport
    # knowledge (~80% in-domain), but concatenating 4 prefixes of which 3 are
    # out-of-domain interferes; the gate learns per-request weights.
    gating, new_fusers, g_hist = train_gating(
        world, system, receiver, participants,
        steps=int(os.environ.get("CS_GATE_STEPS", 250)))
    system.registry.gating[rx_cfg.name] = gating
    for t, fz in zip(participants, new_fusers):
        system.registry.fusers[(t.name, rx_cfg.name)] = fz
    print(f"  [build] joint federation refinement: loss "
          f"{g_hist[0]:.3f}->{g_hist[-1]:.3f}")

    print(f"  [build] case study ready in {time.time()-t0:.0f}s")
    return {"world": world, "system": system, "receiver": receiver,
            "transmitters": participants}


def train_gating(world, system, receiver, transmitters, *, steps=250, lr=2e-3):
    """JOINT federation refinement (the paper's "continuous global federation
    iterations"): train the gating network AND all fusers together on
    mixed-domain question batches with every transmitter present (full Eq. 4).
    Individually-pretrained fusers steer confidently even out-of-domain;
    joint training teaches each link to stand down when its transmitter
    doesn't know (pilot-4 lesson: gate-only training cannot fix this)."""
    from repro.core.gating import apply_gates, init_gating
    from repro.optim.adamw import AdamWConfig, apply_updates, init_opt_state

    rx = receiver
    channel = system.channel
    fusers0 = [system.registry.get(t.name, rx.name) for t in transmitters]
    cfgs = [t.cfg for t in transmitters]
    gating0 = init_gating(rx.cfg, jax.random.PRNGKey(77))
    opt_cfg = AdamWConfig(lr=lr, schedule="cosine", total_steps=steps)

    def loss_fn(trainable, tx_toks, rx_toks, labels, mask):
        fusers, gating = trainable
        projected = []
        for i, (tx, fz, cfg) in enumerate(zip(transmitters, fusers, cfgs)):
            _, cache = T.prefill(cfg, jax.lax.stop_gradient(tx.params),
                                 tx_toks[i], max_seq=tx_toks.shape[-1],
                                 cache_dtype=jnp.float32)
            st = jax.lax.stop_gradient(
                cache.export_stack(cfg, length=tx_toks.shape[-1]))
            projected.append(F.project_cache(fz, cfg, rx.cfg, st))
        gated = apply_gates(gating, projected)
        # transmitter-subset dropout: every federation size is in-distribution
        # (evaluating n < N transmitters otherwise degrades — pilot-5 lesson)
        gated = [p.with_bias(p.bias + jnp.log(mask[i]))
                 for i, p in enumerate(gated)]
        fused = FusedPrefix.concat(gated)
        logits, _ = c2c.c2c_forward(rx.cfg, jax.lax.stop_gradient(rx.params),
                                    rx_toks, fused)
        logits = logits.astype(jnp.float32)
        valid = labels >= 0
        safe = jnp.where(valid, labels, 0)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
        return jnp.sum(nll * valid) / jnp.maximum(jnp.sum(valid), 1)

    trainable = (fusers0, gating0)
    opt_state = init_opt_state(trainable)

    @jax.jit
    def step(trainable, opt_state, tx_toks, rx_toks, labels, mask):
        loss, grads = jax.value_and_grad(loss_fn, allow_int=True)(
            trainable, tx_toks, rx_toks, labels, mask)
        t2, s2 = apply_updates(opt_cfg, trainable, grads, opt_state)
        return t2, s2, loss

    rng = np.random.default_rng(888)
    hist = []
    n_tx = len(transmitters)
    for i in range(steps):
        b = world.question_batch(rng, 2 * BATCH, 4, domain=None, known=False)
        toks = jnp.asarray(b["tokens"])
        tx_toks = jnp.stack([
            channel.rephrase(toks, jax.random.PRNGKey(1000 * i + j))
            for j in range(n_tx)])
        rx_toks = channel.rephrase(toks, jax.random.PRNGKey(1000 * i + 99))
        keep = rng.random(n_tx) < 0.7
        if not keep.any():
            keep[rng.integers(n_tx)] = True
        mask = jnp.asarray(keep, jnp.float32)
        trainable, opt_state, loss = step(trainable, opt_state, tx_toks,
                                          rx_toks, jnp.asarray(b["labels"]),
                                          mask)
        hist.append(float(loss))
    fusers, gating = trainable
    return gating, fusers, hist


# ------------------------------------------------------------------- eval


def answer_accuracy_standalone(p: Participant, world: World, rng, n=EVAL_N,
                               rephrase_key=None, channel=None) -> float:
    ev = world.eval_batch(rng, n, known=EVAL_KNOWN)
    prompts = jnp.asarray(ev["prompt"])
    if channel is not None and rephrase_key is not None:
        prompts = channel.rephrase(prompts, rephrase_key)
    logits, _ = T.forward(p.cfg, p.params, prompts)
    pred = jnp.argmax(logits[:, -1], axis=-1)
    return float(jnp.mean(pred == jnp.asarray(ev["answer"])))


def answer_accuracy_c2c(cs, tx_names, rng, n=EVAL_N, *, rephrased=True,
                        key=None, gated: bool = True) -> float:
    """Receiver answers with fused caches from ``tx_names`` (Eq. 4, gated)."""
    world, system, rx = cs["world"], cs["system"], cs["receiver"]
    key = key if key is not None else jax.random.PRNGKey(0)
    ev = world.eval_batch(rng, n, known=EVAL_KNOWN)
    prompts = jnp.asarray(ev["prompt"])
    answers = jnp.asarray(ev["answer"])
    if not tx_names:
        logits, _ = T.forward(rx.cfg, rx.params, prompts)
        return float(jnp.mean(jnp.argmax(logits[:, -1], -1) == answers))

    stacks, fusers, cfgs = [], [], []
    for i, name in enumerate(tx_names):
        tx = system.participants[name]
        tp = (system.channel.rephrase(prompts, jax.random.fold_in(key, i))
              if rephrased else prompts)
        S = tp.shape[1]
        _, cache = T.prefill(tx.cfg, tx.params, tp, max_seq=S,
                             cache_dtype=jnp.float32)
        stacks.append(cache.export_stack(tx.cfg, length=S))
        fusers.append(system.registry.get(name, rx.name))
        cfgs.append(tx.cfg)
    rx_prompts = (system.channel.rephrase(prompts, jax.random.fold_in(key, 99))
                  if rephrased else prompts)
    gating = system.registry.gating.get(rx.name) if gated else None
    fused = c2c.fused_prefix(fusers, cfgs, rx.cfg, stacks, gating=gating)
    logits, _ = c2c.c2c_forward(rx.cfg, rx.params, rx_prompts, fused)
    return float(jnp.mean(jnp.argmax(logits[:, -1], -1) == answers))


def answer_accuracy_t2t(cs, tx_names, rng, n=EVAL_N, *, rephrased=True,
                        key=None) -> float:
    """T2T baseline: each transmitter ships its question+answer AS TEXT
    ([Q s r A o_tx SEP], the receiver's trained packed-QA format); the receiver
    re-prefills everything and answers its own copy of the question — paying
    the full prefill rebuild the paper charges T2T with."""
    from repro.data.synthetic import SEP_TOK

    world, system, rx = cs["world"], cs["system"], cs["receiver"]
    key = key if key is not None else jax.random.PRNGKey(0)
    ev = world.eval_batch(rng, n, known=EVAL_KNOWN)
    prompts = jnp.asarray(ev["prompt"])
    answers = jnp.asarray(ev["answer"])
    B = prompts.shape[0]
    sep = jnp.full((B, 1), SEP_TOK, prompts.dtype)
    shared = []
    for i, name in enumerate(tx_names):
        tx = system.participants[name]
        tp = (system.channel.rephrase(prompts, jax.random.fold_in(key, i))
              if rephrased else prompts)
        ans_tok = c2c.generate(tx.cfg, tx.params, tp, 1)  # (B, 1)
        shared.append(jnp.concatenate([tp, ans_tok, sep], axis=1))
    rx_prompts = (system.channel.rephrase(prompts, jax.random.fold_in(key, 99))
                  if rephrased else prompts)
    combined = jnp.concatenate([*shared, rx_prompts], axis=1)
    logits, _ = T.forward(rx.cfg, rx.params, combined)
    return float(jnp.mean(jnp.argmax(logits[:, -1], -1) == answers))
