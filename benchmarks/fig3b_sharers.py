"""Fig. 3(b): per-transmitter contribution — collaborative accuracy with each
single sharer, split by whether the question falls in that sharer's knowledge
domain. Paper: "the intrinsic capabilities of the sharer model directly impact
the performance of the collaborative model"."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import build_case_study
from repro.core import c2c
from repro.models import transformer as T


def _acc_domain(cs, tx_name, domain, n=96):
    world, system, rx = cs["world"], cs["system"], cs["receiver"]
    rng = np.random.default_rng(13 + domain)
    ev = world.eval_batch(rng, n, domain=domain)
    prompts = jnp.asarray(ev["prompt"])
    tx = system.participants[tx_name]
    _, cache = T.prefill(tx.cfg, tx.params, prompts, max_seq=prompts.shape[1],
                         cache_dtype=jnp.float32)
    stack = cache.export_stack(tx.cfg, length=prompts.shape[1])
    fz = system.registry.get(tx_name, rx.name)
    fused = c2c.fused_prefix([fz], [tx.cfg], rx.cfg, [stack])
    logits, _ = c2c.c2c_forward(rx.cfg, rx.params, prompts, fused)
    return float(jnp.mean(jnp.argmax(logits[:, -1], -1) == jnp.asarray(ev["answer"])))


def run() -> list:
    cs = build_case_study()
    rows = []
    for d, tx in enumerate(cs["transmitters"]):
        in_dom = _acc_domain(cs, tx.name, d)
        off = np.mean([_acc_domain(cs, tx.name, o)
                       for o in range(len(cs["transmitters"])) if o != d])
        rows.append((tx.name, d, in_dom, float(off)))
    return rows


def main() -> None:
    for name, d, in_dom, off_dom in run():
        print(f"fig3b,{name},domain{d},in_domain={in_dom:.4f},off_domain={off_dom:.4f}")


if __name__ == "__main__":
    main()
