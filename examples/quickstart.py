"""Quickstart: the paper's Eq. 1 in ~60 lines.

Two heterogeneous tiny LLMs (different depth/width/kv layout), an untrained
fuser bridging them, and one C2C-refined decode — then the same fuser after a
few training steps, showing the refined logits move toward the labels.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs.case_study import tiny_zoo
from repro.core import c2c, fuser as F
from repro.core.fuser_training import train_fuser
from repro.data.synthetic import World, WorldSpec, lm_stream
from repro.models import transformer as T

key = jax.random.PRNGKey(0)
world = World(WorldSpec())
zoo = tiny_zoo(vocab_size=world.spec.vocab_size)
tx_cfg, rx_cfg = zoo["transmitters"][0], zoo["receiver"]

print(f"transmitter: {tx_cfg.name} ({tx_cfg.num_layers}L d={tx_cfg.d_model} "
      f"kv={tx_cfg.num_kv_heads}x{tx_cfg.resolved_head_dim})")
print(f"receiver:    {rx_cfg.name} ({rx_cfg.num_layers}L d={rx_cfg.d_model} "
      f"kv={rx_cfg.num_kv_heads}x{rx_cfg.resolved_head_dim})")

params_tx = T.init_params(tx_cfg, key, jnp.float32)
params_rx = T.init_params(rx_cfg, jax.random.fold_in(key, 1), jnp.float32)

# --- 1. transmitter prefills locally; its KV cache is the message ----------
prompt = jax.random.randint(key, (2, 12), 8, world.spec.vocab_size)
_, tx_cache = T.prefill(tx_cfg, params_tx, prompt, max_seq=12,
                        cache_dtype=jnp.float32)
tx_stack = tx_cache.export_stack(tx_cfg, length=12)
print(f"\nKV stack communicated: {tx_stack.k.shape} (k) — "
      f"{tx_stack.nbytes} bytes")

# --- 2. fuser projects it into receiver space (Eq. 1's C(F_ij, M_i)) -------
fz = F.init_fuser(tx_cfg, rx_cfg, key)
fused = F.project_cache(fz, tx_cfg, rx_cfg, tx_stack)
print(f"fused into receiver space: {fused.k.shape} (k), "
      f"per-layer gates σ={jax.nn.sigmoid(fz['gate'])[:3]}…")

# --- 3. receiver decodes over [fused ∘ own] ---------------------------------
tokens = c2c.generate(rx_cfg, params_rx, prompt, steps=5, fused=fused)
print(f"C2C-refined generation: {tokens[0]}")

# --- 4. train the fuser briefly — loss drops => the bridge is learnable -----
def batches():
    for b in lm_stream(world, 0, 4, 24):
        yield {"tx_tokens": jnp.asarray(b["tokens"]),
               "rx_tokens": jnp.asarray(b["tokens"]),
               "labels": jnp.asarray(b["labels"])}

fz2, _, hist = train_fuser(tx_cfg, rx_cfg, params_tx, params_rx, batches(),
                           steps=30)
print(f"\nfuser training loss: {hist[0]:.3f} -> {hist[-1]:.3f}")
fused2 = F.project_cache(fz2, tx_cfg, rx_cfg, tx_stack)
tokens2 = c2c.generate(rx_cfg, params_rx, prompt, steps=5, fused=fused2)
print(f"C2C generation after training: {tokens2[0]}")
