"""End-to-end driver: federated serving of batched requests (Fig. 2 pipeline).

Builds the five-member heterogeneous zoo, trains the transmitters on disjoint
knowledge domains + fusers (the server-side {F_ij} registry), then serves a
batch of QA requests through the full FedRefine path:

  rephrase -> transmitter prefill -> fuser projection -> gated fusion
  -> receiver batched decode (Eq. 4) -> answers

and reports accuracy vs the standalone receiver plus the per-request C2C bytes.

Run:  PYTHONPATH=src python examples/serve_federated.py  [--requests 32]
(env CS_TRAIN_STEPS=60 CS_FUSER_STEPS=40 for a faster demo build)
"""
import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, ".")  # allow running from repo root
from benchmarks.common import build_case_study  # noqa: E402
from repro.core import c2c  # noqa: E402
from repro.models import transformer as T  # noqa: E402
from repro.models.cache import attn_kv_stack  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--n-tx", type=int, default=4)
    args = ap.parse_args()

    cs = build_case_study()
    world, system, rx = cs["world"], cs["system"], cs["receiver"]
    tx_names = [t.name for t in cs["transmitters"]][: args.n_tx]

    rng = np.random.default_rng(5)
    ev = world.eval_batch(rng, args.requests)
    prompts = jnp.asarray(ev["prompt"])
    answers = np.asarray(ev["answer"])

    # ---- standalone baseline ------------------------------------------------
    logits, _ = T.forward(rx.cfg, rx.params, prompts)
    solo = np.mean(np.asarray(jnp.argmax(logits[:, -1], -1)) == answers)

    # ---- federated serving --------------------------------------------------
    t0 = time.perf_counter()
    key = jax.random.PRNGKey(0)
    stacks, fusers, cfgs, bytes_total = [], [], [], 0
    for i, name in enumerate(tx_names):
        tx = system.participants[name]
        tp = system.channel.rephrase(prompts, jax.random.fold_in(key, i))
        _, cache = T.prefill(tx.cfg, tx.params, tp, max_seq=tp.shape[1],
                             cache_dtype=jnp.float32)
        st = attn_kv_stack(tx.cfg, cache, length=tp.shape[1])
        stacks.append(st)
        fusers.append(system.registry.get(name, rx.name))
        cfgs.append(tx.cfg)
        bytes_total += 2 * st["k"].nbytes  # k + v on the wire
    fused = c2c.fused_prefix(fusers, cfgs, rx.cfg, stacks)
    rx_prompts = system.channel.rephrase(prompts, jax.random.fold_in(key, 99))
    logits, _ = c2c.c2c_forward(rx.cfg, rx.params, rx_prompts, fused)
    fed = np.mean(np.asarray(jnp.argmax(logits[:, -1], -1)) == answers)
    dt = time.perf_counter() - t0

    print(f"\nrequests={args.requests} transmitters={tx_names}")
    print(f"standalone receiver accuracy: {solo:.3f}")
    print(f"FedRefine accuracy:           {fed:.3f}")
    print(f"C2C bytes shipped: {bytes_total} "
          f"({bytes_total // args.requests} per request), wall {dt*1e3:.0f} ms")


if __name__ == "__main__":
    main()
