"""End-to-end driver: continuous-batching federated serving (Fig. 2 pipeline).

Builds the five-member heterogeneous zoo, trains the transmitters on disjoint
knowledge domains + fusers (the server-side {F_ij} registry), then serves a
stream of QA requests through the FedRefine engine path:

  submit: rephrase -> transmitter prefill -> fuser projection -> gated fusion
  drain:  receiver continuous-batching decode (Eq. 4) -> answers

and reports accuracy vs the standalone receiver plus the per-request C2C bytes.

Engine quickstart
-----------------
The continuous-batching engine (``repro.launch.engine``) replaces lockstep
serving: a fixed-capacity slot table lets requests join mid-flight and frees
slots the moment a request finishes, while ONE jitted decode step covers every
standalone / C2C-fused / T2T mix (per-slot fused prefixes live in a fixed
``max_prefix`` bucket, absent positions masked by attention-logit bias)::

    system = FedRefineSystem.build([receiver, *transmitters])
    system.make_engine(rx.name, max_slots=8, max_seq=64, max_prefix=16)
    rid_a = system.submit(rx.name, prompt_a, steps=2, protocol="c2c", n_tx=4)
    rid_b = system.submit(rx.name, prompt_b, steps=2, protocol="standalone")
    results = system.drain(rx.name)   # {rid: {"tokens", "protocol", ...}}

or drive the engine directly (``engine.submit(...)``/``engine.step()``) for
online serving; ``benchmarks/engine_bench.py`` measures it against the old
lockstep ``BatchedServer`` under Poisson arrivals.

Run:  PYTHONPATH=src python examples/serve_federated.py  [--requests 32]
(env CS_TRAIN_STEPS=60 CS_FUSER_STEPS=40 for a faster demo build)
"""
import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, ".")  # allow running from repo root
from benchmarks.common import build_case_study  # noqa: E402
from repro.core import commload  # noqa: E402
from repro.models import transformer as T  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--n-tx", type=int, default=4)
    ap.add_argument("--slots", type=int, default=8)
    args = ap.parse_args()

    cs = build_case_study()
    world, system, rx = cs["world"], cs["system"], cs["receiver"]
    tx_names = [t.name for t in cs["transmitters"]][: args.n_tx]

    rng = np.random.default_rng(5)
    ev = world.eval_batch(rng, args.requests)
    prompts = jnp.asarray(ev["prompt"])
    answers = np.asarray(ev["answer"])
    S = prompts.shape[1]

    # ---- standalone baseline ------------------------------------------------
    logits, _ = T.forward(rx.cfg, rx.params, prompts)
    solo = np.mean(np.asarray(jnp.argmax(logits[:, -1], -1)) == answers)

    # ---- federated serving through the continuous-batching engine ----------
    system.make_engine(rx.name, max_slots=args.slots, max_seq=2 * S + 4,
                       max_prefix=args.n_tx * S)
    t0 = time.perf_counter()
    key = jax.random.PRNGKey(0)
    rids = []
    for i in range(args.requests):
        # every participant gets its OWN rephrasing of the original prompt
        # (the Fig. 2 privacy regime — never a rephrase of a rephrase)
        ki = jax.random.fold_in(key, i)
        rx_prompt = system.channel.rephrase(prompts[i : i + 1],
                                            jax.random.fold_in(ki, 99))
        tx_prompts = {
            n: system.channel.rephrase(prompts[i : i + 1],
                                       jax.random.fold_in(ki, j))
            for j, n in enumerate(tx_names)
        }
        rids.append(system.submit(rx.name, rx_prompt, steps=2, protocol="c2c",
                                  n_tx=args.n_tx, tx_prompts=tx_prompts))
    results = system.drain(rx.name)
    dt = time.perf_counter() - t0

    preds = np.array([results[r]["tokens"][0] for r in rids])
    fed = np.mean(preds == answers)
    per_req = sum(commload.c2c_bytes_per_token(system.participants[n].cfg)
                  for n in tx_names) * S
    eng = system.engines[rx.name]

    print(f"\nrequests={args.requests} transmitters={tx_names}")
    print(f"standalone receiver accuracy: {solo:.3f}")
    print(f"FedRefine accuracy:           {fed:.3f}")
    print(f"C2C bytes shipped: {per_req * args.requests} "
          f"({per_req} per request), wall {dt*1e3:.0f} ms")
    print(f"engine: {eng.stats['admitted']} admitted through "
          f"{args.slots} slots, decode traced {eng.stats['decode_traces']}x")


if __name__ == "__main__":
    main()
