"""End-to-end backbone training driver: any assigned architecture, synthetic
LM stream, AdamW + cosine schedule, periodic checkpointing.

Default runs a ~10M-param reduction for a quick CPU demo; ``--full --arch
mamba2-130m`` trains the real 130M SSD config (slow on one CPU core — sized
for a real accelerator; on the production mesh this is exactly what
launch/dryrun.py lowers for train_4k).

Run:  PYTHONPATH=src python examples/train_backbone.py --steps 200
"""
import argparse
import os

import jax.numpy as jnp

from repro.checkpoint.checkpoint import save_train_state
from repro.configs.base import get_config, get_smoke_config
from repro.data.synthetic import World, WorldSpec, lm_stream
from repro.launch.train import train_loop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--full", action="store_true",
                    help="use the full config (default: smoke reduction)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=96)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default="experiments/backbone_ckpt/state")
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else get_smoke_config(args.arch)
    cfg = cfg.with_overrides(vocab_size=max(cfg.vocab_size, 512)) \
        if cfg.vocab_size < 512 else cfg
    world = World(WorldSpec(vocab_size=512))
    print(f"training {cfg.name}: {cfg.num_layers}L d={cfg.d_model} "
          f"~{cfg.param_count()/1e6:.1f}M params, {args.steps} steps")

    stream = lm_stream(world, 0, args.batch, args.seq)
    params, losses = train_loop(cfg, stream, args.steps, lr=args.lr,
                                dtype=jnp.float32, log_every=25)
    os.makedirs(os.path.dirname(args.ckpt), exist_ok=True)
    save_train_state(args.ckpt, args.steps, params, None)
    print(f"loss {losses[0]:.4f} -> {losses[-1]:.4f}; checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
