"""Opportunistic protocol selection demo (paper §Possible Variants).

Sweeps link bandwidth / QoS latency budgets for the paper's real case-study zoo
(Qwen3-0.6B receiver + 4 transmitters) and prints which protocol the
opportunistic controller picks — C2C when the pipe affords 86 KB/token,
degrading to T2T then standalone as the link or the budget tightens.

Run:  PYTHONPATH=src python examples/opportunistic_protocol.py
"""
from repro.configs.case_study import ZOO
from repro.core import protocol

rx = ZOO["receiver"]
txs = ZOO["transmitters"]

print(f"receiver {rx.name}; transmitters {[t.name for t in txs]}")
print(f"{'bandwidth':>12} {'QoS budget':>10} {'chosen':>11} "
      f"{'c2c_s':>8} {'t2t_s':>8} {'solo_s':>8}")
for bw_mbps in (1, 10, 100, 1000, 10_000, 400_000):
    for budget_s in (0.5, 2.0, 10.0):
        link = protocol.LinkModel(bandwidth_bps=bw_mbps * 125_000, rtt_s=0.02)
        qos = protocol.QoS(max_latency_s=budget_s)
        r = protocol.choose_protocol(txs, rx, seq=512, gen_steps=128,
                                     link=link, qos=qos)
        lat = r["latencies"]
        flag = "" if r["qos_met"] else "  (QoS infeasible -> fastest)"
        print(f"{bw_mbps:>9}Mbps {budget_s:>9.1f}s {r['protocol']:>11} "
              f"{lat['c2c']:8.2f} {lat['t2t']:8.2f} {lat['standalone']:8.2f}{flag}")
