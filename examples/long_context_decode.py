"""Long-context decode (the long_500k regime) at CPU scale: sliding-window
ring-buffer caches (dense archs) and constant-size recurrent state (Mamba-2)
make half-million-token decoding memory-feasible.

Demonstrates, on reduced configs:
  1. a windowed dense model decodes past 4x its window with an O(window) cache;
  2. mamba2's state never grows;
  3. decode past the window matches a teacher-forced full forward.

Run:  PYTHONPATH=src python examples/long_context_decode.py
"""
import jax
import jax.numpy as jnp

from repro.configs.base import get_smoke_config
from repro.models import transformer as T

key = jax.random.PRNGKey(0)

# --- dense arch in its long-context (sliding-window) variant ---------------
cfg = get_smoke_config("qwen2.5-32b").with_overrides(
    block_pattern=("swa",), sliding_window=16)
params = T.init_params(cfg, key, jnp.float32)
S, extra = 48, 24  # decode to 72 tokens with a 16-token window
toks = jax.random.randint(key, (1, S + extra), 0, cfg.vocab_size)
full, _ = T.forward(cfg, params, toks)
_, cache = T.prefill(cfg, params, toks[:, :S], max_seq=S + extra,
                     cache_dtype=jnp.float32)
cache_bytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))
errs = []
for i in range(extra):
    lg, cache = T.decode_step(cfg, params, cache, toks[:, S + i])
    errs.append(float(jnp.abs(lg - full[:, S + i]).max()))
print(f"[swa ] window=16 cache={cache_bytes/1024:.0f} KiB "
      f"(vs {(S+extra)*cache_bytes/(16*1024):.0f} KiB unwindowed), "
      f"decode-vs-teacher max err {max(errs):.2e}")

# --- mamba2: O(1) state ------------------------------------------------------
cfg = get_smoke_config("mamba2-130m")
params = T.init_params(cfg, key, jnp.float32)
toks = jax.random.randint(key, (1, S + extra), 0, cfg.vocab_size)
full, _ = T.forward(cfg, params, toks)
_, cache = T.prefill(cfg, params, toks[:, :S], max_seq=S + extra,
                     cache_dtype=jnp.float32)
state_bytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))
errs = []
for i in range(extra):
    lg, cache = T.decode_step(cfg, params, cache, toks[:, S + i])
    errs.append(float(jnp.abs(lg - full[:, S + i]).max()))
print(f"[ssm ] state={state_bytes/1024:.0f} KiB (constant in context length), "
      f"decode-vs-teacher max err {max(errs):.2e}")
print("at full scale: see `python -m repro.launch.dryrun --shape long_500k`")
