"""Cross-family federation: a Mixture-of-Experts receiver refined by a dense
transmitter, and a hybrid (RG-LRU + local-attention) receiver refined by the
same transmitter — the paper's "model-agnostic" claim exercised across
architecture families (smoke scale; the production-mesh versions are
`python -m repro.launch.dryrun --arch qwen3-moe-30b-a3b --federated-from
qwen2.5-32b --pre-projected --split-prefix`).

Also shows the attention-free case: mamba2 CANNOT join via KV C2C (typed
error, DESIGN.md §Arch-applicability) but CAN via the beyond-paper state
fuser.

Run:  PYTHONPATH=src python examples/cross_family_federation.py
"""
import jax
import jax.numpy as jnp

from repro.configs.base import get_smoke_config
from repro.core import c2c, fuser as F, state_fuser as SF
from repro.models import transformer as T
from repro.models.cache import FusedPrefix

key = jax.random.PRNGKey(0)
tx_cfg = get_smoke_config("qwen2.5-32b")  # dense transmitter
params_tx = T.init_params(tx_cfg, key, jnp.float32)
prompt = jax.random.randint(key, (1, 12), 8, 256)
_, tx_cache = T.prefill(tx_cfg, params_tx, prompt, max_seq=12,
                        cache_dtype=jnp.float32)
tx_stack = tx_cache.export_stack(tx_cfg, length=12)
print(f"transmitter: {tx_cfg.name} — exported KV stack {tx_stack.k.shape}")

for rx_arch in ("qwen3-moe-30b-a3b", "recurrentgemma-9b", "qwen2-vl-72b"):
    rx_cfg = get_smoke_config(rx_arch)
    params_rx = T.init_params(rx_cfg, jax.random.fold_in(key, hash(rx_arch) % 97),
                              jnp.float32)
    fz = F.init_fuser(tx_cfg, rx_cfg, key)
    fused = F.project_cache(fz, tx_cfg, rx_cfg, tx_stack)
    if rx_cfg.frontend == "vision":
        from repro.models.frontend import synth_embeddings
        emb = synth_embeddings(rx_cfg, key, 1, 12, jnp.float32)
        logits, _ = T.forward(rx_cfg, params_rx, embeds=emb,
                              extra_kv=FusedPrefix.ensure(fused)
                              .to_extra_kv(rx_cfg))
        toks = jnp.argmax(logits[:, -1:], -1)
    else:
        toks = c2c.generate(rx_cfg, params_rx, prompt % rx_cfg.vocab_size, 4,
                            fused=fused)
    n_attach = len(rx_cfg.attention_layers)
    print(f"  -> {rx_cfg.name:28s} [{rx_cfg.family:6s}] fused into {n_attach} "
          f"attention layers; refined tokens {toks[0]}")

# attention-free member: KV C2C is typed-inapplicable; state fusion works
mamba = get_smoke_config("mamba2-130m")
try:
    F.init_fuser(tx_cfg, mamba, key)
except F.InapplicableError as e:
    print(f"  -> {mamba.name:28s} [ssm   ] KV C2C inapplicable (as designed): "
          f"{str(e)[:60]}…")
mb_params = T.init_params(mamba, key, jnp.float32)
mamba_b = mamba.with_overrides(num_layers=3, d_model=96, ssm_head_dim=24,
                               name="mamba2-peer")
mb2_params = T.init_params(mamba_b, jax.random.fold_in(key, 5), jnp.float32)
_, ca = T.prefill(mamba_b, mb2_params, prompt % mamba_b.vocab_size, max_seq=16,
                  cache_dtype=jnp.float32)
_, cb = T.prefill(mamba, mb_params, prompt % mamba.vocab_size, max_seq=16,
                  cache_dtype=jnp.float32)
sf = SF.init_state_fuser(mamba_b, mamba, key)
fused_cache = SF.fuse_states(sf, mamba_b, mamba, ca, cb)
lg, _ = T.decode_step(mamba, mb_params, fused_cache, (prompt % mamba.vocab_size)[:, -1])
print(f"     …but state-to-state fusion works: {SF.state_bytes(mamba_b)} B "
      f"state message, refined logits {lg.shape}")
