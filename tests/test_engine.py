"""Continuous-batching engine (launch/engine.py): slot lifecycle, mid-flight
joins, mixed standalone+C2C batches, and the one-trace compilation guarantee."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.configs.case_study import tiny_zoo
from repro.core import fuser as F
from repro.launch.engine import ContinuousBatchingEngine
from repro.models import transformer as T
from repro.models.cache import FusedPrefix, KVCache, PREFIX_MASK_BIAS

VOCAB = 64


@pytest.fixture(scope="module")
def cfg():
    return ModelConfig(name="eng-tiny", family="dense", num_layers=2,
                       d_model=32, num_heads=2, num_kv_heads=1, head_dim=16,
                       d_ff=64, vocab_size=VOCAB, tie_embeddings=True)


@pytest.fixture(scope="module")
def params(cfg):
    return T.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)


def _prompt(key, n):
    return jax.random.randint(key, (1, n), 0, VOCAB)


def _solo(cfg, params, prompt, steps, max_seq, fused=None):
    """Reference greedy run on the plain (scalar-pos) decode path."""
    ek = (FusedPrefix.ensure(fused).to_extra_kv(cfg)
          if fused is not None else None)
    logits, cache = T.prefill(cfg, params, prompt, max_seq=max_seq,
                              cache_dtype=jnp.float32, extra_kv=ek)
    tok = jnp.argmax(logits[:, prompt.shape[1] - 1], -1)
    out = [tok]
    for _ in range(steps - 1):
        lg, cache = T.decode_step(cfg, params, cache, tok, extra_kv=ek)
        tok = jnp.argmax(lg, -1)
        out.append(tok)
    return np.asarray(jnp.stack(out, 1)[0])


# ------------------------------------------------------------- slot lifecycle


def test_slot_admission_eviction_reuse(cfg, params):
    """More requests than slots: slots are freed on completion and reused, and
    every request still matches its solo reference."""
    eng = ContinuousBatchingEngine(cfg, params, max_slots=2, max_seq=48)
    key = jax.random.PRNGKey(1)
    reqs = [( _prompt(jax.random.fold_in(key, i), 4 + i), 3 + i)
            for i in range(5)]
    rids = [eng.submit(p, n) for p, n in reqs]
    assert eng.num_active == 0 and eng.num_queued == 5
    done = {c.rid: c.tokens for c in eng.drain()}
    assert eng.num_active == 0 and eng.num_queued == 0
    assert eng.stats["admitted"] == 5 and eng.stats["completed"] == 5
    for rid, (p, n) in zip(rids, reqs):
        assert np.array_equal(done[rid], _solo(cfg, params, p, n, 48))


def test_slot_insert_evict_roundtrip(cfg, params):
    """KVCache.insert_slot/evict_slot: inserted slot carries the request's
    position; evicted slot resets to 0 and hides its stale keys."""
    table = KVCache.init_slots(cfg, 3, 32, jnp.float32)
    p = _prompt(jax.random.PRNGKey(2), 6)
    _, req = T.prefill(cfg, params, p, max_seq=32, cache_dtype=jnp.float32)
    table = table.insert_slot(1, req, 6)
    assert table.pos.shape == (3,)
    assert table.pos.tolist() == [0, 6, 0]
    table = table.evict_slot(1)
    assert table.pos.tolist() == [0, 0, 0]


def test_completion_at_prefill_never_occupies_slot(cfg, params):
    """max_new_tokens=1 completes from the prefill logits directly."""
    eng = ContinuousBatchingEngine(cfg, params, max_slots=1, max_seq=32)
    p = _prompt(jax.random.PRNGKey(3), 5)
    rid = eng.submit(p, 1)
    done = {c.rid: c.tokens for c in eng.drain()}
    assert np.array_equal(done[rid], _solo(cfg, params, p, 1, 32))
    assert eng.stats["decode_steps"] == 0


# ------------------------------------------------------------ mid-flight joins


def test_midflight_join_matches_solo(cfg, params):
    """A request admitted while others are mid-decode produces exactly the
    tokens of a solo run (slot isolation)."""
    eng = ContinuousBatchingEngine(cfg, params, max_slots=3, max_seq=48)
    key = jax.random.PRNGKey(4)
    p1, p2, p3 = (_prompt(jax.random.fold_in(key, i), n)
                  for i, n in enumerate((7, 5, 9)))
    r1 = eng.submit(p1, 10)
    for _ in range(3):
        eng.step()
    r2 = eng.submit(p2, 6)   # joins while r1 is mid-decode
    r3 = eng.submit(p3, 8)
    done = {c.rid: c.tokens for c in eng.drain()}
    assert np.array_equal(done[r1], _solo(cfg, params, p1, 10, 48))
    assert np.array_equal(done[r2], _solo(cfg, params, p2, 6, 48))
    assert np.array_equal(done[r3], _solo(cfg, params, p3, 8, 48))


# ----------------------------------------------------------------- mixed batch


def _tiny_c2c():
    zoo = tiny_zoo(vocab_size=VOCAB)
    rx, tx = zoo["receiver"], zoo["transmitters"][0]
    key = jax.random.PRNGKey(5)
    p_rx = T.init_params(rx, key, jnp.float32)
    p_tx = T.init_params(tx, jax.random.fold_in(key, 1), jnp.float32)
    fz = F.init_fuser(tx, rx, jax.random.fold_in(key, 2))
    return rx, p_rx, tx, p_tx, fz


def test_mixed_standalone_c2c_batch():
    """Standalone and C2C-fused requests share one slot table; each matches
    its own solo reference, and the fixed-bucket prefix mask is exact."""
    rx, p_rx, tx, p_tx, fz = _tiny_c2c()
    key = jax.random.PRNGKey(6)
    pa = _prompt(key, 6)
    pb = _prompt(jax.random.fold_in(key, 1), 5)
    S = pa.shape[1]
    _, txc = T.prefill(tx, p_tx, pa, max_seq=S, cache_dtype=jnp.float32)
    fused = F.project_cache(fz, tx, rx, txc.export_stack(tx, length=S))

    eng = ContinuousBatchingEngine(rx, p_rx, max_slots=2, max_seq=40,
                                   max_prefix=8)
    ra = eng.submit(pa, 7, fused=fused)
    rb = eng.submit(pb, 7)
    done = {c.rid: c for c in eng.drain()}
    assert done[ra].protocol == "c2c" and done[rb].protocol == "standalone"
    # unpadded reference == engine (prefix padded to the bucket): mask exact
    assert np.array_equal(done[ra].tokens, _solo(rx, p_rx, pa, 7, 40, fused))
    assert np.array_equal(done[rb].tokens, _solo(rx, p_rx, pb, 7, 40))


def test_padded_prefix_mask_is_exact():
    """FusedPrefix.pad / FusedPrefix.empty: masked positions carry zero
    attention mass, so a padded prefix equals the unpadded one and an empty
    prefix equals no prefix."""
    rx, p_rx, tx, p_tx, fz = _tiny_c2c()
    p = _prompt(jax.random.PRNGKey(7), 6)
    _, txc = T.prefill(tx, p_tx, p, max_seq=6, cache_dtype=jnp.float32)
    fused = F.project_cache(fz, tx, rx, txc.export_stack(tx, length=6))
    padded = fused.pad(11)
    assert padded.k.shape[-2] == 11
    assert float(padded.bias[..., -1].max()) == float(
        jnp.float32(PREFIX_MASK_BIAS))
    assert np.array_equal(_solo(rx, p_rx, p, 5, 32, fused),
                          _solo(rx, p_rx, p, 5, 32, padded))
    empty = FusedPrefix.empty(rx, 1, 4, jnp.float32)
    assert np.array_equal(_solo(rx, p_rx, p, 5, 32),
                          _solo(rx, p_rx, p, 5, 32, empty))


# ------------------------------------------------------------ recompile count


def test_decode_jits_exactly_once_across_mixes():
    """The decode step traces once, no matter how the request mix changes
    (standalone-only -> fused-only -> mixed, different prefix lengths)."""
    rx, p_rx, tx, p_tx, fz = _tiny_c2c()
    key = jax.random.PRNGKey(8)
    eng = ContinuousBatchingEngine(rx, p_rx, max_slots=2, max_seq=40,
                                   max_prefix=8, prompt_bucket=8)

    def fused_for(p):
        S = p.shape[1]
        _, c = T.prefill(tx, p_tx, p, max_seq=S, cache_dtype=jnp.float32)
        return F.project_cache(fz, tx, rx, c.export_stack(tx, length=S))

    # wave 1: standalone only
    eng.submit(_prompt(key, 5), 4)
    eng.drain()
    # wave 2: fused only, prefix length 6
    p = _prompt(jax.random.fold_in(key, 1), 6)
    eng.submit(p, 4, fused=fused_for(p))
    eng.drain()
    # wave 3: mixed, different prefix length (3) and prompt lengths
    q = _prompt(jax.random.fold_in(key, 2), 3)
    eng.submit(q, 4, fused=fused_for(q))
    eng.submit(_prompt(jax.random.fold_in(key, 3), 7), 4)
    eng.drain()

    assert eng.stats["decode_steps"] > 0
    assert eng.stats["decode_traces"] == 1, (
        "decode step re-traced as the request mix changed")
    # bucketed prompts: one prefill trace covers every wave too
    assert eng.stats["prefill_traces"] == 1


# ------------------------------------------------------- fedrefine submit/drain


def test_fedrefine_submit_drain_mixed_protocols():
    """FedRefineSystem.submit()/drain(): standalone, C2C and T2T requests
    coexist in one engine; explicit protocols without transmitters raise."""
    from repro.core.fedrefine import FedRefineSystem, Participant

    zoo = tiny_zoo(vocab_size=VOCAB)
    key = jax.random.PRNGKey(10)
    members = [Participant(c.name, c, T.init_params(c, jax.random.fold_in(key, i),
                                                    jnp.float32))
               for i, c in enumerate([zoo["receiver"], zoo["transmitters"][0]])]
    system = FedRefineSystem.build(members)
    rx = members[0].name
    system.make_engine(rx, max_slots=3, max_seq=64, max_prefix=8)
    p = _prompt(key, 5)
    r_solo = system.submit(rx, p, 3, protocol="standalone")
    r_c2c = system.submit(rx, p, 3, protocol="c2c")
    r_t2t = system.submit(rx, p, 3, protocol="t2t")
    out = system.drain(rx)
    assert out[r_solo]["protocol"] == "standalone"
    assert out[r_c2c]["protocol"] == "c2c"
    assert out[r_t2t]["protocol"] == "t2t"
    assert all(len(out[r]["tokens"]) == 3 for r in (r_solo, r_c2c, r_t2t))
    assert out[r_c2c]["transmitters"] == [members[1].name]
    assert system.engines[rx].stats["decode_traces"] == 1

    # a receiver-only system cannot satisfy an explicit c2c request
    lone = FedRefineSystem.build(members[:1])
    lone.make_engine(rx, max_slots=1, max_seq=32, max_prefix=4)
    with pytest.raises(ValueError, match="no transmitter"):
        lone.submit(rx, p, 2, protocol="c2c")


# ----------------------------------------------------- other block families


@pytest.mark.parametrize("arch", ["recurrentgemma_9b", "mamba2_130m"])
def test_engine_stateful_families(arch):
    """Per-slot decode through swa ring buffers (RecurrentGemma) and
    recurrent/SSD states (Mamba-2): mid-flight joins still match solo runs.
    Stateful families use exact-length prefill (no prompt bucketing)."""
    from repro.configs.base import get_smoke_config

    cfg = get_smoke_config(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    eng = ContinuousBatchingEngine(cfg, params, max_slots=2, max_seq=32,
                                   prompt_bucket=8)
    assert eng.prompt_bucket is None  # stateful: bucketing must be refused
    key = jax.random.PRNGKey(1)
    p1 = jax.random.randint(key, (1, 6), 0, cfg.vocab_size)
    p2 = jax.random.randint(jax.random.fold_in(key, 1), (1, 4), 0,
                            cfg.vocab_size)
    r1 = eng.submit(p1, 6)
    eng.step()
    r2 = eng.submit(p2, 4)  # joins mid-decode
    done = {c.rid: c.tokens for c in eng.drain()}
    assert np.array_equal(done[r1], _solo(cfg, params, p1, 6, 32))
    assert np.array_equal(done[r2], _solo(cfg, params, p2, 4, 32))
    assert eng.stats["decode_traces"] == 1


# ------------------------------------------------------------- per-slot decode


def test_per_slot_positions_decode_parity(cfg, params):
    """Vector-pos decode_step == scalar-pos decode_step when all slots happen
    to sit at the same position (the refactor preserves the lockstep path)."""
    B, S = 2, 6
    toks = jax.random.randint(jax.random.PRNGKey(9), (B, S + 1), 0, VOCAB)
    _, cache = T.prefill(cfg, params, toks[:, :S], max_seq=S + 2,
                         cache_dtype=jnp.float32)
    lg_scalar, _ = T.decode_step(cfg, params, cache, toks[:, S])
    vec_cache = cache.with_pos(jnp.full((B,), cache.pos, jnp.int32))
    lg_vec, new_cache = T.decode_step(cfg, params, vec_cache, toks[:, S])
    assert float(jnp.abs(lg_scalar - lg_vec).max()) < 1e-5
    assert new_cache.pos.tolist() == [S + 1] * B


# ------------------------------------------------------------- paged slots


def test_paged_engine_matches_dense_byte_identical(cfg, params):
    """Paged SlotTable decode == dense-slot decode, token for token: paging is
    a pure layout change (gather view + per-slot mask), never numerics."""
    key = jax.random.PRNGKey(20)
    reqs = [(_prompt(jax.random.fold_in(key, i), 4 + i), 3 + i)
            for i in range(5)]
    dense = ContinuousBatchingEngine(cfg, params, max_slots=2, max_seq=48)
    paged = ContinuousBatchingEngine(cfg, params, max_slots=2, max_seq=48,
                                     paged=True, page_size=8)
    rd = [dense.submit(p, n) for p, n in reqs]
    rp = [paged.submit(p, n) for p, n in reqs]
    out_d = {c.rid: c.tokens for c in dense.drain()}
    out_p = {c.rid: c.tokens for c in paged.drain()}
    for a, b in zip(rd, rp):
        assert np.array_equal(out_d[a], out_p[b])
    assert paged.stats["decode_traces"] == 1


def test_paged_capacity_beyond_dense_budget(cfg, params):
    """A paged pool sized for 2 dense slots serves 4 concurrent short
    requests (the ROADMAP paged-KV capacity win), and frees pages on
    completion."""
    max_seq, page = 32, 8
    dense_slots = 2
    eng = ContinuousBatchingEngine(
        cfg, params, max_slots=4, max_seq=max_seq, paged=True,
        page_size=page, num_pages=dense_slots * max_seq // page)
    key = jax.random.PRNGKey(21)
    prompts = [_prompt(jax.random.fold_in(key, i), 5) for i in range(4)]
    rids = [eng.submit(p, 6) for p in prompts]  # 11 tok -> 2 pages each
    done = {c.rid: c.tokens for c in eng.drain()}
    assert eng.stats["peak_active"] == 4  # 2x the dense-slot equivalent
    # every page is either free or pinned only by the radix prefix index;
    # dropping the index returns the pool to fully free
    eng._allocator.assert_consistent()
    assert eng._allocator.num_free + eng._radix.num_pages \
        == eng._table.num_pages
    eng._radix.clear(eng._allocator)
    assert eng._allocator.num_free == eng._table.num_pages
    for rid, p in zip(rids, prompts):
        assert np.array_equal(done[rid], _solo(cfg, params, p, 6, max_seq))


def test_paged_blocks_admission_until_pages_free(cfg, params):
    """When the pool is exhausted the head request waits (FIFO) and is
    admitted as soon as a completion returns pages."""
    eng = ContinuousBatchingEngine(cfg, params, max_slots=4, max_seq=32,
                                   paged=True, page_size=8, num_pages=4)
    key = jax.random.PRNGKey(22)
    p1, p2 = _prompt(key, 5), _prompt(jax.random.fold_in(key, 1), 5)
    r1 = eng.submit(p1, 6)   # 11 tok -> 2 pages
    r2 = eng.submit(p2, 10)  # 15 tok -> 2 pages
    eng.step()
    assert eng.num_active == 2 and eng.num_queued == 0
    r3 = eng.submit(p1, 3)   # pool full: must wait for r1/r2 to finish
    eng.step()
    assert eng.num_queued == 1
    done = {c.rid: c.tokens for c in eng.drain()}
    assert set(done) == {r1, r2, r3}
    assert np.array_equal(done[r3], _solo(cfg, params, p1, 3, 32))


def test_paged_kernel_path_matches_gather_path(cfg, params):
    """The in-place paged-attention kernel (default) and the dense_view()
    gather reference produce identical tokens; the kernel path never gathers
    a dense view during decode."""
    key = jax.random.PRNGKey(25)
    reqs = [(_prompt(jax.random.fold_in(key, i), 4 + i), 3 + i)
            for i in range(4)]
    mk = lambda mode: ContinuousBatchingEngine(
        cfg, params, max_slots=2, max_seq=48, paged=True, page_size=8,
        paged_attention=mode)
    kern, gath = mk("kernel"), mk("gather")
    rk = [kern.submit(p, n) for p, n in reqs]
    rg = [gath.submit(p, n) for p, n in reqs]
    out_k = {c.rid: c.tokens for c in kern.drain()}
    out_g = {c.rid: c.tokens for c in gath.drain()}
    for a, b in zip(rk, rg):
        assert np.array_equal(out_k[a], out_g[b])
    assert kern.stats["decode_view_gathers"] == 0
    assert gath.stats["decode_view_gathers"] == 1  # trace-time: once
    assert kern.stats["decode_traces"] == 1


def test_paged_kernel_with_fused_prefix_matches_dense():
    """C2C through the paged kernel: the fused prefix is LSE-merged from the
    kernel's online-softmax stats, and still matches the dense engine's
    concat-path tokens."""
    rx, p_rx, tx, p_tx, fz = _tiny_c2c()
    key = jax.random.PRNGKey(26)
    pa, pb = _prompt(key, 6), _prompt(jax.random.fold_in(key, 1), 5)
    _, txc = T.prefill(tx, p_tx, pa, max_seq=6, cache_dtype=jnp.float32)
    fused = F.project_cache(fz, tx, rx, txc.export_stack(tx, length=6))
    outs = {}
    for name, kw in (("dense", {}), ("paged", dict(paged=True, page_size=8))):
        eng = ContinuousBatchingEngine(rx, p_rx, max_slots=2, max_seq=40,
                                       max_prefix=8, **kw)
        ra = eng.submit(pa, 7, fused=fused)
        rb = eng.submit(pb, 7)
        done = {c.rid: c.tokens for c in eng.drain()}
        outs[name] = (done[ra], done[rb])
    assert np.array_equal(outs["dense"][0], outs["paged"][0])
    assert np.array_equal(outs["dense"][1], outs["paged"][1])


def test_paged_kernel_decode_step_direct(cfg, params):
    """transformer.decode_step dispatches on the SlotTable type: one step on a
    paged table == one step on its dense_view, and the new token lands on the
    right physical page (in-place write, no commit)."""
    from repro.models.cache import SlotTable

    table = SlotTable.init(cfg, 2, 32, jnp.float32, page_size=8)
    p = _prompt(jax.random.PRNGKey(27), 6)
    _, req = T.prefill(cfg, params, p, max_seq=32, cache_dtype=jnp.float32)
    pages = np.full((4,), table.invalid_page, np.int32)
    pages[:2] = [3, 1]  # out-of-order physical pages
    table = table.insert_slot(0, req, 6, jnp.asarray(pages))
    tok = jnp.array([7, 0], jnp.int32)
    lg_paged, new_table = T.decode_step(cfg, params, table, tok)
    lg_dense, _ = T.decode_step(cfg, params, table.dense_view(), tok)
    assert isinstance(new_table, SlotTable)
    assert jnp.argmax(lg_paged[0]) == jnp.argmax(lg_dense[0])
    assert float(jnp.abs(lg_paged[0] - lg_dense[0]).max()) < 1e-4
    # token at pos 6 -> page idx 0 -> physical page 3, offset 6
    e_new, e_old = new_table.layers[0], table.layers[0]
    assert float(jnp.abs(e_new["k"][:, 3, :, 6] - e_old["k"][:, 3, :, 6]).max()) > 0.0
    assert np.array_equal(new_table.page_map, table.page_map)
    assert new_table.pos.tolist() == [7, 1]


def test_kv_read_bytes_per_step_accounting(cfg, params):
    """The analytic HBM metric: in-place kernel bytes scale with live tokens,
    gather bytes with slots x view_seq (the engine_bench acceptance metric)."""
    eng = ContinuousBatchingEngine(cfg, params, max_slots=4, max_seq=32,
                                   paged=True, page_size=8)
    for i in range(4):
        eng.submit(_prompt(jax.random.fold_in(jax.random.PRNGKey(28), i), 5), 4)
    eng.step()  # all admitted, pos == 5 -> 1 page each
    b = eng.kv_read_bytes_per_step()
    n_entries = sum(int(e["k"].shape[0]) for e in eng._table.layers)
    row = 2 * cfg.num_kv_heads * cfg.resolved_head_dim * 4 * n_entries
    assert b["paged_kernel"] == 4 * 8 * row       # 4 slots x 1 live page
    assert b["dense_gather"] == 4 * 32 * row      # 4 slots x view_seq
    assert b["paged_kernel"] < b["dense_gather"]


def test_paged_requires_pure_attention():
    from repro.configs.base import get_smoke_config

    cfg = get_smoke_config("recurrentgemma_9b")
    params = T.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    with pytest.raises(ValueError, match="pure full-attention"):
        ContinuousBatchingEngine(cfg, params, max_slots=2, max_seq=32,
                                 paged=True, page_size=8)


# -------------------------------------------------------- batch admission


def test_batch_admission_matches_solo_and_traces_once(cfg, params):
    """admit_batch>1 prefills same-bucket requests together: outputs equal
    solo runs and the prefill still traces once per bucket length."""
    eng = ContinuousBatchingEngine(cfg, params, max_slots=4, max_seq=48,
                                   admit_batch=4, prompt_bucket=8)
    key = jax.random.PRNGKey(23)
    reqs = [(_prompt(jax.random.fold_in(key, i), 3 + i), 4 + i)
            for i in range(4)]  # lengths 3..6 share the 8-bucket
    rids = [eng.submit(p, n) for p, n in reqs]
    done = {c.rid: c.tokens for c in eng.drain()}
    for rid, (p, n) in zip(rids, reqs):
        assert np.array_equal(done[rid], _solo(cfg, params, p, n, 48))
    assert eng.stats["prefill_traces"] == 1
    assert eng.stats["admit_batches"] == 1  # one forward admitted all four
    assert eng.stats["decode_traces"] == 1


def test_batch_admission_mixed_protocols():
    """Batched admission keeps per-request fused prefixes separated: a C2C
    and a standalone request admitted in one prefill each match their solo
    references."""
    rx, p_rx, tx, p_tx, fz = _tiny_c2c()
    key = jax.random.PRNGKey(24)
    pa = _prompt(key, 6)
    pb = _prompt(jax.random.fold_in(key, 1), 6)
    _, txc = T.prefill(tx, p_tx, pa, max_seq=6, cache_dtype=jnp.float32)
    fused = F.project_cache(fz, tx, rx, txc.export_stack(tx, length=6))
    eng = ContinuousBatchingEngine(rx, p_rx, max_slots=2, max_seq=40,
                                   max_prefix=8, admit_batch=2)
    ra = eng.submit(pa, 7, fused=fused)
    rb = eng.submit(pb, 7)
    done = {c.rid: c for c in eng.drain()}
    assert eng.stats["admit_batches"] == 1
    assert np.array_equal(done[ra].tokens, _solo(rx, p_rx, pa, 7, 40, fused))
    assert np.array_equal(done[rb].tokens, _solo(rx, p_rx, pb, 7, 40))


def test_paged_rejects_never_admittable_request(cfg, params):
    """A request whose page demand exceeds the whole pool fails at submit()
    instead of hanging drain() forever."""
    eng = ContinuousBatchingEngine(cfg, params, max_slots=2, max_seq=32,
                                   paged=True, page_size=8, num_pages=2)
    with pytest.raises(ValueError, match="could never be admitted"):
        eng.submit(_prompt(jax.random.PRNGKey(30), 12), 10)  # 3 pages > 2


def test_paged_pages_sized_by_request_not_bucket(cfg, params):
    """Bucket padding must not inflate page reservations: a 5+3-token request
    under a large prompt bucket takes ceil(8/8)=1 page, not bucket/page."""
    eng = ContinuousBatchingEngine(cfg, params, max_slots=2, max_seq=32,
                                   paged=True, page_size=8, num_pages=4,
                                   prompt_bucket=32)
    p = _prompt(jax.random.PRNGKey(31), 5)
    rid = eng.submit(p, 3)
    eng.step()
    assert eng._leases[0].num_pages == 1  # one page, despite the 32-bucket
    done = {c.rid: c.tokens for c in eng.drain()}
    assert np.array_equal(done[rid], _solo(cfg, params, p, 3, 32))


# -------------------------------------------------------- chunked prefill


def test_chunked_prefill_matches_monolithic(cfg, params):
    """Token-budget chunked prefill is a pure scheduling change: byte-identical
    tokens vs the monolithic paged engine on a mixed long/short workload
    (including a 1-token request), ONE chunk-prefill trace regardless of
    prompt lengths or chunk counts, and a clean sanitizer."""
    key = jax.random.PRNGKey(40)
    reqs = [(_prompt(jax.random.fold_in(key, i), n), m)
            for i, (n, m) in enumerate(
                [(37, 6), (5, 4), (21, 1), (12, 8), (40, 3)])]

    def run(**kw):
        eng = ContinuousBatchingEngine(cfg, params, max_slots=3, max_seq=64,
                                       paged=True, page_size=8, num_pages=40,
                                       sanitize=True, **kw)
        rids = [eng.submit(p, n) for p, n in reqs]
        done = {c.rid: c.tokens for c in eng.drain()}
        assert eng.sanitizer_report() == []
        return eng, [done[r] for r in rids]

    _, base = run()
    for budget in (4, 16):
        eng, out = run(prefill_token_budget=budget)
        for a, b in zip(base, out):
            assert np.array_equal(a, b)
        assert eng.stats["prefill_traces"] == 1
        assert eng.stats["decode_traces"] == 1
        assert eng.stats["prefill_chunks"] >= sum(
            -(-p.shape[1] // budget) for p, _ in reqs)


def test_chunked_prefill_no_decode_before_final_chunk(cfg, params):
    """Mid-prefill a slot is invisible to decode: no decode step runs (and the
    slot never activates) until the prompt's final chunk adopts its pages."""
    eng = ContinuousBatchingEngine(cfg, params, max_slots=2, max_seq=64,
                                   paged=True, page_size=8,
                                   prefill_token_budget=8, sanitize=True)
    p = _prompt(jax.random.PRNGKey(41), 29)  # ceil(29/8) = 4 chunks
    rid = eng.submit(p, 5)
    for i in range(3):  # chunks 1..3: 24 of 29 tokens resident
        eng.step()
        assert eng.num_active == 0 and eng.stats["decode_steps"] == 0
        assert len(eng._partials) == 1
        assert eng._partials[0].done == 8 * (i + 1)
        # the reserved slot's device page row stays INVALID throughout
        assert (np.asarray(eng._table.page_map[eng._partials[0].slot])
                == eng._table.invalid_page).all()
    done = {c.rid: c.tokens for c in eng.drain()}
    assert eng.stats["prefill_chunks"] == 4
    assert np.array_equal(done[rid], _solo(cfg, params, p, 5, 64))


def test_chunked_prefill_radix_sharing_and_cow(cfg, params):
    """Radix hits still share pages under chunking: a common (non-page-
    aligned) system prompt is served from cached pages with a CoW copy of the
    partial page, only the tail is chunked, and tokens stay byte-identical
    to the monolithic engine's."""
    key = jax.random.PRNGKey(42)
    sys_p = _prompt(jax.random.fold_in(key, 100), 19)  # 19 % 8 != 0 -> CoW
    reqs = [(jnp.concatenate(
        [sys_p, _prompt(jax.random.fold_in(key, i), 6 + 3 * i)], 1), 4 + i)
        for i in range(3)]

    def run(**kw):
        eng = ContinuousBatchingEngine(cfg, params, max_slots=2, max_seq=64,
                                       paged=True, page_size=8, num_pages=40,
                                       sanitize=True, **kw)
        rids = [eng.submit(p, n) for p, n in reqs]
        done = {c.rid: c.tokens for c in eng.drain()}
        assert eng.sanitizer_report() == []
        return eng, [done[r] for r in rids]

    _, base = run()
    eng, out = run(prefill_token_budget=8)
    for a, b in zip(base, out):
        assert np.array_equal(a, b)
    assert eng.stats["radix_hits"] == 2
    assert eng.stats["cow_copies"] == 2
    assert eng.stats["radix_matched_tokens"] > 0
    # shared tokens never re-prefilled: chunked tokens cover only the tails
    total = sum(p.shape[1] for p, _ in reqs)
    assert eng.stats["prefill_tokens"] == \
        total - eng.stats["radix_matched_tokens"]


def test_chunked_prefill_validation(cfg, params):
    with pytest.raises(ValueError, match="needs paged=True"):
        ContinuousBatchingEngine(cfg, params, max_slots=2, max_seq=32,
                                 prefill_token_budget=8)
    with pytest.raises(ValueError, match="must be >= 1"):
        ContinuousBatchingEngine(cfg, params, max_slots=2, max_seq=32,
                                 paged=True, page_size=8,
                                 prefill_token_budget=0)


# ------------------------------------------- prompt bucket max_seq headroom


def test_prompt_of_max_seq_rejected_with_headroom_error(cfg, params):
    """Regression: a prompt of exactly max_seq must be rejected up front with
    an error naming the missing decode headroom — bucket rounding clamps at
    max_seq, so such a prompt would otherwise land in a bucket with zero room
    for the first decoded token."""
    eng = ContinuousBatchingEngine(cfg, params, max_slots=2, max_seq=32,
                                   prompt_bucket=8)
    with pytest.raises(ValueError, match="no headroom"):
        eng.submit(_prompt(jax.random.PRNGKey(43), 32), 1)
    with pytest.raises(ValueError, match="no headroom"):
        eng._bucket_len(32)  # the guard also covers direct callers
    # the boundary that IS admissible: prompt + gen == max_seq exactly, with
    # the bucket rounding the prompt up to the max_seq clamp
    p = _prompt(jax.random.PRNGKey(44), 31)
    rid = eng.submit(p, 1)
    done = {c.rid: c.tokens for c in eng.drain()}
    assert np.array_equal(done[rid], _solo(cfg, params, p, 1, 32))
