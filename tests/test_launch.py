"""Launch-layer logic that doesn't need the 512-device process: long-context
variants, input specs, analytic roofline formulas, report generation."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ARCH_IDS, INPUT_SHAPES, get_config
from repro import roofline as RL


def _variant(cfg, shape_name):
    # mirror of launch.dryrun.variant_config without importing it (that module
    # forces XLA_FLAGS at import time)
    if shape_name != "long_500k" or cfg.family in ("ssm", "hybrid"):
        return cfg
    pattern = tuple("swa" if t == "attn" else t for t in cfg.block_pattern)
    return cfg.with_overrides(block_pattern=pattern,
                              sliding_window=cfg.long_context_window)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_long_context_variant(arch):
    cfg = get_config(arch)
    v = _variant(cfg, "long_500k")
    if cfg.family in ("ssm", "hybrid"):
        assert v == cfg  # native sub-quadratic: no variant needed
    else:
        assert all(t != "attn" for t in v.layer_types)
        assert v.sliding_window == cfg.long_context_window
    # other shapes unchanged
    assert _variant(cfg, "train_4k") == cfg


def test_long_500k_cache_is_windowed():
    """The 524k decode cache must be O(window), not O(seq)."""
    from repro.models.cache import KVCache
    cfg = _variant(get_config("qwen2.5-32b"), "long_500k")
    cache = jax.eval_shape(lambda: KVCache.init(cfg, 1, 524_288, jnp.bfloat16))
    k = cache.layers[0]["k"]
    assert k.shape[-2] == cfg.long_context_window  # ring buffer, not 524288
    total = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))
    assert total < 2 * 2**30  # whole decode state ≪ naive 137 GB


def test_flops_analytic_scales():
    cfg = get_config("internlm2-1.8b")
    tr = RL.flops_analytic(cfg, INPUT_SHAPES["train_4k"], "train")
    pf = RL.flops_analytic(cfg, INPUT_SHAPES["prefill_32k"], "prefill")
    de = RL.flops_analytic(cfg, INPUT_SHAPES["decode_32k"], "decode")
    # train multiplier (×4 remat) vs prefill's 8× larger attention seq: both
    # matter — just pin the ordering and magnitudes
    assert tr > pf > de
    # decode processes B tokens vs B·S: orders of magnitude apart
    assert de < pf / 1000
    # 6·N·D sanity: analytic(train) within [1, 4]× of 6·N·D (attention + remat)
    model = RL.model_flops_for(cfg, INPUT_SHAPES["train_4k"], "train")
    assert 1.0 < tr / model < 4.0


def test_flops_analytic_moe_counts_dispatch():
    cfg = get_config("qwen3-moe-30b-a3b")
    with_d = RL.flops_analytic(cfg, INPUT_SHAPES["train_4k"], "train")
    # the dispatch/combine share must be visible: compare against a config with
    # tiny capacity
    small = cfg.with_overrides(moe_capacity_factor=0.01)
    without = RL.flops_analytic(small, INPUT_SHAPES["train_4k"], "train")
    assert with_d > without


def test_useful_ratio_below_one():
    """6·N·D may never exceed the as-written FLOPs (over-counting guard)."""
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape_name in ("train_4k", "prefill_32k"):
            shp = INPUT_SHAPES[shape_name]
            kind = shp.kind
            a = RL.flops_analytic(cfg, shp, kind, remat=(kind == "train"))
            m = RL.model_flops_for(cfg, shp, kind)
            assert m <= a * 1.10, (arch, shape_name, m / a)


def test_collective_parser_on_real_snippet():
    hlo = """
  %all-reduce.1 = bf16[8,128]{1,0} all-reduce(bf16[8,128]{1,0} %x), replica_groups={}
  %ag = (f32[4], f32[16]) all-gather(f32[4] %y), dimensions={0}
  %nothing = f32[2] add(f32[2] %a, f32[2] %b)
  %a2a.3 = s32[64]{0} all-to-all(s32[64]{0} %z)
"""
    st = RL.parse_collectives(hlo)
    assert st.counts == {"all-reduce": 1, "all-gather": 1, "all-to-all": 1}
    assert st.bytes_by_op["all-reduce"] == 8 * 128 * 2
    # AR weighted 2×; AG counts the (tuple) result bytes
    assert st.total_bytes == 2 * 8 * 128 * 2 + (4 + 16) * 4 + 64 * 4


def test_report_tables(tmp_path, monkeypatch):
    import json
    from repro.launch import report
    d = tmp_path / "dryrun"
    d.mkdir()
    rec = {"arch": "internlm2-1.8b", "shape": "train_4k", "mesh": "pod1x16x16",
           "ok": True, "compute_s": 0.1, "memory_s": 0.2, "collective_s": 0.3,
           "bottleneck": "collective", "useful_ratio": 0.7,
           "memory_per_device": {"temp_bytes": 2**30, "argument_bytes": 2**29}}
    with open(d / "internlm2_1_8b__train_4k__pod1x16x16.json", "w") as f:
        json.dump(rec, f)
    monkeypatch.setattr(report, "DRYRUN_DIR", str(d))
    recs = report.load_all()
    table = report.roofline_table(recs)
    assert "| internlm2_1_8b | train_4k | 100.00 | 200.00 | 300.00 " in table
    assert "MISSING" in table  # other archs absent
    assert "collective" in report.summary(recs)
