"""Beyond-paper state-to-state fuser (attention-free federation) — see
core/state_fuser.py and DESIGN.md §Arch-applicability."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import get_config, get_smoke_config
from repro.core import state_fuser as SF
from repro.models import transformer as T

KEY = jax.random.PRNGKey(2)


@pytest.fixture(scope="module")
def pair():
    cfg_a = get_smoke_config("mamba2-130m")
    cfg_b = cfg_a.with_overrides(num_layers=3, d_model=96, ssm_head_dim=24,
                                 name="mamba2-smoke-b")
    pa = T.init_params(cfg_a, KEY, jnp.float32)
    pb = T.init_params(cfg_b, jax.random.fold_in(KEY, 1), jnp.float32)
    return cfg_a, pa, cfg_b, pb


@pytest.mark.slow
def test_state_fusion_decode(pair):
    cfg_a, pa, cfg_b, pb = pair
    prompt = jax.random.randint(KEY, (2, 16), 0, cfg_a.vocab_size)
    _, ca = T.prefill(cfg_b, pb, prompt % cfg_b.vocab_size, max_seq=20,
                      cache_dtype=jnp.float32)
    _, cb = T.prefill(cfg_a, pa, prompt, max_seq=20, cache_dtype=jnp.float32)
    fz = SF.init_state_fuser(cfg_b, cfg_a, KEY)
    fused = SF.fuse_states(fz, cfg_b, cfg_a, ca, cb)
    lg, _ = T.decode_step(cfg_a, pa, fused, prompt[:, -1])
    assert lg.shape == (2, cfg_a.vocab_size)
    assert bool(jnp.isfinite(lg).all())


@pytest.mark.slow
def test_closed_gate_is_identity(pair):
    cfg_a, pa, cfg_b, pb = pair
    prompt = jax.random.randint(KEY, (2, 16), 0, cfg_a.vocab_size)
    _, ca = T.prefill(cfg_b, pb, prompt % cfg_b.vocab_size, max_seq=20,
                      cache_dtype=jnp.float32)
    _, cb = T.prefill(cfg_a, pa, prompt, max_seq=20, cache_dtype=jnp.float32)
    fz = dict(SF.init_state_fuser(cfg_b, cfg_a, KEY))
    fz["gate"] = jnp.full_like(fz["gate"], -200.0)
    fused = SF.fuse_states(fz, cfg_b, cfg_a, ca, cb)
    lg0, _ = T.decode_step(cfg_a, pa, fused, prompt[:, -1])
    lg_ref, _ = T.decode_step(cfg_a, pa, cb, prompt[:, -1])
    assert float(jnp.abs(lg0 - lg_ref).max()) == 0.0


def test_attention_archs_rejected(pair):
    cfg_a, *_ = pair
    with pytest.raises(SF.StateInapplicableError):
        SF.init_state_fuser(get_smoke_config("qwen3-1.7b"), cfg_a, KEY)


def test_hybrid_rec_layers_accepted():
    rg = get_smoke_config("recurrentgemma-9b")
    mb = get_smoke_config("mamba2-130m")
    fz = SF.init_state_fuser(rg, mb, KEY)  # rec -> ssd states
    assert fz["mlp"]["w1"]["w"].shape[0] == len(
        [t for t in mb.layer_types if t == "ssd"])


def test_constant_message_size():
    """The state medium is O(1) in sequence length (vs O(S) for KV C2C)."""
    cfg = get_config("mamba2-130m")
    b = SF.state_bytes(cfg)
    assert b == 24 * cfg.ssm_nheads * cfg.ssm_head_dim * cfg.ssm_state * 4
    assert b < 32 * 2**20  # ~19 MB regardless of context length
