"""Transport-channel & paged-slot-table invariants (core/transport.py,
models/cache.py SlotTable).

Property style (hypothesis when installed, repro.testing.propcheck shim
otherwise): channels must round-trip shapes/dtypes exactly even when lossy in
values; measured bytes_on_wire must reproduce commload.py's analytic numbers;
the paged SlotTable must be byte-for-byte equivalent to the dense slot
reference wherever the per-slot position mask exposes content.
"""
import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # hermetic env: boundary-first deterministic shim
    from repro.testing.propcheck import given, settings, strategies as st

from repro.configs.base import ModelConfig
from repro.core import commload, quant
from repro.core import transport as TR
from repro.core.privacy import synonym_channel
from repro.models import transformer as T
from repro.models.cache import KVCache, KVStack, SlotTable

KEY = jax.random.PRNGKey(13)
settings.register_profile("ci", max_examples=10, deadline=None)
settings.load_profile("ci")


def _stack(n=2, B=1, H=2, S=6, hd=8, dtype=jnp.float32) -> KVStack:
    k1, k2 = jax.random.split(KEY)
    return KVStack(k=jax.random.normal(k1, (n, B, H, S, hd), dtype),
                   v=jax.random.normal(k2, (n, B, H, S, hd), dtype))


# ------------------------------------------------------------- round trips


@given(st.integers(1, 3), st.integers(1, 3), st.integers(2, 10))
def test_pipeline_roundtrips_shapes_dtypes(n, B, S):
    """Pipeline([RephraseChannel, QuantChannel]): lossy in values (int8,
    paraphrase) but exact in shapes and dtypes — the channel contract."""
    stack = _stack(n=n, B=B, S=S)
    tokens = jax.random.randint(KEY, (B, S), 0, 64)
    pipe = TR.Pipeline([
        TR.RephraseChannel(synonym_channel(64, 4, KEY), KEY),
        TR.QuantChannel(jnp.float32),
    ])
    out, nbytes = pipe.transmit(TR.Message(stack=stack, tokens=tokens))
    assert out.stack.k.shape == stack.k.shape
    assert out.stack.v.shape == stack.v.shape
    assert out.stack.k.dtype == stack.k.dtype
    assert out.tokens.shape == tokens.shape
    assert out.tokens.dtype == tokens.dtype
    assert nbytes > 0


def test_quant_channel_reconstruction_close():
    stack = _stack(S=32)
    out, _ = TR.QuantChannel(jnp.float32).transmit(TR.stack_message(stack))
    rel = float(jnp.abs(out.stack.k - stack.k).max()
                / jnp.abs(stack.k).max())
    assert rel < 0.02  # int8 per-channel round trip


def test_rephrase_channel_preserves_synonym_class():
    ch = synonym_channel(64, 4, KEY)
    tokens = jax.random.randint(KEY, (2, 8), 0, 64)
    out, _ = TR.RephraseChannel(ch, KEY).transmit(TR.token_message(tokens))
    assert (ch.class_of[tokens] == ch.class_of[out.tokens]).all()


# ---------------------------------------------------------- byte accounting


def test_identity_bytes_match_commload_c2c():
    """Measured IdentityChannel bytes over a real exported stack == the
    analytic c2c_bytes_total the protocol model uses."""
    cfg = ModelConfig(name="bytes-tiny", family="dense", num_layers=2,
                      d_model=32, num_heads=2, num_kv_heads=2, head_dim=8,
                      d_ff=64, vocab_size=64, tie_embeddings=True)
    params = T.init_params(cfg, KEY, jnp.float32)
    S = 10
    prompt = jax.random.randint(KEY, (1, S), 0, 64)
    _, cache = T.prefill(cfg, params, prompt, max_seq=S,
                         cache_dtype=jnp.bfloat16)
    stack = cache.export_stack(cfg, length=S)
    wire = TR.IdentityChannel().encode(TR.stack_message(stack))
    measured = TR.IdentityChannel().bytes_on_wire(wire)
    assert measured == commload.c2c_bytes_total([cfg], S, dtype_bytes=2)
    assert measured == commload.measured_bytes(stack)


@given(st.integers(1, 4), st.integers(1, 64))
def test_identity_bytes_match_commload_t2t(B, S):
    tokens = jnp.zeros((B, S), jnp.int32)
    wire = TR.IdentityChannel().encode(TR.token_message(tokens))
    assert (TR.IdentityChannel().bytes_on_wire(wire)
            == B * S * commload.t2t_bytes_per_token())


def test_quant_bytes_match_quantized_bytes():
    stack = _stack(n=3, B=2, S=16)
    wire = TR.QuantChannel().encode(TR.stack_message(stack))
    assert TR.QuantChannel().bytes_on_wire(wire) == quant.quantized_bytes(stack)


# --------------------------------------------------------- paged slot table


def _tiny_cfg():
    return ModelConfig(name="paged-tiny", family="dense", num_layers=3,
                       d_model=32, num_heads=2, num_kv_heads=1, head_dim=16,
                       d_ff=64, vocab_size=64, tie_embeddings=True)


def _visible(table_cache: KVCache, slot: int, upto: int):
    """K/V content the position mask exposes for ``slot``."""
    return [(np.asarray(e["k"][:, slot, :, :upto]),
             np.asarray(e["v"][:, slot, :, :upto]))
            for e in table_cache.layers]


@given(st.integers(2, 4), st.integers(1, 12))
@settings(max_examples=8)
def test_paged_insert_evict_equals_dense_reference(slots, length):
    """SlotTable insert/evict == the dense KVCache slot reference on every
    position the mask exposes, for random slots/lengths."""
    cfg = _tiny_cfg()
    params = T.init_params(cfg, KEY, jnp.float32)
    max_seq, page = 16, 4
    dense = KVCache.init_slots(cfg, slots, max_seq, jnp.float32)
    paged = SlotTable.init(cfg, slots, max_seq, jnp.float32, page_size=page)
    prompt = jax.random.randint(jax.random.fold_in(KEY, length),
                                (1, length), 0, 64)
    _, req = T.prefill(cfg, params, prompt, max_seq=max_seq,
                       cache_dtype=jnp.float32)
    slot = length % slots
    need = -(-length // page)
    page_ids = np.full((max_seq // page,), paged.invalid_page, np.int32)
    page_ids[:need] = np.arange(need)
    dense = dense.insert_slot(slot, req, length)
    paged = paged.insert_slot(slot, req, length, jnp.asarray(page_ids))
    assert paged.pos.tolist() == dense.pos.tolist()
    for (dk, dv), (pk, pv) in zip(_visible(dense, slot, length),
                                  _visible(paged.dense_view(), slot, length)):
        assert np.array_equal(dk, pk) and np.array_equal(dv, pv)
    # evict resets position and unmaps every page
    dense = dense.evict_slot(slot)
    paged = paged.evict_slot(slot)
    assert paged.pos.tolist() == dense.pos.tolist() == [0] * slots
    assert (np.asarray(paged.page_map[slot]) == paged.invalid_page).all()


def test_paged_commit_scatters_decode_token():
    """One decode step through the gathered view lands in the right physical
    page, and the refreshed view equals a dense decode's cache content."""
    cfg = _tiny_cfg()
    params = T.init_params(cfg, KEY, jnp.float32)
    max_seq, page, S = 16, 4, 6
    prompt = jax.random.randint(KEY, (1, S), 0, 64)
    _, req = T.prefill(cfg, params, prompt, max_seq=max_seq,
                       cache_dtype=jnp.float32)
    dense = KVCache.init_slots(cfg, 2, max_seq, jnp.float32)
    paged = SlotTable.init(cfg, 2, max_seq, jnp.float32, page_size=page)
    page_ids = np.full((max_seq // page,), paged.invalid_page, np.int32)
    page_ids[:2] = [3, 1]  # deliberately non-contiguous physical pages
    dense = dense.insert_slot(0, req, S)
    paged = paged.insert_slot(0, req, S, jnp.asarray(page_ids))
    tok = jnp.asarray([7, 0], jnp.int32)
    lg_d, dense2 = T.decode_step(cfg, params, dense, tok)
    lg_p, view2 = T.decode_step(cfg, params, paged.dense_view(), tok)
    assert np.array_equal(np.asarray(lg_d[0]), np.asarray(lg_p[0]))
    paged2 = paged.commit(view2, view2.pos)
    for (dk, dv), (pk, pv) in zip(_visible(dense2, 0, S + 1),
                                  _visible(paged2.dense_view(), 0, S + 1)):
        assert np.array_equal(dk, pk) and np.array_equal(dv, pv)


def test_quant_channel_restores_source_dtype_by_default():
    """QuantChannel() with no dtype reconstructs at the ENCODED stack's dtype
    (the round-trip contract), via the zero-byte dtype marker."""
    for dtype in (jnp.float32, jnp.bfloat16):
        stack = _stack(dtype=dtype)
        wire = TR.QuantChannel().encode(TR.stack_message(stack))
        assert TR.QuantChannel().bytes_on_wire(wire) == quant.quantized_bytes(
            stack)  # the marker adds zero wire bytes
        out = TR.QuantChannel().decode(wire)
        assert out.stack.k.dtype == dtype


# ------------------------------------------------------------ codec registry


def test_codec_registry_roundtrip_nondefault_layouts():
    """Every registered codec round-trips shapes/dtypes exactly on int8-able
    KV stacks across non-default head/layer layouts (MQA-style H=1, deep
    narrow n=5, wide-head hd=32) — the channel contract, per codec."""
    layouts = [dict(n=5, B=1, H=1, S=7, hd=32),   # MQA-ish, wide head
               dict(n=1, B=3, H=4, S=9, hd=4),    # single layer, many heads
               dict(n=3, B=2, H=2, S=1, hd=8)]    # single-token sequence
    tokens = jax.random.randint(KEY, (2, 6), 0, 64)
    for name in sorted(TR.CODECS):
        for layout in layouts:
            stack = _stack(**layout)
            codec = TR.make_codec(name, vocab=64, key=KEY)
            out, nbytes = codec.transmit(TR.Message(stack=stack,
                                                    tokens=tokens))
            assert out.stack.k.shape == stack.k.shape, (name, layout)
            assert out.stack.v.dtype == stack.v.dtype, (name, layout)
            assert out.tokens.shape == tokens.shape
            assert out.tokens.dtype == tokens.dtype
            assert nbytes > 0


def test_codec_registry_empty_stack_edge_case():
    """S=0 stacks (nothing prefilled yet) must survive every codec: exact
    shape/dtype round trip and non-negative accounted bytes."""
    empty = KVStack(k=jnp.zeros((2, 1, 2, 0, 8), jnp.float32),
                    v=jnp.zeros((2, 1, 2, 0, 8), jnp.float32))
    for name in sorted(TR.CODECS):
        codec = TR.make_codec(name, vocab=64, key=KEY)
        out, nbytes = codec.transmit(TR.stack_message(empty))
        assert out.stack.k.shape == empty.k.shape, name
        assert out.stack.k.dtype == empty.k.dtype, name
        assert nbytes >= 0


def test_codec_registry_bytes_pinned_against_commload():
    """Measured bytes_on_wire for every registry codec equals the analytic
    number: dense measured_bytes for token-only transforms, quantized_bytes
    once an int8 stage is in the pipeline, plus 4 B/token either way."""
    stack = _stack(n=3, B=2, H=2, S=12, hd=8)
    tokens = jax.random.randint(KEY, (2, 12), 0, 64)
    token_bytes = int(tokens.size) * commload.t2t_bytes_per_token()
    expected = {
        "identity": commload.measured_bytes(stack) + token_bytes,
        "rephrase": commload.measured_bytes(stack) + token_bytes,
        "int8": quant.quantized_bytes(stack) + token_bytes,
        "rephrase+int8": quant.quantized_bytes(stack) + token_bytes,
    }
    assert set(expected) == set(TR.CODECS)  # pin the registry contents
    for name, want in expected.items():
        codec = TR.make_codec(name, vocab=64, key=KEY)
        wire = codec.encode(TR.Message(stack=stack, tokens=tokens))
        assert codec.bytes_on_wire(wire) == want, name


def test_make_codec_unknown_name_raises():
    try:
        TR.make_codec("zstd")
    except ValueError as e:
        assert "zstd" in str(e) and "identity" in str(e)
    else:
        raise AssertionError("unknown codec name must raise")


def test_rephrase_channel_distinct_draws_per_transmit():
    """Repeated encodes fold a call counter into the key: two transmissions
    of one prompt get different rephrasings (transmitter diversity)."""
    ch = synonym_channel(64, 2, KEY)
    tokens = jax.random.randint(KEY, (4, 16), 0, 64)
    rc = TR.RephraseChannel(ch, KEY)
    a, _ = rc.transmit(TR.token_message(tokens))
    b, _ = rc.transmit(TR.token_message(tokens))
    assert not np.array_equal(np.asarray(a.tokens), np.asarray(b.tokens))
    assert (ch.class_of[a.tokens] == ch.class_of[b.tokens]).all()
