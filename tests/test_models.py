"""Per-architecture model behaviour: forward/train smoke + decode parity."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ARCH_IDS, get_smoke_config
from repro.models import transformer as T
from repro.models.frontend import synth_embeddings, synth_mrope_positions


def _inputs(cfg, key, B, S):
    if cfg.frontend == "vision":
        return {
            "embeds": synth_embeddings(cfg, key, B, S, jnp.float32),
            "positions_3d": synth_mrope_positions(B, S, image_patches=S // 2),
        }
    return {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_smoke(arch, key):
    cfg = get_smoke_config(arch)
    params = T.init_params(cfg, key, jnp.float32)
    B, S = 2, 32
    ins = _inputs(cfg, key, B, S)
    logits, aux = T.forward(cfg, params, **ins)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    if cfg.num_experts:
        assert float(aux) > 0  # load-balance loss is live


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch, key):
    from repro.launch.train import make_train_step
    from repro.optim.adamw import AdamWConfig, init_opt_state

    cfg = get_smoke_config(arch)
    params = T.init_params(cfg, key, jnp.float32)
    B, S = 2, 16
    ins = _inputs(cfg, key, B, S)
    batch = dict(ins)
    batch["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    opt_cfg = AdamWConfig(lr=1e-3)
    opt_state = init_opt_state(params)
    step = jax.jit(make_train_step(cfg, opt_cfg, remat=False))
    new_params, new_state, loss = step(params, opt_state, batch)
    assert bool(jnp.isfinite(loss))
    assert int(new_state["step"]) == 1
    # params actually moved
    moved = jax.tree.leaves(jax.tree.map(
        lambda a, b: bool(jnp.any(a != b)) if a.dtype.kind == "f" else False,
        params, new_params))
    assert any(moved)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_teacher_forced(arch, key):
    cfg = get_smoke_config(arch)
    params = T.init_params(cfg, key, jnp.float32)
    B, S, extra = 2, 16, 4
    toks = jax.random.randint(key, (B, S + extra), 0, cfg.vocab_size)
    full_logits, _ = T.forward(cfg, params, toks)
    logits_p, cache = T.prefill(cfg, params, toks[:, :S], max_seq=S + extra,
                                cache_dtype=jnp.float32)
    errs = [float(jnp.abs(logits_p[:, -1] - full_logits[:, S - 1]).max())]
    for i in range(extra):
        lg, cache = T.decode_step(cfg, params, cache, toks[:, S + i])
        errs.append(float(jnp.abs(lg - full_logits[:, S + i]).max()))
    assert max(errs) < 2e-3, f"{arch}: decode diverges from teacher-forced {errs}"


@pytest.mark.slow
def test_unroll_matches_scan(key):
    cfg = get_smoke_config("recurrentgemma-9b")  # pattern cycles + tail
    cfg = cfg.with_overrides(num_layers=3)
    params = T.init_params(cfg, key, jnp.float32)
    toks = jax.random.randint(key, (2, 12), 0, cfg.vocab_size)
    a, _ = T.forward(cfg, params, toks)
    b, _ = T.forward(cfg, params, toks, unroll=True)
    assert float(jnp.abs(a - b).max()) < 1e-4


@pytest.mark.slow
def test_sliding_window_ring_buffer_wraps(key):
    cfg = get_smoke_config("recurrentgemma-9b").with_overrides(sliding_window=8)
    params = T.init_params(cfg, key, jnp.float32)
    B, S, extra = 1, 12, 6  # decode well past the window
    toks = jax.random.randint(key, (B, S + extra), 0, cfg.vocab_size)
    full_logits, _ = T.forward(cfg, params, toks)
    _, cache = T.prefill(cfg, params, toks[:, :S], max_seq=S + extra,
                         cache_dtype=jnp.float32)
    errs = []
    for i in range(extra):
        lg, cache = T.decode_step(cfg, params, cache, toks[:, S + i])
        errs.append(float(jnp.abs(lg - full_logits[:, S + i]).max()))
    assert max(errs) < 2e-3


def test_mrope_vision_block_changes_logits(key):
    cfg = get_smoke_config("qwen2-vl-72b")
    params = T.init_params(cfg, key, jnp.float32)
    B, S = 1, 16
    emb = synth_embeddings(cfg, key, B, S, jnp.float32)
    p_img = synth_mrope_positions(B, S, image_patches=8)
    p_txt = synth_mrope_positions(B, S)
    a, _ = T.forward(cfg, params, embeds=emb, positions_3d=p_img)
    b, _ = T.forward(cfg, params, embeds=emb, positions_3d=p_txt)
    assert float(jnp.abs(a - b).max()) > 1e-4  # M-RoPE stream actually used


def test_moe_capacity_drops_are_bounded(key):
    from repro.models.moe import moe_ffn
    cfg = get_smoke_config("qwen3-moe-30b-a3b")
    params = T.init_params(cfg, key, jnp.float32)
    layer = jax.tree.map(lambda a: a[0], params["cycle"][0])
    x = jax.random.normal(key, (2, 64, cfg.d_model), jnp.float32)
    y, aux = moe_ffn(cfg, layer["ffn"], x)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())
    assert 0.5 < float(aux) < 10.0  # near-uniform router at init => aux ≈ 1
