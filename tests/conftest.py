import os

# Tests must see the real (1-device) CPU platform — the 512-device override is
# exclusively for launch/dryrun.py (see its module docstring).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import jax.numpy as jnp
import pytest


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def f32():
    return jnp.float32
