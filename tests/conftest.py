import importlib.util
import os

# Tests must see the real (1-device) CPU platform — the 512-device override is
# exclusively for launch/dryrun.py (see its module docstring).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import jax.numpy as jnp
import pytest

# ---------------------------------------------------------------- timeouts
# CI installs pytest-timeout (requirements.txt) and the ``timeout`` ini key in
# pyproject.toml gives every test a hard ceiling. Hermetic environments
# without the plugin get this SIGALRM fallback so a hung interpret-mode
# kernel still fails fast instead of stalling the whole gate. (Same spirit as
# the hypothesis fallback shim in repro/testing/propcheck.py.)
_HAVE_TIMEOUT_PLUGIN = importlib.util.find_spec("pytest_timeout") is not None

if not _HAVE_TIMEOUT_PLUGIN:
    def pytest_addoption(parser):
        parser.addini("timeout", "per-test ceiling in seconds (fallback shim "
                      "for pytest-timeout)", default="0")

    @pytest.hookimpl(wrapper=True)
    def pytest_runtest_call(item):
        import signal

        limit = float(item.config.getini("timeout") or 0)
        marker = item.get_closest_marker("timeout")
        if marker and marker.args:
            limit = float(marker.args[0])
        if limit <= 0 or not hasattr(signal, "SIGALRM"):
            return (yield)

        def _alarm(signum, frame):
            pytest.fail(f"test exceeded the {limit:.0f}s per-test ceiling "
                        f"(conftest pytest-timeout shim)", pytrace=False)

        old = signal.signal(signal.SIGALRM, _alarm)
        signal.setitimer(signal.ITIMER_REAL, limit)
        try:
            return (yield)
        finally:
            signal.setitimer(signal.ITIMER_REAL, 0)
            signal.signal(signal.SIGALRM, old)


# ------------------------------------------------------------- compile churn
# Every live jitted executable keeps its JIT-compiled code resident in the
# XLA CPU client. Across the full suite (~350 tests, most compiling several
# programs) that accumulates until a later backend_compile segfaults inside
# the compiler — deterministically at whichever test crosses the threshold,
# while any subset of the suite passes. Dropping the executable caches at
# module teardown bounds resident code by the heaviest module instead of the
# whole run; cross-module cache reuse is negligible (modules compile their
# own shapes), so the wall-clock cost is noise.
@pytest.fixture(autouse=True, scope="module")
def _bounded_compile_residency():
    yield
    jax.clear_caches()


@pytest.fixture
def trace_guard():
    """Factory fixture for repro.analysis.TraceGuard: returns the class so a
    test can open its own budgeted window, e.g.
    ``with trace_guard(max_traces={"decode": 1}) as tg: ...``."""
    from repro.analysis import TraceGuard

    return TraceGuard


@pytest.fixture
def sanitized_engine():
    """Factory fixture: a paged ContinuousBatchingEngine with the page-
    lifecycle sanitizer on (repro.analysis.PageSanitizer backs the
    allocator; every step is cross-checked and drain() raises on leaks).
    Usage: ``eng = sanitized_engine(cfg, params, max_slots=4, ...)``."""
    from repro.launch.engine import ContinuousBatchingEngine

    def make(cfg, params, **kw):
        kw.setdefault("paged", True)
        return ContinuousBatchingEngine(cfg, params, sanitize=True, **kw)

    return make


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def f32():
    return jnp.float32
