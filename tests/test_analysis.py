"""repro.analysis: linter rule corpus (true positive + no false positive per
rule), jit-root/reachability behaviour, suppression comments, the CLI, the
self-lint acceptance gate, and TraceGuard retrace enforcement on the engine.

Corpus contract (ISSUE 7): every rule class ships a known-bad snippet the
linter must flag and a known-good twin it must stay silent on — CI treats any
finding as a failure, so the no-FP half is what keeps the gate trustworthy.
"""
import os
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import RULES, TraceGuard, TraceGuardError, lint_paths
from repro.analysis.__main__ import main as lint_main
from repro.configs.base import ModelConfig
from repro.configs.case_study import tiny_zoo
from repro.core import fuser as F
from repro.launch.engine import ContinuousBatchingEngine
from repro.models import transformer as T

VOCAB = 64

_PALLAS_HEADER = """
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    def kernel(x_ref, o_ref):
        o_ref[...] = x_ref[...]
"""

# rule -> (bad snippet, good twin). Bad must produce >= 1 finding of exactly
# that rule; good must produce zero findings of any rule.
CORPUS = {
    "tracer-branch": (
        """
        import jax
        import jax.numpy as jnp

        @jax.jit
        def f(x):
            y = jnp.sum(x)
            if y > 0:
                return y
            return -y
        """,
        """
        import jax
        import jax.numpy as jnp

        @jax.jit
        def f(x):
            y = jnp.sum(x)
            return jnp.where(y > 0, y, -y)

        @jax.jit
        def g(x):
            if x.shape[0] > 2:  # static shape: fine under jit
                return x * 2
            return x
        """,
    ),
    "tracer-bool-cast": (
        """
        import jax
        import jax.numpy as jnp

        @jax.jit
        def f(x):
            s = jnp.sum(x)
            assert s > 0
            return bool(jnp.max(x) > 0), s
        """,
        """
        import jax
        import jax.numpy as jnp

        @jax.jit
        def f(x, lo):
            assert x.ndim == 2, x.shape  # static metadata: fine
            assert lo is not None       # identity test: fine
            return jnp.sum(x)
        """,
    ),
    "tracer-host-op": (
        """
        import jax
        import jax.numpy as jnp
        import numpy as np

        @jax.jit
        def f(x):
            y = jnp.sum(x)
            hi = np.asarray(y)
            return float(jnp.mean(x)), jnp.max(x).item(), hi
        """,
        """
        import jax
        import jax.numpy as jnp
        import numpy as np

        @jax.jit
        def f(x):
            n = int(x.shape[0])          # static shape
            host = np.arange(n)          # np on host values only
            return jnp.sum(x) + jnp.asarray(host)

        def host_side(x):
            return float(np.mean(x))     # not jit-reachable: fine
        """,
    ),
    "trace-side-effect": (
        """
        import jax
        import jax.numpy as jnp

        class Engine:
            def __init__(self):
                self.stats = {}
                self.fn = jax.jit(lambda x: self.step(x))

            def step(self, x):
                self.stats["steps"] = 1
                print("tracing step")
                return jnp.sum(x)
        """,
        """
        import jax
        import jax.numpy as jnp

        class Engine:
            def __init__(self):
                self.stats = {}
                self.fn = jax.jit(lambda x: self.step(x))

            def step(self, x):
                jax.debug.print("step {x}", x=x)  # runs per call, not per trace
                return jnp.sum(x)

            def host_update(self):  # not jit-reachable: fine
                self.stats["drained"] = 1
        """,
    ),
    "dropped-at-set": (
        """
        import jax.numpy as jnp

        def f(x):
            x.at[0].set(1.0)
            return x
        """,
        """
        import jax.numpy as jnp

        def f(x):
            x = x.at[0].set(1.0)
            return x
        """,
    ),
    "dict-kv-access": (
        """
        from repro.models.cache import FusedPrefix

        def f(obj):
            fp = FusedPrefix.ensure(obj)
            return fp["k"], fp["v"]
        """,
        """
        from repro.models.cache import FusedPrefix

        def f(obj, entry):
            fp = FusedPrefix.ensure(obj)
            return fp.k, fp.v, entry["k"]  # plain layer dicts stay dicts
        """,
    ),
    "dict-kv-literal": (
        """
        def f(k, v, b):
            return {"k": k, "v": v, "bias": b}
        """,
        """
        from repro.models.cache import FusedPrefix

        def f(k, v, b):
            typed = FusedPrefix(k=k, v=v, bias=b)
            layer_entry = {"k": k, "v": v}  # 2-key cache entries are fine
            return typed, layer_entry
        """,
    ),
    "pallas-grid-arity": (
        _PALLAS_HEADER + """
        def f(x):
            return pl.pallas_call(
                kernel,
                grid=(4, 4),
                in_specs=[pl.BlockSpec((8, 8), lambda i: (i, 0))],
                out_specs=pl.BlockSpec((8, 8), lambda i, j: (i, j)),
                out_shape=jax.ShapeDtypeStruct((32, 32), jnp.float32),
            )(x)
        """,
        _PALLAS_HEADER + """
        def f(x):
            grid = (4, 4)
            spec = pl.BlockSpec((8, 8), lambda i, j: (i, j))
            return pl.pallas_call(
                kernel,
                grid=grid,
                in_specs=[spec],
                out_specs=spec,
                out_shape=jax.ShapeDtypeStruct((32, 32), jnp.float32),
            )(x)
        """,
    ),
    "pallas-scalar-prefetch": (
        _PALLAS_HEADER + """
        def f(x, y):
            return pl.pallas_call(
                kernel,
                grid=(4,),
                in_specs=[pl.BlockSpec((8,), lambda i: (i,))],
                out_specs=pl.BlockSpec((8,), lambda i: (i,)),
                out_shape=jax.ShapeDtypeStruct((32,), jnp.float32),
            )(x, y)
        """,
        _PALLAS_HEADER + """
        def f(x, y):
            specs = [pl.BlockSpec((8,), lambda i: (i,))] * 2
            return pl.pallas_call(
                kernel,
                grid=(4,),
                in_specs=specs,
                out_specs=pl.BlockSpec((8,), lambda i: (i,)),
                out_shape=jax.ShapeDtypeStruct((32,), jnp.float32),
            )(x, y)
        """,
    ),
    "pallas-out-shape": (
        _PALLAS_HEADER + """
        def f(x):
            return pl.pallas_call(
                kernel,
                grid=(4,),
                in_specs=[pl.BlockSpec((8,), lambda i: (i,))],
                out_specs=[pl.BlockSpec((8,), lambda i: (i,)),
                           pl.BlockSpec((8,), lambda i: (i,))],
                out_shape=[jax.ShapeDtypeStruct((32,), jnp.float32)],
            )(x)
        """,
        _PALLAS_HEADER + """
        def f(x):
            return pl.pallas_call(
                kernel,
                grid=(4,),
                in_specs=[pl.BlockSpec((8,), lambda i: (i,))],
                out_specs=[pl.BlockSpec((8,), lambda i: (i,)),
                           pl.BlockSpec((8,), lambda i: (i,))],
                out_shape=[jax.ShapeDtypeStruct((32,), jnp.float32),
                           jax.ShapeDtypeStruct((32,), jnp.int32)],
            )(x)
        """,
    ),
    "bare-assert-kernel": (
        """
        def tile(T, bt):
            assert T % bt == 0, (T, bt)
            return T // bt
        """,
        """
        def tile(T, bt):
            if T % bt != 0:
                raise ValueError(f"T {T} not divisible by block {bt}")
            return T // bt
        """,
    ),
    # ---- OWNxxx: page-lease ownership pass (repro.analysis.ownership) ----
    "lease-leak": (
        """
        def admit(allocator):
            lease = allocator.lease(fresh=2)   # never sunk: pages held forever
            return lease.num_pages
        """,
        """
        def admit(allocator, table, slot):
            lease = allocator.lease(fresh=2)
            row = lease.page_row(8, 99)
            table = table.insert_slot(slot, row)
            return table, lease

        def borrow(lease):
            return lease.num_pages  # parameters are borrowed, not owned
        """,
    ),
    "lease-double-release": (
        """
        def evict(allocator, lease0):
            lease = allocator.lease(fresh=1)
            allocator.release(lease)
            allocator.release(lease)
        """,
        """
        def evict(allocator, keep):
            lease = allocator.lease(fresh=1)
            if keep:
                allocator.release(lease)
            else:
                allocator.release(lease)  # exactly once on every path
        """,
    ),
    "lease-use-after-release": (
        """
        def evict(allocator):
            lease = allocator.lease(fresh=1)
            allocator.release(lease)
            return lease.ids()
        """,
        """
        def evict(allocator, index):
            lease = allocator.lease(fresh=1)
            index.register(lease.ids())  # derived views consumed pre-release
            n = lease.num_pages
            allocator.release(lease)
            return n                     # plain ints: not a tainted view
        """,
    ),
    "shared-write-no-cow": (
        """
        def admit(allocator, table, slot, cache, phys, off, pos):
            lease = allocator.lease(shared=[3, 4], fresh=1)
            row = lease.page_row(8, 99)
            table = table.insert_suffix(slot, cache, phys, off, row, pos)
            return table, lease
        """,
        """
        def admit(allocator, table, slot, cache, phys, off, pos):
            lease = allocator.lease(shared=[3, 4], fresh=1)
            allocator.cow(lease, 1)   # fault the partial page first
            row = lease.page_row(8, 99)
            table = table.insert_suffix(slot, cache, phys, off, row, pos)
            return table, lease
        """,
    ),
    "jit-page-mutation": (
        """
        import jax

        class Eng:
            def __init__(self):
                self._step = jax.jit(lambda t: self.decode(t))

            def decode(self, t):
                ids = self._allocator.alloc(1)  # host mutation under trace
                return t, ids
        """,
        """
        import jax

        class Eng:
            def __init__(self):
                self._step = jax.jit(lambda t: self.decode(t))

            def decode(self, t):
                return t * 2

            def admit(self):                  # host-side: mutation is fine
                ids = self._allocator.alloc(1)
                return ids
        """,
    ),
    "private-on-wire": (
        """
        from repro.core import transport as TR

        def ship(cfg, cache, wire: TR.IdentityChannel):
            stack = cache.export_stack(cfg, length=8)
            return wire.transmit(stack)
        """,
        """
        from repro.core import transport as TR

        def ship(cfg, cache, wire: TR.IdentityChannel):
            msg = TR.stack_message(cache.export_stack(cfg, length=8))
            received, nbytes = wire.transmit(msg)
            return received, nbytes
        """,
    ),
    "message-outside-codec": (
        """
        from repro.core import transport as TR

        def handcraft(ids):
            return TR.Message(tokens=ids)
        """,
        """
        from repro.core import transport as TR

        def handcraft(ids):
            return TR.token_message(ids)

        class MarkerChannel(TR.Channel):
            def encode(self, msg: TR.Message) -> TR.Message:
                # codec internals ARE the sanctioned place to build messages
                return TR.Message(tokens=msg.tokens,
                                  payload=dict(msg.payload))
        """,
    ),
    "unaccounted-wire-bytes": (
        """
        from repro.core.protocol import FederationProtocol, PreparedRequest

        class LeakyC2C(FederationProtocol):
            name = "leaky"

            def prepare(self, system, receiver, rx_ids, tx_names, *,
                        steps, key, gated=True, tx_prompts=None):
                stacks, _ = system.transmit_stacks(tx_names, {})
                fused = system.fused_prefix(receiver, tx_names, stacks)
                return PreparedRequest(prompt=rx_ids, fused=fused)
        """,
        """
        from repro.core.protocol import FederationProtocol, PreparedRequest

        class AccountedC2C(FederationProtocol):
            name = "accounted"

            def prepare(self, system, receiver, rx_ids, tx_names, *,
                        steps, key, gated=True, tx_prompts=None):
                stacks, wire_bytes = system.transmit_stacks(tx_names, {})
                fused = system.fused_prefix(receiver, tx_names, stacks)
                return PreparedRequest(prompt=rx_ids, fused=fused,
                                       transmitters=tx_names,
                                       wire_bytes=wire_bytes)
        """,
    ),
    "pipeline-drops-stage": (
        """
        from repro.core.protocol import WireSchema
        from repro.core.transport import (Pipeline, QuantChannel,
                                          RephraseChannel)

        SCHEMA = WireSchema(protocol="c2c", stages=("rephrase", "quant"))

        def build_wire(paraphraser, key):
            return Pipeline([RephraseChannel(paraphraser, key)])
        """,
        """
        from repro.core.protocol import WireSchema
        from repro.core.transport import (Pipeline, QuantChannel,
                                          RephraseChannel)

        SCHEMA = WireSchema(protocol="c2c", stages=("rephrase", "quant"))

        def build_wire(paraphraser, key):
            return Pipeline([RephraseChannel(paraphraser, key),
                             QuantChannel()])
        """,
    ),
    "jit-wire-sink": (
        """
        import jax
        from repro.core import transport as TR

        @jax.jit
        def step(x, wire: TR.IdentityChannel):
            msg = TR.token_message(x)
            return wire.encode(msg)
        """,
        """
        import jax
        import jax.numpy as jnp
        from repro.core import transport as TR

        @jax.jit
        def step(x):
            return jnp.sum(x)

        def host_transmit(x, wire: TR.IdentityChannel):
            msg = TR.token_message(step(x))
            received, nbytes = wire.transmit(msg)
            return received, nbytes
        """,
    ),
}


def _write(tmp_path, rule, kind, src):
    # PLC004 only fires inside kernel modules: route its corpus there
    sub = "kernels" if rule == "bare-assert-kernel" else "lib"
    d = tmp_path / kind / sub
    d.mkdir(parents=True, exist_ok=True)
    p = d / f"{rule.replace('-', '_')}.py"
    p.write_text(textwrap.dedent(src))
    return str(p)


@pytest.mark.parametrize("rule", sorted(CORPUS))
def test_rule_true_positive(tmp_path, rule):
    path = _write(tmp_path, rule, "bad", CORPUS[rule][0])
    hits = lint_paths([path])
    assert any(f.rule == rule for f in hits), (
        f"{rule}: known-bad snippet produced {[f.format() for f in hits]}")


@pytest.mark.parametrize("rule", sorted(CORPUS))
def test_rule_no_false_positive(tmp_path, rule):
    path = _write(tmp_path, rule, "good", CORPUS[rule][1])
    hits = lint_paths([path])
    assert hits == [], (
        f"{rule}: known-good snippet produced {[f.format() for f in hits]}")


def test_corpus_covers_at_least_eight_rules():
    assert len(CORPUS) >= 8
    assert set(CORPUS) <= set(RULES)


def test_suppression_comment_drops_finding(tmp_path):
    src = textwrap.dedent("""
        def f(k, v, b):
            # lint: allow(dict-kv-literal)
            a = {"k": k, "v": v, "bias": b}
            b2 = {"k": k, "v": v, "bias": b}  # lint: allow(dict-kv-literal)
            return a, b2
    """)
    p = tmp_path / "sup.py"
    p.write_text(src)
    assert lint_paths([str(p)]) == []
    # the same file without the comments does get flagged (twice)
    q = tmp_path / "nosup.py"
    q.write_text(src.replace("# lint: allow(dict-kv-literal)", ""))
    assert len(lint_paths([str(q)])) == 2


def test_audit_suppressions_flags_stale_and_unknown(tmp_path):
    """--audit-suppressions: an allow() whose rule no longer fires in its
    window is stale; an unknown rule name is always stale; a live one (the
    finding it covers still exists raw) is kept."""
    from repro.analysis import audit_suppressions

    src = textwrap.dedent("""
        def f(k, v, b):
            live = {"k": k, "v": v, "bias": b}  # lint: allow(dict-kv-literal)
            # lint: allow(dict-kv-literal)
            stale = [k, v, b]
            bogus = 1  # lint: allow(no-such-rule)
            return live, stale, bogus
    """)
    p = tmp_path / "sup.py"
    p.write_text(src)
    stale = audit_suppressions([str(p)])
    assert sorted(s.rule for s in stale) == ["dict-kv-literal",
                                             "no-such-rule"]
    assert all(s.path == str(p) for s in stale)
    # CLI surface: exit 1 + one line per stale comment
    assert lint_main([str(p), "--audit-suppressions"]) == 1
    clean = tmp_path / "clean.py"
    clean.write_text(src.splitlines()[1] + "\n    return k\n")
    assert lint_main([str(clean), "--audit-suppressions"]) == 0


def test_jit_factory_pattern_is_reachable(tmp_path):
    """jax.jit(self._make_step()) marks the factory's nested defs as roots."""
    src = textwrap.dedent("""
        import jax
        import jax.numpy as jnp

        class Eng:
            def __init__(self, params):
                self._step = jax.jit(self._make_step())

            def _make_step(self):
                def step(x):
                    y = jnp.sum(x)
                    if y > 0:
                        return y
                    return -y
                return step
    """)
    p = tmp_path / "factory.py"
    p.write_text(src)
    hits = lint_paths([str(p)])
    assert [f.rule for f in hits] == ["tracer-branch"]


def test_unreachable_code_is_not_tracer_checked(tmp_path):
    """The same tracer sin outside any jit-reachable graph stays silent —
    host-side orchestration code may branch on device values after a sync."""
    src = textwrap.dedent("""
        import jax.numpy as jnp

        def host_loop(x):
            y = jnp.sum(x)
            if y > 0:
                return y
            return -y
    """)
    p = tmp_path / "host.py"
    p.write_text(src)
    assert lint_paths([str(p)]) == []


def test_cli_json_and_exit_codes(tmp_path, capsys):
    bad = _write(tmp_path, "dict-kv-literal", "bad",
                 CORPUS["dict-kv-literal"][0])
    assert lint_main([bad, "--json"]) == 1
    report = capsys.readouterr().out
    assert '"dict-kv-literal"' in report and '"count": 1' in report
    good = _write(tmp_path, "dict-kv-literal", "good",
                  CORPUS["dict-kv-literal"][1])
    assert lint_main([good]) == 0


def test_cli_sarif_output(tmp_path, capsys):
    """--sarif: valid SARIF 2.1.0 skeleton, full rule catalogue, one result
    per finding with a physical location; exit codes match --json."""
    import json

    bad = _write(tmp_path, "private-on-wire", "bad",
                 CORPUS["private-on-wire"][0])
    assert lint_main([bad, "--sarif"]) == 1
    log = json.loads(capsys.readouterr().out)
    assert log["version"] == "2.1.0"
    run = log["runs"][0]
    declared = {r["id"] for r in run["tool"]["driver"]["rules"]}
    assert {RULES[n].code for n in RULES} <= declared
    results = run["results"]
    assert results and all(r["level"] == "error" for r in results)
    assert any(r["ruleId"] == "WIR001" for r in results)
    loc = results[0]["locations"][0]["physicalLocation"]
    assert loc["artifactLocation"]["uri"].endswith(".py")
    assert loc["region"]["startLine"] >= 1
    good = _write(tmp_path, "private-on-wire", "good",
                  CORPUS["private-on-wire"][1])
    assert lint_main([good, "--sarif"]) == 0
    clean = json.loads(capsys.readouterr().out)
    assert clean["runs"][0]["results"] == []


def test_self_lint_src_and_benchmarks_clean():
    """The acceptance gate: the repo's own src/, benchmarks/, examples/ and
    experiments/ trees lint clean — including the WIRxxx wire-contract pass
    (CI runs the same command as a job)."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    findings = lint_paths([os.path.join(root, d)
                           for d in ("src", "benchmarks", "examples",
                                     "experiments")
                           if os.path.isdir(os.path.join(root, d))])
    assert findings == [], "\n".join(f.format() for f in findings)


def test_mypy_analysis_and_cache_clean():
    """The CI mypy gate, runnable locally when mypy is installed (hermetic
    environments without it skip — CI pins mypy in requirements.txt)."""
    pytest.importorskip("mypy")
    import subprocess
    import sys

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ, PYTHONPATH=os.path.join(root, "src"))
    res = subprocess.run(
        [sys.executable, "-m", "mypy", "--config-file",
         os.path.join(root, "mypy.ini"),
         os.path.join(root, "src", "repro", "analysis"),
         os.path.join(root, "src", "repro", "models", "cache.py"),
         os.path.join(root, "src", "repro", "launch", "prefix_cache.py"),
         os.path.join(root, "src", "repro", "launch", "engine.py"),
         os.path.join(root, "src", "repro", "core", "transport.py"),
         os.path.join(root, "src", "repro", "core", "protocol.py"),
         os.path.join(root, "src", "repro", "core", "quant.py")],
        capture_output=True, text=True, env=env, cwd=root)
    assert res.returncode == 0, res.stdout + res.stderr


# ----------------------------------------------------------------- TraceGuard


def test_traceguard_shape_perturbation_trips_with_avals():
    @jax.jit
    def watched_fn(x):
        return x * 2

    with pytest.raises(TraceGuardError) as ei:
        with TraceGuard(max_traces={"watched_fn": 1}):
            watched_fn(jnp.zeros((4,)))
            watched_fn(jnp.zeros((4,)))      # cache hit: free
            watched_fn(jnp.zeros((8,)))      # retrace: must trip
    msg = str(ei.value)
    assert "watched_fn" in msg and "budget is 1" in msg
    assert "float32[8]" in msg       # the offending avals...
    assert "float32[4]" in msg       # ...and the previous trace's


def test_traceguard_exact_counts():
    @jax.jit
    def counted_fn(x):
        return x + 1

    with TraceGuard(exact={"counted_fn": 1}) as tg:
        for _ in range(4):
            counted_fn(jnp.ones((3,)))   # one trace, three cache hits
    assert tg.counts["counted_fn"] == 1

    with pytest.raises(TraceGuardError, match="expected exactly 1"):
        with TraceGuard(exact={"never_traced_fn": 1}):
            pass


def test_traceguard_restores_hook_after_exception():
    from jax._src.interpreters import partial_eval as pe

    before = pe.trace_to_jaxpr_dynamic
    with pytest.raises(TraceGuardError):
        with TraceGuard(exact={"missing": 1}):
            pass
    assert pe.trace_to_jaxpr_dynamic is before


# ------------------------------------------------- TraceGuard x engine


def _prompt(key, n):
    return jax.random.randint(key, (1, n), 0, VOCAB)


def test_traceguard_engine_mixed_protocols_decode_once():
    """The acceptance invariant, enforced by the guard rather than the
    engine's hand-maintained stats: decode traces exactly once across
    standalone, C2C and T2T requests over several waves with changing
    prompt lengths and request mixes."""
    from repro.core.fedrefine import FedRefineSystem, Participant

    zoo = tiny_zoo(vocab_size=VOCAB)
    key = jax.random.PRNGKey(50)
    members = [Participant(c.name, c,
                           T.init_params(c, jax.random.fold_in(key, i),
                                         jnp.float32))
               for i, c in enumerate([zoo["receiver"],
                                      zoo["transmitters"][0]])]
    system = FedRefineSystem.build(members)
    rx = members[0].name
    system.make_engine(rx, max_slots=3, max_seq=64, max_prefix=8)

    with TraceGuard(exact={"decode": 1}) as tg:
        for wave, n in enumerate((5, 7)):
            p = _prompt(jax.random.fold_in(key, 10 + wave), n)
            system.submit(rx, p, 3, protocol="standalone")
            system.submit(rx, p, 3, protocol="c2c")
            system.submit(rx, p, 3, protocol="t2t")
            out = system.drain(rx)
            assert all(len(r["tokens"]) == 3 for r in out.values())
    # the guard counted the actual jit lowerings — independent of stats
    assert tg.counts["decode"] == 1


def test_traceguard_suffix_prefill_once_per_bucket():
    """Shared-prefix admissions suffix-prefill through one trace per suffix
    bucket: tails of 4 and 6 tokens share the 8-bucket, a 12-token tail opens
    the 16-bucket — two sprefill traces total, decode still one."""
    cfg = ModelConfig(name="tg-tiny", family="dense", num_layers=2,
                      d_model=32, num_heads=2, num_kv_heads=1, head_dim=16,
                      d_ff=64, vocab_size=VOCAB, tie_embeddings=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    key = jax.random.PRNGKey(51)
    shared = _prompt(key, 16)
    prompts = [shared]
    for i, tail_len in enumerate((4, 6, 12)):
        tail = jax.random.randint(jax.random.fold_in(key, i + 1),
                                  (1, tail_len), 0, VOCAB, jnp.int32)
        prompts.append(jnp.concatenate([shared, tail], axis=1))

    eng = ContinuousBatchingEngine(cfg, params, max_slots=8, max_seq=64,
                                   paged=True, page_size=8, num_pages=32,
                                   prefix_cache=True, prompt_bucket=8)
    with TraceGuard(exact={"decode": 1, "sprefill": 2}) as tg:
        rids = [eng.submit(p, 4) for p in prompts]
        done = {c.rid: c.tokens for c in eng.drain()}
    assert set(done) == set(rids)
    assert eng.stats["radix_hits"] == 3
    assert tg.counts["decode"] == 1 and tg.counts["sprefill"] == 2


def test_traceguard_engine_bench_style_smoke():
    """What the engine_bench smoke runs under: a short mixed run inside a
    decode budget of one — and token outputs are unaffected by the guard."""
    rx_zoo = tiny_zoo(vocab_size=VOCAB)
    rx = rx_zoo["receiver"]
    tx = rx_zoo["transmitters"][0]
    key = jax.random.PRNGKey(52)
    p_rx = T.init_params(rx, key, jnp.float32)
    p_tx = T.init_params(tx, jax.random.fold_in(key, 1), jnp.float32)
    fz = F.init_fuser(tx, rx, jax.random.fold_in(key, 2))
    p = _prompt(key, 6)
    _, txc = T.prefill(tx, p_tx, p, max_seq=6, cache_dtype=jnp.float32)
    fused = F.project_cache(fz, tx, rx, txc.export_stack(tx, length=6))

    def run():
        eng = ContinuousBatchingEngine(rx, p_rx, max_slots=2, max_seq=40,
                                       max_prefix=8)
        ra = eng.submit(p, 5, fused=fused)
        rb = eng.submit(_prompt(jax.random.fold_in(key, 3), 4), 5)
        done = {c.rid: c.tokens for c in eng.drain()}
        return done[ra], done[rb]

    base = run()
    with TraceGuard(max_traces={"decode": 1}) as tg:
        guarded = run()
    assert tg.counts["decode"] == 1
    for a, b in zip(base, guarded):
        assert np.array_equal(a, b)


# ------------------------------------------- PageSanitizer (runtime checker)


def _san_cfg():
    return ModelConfig(name="san-tiny", family="dense", num_layers=2,
                       d_model=32, num_heads=2, num_kv_heads=1, head_dim=16,
                       d_ff=64, vocab_size=VOCAB, tie_embeddings=True)


def test_sanitizer_reports_leak_with_alloc_site():
    from repro.analysis import PageSanitizer

    san = PageSanitizer(8)
    lease = san.lease(fresh=2)
    san.annotate(lease, slot=3, rid=7)
    (line,) = san.leak_report()
    assert "leaked lease of 2 page(s)" in line
    assert "slot=3" in line and "rid=7" in line
    assert "test_analysis.py" in line  # the grant site, not the report site


def test_sanitizer_double_release_names_both_sites():
    from repro.analysis import PageSanitizer, SanitizerError

    san = PageSanitizer(4)
    lease = san.lease(fresh=1)
    san.release(lease)
    with pytest.raises(SanitizerError, match="double release") as ei:
        san.release(lease)
    msg = str(ei.value)
    assert "first released at" in msg and "test_analysis.py" in msg
    assert san.leak_report() == []


def test_sanitizer_raw_release_of_leased_page_is_evict_while_shared():
    from repro.analysis import PageSanitizer, SanitizerError

    san = PageSanitizer(4)
    lease = san.lease(fresh=2)
    with pytest.raises(SanitizerError, match="evict-while-shared") as ei:
        san.release([lease.ids()[0]])
    assert "test_analysis.py" in str(ei.value)  # names the holder's grant
    # a pinned page releases its pin without touching the lease's hold
    san.retain(lease.ids()[0])
    san.release([lease.ids()[0]])
    assert san.refcount(lease.ids()[0]) == 1
    san.release(lease)
    assert san.num_free == 4


def test_sanitizer_shared_write_requires_cow():
    from repro.analysis import PageSanitizer, SanitizerError

    san = PageSanitizer(4)
    owner = san.lease(fresh=1)
    sharer = san.lease(shared=owner.ids())
    with pytest.raises(SanitizerError, match="without a cow"):
        san.note_write(sharer.ids(), sharer)
    san.cow(sharer, 0)
    san.note_write(sharer.ids(), sharer)  # owned after the fault: fine
    san.release(owner)
    san.release(sharer)
    assert san.leak_report() == []


def _san_engine_run(cfg, params, sanitize, **kw):
    eng = ContinuousBatchingEngine(cfg, params, max_slots=4, max_seq=64,
                                   paged=True, page_size=8, num_pages=24,
                                   sanitize=sanitize, **kw)
    key = jax.random.PRNGKey(60)
    base = _prompt(key, 20)
    shared_tail = jnp.concatenate(
        [base[:, :17], _prompt(jax.random.fold_in(key, 1), 6)], axis=1)
    rids = [eng.submit(base, 6),            # registers its pages
            eng.submit(shared_tail, 5),     # radix hit + CoW partial page
            eng.submit(_prompt(jax.random.fold_in(key, 2), 11), 7),
            eng.submit(base[:, :9], 1)]     # answered at prefill, no slot
    done = {c.rid: c.tokens for c in eng.drain()}
    return [done[r] for r in rids], eng


def test_sanitized_engine_byte_identical_and_clean():
    """Clean shared-prefix/CoW/mixed-length runs finish with a zero-finding
    sanitizer report and tokens byte-identical to sanitize=False."""
    cfg = _san_cfg()
    params = T.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    base_toks, base_eng = _san_engine_run(cfg, params, sanitize=False)
    san_toks, san_eng = _san_engine_run(cfg, params, sanitize=True)
    assert san_eng.stats["shared_admits"] >= 1
    assert san_eng.stats["cow_copies"] >= 1
    assert san_eng.sanitizer_report() == []
    for a, b in zip(base_toks, san_toks):
        assert np.array_equal(a, b)
    assert base_eng.stats["decode_steps"] == san_eng.stats["decode_steps"]


def test_sanitized_engine_fixture_runs_clean(sanitized_engine):
    cfg = _san_cfg()
    params = T.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    eng = sanitized_engine(cfg, params, max_slots=2, max_seq=32,
                           page_size=8, num_pages=8)
    eng.submit(_prompt(jax.random.PRNGKey(61), 7), 4)
    assert len(eng.drain()) == 1
    assert eng.sanitizer_report() == []


def test_sanitized_engine_catches_injected_leak():
    """An eviction that drops the lease without releasing it surfaces at
    drain() as a leak naming the admitting call site."""
    from repro.analysis import SanitizerError

    cfg = _san_cfg()
    params = T.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    eng = ContinuousBatchingEngine(cfg, params, max_slots=2, max_seq=32,
                                   paged=True, page_size=8, num_pages=8,
                                   sanitize=True)
    orig_evict = eng._evict

    def leaky_evict(slot):
        eng._leases.pop(slot, None)  # injected: lease dropped, never released
        orig_evict(slot)

    eng._evict = leaky_evict
    eng.submit(_prompt(jax.random.PRNGKey(62), 7), 3)
    with pytest.raises(SanitizerError, match="leaked lease") as ei:
        eng.drain()
    assert "engine.py" in str(ei.value)  # grant site: _admit's lease() call


def test_sanitized_engine_catches_premature_release():
    """Releasing a live slot's lease out from under the engine trips the
    very next step's cross-check (and the engine's own eviction would be the
    double release)."""
    from repro.analysis import SanitizerError

    cfg = _san_cfg()
    params = T.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    eng = ContinuousBatchingEngine(cfg, params, max_slots=2, max_seq=32,
                                   paged=True, page_size=8, num_pages=8,
                                   sanitize=True)
    eng.submit(_prompt(jax.random.PRNGKey(63), 7), 4)
    eng.step()
    slot = int(np.nonzero(eng._active)[0][0])
    eng._allocator.release(eng._leases[slot])  # injected premature release
    with pytest.raises(SanitizerError):
        eng.drain()


def test_sanitized_engine_catches_evict_while_shared():
    """A raw page-id release of a page a live lease still maps (the bug class
    refcounting exists to prevent) is refused with the holder named."""
    from repro.analysis import SanitizerError

    cfg = _san_cfg()
    params = T.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    eng = ContinuousBatchingEngine(cfg, params, max_slots=2, max_seq=32,
                                   paged=True, page_size=8, num_pages=8,
                                   sanitize=True, prefix_cache=False)
    eng.submit(_prompt(jax.random.PRNGKey(64), 7), 4)
    eng.step()
    slot = int(np.nonzero(eng._active)[0][0])
    page = int(eng._leases[slot].page_ids[0])
    with pytest.raises(SanitizerError, match="evict-while-shared") as ei:
        eng._allocator.release([page])
    msg = str(ei.value)
    assert f"slot={slot}" in msg and "engine.py" in msg


def test_sanitized_engine_catches_missing_cow(monkeypatch):
    """If the CoW fault is skipped (the shared partial page handed to the
    sharer as-is), the suffix prefill's write into it is caught before it
    lands, naming the page's other holder."""
    from repro.analysis import SanitizerError

    cfg = _san_cfg()
    params = T.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    eng = ContinuousBatchingEngine(cfg, params, max_slots=4, max_seq=64,
                                   paged=True, page_size=8, num_pages=24,
                                   sanitize=True)
    key = jax.random.PRNGKey(65)
    base = _prompt(key, 12)
    eng.submit(base, 4)
    eng.drain()  # registers base's pages (incl. the partial second page)

    def broken_cow(lease, index):  # injected: no copy, share stays shared
        src = int(lease.page_ids[index])
        return src, src

    monkeypatch.setattr(eng._allocator, "cow", broken_cow)
    tail = jnp.concatenate([base[:, :10],
                            _prompt(jax.random.fold_in(key, 1), 5)], axis=1)
    eng.submit(tail, 4)  # radix hit with a partial-page extension
    with pytest.raises(SanitizerError, match="without a cow") as ei:
        eng.drain()
    assert "cow page copy" in str(ei.value)


def test_pool_exhaustion_reports_holders():
    """Satellite: the allocator's exhaustion error names who holds the pool —
    slots, index pins, and (sanitized) the grant sites."""
    cfg = _san_cfg()
    params = T.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    eng = ContinuousBatchingEngine(cfg, params, max_slots=2, max_seq=32,
                                   paged=True, page_size=8, num_pages=4,
                                   sanitize=True)
    eng.submit(_prompt(jax.random.PRNGKey(66), 9), 4)
    eng.step()
    with pytest.raises(RuntimeError, match="exhausted") as ei:
        eng._allocator.alloc(10)
    msg = str(ei.value)
    assert "current holders" in msg
    assert "slot 0" in msg and "grant sites" in msg
