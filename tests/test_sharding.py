"""Sharding rules + launch-layer behaviour (host-scale mesh + spec validation).

The full 512-device validation is the dry-run (repro.launch.dryrun, separate
process because it forces the device count); here we verify the SPEC TREES are
structurally valid for the production mesh shape and that the sharded train
step runs on a 1×1 host mesh.
"""
import subprocess
import sys
import os

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import ARCH_IDS, get_config, get_smoke_config
from repro.launch import sharding as SH
from repro.models import transformer as T
from repro.optim.adamw import init_opt_state


class FakeMesh:
    """Axis-name/size stand-in so spec construction can target 16×16 without
    actually building 256 devices inside the test process."""

    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)


PROD = FakeMesh({"data": 16, "model": 16})
PROD2 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _spec_valid(spec, shape, mesh) -> bool:
    if spec is None:
        return True
    dims = list(spec)
    assert len(dims) <= len(shape), (spec, shape)
    used = []
    for d, n in zip(dims, shape):
        if d is None:
            continue
        names = d if isinstance(d, tuple) else (d,)
        size = 1
        for nm in names:
            assert nm in mesh.shape, f"unknown axis {nm}"
            assert nm not in used, f"axis {nm} used twice in {spec}"
            used.append(nm)
            size *= mesh.shape[nm]
        assert n % size == 0, f"dim {n} not divisible by {size} in {spec} {shape}"
    return True


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("mesh", [PROD, PROD2], ids=["1pod", "2pod"])
@pytest.mark.parametrize("fsdp", [False, True], ids=["tp", "fsdp"])
def test_param_specs_divisible(arch, mesh, fsdp):
    cfg = get_config(arch)
    p_struct = jax.eval_shape(
        lambda k: T.init_params(cfg, k, jnp.bfloat16),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    specs = SH.param_pspecs(cfg, p_struct, mesh, fsdp=fsdp)
    jax.tree.map(
        lambda s, l: _spec_valid(s, l.shape, mesh), specs, p_struct,
        is_leaf=lambda x: isinstance(x, P))


@pytest.mark.parametrize("arch", ["qwen2_5_32b", "granite_20b", "mamba2_130m",
                                  "recurrentgemma_9b"])
def test_cache_specs_divisible(arch):
    from repro.models.cache import KVCache
    cfg = get_config(arch)
    cache = jax.eval_shape(
        lambda: KVCache.init(cfg, 128, 32_768, jnp.bfloat16))
    specs = SH.cache_pspecs(cfg, cache, PROD, 128)
    jax.tree.map(
        lambda s, l: _spec_valid(s, l.shape, PROD), specs, cache,
        is_leaf=lambda x: isinstance(x, P))


def test_opt_specs_add_zero1_data_axis():
    cfg = get_config("qwen2_5_32b")
    p_struct = jax.eval_shape(
        lambda k: T.init_params(cfg, k, jnp.bfloat16),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    p_specs = SH.param_pspecs(cfg, p_struct, PROD)
    opt_struct = jax.eval_shape(init_opt_state, p_struct)
    opt_specs = SH.opt_pspecs(p_specs, opt_struct, PROD)
    flat = [s for s in jax.tree.leaves(
        opt_specs["master"], is_leaf=lambda x: isinstance(x, P))
        if isinstance(s, P)]
    n_data = sum(1 for s in flat
                 if any("data" in (d if isinstance(d, tuple) else (d,))
                        for d in s if d))
    assert n_data / len(flat) > 0.9  # nearly every master leaf is ZeRO-sharded


@pytest.mark.slow
def test_train_step_runs_under_host_mesh(key):
    """The exact sharded train path executes on a 1×1 mesh (CPU)."""
    from repro.launch.mesh import make_host_mesh
    from repro.launch.train import make_train_step
    from repro.optim.adamw import AdamWConfig
    mesh = make_host_mesh()
    cfg = get_smoke_config("qwen3-1.7b")
    params = T.init_params(cfg, key, jnp.float32)
    opt_state = init_opt_state(params)
    batch = {
        "tokens": jax.random.randint(key, (2, 16), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (2, 16), 0, cfg.vocab_size),
    }
    p_specs = SH.param_pspecs(cfg, params, mesh)
    step = jax.jit(
        make_train_step(cfg, AdamWConfig(lr=1e-3), remat=True),
        in_shardings=(SH.to_sharding(mesh, p_specs), None, None))
    with mesh:
        _, _, loss = step(params, opt_state, batch)
    assert bool(jnp.isfinite(loss))


@pytest.mark.slow
def test_dryrun_cli_one_pair(tmp_path):
    """The dry-run CLI end-to-end on the cheapest pair (subprocess because it
    forces 512 host devices)."""
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "mamba2-130m", "--shape", "long_500k"],
        capture_output=True, text=True, timeout=560, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert "OK" in out.stdout, out.stdout + out.stderr[-2000:]
