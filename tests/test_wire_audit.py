"""WireAuditor — runtime twin of the WIRxxx static pass (ISSUE 10).

Unit half: schema verification on raw channels (media, dtypes, declared
stages, byte accounting, QoS ceilings, call-site provenance). Engine half:
``FedRefineSystem.build(..., audit_wire=True)`` — a clean mixed-protocol
run stays byte-identical to the unaudited system with an empty audit
report, and each of the injected leaks (raw token ids bypassing the codec,
dense KV where the protocol declares int8, bytes_on_wire drift past
tolerance) is caught with the producing call site named.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import WireAuditError, WireAuditor
from repro.analysis.wire_audit import derive_schemas
from repro.configs.case_study import tiny_zoo
from repro.core import commload, quant
from repro.core import transport as TR
from repro.core.fedrefine import FedRefineSystem, Participant
from repro.core.protocol import WIRE_SCHEMAS, LinkModel, QoS
from repro.models import transformer as T
from repro.models.cache import KVStack

KEY = jax.random.PRNGKey(23)


def _stack(n=2, B=1, H=2, S=6, hd=8, dtype=jnp.float32) -> KVStack:
    k1, k2 = jax.random.split(KEY)
    return KVStack(k=jax.random.normal(k1, (n, B, H, S, hd), dtype),
                   v=jax.random.normal(k2, (n, B, H, S, hd), dtype))


# ------------------------------------------------------------------ unit


def test_identity_c2c_clean_and_recorded():
    aud = WireAuditor()
    stack = _stack()
    aud.expect(protocol="c2c")
    out, nbytes = aud.transmit(TR.stack_message(stack))
    assert np.array_equal(np.asarray(out.stack.k), np.asarray(stack.k))
    assert nbytes == commload.measured_bytes(stack)
    assert aud.report() == []
    [rec] = aud.records
    assert rec.protocol == "c2c" and rec.media == ("stack",)
    assert rec.measured_bytes == rec.estimated_bytes == nbytes
    assert ".py:" in rec.site  # call-site provenance survives formatting
    assert "test_wire_audit" in rec.describe()


def test_quant_wire_derives_quant_stage_and_int8_estimate():
    aud = WireAuditor(TR.QuantChannel())
    assert aud.schemas["c2c"].stages == ("quant",)
    stack = _stack(n=3, B=2, S=10)
    aud.expect(protocol="c2c")
    _, nbytes = aud.transmit(TR.stack_message(stack))
    assert nbytes == quant.quantized_bytes(stack)
    assert aud.report() == []


def test_empty_stack_through_quant_wire_is_clean():
    empty = KVStack(k=jnp.zeros((2, 1, 2, 0, 8), jnp.float32),
                    v=jnp.zeros((2, 1, 2, 0, 8), jnp.float32))
    aud = WireAuditor(TR.QuantChannel())
    aud.expect(protocol="c2c")
    _, nbytes = aud.transmit(TR.stack_message(empty))
    assert nbytes == quant.quantized_bytes(empty)


def test_t2t_tokens_clean_and_pinned():
    aud = WireAuditor()
    tokens = jax.random.randint(KEY, (2, 9), 0, 64)
    aud.expect(protocol="t2t")
    _, nbytes = aud.transmit(TR.token_message(tokens))
    assert nbytes == tokens.size * commload.t2t_bytes_per_token()


def test_no_expect_context_fails():
    aud = WireAuditor()
    with pytest.raises(WireAuditError, match="no expect"):
        aud.transmit(TR.stack_message(_stack()))
    assert len(aud.report()) == 1


def test_unknown_protocol_in_expect_fails():
    with pytest.raises(WireAuditError, match="carrier-pigeon"):
        WireAuditor().expect(protocol="carrier-pigeon")


def test_tokens_on_c2c_wire_is_media_violation():
    aud = WireAuditor()
    aud.expect(protocol="c2c")
    with pytest.raises(WireAuditError, match="raw token ids"):
        aud.transmit(TR.token_message(jnp.arange(5)))


def test_stack_on_t2t_wire_is_media_violation():
    aud = WireAuditor()
    aud.expect(protocol="t2t")
    with pytest.raises(WireAuditError, match="KV stack"):
        aud.transmit(TR.stack_message(_stack()))


def test_int64_payload_rejected():
    aud = WireAuditor()
    aud.expect(protocol="t2t")
    with pytest.raises(WireAuditError, match="int64"):
        aud.encode(TR.Message(tokens=np.arange(4, dtype=np.int64)))


def test_schema_declared_quant_stage_rejects_dense_stack():
    """Identity wire under a schema that declares the quant stage: the
    dense stack itself (not just its byte count) is the violation."""
    aud = WireAuditor(TR.IdentityChannel(),
                      schemas=derive_schemas(TR.QuantChannel()))
    aud.expect(protocol="c2c")
    with pytest.raises(WireAuditError, match="quant"):
        aud.transmit(TR.stack_message(_stack()))


def test_byte_drift_past_tolerance_fails():
    class JunkChannel(TR.Channel):
        def encode(self, msg):
            pad = jnp.zeros((64,), jnp.float32)
            return msg.replace(payload={**msg.payload, "junk": pad})

    aud = WireAuditor(JunkChannel())
    aud.expect(protocol="c2c")
    with pytest.raises(WireAuditError, match="drift"):
        aud.transmit(TR.stack_message(_stack()))


def test_qos_budget_ceiling_enforced():
    stack = _stack(S=16)
    aud = WireAuditor()
    aud.set_budget(commload.measured_bytes(stack) - 1)
    aud.expect(protocol="c2c")
    with pytest.raises(WireAuditError, match="QoS budget"):
        aud.transmit(TR.stack_message(stack))
    aud.set_budget(None)
    aud.expect(protocol="c2c")
    aud.transmit(TR.stack_message(stack))  # cleared budget: clean again


def test_schema_max_message_bytes_enforced():
    small = dataclasses.replace(WIRE_SCHEMAS["c2c"], max_message_bytes=8)
    aud = WireAuditor(schemas={"c2c": small})
    aud.expect(protocol="c2c")
    with pytest.raises(WireAuditError, match="schema ceiling"):
        aud.transmit(TR.stack_message(_stack()))


# ---------------------------------------------------------------- engine


@pytest.fixture(scope="module")
def zoo():
    z = tiny_zoo()
    members = []
    for i, cfg in enumerate([z["receiver"], *z["transmitters"]]):
        params = T.init_params(cfg, jax.random.fold_in(KEY, i), jnp.float32)
        members.append(Participant(cfg.name, cfg, params))
    return members


def _run_mixed(system, rx, prompt):
    system.submit(rx, prompt, 4, protocol="c2c", key=jax.random.PRNGKey(7))
    system.submit(rx, prompt, 4, protocol="t2t", key=jax.random.PRNGKey(7))
    system.submit(rx, prompt, 4, protocol="standalone")
    return system.drain(rx)


def test_audited_mixed_run_byte_identical_and_clean(zoo):
    """audit_wire=True is observability, not behaviour: tokens and wire
    bytes of a mixed C2C/T2T/standalone run match the unaudited system,
    the audit report is empty, and every C2C transmission got a record."""
    rx = zoo[0].name
    prompt = jnp.array([[1, 2, 3, 4]], jnp.int32)
    plain = _run_mixed(FedRefineSystem.build(zoo), rx, prompt)
    audited_sys = FedRefineSystem.build(zoo, audit_wire=True)
    audited = _run_mixed(audited_sys, rx, prompt)
    assert sorted(plain) == sorted(audited)
    for rid in plain:
        assert np.array_equal(np.asarray(plain[rid]["tokens"]),
                              np.asarray(audited[rid]["tokens"]))
        assert plain[rid].get("wire_bytes") == audited[rid].get("wire_bytes")
    aud = audited_sys.wire
    assert aud.report() == []
    assert [r.protocol for r in aud.records] == ["c2c"]
    assert "transmit_stacks" in aud.records[0].site


def test_audited_quant_wire_run_clean(zoo):
    """Derived schemas make the int8 wire audit-clean with exact int8
    byte accounting — no explicit wire_schemas needed."""
    rx = zoo[0].name
    sys_ = FedRefineSystem.build(zoo, wire=TR.QuantChannel(),
                                 audit_wire=True)
    out = _run_mixed(sys_, rx, jnp.array([[1, 2, 3, 4]], jnp.int32))
    assert sys_.wire.report() == []
    wb = [v["wire_bytes"] for v in out.values() if "transmitters" in v
          and v["protocol"] == "c2c"]
    assert wb == [sys_.wire.records[0].measured_bytes]


def test_engine_catches_raw_tokens_bypassing_codec(zoo, monkeypatch):
    """Injected leak 1: a compromised stack_message smuggles the raw prompt
    ids alongside the KV stack — the c2c schema's media set catches it."""
    rx = zoo[0].name
    prompt = jnp.array([[1, 2, 3, 4]], jnp.int32)
    real = TR.stack_message
    monkeypatch.setattr(
        TR, "stack_message",
        lambda stack: real(stack).replace(tokens=prompt))
    sys_ = FedRefineSystem.build(zoo, audit_wire=True)
    with pytest.raises(WireAuditError, match="raw token ids"):
        sys_.submit(rx, prompt, 4, protocol="c2c")
    assert len(sys_.wire.report()) == 1


def test_engine_catches_dense_kv_where_protocol_declares_int8(zoo):
    """Injected leak 2: the protocol contract says int8 C2C but the system
    was (mis)built with an identity wire — every dense stack is flagged."""
    rx = zoo[0].name
    sys_ = FedRefineSystem.build(
        zoo, audit_wire=True,
        wire_schemas=derive_schemas(TR.QuantChannel()))
    with pytest.raises(WireAuditError, match="quant"):
        sys_.submit(rx, jnp.array([[1, 2, 3, 4]], jnp.int32), 4,
                    protocol="c2c")


def test_engine_catches_bytes_on_wire_drift(zoo):
    """Injected leak 3: a wire whose encode inflates the message (stray
    debug payload) drifts measured bytes past the schema tolerance."""
    class PaddingChannel(TR.Channel):
        def encode(self, msg):
            pad = jnp.zeros((128,), jnp.float32)
            return msg.replace(payload={**msg.payload, "debug": pad})

    rx = zoo[0].name
    sys_ = FedRefineSystem.build(zoo, wire=PaddingChannel(),
                                 audit_wire=True)
    with pytest.raises(WireAuditError, match="drift"):
        sys_.submit(rx, jnp.array([[1, 2, 3, 4]], jnp.int32), 4,
                    protocol="c2c")


def test_serve_opportunistic_threads_qos_budget(zoo):
    """serve_opportunistic wires the link x latency byte budget into the
    auditor; a generous budget stays clean end to end."""
    rx = zoo[0].name
    sys_ = FedRefineSystem.build(zoo, audit_wire=True)
    out = sys_.serve_opportunistic(
        rx, jnp.array([[1, 2, 3, 4]], jnp.int32), 4,
        link=LinkModel(bandwidth_bps=1e9, rtt_s=0.001),
        qos=QoS(max_latency_s=60.0, min_quality="standalone"))
    assert sys_.wire.report() == []
    if out["protocol"] == "c2c":
        assert sys_.wire._budget == int(1e9 * 60.0)
