"""PageAllocator refcounting, radix prefix index, and the engine's
copy-on-write page-sharing admission path (launch/prefix_cache.py +
models/cache.PageAllocator).

Sharing invariants pinned here (the ISSUE's satellite list):
- refcounts never go negative (double-free / free-page sharing raise),
- CoW divergence decodes byte-identical to an unshared run,
- radix lookup returns the longest matching prefix (brute-force oracle),
- evicting one sharer never frees pages another slot still maps,
- a fused C2C prefix is inserted once per digest and reused by every
  subsequent request fusing the same digest.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.configs.case_study import tiny_zoo
from repro.core import fuser as F
from repro.launch.engine import ContinuousBatchingEngine
from repro.launch.prefix_cache import RadixPrefixIndex
from repro.models import transformer as T
from repro.models.cache import (FusedPrefix, KVCache, KVStack, PageAllocator,
                                PageLease, SlotTable)

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # hermetic fallback (see repro/testing/propcheck.py)
    from repro.testing.propcheck import given, settings, strategies as st

VOCAB = 64


@pytest.fixture(scope="module")
def cfg():
    return ModelConfig(name="pfx-tiny", family="dense", num_layers=2,
                       d_model=32, num_heads=2, num_kv_heads=1, head_dim=16,
                       d_ff=64, vocab_size=VOCAB, tie_embeddings=True)


@pytest.fixture(scope="module")
def params(cfg):
    return T.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)


def _prompt(key, n):
    return jax.random.randint(key, (1, n), 0, VOCAB)


def _solo(cfg, params, prompt, steps, max_seq, fused=None):
    ek = (FusedPrefix.ensure(fused).to_extra_kv(cfg)
          if fused is not None else None)
    logits, cache = T.prefill(cfg, params, prompt, max_seq=max_seq,
                              cache_dtype=jnp.float32, extra_kv=ek)
    tok = jnp.argmax(logits[:, prompt.shape[1] - 1], -1)
    out = [tok]
    for _ in range(steps - 1):
        lg, cache = T.decode_step(cfg, params, cache, tok, extra_kv=ek)
        tok = jnp.argmax(lg, -1)
        out.append(tok)
    return np.asarray(jnp.stack(out, 1)[0])


# ------------------------------------------------------------ PageAllocator


def test_allocator_alloc_release_roundtrip():
    a = PageAllocator(8)
    ids = a.alloc(3)
    assert len(set(ids)) == 3 and a.num_free == 5
    assert all(a.refcount(p) == 1 for p in ids)
    a.release(ids)
    assert a.num_free == 8
    a.assert_consistent()


def test_allocator_share_keeps_pages_alive():
    """Evicting one sharer never frees a page the other still holds."""
    a = PageAllocator(4)
    lease1 = a.lease(fresh=2)
    lease2 = a.lease(shared=lease1.ids(), fresh=1)
    assert a.refcount(lease1.ids()[0]) == 2
    a.release(lease1)  # sharer 1 evicted
    assert a.num_free == 1  # shared pages survive, only nothing was exclusive
    assert all(a.refcount(p) == 1 for p in lease2.ids())
    a.release(lease2)
    assert a.num_free == 4
    a.assert_consistent()


def test_allocator_refcount_underflow_raises():
    a = PageAllocator(2)
    ids = a.alloc(1)
    a.release(ids)
    with pytest.raises(ValueError, match="underflow"):
        a.release(ids)  # double free
    with pytest.raises(ValueError, match="free page"):
        a.share(ids)  # sharing a freed page
    a.assert_consistent()


def test_allocator_exhaustion_raises():
    a = PageAllocator(2)
    assert a.can_alloc(2) and not a.can_alloc(3)
    with pytest.raises(RuntimeError, match="exhausted"):
        a.alloc(3)
    a.alloc(2)
    with pytest.raises(RuntimeError, match="exhausted"):
        a.lease(fresh=1)


def test_allocator_cow_swaps_shared_for_owned():
    a = PageAllocator(4)
    owner = a.lease(fresh=1)
    sharer = a.lease(shared=owner.ids())
    assert not sharer.owned[0]
    with pytest.raises(ValueError, match="already owned"):
        a.cow(owner, 0)
    src, dst = a.cow(sharer, 0)
    assert src == owner.ids()[0] and dst != src
    assert sharer.owned[0] and sharer.ids() == [dst]
    assert a.refcount(src) == 1 and a.refcount(dst) == 1
    a.release(owner)
    a.release(sharer)
    assert a.num_free == 4
    a.assert_consistent()


@settings(max_examples=40)
@given(st.lists(st.tuples(st.integers(0, 2), st.integers(0, 3)),
                min_size=0, max_size=40))
def test_allocator_refcounts_never_negative(ops):
    """Random op soup (alloc/share/release/cow) through the public API keeps
    the allocator consistent: counts never negative, free list exact."""
    a = PageAllocator(6)
    held = []  # leases we still hold
    for op, arg in ops:
        if op == 0 and a.can_alloc(1):  # fresh lease
            held.append(a.lease(fresh=1))
        elif op == 1 and held:  # share an existing lease's pages
            src = held[arg % len(held)]
            held.append(a.lease(shared=src.ids()))
        elif op == 2 and held:  # release one
            a.release(held.pop(arg % len(held)))
        elif op == 3 and held and a.can_alloc(1):  # CoW any shared page
            lease = held[arg % len(held)]
            shared_idx = [i for i in range(lease.num_pages)
                          if not lease.owned[i]]
            if shared_idx:
                a.cow(lease, shared_idx[0])
        a.assert_consistent()
        assert a.pages_in_use + a.num_free == 6
    for lease in held:
        a.release(lease)
    assert a.num_free == 6
    a.assert_consistent()


def test_page_lease_row_padding():
    lease = PageLease(page_ids=np.asarray([5, 2], np.int32),
                      owned=np.asarray([False, True]))
    row = lease.page_row(4, invalid=9)
    assert row.tolist() == [5, 2, 9, 9]
    with pytest.raises(ValueError, match="exceeds"):
        lease.page_row(1, invalid=9)


# ------------------------------------------------------- RadixPrefixIndex


def _register_seq(idx, alloc, tokens, digest=None):
    pg = idx.page_size
    n = -(-len(tokens) // pg)  # ceil: full pages + the partial tail page
    ids = alloc.alloc(n)
    idx.register(digest, np.asarray(tokens), ids, alloc)
    return ids


@settings(max_examples=60)
@given(st.lists(st.lists(st.integers(0, 1), min_size=1, max_size=12),
                min_size=0, max_size=5),
       st.lists(st.integers(0, 1), min_size=1, max_size=12))
def test_radix_longest_match_oracle(seqs, query):
    """lookup() returns exactly min(max lcp over registered sequences,
    len(query) - 1) matched tokens — the longest-matching-prefix contract."""
    alloc = PageAllocator(256)
    idx = RadixPrefixIndex(3, max_partials_per_node=32)
    for s in seqs:
        _register_seq(idx, alloc, s)
    m = idx.lookup(None, np.asarray(query))
    expect = min(max((_lcp(s, query) for s in seqs), default=0),
                 len(query) - 1)
    got = 0 if m is None else m.matched
    assert got == expect, (seqs, query, got, expect)
    if m is not None:
        # full pages + partial arithmetic is internally consistent
        assert m.matched == len(m.page_ids) * 3 + m.partial_tokens
        assert m.partial_tokens < 3
        # the slot's leases release fine and the index pins stay consistent
        alloc.assert_consistent()


def _lcp(a, b):
    n = min(len(a), len(b))
    for i in range(n):
        if a[i] != b[i]:
            return i
    return n


def test_radix_keyed_by_fused_digest():
    """Pages registered under one fused digest are invisible to lookups under
    another (prompt KV depends on the attended fused prefix)."""
    alloc = PageAllocator(16)
    idx = RadixPrefixIndex(2)
    toks = [1, 2, 3, 4, 5]
    _register_seq(idx, alloc, toks, digest="aaa")
    assert idx.lookup("aaa", np.asarray(toks)).matched == 4  # capped at S-1
    assert idx.lookup("bbb", np.asarray(toks)) is None
    assert idx.lookup(None, np.asarray(toks)) is None


def test_radix_evict_frees_only_unshared():
    """Index eviction releases pins LRU-first, but a page a live lease still
    maps survives (refcount protects it)."""
    alloc = PageAllocator(16)
    idx = RadixPrefixIndex(2)
    ids_a = _register_seq(idx, alloc, [1, 2, 3, 4])  # 2 full nodes
    alloc.release(ids_a)  # registering slot evicted; index pins remain
    lease = alloc.lease(shared=[ids_a[0]])  # a new slot maps page 0
    freed = idx.evict(alloc, want_pages=2)
    # page ids_a[1] freed; ids_a[0] survives its pin release via the lease
    assert freed == 1
    assert alloc.refcount(ids_a[0]) == 1
    assert alloc.refcount(ids_a[1]) == 0
    alloc.release(lease)
    assert alloc.num_free == 16
    alloc.assert_consistent()


def test_radix_clear_releases_all_pins():
    alloc = PageAllocator(16)
    idx = RadixPrefixIndex(2)
    ids1 = _register_seq(idx, alloc, [1, 2, 3, 4, 5], digest="d")
    ids2 = _register_seq(idx, alloc, [1, 2, 9], digest="d")
    alloc.release(ids1)  # both registering slots evicted; pins remain
    alloc.release(ids2)
    # 2 full nodes + 2 partials survive; ids2[0] (duplicate chunk) was freed
    assert alloc.pages_in_use == idx.num_pages == 4
    idx.clear(alloc)
    assert alloc.num_free == 16
    alloc.assert_consistent()


# ------------------------------------------- engine sharing + CoW identity


def _shared_prompts(key, n, shared_len, tail_len):
    shared = jax.random.randint(jax.random.fold_in(key, 99),
                                (1, shared_len), 0, VOCAB, jnp.int32)
    out = []
    for i in range(n):
        tail = jax.random.randint(jax.random.fold_in(key, i),
                                  (1, tail_len), 0, VOCAB, jnp.int32)
        out.append(jnp.concatenate([shared, tail], axis=1))
    return out


def test_engine_shared_prefix_byte_identical(cfg, params):
    """Shared-system-prompt workload: the prefix cache shares pages, CoW
    copies the partially-matched page, prefills only suffixes — and decodes
    byte-identically to the unshared engine."""
    prompts = _shared_prompts(jax.random.PRNGKey(40), 6, 20, 6)  # S=26

    def run(pc):
        eng = ContinuousBatchingEngine(cfg, params, max_slots=8, max_seq=64,
                                       paged=True, page_size=8, num_pages=32,
                                       prefix_cache=pc)
        rids = [eng.submit(p, 6) for p in prompts]
        done = {c.rid: c.tokens for c in eng.drain()}
        return [done[r] for r in rids], eng

    out_on, eng = run(True)
    out_off, _ = run(False)
    for a, b in zip(out_on, out_off):
        assert np.array_equal(a, b)
    st = eng.stats
    assert st["radix_hits"] == 5 and st["shared_admits"] == 5
    assert st["cow_copies"] >= 1  # 20 % 8 != 0: partial page CoW-copied
    assert st["radix_matched_tokens"] == 5 * 20
    assert st["decode_traces"] == 1 and st["suffix_prefill_traces"] == 1
    # engine holds no raw page-id lists: the allocator is the only authority
    assert not hasattr(eng, "_free_pages") and not hasattr(eng, "_slot_pages")
    eng._allocator.assert_consistent()
    assert not eng._leases  # all released on completion
    assert eng._allocator.num_free + eng._radix.num_pages \
        == eng._table.num_pages


def test_engine_shared_prefix_fewer_prefill_tokens(cfg, params):
    """The capacity win: shared admissions prefill only suffixes."""
    prompts = _shared_prompts(jax.random.PRNGKey(41), 5, 24, 8)  # S=32
    # force tails to diverge at their first token so every match is exactly
    # the 24 shared tokens (random tails can chance-share a first token)
    prompts = [p.at[0, 24].set(i) for i, p in enumerate(prompts)]

    def tokens_prefilled(pc):
        eng = ContinuousBatchingEngine(cfg, params, max_slots=8, max_seq=64,
                                       paged=True, page_size=8,
                                       prefix_cache=pc)
        for p in prompts:
            eng.submit(p, 4)
        eng.drain()
        return eng.stats["prefill_tokens"]

    on, off = tokens_prefilled(True), tokens_prefilled(False)
    assert off == 5 * 32
    assert on == 32 + 4 * (32 - 24)  # one full prefill + 4 suffixes
    assert on * 2 < off


def test_engine_sharer_eviction_leaves_other_decoding(cfg, params):
    """A short sharer finishing (and releasing its lease) must not disturb a
    long sharer still decoding from the same physical prefix pages."""
    pa, pb = _shared_prompts(jax.random.PRNGKey(42), 2, 16, 4)  # S=20
    eng = ContinuousBatchingEngine(cfg, params, max_slots=4, max_seq=64,
                                   paged=True, page_size=8, num_pages=16)
    ra = eng.submit(pa, 3)   # finishes early, releases shared pages
    rb = eng.submit(pb, 12)  # keeps decoding long after
    done = {c.rid: c.tokens for c in eng.drain()}
    assert eng.stats["shared_admits"] == 1
    assert np.array_equal(done[ra], _solo(cfg, params, pa, 3, 64))
    assert np.array_equal(done[rb], _solo(cfg, params, pb, 12, 64))
    eng._allocator.assert_consistent()


def test_engine_prefix_survives_sharer_completion(cfg, params):
    """Index pins outlive the registering request: a request submitted after
    the original owner completed still shares its pages."""
    pa, pb = _shared_prompts(jax.random.PRNGKey(43), 2, 16, 4)
    eng = ContinuousBatchingEngine(cfg, params, max_slots=4, max_seq=64,
                                   paged=True, page_size=8, num_pages=16)
    ra = eng.submit(pa, 3)
    done_a = {c.rid: c.tokens for c in eng.drain()}
    rb = eng.submit(pb, 5)  # owner long gone; pages live via index pins
    done_b = {c.rid: c.tokens for c in eng.drain()}
    assert eng.stats["radix_hits"] == 1
    assert np.array_equal(done_a[ra], _solo(cfg, params, pa, 3, 64))
    assert np.array_equal(done_b[rb], _solo(cfg, params, pb, 5, 64))


def test_engine_pool_pressure_evicts_index_not_slots(cfg, params):
    """When index pins would starve a fresh admission, LRU prefix entries are
    evicted to free pages; the engine never deadlocks on its own cache."""
    eng = ContinuousBatchingEngine(cfg, params, max_slots=2, max_seq=32,
                                   paged=True, page_size=8, num_pages=4)
    key = jax.random.PRNGKey(44)
    # sequential: each leaves its pages pinned by the index after completion
    outs = {}
    for i in range(4):
        p = _prompt(jax.random.fold_in(key, i), 10)  # 2 pages each
        rid = eng.submit(p, 4)
        outs[rid] = (p, {c.rid: c.tokens for c in eng.drain()}[rid])
    for rid, (p, toks) in outs.items():
        assert np.array_equal(toks, _solo(cfg, params, p, 4, 32))
    eng._allocator.assert_consistent()


def test_engine_fused_digest_inserted_once():
    """A fused C2C prefix transmitted once is inserted into the row table
    once; every later request with the same digest reuses the row, and
    outputs match the non-shared engine."""
    zoo = tiny_zoo(vocab_size=VOCAB)
    rx, tx = zoo["receiver"], zoo["transmitters"][0]
    key = jax.random.PRNGKey(45)
    p_rx = T.init_params(rx, key, jnp.float32)
    p_tx = T.init_params(tx, jax.random.fold_in(key, 1), jnp.float32)
    fz = F.init_fuser(tx, rx, jax.random.fold_in(key, 2))
    src = _prompt(key, 6)
    _, txc = T.prefill(tx, p_tx, src, max_seq=6, cache_dtype=jnp.float32)
    fused = F.project_cache(fz, tx, rx, txc.export_stack(tx, length=6))
    prompts = [_prompt(jax.random.fold_in(key, 10 + i), 5 + i)
               for i in range(4)]

    def run(pc):
        eng = ContinuousBatchingEngine(rx, p_rx, max_slots=4, max_seq=40,
                                       max_prefix=8, paged=True, page_size=8,
                                       prefix_cache=pc)
        rids = [eng.submit(p, 5, fused=fused) for p in prompts]
        done = {c.rid: c.tokens for c in eng.drain()}
        return [done[r] for r in rids], eng.stats

    out_on, st = run(True)
    out_off, st_off = run(False)
    for a, b in zip(out_on, out_off):
        assert np.array_equal(a, b)
    assert st["fused_inserts"] == 1
    assert st["fused_digest_hits"] == len(prompts) - 1
    # row sharing is digest-level, independent of the radix prefix cache
    assert st_off["fused_inserts"] == 1
    for p, toks in zip(prompts, out_on):
        assert np.array_equal(toks, _solo(rx, p_rx, p, 5, 40, fused))


def test_engine_fused_row_reused_across_slot_turnover():
    """Slot reuse doesn't re-insert a known digest: rows are refcounted and
    the digest pin keeps the row warm between occupants."""
    zoo = tiny_zoo(vocab_size=VOCAB)
    rx, tx = zoo["receiver"], zoo["transmitters"][0]
    key = jax.random.PRNGKey(46)
    p_rx = T.init_params(rx, key, jnp.float32)
    p_tx = T.init_params(tx, jax.random.fold_in(key, 1), jnp.float32)
    fz = F.init_fuser(tx, rx, jax.random.fold_in(key, 2))
    src = _prompt(key, 6)
    _, txc = T.prefill(tx, p_tx, src, max_seq=6, cache_dtype=jnp.float32)
    fused = F.project_cache(fz, tx, rx, txc.export_stack(tx, length=6))
    eng = ContinuousBatchingEngine(rx, p_rx, max_slots=1, max_seq=40,
                                   max_prefix=8)
    for i in range(3):  # sequential: the single slot turns over each time
        p = _prompt(jax.random.fold_in(key, 20 + i), 5)
        rid = eng.submit(p, 4, fused=fused)
        done = {c.rid: c.tokens for c in eng.drain()}
        assert np.array_equal(done[rid], _solo(rx, p_rx, p, 4, 40, fused))
    assert eng.stats["fused_inserts"] == 1
    assert eng.stats["fused_digest_hits"] == 2


# ------------------------------------------------ unified insert_slot API


def test_insert_slot_polymorphic_over_lease(cfg, params):
    """KVCache.insert_slot accepts (and ignores) a PageLease in the same
    positional slot where SlotTable.insert_slot requires one."""
    p = _prompt(jax.random.PRNGKey(47), 6)
    _, req = T.prefill(cfg, params, p, max_seq=32, cache_dtype=jnp.float32)
    lease = PageLease(page_ids=np.asarray([0], np.int32),
                      owned=np.asarray([True]))

    dense = KVCache.init_slots(cfg, 2, 32, jnp.float32)
    with_lease = dense.insert_slot(0, req, 6, lease)
    without = dense.insert_slot(0, req, 6)
    assert jax.tree.all(jax.tree.map(
        lambda a, b: jnp.array_equal(a, b), with_lease, without))

    table = SlotTable.init(cfg, 2, 32, jnp.float32, page_size=8)
    via_lease = table.insert_slot(0, req, 6, lease)
    row = lease.page_row(table.pages_per_slot, table.invalid_page)
    via_row = table.insert_slot(0, req, 6, jnp.asarray(row))
    assert jax.tree.all(jax.tree.map(
        lambda a, b: jnp.array_equal(a, b), via_lease.layers, via_row.layers))
    assert np.array_equal(via_lease.page_map, via_row.page_map)


# ---------------------------------------------------- legacy dict interop


def test_getitem_emits_deprecation_warning(cfg):
    stack = KVStack(k=jnp.zeros((1, 1, 1, 2, 4)), v=jnp.zeros((1, 1, 1, 2, 4)))
    with pytest.warns(DeprecationWarning, match="dict-style access"):
        _ = stack["k"]
    fused = FusedPrefix.empty(cfg, 1, 4)
    with pytest.warns(DeprecationWarning, match="dict-style access"):
        _ = fused["bias"]
    cache = KVCache.init(cfg, 1, 8, jnp.float32)
    with pytest.warns(DeprecationWarning, match="dict-style access"):
        _ = cache["pos"]
    # attribute access stays silent and returns the same leaves
    assert stack.k is not None and fused.bias is not None
    assert cache.pos.shape == ()
