"""Case-study data + protocol-shape invariants (fast; the full trained case
study runs in benchmarks)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.synthetic import A_TOK, Q_TOK, World, WorldSpec


@pytest.fixture(scope="module")
def world():
    return World(WorldSpec())


def test_question_batch_never_contains_answers(world):
    """The anti-cheating invariant: answer tokens appear only in labels."""
    rng = np.random.default_rng(0)
    b = world.question_batch(rng, 8, 24)
    obj_base = world.spec.obj_base
    assert not ((b["tokens"] >= obj_base) &
                (b["tokens"] < obj_base + world.spec.n_objects)).any()
    lab = b["labels"][b["labels"] >= 0]
    assert ((lab >= obj_base) & (lab < obj_base + world.spec.n_objects)).all()


def test_question_batch_single_question_matches_eval_shape(world):
    rng = np.random.default_rng(0)
    b = world.question_batch(rng, 4, 4)
    assert b["tokens"].shape == (4, 4)
    assert (b["tokens"][:, 0] == Q_TOK).all()
    assert (b["tokens"][:, 3] == A_TOK).all()
    assert (b["labels"][:, 3] >= world.spec.obj_base).all()
    ev = world.eval_batch(np.random.default_rng(0), 4)
    assert ev["prompt"].shape == (4, 4)


def test_known_mask_partitions_facts(world):
    rng = np.random.default_rng(1)
    for known in (True, False):
        for _ in range(20):
            t, _ = world.qa_example(rng, known=known)
            s_cls = (t[1] - world.spec.subj_base) // world.spec.syn_width
            r_cls = (t[2] - world.spec.rel_base) // world.spec.syn_width
            assert bool(world.known[s_cls, r_cls]) == known
    frac = world.known.mean()
    assert 0.15 < frac < 0.45  # ~receiver_known_frac


def test_domain_partition(world):
    rng = np.random.default_rng(2)
    for d in range(world.spec.n_domains):
        t, _ = world.qa_example(rng, domain=d)
        s_cls = (t[1] - world.spec.subj_base) // world.spec.syn_width
        assert world.domain_of_subj(int(s_cls)) == d


def test_answers_invariant_under_rephrasing(world):
    """Same fact, any synonym surface -> same answer token."""
    ch = world.synonym_channel()
    rng = np.random.default_rng(3)
    ev = world.eval_batch(rng, 32)
    p = jnp.asarray(ev["prompt"])
    rp = ch.rephrase(p, jax.random.PRNGKey(0))
    # recompute answers from the rephrased surface forms
    for b in range(32):
        s_cls = int((rp[b, 1] - world.spec.subj_base) // world.spec.syn_width)
        r_cls = int((rp[b, 2] - world.spec.rel_base) // world.spec.syn_width)
        assert world.obj_token(world.facts[s_cls, r_cls]) == ev["answer"][b]


def test_gating_selects_between_transmitters():
    """Gate weights differ across differently-distributed fused stacks."""
    from repro.configs.case_study import tiny_zoo
    from repro.core.gating import gate_weight, init_gating
    rx = tiny_zoo()["receiver"]
    g = init_gating(rx, jax.random.PRNGKey(0))
    n, B, H, S, hd = len(rx.attention_layers), 3, rx.num_kv_heads, 4, \
        rx.resolved_head_dim
    mk = lambda k, scale: {
        "k": scale * jax.random.normal(jax.random.PRNGKey(k), (n, B, H, S, hd)),
        "v": scale * jax.random.normal(jax.random.PRNGKey(k + 1), (n, B, H, S, hd)),
    }
    w1 = gate_weight(g, mk(0, 1.0))
    w2 = gate_weight(g, mk(10, 5.0))
    assert w1.shape == (B,)
    assert ((w1 >= 0) & (w1 <= 1)).all()
    assert float(jnp.abs(w1 - w2).max()) > 1e-6
