"""int8 cache-communication quantisation (beyond-paper; core/quant.py)."""
import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.core import quant
from repro.core import commload
from repro.models.cache import FusedPrefix

KEY = jax.random.PRNGKey(9)


def _stack(n=3, B=2, H=2, S=16, hd=8, scale=1.0):
    k1, k2 = jax.random.split(KEY)
    return {"k": scale * jax.random.normal(k1, (n, B, H, S, hd)),
            "v": scale * jax.random.normal(k2, (n, B, H, S, hd))}


def test_roundtrip_error_small():
    st = _stack()
    err = quant.roundtrip_error(st)
    assert err < 0.01  # int8 per-channel: <1% relative L2


def test_roundtrip_scale_invariant():
    """Per-channel scales make the error independent of magnitude."""
    e1 = quant.roundtrip_error(_stack(scale=1.0))
    e2 = quant.roundtrip_error(_stack(scale=1000.0))
    assert abs(e1 - e2) < 1e-3


def test_dtype_and_shapes():
    st = _stack()
    q = quant.quantize_stack(st)
    assert q.k_q.dtype == jnp.int8
    assert q.k_scale.shape == (3, 2, 2, 1, 8)
    dq = quant.dequantize_stack(q, jnp.bfloat16)
    assert dq.k.dtype == jnp.bfloat16
    assert dq.k.shape == st["k"].shape


def test_wire_bytes_halved():
    """Asymptotically exactly 2× less than bf16 C2C; the paper's 88 KB -> 43 KB."""
    cfg = get_config("internlm2-1.8b")
    bf16 = commload.c2c_bytes_per_token(cfg, 2)
    int8 = quant.c2c_bytes_per_token_quantized(cfg)
    assert int8 == bf16 / 2
    # concrete stack accounting (incl. scale overhead) approaches 0.5 as S grows
    st = _stack(n=24, B=1, H=8, S=256, hd=128)
    bf16_bytes = 2 * st["k"].size * 2  # k+v at 2 B/elem on the wire
    ratio = quant.quantized_bytes(st) / bf16_bytes
    assert 0.5 < ratio < 0.52


def test_quantized_prefix_decode_close():
    """C2C decode with an int8 fused prefix ≈ full-precision decode."""
    from repro.configs.case_study import tiny_zoo
    from repro.core import c2c, fuser as F
    from repro.models import transformer as T

    z = tiny_zoo()
    tx, rx = z["transmitters"][0], z["receiver"]
    p_tx = T.init_params(tx, KEY, jnp.float32)
    p_rx = T.init_params(rx, jax.random.fold_in(KEY, 1), jnp.float32)
    prompt = jax.random.randint(KEY, (1, 8), 8, 200)
    _, cache = T.prefill(tx, p_tx, prompt % tx.vocab_size, max_seq=8,
                         cache_dtype=jnp.float32)
    st = cache.export_stack(tx, length=8)
    fz = F.init_fuser(tx, rx, KEY)
    fused = F.project_cache(fz, tx, rx, st)
    dq = quant.dequantize_stack(quant.quantize_stack(fused), jnp.float32)
    fused_q = FusedPrefix(k=dq.k, v=dq.v, bias=fused.bias)
    a, _ = c2c.c2c_forward(rx, p_rx, prompt, fused)
    b, _ = c2c.c2c_forward(rx, p_rx, prompt, fused_q)
    # logits differ by less than typical logit gaps
    assert float(jnp.abs(a - b).max()) < 0.5
    assert float(jnp.mean(jnp.argmax(a[:, -1], -1) ==
                          jnp.argmax(b[:, -1], -1))) == 1.0


def test_decode_attention_q8_kernel():
    """int8-KV flash decode kernel == fp32 reference on dequantised values."""
    from repro.kernels import ops, ref
    ks = jax.random.split(KEY, 3)
    B, H, Hkv, S, hd = 2, 4, 2, 128, 32
    q = jax.random.normal(ks[0], (B, H, hd), jnp.float32)
    stack_like = {"k": jax.random.normal(ks[1], (1, B, Hkv, S, hd)),
                  "v": jax.random.normal(ks[2], (1, B, Hkv, S, hd))}
    qs = quant.quantize_stack(stack_like)
    qstack = {"k_q": qs.k_q[0], "v_q": qs.v_q[0],
              "k_scale": qs.k_scale[0], "v_scale": qs.v_scale[0]}
    bias = jnp.zeros((B, S))
    o1 = ops.decode_attention_q8(q, qstack, bias)
    dq = quant.dequantize_stack(qs, jnp.float32)
    o2 = ref.decode_attention_ref(q.reshape(B, Hkv, H // Hkv, hd),
                                  dq.k[0], dq.v[0], bias)
    o2 = o2.reshape(B, H, hd)
    assert float(jnp.abs(o1 - o2).max()) < 1e-4
