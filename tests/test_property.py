"""Hypothesis property tests on system invariants.

Uses the real ``hypothesis`` when installed (pinned in requirements.txt — CI);
hermetic environments without it fall back to the API-compatible deterministic
shim in repro.testing.propcheck so these invariants stay exercised everywhere.
"""
import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # hermetic env: boundary-first deterministic shim
    from repro.testing.propcheck import given, settings, strategies as st

from repro.core import fuser as F
from repro.roofline import _shape_bytes, parse_collectives

KEY = jax.random.PRNGKey(5)
settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


# ------------------------------------------------------------------ alignment


@given(rx=st.integers(1, 96), tx=st.integers(1, 96),
       mode=st.sampled_from(["bottom_up", "proportional"]))
def test_alignment_total_and_monotone(rx, tx, mode):
    table = F.LayerAlignment(rx, tx, mode).table
    assert len(table) == rx
    assert all(0 <= t < tx for t in table)
    assert list(table) == sorted(table)  # bottom-up order preserved
    assert table[0] == 0  # bottom layers pair with bottom layers


# ------------------------------------------------------------------ roofline


@given(st.lists(st.tuples(
    st.sampled_from(["all-reduce", "all-gather", "reduce-scatter",
                     "all-to-all", "collective-permute"]),
    st.sampled_from(["f32", "bf16", "s32"]),
    st.lists(st.integers(1, 64), min_size=1, max_size=3)), max_size=8))
def test_collective_parser_counts(ops):
    lines = ["HloModule m"]
    expected = {}
    for i, (op, dt, dims) in enumerate(ops):
        shape = f"{dt}[{','.join(map(str, dims))}]"
        lines.append(f"  %{op}.{i} = {shape} {op}({shape} %x.{i}), replica_groups={{}}")
        expected[op] = expected.get(op, 0) + 1
    stats = parse_collectives("\n".join(lines))
    assert stats.counts == expected


@given(st.sampled_from(["f32", "bf16", "s8"]),
       st.lists(st.integers(1, 32), min_size=0, max_size=4))
def test_shape_bytes(dt, dims):
    nbytes = {"f32": 4, "bf16": 2, "s8": 1}[dt]
    n = int(np.prod(dims)) if dims else 1
    s = f"{dt}[{','.join(map(str, dims))}]"
    assert _shape_bytes(s) == n * nbytes


# ------------------------------------------------------------------ caches


@given(st.integers(1, 3), st.integers(1, 4), st.integers(2, 8))
def test_cache_concat_associative(n, b, s):
    from repro.models.cache import KVStack
    shapes = (n, b, 2, s, 4)
    rng = np.random.default_rng(42)
    mk = lambda: KVStack(k=jnp.asarray(rng.normal(size=shapes), jnp.float32),
                         v=jnp.asarray(rng.normal(size=shapes), jnp.float32))
    a, b_, c = mk(), mk(), mk()
    left = a.prepend(b_).prepend(c)
    right = a.prepend(b_.prepend(c))
    # own.prepend(fused) prepends fused: (c∘(b∘a)) vs ((c∘b)∘a) equal
    assert jnp.array_equal(left.k, right.k)


# ------------------------------------------------------------------ privacy


@given(st.integers(0, 2**31 - 1))
def test_paraphrase_channel_closure_and_class_invariance(seed):
    from repro.core.privacy import synonym_channel
    V, W = 64, 4
    ch = synonym_channel(V, W, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(seed), (2, 10), 0, V)
    out = ch.rephrase(toks, jax.random.PRNGKey(seed + 1))
    assert ((0 <= out) & (out < V)).all()  # vocabulary closure
    assert (ch.class_of[toks] == ch.class_of[out]).all()  # semantics preserved


# ------------------------------------------------------------------ fuser


@given(st.integers(1, 3))
@settings(max_examples=5)
def test_fuser_batch_equivariance(b):
    """Projecting a batch == projecting each element (no cross-batch leakage)."""
    from repro.configs.case_study import tiny_zoo
    z = tiny_zoo()
    tx, rx = z["transmitters"][0], z["receiver"]
    fz = F.init_fuser(tx, rx, KEY)
    n_tx = len(tx.attention_layers)
    S = 4
    stack = {
        "k": jax.random.normal(KEY, (n_tx, b, tx.num_kv_heads, S,
                                     tx.resolved_head_dim)),
        "v": jax.random.normal(jax.random.fold_in(KEY, 1),
                               (n_tx, b, tx.num_kv_heads, S,
                                tx.resolved_head_dim)),
    }
    full = F.project_cache(fz, tx, rx, stack)
    for i in range(b):
        one = F.project_cache(fz, tx, rx,
                              jax.tree.map(lambda a: a[:, i : i + 1], stack))
        assert float(jnp.abs(one.k[:, 0] - full.k[:, i]).max()) < 1e-5


# ------------------------------------------------------------------ tokenizer


@given(st.text(max_size=64))
def test_tokenizer_roundtrip_property(s):
    from repro.data.tokenizer import ByteTokenizer
    tok = ByteTokenizer()
    assert tok.decode(tok.encode(s)) == s


# ------------------------------------------------------------------ optimizer


@given(st.floats(1e-5, 1e-1), st.integers(1, 20))
@settings(max_examples=10)
def test_adamw_step_bounded(lr, steps):
    """|Δw| per step ≤ lr·(1+wd) — AdamW's normalised-update invariant."""
    from repro.optim.adamw import AdamWConfig, apply_updates, init_opt_state
    cfg = AdamWConfig(lr=lr, grad_clip=0.0, weight_decay=0.0)
    params = {"w": jnp.asarray([1.0, -1.0, 0.5])}
    state = init_opt_state(params)
    for i in range(steps):
        prev = params["w"]
        grads = {"w": jnp.sin(jnp.asarray([i, i + 1, i + 2], jnp.float32))}
        params, state = apply_updates(cfg, params, grads, state)
        assert float(jnp.abs(params["w"] - prev).max()) <= lr * 1.2


# ----------------------------------------------------------- chunked prefill


def _chunk_world():
    """Module-cached tiny engine world (params jit once per session)."""
    global _CHUNK_WORLD
    try:
        return _CHUNK_WORLD
    except NameError:
        from repro.configs.base import ModelConfig
        from repro.models import transformer as T
        cfg = ModelConfig(name="prop-tiny", family="dense", num_layers=2,
                          d_model=32, num_heads=2, num_kv_heads=1,
                          head_dim=16, d_ff=64, vocab_size=64,
                          tie_embeddings=True)
        _CHUNK_WORLD = (cfg, T.init_params(cfg, jax.random.PRNGKey(0),
                                           jnp.float32))
        return _CHUNK_WORLD


@given(budget=st.integers(1, 17), seed=st.integers(0, 2**31 - 1),
       shared=st.booleans())
@settings(max_examples=5, deadline=None)
def test_chunked_prefill_scheduler_invariants(budget, seed, shared):
    """Chunked prefill is a pure scheduling change. For random chunk budgets,
    prompt lengths and radix-hit patterns: tokens are byte-identical to the
    monolithic paged engine's, no slot is ever active (decoding) before its
    final chunk adopts its pages, chunked prefill traces once, and the
    sanitizer's leak report is empty after drain."""
    from repro.launch.engine import ContinuousBatchingEngine
    cfg, params = _chunk_world()
    rng = np.random.default_rng(seed)
    base_p = jnp.asarray(rng.integers(0, 64, (1, int(rng.integers(9, 20)))),
                         jnp.int32)
    reqs = []
    for _ in range(3):
        tail = jnp.asarray(rng.integers(0, 64, (1, int(rng.integers(1, 20)))),
                           jnp.int32)
        p = jnp.concatenate([base_p, tail], 1) if shared else tail
        reqs.append((p, int(rng.integers(1, 6))))

    def mk(**kw):
        eng = ContinuousBatchingEngine(cfg, params, max_slots=2, max_seq=48,
                                       paged=True, page_size=8,
                                       sanitize=True, **kw)
        return eng, [eng.submit(p, n) for p, n in reqs]

    ref_eng, ref_rids = mk()
    ref = {c.rid: c.tokens for c in ref_eng.drain()}
    assert ref_eng.sanitizer_report() == []

    eng, rids = mk(prefill_token_budget=budget)
    done = {}
    while eng._queue or eng._partials or eng._active.any():
        for c in eng.step():
            done[c.rid] = c.tokens
        # mid-flight invariant: a slot mid-chunked-prefill never decodes —
        # it is inactive and its device page row is still fully INVALID
        for part in eng._partials:
            assert not eng._active[part.slot]
            assert (np.asarray(eng._table.page_map[part.slot])
                    == eng._table.invalid_page).all()
    for c in eng._ready:
        done[c.rid] = c.tokens
    eng._ready = []
    assert eng.sanitizer_report() == []
    for ra, rb in zip(ref_rids, rids):
        assert np.array_equal(ref[ra], done[rb])
    assert eng.stats["prefill_traces"] == 1
