"""FedRefine core invariants: fusers, gating, C2C equations, protocol, commload."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import get_config, get_smoke_config
from repro.configs.case_study import tiny_zoo
from repro.core import c2c, commload, fuser as F, protocol
from repro.core.fedrefine import FedRefineSystem, Participant
from repro.models import transformer as T

KEY = jax.random.PRNGKey(3)


@pytest.fixture(scope="module")
def zoo():
    z = tiny_zoo()
    members = []
    for i, cfg in enumerate([z["receiver"], *z["transmitters"]]):
        params = T.init_params(cfg, jax.random.fold_in(KEY, i), jnp.float32)
        members.append(Participant(cfg.name, cfg, params))
    return members


@pytest.fixture(scope="module")
def system(zoo):
    return FedRefineSystem.build(zoo)


# --------------------------------------------------------------------- fusers


@pytest.mark.slow
def test_fuser_heterogeneous_dims(system, zoo):
    """Fusers bridge models with different layer counts / kv dims / head counts."""
    rx = zoo[0]
    for tx in zoo[1:]:
        fz = system.registry.get(tx.name, rx.name)
        S = 8
        prompt = jnp.zeros((2, S), jnp.int32)
        _, cache = T.prefill(tx.cfg, tx.params, prompt, max_seq=S,
                             cache_dtype=jnp.float32)
        st = cache.export_stack(tx.cfg, length=S)
        out = F.project_cache(fz, tx.cfg, rx.cfg, st)
        n_rx = len(rx.cfg.attention_layers)
        assert out.k.shape == (n_rx, 2, rx.cfg.num_kv_heads, S,
                               rx.cfg.resolved_head_dim)
        assert out.bias.shape == (n_rx, 2, S)


def test_alignment_bottom_up_clips():
    a = F.LayerAlignment(rx_layers=6, tx_layers=3, mode="bottom_up")
    assert a.table == (0, 1, 2, 2, 2, 2)
    p = F.LayerAlignment(rx_layers=6, tx_layers=3, mode="proportional")
    assert p.table == (0, 0, 1, 1, 2, 2)
    assert max(p.table) < 3


def test_inapplicable_for_ssm():
    mamba = get_smoke_config("mamba2-130m")
    qwen = get_smoke_config("qwen3-1.7b")
    with pytest.raises(F.InapplicableError):
        F.make_alignment(mamba, qwen)
    with pytest.raises(F.InapplicableError):
        F.make_alignment(qwen, mamba)


@pytest.mark.slow
def test_closed_gate_is_standalone(system, zoo):
    rx, tx = zoo[0], zoo[1]
    prompt = jax.random.randint(KEY, (2, 10), 8, rx.cfg.vocab_size)
    fz = dict(system.registry.get(tx.name, rx.name))
    fz["gate"] = jnp.full_like(fz["gate"], -200.0)
    _, cache = T.prefill(tx.cfg, tx.params, prompt % tx.cfg.vocab_size,
                         max_seq=10, cache_dtype=jnp.float32)
    st = cache.export_stack(tx.cfg, length=10)
    fused = F.project_cache(fz, tx.cfg, rx.cfg, st)
    lg_c2c, _ = c2c.c2c_forward(rx.cfg, rx.params, prompt, fused)
    lg_solo, _ = T.forward(rx.cfg, rx.params, prompt)
    assert float(jnp.abs(lg_c2c - lg_solo).max()) < 1e-4


def test_open_gate_changes_logits(system, zoo):
    rx, tx = zoo[0], zoo[1]
    prompt = jax.random.randint(KEY, (2, 10), 8, rx.cfg.vocab_size)
    fz = dict(system.registry.get(tx.name, rx.name))
    fz["gate"] = jnp.full_like(fz["gate"], 5.0)
    _, cache = T.prefill(tx.cfg, tx.params, prompt % tx.cfg.vocab_size,
                         max_seq=10, cache_dtype=jnp.float32)
    st = cache.export_stack(tx.cfg, length=10)
    fused = F.project_cache(fz, tx.cfg, rx.cfg, st)
    lg_c2c, _ = c2c.c2c_forward(rx.cfg, rx.params, prompt, fused)
    lg_solo, _ = T.forward(rx.cfg, rx.params, prompt)
    assert float(jnp.abs(lg_c2c - lg_solo).max()) > 1e-3


def test_eq1_equals_eq4_single_transmitter(system, zoo):
    rx, tx = zoo[0], zoo[1]
    prompt = jnp.zeros((1, 6), jnp.int32)
    _, cache = T.prefill(tx.cfg, tx.params, prompt, max_seq=6,
                         cache_dtype=jnp.float32)
    st = cache.export_stack(tx.cfg, length=6)
    fz = system.registry.get(tx.name, rx.name)
    one = F.project_cache(fz, tx.cfg, rx.cfg, st)
    multi = c2c.fused_prefix([fz], [tx.cfg], rx.cfg, [st])
    for k in ("k", "v", "bias"):
        assert float(jnp.abs(getattr(one, k) - getattr(multi, k)).max()) == 0.0


def test_multi_transmitter_concat_order(system, zoo):
    rx = zoo[0]
    txs = zoo[1:3]
    prompt = jnp.zeros((1, 5), jnp.int32)
    stacks, fusers, cfgs = [], [], []
    for tx in txs:
        _, cache = T.prefill(tx.cfg, tx.params, prompt, max_seq=5,
                             cache_dtype=jnp.float32)
        stacks.append(cache.export_stack(tx.cfg, length=5))
        fusers.append(system.registry.get(tx.name, rx.name))
        cfgs.append(tx.cfg)
    fused = c2c.fused_prefix(fusers, cfgs, rx.cfg, stacks)
    assert fused.k.shape[-2] == 10  # seq-wise concatenation (Eq. 4)


@pytest.mark.slow
def test_bidirectional_roles(system, zoo):
    a, b = zoo[1], zoo[2]
    B, S = 1, 6
    prompt = jnp.zeros((B, S), jnp.int32)
    _, ca = T.prefill(a.cfg, a.params, prompt, max_seq=S + 2, cache_dtype=jnp.float32)
    _, cb = T.prefill(b.cfg, b.params, prompt, max_seq=S + 2, cache_dtype=jnp.float32)
    fab = system.registry.get(a.name, b.name)
    fba = system.registry.get(b.name, a.name)
    ta = jnp.zeros((B,), jnp.int32)
    (lg_a, _), (lg_b, _) = c2c.bidirectional_step(
        a.cfg, a.params, ca, ta, b.cfg, b.params, cb, ta, fab, fba)
    assert lg_a.shape == (B, a.cfg.vocab_size)
    assert lg_b.shape == (B, b.cfg.vocab_size)


def test_registry_full_matrix(system, zoo):
    n = len(zoo)
    assert len(system.registry.links()) == n * (n - 1)


def test_scheduler_affinity(system, zoo):
    system.task_affinity["code"] = [zoo[2].name]
    picks = system.schedule("code", zoo[0].name, 2)
    assert picks[0] == zoo[2].name
    assert zoo[0].name not in picks


# ------------------------------------------------------------------- commload


def test_paper_88kb_vs_16b():
    """The case-study zoo's published dims reproduce the paper's byte counts."""
    r = commload.paper_case_study_bytes(dtype_bytes=2)
    assert 70_000 < r["c2c_total_per_token"] < 100_000  # paper: 88 KB
    assert r["t2t_total_per_token"] == 16  # paper: 16 B


def test_c2c_bytes_formula():
    cfg = get_config("internlm2-1.8b")
    b = commload.c2c_bytes_per_token(cfg, 2)
    assert b == 2 * 24 * 8 * 128 * 2  # k+v × layers × kv_heads × hd × bytes


# ------------------------------------------------------------------- protocol


def test_protocol_monotone_in_bandwidth():
    txs = [get_config("internlm2-1.8b")]
    rx = get_config("qwen3-1.7b")
    qos = protocol.QoS(max_latency_s=2.0)
    chosen = []
    for bw in (1e5, 1e6, 1e7, 1e8, 1e9, 1e10):
        r = protocol.choose_protocol(txs, rx, seq=1024, gen_steps=64,
                                     link=protocol.LinkModel(bw), qos=qos)
        chosen.append(r["protocol"])
    rank = {"standalone": 0, "t2t": 1, "c2c": 2}
    ranks = [rank[c] for c in chosen]
    assert ranks == sorted(ranks), f"not monotone: {chosen}"
    assert chosen[-1] == "c2c"  # infinite bandwidth => cache communication


def test_protocol_respects_qos_floor():
    txs = [get_config("internlm2-1.8b")]
    rx = get_config("qwen3-1.7b")
    r = protocol.choose_protocol(
        txs, rx, seq=1024, gen_steps=64,
        link=protocol.LinkModel(1e12),
        qos=protocol.QoS(max_latency_s=100.0, min_quality="t2t"))
    assert r["protocol"] in ("c2c", "t2t")
    assert r["qos_met"]


def test_latency_c2c_beats_t2t_on_fast_links():
    """Fig 3(c): C2C skips the receiver-side prefill rebuild."""
    txs = [get_config("qwen2.5-32b")]
    rx = get_config("qwen3-1.7b")
    link = protocol.LinkModel(bandwidth_bps=50e9)  # ICI-class link
    lat_c2c = protocol.latency_c2c(txs, rx, seq=32_768, gen_steps=128, link=link)
    lat_t2t = protocol.latency_t2t(txs, rx, seq=32_768, gen_steps=128, link=link,
                                   shared_tokens=128)
    assert lat_c2c < lat_t2t
