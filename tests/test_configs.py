"""Assigned-architecture configs: exact published dims + smoke-config contracts."""
import pytest

from repro.configs.base import (ARCH_IDS, INPUT_SHAPES, ALIASES, canonical,
                                get_config, get_smoke_config)

# (layers, d_model, heads, kv_heads, vocab) from the assignment table
EXPECTED = {
    "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 151_936),
    "qwen2.5-32b": (64, 5120, 40, 8, 152_064),
    "musicgen-large": (48, 2048, 32, 32, 2048),
    "granite-20b": (52, 6144, 48, 1, 49_152),
    "recurrentgemma-9b": (38, 4096, 16, 1, 256_000),
    "qwen2-vl-72b": (80, 8192, 64, 8, 152_064),
    "internlm2-1.8b": (24, 2048, 16, 8, 92_544),
    "mamba2-130m": (24, 768, 0, 0, 50_280),
    "qwen3-1.7b": (28, 2048, 16, 8, 151_936),
    "qwen2-moe-a2.7b": (24, 2048, 16, 16, 151_936),
}

FFN = {
    "qwen2.5-32b": 27_648, "musicgen-large": 8192, "granite-20b": 24_576,
    "recurrentgemma-9b": 12_288, "qwen2-vl-72b": 29_568,
    "internlm2-1.8b": 8192, "qwen3-1.7b": 6144,
}

MOE = {  # (experts, top_k, shared, moe_d_ff)
    "qwen3-moe-30b-a3b": (128, 8, 0, 768),
    "qwen2-moe-a2.7b": (60, 4, 4, 1408),
}


@pytest.mark.parametrize("alias", sorted(EXPECTED))
def test_exact_dims(alias):
    cfg = get_config(alias)
    L, d, H, Hkv, V = EXPECTED[alias]
    assert cfg.num_layers == L
    assert cfg.d_model == d
    assert cfg.num_heads == H
    assert cfg.num_kv_heads == Hkv
    assert cfg.vocab_size == V
    if alias in FFN:
        assert cfg.d_ff == FFN[alias]
    if alias in MOE:
        E, K, Sh, f = MOE[alias]
        assert (cfg.num_experts, cfg.num_experts_per_tok,
                cfg.num_shared_experts, cfg.moe_d_ff) == (E, K, Sh, f)
    assert cfg.source, "every config must cite its source"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_reductions(arch):
    cfg = get_smoke_config(arch)
    full = get_config(arch)
    assert cfg.num_layers <= 3
    assert cfg.d_model <= 512
    if cfg.num_experts:
        assert cfg.num_experts <= 4
    assert cfg.family == full.family
    # family-defining features preserved
    assert cfg.qk_norm == full.qk_norm
    assert cfg.qkv_bias == full.qkv_bias
    assert (cfg.mrope_sections is None) == (full.mrope_sections is None)
    assert cfg.block_pattern == full.block_pattern or cfg.family == "hybrid"


def test_family_coverage():
    fams = {get_config(a).family for a in ARCH_IDS}
    assert fams == {"dense", "moe", "ssm", "hybrid", "vlm", "audio"}


def test_param_counts_match_billing():
    # sanity: analytic param counts are in the advertised ballpark
    assert 25e9 < get_config("qwen3-moe-30b-a3b").param_count() < 35e9
    assert 2.5e9 < get_config("qwen3-moe-30b-a3b").active_param_count() < 4.5e9
    assert 28e9 < get_config("qwen2.5-32b").param_count() < 36e9
    assert 0.10e9 < get_config("mamba2-130m").param_count() < 0.16e9
    assert 60e9 < get_config("qwen2-vl-72b").param_count() < 80e9
    assert 1.5e9 < get_config("internlm2-1.8b").param_count() < 2.2e9
    assert 8e9 < get_config("recurrentgemma-9b").param_count() < 14e9
    assert 2.2e9 < get_config("qwen2-moe-a2.7b").active_param_count() < 3.8e9


def test_shapes_table():
    assert INPUT_SHAPES["train_4k"].seq_len == 4096
    assert INPUT_SHAPES["train_4k"].global_batch == 256
    assert INPUT_SHAPES["prefill_32k"].seq_len == 32_768
    assert INPUT_SHAPES["decode_32k"].global_batch == 128
    assert INPUT_SHAPES["long_500k"].seq_len == 524_288
    assert INPUT_SHAPES["long_500k"].global_batch == 1


def test_aliases_roundtrip():
    for alias, mod in ALIASES.items():
        assert canonical(alias) == mod
        assert get_config(alias).name == alias
