"""Substrate: optimizer, checkpoint roundtrip, tokenizer, data pipeline, serve."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.adamw import (AdamWConfig, apply_updates, init_opt_state,
                               schedule_lr)

KEY = jax.random.PRNGKey(11)


def test_adamw_minimises_quadratic():
    cfg = AdamWConfig(lr=0.1, grad_clip=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = init_opt_state(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state = apply_updates(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_adamw_skips_integer_leaves():
    cfg = AdamWConfig(lr=0.1)
    params = {"w": jnp.ones((2,)), "align": jnp.asarray([0, 1], jnp.int32)}
    state = init_opt_state(params)
    import jax as _jax
    grads = {"w": jnp.ones((2,)),
             "align": np.zeros((2, 0), dtype=_jax.dtypes.float0)}
    new_p, _ = apply_updates(cfg, params, grads, state)
    assert (new_p["align"] == params["align"]).all()
    assert (new_p["w"] != params["w"]).all()


def test_grad_clip_limits_update():
    cfg = AdamWConfig(lr=1.0, grad_clip=1e-6)
    params = {"w": jnp.zeros((4,))}
    state = init_opt_state(params)
    new_p, _ = apply_updates(cfg, params, {"w": jnp.full((4,), 1e6)}, state)
    # clipped grads are tiny, but adam normalisation makes the step ~lr;
    # verify no blow-up beyond lr
    assert float(jnp.abs(new_p["w"]).max()) <= 1.0 + 1e-6


def test_lr_schedules():
    cfg = AdamWConfig(lr=1.0, schedule="linear_warmup_cosine",
                      warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    lr0 = float(schedule_lr(cfg, jnp.asarray(0)))
    lr10 = float(schedule_lr(cfg, jnp.asarray(10)))
    lr100 = float(schedule_lr(cfg, jnp.asarray(100)))
    assert lr0 < 0.05
    assert 0.9 < lr10 <= 1.0
    assert abs(lr100 - 0.1) < 1e-5


def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint.checkpoint import load_pytree, save_pytree
    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "b": [jnp.ones((2,), jnp.bfloat16), None,
              (jnp.asarray(3, jnp.int32), {"c": jnp.zeros((1,))})],
    }
    path = os.path.join(tmp_path, "ckpt")
    save_pytree(path, tree)
    back = load_pytree(path)
    assert (back["a"] == tree["a"]).all()
    assert back["b"][1] is None
    assert back["b"][0].dtype == jnp.bfloat16
    assert int(back["b"][2][0]) == 3


def test_registry_checkpoint_roundtrip(tmp_path):
    from repro.configs.case_study import tiny_zoo
    from repro.core.registry import FuserRegistry
    z = tiny_zoo()
    reg = FuserRegistry({c.name: c for c in [z["receiver"], z["transmitters"][0]]})
    reg.ensure_all_pairs()
    path = os.path.join(tmp_path, "reg")
    reg.save(path)
    reg2 = FuserRegistry(reg.models)
    reg2.load(path)
    assert set(reg2.fusers) == set(reg.fusers)
    k0 = next(iter(reg.fusers))
    a = jax.tree.leaves(reg.fusers[k0])[0]
    b = jax.tree.leaves(reg2.fusers[k0])[0]
    assert (np.asarray(a) == np.asarray(b)).all()


def test_tokenizer_roundtrip():
    from repro.data.tokenizer import ByteTokenizer
    tok = ByteTokenizer()
    s = "FedRefine: héllo wörld! 123"
    assert tok.decode(tok.encode(s)) == s


def test_synthetic_world_answers_are_consistent():
    from repro.data.synthetic import World, WorldSpec
    w = World(WorldSpec())
    rng = np.random.default_rng(0)
    batch = w.qa_batch(rng, 4, 30)
    assert batch["tokens"].shape == (4, 30)
    # labels only on answer positions (shifted)
    n_labels = (batch["labels"] >= 0).sum()
    assert n_labels == 4 * (30 // 6)  # one answer per packed example


def test_synonym_channel_preserves_semantics():
    from repro.data.synthetic import World, WorldSpec
    w = World(WorldSpec())
    ch = w.synonym_channel()
    rng = np.random.default_rng(1)
    ev = w.eval_batch(rng, 16)
    p = jnp.asarray(ev["prompt"])
    rp = ch.rephrase(p, KEY)
    # answers must be invariant: class of subject/relation unchanged
    assert (ch.class_of[p[:, 1]] == ch.class_of[rp[:, 1]]).all()
    assert (ch.class_of[p[:, 2]] == ch.class_of[rp[:, 2]]).all()
    # surface must actually change sometimes (privacy)
    assert float(ch.overlap(p[:, 1:3], rp[:, 1:3])) < 0.9


def test_batched_server(key):
    from repro.configs.base import get_smoke_config
    from repro.launch.serve import BatchedServer
    from repro.models import transformer as T
    cfg = get_smoke_config("qwen3-1.7b")
    params = T.init_params(cfg, key, jnp.float32)
    srv = BatchedServer(cfg, params, max_batch=4, max_seq=48)
    prompts = jax.random.randint(key, (3, 12), 0, cfg.vocab_size)
    out = srv.serve(prompts, gen_steps=5)
    assert out.shape == (3, 5)


def test_pipeline_placement():
    from repro.data.pipeline import place_batch, prefetch
    batch = {"tokens": np.zeros((4, 8), np.int32)}
    out = place_batch(batch)
    assert out["tokens"].shape == (4, 8)
    it = prefetch(iter([batch, batch, batch]), depth=2)
    assert len(list(it)) == 3


@pytest.mark.slow
def test_model_rephrase_paper_mechanism(key):
    """The paper's own rephrasing mechanism (receiver model rewrites the query)
    produces vocabulary-valid, temperature-sampled rewrites."""
    from repro.configs.base import get_smoke_config
    from repro.core.privacy import model_rephrase
    from repro.models import transformer as T
    cfg = get_smoke_config("qwen3-1.7b")
    params = T.init_params(cfg, key, jnp.float32)
    toks = jax.random.randint(key, (2, 6), 0, cfg.vocab_size)
    out = model_rephrase(cfg, params, toks, steps=6, key=key)
    assert out.shape == (2, 6)
    assert bool(((0 <= out) & (out < cfg.vocab_size)).all())
    # different key -> different rewrite (sampled; random-init tied-embedding
    # models are extremely peaked, so a high temperature is needed to see it)
    out2 = model_rephrase(cfg, params, toks, steps=6, temperature=50.0,
                          key=jax.random.fold_in(key, 1))
    assert not bool(jnp.array_equal(out, out2))


def test_batched_server_fused_path(key):
    """BatchedServer serves with a C2C fused prefix (the federated hot path)."""
    from repro.configs.case_study import tiny_zoo
    from repro.core import fuser as F
    from repro.launch.serve import BatchedServer
    from repro.models import transformer as T
    z = tiny_zoo()
    tx, rx = z["transmitters"][0], z["receiver"]
    p_tx = T.init_params(tx, key, jnp.float32)
    p_rx = T.init_params(rx, jax.random.fold_in(key, 1), jnp.float32)
    prompts = jax.random.randint(key, (2, 10), 8, 200)
    _, cache = T.prefill(tx, p_tx, prompts % tx.vocab_size, max_seq=10,
                         cache_dtype=jnp.float32)
    st = cache.export_stack(tx, length=10)
    fused = F.project_cache(F.init_fuser(tx, rx, key), tx, rx, st)
    srv = BatchedServer(rx, p_rx, max_batch=4, max_seq=32)
    out_fused = srv.serve(prompts, gen_steps=4, fused=fused)
    out_plain = srv.serve(prompts, gen_steps=4)
    assert out_fused.shape == out_plain.shape == (2, 4)
