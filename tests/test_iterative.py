"""Iterative refinement + opportunistic serving (paper §Possible Variants)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.case_study import tiny_zoo
from repro.core import protocol
from repro.core.fedrefine import FedRefineSystem, Participant
from repro.core.iterative import iterative_c2c_refine, self_refine_with_c2c
from repro.models import transformer as T

KEY = jax.random.PRNGKey(4)


@pytest.fixture(scope="module")
def system():
    z = tiny_zoo()
    members = []
    for i, cfg in enumerate([z["receiver"], *z["transmitters"][:2]]):
        params = T.init_params(cfg, jax.random.fold_in(KEY, i), jnp.float32)
        members.append(Participant(cfg.name, cfg, params))
    return FedRefineSystem.build(members)


@pytest.mark.slow
def test_iterative_c2c_rounds(system):
    names = list(system.participants)
    rx = system.participants[names[0]]
    txs = [system.participants[n] for n in names[1:]]
    prompt = jax.random.randint(KEY, (1, 8), 8, 200)
    out = iterative_c2c_refine(
        rx.cfg, rx.params,
        [system.registry.get(t.name, rx.name) for t in txs],
        [t.cfg for t in txs], [t.params for t in txs],
        prompt, [prompt for _ in txs], rounds=2, steps=4)
    assert out["tokens"].shape == (1, 4)
    assert len(out["rounds"]) == 2
    # round 2 re-prefilled with the draft -> refreshed caches may change output
    assert out["rounds"][0].shape == out["rounds"][1].shape


@pytest.mark.slow
def test_self_refine_with_c2c(system):
    names = list(system.participants)
    rx = system.participants[names[0]]
    prompt = jax.random.randint(KEY, (1, 8), 8, 200)
    out = self_refine_with_c2c(rx.cfg, rx.params, None, prompt,
                               rounds=2, steps=4)
    assert out.shape == (1, 4)


@pytest.mark.parametrize("bw,expected", [
    (400e9, "c2c"),        # ICI-class link: ship the caches
    (1.0, "standalone"),   # dead link: even 24 B of tokens misses the budget
])
@pytest.mark.slow
def test_serve_opportunistic_executes_choice(system, bw, expected):
    names = list(system.participants)
    prompt = jax.random.randint(KEY, (1, 8), 8, 200)
    out = system.serve_opportunistic(
        names[0], prompt, steps=3,
        link=protocol.LinkModel(bandwidth_bps=bw),
        qos=protocol.QoS(max_latency_s=5.0), n_tx=2)
    assert out["tokens"].shape == (1, 3)
    assert out["protocol"] == expected
