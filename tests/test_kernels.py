"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)


def _mlp_params(k, d_in, d_h, d_out, dt):
    ks = jax.random.split(k, 6)
    mk = lambda kk, shape: (jax.random.normal(kk, shape, jnp.float32) * 0.05).astype(dt)
    return {
        "w1": {"w": mk(ks[0], (d_in, d_h)), "b": mk(ks[1], (d_h,))},
        "w2": {"w": mk(ks[2], (d_h, d_h)), "b": mk(ks[3], (d_h,))},
        "w3": {"w": mk(ks[4], (d_h, d_out)), "b": mk(ks[5], (d_out,))},
    }


@pytest.mark.parametrize("T,d_in,d_h,d_out", [
    (64, 96, 128, 160), (256, 128, 128, 128), (100, 48, 64, 80), (8, 32, 32, 32),
])
@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
def test_fuser_mlp_sweep(T, d_in, d_h, d_out, dt):
    x = jax.random.normal(KEY, (T, d_in), jnp.float32).astype(dt)
    p = _mlp_params(KEY, d_in, d_h, d_out, dt)
    y = ops.fuser_mlp(p, x)
    yr = ref.fuser_mlp_ref(x, p["w1"]["w"], p["w1"]["b"], p["w2"]["w"],
                           p["w2"]["b"], p["w3"]["w"], p["w3"]["b"])
    tol = 1e-5 if dt == jnp.float32 else 5e-2
    assert y.shape == (T, d_out)
    assert float(jnp.abs(y.astype(jnp.float32) - yr.astype(jnp.float32)).max()) < tol


def test_fuser_mlp_batched_leading_dims():
    p = _mlp_params(KEY, 32, 48, 40, jnp.float32)
    x = jax.random.normal(KEY, (3, 5, 7, 32), jnp.float32)
    y = ops.fuser_mlp(p, x)
    assert y.shape == (3, 5, 7, 40)


@pytest.mark.parametrize("n,B,H,S,hd", [(3, 2, 2, 64, 32), (1, 1, 4, 128, 16),
                                        (5, 2, 1, 96, 64)])
@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
def test_gated_fusion_sweep(n, B, H, S, hd, dt):
    ks = jax.random.split(KEY, 5)
    args = [jax.random.normal(k, (n, B, H, S, hd), jnp.float32).astype(dt)
            for k in ks[:4]]
    gate = jax.random.normal(ks[4], (n,))
    k1, v1 = ops.gated_fusion(*args, gate)
    k2, v2 = ref.gated_fusion_ref(*args, gate)
    tol = 1e-6 if dt == jnp.float32 else 2e-2
    assert float(jnp.abs((k1 - k2).astype(jnp.float32)).max()) < tol
    assert float(jnp.abs((v1 - v2).astype(jnp.float32)).max()) < tol


@pytest.mark.parametrize("B,H,Hkv,S,hd", [
    (2, 8, 2, 256, 64), (1, 4, 4, 128, 32),
    pytest.param(2, 16, 1, 512, 128, marks=pytest.mark.slow),  # largest interp case
    (1, 8, 8, 96, 64),
])
@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(B, H, Hkv, S, hd, dt):
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.float32).astype(dt)
    k = jax.random.normal(ks[1], (B, Hkv, S, hd), jnp.float32).astype(dt)
    v = jax.random.normal(ks[2], (B, Hkv, S, hd), jnp.float32).astype(dt)
    bias = jnp.where(jax.random.uniform(ks[3], (B, S)) < 0.25, -1e30, 0.0)
    o1 = ops.decode_attention(q, k, v, bias)
    o2 = ref.decode_attention_ref(q.reshape(B, Hkv, H // Hkv, hd), k, v,
                                  bias).reshape(B, H, hd)
    tol = 1e-4 if dt == jnp.float32 else 3e-2
    assert float(jnp.abs((o1 - o2).astype(jnp.float32)).max()) < tol


def test_decode_attention_fully_masked_prefix_is_standalone():
    """Gate bias -inf on a fused prefix must equal attention w/o the prefix."""
    ks = jax.random.split(KEY, 4)
    B, H, Hkv, S, Sf, hd = 1, 4, 2, 64, 16, 32
    q = jax.random.normal(ks[0], (B, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, Hkv, S + Sf, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, Hkv, S + Sf, hd), jnp.float32)
    bias = jnp.concatenate([jnp.full((B, Sf), -1e30), jnp.zeros((B, S))], -1)
    o_masked = ops.decode_attention(q, k, v, bias)
    o_own = ops.decode_attention(q, k[:, :, Sf:], v[:, :, Sf:], jnp.zeros((B, S)))
    assert float(jnp.abs(o_masked - o_own).max()) < 1e-5


def test_kernel_matches_model_fuser():
    """ops.fuser_mlp == core.fuser's jnp MLP on the same params."""
    from repro.core import fuser as F
    from repro.configs.case_study import tiny_zoo
    zoo = tiny_zoo()
    tx, rx = zoo["transmitters"][0], zoo["receiver"]
    fz = F.init_fuser(tx, rx, KEY)
    one = jax.tree.map(lambda a: a[0], fz["mlp"])
    x = jax.random.normal(KEY, (4, 2 * tx.kv_dim), jnp.float32)
    y_kernel = ops.fuser_mlp(one, x)
    y_jnp = F._mlp(one, x)
    assert float(jnp.abs(y_kernel - y_jnp).max()) < 1e-4


def test_project_cache_kernel_path_exact():
    """core.fuser.project_cache(use_kernel=True) routes through the Pallas
    fuser kernel and must equal the jnp path bit-for-bit (fp32, interpret)."""
    from repro.configs.case_study import tiny_zoo
    from repro.core import fuser as F
    z = tiny_zoo()
    tx, rx = z["transmitters"][0], z["receiver"]
    fz = F.init_fuser(tx, rx, KEY)
    n_tx = len(tx.attention_layers)
    st = {"k": jax.random.normal(KEY, (n_tx, 2, tx.num_kv_heads, 8,
                                       tx.resolved_head_dim)),
          "v": jax.random.normal(jax.random.fold_in(KEY, 1),
                                 (n_tx, 2, tx.num_kv_heads, 8,
                                  tx.resolved_head_dim))}
    a = F.project_cache(fz, tx, rx, st, use_kernel=False)
    b = F.project_cache(fz, tx, rx, st, use_kernel=True)
    for kk in ("k", "v", "bias"):
        assert float(jnp.abs(getattr(a, kk) - getattr(b, kk)).max()) == 0.0


# ------------------------------------------------- odd/prime S (padded tail)


@pytest.mark.parametrize("S", [13, 97, 251])
def test_decode_attention_odd_prime_S(S):
    """Odd/prime S (an unpadded fused-prefix length) must not degrade the
    block size to 1 (an S-program grid): ops pads to a lane-aligned block
    with -inf bias on the tail and unpads the output."""
    B, H, Hkv, hd = 2, 4, 2, 32
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, H, hd))
    k = jax.random.normal(ks[1], (B, Hkv, S, hd))
    v = jax.random.normal(ks[2], (B, Hkv, S, hd))
    bias = jnp.where(jax.random.uniform(ks[3], (B, S)) < 0.25, -1e30, 0.0)
    o1 = ops.decode_attention(q, k, v, bias)
    o2 = ref.decode_attention_ref(q.reshape(B, Hkv, H // Hkv, hd), k, v,
                                  bias).reshape(B, H, hd)
    assert o1.shape == (B, H, hd)
    assert float(jnp.abs(o1 - o2).max()) < 1e-4


def test_decode_attention_q8_odd_S():
    B, H, Hkv, S, hd = 1, 4, 2, 37, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, hd))
    kf = jax.random.normal(ks[1], (B, Hkv, S, hd))
    vf = jax.random.normal(ks[2], (B, Hkv, S, hd))
    scale = jnp.full((B, Hkv, 1, hd), 0.02, jnp.float32)
    qstack = {"k_q": jnp.clip(jnp.round(kf / 0.02), -127, 127).astype(jnp.int8),
              "v_q": jnp.clip(jnp.round(vf / 0.02), -127, 127).astype(jnp.int8),
              "k_scale": scale, "v_scale": scale}
    o1 = ops.decode_attention_q8(q, qstack, jnp.zeros((B, S)))
    o2 = ref.decode_attention_ref(
        q.reshape(B, Hkv, H // Hkv, hd),
        qstack["k_q"] * scale, qstack["v_q"] * scale,
        jnp.zeros((B, S))).reshape(B, H, hd)
    assert float(jnp.abs(o1 - o2).max()) < 1e-4


def test_banded_attention_odd_S():
    B, H, S, hd, w = 1, 2, 101, 16, 17
    ks = jax.random.split(KEY, 3)
    q, k, v = (jax.random.normal(kk, (B, H, S, hd)) for kk in ks)
    o1 = ops.banded_attention(q, k, v, window=w, block=32)
    o2 = ref.banded_attention_ref(
        q.reshape(B * H, S, hd), k.reshape(B * H, S, hd),
        v.reshape(B * H, S, hd), window=w).reshape(B, H, S, hd)
    assert o1.shape == (B, H, S, hd)
    assert float(jnp.abs(o1 - o2).max()) < 1e-4


def test_gated_fusion_odd_S():
    n, B, H, S, hd = 2, 1, 2, 37, 16
    ks = jax.random.split(KEY, 5)
    args = [jax.random.normal(k, (n, B, H, S, hd)) for k in ks[:4]]
    gate = jax.random.normal(ks[4], (n,))
    k1, v1 = ops.gated_fusion(*args, gate)
    k2, v2 = ref.gated_fusion_ref(*args, gate)
    assert k1.shape == (n, B, H, S, hd)
    assert float(jnp.abs(k1 - k2).max()) < 1e-6
    assert float(jnp.abs(v1 - v2).max()) < 1e-6


# ------------------------------------------------------ fully-masked rows


def test_decode_attention_fully_masked_rows_are_zero():
    """A row whose bias is all -inf (an empty engine slot) must emit exact
    zeros — not uniform attention over whatever garbage sits in the cache."""
    B, H, Hkv, S, hd = 2, 4, 2, 64, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, hd))
    k = jax.random.normal(ks[1], (B, Hkv, S, hd)) * 1e6  # "uninitialized"
    v = jax.random.normal(ks[2], (B, Hkv, S, hd)) * 1e6
    bias = jnp.stack([jnp.full((S,), -1e30), jnp.zeros((S,))])  # row 0 masked
    out = ops.decode_attention(q, k, v, bias)
    assert float(jnp.abs(out[0]).max()) == 0.0
    assert float(jnp.abs(out[1]).max()) > 0.0  # live row unaffected


def test_decode_attention_q8_fully_masked_rows_are_zero():
    B, H, Hkv, S, hd = 1, 2, 1, 32, 16
    q = jax.random.normal(KEY, (B, H, hd))
    scale = jnp.full((B, Hkv, 1, hd), 1e4, jnp.float32)  # huge garbage KV
    qstack = {"k_q": jnp.full((B, Hkv, S, hd), 127, jnp.int8),
              "v_q": jnp.full((B, Hkv, S, hd), 127, jnp.int8),
              "k_scale": scale, "v_scale": scale}
    out = ops.decode_attention_q8(q, qstack, jnp.full((B, S), -1e30))
    assert float(jnp.abs(out).max()) == 0.0


def test_decode_attention_pallas_bad_block_raises():
    """The shape precondition must survive python -O: ValueError, not assert."""
    from repro.kernels.decode_attention import (decode_attention_pallas,
                                                decode_attention_q8_pallas)
    B, Hkv, G, S, hd = 1, 1, 2, 24, 16
    q = jnp.zeros((B, Hkv, G, hd))
    k = jnp.zeros((B, Hkv, S, hd))
    bias = jnp.zeros((B, S))
    with pytest.raises(ValueError, match="not divisible"):
        decode_attention_pallas(q, k, k, bias, block_s=16, interpret=True)
    scale = jnp.zeros((B, Hkv, 1, hd))
    with pytest.raises(ValueError, match="not divisible"):
        decode_attention_q8_pallas(q, k.astype(jnp.int8), k.astype(jnp.int8),
                                   scale, scale, bias, block_s=16,
                                   interpret=True)


# ------------------------------------------------------- paged attention


def _paged_case(page_size, *, dt=jnp.float32):
    """Pool + page maps exercising partial final pages, interleaved
    INVALID_PAGE entries and a fully-evicted slot."""
    Hkv, G, hd = 2, 4, 32
    pps = 4
    num_pages = 3 * pps
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (5, Hkv * G, hd), jnp.float32).astype(dt)
    k_pool = jax.random.normal(
        ks[1], (num_pages, Hkv, page_size, hd), jnp.float32).astype(dt)
    v_pool = jax.random.normal(
        ks[2], (num_pages, Hkv, page_size, hd), jnp.float32).astype(dt)
    INV = num_pages
    pm = jnp.array([
        [3, 7, INV, INV],        # partial final page
        [0, INV, 5, INV],        # INVALID interleaved inside the map
        [1, 2, 4, 6],            # fully mapped
        [INV, INV, INV, INV],    # evicted slot
        [8, 9, INV, 11],         # INVALID inside the live length
    ], jnp.int32)
    lengths = jnp.array([page_size + 3, page_size - 2, 4 * page_size,
                         2, 2 * page_size + 1], jnp.int32)
    return q, k_pool, v_pool, pm, lengths


@pytest.mark.parametrize("page_size", [8, 16, 64])
def test_paged_decode_attention_matches_gather_ref(page_size):
    """In-place page-map walk == gather-then-attend oracle, across page sizes,
    partial final pages and interleaved INVALID_PAGE entries."""
    q, k_pool, v_pool, pm, lengths = _paged_case(page_size)
    slots, H, hd = q.shape
    Hkv = k_pool.shape[1]
    out, m, l = ops.paged_decode_attention(q, k_pool, v_pool, pm, lengths)
    oref = ref.paged_decode_attention_ref(
        q.reshape(slots, Hkv, H // Hkv, hd), k_pool, v_pool, pm,
        lengths).reshape(slots, H, hd)
    assert float(jnp.abs(out - oref).max()) < 1e-4
    # evicted slot: zeros with zero attention mass (hardened finish)
    assert float(jnp.abs(out[3]).max()) == 0.0
    assert float(l[3].max()) == 0.0
    assert bool((l[:3] > 0).all())


@pytest.mark.parametrize("page_size", [8, 16])
def test_paged_decode_attention_q8_matches_ref(page_size):
    q, k_pool, v_pool, pm, lengths = _paged_case(page_size)
    slots, H, hd = q.shape
    Hkv = k_pool.shape[1]
    num_pages = k_pool.shape[0]
    sk = jnp.max(jnp.abs(k_pool), axis=2, keepdims=True) / 127.0
    sv = jnp.max(jnp.abs(v_pool), axis=2, keepdims=True) / 127.0
    qpool = {
        "k_q": jnp.clip(jnp.round(k_pool / sk), -127, 127).astype(jnp.int8),
        "v_q": jnp.clip(jnp.round(v_pool / sv), -127, 127).astype(jnp.int8),
        "k_scale": sk, "v_scale": sv,
    }
    assert qpool["k_scale"].shape == (num_pages, Hkv, 1, hd)
    out, _, l = ops.paged_decode_attention_q8(q, qpool, pm, lengths)
    oref = ref.paged_decode_attention_ref(
        q.reshape(slots, Hkv, H // Hkv, hd), qpool["k_q"] * sk,
        qpool["v_q"] * sv, pm, lengths).reshape(slots, H, hd)
    assert float(jnp.abs(out - oref).max()) < 1e-4
    assert float(l[3].max()) == 0.0


def test_paged_decode_attention_lse_stats_merge():
    """The kernel's (m, l) statistics LSE-merge two disjoint page sets to the
    same result as attending over their union — the property the fused-prefix
    merge path relies on."""
    from repro.models.attention import merge_attention
    page_size, Hkv, G, hd = 8, 2, 2, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, Hkv * G, hd))
    k_pool = jax.random.normal(ks[1], (4, Hkv, page_size, hd))
    v_pool = jax.random.normal(ks[2], (4, Hkv, page_size, hd))
    pm_all = jnp.array([[0, 1, 2, 3]], jnp.int32)
    both = ops.paged_decode_attention(q, k_pool, v_pool, pm_all,
                                      jnp.array([32], jnp.int32))[0]
    INV = 4
    parts = []
    for pm in ([[0, 1, INV, INV]], [[INV, INV, 2, 3]]):
        o, m, l = ops.paged_decode_attention(q, k_pool, v_pool,
                                             jnp.array(pm, jnp.int32),
                                             jnp.array([32], jnp.int32))
        parts.append(((o * l[..., None])[:, :, None, :], m[:, :, None],
                      l[:, :, None]))
    merged = merge_attention(parts).reshape(both.shape)
    assert float(jnp.abs(merged - both).max()) < 1e-4


def test_paged_decode_attention_bad_shapes_raise():
    q = jnp.zeros((2, 2, 2, 16))
    pool = jnp.zeros((4, 2, 8, 16))
    with pytest.raises(ValueError, match="page_map"):
        from repro.kernels.paged_attention import paged_decode_attention_pallas
        paged_decode_attention_pallas(q, pool, pool,
                                      jnp.zeros((3, 2), jnp.int32),
                                      jnp.zeros((2,), jnp.int32),
                                      interpret=True)


def test_slot_table_write_token_respects_invalid_pages():
    """SlotTable.write_token scatters each slot's token to its physical page
    and drops writes through INVALID_PAGE (evicted slots can't corrupt the
    pool)."""
    from repro.models.cache import SlotTable
    Hkv, pg, hd = 2, 8, 16
    pool = jnp.zeros((4, Hkv, pg, hd))
    pm = jnp.array([[2, 0], [4, 4]], jnp.int32)  # slot 1 evicted (INVALID=4)
    tok = jnp.ones((2, Hkv, hd))
    out = SlotTable.write_token(pool, tok, pm, jnp.array([9, 3]), pg)
    # slot 0: pos 9 -> page_idx 1 -> phys pm[0,1] == 0, offset 1
    assert float(jnp.abs(out[0, :, 1] - 1.0).max()) == 0.0
    out = out.at[0, :, 1].set(0.0)
    # ... and nothing else was touched (slot 1's write dropped through INVALID)
    assert float(jnp.abs(out).max()) == 0.0


# ------------------------------------------------- ragged varlen prefill


def _ragged_case(page_size, block_q, *, dt=jnp.float32):
    """Packed varlen chunk over a paged pool: three sequences at different
    position offsets (a chunked continuation, a fresh short prompt, a suffix
    after a shared prefix), ragged tails, a trailing dead pad block, and
    INVALID pages past each sequence's live range. Returns the packed operands
    plus per-sequence dense (q, k, v, base, rows) for the monolithic oracle."""
    Hkv, G, hd = 2, 2, 16
    H = Hkv * G
    pps = 4
    num_pages = 3 * pps
    INV = num_pages
    # (first query position, #queries); context covers [0, base + nq)
    seqs = [(page_size + 1, page_size - 1), (0, 5), (3, 2 * page_size)]
    ks = jax.random.split(KEY, 2 + 3 * len(seqs))
    # unmapped pages hold huge garbage: masking must keep it out entirely
    k_pool = jax.random.normal(ks[0], (num_pages, Hkv, page_size, hd),
                               jnp.float32) * 1e3
    v_pool = jax.random.normal(ks[1], (num_pages, Hkv, page_size, hd),
                               jnp.float32) * 1e3
    pm = jnp.full((len(seqs), pps), INV, jnp.int32)
    next_page = 0
    packed_q, block_seq, block_pos, block_len, dense = [], [], [], [], []
    for i, (base, nq) in enumerate(seqs):
        ctx = base + nq
        n_pages = -(-ctx // page_size)
        pages = jnp.arange(next_page, next_page + n_pages)
        next_page += n_pages
        pm = pm.at[i, :n_pages].set(pages)
        kk = jax.random.split(ks[2 + i], 3)
        kd = jax.random.normal(kk[0], (ctx, Hkv, hd), jnp.float32)
        vd = jax.random.normal(kk[1], (ctx, Hkv, hd), jnp.float32)
        qd = jax.random.normal(kk[2], (nq, H, hd), jnp.float32)
        pad = n_pages * page_size - ctx
        put = lambda pool, d: pool.at[pages].set(
            jnp.pad(d, ((0, pad), (0, 0), (0, 0)))
            .reshape(n_pages, page_size, Hkv, hd).transpose(0, 2, 1, 3))
        k_pool = put(k_pool, kd)
        v_pool = put(v_pool, vd)
        n_blk = -(-nq // block_q)
        start = sum(a.shape[0] for a in packed_q)
        packed_q.append(jnp.pad(qd, ((0, n_blk * block_q - nq),
                                     (0, 0), (0, 0))))
        for b in range(n_blk):
            block_seq.append(i)
            block_pos.append(base + b * block_q)
            block_len.append(min(block_q, nq - b * block_q))
        rows = [start + b * block_q + t
                for b in range(n_blk)
                for t in range(min(block_q, nq - b * block_q))]
        dense.append((qd, kd, vd, base, rows))
    packed_q.append(jnp.zeros((block_q, H, hd)))  # dead pad block
    block_seq.append(-1)
    block_pos.append(0)
    block_len.append(0)
    q = jnp.concatenate(packed_q).astype(dt)
    mk = lambda xs: jnp.asarray(xs, jnp.int32)
    return (q, k_pool.astype(dt), v_pool.astype(dt), mk(block_seq),
            mk(block_pos), mk(block_len), pm, dense)


def _dense_causal_chunk(qd, kd, vd, base):
    """Monolithic padded-prefill oracle: chunk queries at absolute offset
    ``base`` attend densely + causally over the full context [0, base+nq)."""
    nq, H, hd = qd.shape
    Hkv = kd.shape[1]
    qg = qd.reshape(nq, Hkv, H // Hkv, hd)
    s = jnp.einsum("qkgd,tkd->kgqt", qg.astype(jnp.float32),
                   kd.astype(jnp.float32)) * (hd ** -0.5)
    causal = jnp.arange(kd.shape[0])[None, :] <= (base + jnp.arange(nq))[:, None]
    s = jnp.where(causal[None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("kgqt,tkd->kgqd", w, vd.astype(jnp.float32))
    return out.transpose(2, 0, 1, 3).reshape(nq, H, hd).astype(qd.dtype)


@pytest.mark.parametrize("page_size", [4, 8, 16])
@pytest.mark.parametrize("block_q", [4, 8])
def test_ragged_prefill_matches_gather_ref(page_size, block_q):
    """Scalar-prefetch page-map walk == gather-then-attend oracle across page
    sizes and block widths; ragged tails, pad blocks and unmapped pages emit
    exact zeros with zero attention mass (hardened finish)."""
    q, k_pool, v_pool, bs, bp, bl, pm, _ = _ragged_case(page_size, block_q)
    out, m, l = ops.ragged_prefill_attention(q, k_pool, v_pool, bs, bp, bl,
                                             pm, block_q=block_q)
    oref = ref.ragged_prefill_attention_ref(q, k_pool, v_pool, bs, bp, bl,
                                            pm, block_q=block_q)
    assert float(jnp.abs(out - oref).max()) < 1e-4
    live = (jnp.arange(block_q)[None] < bl[:, None]).reshape(-1)
    assert float(jnp.abs(out[~live]).max()) == 0.0
    assert float(l[~live].max()) == 0.0
    assert bool((l[live] > 0).all())


@pytest.mark.parametrize("page_size", [4, 8, 16])
def test_ragged_prefill_token_identical_to_dense(page_size):
    """Each packed sequence's rows equal a monolithic dense causal prefill of
    the same chunk — across position offsets and ragged prompt lengths. This
    is the invariant that makes chunked == monolithic prefill token-identical."""
    block_q = 8
    q, k_pool, v_pool, bs, bp, bl, pm, dense = _ragged_case(page_size, block_q)
    out, _, _ = ops.ragged_prefill_attention(q, k_pool, v_pool, bs, bp, bl,
                                             pm, block_q=block_q)
    for qd, kd, vd, base, rows in dense:
        want = _dense_causal_chunk(qd, kd, vd, base)
        assert float(jnp.abs(out[jnp.asarray(rows)] - want).max()) < 1e-4


def test_ragged_prefill_lse_stats_merge():
    """The kernel's (m, l) statistics LSE-merge two disjoint page subsets of
    one sequence to the same result as its full page map — the property the
    fused-prefix merge in the chunked prefill path relies on."""
    from repro.models.attention import merge_attention
    page_size, Hkv, G, hd, bq = 8, 2, 2, 16, 8
    H = Hkv * G
    nq = 2 * page_size
    base = 2 * page_size  # queries sit over pages 2..3; pages 0..1 are context
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (nq, H, hd))
    k_pool = jax.random.normal(ks[1], (4, Hkv, page_size, hd))
    v_pool = jax.random.normal(ks[2], (4, Hkv, page_size, hd))
    n_blk = nq // bq
    bs = jnp.zeros((n_blk,), jnp.int32)
    bp = base + jnp.arange(n_blk, dtype=jnp.int32) * bq
    bl = jnp.full((n_blk,), bq, jnp.int32)
    full, _, _ = ops.ragged_prefill_attention(
        q, k_pool, v_pool, bs, bp, bl, jnp.array([[0, 1, 2, 3]], jnp.int32),
        block_q=bq)
    INV = 4
    parts = []
    for pm in ([[0, 1, INV, INV]], [[INV, INV, 2, 3]]):
        o, m, l = ops.ragged_prefill_attention(
            q, k_pool, v_pool, bs, bp, bl, jnp.array(pm, jnp.int32),
            block_q=bq)
        # (T, H, ...) -> (1, H, T, ...) part layout merge_attention expects
        parts.append(((o * l[..., None]).transpose(1, 0, 2)[None],
                      m.T[None], l.T[None]))
    merged = merge_attention(parts).reshape(nq, H, hd)
    assert float(jnp.abs(merged - full).max()) < 1e-4


def test_ragged_prefill_bad_shapes_raise():
    from repro.kernels.prefill_attention import ragged_prefill_attention_pallas
    pool = jnp.zeros((4, 2, 8, 16))
    bs = jnp.zeros((2,), jnp.int32)
    pm = jnp.zeros((1, 2), jnp.int32)
    with pytest.raises(ValueError, match="not divisible"):
        ops.ragged_prefill_attention(jnp.zeros((10, 4, 16)), pool, pool,
                                     bs, bs, bs, pm, block_q=8)
    with pytest.raises(ValueError, match="does not match pool"):
        ragged_prefill_attention_pallas(jnp.zeros((2, 1, 8, 16)), pool, pool,
                                        bs, bs, bs, pm, block_q=8,
                                        interpret=True)
    with pytest.raises(ValueError, match="block_pos"):
        ragged_prefill_attention_pallas(jnp.zeros((2, 2, 8, 16)), pool, pool,
                                        bs, jnp.zeros((3,), jnp.int32), bs,
                                        pm, block_q=8, interpret=True)
    with pytest.raises(ValueError, match="page_map"):
        ragged_prefill_attention_pallas(jnp.zeros((2, 2, 8, 16)), pool, pool,
                                        bs, bs, bs, jnp.zeros((2,), jnp.int32),
                                        block_q=8, interpret=True)


@pytest.mark.parametrize("S,hd,w,blk", [
    (256, 32, 64, 64),
    pytest.param(512, 64, 100, 128, marks=pytest.mark.slow),  # largest interp case
    (128, 16, 16, 32), (64, 32, 64, 64),
])
@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
def test_banded_attention_sweep(S, hd, w, blk, dt):
    """Banded kernel == dense masked reference; grid never launches blocks
    outside the diagonal band (O(S·window) structural win)."""
    ks = jax.random.split(KEY, 3)
    B, H = 1, 2
    q = jax.random.normal(ks[0], (B, H, S, hd), jnp.float32).astype(dt)
    k = jax.random.normal(ks[1], (B, H, S, hd), jnp.float32).astype(dt)
    v = jax.random.normal(ks[2], (B, H, S, hd), jnp.float32).astype(dt)
    o1 = ops.banded_attention(q, k, v, window=w, block=blk)
    o2 = ref.banded_attention_ref(
        q.reshape(B * H, S, hd), k.reshape(B * H, S, hd),
        v.reshape(B * H, S, hd), window=w).reshape(B, H, S, hd)
    tol = 1e-4 if dt == jnp.float32 else 5e-2
    assert float(jnp.abs((o1 - o2).astype(jnp.float32)).max()) < tol
