"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)


def _mlp_params(k, d_in, d_h, d_out, dt):
    ks = jax.random.split(k, 6)
    mk = lambda kk, shape: (jax.random.normal(kk, shape, jnp.float32) * 0.05).astype(dt)
    return {
        "w1": {"w": mk(ks[0], (d_in, d_h)), "b": mk(ks[1], (d_h,))},
        "w2": {"w": mk(ks[2], (d_h, d_h)), "b": mk(ks[3], (d_h,))},
        "w3": {"w": mk(ks[4], (d_h, d_out)), "b": mk(ks[5], (d_out,))},
    }


@pytest.mark.parametrize("T,d_in,d_h,d_out", [
    (64, 96, 128, 160), (256, 128, 128, 128), (100, 48, 64, 80), (8, 32, 32, 32),
])
@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
def test_fuser_mlp_sweep(T, d_in, d_h, d_out, dt):
    x = jax.random.normal(KEY, (T, d_in), jnp.float32).astype(dt)
    p = _mlp_params(KEY, d_in, d_h, d_out, dt)
    y = ops.fuser_mlp(p, x)
    yr = ref.fuser_mlp_ref(x, p["w1"]["w"], p["w1"]["b"], p["w2"]["w"],
                           p["w2"]["b"], p["w3"]["w"], p["w3"]["b"])
    tol = 1e-5 if dt == jnp.float32 else 5e-2
    assert y.shape == (T, d_out)
    assert float(jnp.abs(y.astype(jnp.float32) - yr.astype(jnp.float32)).max()) < tol


def test_fuser_mlp_batched_leading_dims():
    p = _mlp_params(KEY, 32, 48, 40, jnp.float32)
    x = jax.random.normal(KEY, (3, 5, 7, 32), jnp.float32)
    y = ops.fuser_mlp(p, x)
    assert y.shape == (3, 5, 7, 40)


@pytest.mark.parametrize("n,B,H,S,hd", [(3, 2, 2, 64, 32), (1, 1, 4, 128, 16),
                                        (5, 2, 1, 96, 64)])
@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
def test_gated_fusion_sweep(n, B, H, S, hd, dt):
    ks = jax.random.split(KEY, 5)
    args = [jax.random.normal(k, (n, B, H, S, hd), jnp.float32).astype(dt)
            for k in ks[:4]]
    gate = jax.random.normal(ks[4], (n,))
    k1, v1 = ops.gated_fusion(*args, gate)
    k2, v2 = ref.gated_fusion_ref(*args, gate)
    tol = 1e-6 if dt == jnp.float32 else 2e-2
    assert float(jnp.abs((k1 - k2).astype(jnp.float32)).max()) < tol
    assert float(jnp.abs((v1 - v2).astype(jnp.float32)).max()) < tol


@pytest.mark.parametrize("B,H,Hkv,S,hd", [
    (2, 8, 2, 256, 64), (1, 4, 4, 128, 32), (2, 16, 1, 512, 128),
    (1, 8, 8, 96, 64),
])
@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(B, H, Hkv, S, hd, dt):
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.float32).astype(dt)
    k = jax.random.normal(ks[1], (B, Hkv, S, hd), jnp.float32).astype(dt)
    v = jax.random.normal(ks[2], (B, Hkv, S, hd), jnp.float32).astype(dt)
    bias = jnp.where(jax.random.uniform(ks[3], (B, S)) < 0.25, -1e30, 0.0)
    o1 = ops.decode_attention(q, k, v, bias)
    o2 = ref.decode_attention_ref(q.reshape(B, Hkv, H // Hkv, hd), k, v,
                                  bias).reshape(B, H, hd)
    tol = 1e-4 if dt == jnp.float32 else 3e-2
    assert float(jnp.abs((o1 - o2).astype(jnp.float32)).max()) < tol


def test_decode_attention_fully_masked_prefix_is_standalone():
    """Gate bias -inf on a fused prefix must equal attention w/o the prefix."""
    ks = jax.random.split(KEY, 4)
    B, H, Hkv, S, Sf, hd = 1, 4, 2, 64, 16, 32
    q = jax.random.normal(ks[0], (B, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, Hkv, S + Sf, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, Hkv, S + Sf, hd), jnp.float32)
    bias = jnp.concatenate([jnp.full((B, Sf), -1e30), jnp.zeros((B, S))], -1)
    o_masked = ops.decode_attention(q, k, v, bias)
    o_own = ops.decode_attention(q, k[:, :, Sf:], v[:, :, Sf:], jnp.zeros((B, S)))
    assert float(jnp.abs(o_masked - o_own).max()) < 1e-5


def test_kernel_matches_model_fuser():
    """ops.fuser_mlp == core.fuser's jnp MLP on the same params."""
    from repro.core import fuser as F
    from repro.configs.case_study import tiny_zoo
    zoo = tiny_zoo()
    tx, rx = zoo["transmitters"][0], zoo["receiver"]
    fz = F.init_fuser(tx, rx, KEY)
    one = jax.tree.map(lambda a: a[0], fz["mlp"])
    x = jax.random.normal(KEY, (4, 2 * tx.kv_dim), jnp.float32)
    y_kernel = ops.fuser_mlp(one, x)
    y_jnp = F._mlp(one, x)
    assert float(jnp.abs(y_kernel - y_jnp).max()) < 1e-4


def test_project_cache_kernel_path_exact():
    """core.fuser.project_cache(use_kernel=True) routes through the Pallas
    fuser kernel and must equal the jnp path bit-for-bit (fp32, interpret)."""
    from repro.configs.case_study import tiny_zoo
    from repro.core import fuser as F
    z = tiny_zoo()
    tx, rx = z["transmitters"][0], z["receiver"]
    fz = F.init_fuser(tx, rx, KEY)
    n_tx = len(tx.attention_layers)
    st = {"k": jax.random.normal(KEY, (n_tx, 2, tx.num_kv_heads, 8,
                                       tx.resolved_head_dim)),
          "v": jax.random.normal(jax.random.fold_in(KEY, 1),
                                 (n_tx, 2, tx.num_kv_heads, 8,
                                  tx.resolved_head_dim))}
    a = F.project_cache(fz, tx, rx, st, use_kernel=False)
    b = F.project_cache(fz, tx, rx, st, use_kernel=True)
    for kk in ("k", "v", "bias"):
        assert float(jnp.abs(a[kk] - b[kk]).max()) == 0.0


@pytest.mark.parametrize("S,hd,w,blk", [
    (256, 32, 64, 64), (512, 64, 100, 128), (128, 16, 16, 32), (64, 32, 64, 64),
])
@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
def test_banded_attention_sweep(S, hd, w, blk, dt):
    """Banded kernel == dense masked reference; grid never launches blocks
    outside the diagonal band (O(S·window) structural win)."""
    ks = jax.random.split(KEY, 3)
    B, H = 1, 2
    q = jax.random.normal(ks[0], (B, H, S, hd), jnp.float32).astype(dt)
    k = jax.random.normal(ks[1], (B, H, S, hd), jnp.float32).astype(dt)
    v = jax.random.normal(ks[2], (B, H, S, hd), jnp.float32).astype(dt)
    o1 = ops.banded_attention(q, k, v, window=w, block=blk)
    o2 = ref.banded_attention_ref(
        q.reshape(B * H, S, hd), k.reshape(B * H, S, hd),
        v.reshape(B * H, S, hd), window=w).reshape(B, H, S, hd)
    tol = 1e-4 if dt == jnp.float32 else 5e-2
    assert float(jnp.abs((o1 - o2).astype(jnp.float32)).max()) < tol
