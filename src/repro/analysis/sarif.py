"""SARIF 2.1.0 serialisation of linter findings.

``python -m repro.analysis --sarif`` emits a Static Analysis Results
Interchange Format log so CI (and code-scanning UIs) can ingest the
TRCxxx/OWNxxx/WIRxxx families without parsing our plain-text format.
Only the stable core of the spec is used: one ``run`` with a ``tool``
declaring every registered rule, and one ``result`` per finding with a
physical location. All rules map to SARIF level ``error`` — this repo's
CI treats any surviving finding as a failure.
"""
from __future__ import annotations

from typing import Any, Dict, List, Sequence

from repro.analysis.rules import Finding, RULES

SARIF_VERSION = "2.1.0"
SARIF_SCHEMA = ("https://raw.githubusercontent.com/oasis-tcs/sarif-spec/"
                "master/Schemata/sarif-schema-2.1.0.json")


def _rule_descriptor(rule_name: str) -> Dict[str, Any]:
    rule = RULES[rule_name]
    return {
        "id": rule.code,
        "name": rule.name,
        "shortDescription": {"text": rule.summary},
        "defaultConfiguration": {"level": "error"},
    }


def _result(finding: Finding, rule_index: Dict[str, int]) -> Dict[str, Any]:
    return {
        "ruleId": finding.code,
        "ruleIndex": rule_index[finding.rule],
        "level": "error",
        "message": {"text": f"[{finding.rule}] {finding.message}"},
        "locations": [{
            "physicalLocation": {
                "artifactLocation": {"uri": finding.path.replace("\\", "/"),
                                     "uriBaseId": "SRCROOT"},
                "region": {"startLine": max(finding.line, 1),
                           "startColumn": max(finding.col + 1, 1)},
            },
        }],
    }


def to_sarif(findings: Sequence[Finding]) -> Dict[str, Any]:
    """Build a SARIF 2.1.0 log dict from linter findings.

    The tool section always declares the *full* rule registry (not just
    the rules that fired) so scanning UIs can show the family catalogue
    even on a clean run."""
    rule_names: List[str] = list(RULES)
    rule_index = {name: i for i, name in enumerate(rule_names)}
    return {
        "$schema": SARIF_SCHEMA,
        "version": SARIF_VERSION,
        "runs": [{
            "tool": {
                "driver": {
                    "name": "repro.analysis",
                    "informationUri":
                        "https://example.invalid/repro/analysis",
                    "rules": [_rule_descriptor(n) for n in rule_names],
                },
            },
            "originalUriBaseIds": {"SRCROOT": {"uri": "file:///"}},
            "results": [_result(f, rule_index) for f in findings],
        }],
    }
