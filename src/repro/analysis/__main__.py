"""CLI: ``python -m repro.analysis [paths...] [--json]``.

Exit code 0 when the tree is clean, 1 when any finding survives
suppression comments. Default output is one ``path:line:col: CODE[rule]
message`` line per finding; ``--json`` emits a machine-readable report;
``--sarif`` emits a SARIF 2.1.0 log (code-scanning interchange format,
uploaded as a CI artifact); ``--audit-suppressions`` instead lists
``# lint: allow(...)`` comments whose rule no longer fires (exit 1 when
any are stale, so CI can gate suppression rot the same way it gates
findings).
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from repro.analysis.lint import audit_suppressions, lint_paths
from repro.analysis.rules import RULES


def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Trace-discipline linter for the serving stack.")
    parser.add_argument("paths", nargs="*", default=["src"],
                        help="files or directories to lint (default: src)")
    parser.add_argument("--json", action="store_true", dest="as_json",
                        help="emit findings as a JSON report")
    parser.add_argument("--sarif", action="store_true", dest="as_sarif",
                        help="emit findings as a SARIF 2.1.0 log")
    parser.add_argument("--list-rules", action="store_true",
                        help="print the rule registry and exit")
    parser.add_argument("--audit-suppressions", action="store_true",
                        dest="audit", help="list stale `# lint: allow(...)` "
                        "comments instead of linting")
    args = parser.parse_args(argv)

    if args.list_rules:
        for rule in RULES.values():
            print(f"{rule.code}  {rule.name:24s} {rule.summary}")
        return 0

    if args.audit:
        stale = audit_suppressions(args.paths or ["src"])
        if args.as_json:
            print(json.dumps({"stale": [vars(s) for s in stale],
                              "count": len(stale)}, indent=2))
        else:
            for s in stale:
                print(s.format())
            if stale:
                print(f"{len(stale)} stale suppression(s)", file=sys.stderr)
        return 1 if stale else 0

    findings = lint_paths(args.paths or ["src"])
    if args.as_sarif:
        from repro.analysis.sarif import to_sarif

        print(json.dumps(to_sarif(findings), indent=2))
        return 1 if findings else 0
    if args.as_json:
        print(json.dumps({"findings": [f.to_dict() for f in findings],
                          "count": len(findings)}, indent=2))
    else:
        for f in findings:
            print(f.format())
        if findings:
            print(f"{len(findings)} finding(s)", file=sys.stderr)
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
