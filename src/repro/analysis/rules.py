"""Rule registry + finding record for the trace-discipline linter.

Every rule has a stable code (``TRCxxx`` tracer discipline, ``KVxxx`` typed
KV-cache API, ``PLCxxx`` Pallas contracts, ``OWNxxx`` page-lease ownership)
and a kebab-case name usable in
suppression comments: a finding on a line containing ``lint: allow(<name>)``
(same line or the line directly above) is dropped. Add a rule by appending a
:class:`Rule` here and emitting its findings from ``lint.py`` — the corpus in
``tests/test_analysis.py`` must then show it catching a known-bad snippet and
passing a known-good one.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict


@dataclass(frozen=True)
class Rule:
    code: str
    name: str
    summary: str


@dataclass(frozen=True)
class Finding:
    """One linter hit, formatted ``path:line:col: CODE[name] message``."""

    path: str
    line: int
    col: int
    rule: str
    message: str

    @property
    def code(self) -> str:
        return RULES[self.rule].code

    def format(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: "
                f"{self.code}[{self.rule}] {self.message}")

    def to_dict(self) -> Dict[str, object]:
        return {"path": self.path, "line": self.line, "col": self.col,
                "code": self.code, "rule": self.rule, "message": self.message}


_RULES = [
    Rule("TRC001", "tracer-branch",
         "Python `if`/`while` on a tracer-valued expression inside "
         "jit-reachable code — branches must use jnp.where / lax.cond"),
    Rule("TRC002", "tracer-bool-cast",
         "`bool()` / `assert` on a tracer-valued expression inside "
         "jit-reachable code — forces a concrete value at trace time"),
    Rule("TRC003", "tracer-host-op",
         "np.* / .item() / float() / int() on a traced value inside "
         "jit-reachable code — a hidden device→host sync per call"),
    Rule("TRC004", "trace-side-effect",
         "host-state mutation (self.* write / print) inside a jit-reachable "
         "function — runs at trace time only, silently wrong on cache hits"),
    Rule("JAX001", "dropped-at-set",
         ".at[...].set()/add()/... result discarded — jax arrays are "
         "immutable, the statement is a no-op"),
    Rule("KV001", "dict-kv-access",
         "dict-style subscript on a typed KV container "
         "(KVCache/KVStack/FusedPrefix/SlotTable) — deprecated; use "
         "attribute access"),
    Rule("KV002", "dict-kv-literal",
         "ad-hoc {'k','v','bias'} dict literal — construct fused/extra-KV "
         "entries through models/cache.FusedPrefix instead"),
    Rule("PLC001", "pallas-grid-arity",
         "BlockSpec index_map arity does not match pallas grid rank "
         "(+ num_scalar_prefetch operands)"),
    Rule("PLC002", "pallas-scalar-prefetch",
         "pallas_call invocation operand count does not match "
         "num_scalar_prefetch + in_specs"),
    Rule("PLC003", "pallas-out-shape",
         "pallas_call out_shape disagrees with out_specs (count) or an "
         "out_shape entry lacks an explicit dtype"),
    Rule("PLC004", "bare-assert-kernel",
         "bare `assert` in a kernel module — vanishes under python -O; "
         "raise ValueError (see decode_attention._check_block)"),
    Rule("OWN001", "lease-leak",
         "a PageLease / alloc'd page-id list is dropped or shadowed before "
         "reaching a sink (insert_slot/insert_suffix/register/release) — "
         "its refcounts are held forever"),
    Rule("OWN002", "lease-double-release",
         "a lease released on every path is released again — the second "
         "release underflows refcounts or frees a sharer's pages"),
    Rule("OWN003", "lease-use-after-release",
         "a lease released on every path is used afterwards — its page ids "
         "may already be reallocated to another slot"),
    Rule("OWN004", "shared-write-no-cow",
         "a lease carrying shared pages flows into a KV write "
         "(insert_slot/insert_suffix) with no allocator.cow() fault in "
         "between — the write would corrupt other holders' pages"),
    Rule("OWN005", "jit-page-mutation",
         "allocator / radix-index host state mutated from jit-reachable "
         "code — page bookkeeping under trace runs once per compile, not "
         "per call"),
    Rule("WIR001", "private-on-wire",
         "a private value (dense KV stack, raw prompt/token ids, model "
         "weights) is passed directly to a wire sink "
         "(Channel.encode/transmit) — wrap it via "
         "stack_message/token_message so the codec pipeline sees it"),
    Rule("WIR002", "message-outside-codec",
         "transport.Message constructed outside core/transport or a "
         "channel's encode/decode — ad-hoc wire messages bypass the schema "
         "and byte accounting the WireAuditor enforces"),
    Rule("WIR003", "unaccounted-wire-bytes",
         "a FederationProtocol.prepare() ships tensors but returns a "
         "PreparedRequest whose wire_bytes is missing or not derived from "
         "commload / transmit / bytes_on_wire accounting"),
    Rule("WIR004", "pipeline-drops-stage",
         "a codec Pipeline omits a stage (quant/rephrase) that a WireSchema "
         "in scope declares — the wire would carry media the protocol "
         "contract says must be transformed first"),
    Rule("WIR005", "jit-wire-sink",
         "wire sink (Channel.encode/transmit or Message construction) "
         "reachable from jit-traced code — serialization and byte "
         "accounting would run at trace time only"),
]

RULES: Dict[str, Rule] = {r.name: r for r in _RULES}
RULES_BY_CODE: Dict[str, Rule] = {r.code: r for r in _RULES}
