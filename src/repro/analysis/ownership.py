"""Lease-lifecycle ownership pass (the OWN* rules).

Tracks :class:`~repro.models.cache.PageLease` values and raw page-id lists
from their **origin** — ``allocator.lease(...)`` / ``allocator.alloc(...)``
on an allocator-like receiver — to their **sink**, enforcing linear use:
every lease must reach exactly one of ``insert_slot`` / ``insert_suffix`` /
index-``register`` / ``release`` (or escape into longer-lived state: stored
on an attribute, returned, or handed to an unknown callee, all of which
transfer the obligation out of the current function).

Per function the pass runs a branch-aware abstract interpretation over one
state record per tracked variable (live / released / sunk / cow-faulted).
Branches merge conservatively in the quiet direction — ``released`` is the
AND of the arms (use-after-release and double-release only fire when the
release happened on *every* path), ``sunk`` is the OR (a sink on any path
discharges the leak obligation) — because CI treats any finding as a
failure, so false positives are the expensive direction.

Rules emitted:

- ``lease-leak`` (OWN001): origin value dropped on the floor, shadowed by a
  rebinding, ``del``-ed, or still live at function end.
- ``lease-double-release`` (OWN002): released again after a must-release.
- ``lease-use-after-release`` (OWN003): any use after a must-release.
- ``shared-write-no-cow`` (OWN004): a lease carrying ``shared=`` pages (or a
  ``page_row`` derived from one) flows into ``insert_slot``, or into
  ``insert_suffix`` with no ``allocator.cow(lease, ...)`` fault anywhere on
  the way.
- ``jit-page-mutation`` (OWN005): allocator / radix-index mutating calls
  (``alloc``/``lease``/``share``/``retain``/``release``/``cow``,
  ``register``/``evict``/``clear``) inside jit-reachable code, reusing the
  linter's reachability walk — host-side page bookkeeping under trace runs
  once per compile, not per call.

Receivers are classified structurally, not nominally: an expression is
allocator-like when its last identifier contains ``alloc``, when it is a
local bound to ``PageAllocator(...)`` / annotated ``PageAllocator``, or when
it is ``self`` inside a class whose name contains ``Allocator`` (radix-like
analogously via ``radix`` / ``RadixPrefixIndex``).
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis.lint import FuncInfo, Project, _walk_own, qualify
from repro.analysis.rules import Finding

_ORIGIN_METHODS = {"lease", "alloc"}
_SINK_METHODS = {"insert_slot", "insert_suffix", "register"}
_VIEW_METHODS = {"page_row", "ids", "shared_ids"}
_ALLOC_MUTATORS = {"alloc", "lease", "share", "retain", "release", "cow"}
_RADIX_MUTATORS = {"register", "evict", "clear"}
_ALLOC_TYPES = {"PageAllocator", "PageSanitizer"}
_RADIX_TYPES = {"RadixPrefixIndex"}
_LEASE_TYPES = {"PageLease"}


@dataclass(frozen=True)
class _Val:
    """Abstract state of one tracked lease-holding variable."""

    line: int
    col: int
    origin: str           # "lease" | "alloc" | "param" (borrowed)
    has_shared: bool
    cowed: bool = False
    released: bool = False
    sunk: bool = False

    @property
    def live(self) -> bool:
        return not (self.sunk or self.released)


_State = Dict[str, _Val]


def check_ownership(project: Project, reachable: Set[int]) -> List[Finding]:
    """Run the OWN* rules over every parsed function."""
    findings: List[Finding] = []
    for info in project.functions.values():
        if isinstance(info.node, ast.Lambda):
            continue
        _OwnershipPass(info, findings).run()
        if id(info.node) in reachable:
            _check_jit_mutation(info, findings)
    return findings


# ------------------------------------------------------- receiver classifiers


def _tail_name(expr: ast.expr) -> Optional[str]:
    if isinstance(expr, ast.Name):
        return expr.id
    if isinstance(expr, ast.Attribute):
        return expr.attr
    return None


def _ann_tail(info: FuncInfo, ann: Optional[ast.expr]) -> Optional[str]:
    if ann is None:
        return None
    qual = qualify(info.module, ann)
    if qual is None and isinstance(ann, ast.Constant) and \
            isinstance(ann.value, str):
        qual = ann.value
    return None if qual is None else qual.rsplit(".", 1)[-1]


def _local_types(info: FuncInfo) -> Dict[str, str]:
    """Map local names to "alloc" / "radix" / "lease" where statically known
    (parameter annotations and direct constructor assignments)."""
    fn = info.node
    types: Dict[str, str] = {}
    if isinstance(fn, ast.Lambda):
        return types

    def classify(tail: Optional[str]) -> Optional[str]:
        if tail in _ALLOC_TYPES:
            return "alloc"
        if tail in _RADIX_TYPES:
            return "radix"
        if tail in _LEASE_TYPES:
            return "lease"
        return None

    for arg in (list(fn.args.posonlyargs) + list(fn.args.args) +
                list(fn.args.kwonlyargs)):
        kind = classify(_ann_tail(info, arg.annotation))
        if kind is not None:
            types[arg.arg] = kind
    for node in _walk_own(fn):
        tgt: Optional[ast.expr] = None
        val: Optional[ast.expr] = None
        if isinstance(node, ast.Assign) and len(node.targets) == 1:
            tgt, val = node.targets[0], node.value
        elif isinstance(node, ast.AnnAssign):
            tgt = node.target
            kind = classify(_ann_tail(info, node.annotation))
            if isinstance(tgt, ast.Name) and kind is not None:
                types[tgt.id] = kind
            continue
        if isinstance(tgt, ast.Name) and isinstance(val, ast.Call):
            qual = qualify(info.module, val.func)
            kind = classify(None if qual is None else qual.rsplit(".", 1)[-1])
            if kind is not None:
                types[tgt.id] = kind
    return types


def _alloc_like(info: FuncInfo, expr: ast.expr,
                types: Dict[str, str]) -> bool:
    if isinstance(expr, ast.Name) and expr.id == "self":
        return bool(info.cls) and "Allocator" in (info.cls or "")
    tail = _tail_name(expr)
    if tail is None:
        return False
    if isinstance(expr, ast.Name) and types.get(tail) == "alloc":
        return True
    return "alloc" in tail.lower()


def _radix_like(info: FuncInfo, expr: ast.expr,
                types: Dict[str, str]) -> bool:
    if isinstance(expr, ast.Name) and expr.id == "self":
        cls = info.cls or ""
        return "Radix" in cls or "PrefixIndex" in cls
    tail = _tail_name(expr)
    if tail is None:
        return False
    if isinstance(expr, ast.Name) and types.get(tail) == "radix":
        return True
    low = tail.lower()
    return "radix" in low or "prefix_index" in low


# ----------------------------------------------------------- the per-fn pass


def _merge(a: _State, b: _State) -> _State:
    out: _State = {}
    for name in set(a) | set(b):
        va, vb = a.get(name), b.get(name)
        if va is None:
            assert vb is not None
            out[name] = vb
        elif vb is None:
            out[name] = va
        else:
            out[name] = replace(va, sunk=va.sunk or vb.sunk,
                                released=va.released and vb.released,
                                cowed=va.cowed or vb.cowed)
    return out


class _OwnershipPass:
    def __init__(self, info: FuncInfo, findings: List[Finding]) -> None:
        self.info = info
        self.mod = info.module
        self.findings = findings
        self.types = _local_types(info)
        # derived handle (page_row()/ids() result) -> tracked root name
        self.derived: Dict[str, str] = {}

    def run(self) -> None:
        fn = self.info.node
        if isinstance(fn, ast.Lambda):
            return
        state: _State = {}
        for arg in (list(fn.args.posonlyargs) + list(fn.args.args) +
                    list(fn.args.kwonlyargs)):
            if self.types.get(arg.arg) == "lease":
                state[arg.arg] = _Val(arg.lineno, arg.col_offset, "param",
                                      has_shared=False)
        state = self._block(fn.body, state)
        captured = self._captured_names(fn)
        for name, val in state.items():
            if val.origin == "param" or val.sunk or val.released:
                continue
            if name in captured:
                continue  # closed over by a nested def — obligation escapes
            self._emit(val.line, val.col, "lease-leak",
                       f"lease bound to `{name}` never reaches a sink "
                       "(insert_slot/insert_suffix/register/release) — its "
                       "page refcounts are held forever")

    def _emit(self, line: int, col: int, rule: str, message: str) -> None:
        self.findings.append(Finding(self.mod.path, line, col, rule, message))

    def _captured_names(self, fn: ast.AST) -> Set[str]:
        names: Set[str] = set()
        for node in ast.walk(fn):
            if node is fn or not isinstance(
                    node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
                continue
            for inner in ast.walk(node):
                if isinstance(inner, ast.Name):
                    names.add(inner.id)
        return names

    # ------------------------------------------------------------ statements
    def _block(self, stmts: Sequence[ast.stmt], state: _State) -> _State:
        for stmt in stmts:
            state = self._stmt(stmt, state)
        return state

    def _stmt(self, stmt: ast.stmt, state: _State) -> _State:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return state  # nested scopes analyzed independently
        if isinstance(stmt, ast.If):
            state = self._expr(stmt.test, state, escape=False)
            return _merge(self._block(stmt.body, dict(state)),
                          self._block(stmt.orelse, dict(state)))
        if isinstance(stmt, (ast.For, ast.AsyncFor)):
            state = self._expr(stmt.iter, state, escape=True)
            once = self._block(list(stmt.body) + list(stmt.orelse),
                               dict(state))
            return _merge(once, state)
        if isinstance(stmt, ast.While):
            state = self._expr(stmt.test, state, escape=False)
            once = self._block(list(stmt.body) + list(stmt.orelse),
                               dict(state))
            return _merge(once, state)
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                state = self._expr(item.context_expr, state, escape=True)
            return self._block(stmt.body, state)
        if isinstance(stmt, ast.Try):
            done = self._block(list(stmt.body) + list(stmt.orelse),
                               dict(state))
            for handler in stmt.handlers:
                done = _merge(done, self._block(handler.body, dict(state)))
            return self._block(stmt.finalbody, done)
        if isinstance(stmt, ast.Delete):
            for tgt in stmt.targets:
                if isinstance(tgt, ast.Name) and tgt.id in state:
                    val = state[tgt.id]
                    if val.live and val.origin != "param":
                        self._emit(stmt.lineno, stmt.col_offset, "lease-leak",
                                   f"`del {tgt.id}` drops a live lease — "
                                   "release or sink it first")
                    state = dict(state)
                    del state[tgt.id]
            return state
        return self._flat(stmt, state)

    # ------------------------------------------------------- flat statements
    def _flat(self, stmt: ast.stmt, state: _State) -> _State:
        if isinstance(stmt, ast.Assign):
            return self._assign(stmt.targets, stmt.value, stmt, state)
        if isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            return self._assign([stmt.target], stmt.value, stmt, state)
        if isinstance(stmt, ast.Expr):
            origin = self._origin_of(stmt.value)
            if origin is not None:
                state = self._expr(stmt.value, state, escape=True,
                                   skip_origin=True)
                self._emit(stmt.lineno, stmt.col_offset, "lease-leak",
                           f"result of `.{origin}(...)` dropped on the floor"
                           " — the pages it granted can never be released")
                return state
            return self._expr(stmt.value, state, escape=True)
        if isinstance(stmt, (ast.Return, ast.Raise)):
            value = stmt.value if isinstance(stmt, ast.Return) else stmt.exc
            if value is None:
                return state
            return self._expr(value, state, escape=True)
        # AugAssign, Assert, Global, ... — process any contained expressions
        state_out = state
        for child in ast.iter_child_nodes(stmt):
            if isinstance(child, ast.expr):
                state_out = self._expr(child, state_out, escape=True)
        return state_out

    def _assign(self, targets: Sequence[ast.expr], value: ast.expr,
                stmt: ast.stmt, state: _State) -> _State:
        name_targets = [t for t in targets if isinstance(t, ast.Name)]
        store_escape = len(name_targets) != len(targets)

        origin = self._origin_of(value)
        if origin is not None:
            state = self._expr(value, state, escape=True, skip_origin=True)
            for tgt in name_targets:
                state = self._shadow_check(tgt.id, stmt, state)
                if store_escape or len(name_targets) != 1:
                    continue
                state = dict(state)
                state[tgt.id] = _Val(value.lineno, value.col_offset, origin,
                                     has_shared=self._lease_has_shared(value))
                self.derived.pop(tgt.id, None)
            # stored straight into longer-lived state (self.x = .lease(...)):
            # the obligation escapes this function — nothing to track
            return state

        root = self._view_root(value, state)
        if root is not None and len(name_targets) == 1 and not store_escape:
            tgt = name_targets[0]
            state = self._shadow_check(tgt.id, stmt, state)
            self.derived[tgt.id] = root
            return state

        if isinstance(value, ast.Name) and value.id in state and \
                len(name_targets) == 1 and not store_escape:
            # alias move: `b = a` transfers the obligation to `b`
            tgt = name_targets[0]
            state = self._shadow_check(tgt.id, stmt, state)
            state = dict(state)
            state[tgt.id] = state.pop(value.id)
            self.derived.pop(tgt.id, None)
            return state

        state = self._expr(value, state, escape=True)
        if store_escape:
            # `self.x[k] = lease` — escapes into longer-lived state
            for node in ast.walk(value):
                if isinstance(node, ast.Name) and node.id in state:
                    state = self._mark(state, node.id, sunk=True)
        for tgt in name_targets:
            state = self._shadow_check(tgt.id, stmt, state)
            if tgt.id in state:
                state = dict(state)
                del state[tgt.id]
            self.derived.pop(tgt.id, None)
        return state

    def _shadow_check(self, name: str, stmt: ast.stmt,
                      state: _State) -> _State:
        val = state.get(name)
        if val is not None and val.live and val.origin != "param":
            self._emit(stmt.lineno, stmt.col_offset, "lease-leak",
                       f"rebinding `{name}` shadows a live lease from line "
                       f"{val.line} before it reached a sink")
            state = self._mark(state, name, sunk=True)
        return state

    # ------------------------------------------------------------ expressions
    def _expr(self, expr: ast.expr, state: _State, *, escape: bool,
              skip_origin: bool = False) -> _State:
        handled: Set[int] = set()
        release_args: Set[int] = set()
        calls = [n for n in ast.walk(expr) if isinstance(n, ast.Call)]
        plans: List[Tuple[str, ast.Call]] = []
        for call in calls:
            kind = self._classify_call(call, state)
            plans.append((kind, call))
            if kind == "release":
                for node in self._release_arg_names(call):
                    release_args.add(id(node))
                    handled.add(id(node))
            elif kind in ("sink", "cow"):
                for arg in call.args:
                    for node in ast.walk(arg):
                        if isinstance(node, ast.Name):
                            handled.add(id(node))
            elif kind == "view":
                func = call.func
                if isinstance(func, ast.Attribute) and \
                        isinstance(func.value, ast.Name):
                    handled.add(id(func.value))
            elif kind == "origin" and skip_origin:
                handled.add(id(call))

        # use-after-release: any load of a must-released name
        for node in ast.walk(expr):
            if isinstance(node, ast.Name) and id(node) not in release_args \
                    and isinstance(node.ctx, ast.Load):
                root = self._root_of(node.id, state)
                if root is not None and state[root].released:
                    self._emit(node.lineno, node.col_offset,
                               "lease-use-after-release",
                               f"`{node.id}` used after its lease was "
                               "released — the pages may already belong to "
                               "another slot")
                    state = self._mark(state, root, released=False, sunk=True)

        for kind, call in plans:
            state = self._apply_call(kind, call, state)

        if escape:
            parents = _parent_map(expr)
            for node in ast.walk(expr):
                if not (isinstance(node, ast.Name) and
                        isinstance(node.ctx, ast.Load)):
                    continue
                if id(node) in handled or node.id not in state:
                    continue
                if not _consuming_position(parents, node):
                    continue
                state = self._mark(state, node.id, sunk=True)
        return state

    def _apply_call(self, kind: str, call: ast.Call,
                    state: _State) -> _State:
        if kind == "release":
            for node in self._release_arg_names(call):
                root = self._root_of(node.id, state)
                if root is None:
                    continue
                if state[root].released:
                    self._emit(call.lineno, call.col_offset,
                               "lease-double-release",
                               f"`{node.id}` released again — already "
                               "released on every path to this point")
                else:
                    state = self._mark(state, root, released=True, sunk=True)
        elif kind == "cow":
            if call.args and isinstance(call.args[0], ast.Name):
                root = self._root_of(call.args[0].id, state)
                if root is not None:
                    state = self._mark(state, root, cowed=True)
        elif kind == "sink":
            func = call.func
            meth = func.attr if isinstance(func, ast.Attribute) else ""
            for arg in call.args:
                root = self._arg_root(arg, state)
                if root is None:
                    continue
                val = state[root]
                if val.has_shared and meth == "insert_slot":
                    self._emit(call.lineno, call.col_offset,
                               "shared-write-no-cow",
                               "a lease carrying shared pages flows into "
                               "insert_slot — a full-slot write hits every "
                               "shared holder's pages; prefill only the "
                               "suffix (insert_suffix after cow)")
                elif val.has_shared and not val.cowed and \
                        meth == "insert_suffix":
                    self._emit(call.lineno, call.col_offset,
                               "shared-write-no-cow",
                               "a shared lease flows into insert_suffix "
                               "with no allocator.cow() fault in between — "
                               "a partial-page write would corrupt the "
                               "sharers' KV")
                state = self._mark(state, root, sunk=True)
        return state

    # -------------------------------------------------------------- helpers
    def _classify_call(self, call: ast.Call, state: _State) -> str:
        func = call.func
        if not isinstance(func, ast.Attribute):
            return "generic"
        meth = func.attr
        recv = func.value
        if meth in ("release", "cow") and \
                _alloc_like(self.info, recv, self.types):
            return meth if meth == "cow" else "release"
        if self._origin_of(call) is not None:
            return "origin"
        if meth in _SINK_METHODS:
            return "sink"
        if meth in _VIEW_METHODS and isinstance(recv, ast.Name) and \
                self._root_of(recv.id, state) is not None:
            return "view"
        return "generic"

    def _origin_of(self, expr: ast.expr) -> Optional[str]:
        if not (isinstance(expr, ast.Call) and
                isinstance(expr.func, ast.Attribute)):
            return None
        meth = expr.func.attr
        if meth in _ORIGIN_METHODS and \
                _alloc_like(self.info, expr.func.value, self.types):
            return meth
        return None

    @staticmethod
    def _lease_has_shared(call: ast.Call) -> bool:
        for kw in call.keywords:
            if kw.arg != "shared":
                continue
            if isinstance(kw.value, (ast.Tuple, ast.List)) and \
                    not kw.value.elts:
                return False
            if isinstance(kw.value, ast.Constant) and not kw.value.value:
                return False
            return True
        return False

    def _release_arg_names(self, call: ast.Call) -> List[ast.Name]:
        out: List[ast.Name] = []
        for arg in call.args:
            if isinstance(arg, ast.Name):
                out.append(arg)
            elif isinstance(arg, (ast.Tuple, ast.List)):
                out.extend(e for e in arg.elts if isinstance(e, ast.Name))
            elif isinstance(arg, ast.Call) and \
                    isinstance(arg.func, ast.Attribute) and \
                    arg.func.attr in _VIEW_METHODS and \
                    isinstance(arg.func.value, ast.Name):
                out.append(arg.func.value)
        return out

    def _root_of(self, name: str, state: _State) -> Optional[str]:
        root = self.derived.get(name, name)
        return root if root in state else None

    def _view_root(self, value: ast.expr, state: _State) -> Optional[str]:
        """Tracked root behind a derived-view RHS (``lease.page_row(...)``)."""
        if isinstance(value, ast.Call) and \
                isinstance(value.func, ast.Attribute) and \
                value.func.attr in _VIEW_METHODS and \
                isinstance(value.func.value, ast.Name):
            return self._root_of(value.func.value.id, state)
        return None

    def _arg_root(self, arg: ast.expr, state: _State) -> Optional[str]:
        if isinstance(arg, ast.Name):
            return self._root_of(arg.id, state)
        if isinstance(arg, ast.Call) and \
                isinstance(arg.func, ast.Attribute) and \
                arg.func.attr in _VIEW_METHODS and \
                isinstance(arg.func.value, ast.Name):
            return self._root_of(arg.func.value.id, state)
        return None

    def _mark(self, state: _State, name: str, **changes: bool) -> _State:
        state = dict(state)
        state[name] = replace(state[name], **changes)
        return state


def _parent_map(expr: ast.expr) -> Dict[int, ast.AST]:
    out: Dict[int, ast.AST] = {}
    for node in ast.walk(expr):
        for child in ast.iter_child_nodes(node):
            out[id(child)] = node
    return out


def _consuming_position(parents: Dict[int, ast.AST],
                        node: ast.Name) -> bool:
    """True when a bare load of ``node`` hands the value somewhere it could
    outlive the current frame (call arg, container literal, return value…).
    Attribute reads, comparisons and subscript bases are neutral — they use
    the lease without transferring the release obligation."""
    parent = parents.get(id(node))
    if isinstance(parent, ast.Attribute) and parent.value is node:
        return False
    if isinstance(parent, (ast.Compare, ast.BoolOp, ast.UnaryOp)):
        return False
    if isinstance(parent, ast.Subscript) and parent.value is node:
        return False
    if isinstance(parent, ast.IfExp) and parent.test is node:
        return False
    return True


# ------------------------------------------------------------ OWN005 checker


def _check_jit_mutation(info: FuncInfo, findings: List[Finding]) -> None:
    types = _local_types(info)
    for node in _walk_own(info.node):
        if not (isinstance(node, ast.Call) and
                isinstance(node.func, ast.Attribute)):
            continue
        meth = node.func.attr
        recv = node.func.value
        if meth in _ALLOC_MUTATORS and _alloc_like(info, recv, types):
            what = "allocator"
        elif meth in _RADIX_MUTATORS and _radix_like(info, recv, types):
            what = "radix index"
        else:
            continue
        findings.append(Finding(
            info.module.path, node.lineno, node.col_offset,
            "jit-page-mutation",
            f"`.{meth}()` mutates {what} host state inside jit-reachable "
            "code — it runs at trace time only; do page bookkeeping on the "
            "host side of the step"))
