"""AST linter over jit-reachable call graphs (the trace-discipline pass).

Pipeline:

1. Parse every ``*.py`` under the given paths into :class:`Module` records
   (AST + per-module import alias map).
2. Find the **jit roots**: functions decorated ``@jax.jit`` /
   ``@functools.partial(jax.jit, ...)``, functions passed to ``jax.jit(...)``
   call sites (including the factory pattern ``jax.jit(self._make_x())`` —
   every ``def`` nested in the factory is a root), and Pallas kernel bodies
   passed to ``pl.pallas_call``.
3. Walk the call graph from the roots: module-local calls, ``mod.fn`` calls
   through import aliases, ``Class.method``, and — over-approximating, which
   is the safe direction for reachability — ``obj.method(...)`` against every
   parsed class that defines ``method``. Nested ``def``s inherit reachability.
4. Run a per-function **taint analysis** on each reachable function: values
   produced by ``jnp.*``/``jax.*``/``pl.*`` calls are tracer-valued; taint
   propagates through arithmetic, indexing and assignment, and is *dropped*
   by static attributes (``.shape``/``.ndim``/``.dtype``/``.size``) and
   ``is``/``is not`` comparisons. Tracer rules (TRC*) fire on tainted sinks.
5. Structural rules (KV*, PLC*, JAX001) run everywhere, reachable or not.

The analysis is deliberately under-approximate for taint (function parameters
are NOT assumed traced) so the linter stays quiet on correct code — CI treats
any finding as a failure, so false positives are the expensive direction.
Suppress an intentional hit with a ``# lint: allow(<rule-name>)`` comment on
the finding's line or the line above.
"""
from __future__ import annotations

import ast
import os
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple, Union

from repro.analysis.rules import RULES, Finding

FuncNode = Union[ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda]

# attributes of a traced value that are static python objects (reading them
# never leaks a tracer to the host)
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "itemsize", "sharding",
                 "aval", "weak_type"}
# typed KV containers whose dict-style __getitem__ is deprecated (KV001)
_KV_TYPES = {"KVCache", "KVStack", "FusedPrefix", "SlotTable", "QuantizedKV"}
_KV_KEYS = {"k", "v", "bias", "pos", "layers", "slot_pos"}
# .at[...].<method> results that are pure (dropping them is always a bug)
_AT_METHODS = {"set", "add", "multiply", "mul", "divide", "div", "power",
               "min", "max", "get", "apply"}
# host-library roots whose calls on traced values force a device→host sync
_HOST_MODULES = ("numpy", "math")
# device-library roots whose call results are tracer-valued in traced code
_DEVICE_PREFIXES = ("jax", "jax.numpy", "jax.lax", "jax.nn", "jax.random",
                    "jax.experimental.pallas")


# --------------------------------------------------------------- module model


@dataclass
class Module:
    path: str
    name: str                      # dotted module name (best effort)
    tree: ast.Module
    lines: List[str]
    aliases: Dict[str, str] = field(default_factory=dict)


@dataclass
class FuncInfo:
    module: Module
    qualname: str                  # e.g. "ContinuousBatchingEngine._make_decode.decode"
    node: FuncNode
    parent: Optional["FuncInfo"] = None
    cls: Optional[str] = None      # enclosing class name, if a method


class Project:
    """Parsed modules + function/method indices + call-graph resolution."""

    def __init__(self) -> None:
        self.modules: List[Module] = []
        # (module name, qualname) -> FuncInfo
        self.functions: Dict[Tuple[str, str], FuncInfo] = {}
        # bare method name -> every class method with that name (over-approx)
        self.methods: Dict[str, List[FuncInfo]] = {}
        # module name -> {top-level function name -> FuncInfo}
        self.toplevel: Dict[str, Dict[str, FuncInfo]] = {}

    def add_module(self, mod: Module) -> None:
        self.modules.append(mod)
        self.toplevel.setdefault(mod.name, {})
        self._index(mod, mod.tree, prefix="", cls=None, parent=None)

    def _index(self, mod: Module, node: ast.AST, prefix: str,
               cls: Optional[str], parent: Optional[FuncInfo]) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qual = f"{prefix}{child.name}"
                info = FuncInfo(mod, qual, child, parent=parent, cls=cls)
                self.functions[(mod.name, qual)] = info
                if cls is not None and parent is None:
                    self.methods.setdefault(child.name, []).append(info)
                if cls is None and parent is None:
                    self.toplevel[mod.name][child.name] = info
                self._index(mod, child, prefix=f"{qual}.", cls=None,
                            parent=info)
            elif isinstance(child, ast.ClassDef):
                self._index(mod, child, prefix=f"{prefix}{child.name}.",
                            cls=child.name, parent=parent)
            else:
                self._index(mod, child, prefix=prefix, cls=cls, parent=parent)

    # -------------------------------------------------------- name resolution
    def resolve_call(self, mod: Module, func: ast.expr,
                     scope: Optional[FuncInfo]) -> List[FuncInfo]:
        """Best-effort resolution of a call target to parsed functions."""
        if isinstance(func, ast.Name):
            # nested function in an enclosing scope, else module-level, else
            # an imported `from repro.x import f`
            hit = self._resolve_name(mod, func.id, scope)
            return [hit] if hit is not None else []
        if isinstance(func, ast.Attribute):
            base_qual = qualify(mod, func.value)
            if base_qual is not None:
                # module alias: T.decode_step
                tl = self.toplevel.get(base_qual)
                if tl and func.attr in tl:
                    return [tl[func.attr]]
                # class attribute: FusedPrefix.ensure (class local or imported)
                cls_name = base_qual.rsplit(".", 1)[-1]
                hits = [m for m in self.methods.get(func.attr, [])
                        if m.cls == cls_name]
                if hits:
                    return hits
            # obj.method(...): over-approximate across every parsed class
            return list(self.methods.get(func.attr, []))
        return []

    def _resolve_name(self, mod: Module, name: str,
                      scope: Optional[FuncInfo]) -> Optional[FuncInfo]:
        s = scope
        while s is not None:
            hit = self.functions.get((mod.name, f"{s.qualname}.{name}"))
            if hit is not None:
                return hit
            s = s.parent
        hit = self.toplevel.get(mod.name, {}).get(name)
        if hit is not None:
            return hit
        target = mod.aliases.get(name)
        if target and "." in target:
            tmod, tname = target.rsplit(".", 1)
            return self.toplevel.get(tmod, {}).get(tname)
        return None


def qualify(mod: Module, node: ast.expr) -> Optional[str]:
    """Dotted name of an expression through the module's import aliases
    (``jnp.sum`` -> ``jax.numpy.sum``), or None for non-name expressions."""
    if isinstance(node, ast.Name):
        return mod.aliases.get(node.id, node.id)
    if isinstance(node, ast.Attribute):
        base = qualify(mod, node.value)
        return None if base is None else f"{base}.{node.attr}"
    return None


def _collect_aliases(mod: Module) -> None:
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                mod.aliases[a.asname or a.name.split(".")[0]] = (
                    a.name if a.asname else a.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom) and node.module and \
                node.level == 0:
            for a in node.names:
                if a.name == "*":
                    continue
                mod.aliases[a.asname or a.name] = f"{node.module}.{a.name}"


def _module_name(path: str) -> str:
    parts = os.path.normpath(path).split(os.sep)
    if "src" in parts:
        parts = parts[parts.index("src") + 1:]
    name = ".".join(parts)
    return name[:-3] if name.endswith(".py") else name


def load_project(paths: Sequence[str]) -> Tuple[Project, List[str]]:
    """Parse every python file under ``paths``; returns (project, errors)."""
    project = Project()
    errors: List[str] = []
    for fname in sorted(_iter_files(paths)):
        try:
            with open(fname, "r", encoding="utf-8") as fh:
                source = fh.read()
            tree = ast.parse(source, filename=fname)
        except (OSError, SyntaxError) as exc:
            errors.append(f"{fname}: {exc}")
            continue
        mod = Module(path=fname, name=_module_name(fname), tree=tree,
                     lines=source.splitlines())
        _collect_aliases(mod)
        project.add_module(mod)
    return project, errors


def _iter_files(paths: Sequence[str]) -> Iterable[str]:
    for p in paths:
        if os.path.isfile(p):
            if p.endswith(".py"):
                yield p
            continue
        for root, dirs, files in os.walk(p):
            dirs[:] = [d for d in dirs
                       if d != "__pycache__" and not d.startswith(".")]
            for f in files:
                if f.endswith(".py"):
                    yield os.path.join(root, f)


# ----------------------------------------------------------------- jit roots


def _is_jit_expr(mod: Module, node: ast.expr) -> bool:
    """True for ``jax.jit`` or ``functools.partial(jax.jit, ...)``."""
    if qualify(mod, node) == "jax.jit":
        return True
    if isinstance(node, ast.Call) and \
            qualify(mod, node.func) in ("functools.partial", "partial") and \
            node.args and qualify(mod, node.args[0]) == "jax.jit":
        return True
    return False


def collect_jit_roots(project: Project) -> Set[int]:
    """ids() of FuncNodes that are jit entry points or pallas kernels."""
    roots: Set[int] = set()
    for mod in project.modules:
        scopes = _scope_map(mod, project)
        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if any(_is_jit_expr(mod, d) for d in node.decorator_list):
                    roots.add(id(node))
            if not isinstance(node, ast.Call):
                continue
            qual = qualify(mod, node.func)
            if _is_jit_expr(mod, node.func) and node.args:
                _mark_jit_arg(project, mod, node.args[0],
                              scopes.get(id(node)), roots)
            elif qual is not None and qual.endswith("pallas_call") and \
                    node.args:
                _mark_callable(project, mod, node.args[0],
                               scopes.get(id(node)), roots, factories=False)
    return roots


def _mark_jit_arg(project: Project, mod: Module, arg: ast.expr,
                  scope: Optional[FuncInfo], roots: Set[int]) -> None:
    if isinstance(arg, ast.Lambda):
        roots.add(id(arg))
        _seed_lambda_calls(project, mod, arg, scope, roots)
        return
    if isinstance(arg, ast.Call):
        # factory pattern: jax.jit(make_step(...)) — the returned closure is
        # whatever `def`s the factory nests; mark them all
        for target in project.resolve_call(mod, arg.func, scope):
            for inner in ast.walk(target.node):
                if isinstance(inner, (ast.FunctionDef, ast.Lambda)) and \
                        inner is not target.node:
                    roots.add(id(inner))
        return
    _mark_callable(project, mod, arg, scope, roots, factories=False)


def _mark_callable(project: Project, mod: Module, arg: ast.expr,
                   scope: Optional[FuncInfo], roots: Set[int],
                   *, factories: bool) -> None:
    del factories
    if isinstance(arg, ast.Lambda):
        roots.add(id(arg))
        _seed_lambda_calls(project, mod, arg, scope, roots)
        return
    if isinstance(arg, ast.Call):  # functools.partial(_kernel, ...)
        if arg.args:
            _mark_callable(project, mod, arg.args[0], scope, roots,
                           factories=False)
        return
    for target in project.resolve_call(mod, arg, scope):
        roots.add(id(target.node))


def _seed_lambda_calls(project: Project, mod: Module, lam: ast.Lambda,
                       scope: Optional[FuncInfo], roots: Set[int]) -> None:
    """A jit root lambda's body is the traced program — every function it
    calls is a trace-time callee, so mark those as roots too."""
    for node in ast.walk(lam.body):
        if isinstance(node, ast.Call):
            for target in project.resolve_call(mod, node.func, scope):
                roots.add(id(target.node))


def _scope_map(mod: Module, project: Project) -> Dict[int, FuncInfo]:
    """Map every AST node id to its innermost enclosing FuncInfo."""
    out: Dict[int, FuncInfo] = {}

    def visit(node: ast.AST, scope: Optional[FuncInfo]) -> None:
        for child in ast.iter_child_nodes(node):
            child_scope = scope
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                info = _lookup_info(project, mod, child)
                child_scope = info if info is not None else scope
            if scope is not None:
                out[id(child)] = scope
            visit(child, child_scope)

    visit(mod.tree, None)
    return out


def _lookup_info(project: Project, mod: Module,
                 node: ast.AST) -> Optional[FuncInfo]:
    for info in project.functions.values():
        if info.module is mod and info.node is node:
            return info
    return None


# -------------------------------------------------------------- reachability


def compute_reachable(project: Project, roots: Set[int]) -> Set[int]:
    """ids() of every FuncNode reachable from the jit roots (call graph +
    nested defs + lax control-flow callables)."""
    infos = list(project.functions.values())
    by_id = {id(i.node): i for i in infos}
    reachable: Set[int] = set()
    work: List[FuncInfo] = [i for i in infos if id(i.node) in roots]
    # lambdas marked as roots are bodies of their enclosing function; treat
    # the enclosing function's scope as reachable for rule purposes via the
    # lambda set returned separately (lambda bodies are expressions only).
    while work:
        info = work.pop()
        if id(info.node) in reachable:
            continue
        reachable.add(id(info.node))
        mod = info.module
        for node in ast.walk(info.node):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) and \
                    node is not info.node:
                nested = by_id.get(id(node))
                if nested is not None and id(nested.node) not in reachable:
                    work.append(nested)
            if not isinstance(node, ast.Call):
                continue
            for target in project.resolve_call(mod, node.func, info):
                if id(target.node) not in reachable:
                    work.append(target)
            # callables handed to control-flow/transform combinators
            qual = qualify(mod, node.func) or ""
            if qual.startswith(("jax.lax.", "jax.checkpoint", "jax.vmap",
                                "jax.grad", "jax.value_and_grad", "jax.remat",
                                "jax.tree")):
                for arg in node.args:
                    if isinstance(arg, ast.Name):
                        for target in project.resolve_call(mod, arg, info):
                            if id(target.node) not in reachable:
                                work.append(target)
    return reachable


# ------------------------------------------------------------ taint analysis


class _Taint:
    """Forward may-taint over one function body (fixpoint over loops)."""

    def __init__(self, mod: Module, fn: FuncNode) -> None:
        self.mod = mod
        self.fn = fn
        self.tainted: Set[str] = set()

    def run(self) -> None:
        body = self.fn.body if not isinstance(self.fn, ast.Lambda) else []
        for _ in range(5):
            before = set(self.tainted)
            for stmt in body:
                self._stmt(stmt)
            if self.tainted == before:
                break

    # -- statements
    def _stmt(self, node: ast.stmt) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return  # nested scopes analyzed independently
        if isinstance(node, ast.Assign):
            t = self.is_tainted(node.value)
            for tgt in node.targets:
                self._bind(tgt, t)
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            self._bind(node.target, self.is_tainted(node.value))
        elif isinstance(node, ast.AugAssign):
            t = self.is_tainted(node.value) or self.is_tainted(node.target)
            self._bind(node.target, t)
        elif isinstance(node, (ast.For, ast.AsyncFor)):
            self._bind(node.target, self._iter_tainted(node.iter))
            for s in node.body + node.orelse:
                self._stmt(s)
        elif isinstance(node, (ast.While, ast.If)):
            for s in node.body + node.orelse:
                self._stmt(s)
        elif isinstance(node, (ast.With, ast.AsyncWith)):
            for item in node.items:
                if item.optional_vars is not None:
                    self._bind(item.optional_vars,
                               self.is_tainted(item.context_expr))
            for s in node.body:
                self._stmt(s)
        elif isinstance(node, ast.Try):
            for s in node.body + node.orelse + node.finalbody:
                self._stmt(s)
            for h in node.handlers:
                for s in h.body:
                    self._stmt(s)

    def _bind(self, target: ast.expr, tainted: bool) -> None:
        if isinstance(target, ast.Name):
            if tainted:
                self.tainted.add(target.id)
            else:
                self.tainted.discard(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                self._bind(elt, tainted)
        elif isinstance(target, ast.Starred):
            self._bind(target.value, tainted)

    def _iter_tainted(self, it: ast.expr) -> bool:
        if isinstance(it, ast.Call):
            qual = qualify(self.mod, it.func)
            if qual in ("enumerate", "zip", "reversed", "sorted"):
                return any(self.is_tainted(a) for a in it.args)
            if qual == "range":
                return False
        return self.is_tainted(it)

    # -- expressions
    def is_tainted(self, node: Optional[ast.expr]) -> bool:
        if node is None:
            return False
        if isinstance(node, ast.Name):
            return node.id in self.tainted
        if isinstance(node, ast.Call):
            return self._call_tainted(node)
        if isinstance(node, ast.Attribute):
            if node.attr in _STATIC_ATTRS:
                return False
            return self.is_tainted(node.value)
        if isinstance(node, ast.Subscript):
            return self.is_tainted(node.value) or self.is_tainted(node.slice)
        if isinstance(node, ast.BinOp):
            return self.is_tainted(node.left) or self.is_tainted(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.is_tainted(node.operand)
        if isinstance(node, ast.Compare):
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
                return False  # identity tests are static under jit
            return self.is_tainted(node.left) or \
                any(self.is_tainted(c) for c in node.comparators)
        if isinstance(node, ast.BoolOp):
            return any(self.is_tainted(v) for v in node.values)
        if isinstance(node, ast.IfExp):
            return self.is_tainted(node.body) or self.is_tainted(node.orelse)
        if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
            return any(self.is_tainted(e) for e in node.elts)
        if isinstance(node, ast.Dict):
            return any(self.is_tainted(v) for v in node.values)
        if isinstance(node, ast.Starred):
            return self.is_tainted(node.value)
        if isinstance(node, (ast.ListComp, ast.GeneratorExp, ast.SetComp)):
            return any(self._iter_tainted(g.iter) for g in node.generators) \
                or self.is_tainted(node.elt)
        if isinstance(node, ast.Slice):
            return any(self.is_tainted(e)
                       for e in (node.lower, node.upper, node.step))
        return False

    def _call_tainted(self, node: ast.Call) -> bool:
        qual = qualify(self.mod, node.func)
        if qual is not None:
            if qual in ("int", "float", "bool", "len", "isinstance", "print",
                        "repr", "str", "type", "max", "min", "range"):
                return False  # host result (the sink rules flag the bad ones)
            root = qual.split(".")[0]
            if qual.startswith(_DEVICE_PREFIXES) or root in ("jnp", "pl",
                                                             "pltpu"):
                return True
        # method call on a tainted value (x.astype(...), x.sum(), ...)
        if isinstance(node.func, ast.Attribute) and \
                self.is_tainted(node.func.value):
            return True
        # unknown callee: propagate through arguments (may-taint)
        return any(self.is_tainted(a) for a in node.args) or \
            any(self.is_tainted(k.value) for k in node.keywords)


# ------------------------------------------------------------- rule checkers


class _Checker:
    def __init__(self, project: Project) -> None:
        self.project = project
        self.findings: List[Finding] = []

    def emit(self, mod: Module, node: ast.AST, rule: str,
             message: str) -> None:
        if rule not in RULES:
            raise KeyError(f"unknown lint rule: {rule}")
        line = getattr(node, "lineno", 0)
        col = getattr(node, "col_offset", 0)
        self.findings.append(
            Finding(mod.path, line, col, rule, message))

    # ---- tracer rules (jit-reachable functions only)
    def check_traced(self, info: FuncInfo) -> None:
        mod, fn = info.module, info.node
        taint = _Taint(mod, fn)
        taint.run()
        for node in _walk_own(fn):
            if isinstance(node, (ast.If, ast.While)) and \
                    taint.is_tainted(node.test):
                kind = "if" if isinstance(node, ast.If) else "while"
                self.emit(mod, node, "tracer-branch",
                          f"python `{kind}` on a traced value; use jnp.where"
                          " / lax.cond / lax.while_loop")
            elif isinstance(node, ast.Assert):
                if taint.is_tainted(node.test):
                    self.emit(mod, node, "tracer-bool-cast",
                              "`assert` on a traced value concretizes the "
                              "tracer at trace time")
            elif isinstance(node, (ast.Assign, ast.AugAssign)):
                targets = node.targets if isinstance(node, ast.Assign) \
                    else [node.target]
                for tgt in targets:
                    if _writes_self(tgt):
                        self.emit(mod, node, "trace-side-effect",
                                  "write to self.* inside jit-reachable code"
                                  " runs once per trace, not per call")
                        break
            elif isinstance(node, ast.Call):
                self._check_traced_call(mod, taint, node)

    def _check_traced_call(self, mod: Module, taint: _Taint,
                           node: ast.Call) -> None:
        qual = qualify(mod, node.func)
        args_tainted = any(taint.is_tainted(a) for a in node.args)
        if qual == "bool" and args_tainted:
            self.emit(mod, node, "tracer-bool-cast",
                      "`bool()` on a traced value")
        elif qual in ("float", "int") and args_tainted:
            self.emit(mod, node, "tracer-host-op",
                      f"`{qual}()` on a traced value forces a device→host "
                      "sync (use .astype or keep it on device)")
        elif qual == "print":
            self.emit(mod, node, "trace-side-effect",
                      "`print` inside jit-reachable code fires at trace time"
                      " only; use jax.debug.print")
        elif qual is not None and \
                qual.split(".")[0] in _HOST_MODULES and args_tainted:
            self.emit(mod, node, "tracer-host-op",
                      f"host op `{qual}` on a traced value; use the jnp "
                      "equivalent")
        elif isinstance(node.func, ast.Attribute) and \
                node.func.attr in ("item", "tolist", "__array__") and \
                taint.is_tainted(node.func.value):
            self.emit(mod, node, "tracer-host-op",
                      f"`.{node.func.attr}()` on a traced value is a hidden "
                      "device→host sync")

    # ---- structural rules (whole tree)
    def check_module(self, mod: Module) -> None:
        kernel_module = "kernels" in mod.path.split(os.sep)
        scopes = _scope_map(mod, self.project)
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Expr) and _is_dropped_at_update(
                    node.value):
                self.emit(mod, node, "dropped-at-set",
                          ".at[...] update result is discarded — jax arrays "
                          "are immutable, bind or return the new array")
            elif isinstance(node, ast.Dict):
                keys = {k.value for k in node.keys
                        if isinstance(k, ast.Constant) and
                        isinstance(k.value, str)}
                if {"k", "v", "bias"} <= keys:
                    self.emit(mod, node, "dict-kv-literal",
                              "build {'k','v','bias'} entries via "
                              "models/cache.FusedPrefix, not ad-hoc dicts")
            elif isinstance(node, ast.Assert) and kernel_module:
                self.emit(mod, node, "bare-assert-kernel",
                          "bare assert in a kernel module vanishes under "
                          "python -O; raise ValueError instead")
            elif isinstance(node, ast.Call):
                qual = qualify(mod, node.func) or ""
                if qual.endswith("pallas_call"):
                    self._check_pallas(mod, node, scopes.get(id(node)))
        self._check_kv_subscripts(mod)

    def _check_kv_subscripts(self, mod: Module) -> None:
        """KV001: dict-style subscripts on values known to be typed
        containers (constructor calls, classmethods, annotations)."""
        for (mname, _), info in self.project.functions.items():
            fn = info.node
            if mname != mod.name or isinstance(fn, ast.Lambda):
                continue
            typed = _typed_kv_vars(mod, fn)
            for node in _walk_own(fn):
                if not (isinstance(node, ast.Subscript) and
                        isinstance(node.slice, ast.Constant) and
                        isinstance(node.slice.value, str) and
                        node.slice.value in _KV_KEYS):
                    continue
                base = node.value
                name = base.id if isinstance(base, ast.Name) else None
                is_typed = (name is not None and name in typed) or \
                    _is_kv_producer(mod, base)
                if is_typed and not _in_store_context(node):
                    self.emit(mod, node, "dict-kv-access",
                              f"dict-style access [{node.slice.value!r}] on "
                              "a typed KV container is deprecated; use "
                              f".{node.slice.value}")

    # ---- pallas contracts
    def _check_pallas(self, mod: Module, call: ast.Call,
                      scope: Optional[FuncInfo]) -> None:
        env = _local_env(scope.node) if scope is not None else {}
        kw = {k.arg: k.value for k in call.keywords if k.arg}
        grid_expr = _resolve(env, kw.get("grid"))
        in_specs_expr = kw.get("in_specs")
        out_specs_expr = kw.get("out_specs")
        out_shape_expr = _resolve(env, kw.get("out_shape"))
        n_prefetch = 0
        gspec = _resolve(env, kw.get("grid_spec"))
        if isinstance(gspec, ast.Call):
            gkw = {k.arg: k.value for k in gspec.keywords if k.arg}
            grid_expr = _resolve(env, gkw.get("grid", grid_expr))
            in_specs_expr = gkw.get("in_specs", in_specs_expr)
            out_specs_expr = gkw.get("out_specs", out_specs_expr)
            npf = _resolve(env, gkw.get("num_scalar_prefetch"))
            if isinstance(npf, ast.Constant) and isinstance(npf.value, int):
                n_prefetch = npf.value
        rank = None
        if isinstance(grid_expr, (ast.Tuple, ast.List)):
            rank = len(grid_expr.elts)
        elif isinstance(grid_expr, ast.Constant) and \
                isinstance(grid_expr.value, int):
            rank = 1
        in_specs, n_in = _collect_specs(env, in_specs_expr)
        out_specs, n_out = _collect_specs(env, out_specs_expr)
        if rank is not None:
            want = rank + n_prefetch
            for spec in in_specs + out_specs:
                arity = _index_map_arity(env, spec)
                if arity is not None and arity != want:
                    self.emit(mod, spec, "pallas-grid-arity",
                              f"index_map takes {arity} args but grid rank "
                              f"{rank} + num_scalar_prefetch {n_prefetch} "
                              f"= {want} are passed")
        # PLC002: inline invocation operand count
        parent_call = getattr(call, "_repro_parent_call", None)
        if parent_call is not None and n_in is not None:
            n_args = len(parent_call.args)
            if not any(isinstance(a, ast.Starred) for a in parent_call.args) \
                    and n_args != n_prefetch + n_in:
                self.emit(mod, parent_call, "pallas-scalar-prefetch",
                          f"pallas_call invoked with {n_args} operands but "
                          f"num_scalar_prefetch {n_prefetch} + "
                          f"len(in_specs) {n_in} = {n_prefetch + n_in} "
                          "expected")
        # PLC003: out_shape structure + dtype agreement
        if out_shape_expr is not None:
            shapes = out_shape_expr.elts if isinstance(
                out_shape_expr, (ast.Tuple, ast.List)) else [out_shape_expr]
            if n_out is not None and isinstance(
                    out_shape_expr, (ast.Tuple, ast.List)) and \
                    len(shapes) != n_out:
                self.emit(mod, out_shape_expr, "pallas-out-shape",
                          f"out_shape has {len(shapes)} entries but "
                          f"out_specs has {n_out}")
            for s in shapes:
                s = _resolve(env, s)
                if isinstance(s, ast.Call):
                    squal = qualify(mod, s.func) or ""
                    skw = {k.arg for k in s.keywords}
                    if squal.endswith("ShapeDtypeStruct") and \
                            len(s.args) < 2 and "dtype" not in skw:
                        self.emit(mod, s, "pallas-out-shape",
                                  "ShapeDtypeStruct without an explicit "
                                  "dtype — out dtype must be pinned to the "
                                  "ref kernel's")


def _walk_own(fn: FuncNode) -> Iterable[ast.AST]:
    """ast.walk limited to this function's own body (skips nested defs,
    which are analyzed as their own scopes)."""
    stack: List[ast.AST] = list(ast.iter_child_nodes(fn))
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            continue
        stack.extend(ast.iter_child_nodes(node))


def _writes_self(target: ast.expr) -> bool:
    node: ast.expr = target
    while isinstance(node, (ast.Subscript, ast.Attribute)):
        node = node.value
        if isinstance(node, ast.Attribute) and \
                isinstance(node.value, ast.Name) and node.value.id == "self":
            return True
    return isinstance(target, ast.Attribute) and \
        isinstance(target.value, ast.Name) and target.value.id == "self"


def _is_dropped_at_update(expr: ast.expr) -> bool:
    if not (isinstance(expr, ast.Call) and
            isinstance(expr.func, ast.Attribute) and
            expr.func.attr in _AT_METHODS):
        return False
    node = expr.func.value
    while isinstance(node, (ast.Subscript, ast.Attribute)):
        if isinstance(node, ast.Subscript) and \
                isinstance(node.value, ast.Attribute) and \
                node.value.attr == "at":
            return True
        node = node.value
    return False


def _typed_kv_vars(mod: Module, fn: Union[ast.FunctionDef,
                                          ast.AsyncFunctionDef]) -> Set[str]:
    typed: Set[str] = set()
    for arg in (list(fn.args.posonlyargs) + list(fn.args.args) +
                list(fn.args.kwonlyargs)):
        if arg.annotation is not None and \
                _annotation_kv_type(mod, arg.annotation):
            typed.add(arg.arg)
    for node in _walk_own(fn):
        if isinstance(node, ast.Assign) and _is_kv_producer(mod, node.value):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    typed.add(tgt.id)
        elif isinstance(node, ast.AnnAssign) and \
                isinstance(node.target, ast.Name) and \
                _annotation_kv_type(mod, node.annotation):
            typed.add(node.target.id)
    return typed


def _annotation_kv_type(mod: Module, ann: ast.expr) -> bool:
    qual = qualify(mod, ann)
    if qual is None and isinstance(ann, ast.Constant) and \
            isinstance(ann.value, str):
        qual = ann.value
    return qual is not None and qual.rsplit(".", 1)[-1] in _KV_TYPES


def _is_kv_producer(mod: Module, expr: ast.expr) -> bool:
    """Calls whose result is a typed KV container: constructors and their
    classmethods (FusedPrefix(...), KVCache.init(...), .ensure(...))."""
    if not isinstance(expr, ast.Call):
        return False
    qual = qualify(mod, expr.func)
    if qual is None:
        return False
    parts = qual.rsplit(".", 2)
    if parts[-1] in _KV_TYPES:
        return True
    return len(parts) >= 2 and parts[-2].rsplit(".", 1)[-1] in _KV_TYPES


def _in_store_context(node: ast.Subscript) -> bool:
    return isinstance(node.ctx, (ast.Store, ast.Del))


def _local_env(fn: FuncNode) -> Dict[str, ast.expr]:
    env: Dict[str, ast.expr] = {}
    if isinstance(fn, ast.Lambda):
        return env
    for node in _walk_own(fn):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 and \
                isinstance(node.targets[0], ast.Name):
            env[node.targets[0].id] = node.value
        elif isinstance(node, ast.AnnAssign) and \
                isinstance(node.target, ast.Name) and node.value is not None:
            env[node.target.id] = node.value
    return env


def _resolve(env: Dict[str, ast.expr],
             expr: Optional[ast.expr]) -> Optional[ast.expr]:
    seen = 0
    while isinstance(expr, ast.Name) and expr.id in env and seen < 4:
        expr = env[expr.id]
        seen += 1
    return expr


def _collect_specs(env: Dict[str, ast.expr], expr: Optional[ast.expr],
                   ) -> Tuple[List[ast.Call], Optional[int]]:
    """Flatten an in_specs/out_specs expression into the BlockSpec calls it
    mentions plus the total element count (None when not statically known).
    Handles list literals, Name aliases, `a + b`, and `[spec] * n`."""
    expr = _resolve(env, expr)
    if expr is None:
        return [], None
    if isinstance(expr, (ast.List, ast.Tuple)):
        specs: List[ast.Call] = []
        total: Optional[int] = 0
        for elt in expr.elts:
            sub, n = _collect_specs(env, elt)
            specs.extend(sub)
            total = None if (total is None or n is None) else total + n
        return specs, total
    if isinstance(expr, ast.BinOp) and isinstance(expr.op, ast.Add):
        left, nl = _collect_specs(env, expr.left)
        right, nr = _collect_specs(env, expr.right)
        n = None if (nl is None or nr is None) else nl + nr
        return left + right, n
    if isinstance(expr, ast.BinOp) and isinstance(expr.op, ast.Mult):
        base, nb = _collect_specs(env, expr.left)
        mult = _resolve(env, expr.right)
        if isinstance(mult, ast.Constant) and isinstance(mult.value, int) \
                and nb is not None:
            return base, nb * mult.value
        return base, None
    if isinstance(expr, ast.Call):
        return [expr], 1
    return [], None


def _index_map_arity(env: Dict[str, ast.expr],
                     spec: ast.Call) -> Optional[int]:
    imap: Optional[ast.expr] = None
    if len(spec.args) >= 2:
        imap = spec.args[1]
    for k in spec.keywords:
        if k.arg == "index_map":
            imap = k.value
    imap = _resolve(env, imap)
    if isinstance(imap, ast.Lambda):
        a = imap.args
        return len(a.posonlyargs) + len(a.args)
    return None


# -------------------------------------------------------------- entry point


def _suppressions(mod: Module) -> Dict[int, Set[str]]:
    import re
    out: Dict[int, Set[str]] = {}
    pat = re.compile(r"lint:\s*allow\(([a-z0-9_,\s-]+)\)")
    for i, line in enumerate(mod.lines, start=1):
        m = pat.search(line)
        if m:
            names = {n.strip() for n in m.group(1).split(",") if n.strip()}
            out[i] = names
    return out


def _mark_parent_calls(mod: Module) -> None:
    """Tag each pallas_call Call with its immediate invocation
    (``pl.pallas_call(...)(operands)``) for the PLC002 operand check."""
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Call):
            qual = qualify(mod, node.func.func) or ""
            if qual.endswith("pallas_call"):
                setattr(node.func, "_repro_parent_call", node)


def _collect_findings(paths: Sequence[str]) -> Tuple[Project, List[Finding]]:
    """Run every pass over ``paths``; returns raw (pre-suppression) findings."""
    # local imports: ownership + wire reuse this module's project/reachability
    from repro.analysis.ownership import check_ownership
    from repro.analysis.wire import check_wire

    project, errors = load_project(paths)
    checker = _Checker(project)
    for err in errors:
        path, _, msg = err.partition(": ")
        checker.findings.append(Finding(path, 0, 0, "tracer-branch",
                                        f"parse error: {msg}"))
    roots = collect_jit_roots(project)
    reachable = compute_reachable(project, roots)
    for mod in project.modules:
        _mark_parent_calls(mod)
        checker.check_module(mod)
    for info in project.functions.values():
        if id(info.node) in reachable:
            checker.check_traced(info)
    checker.findings.extend(check_ownership(project, reachable))
    checker.findings.extend(check_wire(project, reachable))
    return project, checker.findings


def lint_paths(paths: Sequence[str]) -> List[Finding]:
    """Lint every python file under ``paths``; returns sorted findings."""
    project, raw = _collect_findings(paths)
    out: List[Finding] = []
    for f in raw:
        mod = next((m for m in project.modules if m.path == f.path), None)
        if mod is not None:
            sup = _suppressions(mod)
            allowed = sup.get(f.line, set()) | sup.get(f.line - 1, set())
            if f.rule in allowed or "all" in allowed:
                continue
        out.append(f)
    out.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return out


@dataclass(frozen=True)
class StaleSuppression:
    """A ``# lint: allow(...)`` comment whose rule no longer fires there."""

    path: str
    line: int
    rule: str
    reason: str

    def format(self) -> str:
        return f"{self.path}:{self.line}: stale `lint: allow({self.rule})` " \
               f"— {self.reason}"


def audit_suppressions(paths: Sequence[str]) -> List[StaleSuppression]:
    """Find ``# lint: allow(...)`` comments that no longer suppress anything.

    A suppression at line L covers findings at L and L+1; it is stale when no
    raw finding of its rule lands in that window (or when it names a rule the
    registry does not know, which a rename would silently orphan)."""
    project, raw = _collect_findings(paths)
    by_module: Dict[str, Dict[int, Set[str]]] = {}
    for f in raw:
        by_module.setdefault(f.path, {}).setdefault(f.line, set()).add(f.rule)
    stale: List[StaleSuppression] = []
    for mod in project.modules:
        fired = by_module.get(mod.path, {})
        for line, names in sorted(_suppressions(mod).items()):
            window = fired.get(line, set()) | fired.get(line + 1, set())
            for name in sorted(names):
                if name != "all" and name not in RULES:
                    stale.append(StaleSuppression(
                        mod.path, line, name, "unknown rule name"))
                    continue
                hit = bool(window) if name == "all" else name in window
                if not hit:
                    stale.append(StaleSuppression(
                        mod.path, line, name,
                        "the rule no longer fires on this line"))
    return stale
