"""Repo-specific correctness tooling: trace-discipline linting + retrace guard.

Two enforcement layers for the invariants the serving stack's performance
story rests on (one decode trace forever, one prefill trace per bucket, no
host syncs on the hot loop, Pallas BlockSpec contracts):

- :mod:`repro.analysis.lint` — an AST linter over jit-reachable call graphs
  (``python -m repro.analysis [paths]``); rules in :mod:`repro.analysis.rules`.
- :mod:`repro.analysis.traceguard` — :class:`TraceGuard`, a context manager /
  pytest fixture that hooks jit lowering and turns the engine's informal
  trace-count stats into hard assertions.
"""
from repro.analysis.rules import Finding, RULES
from repro.analysis.lint import lint_paths
from repro.analysis.traceguard import TraceGuard, TraceGuardError

__all__ = ["Finding", "RULES", "lint_paths", "TraceGuard", "TraceGuardError"]
