"""Repo-specific correctness tooling: trace-discipline linting, a page-lease
ownership pass, a runtime allocator sanitizer, and a retrace guard.

Enforcement layers for the invariants the serving stack's performance story
rests on (one decode trace forever, one prefill trace per bucket, no host
syncs on the hot loop, Pallas BlockSpec contracts, linear page-lease
lifecycles):

- :mod:`repro.analysis.lint` — an AST linter over jit-reachable call graphs
  (``python -m repro.analysis [paths]``); rules in :mod:`repro.analysis.rules`;
  ``--audit-suppressions`` flags stale ``# lint: allow(...)`` comments.
- :mod:`repro.analysis.ownership` — dataflow pass (OWN001–OWN005, runs inside
  ``lint_paths``) tracking every :class:`~repro.models.cache.PageLease` from
  origin to sink: leaks, double-release, use-after-release, shared writes
  without CoW, allocator mutation inside jit-reachable code.
- :mod:`repro.analysis.wire` — wire-contract & privacy dataflow pass
  (WIR001–WIR005, runs inside ``lint_paths``): statically proves no private
  value (dense KV stacks, raw prompt/token ids, checkpoint weights) reaches
  the federation wire outside the sanctioned codec path, that every
  ``prepare()`` byte-accounts what it ships, and that codec pipelines carry
  every stage their :class:`~repro.core.protocol.WireSchema` declares.
- :mod:`repro.analysis.wire_audit` — :class:`WireAuditor`, the runtime twin:
  a wrapping :class:`~repro.core.transport.Channel` that verifies every
  encoded message against the protocol's WireSchema (media, dtypes, stages,
  commload byte accounting, QoS byte budget) with call-site provenance;
  ``FedRefineSystem.build(..., audit_wire=True)`` threads it in.
- :mod:`repro.analysis.sarif` — SARIF 2.1.0 serialisation of findings
  (``python -m repro.analysis --sarif``), uploaded by CI as an artifact.
- :mod:`repro.analysis.sanitizer` — :class:`PageSanitizer`, a drop-in
  :class:`~repro.models.cache.PageAllocator` with per-page shadow holders and
  grant-site provenance; the engine's ``sanitize=True`` mode feeds it every
  write and validates device state each step.
- :mod:`repro.analysis.traceguard` — :class:`TraceGuard`, a context manager /
  pytest fixture that hooks jit lowering and turns the engine's informal
  trace-count stats into hard assertions.
"""
from repro.analysis.rules import Finding, RULES
from repro.analysis.lint import (StaleSuppression, audit_suppressions,
                                 lint_paths)
from repro.analysis.sanitizer import PageSanitizer, SanitizerError
from repro.analysis.traceguard import TraceGuard, TraceGuardError
from repro.analysis.wire_audit import (WireAuditError, WireAuditor,
                                       WireRecord)

__all__ = ["Finding", "RULES", "lint_paths", "audit_suppressions",
           "StaleSuppression", "PageSanitizer", "SanitizerError",
           "TraceGuard", "TraceGuardError", "WireAuditor", "WireAuditError",
           "WireRecord"]
