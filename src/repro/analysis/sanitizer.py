"""Runtime page-lifecycle sanitizer: the ASan/TSan analogue for the paged
serving stack.

:class:`PageSanitizer` is a drop-in :class:`~repro.models.cache.PageAllocator`
(a subclass — same refcounts, same free list, same public surface) that
additionally keeps **shadow state** per page: every holder (lease, prefix-pin
or raw grant) with alloc-site provenance — slot id, request id, fused digest
and a stack summary of the call that granted it — plus a **generation stamp**
bumped on every noted device write. The engine
(``ContinuousBatchingEngine(..., sanitize=True)``) reports each write it is
about to issue (:meth:`note_write`) and hands over its device state after
every step (:meth:`check_step`), so a violation surfaces at the step that
causes it, named by the grant that created the page's holder — not hundreds
of steps later as silently corrupted tokens.

Violations raised as :class:`SanitizerError` (with provenance):

- release of a lease never granted, or granted and already released
  (double-release, naming both the grant site and the first release site);
- raw page-id release of a page only leases map — freeing it would corrupt a
  live slot (the evict-while-shared bug class);
- a noted write to a page the writer does not hold, holds only **shared**
  (a missing ``cow()`` fault — reported with the page's generation stamps),
  or that another lease also holds;
- after a step: allocator refcounts diverging from shadow holders, device
  page-map rows diverging from the slot's lease, an inactive slot still
  mapping pages, two active slots mapping one page writably, or a mapped
  page with refcount zero.

:meth:`leak_report` (called by the engine at ``drain()``) lists leases and
raw grants that never reached a release — each named by its grant site.

Zero-cost when off: with ``sanitize=False`` no sanitizer object exists and
every engine hook is a single ``is not None`` test on a dead branch.
"""
from __future__ import annotations

import os
import traceback
from collections import OrderedDict
from dataclasses import dataclass
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple, \
    Union

import numpy as np

from repro.models.cache import PageAllocator, PageLease


class SanitizerError(AssertionError):
    """A page-lifecycle invariant was violated at runtime."""


def _call_site(depth: int = 2) -> str:
    """Innermost ``depth`` stack frames outside this module — the grant's
    provenance trail (``engine.py:636 _admit <- engine.py:681 step``)."""
    frames: List[str] = []
    for fr in reversed(traceback.extract_stack()):
        fname = fr.filename.replace(os.sep, "/")
        if fname.endswith("analysis/sanitizer.py"):
            continue
        frames.append(f"{os.path.basename(fr.filename)}:{fr.lineno} "
                      f"{fr.name}")
        if len(frames) == depth:
            break
    return " <- ".join(frames) if frames else "<unknown>"


@dataclass
class Provenance:
    """Where (and on whose behalf) a page holder was created. Mutable so the
    engine can enrich a lease's record (:meth:`PageSanitizer.annotate`) after
    issuance — every holder of the lease shares this one object."""

    kind: str                    # "lease" | "pin" | "raw"
    site: str                    # stack summary at grant time
    slot: Optional[int] = None
    rid: Optional[int] = None
    digest: Optional[str] = None

    def describe(self) -> str:
        bits = [self.kind]
        if self.slot is not None:
            bits.append(f"slot={self.slot}")
        if self.rid is not None:
            bits.append(f"rid={self.rid}")
        if self.digest:
            bits.append(f"digest={self.digest[:12]}")
        bits.append(f"@ {self.site}")
        return " ".join(bits)


@dataclass
class _Holder:
    """One reference to one page in the shadow state."""

    key: int                     # id(lease) for leases, unique token otherwise
    kind: str                    # "lease" | "pin" | "raw"
    owned: bool                  # may this holder write the page?
    prov: Provenance
    gen_at_grant: int            # page generation when the hold began

    def describe(self) -> str:
        mode = "owned" if self.owned else "shared"
        return f"{mode} by {self.prov.describe()}"


@dataclass
class _LeaseState:
    lease: PageLease             # strong ref: a leaked lease must stay
    prov: Provenance             # inspectable for the leak report


class PageSanitizer(PageAllocator):
    """A :class:`PageAllocator` that cross-checks every grant and release
    against per-page shadow state and validates the engine's device view.

    Construct it in place of the allocator (``PageSanitizer(num_pages)``);
    the engine does so under ``sanitize=True``. Base-class code paths that
    internally call ``share``/``alloc``/``release`` (``lease``, ``cow``,
    ``retain``) run under a quiet flag so each grant is recorded exactly
    once, at the level the caller asked for."""

    _TOMBSTONES = 256  # released-lease records kept for double-free messages

    def __init__(self, num_pages: int) -> None:
        super().__init__(num_pages)
        self._quiet = 0
        self._next_token = -1
        self._page_holders: Dict[int, List[_Holder]] = {}
        self._lease_states: Dict[int, _LeaseState] = {}
        self._released: "OrderedDict[int, Tuple[_LeaseState, str]]" = \
            OrderedDict()
        self._gen = np.zeros(max(num_pages, 1), np.int64)

    # ------------------------------------------------------ shadow plumbing
    def _token(self) -> int:
        self._next_token -= 1
        return self._next_token

    def _add_holder(self, page_id: int, holder: _Holder) -> None:
        self._page_holders.setdefault(page_id, []).append(holder)

    def _remove_holder(self, page_id: int, key: int, site: str) -> None:
        holders = self._page_holders.get(page_id, [])
        hit = next((h for h in holders if h.key == key), None)
        if hit is None:
            raise SanitizerError(
                f"page {page_id} released at {site} by a holder the shadow "
                "state does not record — shadow/allocator divergence")
        holders.remove(hit)
        if not holders:
            self._page_holders.pop(page_id, None)

    def holders_of(self, page_id: int) -> List[str]:
        return [h.describe() for h in self._page_holders.get(page_id, [])]

    # --------------------------------------------------- allocator overrides
    def alloc(self, n: int) -> List[int]:
        ids = PageAllocator.alloc(self, n)
        if not self._quiet:
            prov = Provenance("raw", _call_site())
            for p in ids:
                self._add_holder(p, _Holder(self._token(), "raw", True, prov,
                                            int(self._gen[p])))
        return ids

    def share(self, page_ids: Sequence[int]) -> List[int]:
        ids = PageAllocator.share(self, page_ids)
        if not self._quiet:
            prov = Provenance("raw", _call_site())
            for p in ids:
                self._add_holder(p, _Holder(self._token(), "raw", False, prov,
                                            int(self._gen[p])))
        return ids

    def retain(self, page_id: int) -> None:
        self._quiet += 1
        try:
            PageAllocator.retain(self, page_id)
        finally:
            self._quiet -= 1
        prov = Provenance("pin", _call_site())
        self._add_holder(page_id, _Holder(self._token(), "pin", False, prov,
                                          int(self._gen[page_id])))

    def lease(self, *, shared: Sequence[int] = (),
              fresh: int = 0) -> PageLease:
        self._quiet += 1
        try:
            out = PageAllocator.lease(self, shared=shared, fresh=fresh)
        finally:
            self._quiet -= 1
        prov = Provenance("lease", _call_site())
        self._lease_states[id(out)] = _LeaseState(lease=out, prov=prov)
        for p, owned in zip(out.ids(), out.owned):
            self._add_holder(p, _Holder(id(out), "lease", bool(owned), prov,
                                        int(self._gen[p])))
        return out

    def cow(self, lease: PageLease, index: int) -> Tuple[int, int]:
        st = self._lease_states.get(id(lease))
        if st is None:
            raise SanitizerError(
                f"cow() at {_call_site()} on a lease this allocator never "
                "granted (or already released)")
        src_dst = None
        self._quiet += 1
        try:
            src_dst = PageAllocator.cow(self, lease, index)
        finally:
            self._quiet -= 1
        src, dst = src_dst
        self._remove_holder(src, id(lease), _call_site())
        self._add_holder(dst, _Holder(id(lease), "lease", True, st.prov,
                                      int(self._gen[dst])))
        return src, dst

    def release(self, pages: Union[PageLease, Sequence[int]]) -> None:
        if self._quiet:
            PageAllocator.release(self, pages)
            return
        site = _call_site()
        if isinstance(pages, PageLease):
            key = id(pages)
            st = self._lease_states.pop(key, None)
            if st is None:
                prev = self._released.get(key)
                if prev is not None:
                    raise SanitizerError(
                        f"double release of lease granted "
                        f"{prev[0].prov.describe()} — first released at "
                        f"{prev[1]}, released again at {site}")
                raise SanitizerError(
                    f"release at {site} of a lease this allocator never "
                    "granted")
            for p in pages.ids():
                self._remove_holder(p, key, site)
            PageAllocator.release(self, pages)
            self._released[key] = (st, site)
            while len(self._released) > self._TOMBSTONES:
                self._released.popitem(last=False)
            return
        ids = [int(p) for p in pages]
        for p in ids:
            holders = self._page_holders.get(p, [])
            pin = next((h for h in holders if h.kind in ("pin", "raw")), None)
            if pin is None:
                if holders:
                    who = "; ".join(h.describe() for h in holders)
                    raise SanitizerError(
                        f"raw release of page {p} at {site} — the page is "
                        f"still mapped by a live lease ({who}); dropping its "
                        "refcount would free or corrupt a sharer's KV "
                        "(evict-while-shared)")
                raise SanitizerError(
                    f"raw release of page {p} at {site} with no recorded "
                    "holder — the page was never granted (or already fully "
                    "released)")
            holders.remove(pin)
            if not holders:
                self._page_holders.pop(p, None)
        PageAllocator.release(self, ids)

    # ------------------------------------------------------------ engine API
    def annotate(self, lease: PageLease, *, slot: Optional[int] = None,
                 rid: Optional[int] = None,
                 digest: Optional[str] = None) -> None:
        """Enrich a lease's provenance with serving identity (slot / request
        id / fused digest) — every holder of the lease shares the record."""
        st = self._lease_states.get(id(lease))
        if st is None:
            raise SanitizerError(
                f"annotate() at {_call_site()} on an unknown lease")
        if slot is not None:
            st.prov.slot = slot
        if rid is not None:
            st.prov.rid = rid
        if digest is not None:
            st.prov.digest = digest

    def note_write(self, page_ids: Iterable[int],
                   lease: Optional[PageLease] = None, *,
                   what: str = "write") -> None:
        """Validate a device write the caller is about to issue into
        ``page_ids`` on behalf of ``lease``: the writer must hold every page
        **owned**, and no other lease may hold it (prefix-index pins are
        fine — registered pages are append-only past their pinned rows).
        Bumps each page's generation stamp."""
        key = None if lease is None else id(lease)
        for raw_p in page_ids:
            p = int(raw_p)
            holders = self._page_holders.get(p, [])
            mine = None if key is None else \
                next((h for h in holders if h.key == key), None)
            others = [h for h in holders
                      if h is not mine and h.kind != "pin"]
            if key is not None and mine is None:
                raise SanitizerError(
                    f"{what}: page {p} written by a lease that does not "
                    f"hold it (holders: "
                    f"{'; '.join(h.describe() for h in holders) or 'none'})")
            if mine is not None and not mine.owned:
                gen = int(self._gen[p])
                raise SanitizerError(
                    f"{what}: write to page {p} held SHARED (granted "
                    f"{mine.prov.describe()} at generation "
                    f"{mine.gen_at_grant}, now {gen}) without a cow() "
                    "fault — the write would corrupt: "
                    + ("; ".join(h.describe() for h in others)
                       or "the cached prefix"))
            if others:
                raise SanitizerError(
                    f"{what}: page {p} is also held by "
                    f"{'; '.join(h.describe() for h in others)} — "
                    "concurrent writable mapping")
            self._gen[p] += 1

    def check_step(self, page_map: np.ndarray, active: np.ndarray,
                   leases: Mapping[int, PageLease],
                   invalid_page: int) -> None:
        """Validate allocator/shadow/device agreement after an engine step:
        refcounts match shadow holders, every active slot's device page row
        is exactly its lease (INVALID-padded), inactive rows are fully
        INVALID, no mapped page is free, and no page is writable twice."""
        self.assert_consistent()
        for p in range(self.num_pages):
            shadow = len(self._page_holders.get(p, []))
            rc = self.refcount(p)
            if shadow != rc:
                who = "; ".join(self.holders_of(p)) or "none"
                raise SanitizerError(
                    f"page {p}: allocator refcount {rc} != {shadow} shadow "
                    f"holder(s) [{who}] — a grant or release bypassed the "
                    "sanitizer")
        page_map = np.asarray(page_map)
        mapped: Dict[int, List[Tuple[int, bool]]] = {}
        for s in range(page_map.shape[0]):
            row = page_map[s]
            if not bool(active[s]):
                extra = row[row != invalid_page]
                if extra.size:
                    raise SanitizerError(
                        f"inactive slot {s} still maps pages "
                        f"{[int(p) for p in extra]}")
                continue
            lease = leases.get(s)
            if lease is None:
                raise SanitizerError(f"active slot {s} has no lease")
            if id(lease) not in self._lease_states:
                raise SanitizerError(
                    f"active slot {s}'s lease is unknown to the sanitizer "
                    "(released while the slot is live?)")
            n = lease.num_pages
            if not (row[:n] == lease.page_ids).all() or \
                    (row[n:] != invalid_page).any():
                raise SanitizerError(
                    f"slot {s}: device page row {[int(p) for p in row]} "
                    f"diverges from its lease {lease.ids()}")
            for i in range(n):
                p = int(lease.page_ids[i])
                if self.refcount(p) <= 0:
                    raise SanitizerError(
                        f"slot {s} maps page {p} with refcount 0 — the page "
                        "was freed while still mapped (evict-while-shared)")
                mapped.setdefault(p, []).append((s, bool(lease.owned[i])))
        for p, slots in mapped.items():
            # one owner + read-only sharers is the normal prefix-sharing
            # shape (writes into shared pages are policed dynamically by
            # note_write); two slots both claiming ownership never is
            if sum(1 for _, owned in slots if owned) > 1:
                raise SanitizerError(
                    f"page {p} is mapped OWNED by multiple slots "
                    f"{[s for s, owned in slots if owned]} — exclusive "
                    "ownership violated (missing share/cow)")

    def leak_report(self, live: Mapping[int, PageLease] = {}) -> List[str]:
        """Grants that never reached a release, each named by its alloc
        site. ``live`` holds the engine's still-intentionally-held leases
        (in-flight slots); prefix-index pins are expected holders and are
        never reported."""
        live_keys = {id(lease) for lease in live.values()}
        report: List[str] = []
        for key, st in self._lease_states.items():
            if key in live_keys:
                continue
            report.append(
                f"leaked lease of {st.lease.num_pages} page(s) "
                f"{st.lease.ids()} granted {st.prov.describe()}")
        for p, holders in sorted(self._page_holders.items()):
            for h in holders:
                if h.kind == "raw":
                    report.append(f"outstanding raw grant of page {p} from "
                                  f"{h.prov.describe()}")
        return report

    def describe_holders(self) -> str:
        """Per-holder provenance summary (pool-exhaustion error payload)."""
        lines: List[str] = []
        for st in self._lease_states.values():
            lines.append(f"  {st.lease.num_pages} page(s) held by "
                         f"{st.prov.describe()}")
        pins = sum(1 for hs in self._page_holders.values()
                   for h in hs if h.kind == "pin")
        if pins:
            lines.append(f"  {pins} page pin(s) held by the prefix index")
        raws = sum(1 for hs in self._page_holders.values()
                   for h in hs if h.kind == "raw")
        if raws:
            lines.append(f"  {raws} raw page grant(s)")
        return "\n".join(lines)
