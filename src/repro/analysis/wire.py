"""Wire-contract & privacy dataflow pass (the WIR* rules).

Statically proves that nothing private reaches the federation wire: the
paper's promise is that heterogeneous LLMs collaborate by communicating KV
caches *privacy-preservingly*, so raw prompt token ids, dense
KVCache/KVStack tensors, slot-table pool pages, and checkpoint weights are
**private sources**, and ``Channel.encode`` / ``Channel.transmit`` /
``Message`` construction / ``FederationProtocol.prepare()`` are the **only
sanctioned wire sinks**. Reuses lint.py's :class:`Project` call-graph and
jit-reachability, and ownership.py's structural receiver classification
(an expression is channel-like when its name tail contains
wire/channel/pipeline/codec, when it is bound to a ``*Channel(...)`` /
``Pipeline(...)`` constructor or annotation, or when it is ``self`` inside
a ``*Channel``/``*Pipeline`` class).

Rules emitted (runs inside ``lint_paths``; suppressions / JSON / SARIF /
``--audit-suppressions`` come with the linter):

- ``private-on-wire`` (WIR001): a private value is passed *directly* to a
  channel-like ``.encode()`` / ``.transmit()`` — the sanctioned path wraps
  it via ``stack_message`` / ``token_message`` so the codec pipeline (and
  the WireAuditor's schema check) sees it as a typed ``Message``.
- ``message-outside-codec`` (WIR002): ``transport.Message`` constructed
  outside ``core/transport.py`` or a channel's ``encode``/``decode`` —
  ad-hoc messages bypass schema verification and byte accounting.
- ``unaccounted-wire-bytes`` (WIR003): a ``FederationProtocol`` subclass's
  ``prepare()`` ships tensors (a transmit call, or a fused prefix in the
  returned ``PreparedRequest``) without a ``wire_bytes=`` derived from
  ``commload`` / ``.transmit()`` / ``.bytes_on_wire()`` accounting.
- ``pipeline-drops-stage`` (WIR004): a codec ``Pipeline([...])`` literal
  omits a stage a :class:`~repro.core.protocol.WireSchema` in the same
  module declares (e.g. the schema says ``stages=("quant",)`` but the
  pipeline has no quant codec).
- ``jit-wire-sink`` (WIR005): a wire sink reachable from jit-traced code —
  encode/serialize at trace time runs once per compile, not per request,
  and its byte accounting silently freezes.

Like the ownership pass, the analysis is biased in the quiet direction (CI
treats any finding as failure): privacy is claimed only for values whose
provenance is statically known (KV-typed annotations, ``export_stack`` /
``dense_view`` / ``dequantize_stack`` / KV-constructor results, or
names that read as prompt/token/weight media), and is *dropped* once a
value passes a sanitioning producer (``quantize_stack``, ``rephrase``,
``stack_message`` / ``token_message`` wrapping, a codec ``encode``).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.lint import (FuncInfo, Module, Project, _walk_own,
                                 qualify)
from repro.analysis.rules import Finding

#: modules that ARE the wire layer: sources/sinks defined there are the
#: sanctioned implementation, not leaks
_WIRE_LAYER_MODULES = ("repro.core.transport", "repro.analysis.wire_audit")

_WIRE_SINK_METHODS = {"encode", "transmit"}
_CHANNEL_TYPES = {"Channel", "IdentityChannel", "QuantChannel",
                  "RephraseChannel", "Pipeline", "WireAuditor"}
_CHANNEL_NAME_HINTS = ("wire", "channel", "pipeline", "codec")
_CHANNEL_CLASS_HINTS = ("Channel", "Pipeline", "Auditor", "Codec")
_PRIVATE_KV_TYPES = {"KVCache", "KVStack", "FusedPrefix", "SlotTable"}
_PRIVATE_KV_METHODS = {"export_stack", "dense_view"}
_PRIVATE_KV_FUNCS = {"dequantize_stack"}
_SANITIZED_PRODUCERS = {"quantize_stack", "stack_message", "token_message",
                        "rephrase", "encode"}
_ACCOUNTING_METHODS = {"transmit", "transmit_stacks", "bytes_on_wire"}


def check_wire(project: Project, reachable: Set[int]) -> List[Finding]:
    """Run the WIR* rules over every parsed function/module."""
    findings: List[Finding] = []
    for mod in project.modules:
        if _wire_layer(mod):
            continue
        _check_schema_pipelines(mod, findings)
        _check_prepare_accounting(mod, findings)
    for info in project.functions.values():
        if isinstance(info.node, ast.Lambda) or _wire_layer(info.module):
            continue
        _check_function(info, findings)
        if id(info.node) in reachable:
            _check_jit_wire(info, findings)
    return findings


def _wire_layer(mod: Module) -> bool:
    return mod.name in _WIRE_LAYER_MODULES


# ------------------------------------------------------------- classifiers


def _tail_name(expr: ast.expr) -> Optional[str]:
    if isinstance(expr, ast.Name):
        return expr.id
    if isinstance(expr, ast.Attribute):
        return expr.attr
    return None


def _ann_tail(mod: Module, ann: Optional[ast.expr]) -> Optional[str]:
    if ann is None:
        return None
    qual = qualify(mod, ann)
    if qual is None and isinstance(ann, ast.Constant) and \
            isinstance(ann.value, str):
        qual = ann.value
    return None if qual is None else qual.rsplit(".", 1)[-1]


def _call_tail(mod: Module, expr: ast.expr) -> Optional[str]:
    if not isinstance(expr, ast.Call):
        return None
    qual = qualify(mod, expr.func)
    if qual is not None:
        return qual.rsplit(".", 1)[-1]
    if isinstance(expr.func, ast.Attribute):
        return expr.func.attr
    return None


def _tokens_name(name: str) -> bool:
    low = name.lower()
    return ("prompt" in low or low == "tokens" or low.endswith("_tokens") or
            "token_id" in low)


def _weights_name(name: str) -> bool:
    low = name.lower()
    return (low in ("params", "weights", "checkpoint") or
            low.endswith(("_params", "_weights")))


def _channel_locals(info: FuncInfo) -> Set[str]:
    """Local names statically known to hold a Channel (annotation tails and
    direct constructor assignments — ownership.py's classifier shape)."""
    fn = info.node
    out: Set[str] = set()
    if isinstance(fn, ast.Lambda):
        return out
    for arg in (list(fn.args.posonlyargs) + list(fn.args.args) +
                list(fn.args.kwonlyargs)):
        if _ann_tail(info.module, arg.annotation) in _CHANNEL_TYPES:
            out.add(arg.arg)
    for node in _walk_own(fn):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 and \
                isinstance(node.targets[0], ast.Name):
            if _call_tail(info.module, node.value) in _CHANNEL_TYPES:
                out.add(node.targets[0].id)
        elif isinstance(node, ast.AnnAssign) and \
                isinstance(node.target, ast.Name) and \
                _ann_tail(info.module, node.annotation) in _CHANNEL_TYPES:
            out.add(node.target.id)
    return out


def _channel_like(info: FuncInfo, expr: ast.expr,
                  channels: Set[str]) -> bool:
    if isinstance(expr, ast.Name) and expr.id == "self":
        cls = info.cls or ""
        return any(h in cls for h in _CHANNEL_CLASS_HINTS)
    tail = _tail_name(expr)
    if tail is None:
        return False
    if isinstance(expr, ast.Name) and tail in channels:
        return True
    low = tail.lower()
    return any(h in low for h in _CHANNEL_NAME_HINTS)


def _private_producer(mod: Module, expr: ast.expr) -> Optional[str]:
    """Description of the private medium ``expr`` produces, if any."""
    if not isinstance(expr, ast.Call):
        return None
    if isinstance(expr.func, ast.Attribute) and \
            expr.func.attr in _PRIVATE_KV_METHODS:
        return f"a dense KV tensor (.{expr.func.attr}() result)"
    tail = _call_tail(mod, expr)
    if tail in _PRIVATE_KV_FUNCS:
        return "a dense KV stack (dequantize_stack result)"
    if tail in _PRIVATE_KV_TYPES:
        return f"a dense {tail} tensor"
    return None


def _sanitized_producer(mod: Module, expr: ast.expr) -> bool:
    if not isinstance(expr, ast.Call):
        return False
    if isinstance(expr.func, ast.Attribute) and \
            expr.func.attr in _SANITIZED_PRODUCERS:
        return True
    return _call_tail(mod, expr) in _SANITIZED_PRODUCERS


def _private_locals(info: FuncInfo) -> Dict[str, str]:
    """Map local names to a description of the private medium they hold."""
    fn = info.node
    mod = info.module
    out: Dict[str, str] = {}
    sanitized: Set[str] = set()
    if isinstance(fn, ast.Lambda):
        return out
    for arg in (list(fn.args.posonlyargs) + list(fn.args.args) +
                list(fn.args.kwonlyargs)):
        tail = _ann_tail(mod, arg.annotation)
        if tail in _PRIVATE_KV_TYPES:
            out[arg.arg] = f"a dense {tail} tensor"
        elif _tokens_name(arg.arg):
            out[arg.arg] = "raw prompt/token ids"
        elif _weights_name(arg.arg):
            out[arg.arg] = "model weights"
    for node in _walk_own(fn):
        tgt: Optional[ast.expr] = None
        val: Optional[ast.expr] = None
        if isinstance(node, ast.Assign) and len(node.targets) == 1:
            tgt, val = node.targets[0], node.value
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            tgt, val = node.target, node.value
            tail = _ann_tail(mod, node.annotation)
            if isinstance(tgt, ast.Name) and tail in _PRIVATE_KV_TYPES:
                out[tgt.id] = f"a dense {tail} tensor"
        if not isinstance(tgt, ast.Name) or val is None:
            continue
        desc = _private_producer(mod, val)
        if desc is not None:
            out[tgt.id] = desc
        elif _sanitized_producer(mod, val):
            sanitized.add(tgt.id)
        elif _tokens_name(tgt.id):
            out.setdefault(tgt.id, "raw prompt/token ids")
        elif _weights_name(tgt.id):
            out.setdefault(tgt.id, "model weights")
    for name in sanitized:
        out.pop(name, None)
    return out


def _is_message_ctor(mod: Module, call: ast.Call) -> bool:
    qual = qualify(mod, call.func)
    return qual is not None and qual.endswith("transport.Message")


def _is_codec_method(info: FuncInfo) -> bool:
    """encode/decode defined on a class — a channel implementation, the one
    place ad-hoc Message manipulation is the sanctioned job."""
    fn = info.node
    return info.cls is not None and not isinstance(fn, ast.Lambda) and \
        fn.name in ("encode", "decode")


# -------------------------------------------------- WIR001 / WIR002 per-fn


def _check_function(info: FuncInfo, findings: List[Finding]) -> None:
    mod = info.module
    codec_method = _is_codec_method(info)
    channels = _channel_locals(info)
    private = _private_locals(info)
    for node in _walk_own(info.node):
        if not isinstance(node, ast.Call):
            continue
        if _is_message_ctor(mod, node):
            if not codec_method:
                findings.append(Finding(
                    mod.path, node.lineno, node.col_offset,
                    "message-outside-codec",
                    "transport.Message constructed outside core/transport "
                    "or a channel's encode/decode — build wire messages "
                    "via stack_message/token_message so schema and byte "
                    "accounting apply"))
            continue
        if codec_method:
            continue
        if not (isinstance(node.func, ast.Attribute) and
                node.func.attr in _WIRE_SINK_METHODS and
                _channel_like(info, node.func.value, channels)):
            continue
        sink = node.func.attr
        for arg in list(node.args) + [k.value for k in node.keywords]:
            desc: Optional[str] = None
            if isinstance(arg, ast.Name):
                desc = private.get(arg.id)
            else:
                desc = _private_producer(mod, arg)
            if desc is not None:
                findings.append(Finding(
                    mod.path, node.lineno, node.col_offset,
                    "private-on-wire",
                    f"{desc} passed directly to a wire sink "
                    f"(.{sink}()) — wrap it via stack_message/"
                    "token_message so the codec pipeline sees it"))


# --------------------------------------------------------- WIR005 (jit)


def _check_jit_wire(info: FuncInfo, findings: List[Finding]) -> None:
    mod = info.module
    channels = _channel_locals(info)
    for node in _walk_own(info.node):
        if not isinstance(node, ast.Call):
            continue
        if _is_message_ctor(mod, node):
            what = "transport.Message constructed"
        elif isinstance(node.func, ast.Attribute) and \
                node.func.attr in _WIRE_SINK_METHODS and \
                _channel_like(info, node.func.value, channels):
            what = f"channel .{node.func.attr}() called"
        else:
            continue
        findings.append(Finding(
            mod.path, node.lineno, node.col_offset, "jit-wire-sink",
            f"{what} inside jit-reachable code — wire serialization and "
            "byte accounting would run at trace time only; transmit on "
            "the host side of the step"))


# -------------------------------------------------------- WIR004 (schemas)


def _schema_decls(mod: Module) -> List[Tuple[ast.Call, str, Set[str]]]:
    out: List[Tuple[ast.Call, str, Set[str]]] = []
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        qual = qualify(mod, node.func) or ""
        if qual.rsplit(".", 1)[-1] != "WireSchema":
            continue
        kw = {k.arg: k.value for k in node.keywords if k.arg}
        proto = "?"
        proto_expr = kw.get("protocol",
                            node.args[0] if node.args else None)
        if isinstance(proto_expr, ast.Constant) and \
                isinstance(proto_expr.value, str):
            proto = proto_expr.value
        stages: Set[str] = set()
        stages_expr = kw.get("stages")
        if isinstance(stages_expr, (ast.Tuple, ast.List)):
            stages = {e.value for e in stages_expr.elts
                      if isinstance(e, ast.Constant) and
                      isinstance(e.value, str)}
        if stages:
            out.append((node, proto, stages))
    return out


def _stage_of(channel_class: str) -> str:
    low = channel_class.lower()
    if "quant" in low:
        return "quant"
    if "rephrase" in low or "paraphrase" in low:
        return "rephrase"
    if "identity" in low:
        return "identity"
    return low


def _check_schema_pipelines(mod: Module, findings: List[Finding]) -> None:
    declared = _schema_decls(mod)
    if not declared:
        return
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        qual = qualify(mod, node.func) or ""
        if qual.rsplit(".", 1)[-1] != "Pipeline" or not node.args or \
                not isinstance(node.args[0], (ast.List, ast.Tuple)):
            continue
        stages: Set[str] = set()
        for elt in node.args[0].elts:
            if isinstance(elt, ast.Call):
                tail = (qualify(mod, elt.func) or "").rsplit(".", 1)[-1]
                if not tail and isinstance(elt.func, ast.Attribute):
                    tail = elt.func.attr
                stages.add(_stage_of(tail))
        for _, proto, want in declared:
            missing = sorted(want - stages)
            if missing:
                findings.append(Finding(
                    mod.path, node.lineno, node.col_offset,
                    "pipeline-drops-stage",
                    f"Pipeline omits stage(s) {missing} declared by the "
                    f"WireSchema for protocol {proto!r} in this module — "
                    "the wire would carry media the contract says must be "
                    "transformed"))


# ------------------------------------------------------- WIR003 (prepare)


def _protocol_classes(mod: Module) -> List[ast.ClassDef]:
    out: List[ast.ClassDef] = []
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.ClassDef):
            continue
        for base in node.bases:
            qual = qualify(mod, base) or ""
            if qual.rsplit(".", 1)[-1] == "FederationProtocol":
                out.append(node)
                break
    return out


def _accounts(mod: Module, expr: ast.expr) -> bool:
    """True when ``expr`` contains byte accounting: a commload call, or a
    ``.transmit()`` / ``.transmit_stacks()`` / ``.bytes_on_wire()`` call."""
    for node in ast.walk(expr):
        if not isinstance(node, ast.Call):
            continue
        qual = qualify(mod, node.func) or ""
        if "commload" in qual.split("."):
            return True
        if isinstance(node.func, ast.Attribute) and \
                node.func.attr in _ACCOUNTING_METHODS:
            return True
    return False


def _bind_names(target: ast.expr, names: Set[str]) -> None:
    if isinstance(target, ast.Name):
        names.add(target.id)
    elif isinstance(target, (ast.Tuple, ast.List)):
        for elt in target.elts:
            _bind_names(elt, names)
    elif isinstance(target, ast.Starred):
        _bind_names(target.value, names)


def _check_prepare_accounting(mod: Module,
                              findings: List[Finding]) -> None:
    for cls in _protocol_classes(mod):
        for item in cls.body:
            if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and item.name == "prepare":
                _check_one_prepare(mod, item, findings)


def _check_one_prepare(mod: Module, fn: ast.FunctionDef,
                       findings: List[Finding]) -> None:
    accounted: Set[str] = set()
    transmits = False
    prep_binds: Dict[str, ast.Call] = {}
    returned: List[ast.Call] = []
    for node in _walk_own(fn):
        if isinstance(node, ast.Assign):
            if _accounts(mod, node.value):
                for tgt in node.targets:
                    _bind_names(tgt, accounted)
            if len(node.targets) == 1 and \
                    isinstance(node.targets[0], ast.Name) and \
                    _is_prepared_call(mod, node.value):
                prep_binds[node.targets[0].id] = node.value  # type: ignore[index]
        elif isinstance(node, ast.AugAssign):
            if _accounts(mod, node.value) and \
                    isinstance(node.target, ast.Name):
                accounted.add(node.target.id)
        if isinstance(node, ast.Call) and \
                isinstance(node.func, ast.Attribute) and \
                node.func.attr in ("transmit", "transmit_stacks"):
            transmits = True
    for node in _walk_own(fn):
        if not isinstance(node, ast.Return) or node.value is None:
            continue
        val = node.value
        if _is_prepared_call(mod, val):
            returned.append(val)  # type: ignore[arg-type]
        elif isinstance(val, ast.Name) and val.id in prep_binds:
            returned.append(prep_binds[val.id])
    for call in returned:
        kw = {k.arg: k.value for k in call.keywords if k.arg}
        fused = kw.get("fused")
        ships = transmits or (
            fused is not None and not (isinstance(fused, ast.Constant) and
                                       fused.value is None))
        if not ships:
            continue
        wb = kw.get("wire_bytes")
        ok = wb is not None and (
            _accounts(mod, wb) or
            any(isinstance(n, ast.Name) and n.id in accounted
                for n in ast.walk(wb)))
        if not ok:
            findings.append(Finding(
                mod.path, call.lineno, call.col_offset,
                "unaccounted-wire-bytes",
                "prepare() ships tensors but the returned PreparedRequest "
                "has no wire_bytes derived from commload / transmit / "
                "bytes_on_wire accounting — the link model would charge "
                "zero for this request"))


def _is_prepared_call(mod: Module, expr: ast.expr) -> bool:
    if not isinstance(expr, ast.Call):
        return False
    qual = qualify(mod, expr.func) or ""
    return qual.rsplit(".", 1)[-1] == "PreparedRequest"
