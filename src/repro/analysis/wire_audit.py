"""Runtime wire auditor: the dynamic twin of the WIR* static pass.

:class:`WireAuditor` is a wrapping :class:`~repro.core.transport.Channel`
(mirroring :class:`~repro.analysis.sanitizer.PageSanitizer`'s drop-in
pattern): it delegates encode/decode/byte accounting to the real wire
channel, and verifies every **encoded** message against the per-protocol
:class:`~repro.core.protocol.WireSchema` declared in
``core/protocol.py``'s registry:

- **media**: a dense stack / raw tokens may cross the link only if the
  protocol's schema lists that medium;
- **dtypes**: no int64/uint64/float64 or object payloads ever
  (:data:`~repro.core.protocol.FORBIDDEN_WIRE_DTYPES`), and a dense stack
  must ship at one of the schema's ``stack_dtypes`` (so a schema declaring
  ``{"int8"}`` rejects dense bf16 KV on an identity wire);
- **stages**: a schema declaring the ``"quant"`` stage rejects any message
  still carrying a dense stack after encode — the codec dropped the stage;
- **bytes**: measured ``bytes_on_wire`` is cross-checked against the
  commload estimate (:meth:`WireSchema.estimate_wire_bytes`, or an explicit
  ``expect(estimate=...)``) within the schema's declared tolerance, and
  against the request's QoS byte budget (:meth:`set_budget`).

Violations raise :class:`WireAuditError` naming the producing call site
(stack summary, sanitizer-style); every violation is also retained for
:meth:`report`, and every clean transmission is recorded with provenance
in :attr:`records` — the engine-bench audited smoke gates an empty report
plus a non-zero record count.

``FedRefineSystem.build(..., audit_wire=True)`` threads an auditor in as
the system wire; ``transmit_stacks`` announces each message's protocol via
:meth:`expect` before transmitting. Zero-cost when off: without
``audit_wire`` no auditor exists and the wire is untouched.
"""
from __future__ import annotations

import dataclasses
import os
import traceback
from dataclasses import dataclass
from typing import Any, Dict, List, Mapping, Optional, Tuple

import jax

from repro.core import transport as TR
from repro.core.protocol import (FORBIDDEN_WIRE_DTYPES, WIRE_SCHEMAS,
                                 WireSchema)


class WireAuditError(AssertionError):
    """A wire-contract invariant was violated at runtime."""


def _call_site(depth: int = 3) -> str:
    """Innermost ``depth`` stack frames outside this module and the
    transport layer — the transmission's provenance trail."""
    frames: List[str] = []
    for fr in reversed(traceback.extract_stack()):
        fname = fr.filename.replace(os.sep, "/")
        if fname.endswith(("analysis/wire_audit.py", "core/transport.py")):
            continue
        frames.append(f"{os.path.basename(fr.filename)}:{fr.lineno} "
                      f"{fr.name}")
        if len(frames) == depth:
            break
    return " <- ".join(frames) if frames else "<unknown>"


def _dtype_name(leaf: Any) -> Optional[str]:
    dtype = getattr(leaf, "dtype", None)
    return None if dtype is None else str(dtype)


def derive_schemas(wire: TR.Channel) -> Dict[str, WireSchema]:
    """Default :data:`WIRE_SCHEMAS` adapted to the wire's actual codec
    composition: a wire containing a :class:`~repro.core.transport.
    QuantChannel` (directly or inside a Pipeline) declares the ``"quant"``
    stage on the C2C schema (so byte estimates use the int8 layout and a
    dense stack on the wire becomes a violation); a RephraseChannel
    declares ``"rephrase"``. Pass explicit ``schemas=`` to override."""
    stages: List[str] = []

    def walk(ch: TR.Channel) -> None:
        if isinstance(ch, TR.Pipeline):
            for sub in ch.channels:
                walk(sub)
        elif isinstance(ch, TR.QuantChannel):
            stages.append("quant")
        elif isinstance(ch, TR.RephraseChannel):
            stages.append("rephrase")

    walk(wire)
    schemas = dict(WIRE_SCHEMAS)
    if stages:
        schemas["c2c"] = dataclasses.replace(
            schemas["c2c"], stages=tuple(stages))
        if "rephrase" in stages:
            schemas["t2t"] = dataclasses.replace(
                schemas["t2t"], stages=("rephrase",))
    return schemas


@dataclass(frozen=True)
class WireRecord:
    """Provenance of one audited transmission."""

    protocol: str
    site: str
    media: Tuple[str, ...]        # media of the *pre-encode* message
    measured_bytes: int
    estimated_bytes: int

    def describe(self) -> str:
        return (f"{self.protocol} message ({'+'.join(self.media) or 'empty'}"
                f") {self.measured_bytes} B on wire "
                f"(estimate {self.estimated_bytes} B) @ {self.site}")


class WireAuditor(TR.Channel):
    """A wire :class:`~repro.core.transport.Channel` that verifies every
    encoded message against the protocol's declared :class:`WireSchema`.

    Wraps the real channel (``WireAuditor(QuantChannel())``); the default
    inner channel is the identity wire, matching ``FedRefineSystem``'s
    default. Announce each message's protocol (and optionally an explicit
    commload estimate) with :meth:`expect` before transmitting — the
    context is sticky until the next :meth:`expect`."""

    def __init__(self, inner: Optional[TR.Channel] = None, *,
                 schemas: Optional[Mapping[str, WireSchema]] = None) -> None:
        self.inner: TR.Channel = inner if inner is not None \
            else TR.IdentityChannel()
        self.schemas: Dict[str, WireSchema] = (
            derive_schemas(self.inner) if schemas is None else dict(schemas))
        self.records: List[WireRecord] = []
        self._violations: List[str] = []
        self._protocol: Optional[str] = None
        self._estimate: Optional[int] = None
        self._budget: Optional[int] = None

    # ------------------------------------------------------------- context
    def expect(self, protocol: str, *, estimate: Optional[int] = None
               ) -> None:
        """Declare the protocol (and optionally a commload byte estimate)
        of the next transmission(s). Sticky until the next call."""
        if protocol not in self.schemas:
            raise WireAuditError(
                f"expect({protocol!r}) at {_call_site()}: no WireSchema "
                f"registered for this protocol (have "
                f"{sorted(self.schemas)})")
        self._protocol = protocol
        self._estimate = estimate

    def set_budget(self, max_bytes: Optional[int]) -> None:
        """Per-request QoS byte ceiling (e.g. link bandwidth x latency
        budget); ``None`` clears it."""
        self._budget = max_bytes

    def report(self) -> List[str]:
        """All violations seen so far (empty on a clean run)."""
        return list(self._violations)

    # ------------------------------------------------------- channel duty
    def encode(self, msg: TR.Message) -> TR.Message:
        wire = self.inner.encode(msg)
        self._verify(msg, wire)
        return wire

    def decode(self, msg: TR.Message) -> TR.Message:
        return self.inner.decode(msg)

    def bytes_on_wire(self, msg: TR.Message) -> int:
        return self.inner.bytes_on_wire(msg)

    # ---------------------------------------------------------- the audit
    def _fail(self, protocol: str, message: str) -> None:
        detail = (f"wire audit [{protocol}]: {message} "
                  f"(produced at {_call_site()})")
        self._violations.append(detail)
        raise WireAuditError(detail)

    def _verify(self, pre: TR.Message, wire: TR.Message) -> None:
        proto = self._protocol
        if proto is None:
            self._fail("?", "message encoded with no expect() context — "
                       "the producing protocol is unknown, so no schema "
                       "can be enforced")
            return
        schema = self.schemas[proto]
        # media
        if wire.stack is not None and "stack" not in schema.media:
            self._fail(proto, "a KV stack is on the wire but the schema "
                       f"allows media {sorted(schema.media)}")
        if wire.tokens is not None and "tokens" not in schema.media:
            self._fail(proto, "raw token ids are on the wire but the "
                       f"schema allows media {sorted(schema.media)}")
        if wire.payload and not schema.media:
            self._fail(proto, "codec payload on a wire whose schema "
                       "declares no media at all")
        # dtypes — every array leaf of the encoded message
        for leaf in jax.tree_util.tree_leaves(wire):
            name = _dtype_name(leaf)
            if name is None or name == "object":
                self._fail(proto, f"non-tensor payload {type(leaf).__name__}"
                           " on the wire")
            elif name in FORBIDDEN_WIRE_DTYPES:
                self._fail(proto, f"forbidden wire dtype {name} "
                           f"(never allowed: {sorted(FORBIDDEN_WIRE_DTYPES)})")
        if wire.stack is not None and schema.stack_dtypes:
            name = _dtype_name(wire.stack.k) or "?"
            if name not in schema.stack_dtypes:
                self._fail(proto, f"dense stack ships at dtype {name} but "
                           f"the schema declares {sorted(schema.stack_dtypes)}")
        # declared codec stages
        if "quant" in schema.stages and wire.stack is not None:
            self._fail(proto, "schema declares the 'quant' stage but the "
                       "encoded message still carries a dense stack — the "
                       "codec pipeline dropped the quantization stage")
        # byte accounting
        measured = self.inner.bytes_on_wire(wire)
        estimate = self._estimate if self._estimate is not None \
            else schema.estimate_wire_bytes(pre)
        tol = schema.tolerance
        if abs(measured - estimate) > tol * max(estimate, 1):
            self._fail(proto, f"measured bytes_on_wire {measured} drifts "
                       f"from the commload estimate {estimate} past the "
                       f"declared tolerance {tol:g}")
        for ceiling, what in ((schema.max_message_bytes, "schema"),
                              (self._budget, "QoS budget")):
            if ceiling is not None and measured > ceiling:
                self._fail(proto, f"message is {measured} B on the wire, "
                           f"over the {what} ceiling of {ceiling} B")
        media = tuple(m for m, v in (("stack", pre.stack),
                                     ("tokens", pre.tokens)) if v is not None)
        self.records.append(WireRecord(
            protocol=proto, site=_call_site(), media=media,
            measured_bytes=int(measured), estimated_bytes=int(estimate)))
