"""TraceGuard: hard retrace assertions for jitted serving-stack functions.

The engine's trace-count stats (``stats["decode_traces"]``) are
hand-incremented inside the traced bodies — informative, but nothing fails
when a new protocol combination sneaks in a retrace. :class:`TraceGuard`
hooks the one chokepoint every jit trace passes through
(``jax._src.interpreters.partial_eval.trace_to_jaxpr_dynamic``) and raises
:class:`TraceGuardError` — with the offending avals and every aval set seen
before — the moment a watched function traces more often than its budget.

Usage::

    with TraceGuard(max_traces={"decode": 1, "sprefill": n_buckets}) as tg:
        run_engine(...)
    assert tg.counts["decode"] == 1

Only functions whose ``__name__`` matches a ``max_traces`` key are
constrained; everything else (jnp-internal primitive jits, unrelated user
functions) is recorded in :attr:`counts` but never raises. The XLA C++
fastpath serves cache hits without re-entering Python, so a count of 1 means
"traced exactly once" — there is no double-counting on steady-state steps.

``conftest.py`` exposes this as the ``trace_guard`` pytest fixture.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

from jax._src.interpreters import partial_eval as _pe


class TraceGuardError(AssertionError):
    """A watched function re-traced past its budget."""


class TraceGuard:
    """Context manager counting jit traces by traced-function name.

    Args:
        max_traces: name -> maximum number of traces allowed while the
            guard is active. A watched name exceeding its budget raises
            :class:`TraceGuardError` at the offending trace, not at exit.
        exact: optional name -> exact required count, checked at ``__exit__``
            (a watched function that never traced at all is also a failure
            when listed here).
    """

    def __init__(self, max_traces: Optional[Dict[str, int]] = None,
                 exact: Optional[Dict[str, int]] = None) -> None:
        self.max_traces = dict(max_traces or {})
        self.exact = dict(exact or {})
        for name, want in self.exact.items():
            cap = self.max_traces.get(name, want)
            self.max_traces[name] = min(cap, want)
        self.counts: Dict[str, int] = {}
        self.avals: Dict[str, List[Tuple[Any, ...]]] = {}
        self._orig: Any = None
        self._active = False

    # ------------------------------------------------------------- plumbing
    def __enter__(self) -> "TraceGuard":
        if self._active:
            raise RuntimeError("TraceGuard is not re-entrant")
        self._active = True
        self._orig = _pe.trace_to_jaxpr_dynamic
        guard = self

        def traced(fun: Any, in_avals: Any, *args: Any, **kwargs: Any) -> Any:
            guard._record(fun, in_avals)
            return guard._orig(fun, in_avals, *args, **kwargs)

        _pe.trace_to_jaxpr_dynamic = traced
        return self

    def __exit__(self, exc_type: Any, exc: Any, tb: Any) -> None:
        _pe.trace_to_jaxpr_dynamic = self._orig
        self._active = False
        if exc_type is None:
            for name, want in self.exact.items():
                got = self.counts.get(name, 0)
                if got != want:
                    raise TraceGuardError(
                        f"TraceGuard: '{name}' traced {got} time(s), "
                        f"expected exactly {want}; aval history: "
                        f"{self._history(name)}")

    # -------------------------------------------------------------- helpers
    def _fun_name(self, fun: Any) -> str:
        f = getattr(fun, "f", None)
        name = getattr(f, "__name__", None) or getattr(fun, "__name__", "")
        return str(name)

    def _record(self, fun: Any, in_avals: Any) -> None:
        name = self._fun_name(fun)
        if not name:
            return
        self.counts[name] = self.counts.get(name, 0) + 1
        try:
            sig = tuple(str(a) for a in in_avals)
        except TypeError:
            sig = (str(in_avals),)
        self.avals.setdefault(name, []).append(sig)
        cap = self.max_traces.get(name)
        if cap is not None and self.counts[name] > cap:
            raise TraceGuardError(
                f"TraceGuard: '{name}' traced {self.counts[name]} time(s), "
                f"budget is {cap}. Retrace avals:\n  "
                + "\n  ".join(sig)
                + f"\nPrevious trace(s):{self._history(name, skip_last=True)}"
            )

    def _history(self, name: str, skip_last: bool = False) -> str:
        hist = self.avals.get(name, [])
        if skip_last and hist:
            hist = hist[:-1]
        if not hist:
            return " (never traced)"
        out = []
        for i, sig in enumerate(hist):
            out.append(f"\n  trace {i}: " + ", ".join(sig))
        return "".join(out)
