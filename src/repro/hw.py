"""Shared accelerator constants (TPU v5e-class, per chip).

Single source of truth for the compute/bandwidth numbers used by BOTH the
roofline analysis (roofline.py) and the opportunistic-protocol latency model
(core/protocol.py). They were previously copied into each module, which let
the protocol's latency estimates silently diverge from the §Roofline tables
whenever one copy was tuned.
"""
from __future__ import annotations

# TPU v5e-class constants (per chip)
PEAK_FLOPS = 197e12  # bf16 FLOP/s
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s per link

__all__ = ["PEAK_FLOPS", "HBM_BW", "ICI_BW"]
