"""Minimal, dependency-free stand-in for the slice of `hypothesis` the test
suite uses (``given``/``settings``/``strategies``).

tests/test_property.py prefers the real library (pinned in requirements.txt —
CI installs it); this shim keeps the property tests collectable and meaningful
in hermetic environments where ``pip install`` is unavailable. It is NOT a
general hypothesis replacement: no shrinking, no database, no stateful
testing — just deterministic boundary-first example generation.

Examples are generated from a per-test seed (stable across runs): the first
examples exercise each strategy's boundary values, the rest are pseudo-random.
"""
from __future__ import annotations

import functools
import inspect
import random
import zlib
from typing import Any, Callable, Dict, List, Optional, Sequence


# ------------------------------------------------------------------ strategies


class SearchStrategy:
    """Base: ``edges()`` are tried first (boundary values), then ``sample``."""

    def edges(self) -> List[Any]:
        return []

    def sample(self, rng: random.Random) -> Any:
        raise NotImplementedError

    def example(self, rng: random.Random, i: int = 0) -> Any:
        e = self.edges()
        return e[i] if i < len(e) else self.sample(rng)


class _Integers(SearchStrategy):
    def __init__(self, lo: int, hi: int):
        self.lo, self.hi = lo, hi

    def edges(self):
        return [self.lo, self.hi] if self.hi != self.lo else [self.lo]

    def sample(self, rng):
        return rng.randint(self.lo, self.hi)


class _Floats(SearchStrategy):
    def __init__(self, lo: float, hi: float):
        self.lo, self.hi = lo, hi

    def edges(self):
        return [self.lo, self.hi]

    def sample(self, rng):
        return rng.uniform(self.lo, self.hi)


class _SampledFrom(SearchStrategy):
    def __init__(self, elements: Sequence[Any]):
        self.elements = list(elements)

    def edges(self):
        return self.elements[:2]

    def sample(self, rng):
        return rng.choice(self.elements)


class _Lists(SearchStrategy):
    def __init__(self, elem: SearchStrategy, min_size: int = 0,
                 max_size: Optional[int] = None):
        self.elem, self.min_size = elem, min_size
        self.max_size = max_size if max_size is not None else min_size + 8

    def edges(self):
        if self.min_size == 0:
            return [[]]
        return []

    def sample(self, rng):
        n = rng.randint(self.min_size, self.max_size)
        return [self.elem.example(rng, i=2 + rng.randint(0, 10))
                for _ in range(n)]


class _Tuples(SearchStrategy):
    def __init__(self, *elems: SearchStrategy):
        self.elems = elems

    def sample(self, rng):
        return tuple(e.example(rng, i=2 + rng.randint(0, 10))
                     for e in self.elems)


_ALPHABET = ("abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ0123456789"
             " \t\n.,;:!?-_()[]{}'\"@#$%&*+=/\\|<>~`^"
             "äöüßéèêñçαβγδΩπ☃€→中日한🦜🎉")


class _Text(SearchStrategy):
    def __init__(self, max_size: int = 32):
        self.max_size = max_size

    def edges(self):
        return ["", "\x00", _ALPHABET[-8:]]

    def sample(self, rng):
        n = rng.randint(0, self.max_size)
        return "".join(rng.choice(_ALPHABET) for _ in range(n))


class _Strategies:
    """The ``strategies as st`` namespace."""

    @staticmethod
    def integers(min_value: int, max_value: int) -> SearchStrategy:
        return _Integers(min_value, max_value)

    @staticmethod
    def booleans() -> SearchStrategy:
        return _SampledFrom([False, True])

    @staticmethod
    def floats(min_value: float, max_value: float) -> SearchStrategy:
        return _Floats(min_value, max_value)

    @staticmethod
    def sampled_from(elements: Sequence[Any]) -> SearchStrategy:
        return _SampledFrom(elements)

    @staticmethod
    def lists(elem: SearchStrategy, *, min_size: int = 0,
              max_size: Optional[int] = None) -> SearchStrategy:
        return _Lists(elem, min_size, max_size)

    @staticmethod
    def tuples(*elems: SearchStrategy) -> SearchStrategy:
        return _Tuples(*elems)

    @staticmethod
    def text(*, max_size: int = 32) -> SearchStrategy:
        return _Text(max_size)


strategies = _Strategies()


# -------------------------------------------------------------------- settings


_PROFILES: Dict[str, dict] = {"default": {"max_examples": 25}}
_ACTIVE: dict = dict(_PROFILES["default"])


class settings:
    """Decorator + profile registry (the subset the suite touches)."""

    def __init__(self, max_examples: Optional[int] = None, deadline=None,
                 **_ignored):
        self.max_examples = max_examples

    def __call__(self, fn: Callable) -> Callable:
        fn._propcheck_settings = {"max_examples": self.max_examples}
        return fn

    @staticmethod
    def register_profile(name: str, *, max_examples: int = 25,
                         deadline=None, **_ignored) -> None:
        _PROFILES[name] = {"max_examples": max_examples}

    @staticmethod
    def load_profile(name: str) -> None:
        _ACTIVE.clear()
        _ACTIVE.update(_PROFILES[name])


# ----------------------------------------------------------------------- given


def given(*arg_strats: SearchStrategy, **kw_strats: SearchStrategy):
    """Run the test once per generated example (boundaries first)."""

    def deco(fn: Callable) -> Callable:
        n_override = getattr(fn, "_propcheck_settings", {}).get("max_examples")

        @functools.wraps(fn)
        def wrapper(*outer_args, **outer_kw):
            n = n_override or _ACTIVE.get("max_examples", 25)
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for i in range(n):
                args = [s.example(rng, i) for s in arg_strats]
                kw = {k: s.example(rng, i) for k, s in kw_strats.items()}
                fn(*outer_args, *args, **outer_kw, **kw)

        # hide strategy-bound params from pytest's fixture resolution: the
        # wrapper's visible signature keeps only the test's real fixtures.
        # Positional strategies bind to the RIGHTMOST parameters (hypothesis
        # semantics — fixtures come first), so drop from the right.
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        if arg_strats:
            params = params[:-len(arg_strats)]
        wrapper.__signature__ = sig.replace(
            parameters=[p for p in params if p.name not in kw_strats])
        del wrapper.__wrapped__  # pytest would re-inspect the original
        return wrapper

    return deco
