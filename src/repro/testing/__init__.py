"""Test-support utilities (hypothesis fallback shim, see propcheck.py)."""
