"""Checkpointing without orbax: a pytree is flattened to numpy arrays stored in a
single .npz plus a JSON manifest describing the tree structure and dtypes.

Safe against pickle (arrays only), deterministic key ordering, supports nested
dicts / lists / tuples / None leaves (None encoded in the manifest).
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree: Any, prefix: str, out: dict, manifest: list) -> None:
    if tree is None:
        manifest.append({"path": prefix, "kind": "none"})
    elif isinstance(tree, dict):
        manifest.append({"path": prefix, "kind": "dict",
                         "keys": sorted(tree.keys())})
        for k in sorted(tree.keys()):
            _flatten(tree[k], f"{prefix}/{k}", out, manifest)
    elif isinstance(tree, (list, tuple)):
        manifest.append({"path": prefix,
                         "kind": "list" if isinstance(tree, list) else "tuple",
                         "len": len(tree)})
        for i, v in enumerate(tree):
            _flatten(v, f"{prefix}/{i}", out, manifest)
    else:
        arr = np.asarray(tree)
        key = f"a{len(out)}"
        dtype = str(arr.dtype)
        if arr.dtype == np.dtype("O") or dtype in ("bfloat16", "float8_e4m3fn",
                                                   "float8_e5m2", "float16"):
            # ml_dtypes aren't numpy-native: store the raw bits (npz would
            # otherwise fall back to pickled object arrays)
            import ml_dtypes  # noqa: F401 - ensures dtype registry
            arr = np.asarray(tree)
            width = arr.dtype.itemsize
            arr = arr.view({1: np.uint8, 2: np.uint16}[width])
        out[key] = arr
        manifest.append({"path": prefix, "kind": "leaf", "npz_key": key,
                         "dtype": dtype})


def _unflatten(manifest: list, arrays: dict, idx: list) -> Any:
    entry = manifest[idx[0]]
    idx[0] += 1
    if entry["kind"] == "none":
        return None
    if entry["kind"] == "leaf":
        arr = arrays[entry["npz_key"]]
        dtype = entry.get("dtype", str(arr.dtype))
        if dtype != str(arr.dtype):  # bit-stored ml_dtype: view back
            import ml_dtypes
            arr = arr.view(np.dtype(dtype))
        return jnp.asarray(arr)
    if entry["kind"] == "dict":
        return {k: _unflatten(manifest, arrays, idx) for k in entry["keys"]}
    n = entry["len"]
    items = [_unflatten(manifest, arrays, idx) for _ in range(n)]
    return items if entry["kind"] == "list" else tuple(items)


def save_pytree(path: str, tree: Any) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tree = jax.tree.map(lambda a: a if a is None else np.asarray(a), tree,
                        is_leaf=lambda x: x is None)
    arrays: dict = {}
    manifest: list = []
    _flatten(tree, "", arrays, manifest)
    np.savez(path + ".npz", **arrays)
    with open(path + ".json", "w") as f:
        json.dump(manifest, f)


def load_pytree(path: str) -> Any:
    with open(path + ".json") as f:
        manifest = json.load(f)
    with np.load(path + ".npz") as z:
        arrays = {k: z[k] for k in z.files}
    return _unflatten(manifest, arrays, [0])


def save_train_state(path: str, step: int, params: Any, opt_state: Any,
                     extra: dict | None = None) -> None:
    save_pytree(path, {"step": np.asarray(step), "params": params,
                       "opt_state": opt_state, "extra": extra or {}})


def load_train_state(path: str) -> dict:
    return load_pytree(path)
