"""Pallas TPU kernel: gated KV mixing (case-study fusion: "the receiver then
mixes the projected KV cache with its own").

Elementwise chain  out = (1-σ(g))·own + σ(g)·proj  over k and v simultaneously —
trivially memory-bound, so the win is doing one fused pass (3 reads, 2 writes)
instead of the unfused 4-kernel dataflow, and never materialising σ(g) broadcasts
in HBM. Grid tiles the (layers·batch·heads, seq, head_dim) view with seq-blocks;
the per-layer scalar gate rides along as an SMEM operand.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(ko_ref, vo_ref, kp_ref, vp_ref, g_ref, k_out, v_out):
    g = jax.nn.sigmoid(g_ref[0].astype(jnp.float32))
    ko = ko_ref[...].astype(jnp.float32)
    vo = vo_ref[...].astype(jnp.float32)
    kp = kp_ref[...].astype(jnp.float32)
    vp = vp_ref[...].astype(jnp.float32)
    k_out[...] = ((1 - g) * ko + g * kp).astype(k_out.dtype)
    v_out[...] = ((1 - g) * vo + g * vp).astype(v_out.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def gated_fusion_pallas(
    k_own: jax.Array,  # (n_layers, R, S, hd)   R = batch*kv_heads
    v_own: jax.Array,
    k_proj: jax.Array,
    v_proj: jax.Array,
    gate: jax.Array,  # (n_layers,) pre-sigmoid
    *,
    block_s: int = 256,
    interpret: bool = False,
) -> tuple:
    from repro.kernels.decode_attention import _check_block
    n, R, S, hd = k_own.shape
    bs = min(block_s, S)
    _check_block(S, bs, "gated_fusion_pallas")
    grid = (n, R, S // bs)
    specs = pl.BlockSpec((1, 1, bs, hd), lambda l, r, s: (l, r, s, 0))
    gspec = pl.BlockSpec((1,), lambda l, r, s: (l,))
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[specs, specs, specs, specs, gspec],
        out_specs=[specs, specs],
        out_shape=[jax.ShapeDtypeStruct(k_own.shape, k_own.dtype),
                   jax.ShapeDtypeStruct(v_own.shape, v_own.dtype)],
        interpret=interpret,
    )(k_own, v_own, k_proj, v_proj, gate)
    return out[0], out[1]
