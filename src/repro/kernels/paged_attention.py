"""Pallas TPU kernel: GQA flash-decode attention that walks a *paged* KV pool
in-place — the serving hot loop when the engine runs ``paged=True``.

The paged ``models/cache.SlotTable`` keeps attention K/V in a shared pool of
fixed-size pages, ``(num_pages, Hkv, page_size, hd)`` per layer, with each slot
owning an ordered ``page_map`` row of physical page ids. The previous decode
path gathered every slot's pages into a contiguous ``dense_view()`` each step —
O(slots · max_seq) HBM traffic that grows with the *capacity* of the table, not
with the tokens actually cached. This kernel removes that term: the page map
and per-slot lengths ride in as **scalar-prefetch** operands, the kv BlockSpec
index map dereferences ``page_map[slot, page]`` directly (so the DMA engine
fetches physical pages straight from the pool), and unallocated
(``INVALID_PAGE``) or beyond-length pages are skipped with ``pl.when`` instead
of being gathered and masked. Per step the kernel reads exactly the pages that
hold live tokens: O(Σ_slots ceil(len_s / page_size) · page_size).

Online-softmax recurrence over the sequential innermost page dimension (same
scratch discipline as decode_attention.py), with the hardened finish: a row
whose every page was skipped (an evicted slot — all pages INVALID) emits
*zeros*, never uniform attention over uninitialized pool memory. Alongside the
normalised output the kernel returns its (m, l) statistics so the caller can
LSE-merge a fused C2C prefix segment without ever concatenating it into the
paged cache (models/attention.decode_forward_paged).

Grid: (slots, kv_heads, pages_per_slot); q rows are the G = H/Hkv grouped
query heads for that kv head. An int8-KV variant mirrors _kernel_q8: pages are
stored quantised with per-(page, head, dim) fp32 scales and dequantised in
VMEM, halving pool HBM traffic again.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.decode_attention import _NEG  # one shared mask constant


def _kernel(pm_ref, len_ref, q_ref, k_ref, v_ref, o_ref, m_out, l_out,
            m_ref, l_ref, acc_ref, *, page_size: int, num_pages: int):
    s_idx = pl.program_id(0)
    p_idx = pl.program_id(2)
    n_p = pl.num_programs(2)

    @pl.when(p_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[s_idx]
    page = pm_ref[s_idx, p_idx]
    # INVALID_PAGE (== num_pages) or a page past the live length: skip the
    # block entirely — no gather, no masking, no HBM read is consumed by it.
    live = (page < num_pages) & (p_idx * page_size < length)

    @pl.when(live)
    def _accum():
        q = q_ref[0, 0].astype(jnp.float32)  # (G, hd)
        k = k_ref[0, 0].astype(jnp.float32)  # (page_size, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        t = p_idx * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (1, page_size), 1)
        scores = q @ k.T * (q.shape[-1] ** -0.5)  # (G, page_size)
        scores = jnp.where(t < length, scores, _NEG)  # partial final page
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, scores.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(scores - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + p @ v
        m_ref[...] = m_new

    @pl.when(p_idx == n_p - 1)
    def _finish():
        # hardened: a fully-skipped row (every page INVALID/out-of-length)
        # still has m == _NEG; emit zeros so garbage can never leak past the
        # slot mask (p = exp(0) = 1 uniform attention otherwise).
        seen = m_ref[...] > _NEG / 2  # (G, 1)
        o = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = jnp.where(seen, o, 0.0).astype(o_ref.dtype)
        m_out[0, 0] = m_ref[..., 0]
        l_out[0, 0] = jnp.where(seen[:, 0], l_ref[..., 0], 0.0)


def _kernel_q8(pm_ref, len_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref,
               m_out, l_out, m_ref, l_ref, acc_ref, *, page_size: int,
               num_pages: int):
    """int8-pool variant: pages arrive as int8 blocks and are dequantised in
    VMEM with per-(page, head, dim) fp32 scales — pool HBM traffic halves."""
    s_idx = pl.program_id(0)
    p_idx = pl.program_id(2)
    n_p = pl.num_programs(2)

    @pl.when(p_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[s_idx]
    page = pm_ref[s_idx, p_idx]
    live = (page < num_pages) & (p_idx * page_size < length)

    @pl.when(live)
    def _accum():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32) * ks_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32) * vs_ref[0, 0].astype(jnp.float32)
        t = p_idx * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (1, page_size), 1)
        scores = q @ k.T * (q.shape[-1] ** -0.5)
        scores = jnp.where(t < length, scores, _NEG)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, scores.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(scores - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + p @ v
        m_ref[...] = m_new

    @pl.when(p_idx == n_p - 1)
    def _finish():
        seen = m_ref[...] > _NEG / 2
        o = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = jnp.where(seen, o, 0.0).astype(o_ref.dtype)
        m_out[0, 0] = m_ref[..., 0]
        l_out[0, 0] = jnp.where(seen[:, 0], l_ref[..., 0], 0.0)


def _validate(q, pool_shape, page_map, lengths):
    slots, Hkv_q, G, hd = q.shape
    num_pages, Hkv, page_size, hd_p = pool_shape
    if Hkv != Hkv_q or hd != hd_p:
        raise ValueError(
            f"q {q.shape} does not match pool {pool_shape}: expected "
            f"(slots, {Hkv}, G, {hd_p})")
    if page_map.ndim != 2 or page_map.shape[0] != slots:
        raise ValueError(
            f"page_map {page_map.shape} must be (slots={slots}, pages_per_slot)")
    if lengths.shape != (slots,):
        raise ValueError(f"lengths {lengths.shape} must be (slots={slots},)")


def _paged_call(kernel_fn, q, pool_shape, pps, *, n_scales: int,
                interpret: bool):
    """Shared pallas_call plumbing for the fp32/bf16 and int8 variants: the
    scalar-prefetch grid spec (page-map-dereferencing kv index maps), the
    (o, m, l) out specs/shapes and the online-softmax scratch."""
    slots, Hkv, G, hd = q.shape
    num_pages, _, page_size, _ = pool_shape

    def kv_index(s, h, p, pm, ln):
        # dereference the page map at DMA-issue time (scalar prefetch);
        # INVALID ids clamp to a real page whose block the kernel skips
        return (jnp.minimum(pm[s, p], num_pages - 1), h, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(slots, Hkv, pps),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda s, h, p, pm, ln: (s, h, 0, 0)),
            pl.BlockSpec((1, 1, page_size, hd), kv_index),
            pl.BlockSpec((1, 1, page_size, hd), kv_index),
        ] + [pl.BlockSpec((1, 1, 1, hd), kv_index)] * n_scales,
        out_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda s, h, p, pm, ln: (s, h, 0, 0)),
            pl.BlockSpec((1, 1, G), lambda s, h, p, pm, ln: (s, h, 0)),
            pl.BlockSpec((1, 1, G), lambda s, h, p, pm, ln: (s, h, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),   # running max m
            pltpu.VMEM((G, 1), jnp.float32),   # normaliser l
            pltpu.VMEM((G, hd), jnp.float32),  # output accumulator
        ],
    )
    return pl.pallas_call(
        functools.partial(kernel_fn, page_size=page_size,
                          num_pages=num_pages),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((slots, Hkv, G, hd), q.dtype),
            jax.ShapeDtypeStruct((slots, Hkv, G), jnp.float32),
            jax.ShapeDtypeStruct((slots, Hkv, G), jnp.float32),
        ],
        interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention_pallas(
    q: jax.Array,  # (slots, Hkv, G, hd) — grouped query heads
    k_pool: jax.Array,  # (num_pages, Hkv, page_size, hd)
    v_pool: jax.Array,
    page_map: jax.Array,  # (slots, pages_per_slot) int32; num_pages = INVALID
    lengths: jax.Array,  # (slots,) int32 live tokens per slot
    *,
    interpret: bool = False,
):
    """Returns (o (slots,Hkv,G,hd), m (slots,Hkv,G), l (slots,Hkv,G))."""
    _validate(q, k_pool.shape, page_map, lengths)
    call = _paged_call(_kernel, q, k_pool.shape, page_map.shape[1],
                       n_scales=0, interpret=interpret)
    return call(page_map.astype(jnp.int32), lengths.astype(jnp.int32),
                q, k_pool, v_pool)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention_q8_pallas(
    q: jax.Array,  # (slots, Hkv, G, hd)
    k_q: jax.Array,  # (num_pages, Hkv, page_size, hd) int8
    v_q: jax.Array,  # int8
    k_scale: jax.Array,  # (num_pages, Hkv, 1, hd) fp32
    v_scale: jax.Array,
    page_map: jax.Array,  # (slots, pages_per_slot) int32
    lengths: jax.Array,  # (slots,) int32
    *,
    interpret: bool = False,
):
    """int8-pool twin of :func:`paged_decode_attention_pallas`."""
    _validate(q, k_q.shape, page_map, lengths)
    call = _paged_call(_kernel_q8, q, k_q.shape, page_map.shape[1],
                       n_scales=2, interpret=interpret)
    return call(page_map.astype(jnp.int32), lengths.astype(jnp.int32),
                q, k_q, v_q, k_scale, v_scale)
