"""Pallas TPU kernel: ragged/varlen causal flash-prefill straight over the
paged KV pool — the chunked-prefill hot path.

Monolithic bucketed prefill pads every prompt in an admission batch to the
bucket length and runs one dense forward over the padded rectangle: a single
long prompt monopolises the device for the whole forward while every decode
slot starves (the long-prompt p99 stall engine_bench measures). This kernel is
the attention half of the fix: *chunks* of multiple variable-length prompts
are packed back to back into one query buffer — block_q-aligned, no bucket
padding — and each query attends, causally, the keys of **its own sequence
only**, read directly from the paged ``models/cache.SlotTable`` pool the
chunk's K/V were just scattered into.

Ragged bookkeeping rides in as **scalar-prefetch** operands (the same
``PrefetchScalarGridSpec`` machinery as kernels/paged_attention.py):

- ``block_seq`` (n_blocks,): which packed sequence each query block belongs
  to (a row of ``page_map``); -1 marks a padding block (skipped entirely).
- ``block_pos`` (n_blocks,): absolute position of the block's first query
  token — the chunk's ``pos_offset`` plus its offset within the chunk.
- ``block_len`` (n_blocks,): live query rows in the block (ragged tail).
- ``page_map`` (rows, pages_per_slot): physical page ids per sequence,
  ``num_pages`` == INVALID; the kv BlockSpec index map dereferences
  ``page_map[block_seq[b], p]`` at DMA-issue time, so the DMA engine fetches
  exactly the pages that hold the sequence's live tokens.

Because a chunk's own K/V are written to their pages *before* the kernel
runs, causality (``k_pos <= q_pos``) uniformly covers three key segments with
one rule: radix-shared prefix pages, pages written by earlier chunks, and the
current chunk itself. Pages past the last query position are skipped with
``pl.when`` — a chunk at offset P reads O(P + chunk) keys, not O(max_seq).

Online-softmax recurrence over the sequential innermost page dimension with
the hardened finish (masked tails and dead blocks emit exact zeros, never
uniform attention over uninitialized pool memory). Alongside the normalised
output the kernel returns its (m, l) statistics so the caller can LSE-merge a
fused C2C prefix segment (models/attention.prefill_chunk_forward) without
concatenating it into the paged cache.

Grid: (n_blocks, kv_heads, pages_per_slot); q rows are the G = H/Hkv grouped
query heads × block_q chunk tokens for that kv head (row r = g·block_q + t).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.decode_attention import _NEG  # one shared mask constant


def _kernel(seq_ref, pos_ref, len_ref, pm_ref, q_ref, k_ref, v_ref,
            o_ref, m_out, l_out, m_ref, l_ref, acc_ref, *,
            page_size: int, num_pages: int, block_q: int):
    b_idx = pl.program_id(0)
    p_idx = pl.program_id(2)
    n_p = pl.num_programs(2)

    @pl.when(p_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    seq = seq_ref[b_idx]
    base = pos_ref[b_idx]
    nq = len_ref[b_idx]
    page = pm_ref[jnp.maximum(seq, 0), p_idx]
    # a padding block (seq == -1), an INVALID page, or a page entirely past
    # the block's last query position: skip — no HBM read is consumed by it
    live = (seq >= 0) & (page < num_pages) & (p_idx * page_size < base + nq)

    @pl.when(live)
    def _accum():
        q = q_ref[0, 0].astype(jnp.float32)  # (G*block_q, hd)
        k = k_ref[0, 0].astype(jnp.float32)  # (page_size, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        rows = jax.lax.broadcasted_iota(jnp.int32, (q.shape[0], 1), 0)
        t = rows % block_q                   # query index within the chunk
        q_pos = base + t                     # absolute query position
        k_pos = p_idx * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (1, page_size), 1)
        scores = q @ k.T * (q.shape[-1] ** -0.5)  # (G*block_q, page_size)
        # causal against absolute positions + ragged tail rows masked out
        valid = (k_pos <= q_pos) & (t < nq)
        scores = jnp.where(valid, scores, _NEG)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, scores.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(scores - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + p @ v
        m_ref[...] = m_new

    @pl.when(p_idx == n_p - 1)
    def _finish():
        # hardened: rows past the ragged tail and fully-dead blocks still
        # have m == _NEG; emit exact zeros so garbage can never leak past the
        # packing mask (p = exp(0) = 1 uniform attention otherwise). A live
        # row always sees at least its own key (written before the call).
        seen = m_ref[...] > _NEG / 2  # (G*block_q, 1)
        o = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = jnp.where(seen, o, 0.0).astype(o_ref.dtype)
        m_out[0, 0] = m_ref[..., 0]
        l_out[0, 0] = jnp.where(seen[:, 0], l_ref[..., 0], 0.0)


def _validate(q, pool_shape, block_seq, block_pos, block_len, page_map):
    n_blocks, Hkv_q, gbq, hd = q.shape
    num_pages, Hkv, page_size, hd_p = pool_shape
    if Hkv != Hkv_q or hd != hd_p:
        raise ValueError(
            f"q {q.shape} does not match pool {pool_shape}: expected "
            f"(n_blocks, {Hkv}, G*block_q, {hd_p})")
    for name, arr in (("block_seq", block_seq), ("block_pos", block_pos),
                      ("block_len", block_len)):
        if arr.shape != (n_blocks,):
            raise ValueError(
                f"{name} {arr.shape} must be (n_blocks={n_blocks},)")
    if page_map.ndim != 2:
        raise ValueError(
            f"page_map {page_map.shape} must be (rows, pages_per_slot)")


def _ragged_call(q, pool_shape, pps, *, block_q: int, interpret: bool):
    """The pallas_call plumbing: scalar-prefetch grid spec whose kv index
    maps dereference ``page_map[block_seq[b], p]`` at DMA-issue time, the
    (o, m, l) out specs/shapes and the online-softmax scratch."""
    n_blocks, Hkv, gbq, hd = q.shape
    num_pages, _, page_size, _ = pool_shape

    def kv_index(b, h, p, bs, bp, bl, pm):
        # dereference the packed sequence's page map (scalar prefetch);
        # dead blocks clamp to row 0 and INVALID ids clamp to a real page
        # whose block the kernel skips
        return (jnp.minimum(pm[jnp.maximum(bs[b], 0), p], num_pages - 1),
                h, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(n_blocks, Hkv, pps),
        in_specs=[
            pl.BlockSpec((1, 1, gbq, hd),
                         lambda b, h, p, bs, bp, bl, pm: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, page_size, hd), kv_index),
            pl.BlockSpec((1, 1, page_size, hd), kv_index),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, gbq, hd),
                         lambda b, h, p, bs, bp, bl, pm: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, gbq), lambda b, h, p, bs, bp, bl, pm: (b, h, 0)),
            pl.BlockSpec((1, 1, gbq), lambda b, h, p, bs, bp, bl, pm: (b, h, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((gbq, 1), jnp.float32),   # running max m
            pltpu.VMEM((gbq, 1), jnp.float32),   # normaliser l
            pltpu.VMEM((gbq, hd), jnp.float32),  # output accumulator
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel, page_size=page_size, num_pages=num_pages,
                          block_q=block_q),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n_blocks, Hkv, gbq, hd), q.dtype),
            jax.ShapeDtypeStruct((n_blocks, Hkv, gbq), jnp.float32),
            jax.ShapeDtypeStruct((n_blocks, Hkv, gbq), jnp.float32),
        ],
        interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("block_q", "interpret"))
def ragged_prefill_attention_pallas(
    q: jax.Array,  # (n_blocks, Hkv, G*block_q, hd) — packed query blocks
    k_pool: jax.Array,  # (num_pages, Hkv, page_size, hd)
    v_pool: jax.Array,
    block_seq: jax.Array,  # (n_blocks,) int32 page_map row; -1 = pad block
    block_pos: jax.Array,  # (n_blocks,) int32 absolute first-query position
    block_len: jax.Array,  # (n_blocks,) int32 live query rows (<= block_q)
    page_map: jax.Array,  # (rows, pages_per_slot) int32; num_pages = INVALID
    *,
    block_q: int,
    interpret: bool = False,
):
    """Returns (o (n_blocks,Hkv,G*block_q,hd), m, l (n_blocks,Hkv,G*block_q))."""
    _validate(q, k_pool.shape, block_seq, block_pos, block_len, page_map)
    call = _ragged_call(q, k_pool.shape, page_map.shape[1],
                        block_q=block_q, interpret=interpret)
    return call(block_seq.astype(jnp.int32), block_pos.astype(jnp.int32),
                block_len.astype(jnp.int32), page_map.astype(jnp.int32),
                q, k_pool, v_pool)
