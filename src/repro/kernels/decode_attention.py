"""Pallas TPU kernel: GQA flash-decode attention over (possibly concatenated)
KV caches — the FedRefine serve-side hot loop.

Eq. 4 decode attends over [fused_1 ∘ … ∘ fused_s ∘ own] caches. Rather than
materialising (G, S_total) attention matrices in HBM, the kernel walks the cache
in ``block_s`` VMEM tiles with the online-softmax recurrence (running max m,
normaliser l, accumulator acc persist in VMEM scratch across the sequential
innermost grid dim). All validity/window/ring/prefix-gate logic is folded into a
single additive fp32 ``bias`` operand built by the caller (ops.decode_attention):
-inf ⇒ masked, log σ(gate) on fused-prefix positions — so one kernel serves full
caches, sliding-window rings and C2C prefixes alike.

Grid: (batch, kv_heads, S // block_s); q rows are the G = H/Hkv grouped query
heads for that kv head, padded to the fp32 sublane (8) when G < 8.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# THE mask constant, shared by every kernel module (ops._MASK and
# paged_attention import it): a python scalar (jnp constants would be captured
# as kernel consts) whose value is coupled to the hardened-finish dead-row
# test ``m > _NEG / 2`` — change it only in this one place.
_NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, bias_ref, o_ref, m_ref, l_ref, acc_ref):
    s_idx = pl.program_id(2)
    n_s = pl.num_programs(2)

    @pl.when(s_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # (G, hd)
    k = k_ref[0, 0].astype(jnp.float32)  # (bs, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    bias = bias_ref[0].astype(jnp.float32)  # (bs,)

    scores = q @ k.T * (q.shape[-1] ** -0.5) + bias[None, :]  # (G, bs)
    m_prev = m_ref[...]  # (G, 1)
    m_new = jnp.maximum(m_prev, scores.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new)  # (G, bs)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + p @ v
    m_ref[...] = m_new

    @pl.when(s_idx == n_s - 1)
    def _finish():
        # Fully-masked rows (bias all _NEG — e.g. an empty engine slot) would
        # otherwise yield scores ≈ m ≈ _NEG, p = exp(0) = 1: *uniform*
        # attention over uninitialized KV. Emit exact zeros instead so garbage
        # can never leak past the slot mask.
        seen = m_ref[...] > _NEG / 2
        o = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = jnp.where(seen, o, 0.0).astype(o_ref.dtype)


def _kernel_q8(q_ref, k_ref, v_ref, ks_ref, vs_ref, bias_ref, o_ref,
               m_ref, l_ref, acc_ref):
    """int8-KV variant: k/v arrive as int8 blocks and are dequantised in VMEM
    with per-(head, dim) fp32 scales — HBM traffic for the cache halves
    (the quantised-C2C serving path; core/quant.py)."""
    s_idx = pl.program_id(2)
    n_s = pl.num_programs(2)

    @pl.when(s_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32) * ks_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32) * vs_ref[0, 0].astype(jnp.float32)
    bias = bias_ref[0].astype(jnp.float32)

    scores = q @ k.T * (q.shape[-1] ** -0.5) + bias[None, :]
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, scores.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + p @ v
    m_ref[...] = m_new

    @pl.when(s_idx == n_s - 1)
    def _finish():
        seen = m_ref[...] > _NEG / 2  # see _kernel._finish
        o = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = jnp.where(seen, o, 0.0).astype(o_ref.dtype)


def _check_block(S: int, bs: int, caller: str) -> None:
    """A bare ``assert`` here vanishes under ``python -O`` and turns a shape
    bug into silent BlockSpec corruption — fail loudly instead."""
    if bs < 1 or S % bs:
        raise ValueError(
            f"{caller}: sequence length S={S} is not divisible by "
            f"block_s={bs}; pad S to a block multiple (ops._seq_tile) or "
            f"pass a dividing block_s")


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def decode_attention_q8_pallas(
    q: jax.Array,  # (B, Hkv, G, hd)
    k_q: jax.Array,  # (B, Hkv, S, hd) int8
    v_q: jax.Array,  # int8
    k_scale: jax.Array,  # (B, Hkv, 1, hd) fp32
    v_scale: jax.Array,
    bias: jax.Array,  # (B, S) fp32
    *,
    block_s: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, Hkv, G, hd = q.shape
    S = k_q.shape[2]
    bs = min(block_s, S)
    _check_block(S, bs, "decode_attention_q8_pallas")
    grid = (B, Hkv, S // bs)

    return pl.pallas_call(
        _kernel_q8,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bs, hd), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, 1, bs, hd), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, 1, 1, hd), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, 1, hd), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, bs), lambda b, h, s: (b, s)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, s: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k_q, v_q, k_scale, v_scale, bias)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def decode_attention_pallas(
    q: jax.Array,  # (B, Hkv, G, hd) — grouped query heads
    k: jax.Array,  # (B, Hkv, S, hd)
    v: jax.Array,  # (B, Hkv, S, hd)
    bias: jax.Array,  # (B, S) fp32 additive (−inf = masked)
    *,
    block_s: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, Hkv, G, hd = q.shape
    S = k.shape[2]
    bs = min(block_s, S)
    _check_block(S, bs, "decode_attention_pallas")
    grid = (B, Hkv, S // bs)

    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bs, hd), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, 1, bs, hd), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, bs), lambda b, h, s: (b, s)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, s: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),   # running max m
            pltpu.VMEM((G, 1), jnp.float32),   # normaliser l
            pltpu.VMEM((G, hd), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q, k, v, bias)
