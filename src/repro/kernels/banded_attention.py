"""Pallas TPU kernel: banded (sliding-window) prefill attention.

The jnp flash path computes every (q-chunk × kv) block and masks — at
long_500k-style shapes with window ≪ S that wastes S/window × the useful work
(EXPERIMENTS.md §Perf notes). This kernel exploits the band structure
STRUCTURALLY: the grid's kv dimension only spans the diagonal band
(ceil(window/block)+1 blocks per q block), and the kv BlockSpec index_map
selects the diagonal-relative block — fully-masked blocks are never launched.

    FLOPs: O(S · window)   instead of   O(S²)

Online-softmax accumulation across the band (same scratch discipline as
decode_attention.py). Causality + window masking applied per element inside
the band's edge blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def _kernel(w_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, block: int, nband: int):
    b_idx = pl.program_id(2)  # position within the band (sequential)
    n_b = pl.num_programs(2)
    qi = pl.program_id(1)  # q block row

    @pl.when(b_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    window = w_ref[0]
    q = q_ref[0].astype(jnp.float32)  # (G*block? no: (bq, hd)) — see specs
    k = k_ref[0].astype(jnp.float32)  # (block, hd)
    v = v_ref[0].astype(jnp.float32)

    # absolute positions of this q block and this band kv block
    q_pos = qi * block + jax.lax.broadcasted_iota(jnp.int32, (block, 1), 0)
    kv_block_idx = qi - (nband - 1) + b_idx  # diagonal-relative
    k_pos = kv_block_idx * block + jax.lax.broadcasted_iota(
        jnp.int32, (1, block), 1)

    s = q @ k.T * (q.shape[-1] ** -0.5)  # (block, block)
    valid = (k_pos >= 0) & (k_pos <= q_pos) & (k_pos > q_pos - window)
    s = jnp.where(valid, s, _NEG)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + p @ v
    m_ref[...] = m_new

    @pl.when(b_idx == n_b - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(
            o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "block", "interpret"))
def banded_attention_pallas(
    q: jax.Array,  # (BH, S, hd) — batch×heads flattened (MHA rows)
    k: jax.Array,  # (BH, S, hd)
    v: jax.Array,
    *,
    window: int,
    block: int = 256,
    interpret: bool = False,
) -> jax.Array:
    from repro.kernels.decode_attention import _check_block
    BH, S, hd = q.shape
    blk = min(block, S)
    _check_block(S, blk, "banded_attention_pallas")
    nq = S // blk
    # band width in blocks: the diagonal block + enough to cover the window
    nband = min(-(-window // blk) + 1, nq)
    grid = (BH, nq, nband)

    def kv_index(r, qi, b):
        # diagonal-relative kv block, clamped into range (clamped duplicates
        # are fully masked by the position test inside the kernel)
        idx = qi - (nband - 1) + b
        return (r, jnp.clip(idx, 0, nq - 1), 0)

    w_arr = jnp.full((1,), window, jnp.int32)
    return pl.pallas_call(
        functools.partial(_kernel, block=blk, nband=nband),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda r, qi, b: (0,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, blk, hd), lambda r, qi, b: (r, qi, 0)),
            pl.BlockSpec((1, blk, hd), kv_index),
            pl.BlockSpec((1, blk, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, blk, hd), lambda r, qi, b: (r, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk, 1), jnp.float32),
            pltpu.VMEM((blk, 1), jnp.float32),
            pltpu.VMEM((blk, hd), jnp.float32),
        ],
        interpret=interpret,
    )(w_arr, q, k, v)
