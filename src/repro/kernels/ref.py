"""Pure-jnp oracles for every Pallas kernel (the allclose reference in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention import _NEG  # the one shared mask const


def fuser_mlp_ref(x, w1, b1, w2, b2, w3, b3):
    """3-layer SiLU MLP, fp32 accumulation to match the kernel."""
    h = jnp.dot(x, w1, preferred_element_type=jnp.float32) + b1.astype(jnp.float32)
    h = jax.nn.silu(h).astype(x.dtype)
    h = jnp.dot(h, w2, preferred_element_type=jnp.float32) + b2.astype(jnp.float32)
    h = jax.nn.silu(h).astype(x.dtype)
    y = jnp.dot(h, w3, preferred_element_type=jnp.float32) + b3.astype(jnp.float32)
    return y.astype(x.dtype)


def gated_fusion_ref(k_own, v_own, k_proj, v_proj, gate):
    g = jax.nn.sigmoid(gate.astype(jnp.float32))[:, None, None, None, None]
    k = (1 - g) * k_own.astype(jnp.float32) + g * k_proj.astype(jnp.float32)
    v = (1 - g) * v_own.astype(jnp.float32) + g * v_proj.astype(jnp.float32)
    return k.astype(k_own.dtype), v.astype(v_own.dtype)


def decode_attention_ref(q, k, v, bias):
    """q (B,Hkv,G,hd), k/v (B,Hkv,S,hd), bias (B,S) additive fp32.

    Matches the hardened kernel contract: a row whose bias masks every key
    returns exact zeros (softmax alone would return uniform attention over
    the garbage values)."""
    scores = jnp.einsum("bhgd,bhsd->bhgs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * (q.shape[-1] ** -0.5)
    scores = scores + bias[:, None, None, :].astype(jnp.float32)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bhsd->bhgd", w, v.astype(jnp.float32))
    any_live = (bias > _NEG / 2).any(axis=-1)[:, None, None, None]
    return jnp.where(any_live, out, 0.0).astype(q.dtype)


def paged_decode_attention_ref(q, k_pool, v_pool, page_map, lengths):
    """Gather-then-attend oracle for the paged flash-decode kernel.

    q (slots, Hkv, G, hd); k_pool/v_pool (num_pages, Hkv, page_size, hd);
    page_map (slots, pages_per_slot) int32 with num_pages == INVALID;
    lengths (slots,) int32. Mirrors SlotTable.dense_view(): clamp-gather every
    mapped page, then mask unmapped pages and beyond-length positions; rows
    with no live key return zeros (the hardened kernel contract)."""
    num_pages, Hkv, pg, hd = k_pool.shape
    slots, pps = page_map.shape
    pm = jnp.minimum(page_map, num_pages - 1)

    def gather(pool):
        v = pool[pm]  # (slots, pps, Hkv, pg, hd)
        return v.transpose(0, 2, 1, 3, 4).reshape(slots, Hkv, pps * pg, hd)

    t = jnp.arange(pps * pg)
    mapped = jnp.repeat(page_map < num_pages, pg, axis=1)  # (slots, pps*pg)
    live = mapped & (t[None, :] < lengths[:, None])
    bias = jnp.where(live, 0.0, _NEG)
    out = decode_attention_ref(q, gather(k_pool), gather(v_pool), bias)
    any_live = live.any(axis=-1)[:, None, None, None]
    return jnp.where(any_live, out, 0.0).astype(q.dtype)


def ragged_prefill_attention_ref(q, k_pool, v_pool, block_seq, block_pos,
                                 block_len, page_map, *, block_q: int):
    """Gather-then-attend oracle for the ragged varlen flash-prefill kernel.

    q (T, H, hd) packed chunk queries at block_q alignment; k_pool/v_pool
    (num_pages, Hkv, page_size, hd); per-block metadata as in
    ops.ragged_prefill_attention. Each query row attends causally (absolute
    positions) over its sequence's mapped pages gathered dense; rows past a
    block's ragged tail and pad blocks return zeros (the hardened kernel
    contract). Returns out (T, H, hd)."""
    num_pages, Hkv, pg, hd = k_pool.shape
    T, H, _ = q.shape
    G = H // Hkv
    n_blocks = T // block_q
    pps = page_map.shape[1]
    rows = jnp.maximum(block_seq, 0)
    pmb = jnp.minimum(page_map, num_pages - 1)[rows]  # (n_blocks, pps)
    mapped = jnp.repeat(page_map[rows] < num_pages, pg, axis=1)

    def gather(pool):
        v = pool[pmb]  # (n_blocks, pps, Hkv, pg, hd)
        return v.transpose(0, 2, 1, 3, 4).reshape(n_blocks, Hkv, pps * pg, hd)

    t = jnp.arange(block_q)
    q_pos = block_pos[:, None] + t[None, :]  # (n_blocks, block_q)
    live_q = (block_seq[:, None] >= 0) & (t[None, :] < block_len[:, None])
    k_pos = jnp.arange(pps * pg)
    valid = (mapped[:, None, :] & (k_pos[None, None, :] <= q_pos[..., None])
             & live_q[..., None])  # (n_blocks, block_q, pps*pg)
    qb = q.reshape(n_blocks, block_q, Hkv, G, hd).transpose(0, 2, 3, 1, 4)
    s = jnp.einsum("bkgqd,bktd->bkgqt", qb.astype(jnp.float32),
                   gather(k_pool).astype(jnp.float32)) * (hd ** -0.5)
    s = jnp.where(valid[:, None, None], s, _NEG)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqt,bktd->bkgqd", w,
                     gather(v_pool).astype(jnp.float32))
    out = jnp.where(valid.any(-1)[:, None, None, :, None], out, 0.0)
    return out.transpose(0, 3, 1, 2, 4).reshape(T, H, hd).astype(q.dtype)


def banded_attention_ref(q, k, v, *, window: int):
    """q/k/v (BH, S, hd); causal sliding-window attention, fp32 softmax."""
    BH, S, hd = q.shape
    s = jnp.einsum("rsd,rtd->rst", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (hd ** -0.5)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = (kpos <= qpos) & (kpos > qpos - window)
    s = jnp.where(mask[None], s, _NEG)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("rst,rtd->rsd", w, v.astype(jnp.float32)).astype(q.dtype)
