"""Jitted public wrappers around the Pallas kernels.

On CPU (this container) the kernels execute in ``interpret=True`` mode — the
kernel body runs in Python with real BlockSpec tiling semantics — so the same
call sites work on TPU unchanged. ``interpret`` auto-detects the backend unless
forced via REPRO_PALLAS_INTERPRET=0/1.
"""
from __future__ import annotations

import math
import os

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention import _NEG as _MASK  # shared mask const
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.fuser_mlp import fuser_mlp_pallas
from repro.kernels.gated_fusion import gated_fusion_pallas


def _interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false")
    return jax.default_backend() != "tpu"


def _seq_tile(S: int, block: int) -> tuple[int, int]:
    """Pick a sequence block size and the padded length it implies.

    Preference order: (1) ``min(block, S)`` when it divides S — no padding;
    (2) a halved power-of-two divisor, but only down to 64 (the old
    ``while S % bs: bs //= 2`` fallback degraded all the way to ``bs = 1``
    for odd/prime S — e.g. an unpadded fused-prefix length — launching an
    S-program grid); (3) otherwise keep a lane-aligned power-of-two block
    and pad the tail instead (callers mask padded keys with ``_MASK`` bias /
    positional masks and un-pad the output).
    """
    bs = min(block, S)
    if S % bs == 0:
        return bs, S
    b = bs
    while b > 64 and S % b:
        b //= 2
    if S % b == 0:
        return b, S
    bs = max(8, min(block, 1 << (S - 1).bit_length()))
    return bs, S + (-S) % bs


def fuser_mlp(mlp_params: dict, x: jax.Array, *, block_t: int = 128) -> jax.Array:
    """Apply one fuser MLP {wN: {w, b}} to x (..., d_in) -> (..., d_out)."""
    lead = x.shape[:-1]
    d_in = x.shape[-1]
    T = math.prod(lead) if lead else 1
    xf = x.reshape(T, d_in)
    # pad T to a block multiple
    bt = min(block_t, max(8, T))
    pad = (-T) % bt
    if pad:
        xf = jnp.concatenate([xf, jnp.zeros((pad, d_in), x.dtype)], 0)
    y = fuser_mlp_pallas(
        xf,
        mlp_params["w1"]["w"], mlp_params["w1"]["b"],
        mlp_params["w2"]["w"], mlp_params["w2"]["b"],
        mlp_params["w3"]["w"], mlp_params["w3"]["b"],
        block_t=bt, interpret=_interpret())
    if pad:
        y = y[:T]
    return y.reshape(*lead, y.shape[-1])


def gated_fusion(k_own, v_own, k_proj, v_proj, gate, *, block_s: int = 256):
    """Gated mix over stacked caches (n, B, Hkv, S, hd) + gate (n,)."""
    n, B, H, S, hd = k_own.shape
    bs, Sp = _seq_tile(S, block_s)
    pad5 = ((0, 0), (0, 0), (0, 0), (0, Sp - S), (0, 0))
    rs = lambda a: jnp.pad(a, pad5).reshape(n, B * H, Sp, hd)
    k, v = gated_fusion_pallas(rs(k_own), rs(v_own), rs(k_proj), rs(v_proj),
                               gate, block_s=bs, interpret=_interpret())
    k = k.reshape(n, B, H, Sp, hd)[..., :S, :]
    v = v.reshape(n, B, H, Sp, hd)[..., :S, :]
    return k, v


def _pad_keys(k, v, bias, S: int, Sp: int):
    """Right-pad k/v (B,Hkv,S,hd) with zero keys and bias (B,S) with _MASK so
    the padded tail carries exactly zero attention mass."""
    if Sp == S:
        return k, v, bias
    pad = Sp - S
    k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    bias = jnp.pad(bias, ((0, 0), (0, pad)), constant_values=_MASK)
    return k, v, bias


def decode_attention(q, k, v, bias, *, block_s: int = 512):
    """Flash decode. q (B,H,hd) with GQA heads, k/v (B,Hkv,S,hd), bias (B,S)."""
    B, H, hd = q.shape
    Hkv = k.shape[1]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, hd)
    S = k.shape[2]
    bs, Sp = _seq_tile(S, block_s)
    k, v, bias = _pad_keys(k, v, bias.astype(jnp.float32), S, Sp)
    out = decode_attention_pallas(qg, k, v, bias, block_s=bs,
                                  interpret=_interpret())
    return out.reshape(B, H, hd)


def banded_attention(q, k, v, *, window: int, block: int = 256):
    """Sliding-window prefill attention, O(S·window). q/k/v (B, H, S, hd)."""
    from repro.kernels.banded_attention import banded_attention_pallas
    B, H, S, hd = q.shape
    blk, Sp = _seq_tile(S, block)
    pad4 = ((0, 0), (0, 0), (0, Sp - S), (0, 0))
    # padded queries land after every real key (sliced off below); padded keys
    # sit at positions > every real query, so causality already masks them
    rs = lambda a: jnp.pad(a, pad4).reshape(B * H, Sp, hd)
    out = banded_attention_pallas(rs(q), rs(k), rs(v), window=window,
                                  block=blk, interpret=_interpret())
    return out.reshape(B, H, Sp, hd)[..., :S, :]


def decode_attention_q8(q, qstack, bias, *, block_s: int = 512):
    """Flash decode over an int8-quantised cache (core/quant.py layout):
    q (B,H,hd); qstack {"k_q","v_q" int8 (B,Hkv,S,hd), "k_scale","v_scale"}."""
    from repro.kernels.decode_attention import decode_attention_q8_pallas
    B, H, hd = q.shape
    Hkv = qstack["k_q"].shape[1]
    G = H // Hkv
    S = qstack["k_q"].shape[2]
    bs, Sp = _seq_tile(S, block_s)
    k_q, v_q, bias = _pad_keys(qstack["k_q"], qstack["v_q"],
                               bias.astype(jnp.float32), S, Sp)
    out = decode_attention_q8_pallas(
        q.reshape(B, Hkv, G, hd), k_q, v_q,
        qstack["k_scale"].astype(jnp.float32),
        qstack["v_scale"].astype(jnp.float32),
        bias, block_s=bs, interpret=_interpret())
    return out.reshape(B, H, hd)


# ------------------------------------------------------------------ paged


def paged_decode_attention(q, k_pool, v_pool, page_map, lengths):
    """Flash decode that walks a paged KV pool in-place (no gathered view).

    q (slots, H, hd) with GQA heads; k_pool/v_pool (num_pages, Hkv,
    page_size, hd); page_map (slots, pages_per_slot) int32 physical page ids
    (num_pages == INVALID_PAGE); lengths (slots,) int32 live tokens per slot.

    Returns ``(out (slots, H, hd), m (slots, H), l (slots, H))`` — the online
    softmax statistics let the caller LSE-merge a fused C2C prefix segment
    (models/attention.merge_attention) without concatenating caches. Rows with
    no live page (evicted slots) return zeros with l == 0.
    """
    from repro.kernels.paged_attention import paged_decode_attention_pallas
    B, H, hd = q.shape
    Hkv = k_pool.shape[1]
    G = H // Hkv
    out, m, l = paged_decode_attention_pallas(
        q.reshape(B, Hkv, G, hd), k_pool, v_pool, page_map, lengths,
        interpret=_interpret())
    return out.reshape(B, H, hd), m.reshape(B, H), l.reshape(B, H)


def ragged_prefill_attention(q, k_pool, v_pool, block_seq, block_pos,
                             block_len, page_map, *, block_q: int = 8):
    """Ragged/varlen causal flash prefill straight over a paged KV pool.

    ``q`` (T, H, hd) is a packed buffer of chunk query tokens — multiple
    variable-length prompts laid back to back at ``block_q`` alignment, no
    bucket padding. Per block of ``block_q`` tokens, ``block_seq`` names the
    ``page_map`` row the block's sequence maps its pages through (-1 = pad
    block), ``block_pos`` its absolute first-token position and ``block_len``
    its live rows. Each query attends causally (absolute positions) over its
    own sequence's pool pages — shared prefix pages, earlier chunks and the
    current chunk (scattered into the pool before this call) alike.

    Returns ``(out (T, H, hd), m (T, H), l (T, H))`` — the online softmax
    statistics let the caller LSE-merge a fused C2C prefix segment
    (models/attention.merge_attention). Rows past a block's ragged tail and
    pad blocks return zeros with l == 0.
    """
    from repro.kernels.prefill_attention import ragged_prefill_attention_pallas
    T, H, hd = q.shape
    if block_q < 1 or T % block_q:
        raise ValueError(f"packed length T={T} is not divisible by "
                         f"block_q={block_q}")
    Hkv = k_pool.shape[1]
    G = H // Hkv
    n_blocks = T // block_q
    # (T, H, hd) -> (n_blocks, Hkv, G*block_q, hd): kernel row r = g*block_q + t
    qb = q.reshape(n_blocks, block_q, Hkv, G, hd).transpose(0, 2, 3, 1, 4)
    qb = qb.reshape(n_blocks, Hkv, G * block_q, hd)
    o, m, l = ragged_prefill_attention_pallas(
        qb, k_pool, v_pool, block_seq, block_pos, block_len, page_map,
        block_q=block_q, interpret=_interpret())
    unpack = lambda a, *tail: (
        a.reshape(n_blocks, Hkv, G, block_q, *tail)
        .transpose(0, 3, 1, 2, *range(4, 4 + len(tail)))
        .reshape(T, H, *tail))
    return unpack(o, hd), unpack(m), unpack(l)


def paged_decode_attention_q8(q, qpool, page_map, lengths):
    """int8-pool twin of :func:`paged_decode_attention`: qpool is
    {"k_q","v_q" int8 (num_pages,Hkv,page_size,hd),
    "k_scale","v_scale" fp32 (num_pages,Hkv,1,hd)} (per-page scales)."""
    from repro.kernels.paged_attention import paged_decode_attention_q8_pallas
    B, H, hd = q.shape
    Hkv = qpool["k_q"].shape[1]
    G = H // Hkv
    out, m, l = paged_decode_attention_q8_pallas(
        q.reshape(B, Hkv, G, hd), qpool["k_q"], qpool["v_q"],
        qpool["k_scale"].astype(jnp.float32),
        qpool["v_scale"].astype(jnp.float32),
        page_map, lengths, interpret=_interpret())
    return out.reshape(B, H, hd), m.reshape(B, H), l.reshape(B, H)
