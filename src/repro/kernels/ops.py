"""Jitted public wrappers around the Pallas kernels.

On CPU (this container) the kernels execute in ``interpret=True`` mode — the
kernel body runs in Python with real BlockSpec tiling semantics — so the same
call sites work on TPU unchanged. ``interpret`` auto-detects the backend unless
forced via REPRO_PALLAS_INTERPRET=0/1.
"""
from __future__ import annotations

import math
import os

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.fuser_mlp import fuser_mlp_pallas
from repro.kernels.gated_fusion import gated_fusion_pallas


def _interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false")
    return jax.default_backend() != "tpu"


def fuser_mlp(mlp_params: dict, x: jax.Array, *, block_t: int = 128) -> jax.Array:
    """Apply one fuser MLP {wN: {w, b}} to x (..., d_in) -> (..., d_out)."""
    lead = x.shape[:-1]
    d_in = x.shape[-1]
    T = math.prod(lead) if lead else 1
    xf = x.reshape(T, d_in)
    # pad T to a block multiple
    bt = min(block_t, max(8, T))
    pad = (-T) % bt
    if pad:
        xf = jnp.concatenate([xf, jnp.zeros((pad, d_in), x.dtype)], 0)
    y = fuser_mlp_pallas(
        xf,
        mlp_params["w1"]["w"], mlp_params["w1"]["b"],
        mlp_params["w2"]["w"], mlp_params["w2"]["b"],
        mlp_params["w3"]["w"], mlp_params["w3"]["b"],
        block_t=bt, interpret=_interpret())
    if pad:
        y = y[:T]
    return y.reshape(*lead, y.shape[-1])


def gated_fusion(k_own, v_own, k_proj, v_proj, gate, *, block_s: int = 256):
    """Gated mix over stacked caches (n, B, Hkv, S, hd) + gate (n,)."""
    n, B, H, S, hd = k_own.shape
    rs = lambda a: a.reshape(n, B * H, S, hd)
    bs = min(block_s, S)
    while S % bs:
        bs //= 2
    k, v = gated_fusion_pallas(rs(k_own), rs(v_own), rs(k_proj), rs(v_proj),
                               gate, block_s=bs, interpret=_interpret())
    return k.reshape(k_own.shape), v.reshape(v_own.shape)


def decode_attention(q, k, v, bias, *, block_s: int = 512):
    """Flash decode. q (B,H,hd) with GQA heads, k/v (B,Hkv,S,hd), bias (B,S)."""
    B, H, hd = q.shape
    Hkv = k.shape[1]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, hd)
    S = k.shape[2]
    bs = min(block_s, S)
    while S % bs:
        bs //= 2
    out = decode_attention_pallas(qg, k, v, bias.astype(jnp.float32),
                                  block_s=bs, interpret=_interpret())
    return out.reshape(B, H, hd)


def banded_attention(q, k, v, *, window: int, block: int = 256):
    """Sliding-window prefill attention, O(S·window). q/k/v (B, H, S, hd)."""
    from repro.kernels.banded_attention import banded_attention_pallas
    B, H, S, hd = q.shape
    rs = lambda a: a.reshape(B * H, S, hd)
    blk = min(block, S)
    while S % blk:
        blk //= 2
    out = banded_attention_pallas(rs(q), rs(k), rs(v), window=window,
                                  block=blk, interpret=_interpret())
    return out.reshape(B, H, S, hd)


def decode_attention_q8(q, qstack, bias, *, block_s: int = 512):
    """Flash decode over an int8-quantised cache (core/quant.py layout):
    q (B,H,hd); qstack {"k_q","v_q" int8 (B,Hkv,S,hd), "k_scale","v_scale"}."""
    from repro.kernels.decode_attention import decode_attention_q8_pallas
    B, H, hd = q.shape
    Hkv = qstack["k_q"].shape[1]
    G = H // Hkv
    S = qstack["k_q"].shape[2]
    bs = min(block_s, S)
    while S % bs:
        bs //= 2
    out = decode_attention_q8_pallas(
        q.reshape(B, Hkv, G, hd), qstack["k_q"], qstack["v_q"],
        qstack["k_scale"].astype(jnp.float32),
        qstack["v_scale"].astype(jnp.float32),
        bias.astype(jnp.float32), block_s=bs, interpret=_interpret())
    return out.reshape(B, H, hd)
