"""Pallas TPU kernel: fused 3-layer fuser MLP (the C2C projection hot spot).

Projecting a 32k-token KV cache through F_ij is the dominant *new* compute C2C
adds (paper §Case Study: one MLP per receiver layer over every cached token).
A naive composition launches three matmuls with two HBM round-trips of the
(tokens, d_h) activations; this kernel keeps the whole 3-matmul + SiLU chain
resident in VMEM per token tile:

    HBM -> VMEM:  x tile (block_t, d_in), all three weight mats (once per grid col)
    MXU:          h1 = silu(x@W1+b1); h2 = silu(h1@W2+b2); y = h2@W3+b3
    VMEM -> HBM:  y tile (block_t, d_out)

Tiling: token dim in ``block_t`` rows (multiple of 8 for fp32 / 16 for bf16
sublane packing; we use 128 to align the MXU systolic dim), feature dims are kept
whole (fuser dims are ≤ a few K — weights fit VMEM comfortably; asserted).
Accumulation is fp32 via ``preferred_element_type``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VMEM budget sanity (v5e ≈ 128 MiB; stay well under half for double buffering)
_VMEM_BYTES = 64 * 1024 * 1024


def _kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, w3_ref, b3_ref, o_ref):
    x = x_ref[...]
    h = jnp.dot(x, w1_ref[...], preferred_element_type=jnp.float32)
    h = jax.nn.silu(h + b1_ref[...].astype(jnp.float32))
    h = h.astype(x.dtype)
    h = jnp.dot(h, w2_ref[...], preferred_element_type=jnp.float32)
    h = jax.nn.silu(h + b2_ref[...].astype(jnp.float32))
    h = h.astype(x.dtype)
    y = jnp.dot(h, w3_ref[...], preferred_element_type=jnp.float32)
    y = y + b3_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def fuser_mlp_pallas(
    x: jax.Array,  # (T, d_in) — flattened tokens
    w1: jax.Array, b1: jax.Array,
    w2: jax.Array, b2: jax.Array,
    w3: jax.Array, b3: jax.Array,
    *,
    block_t: int = 128,
    interpret: bool = False,
) -> jax.Array:
    T, d_in = x.shape
    d_h = w1.shape[1]
    d_out = w3.shape[1]
    bt = min(block_t, T)
    if T % bt != 0:
        raise ValueError(
            f"fuser_mlp_pallas: token count {T} not divisible by block_t {bt}")
    wbytes = (w1.size + w2.size + w3.size) * x.dtype.itemsize
    abytes = bt * (d_in + 2 * d_h + d_out) * 4
    if wbytes + abytes >= _VMEM_BYTES:
        raise ValueError(
            f"fuser_mlp_pallas: fuser dims exceed VMEM tiling budget "
            f"({wbytes + abytes} >= {_VMEM_BYTES} bytes)")

    grid = (T // bt,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, d_in), lambda i: (i, 0)),
            pl.BlockSpec((d_in, d_h), lambda i: (0, 0)),
            pl.BlockSpec((d_h,), lambda i: (0,)),
            pl.BlockSpec((d_h, d_h), lambda i: (0, 0)),
            pl.BlockSpec((d_h,), lambda i: (0,)),
            pl.BlockSpec((d_h, d_out), lambda i: (0, 0)),
            pl.BlockSpec((d_out,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bt, d_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((T, d_out), x.dtype),
        interpret=interpret,
    )(x, w1, b1, w2, b2, w3, b3)
