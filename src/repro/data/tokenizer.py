"""Offline-safe tokenizer: byte-level with a few reserved specials.

Real deployments would plug a SentencePiece model here; the framework only needs
encode/decode + vocab_size, so a byte tokenizer keeps everything runnable offline
(and the synthetic corpus uses its own structured vocabulary anyway).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

PAD, BOS, EOS, SEP = 0, 1, 2, 3
N_SPECIAL = 8


@dataclass(frozen=True)
class ByteTokenizer:
    vocab_size: int = 256 + N_SPECIAL

    def encode(self, text: str, *, bos: bool = True, eos: bool = False) -> np.ndarray:
        ids = [b + N_SPECIAL for b in text.encode("utf-8")]
        if bos:
            ids = [BOS] + ids
        if eos:
            ids = ids + [EOS]
        return np.asarray(ids, np.int32)

    def decode(self, ids) -> str:
        bs = bytes(int(i) - N_SPECIAL for i in ids
                   if int(i) >= N_SPECIAL)
        return bs.decode("utf-8", errors="replace")

    def pad_to(self, ids: np.ndarray, length: int) -> np.ndarray:
        out = np.full((length,), PAD, np.int32)
        out[: min(len(ids), length)] = ids[:length]
        return out
