"""Knowledge-partitioned synthetic QA corpus — the offline stand-in for the
paper's OpenHermes-2.5 (fuser training) + OpenBookQA (evaluation) pair.

World model
-----------
Facts are (subject-class, relation-class) -> object triples, partitioned into
``n_domains`` disjoint knowledge domains (one per transmitter, mirroring the
case study's "different models exhibit varying performance across different
tasks"). Every subject/relation class has ``syn_width`` interchangeable surface
tokens — the synonym structure that makes privacy rephrasing (privacy.py)
semantically lossless but surface-destructive.

A QA example is the token sequence  [Q, s, r, A, o]  with loss only on ``o``.
Transmitter t trains on domain t; the receiver trains on a small mixed sample
(weak generalist) — so standalone receiver accuracy is low and collaboration
has headroom, which is the regime Fig. 3(a) probes.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

Q_TOK, A_TOK, SEP_TOK, PAD_TOK = 1, 2, 3, 0


@dataclass(frozen=True)
class WorldSpec:
    n_domains: int = 4
    subj_classes_per_domain: int = 6
    rel_classes: int = 8
    n_objects: int = 64
    syn_width: int = 3
    vocab_size: int = 512
    seed: int = 0
    # Fraction of facts the RECEIVER trains on: it masters the task format and
    # a subset of knowledge; the held-out facts are what federation must supply
    # (the paper's "limited by the model's internal knowledge" regime).
    receiver_known_frac: float = 0.3

    @property
    def n_subj_classes(self) -> int:
        return self.n_domains * self.subj_classes_per_domain

    # --- token id layout ------------------------------------------------
    @property
    def subj_base(self) -> int:
        return 8

    @property
    def rel_base(self) -> int:
        return self.subj_base + self.n_subj_classes * self.syn_width

    @property
    def obj_base(self) -> int:
        return self.rel_base + self.rel_classes * self.syn_width

    def check(self) -> None:
        assert self.obj_base + self.n_objects <= self.vocab_size, "vocab too small"


class World:
    """Materialised fact table + encode/decode helpers."""

    def __init__(self, spec: WorldSpec):
        spec.check()
        self.spec = spec
        rng = np.random.default_rng(spec.seed)
        # fact table: (subj_class, rel_class) -> object id
        self.facts = rng.integers(
            0, spec.n_objects, size=(spec.n_subj_classes, spec.rel_classes))
        # receiver-known mask over facts (see WorldSpec.receiver_known_frac)
        self.known = (np.random.default_rng(spec.seed + 1)
                      .random((spec.n_subj_classes, spec.rel_classes))
                      < spec.receiver_known_frac)

    # ---------------------------------------------------------------- ids
    def subj_token(self, cls: int, syn: int) -> int:
        return self.spec.subj_base + cls * self.spec.syn_width + syn

    def rel_token(self, cls: int, syn: int) -> int:
        return self.spec.rel_base + cls * self.spec.syn_width + syn

    def obj_token(self, obj: int) -> int:
        return self.spec.obj_base + obj

    def domain_of_subj(self, cls: int) -> int:
        return cls // self.spec.subj_classes_per_domain

    # ------------------------------------------------------------ examples
    def qa_example(self, rng, domain: Optional[int] = None,
                   known: Optional[bool] = None) -> Tuple[np.ndarray, np.ndarray]:
        """One [Q, s, r, A, o] example; labels −100 except the answer slot.

        ``known`` filters on the receiver-known mask (True: receiver-trained
        facts; False: held-out facts that only the domain transmitter knows)."""
        sp = self.spec
        for _ in range(64):  # rejection-sample the known filter
            if domain is None:
                s_cls = rng.integers(0, sp.n_subj_classes)
            else:
                s_cls = rng.integers(0, sp.subj_classes_per_domain) \
                    + domain * sp.subj_classes_per_domain
            r_cls = rng.integers(0, sp.rel_classes)
            if known is None or bool(self.known[s_cls, r_cls]) == known:
                break
        obj = self.facts[s_cls, r_cls]
        toks = np.array([
            Q_TOK,
            self.subj_token(s_cls, rng.integers(0, sp.syn_width)),
            self.rel_token(r_cls, rng.integers(0, sp.syn_width)),
            A_TOK,
            self.obj_token(obj),
        ], np.int32)
        labels = np.full_like(toks, -100)
        labels[-1] = toks[-1]
        return toks, labels

    def qa_batch(self, rng, batch: int, seq: int,
                 domain: Optional[int] = None,
                 known: Optional[bool] = None) -> dict:
        """Pack multiple QA examples per row (SEP-separated); next-token labels."""
        toks = np.full((batch, seq), PAD_TOK, np.int32)
        labels = np.full((batch, seq), -100, np.int32)
        for b in range(batch):
            i = 0
            while i + 6 <= seq:
                t, l = self.qa_example(rng, domain, known)
                toks[b, i : i + 5] = t
                labels[b, i : i + 5] = l
                toks[b, i + 5] = SEP_TOK
                i += 6
        # shift: predict token t+1 from t
        shifted = np.full_like(labels, -100)
        shifted[:, :-1] = labels[:, 1:]
        return {"tokens": toks, "labels": shifted}

    def question_batch(self, rng, batch: int, seq: int,
                       domain: Optional[int] = None,
                       known: Optional[bool] = None) -> dict:
        """Packed QUESTION-ONLY rows for fuser training: [Q s r A SEP]* with the
        answer as a (shifted) label at each 'A' position but NEVER in the token
        stream — so a transmitter cache of these rows contains the answer only
        through the transmitter's weights (its upper-layer features at the 'A'
        position), exactly the eval condition. Without this, fuser training can
        cheat by copying answer tokens out of packed QA caches (a failure mode
        we hit and fixed — see benchmarks/common.py)."""
        toks = np.full((batch, seq), PAD_TOK, np.int32)
        labels = np.full((batch, seq), -100, np.int32)
        for b in range(batch):
            i = 0
            while i + 4 <= seq:
                t, _ = self.qa_example(rng, domain, known)
                toks[b, i : i + 4] = t[:4]  # Q s r A — no answer token
                labels[b, i + 3] = t[4]  # predict o right after 'A'
                if i + 4 < seq:
                    toks[b, i + 4] = SEP_TOK
                i += 5
        return {"tokens": toks, "labels": labels}

    def eval_batch(self, rng, batch: int, domain: Optional[int] = None,
                   known: Optional[bool] = None) -> dict:
        """Single question per row: prompt [Q, s, r, A], answer object id."""
        prompts = np.zeros((batch, 4), np.int32)
        answers = np.zeros((batch,), np.int32)
        for b in range(batch):
            t, _ = self.qa_example(rng, domain, known)
            prompts[b] = t[:4]
            answers[b] = t[4]
        return {"prompt": prompts, "answer": answers}

    # ------------------------------------------------------------- privacy
    def synonym_channel(self):
        """ParaphraseChannel over this world's synonym classes (objects and
        specials map to themselves)."""
        import jax.numpy as jnp
        from repro.core.privacy import ParaphraseChannel

        sp = self.spec
        V = sp.vocab_size
        width = sp.syn_width
        class_of = np.arange(V, dtype=np.int64)  # default: singleton class per token
        members = np.arange(V, dtype=np.int64)[:, None].repeat(width, 1)
        next_cls = V  # class ids beyond V for synonym groups, remapped below
        groups = []
        for base, n_cls in ((sp.subj_base, sp.n_subj_classes),
                            (sp.rel_base, sp.rel_classes)):
            for c in range(n_cls):
                ids = base + c * width + np.arange(width)
                groups.append(ids)
        # compact class ids: singletons keep their token id, groups get fresh ids
        all_ids = np.concatenate(groups)
        for g_i, ids in enumerate(groups):
            class_of[ids] = V + g_i
        # remap class ids to dense [0, n)
        uniq, dense = np.unique(class_of, return_inverse=True)
        table = np.zeros((len(uniq), width), np.int64)
        for d_i, u in enumerate(uniq):
            if u < V:  # singleton
                table[d_i] = u
            else:
                table[d_i] = groups[u - V]
        return ParaphraseChannel(class_of=jnp.asarray(dense, jnp.int32),
                                 members=jnp.asarray(table, jnp.int32))


def lm_stream(world: World, seed: int, batch: int, seq: int,
              domain: Optional[int] = None, known: Optional[bool] = None):
    """Infinite batch generator (the data-pipeline hot loop)."""
    rng = np.random.default_rng(seed)
    while True:
        yield world.qa_batch(rng, batch, seq, domain, known)
