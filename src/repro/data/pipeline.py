"""Host-side data pipeline: batching, host sharding, device placement.

For multi-host production the global batch is sharded along the ("pod","data")
mesh axes with ``jax.make_array_from_process_local_data``; on a single process we
fall back to ``device_put`` with the batch NamedSharding. The generators are pure
python (deterministic via seeds) — substrate, not science.
"""
from __future__ import annotations

from typing import Iterator, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def batch_sharding(mesh: Optional[Mesh]) -> Optional[NamedSharding]:
    if mesh is None:
        return None
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    return NamedSharding(mesh, P(tuple(axes)))


def place_batch(batch: dict, mesh: Optional[Mesh] = None) -> dict:
    """Move a host batch (dict of np arrays, leading dim = global batch) to
    devices, sharded along the data axes."""
    if mesh is None:
        return {k: jnp.asarray(v) for k, v in batch.items()}
    sh = batch_sharding(mesh)
    out = {}
    for k, v in batch.items():
        if jax.process_count() > 1:  # pragma: no cover - multi-host path
            out[k] = jax.make_array_from_process_local_data(sh, v)
        else:
            out[k] = jax.device_put(jnp.asarray(v), sh)
    return out


def prefetch(it: Iterator[dict], mesh: Optional[Mesh] = None,
             depth: int = 2) -> Iterator[dict]:
    """Simple software pipeline: keep ``depth`` batches in flight."""
    import collections

    buf = collections.deque()
    for batch in it:
        buf.append(place_batch(batch, mesh))
        if len(buf) >= depth:
            yield buf.popleft()
    while buf:
        yield buf.popleft()
