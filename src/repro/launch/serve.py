"""Serving entry points: the ``serve_step`` the decode shapes lower, plus a
batched-request federated serving driver (examples/serve_federated.py).

serve_step(params, cache, token) is one decode step; serve_prefill builds the
cache. The federated variants thread the C2C fused prefix through (Eq. 4).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.models.cache import FusedPrefix


def make_serve_step(cfg: ModelConfig, *, window_override: int = 0,
                    unroll: bool = False):
    def serve_step(params, cache, token):
        return T.decode_step(cfg, params, cache, token,
                             window_override=window_override, unroll=unroll)
    return serve_step


def make_serve_prefill(cfg: ModelConfig, max_seq: int, *,
                       window_override: int = 0, cache_dtype=jnp.bfloat16,
                       unroll: bool = False):
    def serve_prefill(params, tokens=None, embeds=None, positions_3d=None):
        return T.prefill(cfg, params, tokens, embeds, positions_3d,
                         max_seq=max_seq, cache_dtype=cache_dtype,
                         window_override=window_override, unroll=unroll)
    return serve_prefill


def make_fedrefine_serve_step(cfg_rx: ModelConfig):
    """Decode step with a fused transmitter prefix (the C2C serving hot path)."""
    def serve_step(params, cache, token, fused):
        ek = FusedPrefix.ensure(fused).to_extra_kv(cfg_rx)
        return T.decode_step(cfg_rx, params, cache, token, extra_kv=ek)
    return serve_step


class BatchedServer:
    """Minimal batched-request server: collects requests up to ``max_batch``,
    prefills once, then decodes in lockstep. CPU-scale driver for examples."""

    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 8,
                 max_seq: int = 256):
        self.cfg, self.params = cfg, params
        self.max_batch, self.max_seq = max_batch, max_seq
        self._prefill = jax.jit(make_serve_prefill(cfg, max_seq,
                                                   cache_dtype=jnp.float32))
        self._step = jax.jit(make_serve_step(cfg))

    def serve(self, prompts: jax.Array, gen_steps: int,
              fused: Optional[dict] = None) -> jax.Array:
        B, S = prompts.shape
        assert B <= self.max_batch and S + gen_steps <= self.max_seq
        if fused is not None:
            step = jax.jit(make_fedrefine_serve_step(self.cfg))
            ek = FusedPrefix.ensure(fused).to_extra_kv(self.cfg)
            logits, cache = T.prefill(self.cfg, self.params, prompts,
                                      max_seq=self.max_seq,
                                      cache_dtype=jnp.float32, extra_kv=ek)
        else:
            logits, cache = self._prefill(self.params, prompts)
        tok = jnp.argmax(logits[:, S - 1], axis=-1)
        out = [tok]
        for _ in range(gen_steps - 1):
            if fused is not None:
                lg, cache = step(self.params, cache, tok, fused)
            else:
                lg, cache = self._step(self.params, cache, tok)
            tok = jnp.argmax(lg, axis=-1)
            out.append(tok)
        return jnp.stack(out, axis=1)
