import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (architecture × input shape) against
the production mesh, with NO real allocation (ShapeDtypeStruct inputs only).

For each pair this proves the sharding config is coherent (SPMD partitioning
succeeds, no unsupported collectives), prints memory_analysis (fits 16 GB/chip)
and cost_analysis (FLOPs/bytes), and derives the three roofline terms
(repro.roofline). Results are cached as JSON under experiments/dryrun/ so the
full 40-pair sweep is resumable.

NOTE the two lines above MUST precede any jax import: jax locks the device count
at first init. This is the ONLY entry point that forces 512 host devices —
tests/benches see the real device list.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--force]
"""
import argparse
import functools
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro import roofline as RL
from repro.configs.base import (ARCH_IDS, INPUT_SHAPES, ModelConfig, canonical,
                                get_config)
from repro.launch import sharding as SH
from repro.launch.mesh import make_production_mesh
from repro.launch.serve import make_serve_prefill, make_serve_step
from repro.launch.train import make_train_step
from repro.models import transformer as T
from repro.models.cache import KVCache
from repro.optim.adamw import AdamWConfig, init_opt_state

OUTDIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                      "experiments", "dryrun")


# ------------------------------------------------------------------ variants


def variant_config(cfg: ModelConfig, shape_name: str) -> ModelConfig:
    """long_500k on full-attention archs runs the sliding-window variant
    (DESIGN.md §Arch-applicability); SSM/hybrid run natively."""
    if shape_name != "long_500k":
        return cfg
    if cfg.family in ("ssm", "hybrid"):
        return cfg
    pattern = tuple("swa" if t == "attn" else t for t in cfg.block_pattern)
    return cfg.with_overrides(block_pattern=pattern,
                              sliding_window=cfg.long_context_window)


# ------------------------------------------------------------------ specs


def _struct(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape_name: str, dtype=jnp.bfloat16) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this shape's step."""
    shp = INPUT_SHAPES[shape_name]
    B, S = shp.global_batch, shp.seq_len
    out: dict = {}
    if shp.kind in ("train", "prefill"):
        if cfg.frontend == "vision":
            out["embeds"] = _struct((B, S, cfg.d_model), dtype)
            out["positions_3d"] = _struct((3, B, S), jnp.int32)
        else:
            out["tokens"] = _struct((B, S), jnp.int32)
        if shp.kind == "train":
            out["labels"] = _struct((B, S), jnp.int32)
    else:  # decode: one token against a seq_len cache
        out["token"] = _struct((B,), jnp.int32)
        cache = jax.eval_shape(
            functools.partial(KVCache.init, cfg, B, S, dtype))
        out["cache"] = cache
    return out


def params_specs(cfg: ModelConfig, dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda k: T.init_params(cfg, k, dtype), jax.ShapeDtypeStruct((2,), jnp.uint32))


# ------------------------------------------------------------------ build


def build_lowered(arch: str, shape_name: str, mesh, *, dtype=jnp.bfloat16,
                  layer_override: int = 0, unroll: bool = False):
    """Lower the right step for (arch, shape) against ``mesh``.

    ``layer_override`` + ``unroll`` build a reduced-depth twin with the layer
    loop unrolled, so XLA cost analysis (which counts while bodies once) sees
    every layer — the two-point per-cycle delta is then exact for everything
    outside the flash-attention chunk scans (see EXPERIMENTS.md §Dry-run notes)."""
    cfg = variant_config(get_config(arch), shape_name)
    if layer_override:
        cfg = cfg.with_overrides(num_layers=layer_override)
    shp = INPUT_SHAPES[shape_name]
    B, S = shp.global_batch, shp.seq_len

    p_struct = params_specs(cfg, dtype)
    # FSDP param storage for training; replicated-over-data weights for serving
    p_specs = SH.param_pspecs(cfg, p_struct, mesh, fsdp=(shp.kind == "train"))
    p_shard = SH.to_sharding(mesh, p_specs)
    ins = input_specs(cfg, shape_name, dtype)

    if shp.kind == "train":
        opt_cfg = AdamWConfig(lr=1e-4, schedule="cosine", total_steps=10_000)
        opt_struct = jax.eval_shape(init_opt_state, p_struct)
        opt_specs = SH.opt_pspecs(p_specs, opt_struct, mesh)
        opt_shard = SH.to_sharding(mesh, opt_specs)
        batch_keys = sorted(ins.keys())
        batch_shard = {
            k: SH.to_sharding(mesh, SH.batch_pspec(
                mesh, B, ins[k].ndim - (2 if k == "positions_3d" else 1)))
            for k in batch_keys
        }
        if "positions_3d" in batch_shard:  # (3, B, S): batch is dim 1
            from jax.sharding import PartitionSpec as P
            from repro.launch.mesh import batch_axes
            bspec = SH.batch_pspec(mesh, B, 1)
            batch_shard["positions_3d"] = SH.to_sharding(
                mesh, P(None, bspec[0], None))
        step = make_train_step(cfg, opt_cfg, remat=True, unroll=unroll)
        fn = jax.jit(step,
                     in_shardings=(p_shard, opt_shard, batch_shard),
                     donate_argnums=(0, 1))
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import axis_size, batch_axes
        act = NamedSharding(mesh, P(batch_axes(mesh), "model", None))
        with T.activation_sharding(act, axis_size(mesh, "model")):
            return fn.lower(p_struct, opt_struct, ins), cfg

    if shp.kind == "prefill":
        prefill = make_serve_prefill(cfg, max_seq=S, cache_dtype=dtype,
                                     unroll=unroll)
        kwargs_shard = {}
        args = [p_struct]
        in_shards = [p_shard]
        if cfg.frontend == "vision":
            from jax.sharding import PartitionSpec as P
            bspec = SH.batch_pspec(mesh, B, 1)
            fn = jax.jit(lambda p, e, pos3: prefill(p, embeds=e, positions_3d=pos3),
                         in_shardings=(p_shard,
                                       SH.to_sharding(mesh, SH.batch_pspec(mesh, B, 2)),
                                       SH.to_sharding(mesh, P(None, bspec[0], None))))
            return fn.lower(p_struct, ins["embeds"], ins["positions_3d"]), cfg
        fn = jax.jit(lambda p, t: prefill(p, tokens=t),
                     in_shardings=(p_shard,
                                   SH.to_sharding(mesh, SH.batch_pspec(mesh, B, 1))))
        return fn.lower(p_struct, ins["tokens"]), cfg

    # decode
    serve_step = make_serve_step(cfg, unroll=unroll)
    cache_struct = ins["cache"]
    cache_specs = SH.cache_pspecs(cfg, cache_struct, mesh, B)
    cache_shard = SH.to_sharding(mesh, cache_specs)
    tok_shard = SH.to_sharding(mesh, SH.batch_pspec(mesh, B, 0))
    fn = jax.jit(serve_step,
                 in_shardings=(p_shard, cache_shard, tok_shard),
                 donate_argnums=(1,))
    return fn.lower(p_struct, cache_struct, ins["token"]), cfg


def _with_expert_sharding(fn):
    """Trace-time MoE expert-parallel constraints (models/moe.py) for every
    lowering in this module."""
    @functools.wraps(fn)
    def wrapped(*a, **kw):
        from jax.sharding import Mesh
        from repro.models.moe import expert_sharding
        mesh = next((x for x in a if isinstance(x, Mesh)), kw.get("mesh"))
        with expert_sharding(mesh):
            return fn(*a, **kw)
    return wrapped


build_lowered = _with_expert_sharding(build_lowered)


# --------------------------------------------------------------- federated


def build_federated_lowered(rx_arch: str, tx_arch: str, shape_name: str, mesh,
                            *, dtype=jnp.bfloat16, pre_projected: bool = False,
                            extra_kv_mode: str = "concat",
                            unroll: bool = False, layer_override: int = 0):
    """Lower the FedRefine serving step (Eq. 1/4) at production scale: receiver
    decode over [fused transmitter cache ∘ own cache].

    baseline (pre_projected=False): the fuser projection of the transmitter's
    full cache runs INSIDE the decode step — the literal reading of Eq. 1 where
    C(F_ij, M_i) is formed at decode time.
    optimized (pre_projected=True): the projection is amortised out of the
    token loop (computed once per task at cache-receipt time); the step
    consumes the already-projected stack. §Perf iteration 1 for pair C.
    """
    from repro.core import fuser as F
    from repro.models.cache import FusedPrefix

    cfg_rx = get_config(rx_arch)
    cfg_tx = get_config(tx_arch)
    if layer_override:
        cfg_rx = cfg_rx.with_overrides(num_layers=layer_override)
    shp = INPUT_SHAPES[shape_name]
    B, S = shp.global_batch, shp.seq_len
    assert shp.kind == "decode"
    n_rx = len(cfg_rx.attention_layers)
    n_tx = len(cfg_tx.attention_layers)
    hd_t, hkv_t = cfg_tx.resolved_head_dim, cfg_tx.num_kv_heads
    hd_r, hkv_r = cfg_rx.resolved_head_dim, cfg_rx.num_kv_heads

    p_struct = params_specs(cfg_rx, dtype)
    p_shard = SH.to_sharding(mesh, SH.param_pspecs(cfg_rx, p_struct, mesh))
    cache_struct = jax.eval_shape(
        functools.partial(KVCache.init, cfg_rx, B, S, dtype))
    cache_shard = SH.to_sharding(
        mesh, SH.cache_pspecs(cfg_rx, cache_struct, mesh, B))
    tok_shard = SH.to_sharding(mesh, SH.batch_pspec(mesh, B, 0))

    fuser_struct = jax.eval_shape(
        lambda k: F.init_fuser(cfg_tx, cfg_rx, k, dtype=dtype),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import batch_axes
    baxes = batch_axes(mesh)
    bspec = baxes if B % (16 * (2 if "pod" in mesh.axis_names else 1)) == 0 \
        else None

    if pre_projected:
        fused_struct = FusedPrefix(
            k=_struct((n_rx, B, hkv_r, S, hd_r), dtype),
            v=_struct((n_rx, B, hkv_r, S, hd_r), dtype),
            bias=_struct((n_rx, B, S), jnp.float32),
        )
        fused_shard = SH.to_sharding(mesh, FusedPrefix(
            k=P(None, bspec, None, "model", None),
            v=P(None, bspec, None, "model", None),
            bias=P(None, bspec, None),
        ))

        def step(params, cache, token, fused):
            return T.decode_step(cfg_rx, params, cache, token,
                                 extra_kv=FusedPrefix.ensure(fused).to_extra_kv(cfg_rx),
                                 extra_kv_mode=extra_kv_mode, unroll=unroll)

        fn = jax.jit(step, in_shardings=(p_shard, cache_shard, tok_shard,
                                         fused_shard), donate_argnums=(1,))
        return fn.lower(p_struct, cache_struct,
                        _struct((B,), jnp.int32), fused_struct), cfg_rx

    tx_stack_struct = {
        "k": _struct((n_tx, B, hkv_t, S, hd_t), dtype),
        "v": _struct((n_tx, B, hkv_t, S, hd_t), dtype),
    }
    tx_shard = SH.to_sharding(mesh, {
        "k": P(None, bspec, None, "model", None),
        "v": P(None, bspec, None, "model", None),
    })
    fuser_shard = SH.to_sharding(
        mesh, jax.tree.map(lambda _: P(), fuser_struct))

    def step(params, cache, token, tx_stack, fuser):
        fused = F.project_cache(fuser, cfg_tx, cfg_rx, tx_stack)
        return T.decode_step(cfg_rx, params, cache, token,
                             extra_kv=FusedPrefix.ensure(fused).to_extra_kv(cfg_rx),
                             extra_kv_mode=extra_kv_mode, unroll=unroll)

    fn = jax.jit(step, in_shardings=(p_shard, cache_shard, tok_shard,
                                     tx_shard, fuser_shard),
                 donate_argnums=(1,))
    return fn.lower(p_struct, cache_struct, _struct((B,), jnp.int32),
                    tx_stack_struct, fuser_struct), cfg_rx


build_federated_lowered = _with_expert_sharding(build_federated_lowered)


# ------------------------------------------------------------------ run


def run_pair(arch: str, shape_name: str, *, multi_pod: bool = False,
             force: bool = False, dtype=jnp.bfloat16, save: bool = True,
             tag: str = "") -> dict:
    mesh_name = ("pod2x16x16" if multi_pod else "pod1x16x16") + tag
    os.makedirs(OUTDIR, exist_ok=True)
    outfile = os.path.join(OUTDIR, f"{canonical(arch)}__{shape_name}__{mesh_name}.json")
    if os.path.exists(outfile) and not force:
        with open(outfile) as f:
            return json.load(f)

    t0 = time.time()
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        chips = 512 if multi_pod else 256
        lowered, cfg = build_lowered(arch, shape_name, mesh, dtype=dtype)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        shp = INPUT_SHAPES[shape_name]

        # --- two-point cycle extrapolation for bytes/collectives -----------
        # XLA cost analysis counts while (scan) bodies once; the layer scan is
        # the dominant loop, so we measure per-cycle deltas by compiling the
        # same step at 1 and 2 pattern cycles and extrapolate linearly to the
        # real depth. (Verified: flops(8L) == flops(16L) raw — EXPERIMENTS.md.)
        p = len(cfg.block_pattern)
        cycles = cfg.num_layers // p
        tail = cfg.num_layers % p
        bytes_corr = coll_corr = None
        if cycles > 2:
            costs = []
            for c in (1, 2):
                small, _ = build_lowered(
                    arch, shape_name, mesh, dtype=dtype,
                    layer_override=c * p + tail, unroll=True)
                costs.append(RL.cost_of(small.compile()))
            d_bytes = costs[1]["bytes"] - costs[0]["bytes"]
            d_coll = costs[1]["coll_bytes"] - costs[0]["coll_bytes"]
            bytes_corr = costs[0]["bytes"] + d_bytes * (cycles - 1)
            coll_corr = costs[0]["coll_bytes"] + d_coll * (cycles - 1)

        vcfg = variant_config(get_config(arch), shape_name)
        rl = RL.analyze(
            arch=arch, shape_name=shape_name, mesh_name=mesh_name, chips=chips,
            compiled=compiled,
            model_flops=RL.model_flops_for(cfg, shp, shp.kind),
            analytic_flops=RL.flops_analytic(
                vcfg, shp, shp.kind, remat=(shp.kind == "train")),
            bytes_corrected=bytes_corr, coll_corrected=coll_corr)
        rec.update(rl.to_json())
        rec["ok"] = True
        rec["lower_s"] = round(t1 - t0, 2)
        rec["compile_s"] = round(t2 - t1, 2)
        rec["params"] = cfg.param_count()
        rec["active_params"] = cfg.active_param_count()
    except Exception as e:  # noqa: BLE001 - dry-run failures are data
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    if save:
        with open(outfile, "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def run_federated(rx_arch: str, tx_arch: str, shape_name: str = "decode_32k",
                  *, multi_pod: bool = False, pre_projected: bool = False,
                  extra_kv_mode: str = "concat",
                  force: bool = False, dtype=jnp.bfloat16) -> dict:
    """Dry-run the FedRefine serving step; cached like run_pair."""
    from repro.core.fuser import fuser_dims

    mode = ("preproj" if pre_projected else "inline") + \
        ("_split" if extra_kv_mode == "split" else "")
    mesh_name = "pod2x16x16" if multi_pod else "pod1x16x16"
    os.makedirs(OUTDIR, exist_ok=True)
    outfile = os.path.join(
        OUTDIR, f"FED_{canonical(rx_arch)}__from_{canonical(tx_arch)}"
                f"__{shape_name}__{mesh_name}__{mode}.json")
    if os.path.exists(outfile) and not force:
        with open(outfile) as f:
            return json.load(f)

    rec: dict = {"arch": f"FED:{rx_arch}<-{tx_arch}:{mode}",
                 "shape": shape_name, "mesh": mesh_name}
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        chips = 512 if multi_pod else 256
        lowered, cfg_rx = build_federated_lowered(
            rx_arch, tx_arch, shape_name, mesh, dtype=dtype,
            pre_projected=pre_projected, extra_kv_mode=extra_kv_mode)
        compiled = lowered.compile()
        shp = INPUT_SHAPES[shape_name]
        B, S = shp.global_batch, shp.seq_len

        # analytic flops: receiver decode attending over 2S (prefix + own)
        cfg_tx = get_config(tx_arch)
        base = RL.flops_analytic(cfg_rx, shp, "decode")
        hd, H = cfg_rx.resolved_head_dim, cfg_rx.num_heads
        extra_attn = 2 * 2 * H * hd * S * len(cfg_rx.attention_layers) * B
        fuser_fl = 0.0
        if not pre_projected:
            d_in, d_h, d_out = fuser_dims(cfg_tx, cfg_rx)
            n_rx = len(cfg_rx.attention_layers)
            fuser_fl = 2.0 * B * S * n_rx * (d_in * d_h + d_h * d_h + d_h * d_out)
        analytic = base + extra_attn + fuser_fl

        rl = RL.analyze(
            arch=rec["arch"], shape_name=shape_name, mesh_name=mesh_name,
            chips=chips, compiled=compiled,
            model_flops=RL.model_flops_for(cfg_rx, shp, "decode"),
            analytic_flops=analytic)
        rec.update(rl.to_json())
        rec["fuser_flops"] = fuser_fl
        rec["ok"] = True
        rec["compile_s"] = round(time.time() - t0, 2)
    except Exception as e:  # noqa: BLE001
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    with open(outfile, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def summarize(rec: dict) -> str:
    if not rec.get("ok"):
        return (f"{rec['arch']:20s} {rec['shape']:12s} {rec['mesh']:12s} "
                f"FAIL {rec['error'][:90]}")
    mem = rec.get("memory_per_device") or {}
    peak = mem.get("temp_bytes") or 0
    return (f"{rec['arch']:20s} {rec['shape']:12s} {rec['mesh']:12s} OK "
            f"comp={rec['compute_s']*1e3:8.2f}ms mem={rec['memory_s']*1e3:8.2f}ms "
            f"coll={rec['collective_s']*1e3:8.2f}ms dom={rec['bottleneck']:10s} "
            f"useful={rec['useful_ratio']:5.2f} temp={peak/2**30:6.2f}GiB "
            f"compile={rec.get('compile_s', 0):.0f}s")


def main() -> None:  # pragma: no cover - CLI
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--federated-from", default=None,
                    help="transmitter arch: dry-run the FedRefine serve step "
                         "(receiver = --arch)")
    ap.add_argument("--pre-projected", action="store_true",
                    help="federated: amortise fuser projection out of the step")
    ap.add_argument("--split-prefix", action="store_true",
                    help="federated: LSE-merged split attention (no concat)")
    args = ap.parse_args()

    if args.federated_from:
        rec = run_federated(args.arch, args.federated_from,
                            args.shape or "decode_32k",
                            multi_pod=args.multi_pod,
                            pre_projected=args.pre_projected,
                            extra_kv_mode="split" if args.split_prefix else "concat",
                            force=args.force)
        print(summarize(rec), flush=True)
        return

    pairs = []
    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                pairs.append((a, s, mp))

    for a, s, mp in pairs:
        rec = run_pair(a, s, multi_pod=mp, force=args.force)
        print(summarize(rec), flush=True)


if __name__ == "__main__":
    main()
