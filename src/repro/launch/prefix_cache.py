"""Radix prefix index: longest-match lookup from prompt token IDs (and fused
C2C digests) to already-cached physical KV pages.

At serving scale requests massively share prefixes — system prompts, few-shot
templates, and (unique to this paper) the fused prefix a C2C peer transmitted
once. The engine consults this index at admission: a hit means the matched
prefix's KV already lives in the :class:`~repro.models.cache.SlotTable` pool,
so the new slot *shares* those physical pages (refcounted through
:class:`~repro.models.cache.PageAllocator`) and prefills only the suffix.

Structure
---------
A forest of tries, one root per *fused digest* (``None`` for standalone
requests). Keying by digest is a correctness requirement, not an
optimization: prompt KV depends on the fused prefix the prompt attended
during prefill, so pages are only reusable between requests that fused the
same digest. Each edge consumes one full page worth of tokens
(``page_size``-sized chunks); a node additionally carries a small set of
*partial* entries — sub-page token runs backed by a page whose leading rows
are valid. A partial (or a longer full-page child) can extend a match by
``m < page_size`` tokens: the sharer takes a copy-on-write copy of that page
(its suffix prefill writes position ``P`` inside it — the first divergent
token write), while full-page matches are shared in place, read-only.

Lookup is capped at ``len(prompt) - 1`` tokens: the engine must always
prefill at least the prompt's last token to obtain logits for the first
generated token.

Pinning and eviction
--------------------
The index holds one allocator reference (:meth:`PageAllocator.retain`) per
page it stores, so registered pages survive the registering slot's eviction.
Under pool pressure the engine calls :meth:`RadixPrefixIndex.evict`, which
drops least-recently-used leaves first and only frees a page when no slot
still maps it (the allocator's refcount guarantees this).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.models.cache import PageAllocator


@dataclass
class PrefixMatch:
    """Result of a longest-prefix lookup.

    ``page_ids`` are full pages shareable in place (read-only). A non-None
    ``partial_page`` extends the match by ``partial_tokens`` (< page_size)
    more tokens, but must be CoW-copied by the sharer before its suffix
    prefill writes into it. ``matched`` is the total token count:
    ``len(page_ids) * page_size + partial_tokens``."""

    page_ids: List[int]
    matched: int
    partial_page: Optional[int] = None
    partial_tokens: int = 0


@dataclass
class _Partial:
    tokens: Tuple[int, ...]  # sub-page token run (len < page_size)
    page_id: int             # page whose rows [0, len(tokens)) hold its KV
    last_use: int = 0


@dataclass
class _Node:
    """One full-page trie node: ``page_id`` backs the chunk of tokens on the
    edge leading here; children are keyed by the next page-sized chunk."""

    page_id: int
    children: Dict[Tuple[int, ...], "_Node"] = field(default_factory=dict)
    partials: List[_Partial] = field(default_factory=list)
    last_use: int = 0


@dataclass
class _Root:
    children: Dict[Tuple[int, ...], _Node] = field(default_factory=dict)
    partials: List[_Partial] = field(default_factory=list)


def _lcp(a: Sequence[int], b: Sequence[int]) -> int:
    n = min(len(a), len(b))
    for i in range(n):
        if a[i] != b[i]:
            return i
    return n


class RadixPrefixIndex:
    """Trie over page-sized prompt-token chunks, per fused digest.

    All state is host-side Python/numpy; the only device interaction is
    indirect, through the page ids it hands back."""

    def __init__(self, page_size: int, *,
                 max_partials_per_node: int = 4) -> None:
        self.page_size = page_size
        self.max_partials_per_node = max_partials_per_node
        self._roots: Dict[Optional[str], _Root] = {}
        self._clock = 0  # LRU stamp, bumped on every lookup/register

    # ------------------------------------------------------------ queries
    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    @property
    def num_pages(self) -> int:
        """Pages currently pinned by the index."""
        return sum(self.pin_summary().values())

    def pin_summary(self) -> Dict[str, int]:
        """Pinned-page count per fused digest (``"<standalone>"`` for the
        None root) — the index-side holders in the engine's pool-exhaustion
        report."""
        out: Dict[str, int] = {}
        for digest, root in self._roots.items():
            n = len(root.partials)
            stack = list(root.children.values())
            while stack:
                node = stack.pop()
                n += 1 + len(node.partials)
                stack.extend(node.children.values())
            out[digest if digest is not None else "<standalone>"] = n
        return out

    def lookup(self, digest: Optional[str], tokens: np.ndarray) -> Optional[PrefixMatch]:
        """Longest matching prefix of ``tokens`` under fused key ``digest``,
        capped at ``len(tokens) - 1`` (at least one token must be prefilled).
        Returns None when nothing matches."""
        root = self._roots.get(digest)
        if root is None:
            return None
        toks = [int(t) for t in np.asarray(tokens).reshape(-1)]
        budget = len(toks) - 1
        if budget <= 0:
            return None
        pg = self.page_size
        now = self._tick()

        pages: List[int] = []
        node: Optional[_Node] = None
        children, partials = root.children, root.partials
        off = 0
        while off + pg <= budget:
            child = children.get(tuple(toks[off: off + pg]))
            if child is None:
                break
            child.last_use = now
            pages.append(child.page_id)
            node = child
            children, partials = child.children, child.partials
            off += pg

        # Partial extension: a stored sub-page run — or the leading rows of a
        # full-page child we can't take whole — may cover a few more tokens.
        rest = toks[off: budget]
        best_m, best_page, best_entry = 0, None, None
        for p in partials:
            m = _lcp(p.tokens, rest)
            if m > best_m:
                best_m, best_page, best_entry = m, p.page_id, p
        for chunk, child in children.items():
            m = _lcp(chunk, rest)
            if m > best_m:
                best_m, best_page, best_entry = m, child.page_id, child

        if best_entry is not None:
            best_entry.last_use = now
        matched = off + best_m
        if matched == 0:
            return None
        return PrefixMatch(page_ids=pages, matched=matched,
                           partial_page=best_page, partial_tokens=best_m)

    # ----------------------------------------------------------- register
    def register(self, digest: Optional[str], tokens: np.ndarray,
                 page_ids: Sequence[int], allocator: PageAllocator) -> int:
        """Record that ``tokens``' KV now lives in ``page_ids`` (the owning
        slot's pages, in order). Only *new* trie entries pin pages
        (``allocator.retain``); chunks already present keep their existing
        page. Returns the number of pages newly pinned."""
        toks = [int(t) for t in np.asarray(tokens).reshape(-1)]
        ids = [int(p) for p in page_ids]
        pg = self.page_size
        now = self._tick()
        root = self._roots.setdefault(digest, _Root())
        children, partials = root.children, root.partials

        pinned = 0
        n_full = len(toks) // pg
        if len(ids) < n_full:
            raise ValueError(
                f"{len(toks)} tokens span {n_full} full pages but only "
                f"{len(ids)} page ids were provided")
        for i in range(n_full):
            chunk = tuple(toks[i * pg: (i + 1) * pg])
            child = children.get(chunk)
            if child is None:
                allocator.retain(ids[i])
                pinned += 1
                child = _Node(page_id=ids[i], last_use=now)
                children[chunk] = child
            else:
                child.last_use = now
            children, partials = child.children, child.partials

        rest = tuple(toks[n_full * pg:])
        if rest and len(ids) > n_full:
            # skip if an existing partial (or full child) already covers it
            covered = any(_lcp(p.tokens, rest) == len(rest) for p in partials)
            covered = covered or any(_lcp(c, rest) == len(rest)
                                     for c in children)
            if not covered and len(partials) < self.max_partials_per_node:
                allocator.retain(ids[n_full])
                pinned += 1
                partials.append(
                    _Partial(tokens=rest, page_id=ids[n_full], last_use=now))
        return pinned

    # ------------------------------------------------------------ eviction
    def evict(self, allocator: PageAllocator, want_pages: int) -> int:
        """Drop least-recently-used leaves until ``want_pages`` pages have
        been *freed* (refcount reached zero) or nothing evictable remains.
        Entries whose page is still mapped by a slot release only the index's
        pin — the page stays alive for its sharers. Returns pages freed."""
        freed = 0
        while freed < want_pages:
            victim = self._lru_leaf()
            if victim is None:
                break
            entry, remove = victim
            before = allocator.num_free
            allocator.release([entry.page_id])
            freed += allocator.num_free - before
            remove()
        self._gc_roots()
        return freed

    def _lru_leaf(
        self,
    ) -> Optional[Tuple[Union[_Node, _Partial], Callable[[], None]]]:
        """Oldest evictable entry: a partial, or a full node with no children
        and no partials. Returns (entry, remove-from-parent thunk) or None."""
        best: Optional[Tuple[Union[_Node, _Partial],
                             Callable[[], None]]] = None

        def consider(entry: Union[_Node, _Partial],
                     remove: Callable[[], None]) -> None:
            nonlocal best
            if best is None or entry.last_use < best[0].last_use:
                best = (entry, remove)

        for root in self._roots.values():
            # walk the forest; leaves = no children AND no partials
            nodes = [(root.children, c, n) for c, n in root.children.items()]
            for p in root.partials:
                consider(p, partial(root.partials.remove, p))
            while nodes:
                parent_children, chunk, node = nodes.pop()
                for p in node.partials:
                    consider(p, partial(node.partials.remove, p))
                if not node.children and not node.partials:
                    consider(node, partial(parent_children.__delitem__, chunk))
                nodes.extend((node.children, c, n)
                             for c, n in node.children.items())
        return best

    def _gc_roots(self) -> None:
        empty = [d for d, r in self._roots.items()
                 if not r.children and not r.partials]
        for d in empty:
            del self._roots[d]

    def clear(self, allocator: PageAllocator) -> int:
        """Release every pin (drops the whole index). Returns pages freed."""
        freed = 0
        for root in self._roots.values():
            stack = list(root.children.values())
            before = allocator.num_free
            for p in root.partials:
                allocator.release([p.page_id])
            while stack:
                node = stack.pop()
                allocator.release([node.page_id])
                for p in node.partials:
                    allocator.release([p.page_id])
                stack.extend(node.children.values())
            freed += allocator.num_free - before
        self._roots.clear()
        return freed
