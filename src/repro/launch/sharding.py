"""Logical→mesh sharding rules for every parameter/cache/input tree.

Strategy (baseline; §Perf iterates on it):
  * batch dims        -> ("pod","data") when divisible, else replicated
  * attention heads   -> "model" via the projection output dims
  * FFN hidden        -> "model" (Megatron-style column/row split)
  * MoE experts       -> "model" (expert parallelism)
  * vocab/embedding   -> "model"
  * RG-LRU width      -> "model"
  * SSD (mamba2-130m) -> replicated weights (130 M params; data-parallel only —
                         documented in DESIGN.md; the state dims don't divide 16)
  * KV caches         -> batch on data, kv_heads on "model" when divisible,
                         else head_dim on "model" (MQA archs), else replicated

Specs are derived from the *path names* of the pytree produced by
transformer.init_params, with divisibility checks against the actual mesh, so
any architecture config lowers without hand-tuning.
"""
from __future__ import annotations

import re
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.launch.mesh import axis_size, batch_axes


def _div(n: int, m: int) -> bool:
    return m > 0 and n % m == 0


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):  # GetAttrKey (registered dataclass pytrees)
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


# --------------------------------------------------------------------- params


def _leaf_spec(name: str, shape: tuple, msize: int, fsdp: bool = False) -> P:
    """Spec for an UNSTACKED param leaf (cycle stacking handled by caller)."""
    dims: list = [None] * len(shape)

    def shard_last_if_div():
        if shape and _div(shape[-1], msize):
            dims[-1] = "model"

    def shard_first_if_div():
        if shape and _div(shape[0], msize):
            dims[0] = "model"

    if re.search(r"embed/table$", name):
        # vocab-sharded. (A d-sharded serving variant was tried and REFUTED:
        # the d-sharded lookup output fixes the layer-scan carry sharding to
        # d-sharded, flipping every layer's comm pattern for the worse —
        # EXPERIMENTS.md §Perf iteration B3.)
        if _div(shape[0], msize):
            dims[0] = "model"  # vocab-sharded embedding
    elif re.search(r"lm_head/w$", name):
        shard_last_if_div()
    elif re.search(r"(wq|wk|wv)/(w|b)$", name):
        shard_last_if_div()
    elif re.search(r"wo/w$", name):
        shard_first_if_div()
    elif re.search(r"ffn/(gate|up)/w$", name) or re.search(r"shared/(gate|up)/w$", name):
        shard_last_if_div()
    elif re.search(r"ffn/down/w$", name) or re.search(r"shared/down/w$", name):
        shard_first_if_div()
    elif re.search(r"ffn/(w_gate|w_up)$", name):
        if _div(shape[0], msize):
            dims[0] = "model"  # expert parallelism
        elif _div(shape[-1], msize):
            # experts ∤ mesh (qwen2-moe: 60 on a 16-way axis): TP WITHIN each
            # expert on the hidden dim — otherwise ~25 GiB of expert weights
            # replicate on every chip (EXPERIMENTS.md §Dry-run notes)
            dims[-1] = "model"
    elif re.search(r"ffn/w_down$", name):
        if _div(shape[0], msize):
            dims[0] = "model"
        elif _div(shape[1], msize):
            dims[1] = "model"  # contraction dim: partial-sum AR, Megatron row
    elif re.search(r"rec/(in_main|in_gate)/w$", name):
        shard_last_if_div()
    elif re.search(r"rec/out/w$", name):
        shard_first_if_div()
    elif re.search(r"rec/conv_[wb]$", name):
        shard_last_if_div()
    elif re.search(r"rec/(w_r|w_i)$", name):
        if _div(shape[0], msize):
            dims[0] = "model"  # block-diagonal heads
    elif re.search(r"rec/(b_r|b_i|lam)$", name):
        shard_first_if_div()
    # ssd/* and norms: replicated (see module docstring)
    return P(*dims)


def param_pspecs(cfg: ModelConfig, params, mesh: Mesh, *, fsdp: bool = False):
    """PartitionSpec tree matching ``params``.

    ``fsdp=True`` (training): additionally shards param STORAGE over the batch
    axes (first remaining divisible dim, never the stacked cycle dim) — GSPMD
    all-gathers each layer's weights inside the scan and reduce-scatters its
    grads, i.e. classic FSDP. Without it, params+optimizer of the 30B+ archs
    exceed 16 GB/chip on a 16-way model axis. Serving keeps weights replicated
    over data for latency (fsdp=False)."""
    msize = axis_size(mesh, "model")
    baxes = batch_axes(mesh)
    bsize = 1
    for a in baxes:
        bsize *= axis_size(mesh, a)
    baxis = baxes if len(baxes) > 1 else (baxes[0] if baxes else None)

    def add_data(spec: P, shape: tuple) -> P:
        if not fsdp or baxis is None or bsize <= 1:
            return spec
        dims = list(spec) + [None] * (len(shape) - len(spec))
        for i, (d, n) in enumerate(zip(dims, shape)):
            if d is None and _div(n, bsize):
                dims[i] = baxis
                break
        return P(*dims)

    def spec(path, leaf):
        name = _path_str(path)
        shape = leaf.shape
        stacked = name.startswith("cycle/")
        if stacked:
            inner = add_data(_leaf_spec(name, shape[1:], msize, fsdp), shape[1:])
            return P(None, *inner)
        return add_data(_leaf_spec(name, shape, msize, fsdp), shape)

    return jax.tree_util.tree_map_with_path(spec, params)


# --------------------------------------------------------------------- opt


def opt_pspecs(param_specs, opt_state, mesh: Optional[Mesh] = None):
    """Optimizer state (m, v, master): the param spec PLUS ZeRO-1-style sharding
    of the first remaining divisible dim over the batch axes. fp32 moments are
    3× the bf16 params — without this, 30B+ archs exceed 16 GB/chip before a
    single activation is allocated (EXPERIMENTS.md §Dry-run)."""
    baxes = batch_axes(mesh) if mesh is not None else ()
    bsize = 1
    for a in baxes:
        bsize *= axis_size(mesh, a)

    def _uses_batch_axes(dims) -> bool:
        for d in dims:
            names = d if isinstance(d, tuple) else (d,)
            if any(n in baxes for n in names if n):
                return True
        return False

    def zero1(spec: P, shape: tuple) -> P:
        if not baxes or bsize <= 1:
            return spec
        dims = list(spec) + [None] * (len(shape) - len(spec))
        if _uses_batch_axes(dims):  # FSDP already shards storage over data
            return P(*dims)
        for i, (d, n) in enumerate(zip(dims, shape)):
            if d is None and _div(n, bsize):
                dims[i] = baxes if len(baxes) > 1 else baxes[0]
                break
        return P(*dims)

    def mirror(tree):
        def pick(path, leaf):
            if leaf is None:
                return None
            node = param_specs
            for p in path:
                key = p.key if hasattr(p, "key") else p.idx
                node = node[key]
            return zero1(node, leaf.shape)

        return jax.tree_util.tree_map_with_path(pick, tree,
                                                is_leaf=lambda x: x is None)

    return {
        "step": P(),
        "m": mirror(opt_state["m"]),
        "v": mirror(opt_state["v"]),
        "master": mirror(opt_state["master"]),
    }


# --------------------------------------------------------------------- cache


def cache_pspecs(cfg: ModelConfig, cache, mesh: Mesh, batch: int):
    """Specs for a decode cache pytree (models/cache.KVCache structure)."""
    msize = axis_size(mesh, "model")
    baxes = batch_axes(mesh)
    bsz = 1
    for a in baxes:
        bsz *= axis_size(mesh, a)
    bspec = baxes if _div(batch, bsz) else None
    hkv = cfg.num_kv_heads
    hd = cfg.resolved_head_dim

    def kv_spec(leaf_name: str, shape: tuple) -> P:
        # (cycles, B, Hkv, S, hd)
        if leaf_name.endswith("slot_pos"):
            return P(None, bspec, None)
        if _div(hkv, msize):
            return P(None, bspec, "model", None, None)
        # kv_heads not divisible (GQA 8 on a 16-way axis / MQA): shard the cache
        # SEQUENCE dim instead — decode attention contracts over seq, so GSPMD
        # lowers it to per-shard partial attention + two small all-reduces
        # (flash-decode-style sequence parallelism) rather than resharding the
        # whole cache every step.
        seq = shape[3]
        if _div(seq, msize):
            return P(None, bspec, None, "model", None)
        if _div(hd, msize):
            return P(None, bspec, None, None, "model")
        return P(None, bspec, None, None, None)

    def spec(path, leaf):
        name = _path_str(path)
        if name == "pos":
            return P()
        if name.endswith("/k") or name.endswith("/v"):
            return kv_spec(name, leaf.shape)
        if name.endswith("slot_pos"):
            return P(None, bspec, None)
        if name.endswith("/h"):  # recurrent states
            if leaf.ndim == 3:  # rglru (C, B, W)
                w = leaf.shape[-1]
                return P(None, bspec, "model" if _div(w, msize) else None)
            return P(None, bspec, None, None, None)  # ssd (C,B,nh,hd,ns)
        if name.endswith("/conv"):
            return P(None, bspec, None, None)
        return P()

    return jax.tree_util.tree_map_with_path(spec, cache)


def batch_pspec(mesh: Mesh, batch: int, extra_dims: int = 1) -> P:
    baxes = batch_axes(mesh)
    bsz = 1
    for a in baxes:
        bsz *= axis_size(mesh, a)
    lead = baxes if _div(batch, bsz) else None
    return P(lead, *([None] * extra_dims))


def to_sharding(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P) or x is None,
    )
