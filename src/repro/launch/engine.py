"""Continuous-batching federated serving engine (replaces lockstep serving).

The lockstep ``BatchedServer`` (launch/serve.py) needs every request to arrive
together, decode in unison and finish together — and its fused-prefix path
re-jits a fresh serve step per call. This engine serves the regime the paper's
federation actually targets: heavy *mixed* traffic, where standalone, C2C-fused
and T2T requests with different lengths and arrival times share one device.

Design:

- **Slot table** — a fixed-capacity decode cache (``models/cache.init_slot_cache``)
  whose batch axis is ``max_slots`` request slots, each with its own position
  (the per-slot ``pos`` vector that ``transformer.decode_step`` now understands).
- **Admission queue** — ``submit()`` enqueues; each ``step()`` first admits
  queued requests into free slots (prefill + ``cache_insert_slot``), so
  requests join mid-flight without disturbing in-flight neighbours.
- **Completion path** — a slot is freed the step its request finishes
  (``cache_evict_slot``); stale K/V are masked by the per-slot position, so no
  zeroing is needed and the slot is immediately reusable.
- **One jitted decode step** — the whole slot array decodes in a single jitted
  function with *fixed* shapes: ``max_slots`` rows, ``max_seq`` cache, and a
  per-slot fused C2C prefix padded to a fixed ``max_prefix`` bucket whose
  absent/inactive positions carry ``PREFIX_MASK_BIAS`` (zero attention mass).
  The step therefore traces exactly once, no matter how the standalone /
  C2C-fused / T2T request mix changes (``stats["decode_traces"]`` proves it).

Prefill is bucketed separately (``prompt_bucket``): right-padding a prompt is
exact for *full-attention* layers (causality — pad keys sit after every real
query, and the per-slot position mask hides them). It is NOT exact for
sliding-window ring buffers (pad writes can wrap the ring and evict real
in-window entries) or recurrent/SSD state (carried left-to-right through
pads), so models with swa/rec/ssd layers prefill at the exact prompt length
instead.

Quickstart::

    eng = ContinuousBatchingEngine(cfg, params, max_slots=8, max_seq=128,
                                   max_prefix=16)
    rid_a = eng.submit(prompt_a, max_new_tokens=16)               # standalone
    rid_b = eng.submit(prompt_b, max_new_tokens=8, fused=prefix)  # C2C-fused
    done = eng.drain()      # or eng.step() per tick for online serving
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.models import cache as C


@dataclass
class EngineRequest:
    """One queued request. ``fused`` is an already-projected C2C prefix stack
    {"k","v"[,"bias"]} of shape (n_attn_rx, 1, Hkv, Sf, hd) with Sf <= the
    engine's ``max_prefix`` (see core/c2c.fused_prefix)."""

    rid: int
    prompt: jax.Array  # (1, S) int32
    max_new_tokens: int
    fused: Optional[dict] = None
    protocol: str = "standalone"
    meta: dict = field(default_factory=dict)


@dataclass
class Completion:
    rid: int
    tokens: np.ndarray  # (max_new_tokens,) int32 greedy continuation
    protocol: str
    meta: dict = field(default_factory=dict)


class ContinuousBatchingEngine:
    """Fixed-slot continuous-batching decode engine for one receiver model."""

    def __init__(
        self,
        cfg: ModelConfig,
        params: dict,
        *,
        max_slots: int = 8,
        max_seq: int = 128,
        max_prefix: int = 0,
        cache_dtype=jnp.float32,
        prompt_bucket: Optional[int] = None,
    ):
        if max_prefix and not cfg.attention_layers:
            raise ValueError("fused prefixes need attention layers (C2C medium)")
        self.cfg, self.params = cfg, params
        self.max_slots, self.max_seq = max_slots, max_seq
        self.max_prefix = max_prefix
        self.cache_dtype = cache_dtype
        # exact-length prefill unless the model is pure full-attention:
        # right-padded prompts pollute rec/ssd left-to-right state, and pad
        # writes can wrap a swa ring buffer and evict real in-window entries
        pad_safe = all(k == "attn" for k in cfg.block_pattern)
        self.prompt_bucket = prompt_bucket if pad_safe else None

        self._table = C.init_slot_cache(cfg, max_slots, max_seq, cache_dtype)
        self._tok = jnp.zeros((max_slots,), jnp.int32)
        self._fused = (C.empty_fused_stack(cfg, max_slots, max_prefix, cache_dtype)
                       if max_prefix else None)
        # shared all-masked prefix for standalone admissions (identical every
        # time — build once, not per request)
        self._empty_req_fused = (C.empty_fused_stack(cfg, 1, max_prefix,
                                                     cache_dtype)
                                 if max_prefix else None)
        self._active = np.zeros(max_slots, bool)
        self._slot_rid: List[Optional[int]] = [None] * max_slots
        self._remaining = np.zeros(max_slots, np.int64)
        self._queue: deque = deque()
        self._outputs: Dict[int, list] = {}
        self._req_info: Dict[int, EngineRequest] = {}
        self._ready: List[Completion] = []  # completed at admission (1-token)
        self._next_rid = 0
        self.stats = {"decode_traces": 0, "prefill_traces": 0, "admitted": 0,
                      "completed": 0, "decode_steps": 0}
        self._decode = jax.jit(self._make_decode())
        self._prefill = jax.jit(self._make_prefill())
        self._insert = jax.jit(C.cache_insert_slot)
        self._insert_fused = jax.jit(C.fused_stack_insert_slot)

    # ------------------------------------------------------------- jitted fns
    def _make_decode(self):
        cfg = self.cfg

        def decode(params, table, tok, fused, active):
            self.stats["decode_traces"] += 1  # trace-time: counts compilations
            ek = C.extra_kv_layers(cfg, fused) if fused is not None else None
            logits, new_table = T.decode_step(cfg, params, table, tok,
                                              extra_kv=ek)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            nxt = jnp.where(active, nxt, tok)
            # hold inactive slots in place so their position never grows past
            # max_seq while they wait for the next occupant
            pos = jnp.where(active, new_table["pos"], table["pos"])
            return nxt, {"pos": pos, "layers": new_table["layers"]}

        return decode

    def _make_prefill(self):
        cfg, max_seq, dtype = self.cfg, self.max_seq, self.cache_dtype

        def prefill(params, tokens, fused):
            self.stats["prefill_traces"] += 1
            ek = C.extra_kv_layers(cfg, fused) if fused is not None else None
            logits, cache = T.prefill(cfg, params, tokens, max_seq=max_seq,
                                      cache_dtype=dtype, extra_kv=ek)
            return logits, cache

        return prefill

    # ------------------------------------------------------------- submission
    def submit(self, prompt, max_new_tokens: int, *,
               fused: Optional[dict] = None, protocol: Optional[str] = None,
               meta: Optional[dict] = None) -> int:
        """Queue a request; returns its rid. Joins the running batch at the
        next step() with a free slot."""
        prompt = jnp.asarray(prompt, jnp.int32)
        if prompt.ndim == 1:
            prompt = prompt[None]
        if prompt.shape[0] != 1:
            raise ValueError("submit() takes one request at a time (B=1)")
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        S = int(prompt.shape[1])
        if S + max_new_tokens > self.max_seq:
            raise ValueError(f"prompt({S}) + gen({max_new_tokens}) exceeds "
                             f"max_seq={self.max_seq}")
        if fused is not None:
            if not self.max_prefix:
                raise ValueError("engine built with max_prefix=0 cannot take "
                                 "fused prefixes")
            fused = C.pad_fused_stack(fused, self.max_prefix)
        proto = protocol or ("c2c" if fused is not None else "standalone")
        rid = self._next_rid
        self._next_rid += 1
        req = EngineRequest(rid, prompt, max_new_tokens, fused, proto,
                            meta or {})
        self._queue.append(req)
        self._req_info[rid] = req
        return rid

    # -------------------------------------------------------------- admission
    def _bucket_len(self, S: int) -> int:
        if self.prompt_bucket is None:
            return S
        b = ((S + self.prompt_bucket - 1) // self.prompt_bucket
             ) * self.prompt_bucket
        return min(b, self.max_seq)

    def _free_slots(self) -> List[int]:
        return [i for i in range(self.max_slots) if not self._active[i]]

    def _admit(self) -> None:
        free = deque(self._free_slots())
        while self._queue and free:
            req = self._queue.popleft()
            S = int(req.prompt.shape[1])
            Sb = self._bucket_len(S)
            toks = jnp.pad(req.prompt, ((0, 0), (0, Sb - S)))
            fused = req.fused
            if self.max_prefix and fused is None:
                # standalone rides the same prefill trace as fused requests
                fused = self._empty_req_fused
            logits, cache1 = self._prefill(self.params, toks, fused)
            first = jnp.argmax(logits[0, S - 1]).astype(jnp.int32)
            self._outputs[req.rid] = [first]
            self.stats["admitted"] += 1
            if req.max_new_tokens == 1:  # done at prefill: never takes a slot
                self._ready.append(self._finish(req.rid))
                continue
            slot = free.popleft()
            self._table = self._insert(self._table, jnp.int32(slot), cache1,
                                       jnp.int32(S))
            self._tok = self._tok.at[slot].set(first)
            if self._fused is not None:
                self._fused = self._insert_fused(self._fused, jnp.int32(slot),
                                                 fused)
            self._active[slot] = True
            self._slot_rid[slot] = req.rid
            self._remaining[slot] = req.max_new_tokens - 1

    # ------------------------------------------------------------- completion
    def _finish(self, rid: int) -> Completion:
        req = self._req_info.pop(rid)
        toks = np.asarray(jnp.stack(self._outputs.pop(rid)), np.int32)
        self.stats["completed"] += 1
        return Completion(rid, toks, req.protocol, req.meta)

    # ------------------------------------------------------------------ step
    def step(self) -> List[Completion]:
        """Admit what fits, decode one token for every active slot, free any
        slot whose request just finished. Returns the completions."""
        self._admit()
        done, self._ready = self._ready, []
        if not self._active.any():
            return done
        self._tok, self._table = self._decode(
            self.params, self._table, self._tok, self._fused,
            jnp.asarray(self._active))
        self.stats["decode_steps"] += 1
        tok_host = np.asarray(self._tok)
        for s in np.nonzero(self._active)[0]:
            rid = self._slot_rid[s]
            self._outputs[rid].append(tok_host[s])
            self._remaining[s] -= 1
            if self._remaining[s] == 0:
                self._active[s] = False
                self._slot_rid[s] = None
                self._table = C.cache_evict_slot(self._table, int(s))
                done.append(self._finish(rid))
        return done

    # ----------------------------------------------------------------- drain
    def drain(self) -> List[Completion]:
        """Run until the queue and every slot are empty."""
        out: List[Completion] = []
        while self._queue or self._active.any():
            out.extend(self.step())
        out.extend(self._ready)
        self._ready = []
        return out

    # ----------------------------------------------------------------- intro
    @property
    def num_active(self) -> int:
        return int(self._active.sum())

    @property
    def num_queued(self) -> int:
        return len(self._queue)
