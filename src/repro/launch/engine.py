"""Continuous-batching federated serving engine (replaces lockstep serving).

The lockstep ``BatchedServer`` (launch/serve.py) needs every request to arrive
together, decode in unison and finish together — and its fused-prefix path
re-jits a fresh serve step per call. This engine serves the regime the paper's
federation actually targets: heavy *mixed* traffic, where standalone, C2C-fused
and T2T requests with different lengths and arrival times share one device.

Design:

- **Slot table** — a fixed-capacity decode cache whose batch axis is
  ``max_slots`` request slots, each with its own position (the per-slot
  ``pos`` vector ``transformer.decode_step`` understands). Two layouts:

  * *dense* (default): ``models/cache.KVCache.init_slots`` — every slot owns a
    full ``max_seq`` row. Simple, and the byte-identity reference.
  * *paged* (``paged=True``): ``models/cache.SlotTable`` — K/V pages live in a
    shared pool; each slot maps ``ceil(tokens/page_size)`` physical pages. At
    a fixed pool budget (``num_pages``) the engine sustains far more
    concurrent slots than dense whenever requests are shorter than
    ``max_seq`` — benchmarks/engine_bench.py shows ≥2× at equal HBM with
    byte-identical decode outputs. Pages are allocated host-side at admission
    (enough for prompt + max_new_tokens, so decode never allocates) and
    returned to the free list on completion. The decode step attends
    **in place**: the paged Pallas kernel (kernels/paged_attention.py) walks
    each slot's page map with scalar prefetch, reading only the pages that
    hold live tokens — no per-step ``dense_view()`` gather, no ``commit()``
    scatter-back (``paged_attention="gather"`` keeps the old gathered-view
    path as the debug/parity reference; engine_bench pins the two paths
    token-identical and reports the HBM bytes saved).

- **Admission queue** — ``submit()`` enqueues; each ``step()`` first admits
  queued requests into free slots, so requests join mid-flight without
  disturbing in-flight neighbours. With ``admit_batch > 1``, up to that many
  same-bucket-length requests share ONE prefill forward (batch-admission
  prefill); the prefill always runs at batch width ``admit_batch`` (short
  batches padded with zero-token rows, whose outputs are discarded — safe
  because inference MoE is dropless, so pad rows can't steal capacity), so
  it still traces once per prompt bucket.

- **Completion path** — a slot is freed the step its request finishes; stale
  K/V are masked by the per-slot position, so no zeroing is needed and the
  slot is immediately reusable.

- **One jitted decode step** — the whole slot array decodes in a single jitted
  function with *fixed* shapes: ``max_slots`` rows, ``max_seq`` cache (paged:
  the gathered page view), and a per-slot fused C2C prefix padded to a fixed
  ``max_prefix`` bucket whose absent/inactive positions carry
  ``PREFIX_MASK_BIAS`` (zero attention mass). The step therefore traces
  exactly once, no matter how the standalone / C2C-fused / T2T request mix
  changes (``stats["decode_traces"]`` proves it).

Prefill is bucketed separately (``prompt_bucket``): right-padding a prompt is
exact for *full-attention* layers (causality — pad keys sit after every real
query, and the per-slot position mask hides them). It is NOT exact for
sliding-window ring buffers (pad writes can wrap the ring and evict real
in-window entries) or recurrent/SSD state (carried left-to-right through
pads), so models with swa/rec/ssd layers prefill at the exact prompt length
instead. Paged mode likewise requires a pure full-attention model (stateful
layers have O(1)-per-slot cost — nothing to page).

Quickstart::

    eng = ContinuousBatchingEngine(cfg, params, max_slots=8, max_seq=128,
                                   max_prefix=16)
    rid_a = eng.submit(prompt_a, max_new_tokens=16)               # standalone
    rid_b = eng.submit(prompt_b, max_new_tokens=8, fused=prefix)  # C2C-fused
    done = eng.drain()      # or eng.step() per tick for online serving

    # paged: 32 slots over a 16-slot-equivalent page pool
    eng = ContinuousBatchingEngine(cfg, params, max_slots=32, max_seq=128,
                                   paged=True, page_size=16,
                                   num_pages=16 * 128 // 16)
"""
from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.models.cache import FusedPrefix, KVCache, SlotTable


@dataclass
class EngineRequest:
    """One queued request. ``fused`` is an already-projected C2C prefix
    (models/cache.FusedPrefix, shapes (n_attn_rx, 1, Hkv, Sf, hd)) with
    Sf <= the engine's ``max_prefix`` (see core/c2c.fused_prefix)."""

    rid: int
    prompt: jax.Array  # (1, S) int32
    max_new_tokens: int
    fused: Optional[FusedPrefix] = None
    protocol: str = "standalone"
    meta: dict = field(default_factory=dict)


@dataclass
class Completion:
    rid: int
    tokens: np.ndarray  # (max_new_tokens,) int32 greedy continuation
    protocol: str
    meta: dict = field(default_factory=dict)


class ContinuousBatchingEngine:
    """Fixed-slot continuous-batching decode engine for one receiver model."""

    def __init__(
        self,
        cfg: ModelConfig,
        params: dict,
        *,
        max_slots: int = 8,
        max_seq: int = 128,
        max_prefix: int = 0,
        cache_dtype=jnp.float32,
        prompt_bucket: Optional[int] = None,
        admit_batch: int = 1,
        paged: bool = False,
        page_size: int = 16,
        num_pages: Optional[int] = None,
        paged_attention: str = "kernel",
    ):
        if max_prefix and not cfg.attention_layers:
            raise ValueError("fused prefixes need attention layers (C2C medium)")
        if admit_batch < 1:
            raise ValueError("admit_batch must be >= 1")
        if paged_attention not in ("kernel", "gather"):
            raise ValueError(f"paged_attention must be 'kernel' (in-place "
                             f"Pallas walk) or 'gather' (dense_view "
                             f"reference), got {paged_attention!r}")
        self.cfg, self.params = cfg, params
        self.max_slots, self.max_seq = max_slots, max_seq
        self.max_prefix = max_prefix
        self.cache_dtype = cache_dtype
        self.admit_batch = admit_batch
        self.paged = paged
        self.page_size = page_size
        self.paged_attention = paged_attention
        # exact-length prefill unless the model is pure full-attention:
        # right-padded prompts pollute rec/ssd left-to-right state, and pad
        # writes can wrap a swa ring buffer and evict real in-window entries
        pad_safe = all(k == "attn" for k in cfg.block_pattern)
        self.prompt_bucket = prompt_bucket if pad_safe else None

        if paged:
            # page pool + per-slot page maps; allocation policy lives here
            # (host), scatter/gather in models/cache.SlotTable (device)
            self._table = SlotTable.init(cfg, max_slots, max_seq, cache_dtype,
                                         page_size=page_size,
                                         num_pages=num_pages)
            self._free_pages: List[int] = list(range(self._table.num_pages))
            self._slot_pages: Dict[int, List[int]] = {}
        else:
            self._table = KVCache.init_slots(cfg, max_slots, max_seq,
                                             cache_dtype)
        self._tok = jnp.zeros((max_slots,), jnp.int32)
        self._fused = (FusedPrefix.empty(cfg, max_slots, max_prefix,
                                         cache_dtype)
                       if max_prefix else None)
        # shared all-masked prefix for standalone admissions (identical every
        # time — build once, not per request)
        self._empty_req_fused = (FusedPrefix.empty(cfg, 1, max_prefix,
                                                   cache_dtype)
                                 if max_prefix else None)
        self._active = np.zeros(max_slots, bool)
        self._slot_rid: List[Optional[int]] = [None] * max_slots
        self._remaining = np.zeros(max_slots, np.int64)
        self._queue: deque = deque()
        self._outputs: Dict[int, list] = {}
        self._req_info: Dict[int, EngineRequest] = {}
        self._ready: List[Completion] = []  # completed at admission (1-token)
        self._next_rid = 0
        self.stats = {"decode_traces": 0, "prefill_traces": 0, "admitted": 0,
                      "completed": 0, "decode_steps": 0, "admit_batches": 0,
                      "peak_active": 0, "decode_view_gathers": 0}
        self._decode = jax.jit(self._make_decode())
        self._prefill = jax.jit(self._make_prefill())
        if paged:
            self._insert = jax.jit(
                lambda table, slot, req, length, pages, bi:
                table.insert_slot(slot, req, length, pages, batch_index=bi))
        else:
            self._insert = jax.jit(
                lambda table, slot, req, length, bi:
                table.insert_slot(slot, req, length, batch_index=bi))
        self._insert_fused = jax.jit(
            lambda table, slot, req: table.insert_slot(slot, req))

    # ------------------------------------------------------------- jitted fns
    def _make_decode(self):
        cfg, paged = self.cfg, self.paged
        in_place = paged and self.paged_attention == "kernel"

        def decode(params, table, tok, fused, active):
            self.stats["decode_traces"] += 1  # trace-time: counts compilations
            ek = fused.to_extra_kv(cfg) if fused is not None else None
            if in_place:
                # paged hot loop: decode_step dispatches on the SlotTable and
                # walks page maps inside the Pallas kernel — no dense_view()
                # gather, no commit() scatter-back
                logits, new_table = T.decode_step(cfg, params, table, tok,
                                                  extra_kv=ek)
                nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                nxt = jnp.where(active, nxt, tok)
                # hold inactive slots in place so their position never grows
                # past max_seq while they wait for the next occupant
                return nxt, new_table.with_pos(
                    jnp.where(active, new_table.pos, table.pos))
            if paged:  # gather reference path (debug/parity)
                self.stats["decode_view_gathers"] += 1
            view = table.dense_view() if paged else table
            logits, new_view = T.decode_step(cfg, params, view, tok,
                                             extra_kv=ek)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            nxt = jnp.where(active, nxt, tok)
            pos = jnp.where(active, new_view.pos, table.pos)
            if paged:
                # scatter this step's tokens back to their physical pages;
                # unmapped (inactive) slots are dropped by the scatter
                new_table = table.commit(new_view, pos)
            else:
                new_table = KVCache(pos=pos, layers=new_view.layers)
            return nxt, new_table

        return decode

    def _make_prefill(self):
        cfg, max_seq, dtype = self.cfg, self.max_seq, self.cache_dtype

        def prefill(params, tokens, fused):
            self.stats["prefill_traces"] += 1
            ek = fused.to_extra_kv(cfg) if fused is not None else None
            logits, cache = T.prefill(cfg, params, tokens, max_seq=max_seq,
                                      cache_dtype=dtype, extra_kv=ek)
            return logits, cache

        return prefill

    # ------------------------------------------------------------- submission
    def submit(self, prompt, max_new_tokens: int, *,
               fused=None, protocol: Optional[str] = None,
               meta: Optional[dict] = None) -> int:
        """Queue a request; returns its rid. Joins the running batch at the
        next step() with a free slot."""
        prompt = jnp.asarray(prompt, jnp.int32)
        if prompt.ndim == 1:
            prompt = prompt[None]
        if prompt.shape[0] != 1:
            raise ValueError("submit() takes one request at a time (B=1)")
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        S = int(prompt.shape[1])
        if S + max_new_tokens > self.max_seq:
            raise ValueError(f"prompt({S}) + gen({max_new_tokens}) exceeds "
                             f"max_seq={self.max_seq}")
        if self.paged and max_new_tokens > 1:  # 1-token: answered at prefill
            need = math.ceil((S + max_new_tokens - 1) / self.page_size)
            if need > self._table.num_pages:
                raise ValueError(
                    f"request needs {need} pages but the pool only has "
                    f"{self._table.num_pages}; it could never be admitted")
        if fused is not None:
            if not self.max_prefix:
                raise ValueError("engine built with max_prefix=0 cannot take "
                                 "fused prefixes")
            fused = FusedPrefix.ensure(fused).pad(self.max_prefix)
        proto = protocol or ("c2c" if fused is not None else "standalone")
        rid = self._next_rid
        self._next_rid += 1
        req = EngineRequest(rid, prompt, max_new_tokens, fused, proto,
                            meta or {})
        self._queue.append(req)
        self._req_info[rid] = req
        return rid

    # -------------------------------------------------------------- admission
    def _bucket_len(self, S: int) -> int:
        if self.prompt_bucket is None:
            return S
        b = ((S + self.prompt_bucket - 1) // self.prompt_bucket
             ) * self.prompt_bucket
        return min(b, self.max_seq)

    def _free_slots(self) -> List[int]:
        return [i for i in range(self.max_slots) if not self._active[i]]

    def _pages_needed(self, req: EngineRequest) -> int:
        # Highest position ever *written* is S + max_new - 2 (the final
        # generated token is emitted, never cached), so pages must cover
        # S + max_new - 1 slots. Bucket padding beyond S never becomes
        # visible (the position mask hides [S, ·), and decode rewrites each
        # index — in the gathered view, before attention — the step it first
        # would be exposed), so unallocated tail pages are never read.
        S = int(req.prompt.shape[1])
        return math.ceil((S + req.max_new_tokens - 1) / self.page_size)

    def _take_admission_batch(self, n_free: int) -> List[EngineRequest]:
        """Pop up to ``admit_batch`` same-bucket-length requests that fit the
        free slots (and, paged, the free page pool). FIFO at the head: if the
        front request cannot be placed, nothing is admitted this step."""
        if not self._queue:
            return []
        head = self._queue[0]
        Sb = self._bucket_len(int(head.prompt.shape[1]))
        pages_left = len(self._free_pages) if self.paged else None
        batch: List[EngineRequest] = []
        taken_idx: List[int] = []
        for i, req in enumerate(self._queue):
            if len(batch) == self.admit_batch:
                break
            if self._bucket_len(int(req.prompt.shape[1])) != Sb:
                if i == 0:
                    return []  # unreachable (head defines Sb), kept for shape
                continue
            takes_slot = req.max_new_tokens > 1
            if takes_slot and n_free - sum(
                    r.max_new_tokens > 1 for r in batch) <= 0:
                break
            if self.paged and takes_slot:
                need = self._pages_needed(req)
                if need > pages_left:
                    if i == 0:
                        return []  # head-of-line blocked on pages: wait
                    continue
                pages_left -= need
            batch.append(req)
            taken_idx.append(i)
        for i in reversed(taken_idx):
            del self._queue[i]
        return batch

    def _admit(self) -> None:
        while self._queue:
            free = deque(self._free_slots())
            if not free:
                break
            batch = self._take_admission_batch(len(free))
            if not batch:
                break
            Sb = self._bucket_len(int(batch[0].prompt.shape[1]))
            B = self.admit_batch
            toks = jnp.concatenate(
                [jnp.pad(r.prompt, ((0, 0), (0, Sb - r.prompt.shape[1])))
                 for r in batch]
                + [jnp.zeros((B - len(batch), Sb), jnp.int32)], axis=0)
            fused_b = None
            if self.max_prefix:
                # standalone members ride the same prefill trace as fused ones
                per_req = [r.fused if r.fused is not None
                           else self._empty_req_fused for r in batch]
                per_req += [self._empty_req_fused] * (B - len(batch))
                fused_b = FusedPrefix(
                    k=jnp.concatenate([f.k for f in per_req], axis=1),
                    v=jnp.concatenate([f.v for f in per_req], axis=1),
                    bias=jnp.concatenate([f.bias for f in per_req], axis=1))
            logits, cache_b = self._prefill(self.params, toks, fused_b)
            self.stats["admit_batches"] += 1
            for b, req in enumerate(batch):
                S = int(req.prompt.shape[1])
                first = jnp.argmax(logits[b, S - 1]).astype(jnp.int32)
                self._outputs[req.rid] = [first]
                self.stats["admitted"] += 1
                if req.max_new_tokens == 1:  # done at prefill: no slot taken
                    self._ready.append(self._finish(req.rid))
                    continue
                slot = free.popleft()
                if self.paged:
                    need = self._pages_needed(req)
                    pages = [self._free_pages.pop() for _ in range(need)]
                    self._slot_pages[slot] = pages
                    page_ids = np.full((self._table.pages_per_slot,),
                                       self._table.invalid_page, np.int32)
                    page_ids[:need] = pages
                    self._table = self._insert(
                        self._table, jnp.int32(slot), cache_b, jnp.int32(S),
                        jnp.asarray(page_ids), jnp.int32(b))
                else:
                    self._table = self._insert(
                        self._table, jnp.int32(slot), cache_b, jnp.int32(S),
                        jnp.int32(b))
                self._tok = self._tok.at[slot].set(first)
                if self._fused is not None:
                    req_fused = (req.fused if req.fused is not None
                                 else self._empty_req_fused)
                    self._fused = self._insert_fused(
                        self._fused, jnp.int32(slot), req_fused)
                self._active[slot] = True
                self._slot_rid[slot] = req.rid
                self._remaining[slot] = req.max_new_tokens - 1
            self.stats["peak_active"] = max(self.stats["peak_active"],
                                            int(self._active.sum()))

    # ------------------------------------------------------------- completion
    def _finish(self, rid: int) -> Completion:
        req = self._req_info.pop(rid)
        toks = np.asarray(jnp.stack(self._outputs.pop(rid)), np.int32)
        self.stats["completed"] += 1
        return Completion(rid, toks, req.protocol, req.meta)

    def _evict(self, slot: int) -> None:
        self._table = self._table.evict_slot(slot)
        if self.paged:
            self._free_pages.extend(self._slot_pages.pop(slot, []))

    # ------------------------------------------------------------------ step
    def step(self) -> List[Completion]:
        """Admit what fits, decode one token for every active slot, free any
        slot whose request just finished. Returns the completions."""
        self._admit()
        done, self._ready = self._ready, []
        if not self._active.any():
            return done
        self._tok, self._table = self._decode(
            self.params, self._table, self._tok, self._fused,
            jnp.asarray(self._active))
        self.stats["decode_steps"] += 1
        tok_host = np.asarray(self._tok)
        for s in np.nonzero(self._active)[0]:
            rid = self._slot_rid[s]
            self._outputs[rid].append(tok_host[s])
            self._remaining[s] -= 1
            if self._remaining[s] == 0:
                self._active[s] = False
                self._slot_rid[s] = None
                self._evict(int(s))
                done.append(self._finish(rid))
        return done

    # ----------------------------------------------------------------- drain
    def drain(self) -> List[Completion]:
        """Run until the queue and every slot are empty."""
        out: List[Completion] = []
        while self._queue or self._active.any():
            out.extend(self.step())
        out.extend(self._ready)
        self._ready = []
        return out

    # ----------------------------------------------------------------- intro
    @property
    def num_active(self) -> int:
        return int(self._active.sum())

    @property
    def num_queued(self) -> int:
        return len(self._queue)

    @property
    def kv_table_bytes(self) -> int:
        """HBM held by the slot table's K/V payload (the capacity-vs-budget
        bench metric: dense = slots × max_seq rows; paged = the page pool).
        Excludes the int32 bookkeeping (pos / page map — KBs, not MBs)."""
        from repro.models.cache import tree_bytes

        return tree_bytes(self._table.layers)

    def kv_read_bytes_per_step(self) -> Dict[str, int]:
        """Analytic KV HBM bytes one decode step reads, at the engine's
        *current* occupancy (call it mid-flight).

        ``paged_kernel`` counts only the pages that hold live tokens — what
        the in-place kernel DMAs (Σ_active ceil((pos+1)/page_size) pages).
        ``dense_gather`` counts every slot's full row — what the
        ``dense_view()`` gather path reads no matter how little of each slot
        is live (slots × view_seq for paged-gather, slots × max_seq dense).
        k + v, summed over all stacked attention layer entries."""
        itemsize = jnp.dtype(self.cache_dtype).itemsize
        n_entries = sum(int(e["k"].shape[0]) for e in self._table.layers)
        row_bytes = 2 * self.cfg.num_kv_heads * self.cfg.resolved_head_dim \
            * itemsize * n_entries  # k+v bytes per cached token
        pos = np.asarray(self._table.pos)
        if self.paged:
            pg = self.page_size
            live = pos[self._active] + 1
            pages = int(np.sum(-(-live // pg)))  # ceil
            view_seq = self._table.view_seq
            return {"paged_kernel": pages * pg * row_bytes,
                    "dense_gather": self.max_slots * view_seq * row_bytes}
        return {"paged_kernel": 0,
                "dense_gather": self.max_slots * self.max_seq * row_bytes}
