"""Continuous-batching federated serving engine (replaces lockstep serving).

The lockstep ``BatchedServer`` (launch/serve.py) needs every request to arrive
together, decode in unison and finish together — and its fused-prefix path
re-jits a fresh serve step per call. This engine serves the regime the paper's
federation actually targets: heavy *mixed* traffic, where standalone, C2C-fused
and T2T requests with different lengths and arrival times share one device.

Design:

- **Slot table** — a fixed-capacity decode cache whose batch axis is
  ``max_slots`` request slots, each with its own position (the per-slot
  ``pos`` vector ``transformer.decode_step`` understands). Two layouts:

  * *dense* (default): ``models/cache.KVCache.init_slots`` — every slot owns a
    full ``max_seq`` row. Simple, and the byte-identity reference.
  * *paged* (``paged=True``): ``models/cache.SlotTable`` — K/V pages live in a
    shared pool; each slot maps ``ceil(tokens/page_size)`` physical pages. At
    a fixed pool budget (``num_pages``) the engine sustains far more
    concurrent slots than dense whenever requests are shorter than
    ``max_seq`` — benchmarks/engine_bench.py shows ≥2× at equal HBM with
    byte-identical decode outputs. Pages are allocated host-side at admission
    (enough for prompt + max_new_tokens, so decode never allocates) and
    returned to the free list on completion. The decode step attends
    **in place**: the paged Pallas kernel (kernels/paged_attention.py) walks
    each slot's page map with scalar prefetch, reading only the pages that
    hold live tokens — no per-step ``dense_view()`` gather, no ``commit()``
    scatter-back (``paged_attention="gather"`` keeps the old gathered-view
    path as the debug/parity reference; engine_bench pins the two paths
    token-identical and reports the HBM bytes saved).

- **Admission queue** — ``submit()`` enqueues; each ``step()`` first admits
  queued requests into free slots, so requests join mid-flight without
  disturbing in-flight neighbours. With ``admit_batch > 1``, up to that many
  same-bucket-length requests share ONE prefill forward (batch-admission
  prefill); the prefill always runs at batch width ``admit_batch`` (short
  batches padded with zero-token rows, whose outputs are discarded — safe
  because inference MoE is dropless, so pad rows can't steal capacity), so
  it still traces once per prompt bucket.

- **Completion path** — a slot is freed the step its request finishes; stale
  K/V are masked by the per-slot position, so no zeroing is needed and the
  slot is immediately reusable.

- **One jitted decode step** — the whole slot array decodes in a single jitted
  function with *fixed* shapes: ``max_slots`` rows, ``max_seq`` cache (paged:
  the gathered page view), and a per-slot fused C2C prefix padded to a fixed
  ``max_prefix`` bucket whose absent/inactive positions carry
  ``PREFIX_MASK_BIAS`` (zero attention mass). The step therefore traces
  exactly once, no matter how the standalone / C2C-fused / T2T request mix
  changes (``stats["decode_traces"]`` proves it).

- **Page sharing (paged only, ``prefix_cache=True``)** — page bookkeeping is
  owned by a typed, refcounted ``models/cache.PageAllocator`` (the engine
  holds ``PageLease`` handles, never raw page-id lists), and admission
  consults a ``launch/prefix_cache.RadixPrefixIndex`` mapping (fused digest,
  prompt tokens) → already-cached physical pages. On a hit the new slot
  *shares* the matched pages (read-only) and prefills only the suffix — the
  cached prefix is gathered into ``extra_kv`` and RoPE positions are shifted
  by ``pos_offset`` — so a shared-system-prompt workload admits with a
  fraction of the pages and prefill FLOPs (benchmarks/engine_bench.py's
  shared-prefix section shows ≥2× concurrent slots at byte-identical
  outputs). A partially-matched page is copy-on-write: the allocator's
  ``cow`` fault swaps the share for a private copy before the suffix's first
  divergent token write lands in it. Fused C2C prefixes are shared by
  *digest*: the per-slot fused table became a row table with host-side row
  indirection, so a prefix a peer transmitted once is inserted once and every
  later request fusing the same digest just points its slot at that row.

- **Chunked prefill (paged only, ``prefill_token_budget=N``)** — a monolithic
  prefill of a long prompt stalls every in-flight decode behind one huge
  forward (the long-prompt p99 tail). With a token budget set, admission only
  *reserves* a slot + page lease; each ``step()`` then spends at most ``N``
  prompt tokens — across the oldest partially-prefilled prompts — before
  decoding, so decode latency is bounded by the budget, not the longest
  prompt. Chunks run through ``transformer.prefill_chunk``: K/V scatter
  straight into the lease's pool pages and the ragged varlen flash-prefill
  kernel (kernels/prefill_attention.py) attends causally over radix-shared
  prefix pages, earlier chunks and the current chunk in one pass — no dense
  staging cache, no ``extra_kv`` prefix gather. The call width is always
  exactly ``N`` (ragged tails padded with dead rows the kernel zero-masks),
  so chunked prefill traces ONCE per engine regardless of prompt lengths or
  chunk counts. Mid-prefill the slot is invisible to decode: its device
  page-map row stays INVALID (decode writes drop) until the final chunk
  adopts the lease row (``SlotTable.adopt_slot``) and publishes the first
  generated token. Radix hits still share matched pages (CoW on a partial
  page) at reservation time — only the unmatched tail is chunked.

- **Sanitizer (paged only, ``sanitize=True``)** — the allocator is built as
  ``analysis/sanitizer.PageSanitizer``, a PageAllocator subclass carrying
  per-page shadow holders with grant-site provenance. The engine reports
  every device write it issues (``note_write``: prefill inserts, suffix
  scatters, CoW copies, per-step decode writes) and hands over its device
  state after each step (``check_step``); ``drain()`` raises on a non-empty
  leak report. Leaks, double-releases, evict-while-shared and
  shared-writes-without-CoW surface at the offending step, named by the
  allocation site — with ``sanitize=False`` (default) no sanitizer exists
  and decode outputs are byte-identical either way.

Prefill is bucketed separately (``prompt_bucket``): right-padding a prompt is
exact for *full-attention* layers (causality — pad keys sit after every real
query, and the per-slot position mask hides them). It is NOT exact for
sliding-window ring buffers (pad writes can wrap the ring and evict real
in-window entries) or recurrent/SSD state (carried left-to-right through
pads), so models with swa/rec/ssd layers prefill at the exact prompt length
instead. Paged mode likewise requires a pure full-attention model (stateful
layers have O(1)-per-slot cost — nothing to page).

Quickstart::

    eng = ContinuousBatchingEngine(cfg, params, max_slots=8, max_seq=128,
                                   max_prefix=16)
    rid_a = eng.submit(prompt_a, max_new_tokens=16)               # standalone
    rid_b = eng.submit(prompt_b, max_new_tokens=8, fused=prefix)  # C2C-fused
    done = eng.drain()      # or eng.step() per tick for online serving

    # paged: 32 slots over a 16-slot-equivalent page pool
    eng = ContinuousBatchingEngine(cfg, params, max_slots=32, max_seq=128,
                                   paged=True, page_size=16,
                                   num_pages=16 * 128 // 16)
"""
from __future__ import annotations

import math
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.sanitizer import PageSanitizer, SanitizerError
from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.models.cache import (FusedPrefix, KVCache, PageAllocator,
                                PageLease, SlotTable, fused_digest)
from repro.launch.prefix_cache import PrefixMatch, RadixPrefixIndex


@dataclass
class EngineRequest:
    """One queued request. ``fused`` is an already-projected C2C prefix
    (models/cache.FusedPrefix, shapes (n_attn_rx, 1, Hkv, Sf, hd)) with
    Sf <= the engine's ``max_prefix`` (see core/c2c.fused_prefix).
    ``digest`` is the fused prefix's content digest (None for standalone) —
    the identity under which fused rows and prompt pages are shared."""

    rid: int
    prompt: jax.Array  # (1, S) int32
    max_new_tokens: int
    fused: Optional[FusedPrefix] = None
    protocol: str = "standalone"
    meta: dict = field(default_factory=dict)
    digest: Optional[str] = None


@dataclass
class Completion:
    rid: int
    tokens: np.ndarray  # (max_new_tokens,) int32 greedy continuation
    protocol: str
    meta: dict = field(default_factory=dict)


@dataclass
class _PartialPrefill:
    """One prompt mid-chunked-prefill: slot + lease reserved, prompt tokens
    ``[0, done)`` already resident in the lease's pages (a radix-shared
    prefix counts), the slot still inactive and its device page-map row
    still INVALID until the final chunk adopts it."""

    req: EngineRequest
    slot: int
    lease: PageLease
    row: np.ndarray  # (pages_per_slot,) int32 lease page row, INVALID-padded
    done: int        # tokens already resident (shared prefix + prior chunks)
    matched: int     # tokens served by the radix hit at reservation time
    host_prompt: np.ndarray  # (S,) int32 host copy: chunk slicing must not
    #                          pay a device sync per per-step chunk call


class ContinuousBatchingEngine:
    """Fixed-slot continuous-batching decode engine for one receiver model."""

    def __init__(
        self,
        cfg: ModelConfig,
        params: dict,
        *,
        max_slots: int = 8,
        max_seq: int = 128,
        max_prefix: int = 0,
        cache_dtype=jnp.float32,
        prompt_bucket: Optional[int] = None,
        admit_batch: int = 1,
        paged: bool = False,
        page_size: int = 16,
        num_pages: Optional[int] = None,
        paged_attention: str = "kernel",
        prefix_cache: bool = True,
        sanitize: bool = False,
        prefill_token_budget: Optional[int] = None,
    ):
        if max_prefix and not cfg.attention_layers:
            raise ValueError("fused prefixes need attention layers (C2C medium)")
        if admit_batch < 1:
            raise ValueError("admit_batch must be >= 1")
        if paged_attention not in ("kernel", "gather"):
            raise ValueError(f"paged_attention must be 'kernel' (in-place "
                             f"Pallas walk) or 'gather' (dense_view "
                             f"reference), got {paged_attention!r}")
        if sanitize and not paged:
            raise ValueError("sanitize=True checks page lifecycles and "
                             "needs paged=True (dense slots own no pages)")
        self.cfg, self.params = cfg, params
        self.max_slots, self.max_seq = max_slots, max_seq
        self.max_prefix = max_prefix
        self.cache_dtype = cache_dtype
        self.admit_batch = admit_batch
        self.paged = paged
        self.page_size = page_size
        self.paged_attention = paged_attention
        # exact-length prefill unless the model is pure full-attention:
        # right-padded prompts pollute rec/ssd left-to-right state, and pad
        # writes can wrap a swa ring buffer and evict real in-window entries
        pad_safe = all(k == "attn" for k in cfg.block_pattern)
        self.prompt_bucket = prompt_bucket if pad_safe else None

        if prefill_token_budget is not None:
            if prefill_token_budget < 1:
                raise ValueError("prefill_token_budget must be >= 1")
            if not paged:
                raise ValueError("prefill_token_budget (chunked prefill) "
                                 "needs paged=True — chunks scatter straight "
                                 "into pool pages")
            if not pad_safe:
                raise ValueError("chunked prefill requires a pure "
                                 "full-attention block pattern; "
                                 f"{cfg.name} has {cfg.block_pattern}")
        self.prefill_budget = prefill_token_budget
        # the ragged kernel's query-block size must divide the chunk width;
        # one full-width block per chunk call minimises grid points (the
        # kernel masks dead rows, so a partial final chunk stays exact)
        self._chunk_bq = prefill_token_budget if prefill_token_budget else 0
        self._partials: "deque[_PartialPrefill]" = deque()

        self.prefix_cache = bool(prefix_cache and paged)

        if paged:
            # page pool + per-slot page maps; the typed PageAllocator is the
            # only authority over page ids (refcounts, sharing, CoW) — the
            # engine holds PageLease handles; device scatter/gather lives in
            # models/cache.SlotTable
            self._table = SlotTable.init(cfg, max_slots, max_seq, cache_dtype,
                                         page_size=page_size,
                                         num_pages=num_pages)
            # PageSanitizer IS a PageAllocator (analysis/sanitizer.py): same
            # refcounts plus shadow holder/provenance state the engine feeds
            # through note_write/check_step hooks below
            self._san: Optional[PageSanitizer] = (
                PageSanitizer(self._table.num_pages) if sanitize else None)
            self._allocator: Optional[PageAllocator] = (
                self._san if self._san is not None
                else PageAllocator(self._table.num_pages))
            self._allocator.holders_hook = self._pool_holders
            self._leases: Dict[int, PageLease] = {}
        else:
            self._table = KVCache.init_slots(cfg, max_slots, max_seq,
                                             cache_dtype)
            self._san = None
            self._allocator = None
        self._radix = (RadixPrefixIndex(page_size)
                       if self.prefix_cache else None)
        self._tok = jnp.zeros((max_slots,), jnp.int32)
        # Fused prefixes live in a ROW table (max_slots usable rows + one
        # permanently all-masked row at index max_slots for standalone slots);
        # each slot points at a row via the host-side _fused_rows indirection,
        # so requests sharing a digest share one inserted row.
        self._fused = (FusedPrefix.empty(cfg, max_slots + 1, max_prefix,
                                         cache_dtype)
                       if max_prefix else None)
        self._fused_rows = np.full(max_slots, max_slots, np.int64)
        self._fused_alloc = PageAllocator(max_slots)  # rows, refcounted
        self._fused_digest_rows: "OrderedDict[str, int]" = OrderedDict()
        # shared all-masked prefix for standalone admissions (identical every
        # time — build once, not per request)
        self._empty_req_fused = (FusedPrefix.empty(cfg, 1, max_prefix,
                                                   cache_dtype)
                                 if max_prefix else None)
        self._active = np.zeros(max_slots, bool)
        self._slot_rid: List[Optional[int]] = [None] * max_slots
        self._remaining = np.zeros(max_slots, np.int64)
        self._queue: deque = deque()
        self._outputs: Dict[int, list] = {}
        self._req_info: Dict[int, EngineRequest] = {}
        self._ready: List[Completion] = []  # completed at admission (1-token)
        self._next_rid = 0
        self.stats = {"decode_traces": 0, "prefill_traces": 0, "admitted": 0,
                      "completed": 0, "decode_steps": 0, "admit_batches": 0,
                      "peak_active": 0, "decode_view_gathers": 0,
                      "prefill_tokens": 0, "prefill_chunks": 0,
                      "suffix_prefill_traces": 0,
                      "shared_admits": 0, "radix_hits": 0,
                      "radix_matched_tokens": 0, "cow_copies": 0,
                      "fused_inserts": 0, "fused_digest_hits": 0}
        self._decode = jax.jit(self._make_decode())
        self._prefill = jax.jit(self._make_prefill())
        if paged:
            self._insert = jax.jit(
                lambda table, slot, req, length, pages, bi:
                table.insert_slot(slot, req, length, pages, batch_index=bi))
        else:
            self._insert = jax.jit(
                lambda table, slot, req, length, bi:
                table.insert_slot(slot, req, length, batch_index=bi))
        self._insert_fused = jax.jit(
            lambda table, slot, req: table.insert_slot(slot, req))
        if self.prefix_cache:
            self._suffix_prefill = jax.jit(self._make_suffix_prefill())
            self._copy_page = jax.jit(
                lambda table, src, dst: table.copy_page(src, dst))
        if self.prefill_budget:
            self._chunk_prefill = jax.jit(self._make_chunk_prefill())

    # ------------------------------------------------------------- jitted fns
    def _make_decode(self):
        cfg, paged = self.cfg, self.paged
        in_place = paged and self.paged_attention == "kernel"

        def decode(params, table, tok, fused, fused_rows, active):
            self.stats["decode_traces"] += 1  # trace-time compile count; lint: allow(trace-side-effect)
            ek = None
            if fused is not None:
                # row indirection: slots sharing a digest gather the same row
                # (standalone slots gather the permanently-masked empty row)
                sel = FusedPrefix(k=fused.k[:, fused_rows],
                                  v=fused.v[:, fused_rows],
                                  bias=fused.bias[:, fused_rows])
                ek = sel.to_extra_kv(cfg)
            if in_place:
                # paged hot loop: decode_step dispatches on the SlotTable and
                # walks page maps inside the Pallas kernel — no dense_view()
                # gather, no commit() scatter-back
                logits, new_table = T.decode_step(cfg, params, table, tok,
                                                  extra_kv=ek)
                nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                nxt = jnp.where(active, nxt, tok)
                # hold inactive slots in place so their position never grows
                # past max_seq while they wait for the next occupant
                return nxt, new_table.with_pos(
                    jnp.where(active, new_table.pos, table.pos))
            if paged:  # gather reference path (debug/parity)
                self.stats["decode_view_gathers"] += 1  # lint: allow(trace-side-effect)
            view = table.dense_view() if paged else table
            logits, new_view = T.decode_step(cfg, params, view, tok,
                                             extra_kv=ek)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            nxt = jnp.where(active, nxt, tok)
            pos = jnp.where(active, new_view.pos, table.pos)
            if paged:
                # scatter this step's tokens back to their physical pages;
                # unmapped (inactive) slots are dropped by the scatter
                new_table = table.commit(new_view, pos)
            else:
                new_table = KVCache(pos=pos, layers=new_view.layers)
            return nxt, new_table

        return decode

    def _make_prefill(self):
        cfg, max_seq, dtype = self.cfg, self.max_seq, self.cache_dtype

        def prefill(params, tokens, fused):
            self.stats["prefill_traces"] += 1  # lint: allow(trace-side-effect)
            ek = fused.to_extra_kv(cfg) if fused is not None else None
            logits, cache = T.prefill(cfg, params, tokens, max_seq=max_seq,
                                      cache_dtype=dtype, extra_kv=ek)
            return logits, cache

        return prefill

    def _make_suffix_prefill(self):
        """Radix-hit admission: prefill only the prompt's uncached suffix.

        The matched prefix's KV is gathered from its (shared) pages into an
        ``extra_kv`` prefix — fixed gather width (pages_per_slot pages) with
        positions ≥ ``prefix_len`` masked at PREFIX_MASK_BIAS, so the fn
        traces once per suffix bucket. RoPE positions are shifted by
        ``prefix_len`` (transformer.prefill's ``pos_offset``); the suffix's
        K/V rows are scattered to their per-token (page, offset) targets and
        the slot adopts the full shared+fresh page row in one fused step."""
        cfg, dtype = self.cfg, self.cache_dtype

        def sprefill(params, table, toks, prefix_pages, prefix_len, fused,
                     phys, off, page_row, slot, final_pos):
            self.stats["suffix_prefill_traces"] += 1  # lint: allow(trace-side-effect)
            ek = table.prefix_extra_kv(prefix_pages, prefix_len)
            if fused is not None:
                # fused C2C prefix precedes the cached prompt prefix, same
                # order as the fresh prefill path
                fek = fused.to_extra_kv(cfg)
                ek = [FusedPrefix.concat([f, p])
                      if f is not None and p is not None else p
                      for f, p in zip(fek, ek)]
            logits, cache = T.prefill(cfg, params, toks,
                                      max_seq=int(toks.shape[1]),
                                      cache_dtype=dtype, extra_kv=ek,
                                      pos_offset=prefix_len)
            table = table.insert_suffix(slot, cache, phys, off, page_row,
                                        final_pos)
            return logits, table

        return sprefill

    def _make_chunk_prefill(self):
        """One token-budget chunk of one prompt, straight into pool pages.

        The call width is ALWAYS ``prefill_token_budget`` (ragged tails ride
        as dead rows: pad writes drop through INVALID page ids and the
        ragged kernel zero-masks their outputs), and every other operand is
        fixed-shape or a traced scalar — so the fn traces exactly once per
        engine no matter how prompt lengths, chunk counts or radix hits
        vary (``stats["prefill_traces"]`` counts it).

        All per-chunk operands ride in ONE packed int32 vector ``meta`` =
        [pos_offset, n_live, slot, adopt_len, page_row(pps), toks(C)]: a
        chunk call is a single host->device transfer plus a single
        dispatch, instead of six eager transfers — on the chunk scheduler's
        per-step hot path that overhead is comparable to the kernel
        itself."""
        cfg, bq = self.cfg, self._chunk_bq
        pps = self.max_seq // self.page_size

        def cprefill(params, table, tok, meta, fused):
            self.stats["prefill_traces"] += 1  # lint: allow(trace-side-effect)
            pos_offset, n_live = meta[0], meta[1]
            slot, adopt_len = meta[2], meta[3]
            page_row = meta[4:4 + pps]
            toks = meta[4 + pps:].reshape(1, -1)
            ek = fused.to_extra_kv(cfg) if fused is not None else None
            logits, table = T.prefill_chunk(cfg, params, table, toks,
                                            pos_offset, n_live, page_row,
                                            block_q=bq, extra_kv=ek)
            # greedy next token off the chunk's last live row, in-jit: only
            # the final chunk's value is used, but computing it here spares
            # the activation path an eager argmax dispatch per admission
            first = jnp.argmax(logits[0, n_live - 1]).astype(jnp.int32)
            # final chunk of a multi-token request (adopt_len = prompt
            # length, else 0): adopt the page row and install the first
            # token in one fused dispatch — an eager adopt + at[].set here
            # would add two device round-trips to every activation step
            adopt = adopt_len > 0
            table = jax.lax.cond(
                adopt,
                lambda t: t.adopt_slot(slot, page_row, adopt_len),
                lambda t: t, table)
            tok = jnp.where(adopt & (jnp.arange(tok.shape[0]) == slot),
                            first, tok)
            return first, tok, table

        return cprefill

    # ------------------------------------------------------------- submission
    def submit(self, prompt, max_new_tokens: int, *,
               fused=None, digest: Optional[str] = None,
               protocol: Optional[str] = None,
               meta: Optional[dict] = None) -> int:
        """Queue a request; returns its rid. Joins the running batch at the
        next step() with a free slot.

        ``digest`` names the fused prefix's content identity (computed from
        its bytes when omitted): requests sharing a digest share one inserted
        fused row, and — with the prefix cache — can share prompt pages."""
        prompt = jnp.asarray(prompt, jnp.int32)
        if prompt.ndim == 1:
            prompt = prompt[None]
        if prompt.shape[0] != 1:
            raise ValueError("submit() takes one request at a time (B=1)")
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        S = int(prompt.shape[1])
        if S >= self.max_seq:
            # checked before the combined bound so the degenerate case gets
            # its own name: bucket rounding (_bucket_len) clamps at max_seq,
            # and a prompt that large would land in a bucket with zero
            # headroom for even the first decoded token
            raise ValueError(
                f"prompt({S}) fills the whole max_seq={self.max_seq} cache: "
                "no headroom for the first decoded token")
        if S + max_new_tokens > self.max_seq:
            raise ValueError(f"prompt({S}) + gen({max_new_tokens}) exceeds "
                             f"max_seq={self.max_seq}")
        # 1-token requests are answered at prefill and own no pages — except
        # under chunked prefill, which leases pages for the prompt itself
        if self.paged and (max_new_tokens > 1 or self.prefill_budget):
            need = math.ceil((S + max_new_tokens - 1) / self.page_size)
            if need > self._table.num_pages:
                raise ValueError(
                    f"request needs {need} pages but the pool only has "
                    f"{self._table.num_pages}; it could never be admitted")
        if fused is not None:
            if not self.max_prefix:
                raise ValueError("engine built with max_prefix=0 cannot take "
                                 "fused prefixes")
            fused = FusedPrefix.ensure(fused).pad(self.max_prefix)
            if digest is None:
                digest = fused_digest(fused)
        else:
            digest = None  # standalone requests share the None radix root
        proto = protocol or ("c2c" if fused is not None else "standalone")
        rid = self._next_rid
        self._next_rid += 1
        req = EngineRequest(rid, prompt, max_new_tokens, fused, proto,
                            meta or {}, digest)
        self._queue.append(req)
        self._req_info[rid] = req
        return rid

    # -------------------------------------------------------------- admission
    def _bucket_len(self, S: int) -> int:
        """Prefill width for an S-token prompt (or prompt suffix): rounded up
        to the bucket, clamped at ``max_seq``. The clamp is only sound while
        the *exact* length leaves decode headroom — a prompt of ``max_seq``
        itself would round into a bucket with zero room for the first decoded
        token, so that degenerate case is rejected here (submit() already
        refuses it with its own message; this guard covers direct callers)."""
        if S >= self.max_seq:
            raise ValueError(
                f"cannot bucket {S} token(s): max_seq={self.max_seq} leaves "
                "no headroom for the first decoded token")
        if self.prompt_bucket is None:
            return S
        b = ((S + self.prompt_bucket - 1) // self.prompt_bucket
             ) * self.prompt_bucket
        return min(b, self.max_seq)

    def _free_slots(self) -> List[int]:
        # a slot mid-chunked-prefill is inactive but reserved (_slot_rid set)
        return [i for i in range(self.max_slots)
                if not self._active[i] and self._slot_rid[i] is None]

    def _pages_needed(self, req: EngineRequest) -> int:
        # Highest position ever *written* is S + max_new - 2 (the final
        # generated token is emitted, never cached), so pages must cover
        # S + max_new - 1 slots. Bucket padding beyond S never becomes
        # visible (the position mask hides [S, ·), and decode rewrites each
        # index — in the gathered view, before attention — the step it first
        # would be exposed), so unallocated tail pages are never read.
        S = int(req.prompt.shape[1])
        return math.ceil((S + req.max_new_tokens - 1) / self.page_size)

    def _radix_match(self, req: EngineRequest) -> Optional[PrefixMatch]:
        """Longest cached-prefix match for a slot-taking request (1-token
        requests are answered at prefill and never own pages)."""
        if self._radix is None or req.max_new_tokens <= 1:
            return None
        return self._radix.lookup(req.digest, np.asarray(req.prompt[0]))

    def _defer_for_sharing(self, head: EngineRequest,
                           req: EngineRequest) -> bool:
        """True when a queued request should sit out this *fresh* admission
        batch because a later _admit pass can admit it shared: it already has
        a radix hit, or it shares a leading prefix (same fused digest) with
        the head about to register its pages."""
        if req.max_new_tokens <= 1:
            return False
        if head.max_new_tokens > 1 and req.digest == head.digest:
            ta = np.asarray(head.prompt[0])
            tb = np.asarray(req.prompt[0])
            # any nonzero lcp can match after head registers (full-page nodes
            # share in place; partials extend via CoW)
            if tb.size > 1 and ta.size and int(ta[0]) == int(tb[0]):
                return True
        return self._radix.lookup(req.digest,
                                  np.asarray(req.prompt[0])) is not None

    def _take_admission_batch(self, n_free: int) -> List[EngineRequest]:
        """Pop up to ``admit_batch`` same-bucket-length requests that fit the
        free slots (and, paged, the free page pool). FIFO at the head: if the
        front request cannot be placed, nothing is admitted this step. With
        the prefix cache on, requests that could share the head's pages are
        left queued for a shared admission on a later pass this same step."""
        if not self._queue:
            return []
        head = self._queue[0]
        Sb = self._bucket_len(int(head.prompt.shape[1]))
        pages_left = 0
        if self.paged:
            assert self._allocator is not None
            pages_left = self._allocator.num_free
        batch: List[EngineRequest] = []
        taken_idx: List[int] = []
        for i, req in enumerate(self._queue):
            if len(batch) == self.admit_batch:
                break
            if self._bucket_len(int(req.prompt.shape[1])) != Sb:
                if i == 0:
                    return []  # unreachable (head defines Sb), kept for shape
                continue
            if self._radix is not None and i > 0 and \
                    self._defer_for_sharing(head, req):
                continue
            takes_slot = req.max_new_tokens > 1
            if takes_slot and n_free - sum(
                    r.max_new_tokens > 1 for r in batch) <= 0:
                break
            if self.paged and takes_slot:
                need = self._pages_needed(req)
                if need > pages_left:
                    if i == 0:
                        return []  # head-of-line blocked on pages: wait
                    continue
                pages_left -= need
            batch.append(req)
            taken_idx.append(i)
        for i in reversed(taken_idx):
            del self._queue[i]
        return batch

    def _ensure_pages(self, need: int) -> bool:
        """Make ``need`` pages allocatable, evicting LRU prefix-index entries
        under pool pressure (only pages no slot still maps actually free)."""
        assert self._allocator is not None
        if self._allocator.can_alloc(need):
            return True
        if self._radix is not None:
            self._radix.evict(self._allocator, need - self._allocator.num_free)
        return self._allocator.can_alloc(need)

    def _pool_holders(self) -> str:
        """Who holds the page pool right now — attached to the allocator's
        pool-exhaustion RuntimeError (``PageAllocator.holders_hook``) so an
        admission failure names the slots, index pins and (under
        ``sanitize=True``) the grant sites responsible."""
        lines: List[str] = []
        for s in sorted(self._leases):
            lease = self._leases[s]
            lines.append(f"  slot {s} (rid={self._slot_rid[s]}): "
                         f"{lease.num_pages} page(s)")
        if self._radix is not None:
            for name, n in sorted(self._radix.pin_summary().items()):
                lines.append(f"  prefix index [{name[:16]}]: "
                             f"{n} pinned page(s)")
        if self._san is not None:
            detail = self._san.describe_holders()
            if detail:
                lines.append("  sanitizer grant sites:")
                lines.extend("  " + ln for ln in detail.splitlines())
        return "\n".join(lines)

    def _register_prefix(self, req: EngineRequest, lease: PageLease) -> None:
        """Publish an admitted prompt's pages to the radix index (pins them,
        so they outlive the slot). Keyed by the request's fused digest —
        prompt KV depends on the fused prefix attended during prefill."""
        if self._radix is None:
            return
        self._radix.register(req.digest, np.asarray(req.prompt[0]),
                             lease.ids(), self._allocator)

    def _assign_fused_row(self, slot: int, req: EngineRequest) -> None:
        """Point ``slot`` at its fused row: the permanently-masked empty row
        for standalone requests; otherwise the digest's existing row (one
        insert amortized over every sharer) or a freshly inserted one."""
        if self._fused is None:
            return
        if req.fused is None:
            self._fused_rows[slot] = self.max_slots
            return
        row = self._fused_digest_rows.get(req.digest)
        if row is not None:
            self._fused_alloc.share([row])  # the slot's reference
            self._fused_digest_rows.move_to_end(req.digest)
            self.stats["fused_digest_hits"] += 1
        else:
            if not self._fused_alloc.can_alloc(1):
                self._evict_fused_rows(1)
            row = self._fused_alloc.alloc(1)[0]
            self._fused = self._insert_fused(self._fused, jnp.int32(row),
                                             req.fused)
            self._fused_alloc.retain(row)  # the digest table's pin
            self._fused_digest_rows[req.digest] = row
            self.stats["fused_inserts"] += 1
        self._fused_rows[slot] = row

    def _evict_fused_rows(self, want: int) -> None:
        """Drop LRU digest pins whose row no active slot references. Always
        succeeds for ``want=1``: rows ≥ max_slots ≥ active slots, so some
        digest is always pin-only when the row pool is full."""
        for digest in list(self._fused_digest_rows):
            if self._fused_alloc.num_free >= want:
                return
            row = self._fused_digest_rows[digest]
            if self._fused_alloc.refcount(row) == 1:  # pin only
                self._fused_alloc.release([row])
                del self._fused_digest_rows[digest]

    def _admit_shared(self, req: EngineRequest, slot: int,
                      match: PrefixMatch) -> bool:
        """Admit one radix-hit request: share the matched pages, CoW-copy a
        partially-matched page, prefill only the suffix. Returns False if the
        pool can't cover the request's unshared pages (head-of-line waits)."""
        S = int(req.prompt.shape[1])
        pg = self.page_size
        P = match.matched  # tokens served from cache (≤ S - 1)
        total = self._pages_needed(req)
        shared_ids = list(match.page_ids)
        cow_idx = None
        if match.partial_page is not None:
            shared_ids.append(match.partial_page)
            cow_idx = len(shared_ids) - 1
        fresh = total - len(shared_ids)
        if not self._ensure_pages(fresh + (1 if cow_idx is not None else 0)):
            return False
        assert self._allocator is not None
        lease = self._allocator.lease(shared=shared_ids, fresh=fresh)
        if self._san is not None:
            self._san.annotate(lease, slot=slot, rid=req.rid,
                               digest=req.digest)
        if cow_idx is not None:
            # the suffix prefill writes position P inside the partially
            # matched page — its first divergent token write — so the CoW
            # fault copies that page before the slot maps it writable
            src, dst = self._allocator.cow(lease, cow_idx)
            self._table = self._copy_page(self._table, jnp.int32(src),
                                          jnp.int32(dst))
            if self._san is not None:
                self._san.note_write([dst], lease, what="cow page copy")
            self.stats["cow_copies"] += 1
        pps, invalid = self._table.pages_per_slot, self._table.invalid_page
        row = lease.page_row(pps, invalid)
        # prefix gather reads the slot's own row: shared full pages plus the
        # CoW copy (same bytes as its source), INVALID-padded to fixed width
        n_prefix_pages = math.ceil(P / pg)
        prefix_pages = np.full(pps, invalid, np.int32)
        prefix_pages[:n_prefix_pages] = row[:n_prefix_pages]

        Ssuf = S - P
        Sb = self._bucket_len(Ssuf)
        toks = jnp.pad(req.prompt[:, P:], ((0, 0), (0, Sb - Ssuf)))
        # per-token scatter targets: suffix row i holds absolute position
        # P + i → page (P+i)//pg at offset (P+i)%pg; pad rows drop (INVALID)
        abs_pos = P + np.arange(Sb)
        page_idx = np.minimum(abs_pos // pg, pps - 1)
        phys = np.where(abs_pos < S, row[page_idx], invalid).astype(np.int32)
        off = (abs_pos % pg).astype(np.int32)
        if self._san is not None:
            # the suffix scatter must only touch pages the lease OWNS: fresh
            # pages and the CoW copy, never the shared full-prefix pages
            self._san.note_write(np.unique(phys[phys != invalid]), lease,
                                 what=f"suffix prefill (slot {slot})")

        rf = req.fused if req.fused is not None else self._empty_req_fused
        logits, self._table = self._suffix_prefill(
            self.params, self._table, toks, jnp.asarray(prefix_pages),
            jnp.int32(P), rf, jnp.asarray(phys), jnp.asarray(off),
            jnp.asarray(row), jnp.int32(slot), jnp.int32(S))
        first = jnp.argmax(logits[0, Ssuf - 1]).astype(jnp.int32)

        self._leases[slot] = lease
        self._outputs[req.rid] = [first]
        self._tok = self._tok.at[slot].set(first)
        self._assign_fused_row(slot, req)
        self._active[slot] = True
        self._slot_rid[slot] = req.rid
        self._remaining[slot] = req.max_new_tokens - 1
        self._register_prefix(req, lease)
        self.stats["admitted"] += 1
        self.stats["shared_admits"] += 1
        self.stats["radix_hits"] += 1
        self.stats["radix_matched_tokens"] += P
        self.stats["prefill_tokens"] += Ssuf
        self.stats["peak_active"] = max(self.stats["peak_active"],
                                        int(self._active.sum()))
        return True

    def _admit(self) -> None:
        while self._queue:
            free = deque(self._free_slots())
            if not free:
                break
            head = self._queue[0]
            match = self._radix_match(head)
            if match is not None:
                if not self._admit_shared(head, free[0], match):
                    break  # pool can't cover the unshared suffix: wait
                self._queue.popleft()
                continue
            if self.paged and head.max_new_tokens > 1:
                # pool pressure may be index pins, not live slots — evict
                # LRU prefix entries so a fresh head is never starved
                self._ensure_pages(self._pages_needed(head))
            batch = self._take_admission_batch(len(free))
            if not batch:
                break
            Sb = self._bucket_len(int(batch[0].prompt.shape[1]))
            B = self.admit_batch
            toks = jnp.concatenate(
                [jnp.pad(r.prompt, ((0, 0), (0, Sb - r.prompt.shape[1])))
                 for r in batch]
                + [jnp.zeros((B - len(batch), Sb), jnp.int32)], axis=0)
            fused_b = None
            if self.max_prefix:
                # standalone members ride the same prefill trace as fused ones
                per_req = [r.fused if r.fused is not None
                           else self._empty_req_fused for r in batch]
                per_req += [self._empty_req_fused] * (B - len(batch))
                fused_b = FusedPrefix(
                    k=jnp.concatenate([f.k for f in per_req], axis=1),
                    v=jnp.concatenate([f.v for f in per_req], axis=1),
                    bias=jnp.concatenate([f.bias for f in per_req], axis=1))
            logits, cache_b = self._prefill(self.params, toks, fused_b)
            self.stats["admit_batches"] += 1
            for b, req in enumerate(batch):
                S = int(req.prompt.shape[1])
                first = jnp.argmax(logits[b, S - 1]).astype(jnp.int32)
                self._outputs[req.rid] = [first]
                self.stats["admitted"] += 1
                self.stats["prefill_tokens"] += S
                if req.max_new_tokens == 1:  # done at prefill: no slot taken
                    self._ready.append(self._finish(req.rid))
                    continue
                slot = free.popleft()
                if self.paged:
                    assert self._allocator is not None
                    lease = self._allocator.lease(fresh=self._pages_needed(req))
                    if self._san is not None:
                        self._san.annotate(lease, slot=slot, rid=req.rid,
                                           digest=req.digest)
                        self._san.note_write(lease.ids(), lease,
                                             what=f"prefill insert "
                                                  f"(slot {slot})")
                    self._leases[slot] = lease
                    row = lease.page_row(self._table.pages_per_slot,
                                         self._table.invalid_page)
                    self._table = self._insert(
                        self._table, jnp.int32(slot), cache_b, jnp.int32(S),
                        jnp.asarray(row), jnp.int32(b))
                    self._register_prefix(req, lease)
                else:
                    self._table = self._insert(
                        self._table, jnp.int32(slot), cache_b, jnp.int32(S),
                        jnp.int32(b))
                self._tok = self._tok.at[slot].set(first)
                self._assign_fused_row(slot, req)
                self._active[slot] = True
                self._slot_rid[slot] = req.rid
                self._remaining[slot] = req.max_new_tokens - 1
            self.stats["peak_active"] = max(self.stats["peak_active"],
                                            int(self._active.sum()))

    # ------------------------------------------------------- chunked prefill
    def _begin_partial(self, req: EngineRequest, slot: int, lease: PageLease,
                       *, done: int, matched: int) -> None:
        """Reserve ``slot`` for a chunked admission: the lease is held (and
        visible to the sanitizer's leak report) from reservation on, but the
        slot stays inactive and its device page row INVALID until the final
        chunk adopts it."""
        row = lease.page_row(self._table.pages_per_slot,
                             self._table.invalid_page)
        self._leases[slot] = lease
        self._slot_rid[slot] = req.rid
        self._partials.append(_PartialPrefill(req, slot, lease,
                                              np.asarray(row, np.int32),
                                              done, matched,
                                              np.asarray(req.prompt[0],
                                                         np.int32)))

    def _reserve_fresh(self, req: EngineRequest, slot: int) -> bool:
        """Reserve pages for a chunked admission with no cached prefix."""
        need = self._pages_needed(req)
        if not self._ensure_pages(need):
            return False
        assert self._allocator is not None
        lease = self._allocator.lease(fresh=need)
        if self._san is not None:
            self._san.annotate(lease, slot=slot, rid=req.rid,
                               digest=req.digest)
        self._begin_partial(req, slot, lease, done=0, matched=0)
        return True

    def _reserve_shared(self, req: EngineRequest, slot: int,
                        match: PrefixMatch) -> bool:
        """Reserve a radix-hit chunked admission: share the matched pages,
        CoW-copy a partially matched one (the first chunk writes position
        ``matched`` inside it), lease fresh pages for the rest. Only the
        unmatched tail will be chunked."""
        P = match.matched
        total = self._pages_needed(req)
        shared_ids = list(match.page_ids)
        cow_idx = None
        if match.partial_page is not None:
            shared_ids.append(match.partial_page)
            cow_idx = len(shared_ids) - 1
        fresh = total - len(shared_ids)
        if not self._ensure_pages(fresh + (1 if cow_idx is not None else 0)):
            return False
        assert self._allocator is not None
        lease = self._allocator.lease(shared=shared_ids, fresh=fresh)
        if self._san is not None:
            self._san.annotate(lease, slot=slot, rid=req.rid,
                               digest=req.digest)
        if cow_idx is not None:
            src, dst = self._allocator.cow(lease, cow_idx)
            self._table = self._copy_page(self._table, jnp.int32(src),
                                          jnp.int32(dst))
            if self._san is not None:
                self._san.note_write([dst], lease, what="cow page copy")
            self.stats["cow_copies"] += 1
        self.stats["radix_hits"] += 1
        self.stats["radix_matched_tokens"] += P
        self._begin_partial(req, slot, lease, done=P, matched=P)
        return True

    def _defer_for_partial(self, req: EngineRequest) -> bool:
        """True when the queue head should wait: an in-flight partial with
        the same digest and leading token will register a shareable prefix
        at its final chunk (the chunked analogue of _defer_for_sharing —
        partials progress every step, so the wait is bounded)."""
        if self._radix is None or req.max_new_tokens <= 1:
            return False
        tb = np.asarray(req.prompt[0])
        for part in self._partials:
            ta = part.host_prompt
            if part.req.digest == req.digest and tb.size > 1 and ta.size \
                    and int(ta[0]) == int(tb[0]):
                return True
        return False

    def _admit_chunked(self) -> None:
        """Chunked admission: FIFO-reserve slots + page leases for queued
        prompts. No prefill compute happens here — _run_chunks spends the
        per-step token budget on the oldest reservations."""
        while self._queue:
            free = self._free_slots()
            if not free:
                break
            head = self._queue[0]
            match = self._radix_match(head)
            if match is None and self._defer_for_partial(head):
                break
            ok = (self._reserve_shared(head, free[0], match)
                  if match is not None else
                  self._reserve_fresh(head, free[0]))
            if not ok:
                break  # head-of-line blocked on pages: wait for evictions
            self._queue.popleft()

    def _run_chunks(self) -> None:
        """Spend up to ``prefill_token_budget`` prompt tokens on the oldest
        partial prefills (leftover budget rolls into the next partial — the
        calls all share one trace). A prompt's final chunk activates it; at
        most one prompt activates per step, so adoption cost (page-row
        adopt + first-token install) is bounded per step the same way the
        token budget bounds prefill compute — a backlog of small partials
        drains one per step instead of bursting into a single stall."""
        if not self.prefill_budget:
            return
        C = self.prefill_budget
        pg, invalid = self.page_size, self._table.invalid_page
        pps = self.max_seq // pg
        left = C
        while left > 0 and self._partials:
            part = self._partials[0]
            req = part.req
            S = int(req.prompt.shape[1])
            n = min(left, S - part.done)
            left -= n
            if self._san is not None:
                # the chunk's scatter only touches pages the lease OWNS:
                # shared full-prefix pages all sit before done//pg, and the
                # page holding position `done` is the CoW copy or fresh
                pages = part.row[part.done // pg:(part.done + n - 1) // pg + 1]
                self._san.note_write(np.unique(pages[pages != invalid]),
                                     part.lease,
                                     what=f"chunk prefill (slot {part.slot})")
            rf = req.fused if req.fused is not None else self._empty_req_fused
            final = part.done + n == S
            adopt_len = S if final and req.max_new_tokens > 1 else 0
            meta = np.zeros(4 + pps + C, np.int32)
            meta[:4] = (part.done, n, part.slot, adopt_len)
            meta[4:4 + pps] = part.row
            meta[4 + pps:4 + pps + n] = \
                part.host_prompt[part.done:part.done + n]
            first, self._tok, self._table = self._chunk_prefill(
                self.params, self._table, self._tok, jnp.asarray(meta), rf)
            part.done += n
            self.stats["prefill_tokens"] += n
            self.stats["prefill_chunks"] += 1
            if part.done == S:
                self._partials.popleft()
                self._activate_partial(part, first)
                break  # one adoption per step: keep the stall envelope flat

    def _activate_partial(self, part: _PartialPrefill, first) -> None:
        """A prompt's final chunk landed: book-keep its activation. The
        device-side work — page-row adoption and first-token install — was
        fused into the final chunk call itself; ``first`` is the chunk jit's
        in-jit argmax. A 1-token request completes here instead (its page
        row was never adopted: the radix registration keeps the pages)."""
        req, slot = part.req, part.slot
        self._outputs[req.rid] = [first]
        self._register_prefix(req, part.lease)
        self.stats["admitted"] += 1
        if part.matched:
            self.stats["shared_admits"] += 1
        if req.max_new_tokens == 1:
            # answered by the final chunk: drop the reservation — the radix
            # registration above keeps the pages pinned for future sharers
            del self._leases[slot]
            assert self._allocator is not None
            self._allocator.release(part.lease)
            self._slot_rid[slot] = None
            self._ready.append(self._finish(req.rid))
            return
        self._assign_fused_row(slot, req)
        self._active[slot] = True
        self._remaining[slot] = req.max_new_tokens - 1
        self.stats["peak_active"] = max(self.stats["peak_active"],
                                        int(self._active.sum()))

    # ------------------------------------------------------------- completion
    def _finish(self, rid: int) -> Completion:
        req = self._req_info.pop(rid)
        # host-side stack: jnp.stack here would eagerly compile a fresh XLA
        # stack per distinct token count, a multi-ms stall on the step that
        # completes a request (the entries are host scalars already, bar the
        # first token, which np.asarray converts per element)
        toks = np.asarray([np.asarray(t) for t in self._outputs.pop(rid)],
                          np.int32)
        self.stats["completed"] += 1
        return Completion(rid, toks, req.protocol, req.meta)

    def _evict(self, slot: int) -> None:
        self._table = self._table.evict_slot(slot)
        if self.paged:
            assert self._allocator is not None
            lease = self._leases.pop(slot, None)
            if lease is not None:
                # refcounted: pages another sharer (or the prefix index)
                # still holds stay alive; exclusively-owned pages free now
                self._allocator.release(lease)
        if self._fused is not None:
            row = int(self._fused_rows[slot])
            if row != self.max_slots:
                self._fused_alloc.release([row])
                self._fused_rows[slot] = self.max_slots

    # ------------------------------------------------------------------ step
    def step(self) -> List[Completion]:
        """Admit what fits, decode one token for every active slot, free any
        slot whose request just finished. Returns the completions.

        Chunked mode (``prefill_token_budget``) replaces monolithic admission
        prefills with a reservation pass plus at most one token-budget's
        worth of chunk compute, so the decode cadence below stays bounded."""
        if self.prefill_budget:
            self._admit_chunked()
            self._run_chunks()
        else:
            self._admit()
        done, self._ready = self._ready, []
        if not self._active.any():
            return done
        fused_rows = (jnp.asarray(self._fused_rows, jnp.int32)
                      if self._fused is not None else None)
        self._tok, self._table = self._decode(
            self.params, self._table, self._tok, self._fused, fused_rows,
            jnp.asarray(self._active))
        self.stats["decode_steps"] += 1
        tok_host = np.asarray(self._tok)
        if self._san is not None:
            # the decode step scattered each active slot's new token into
            # page pos//page_size at the slot's pre-increment position —
            # validate those writes before evictions release any lease
            pos_host = np.asarray(self._table.pos)
            for s in np.nonzero(self._active)[0]:
                lease = self._leases[int(s)]
                idx = (int(pos_host[s]) - 1) // self.page_size
                if idx >= lease.num_pages:
                    raise SanitizerError(
                        f"decode wrote position {int(pos_host[s]) - 1} of "
                        f"slot {int(s)}, past its lease of "
                        f"{lease.num_pages} page(s)")
                self._san.note_write([int(lease.page_ids[idx])], lease,
                                     what=f"decode write (slot {int(s)})")
        for s in np.nonzero(self._active)[0]:
            rid = self._slot_rid[s]
            self._outputs[rid].append(tok_host[s])
            self._remaining[s] -= 1
            if self._remaining[s] == 0:
                self._active[s] = False
                self._slot_rid[s] = None
                self._evict(int(s))
                done.append(self._finish(rid))
        if self._san is not None:
            # allocator / shadow-state / device page-map agreement, after
            # this step's admissions, decode writes and evictions all landed
            self._san.check_step(np.asarray(self._table.page_map),
                                 self._active, self._leases,
                                 self._table.invalid_page)
        return done

    # ----------------------------------------------------------------- drain
    def drain(self) -> List[Completion]:
        """Run until the queue, partial prefills and every slot are empty."""
        out: List[Completion] = []
        while self._queue or self._partials or self._active.any():
            out.extend(self.step())
        out.extend(self._ready)
        self._ready = []
        if self._san is not None:
            report = self._san.leak_report(self._leases)
            if report:
                raise SanitizerError(
                    "page leak(s) at drain:\n"
                    + "\n".join("  " + line for line in report))
        return out

    def sanitizer_report(self) -> List[str]:
        """Outstanding page grants the sanitizer cannot attribute to a live
        slot (empty when clean or when built with ``sanitize=False``)."""
        if self._san is None:
            return []
        return self._san.leak_report(self._leases)

    # ----------------------------------------------------------------- intro
    @property
    def num_active(self) -> int:
        return int(self._active.sum())

    @property
    def num_queued(self) -> int:
        return len(self._queue)

    @property
    def num_partial(self) -> int:
        """Prompts reserved for chunked prefill but not yet fully resident."""
        return len(self._partials)

    def first_token_ready(self, rid: int) -> bool:
        """True once ``rid``'s first token exists (the TTFT marker: set at
        admission for monolithic prefill, at the final chunk when chunked)."""
        return rid in self._outputs

    @property
    def kv_table_bytes(self) -> int:
        """HBM held by the slot table's K/V payload (the capacity-vs-budget
        bench metric: dense = slots × max_seq rows; paged = the page pool).
        Excludes the int32 bookkeeping (pos / page map — KBs, not MBs)."""
        from repro.models.cache import tree_bytes

        return tree_bytes(self._table.layers)

    def kv_read_bytes_per_step(self) -> Dict[str, int]:
        """Analytic KV HBM bytes one decode step reads, at the engine's
        *current* occupancy (call it mid-flight).

        ``paged_kernel`` counts only the pages that hold live tokens — what
        the in-place kernel DMAs (Σ_active ceil((pos+1)/page_size) pages).
        ``dense_gather`` counts every slot's full row — what the
        ``dense_view()`` gather path reads no matter how little of each slot
        is live (slots × view_seq for paged-gather, slots × max_seq dense).
        k + v, summed over all stacked attention layer entries."""
        itemsize = jnp.dtype(self.cache_dtype).itemsize
        n_entries = sum(int(e["k"].shape[0]) for e in self._table.layers)
        row_bytes = 2 * self.cfg.num_kv_heads * self.cfg.resolved_head_dim \
            * itemsize * n_entries  # k+v bytes per cached token
        pos = np.asarray(self._table.pos)
        if self.paged:
            pg = self.page_size
            live = pos[self._active] + 1
            pages = int(np.sum(-(-live // pg)))  # ceil
            view_seq = self._table.view_seq
            return {"paged_kernel": pages * pg * row_bytes,
                    "dense_gather": self.max_slots * view_seq * row_bytes}
        return {"paged_kernel": 0,
                "dense_gather": self.max_slots * self.max_seq * row_bytes}
