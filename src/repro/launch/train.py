"""Training drivers: backbone LM pre-training step (what train_4k lowers) and a
host loop for CPU-scale runs (examples/ and the case-study transmitters).

``--arch`` selects any assigned architecture (repro.configs); the same step
function is what launch/dryrun.py lowers against the production mesh.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, get_config, get_smoke_config
from repro.models import transformer as T
from repro.optim.adamw import AdamWConfig, apply_updates, init_opt_state


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, *, remat: bool = True,
                    unroll: bool = False):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, loss).

    ``batch``: {"tokens" (B,S) | "embeds" (B,S,d)}, "labels" (B,S),
    optional "positions_3d" (3,B,S) for M-RoPE archs.
    """

    def train_step(params, opt_state, batch):
        def loss(p):
            return T.loss_fn(
                cfg, p,
                tokens=batch.get("tokens"),
                labels=batch["labels"],
                embeds=batch.get("embeds"),
                positions_3d=batch.get("positions_3d"),
                remat=remat,
                unroll=unroll,
            )

        loss_val, grads = jax.value_and_grad(loss)(params)
        new_p, new_s = apply_updates(opt_cfg, params, grads, opt_state)
        return new_p, new_s, loss_val

    return train_step


def train_loop(cfg: ModelConfig, batches, steps: int, *, lr: float = 3e-4,
               seed: int = 0, dtype=jnp.float32, params=None,
               log_every: int = 50, verbose: bool = True):
    """Host training loop (CPU scale). Returns (params, losses)."""
    key = jax.random.PRNGKey(seed)
    if params is None:
        params = T.init_params(cfg, key, dtype)
    opt_cfg = AdamWConfig(lr=lr, schedule="linear_warmup_cosine",
                          warmup_steps=min(100, steps // 10 + 1),
                          total_steps=steps)
    opt_state = init_opt_state(params)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, remat=False))
    losses = []
    for i in range(steps):
        batch = next(batches)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, loss = step_fn(params, opt_state, batch)
        losses.append(float(loss))
        if verbose and (i % log_every == 0 or i == steps - 1):
            print(f"  [{cfg.name}] step {i:5d}  loss {float(loss):.4f}")
    return params, losses


def main() -> None:  # pragma: no cover - CLI
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true", help="use the reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)

    from repro.data.synthetic import World, WorldSpec, lm_stream
    world = World(WorldSpec(vocab_size=min(cfg.vocab_size, 512)))
    stream = lm_stream(world, 0, args.batch, args.seq)
    t0 = time.time()
    _, losses = train_loop(cfg, stream, args.steps, lr=args.lr)
    print(f"done: loss {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"({time.time()-t0:.1f}s, {args.steps} steps)")


if __name__ == "__main__":
    main()
