"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from the cached
dry-run JSONs (experiments/dryrun/*.json).

Usage: PYTHONPATH=src python -m repro.launch.report [--write]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs.base import ARCH_IDS, INPUT_SHAPES, canonical

DRYRUN_DIR = os.path.join("experiments", "dryrun")
SHAPE_ORDER = list(INPUT_SHAPES)


def load_all(tag: str = "") -> dict:
    recs = {}
    for path in glob.glob(os.path.join(DRYRUN_DIR, "*.json")):
        with open(path) as f:
            r = json.load(f)
        key = (canonical(r["arch"]), r["shape"], r["mesh"])
        recs[key] = r
    return recs


def _ms(x) -> str:
    return f"{x*1e3:.2f}" if x is not None else "—"


def roofline_table(recs: dict, mesh: str = "pod1x16x16") -> str:
    lines = [
        "| arch | shape | compute ms | memory ms | collective ms | bottleneck "
        "| MODEL/analytic | temp GiB | peak arg GiB | ok |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for a in ARCH_IDS:
        for s in SHAPE_ORDER:
            r = recs.get((a, s, mesh))
            if r is None:
                lines.append(f"| {a} | {s} | — | — | — | — | — | — | — | MISSING |")
                continue
            if not r.get("ok"):
                err = r.get("error", "?")[:60]
                lines.append(f"| {a} | {s} | — | — | — | — | — | — | — | FAIL: {err} |")
                continue
            mem = r.get("memory_per_device") or {}
            temp = (mem.get("temp_bytes") or 0) / 2**30
            args = (mem.get("argument_bytes") or 0) / 2**30
            lines.append(
                f"| {a} | {s} | {_ms(r['compute_s'])} | {_ms(r['memory_s'])} "
                f"| {_ms(r['collective_s'])} | **{r['bottleneck']}** "
                f"| {r['useful_ratio']:.2f} | {temp:.1f} | {args:.1f} | ok |")
    return "\n".join(lines)


def multipod_table(recs: dict) -> str:
    lines = [
        "| arch | shape | 1-pod ok | 2-pod ok | 2-pod collective ms | 2-pod temp GiB |",
        "|---|---|---|---|---|---|",
    ]
    for a in ARCH_IDS:
        for s in SHAPE_ORDER:
            r1 = recs.get((a, s, "pod1x16x16"))
            r2 = recs.get((a, s, "pod2x16x16"))
            ok1 = "ok" if (r1 and r1.get("ok")) else "FAIL"
            ok2 = "ok" if (r2 and r2.get("ok")) else "FAIL"
            coll = _ms(r2["collective_s"]) if r2 and r2.get("ok") else "—"
            mem = ((r2.get("memory_per_device") or {}).get("temp_bytes") or 0) \
                / 2**30 if r2 and r2.get("ok") else 0
            lines.append(f"| {a} | {s} | {ok1} | {ok2} | {coll} | {mem:.1f} |")
    return "\n".join(lines)


def summary(recs: dict) -> str:
    total = ok = 0
    doms: dict = {}
    for a in ARCH_IDS:
        for s in SHAPE_ORDER:
            for m in ("pod1x16x16", "pod2x16x16"):
                r = recs.get((a, s, m))
                total += 1
                if r and r.get("ok"):
                    ok += 1
                    if m == "pod1x16x16":
                        doms[r["bottleneck"]] = doms.get(r["bottleneck"], 0) + 1
    return (f"{ok}/{total} (arch × shape × mesh) combinations lower+compile. "
            f"Single-pod bottleneck split: {doms}.")


def federated_table() -> str:
    lines = [
        "| federated serve step | mesh | compute ms | memory ms | collective ms "
        "| bottleneck |",
        "|---|---|---|---|---|---|",
    ]
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "FED_*.json"))):
        with open(path) as f:
            r = json.load(f)
        if not r.get("ok"):
            lines.append(f"| {r['arch']} | {r['mesh']} | — | — | — | FAIL |")
            continue
        lines.append(
            f"| {r['arch']} | {r['mesh']} | {_ms(r['compute_s'])} "
            f"| {_ms(r['memory_s'])} | {_ms(r['collective_s'])} "
            f"| {r['bottleneck']} |")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod1x16x16")
    args = ap.parse_args()
    recs = load_all()
    print("## Summary\n")
    print(summary(recs))
    print("\n## §Roofline (single-pod 16×16 = 256 chips)\n")
    print(roofline_table(recs, args.mesh))
    print("\n## §Dry-run multi-pod proof (2×16×16 = 512 chips)\n")
    print(multipod_table(recs))
    print("\n## Federated (FedRefine) serve-step dry-runs\n")
    print(federated_table())


if __name__ == "__main__":
    main()
