"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the "pod" axis carries
data-parallel replication across pods AND is the federation axis FedRefine maps
participants onto (DESIGN.md §2).

Defined as functions — importing this module must never touch jax device state
(the dry-run sets XLA_FLAGS before any jax import; see dryrun.py).
"""
from __future__ import annotations

import jax


def _mk(shape, axes):
    import jax.sharding as shd
    if hasattr(shd, "AxisType"):  # jax >= 0.5 explicit-sharding API
        return jax.make_mesh(shape, axes,
                             axis_types=(shd.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_host_mesh():
    """1-device mesh for CPU tests of the sharded code paths."""
    return _mk((1, 1), ("data", "model"))


def batch_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def axis_size(mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1
