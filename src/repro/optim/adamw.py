"""AdamW + schedules in pure JAX (no optax offline).

Mixed-precision convention: model params may be bf16; the optimizer keeps fp32
master weights and fp32 moments, applies the update in fp32 and casts back —
standard large-model practice. Integer leaves (e.g. fuser alignment tables) are
treated as non-trainable and passed through untouched.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp


def _trainable(leaf) -> bool:
    return jnp.issubdtype(leaf.dtype, jnp.floating)


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 1.0
    schedule: str = "constant"  # constant | cosine | linear_warmup_cosine
    warmup_steps: int = 0
    total_steps: int = 1000
    min_lr_frac: float = 0.1


def schedule_lr(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    lr = jnp.asarray(cfg.lr, jnp.float32)
    if cfg.schedule == "constant":
        return lr
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    if cfg.schedule == "cosine":
        return lr * cos
    return lr * warm * cos  # linear_warmup_cosine


def init_opt_state(params) -> dict:
    def zeros_like_f32(p):
        if _trainable(p):
            return jnp.zeros(p.shape, jnp.float32)
        return None

    def master(p):
        return p.astype(jnp.float32) if _trainable(p) else None

    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros_like_f32, params),
        "v": jax.tree.map(zeros_like_f32, params),
        "master": jax.tree.map(master, params),
    }


def global_norm(grads) -> jax.Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(grads) if _trainable(g)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(cfg: AdamWConfig, params, grads, state) -> tuple:
    """One AdamW step. Returns (new_params, new_state)."""
    step = state["step"] + 1
    lr = schedule_lr(cfg, step)
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gn + 1e-9)) if cfg.grad_clip else 1.0
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v, w):
        if not _trainable(p):
            return p, m, v, w
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        w = w - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * w)
        return w.astype(p.dtype), m, v, w

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_w = treedef.flatten_up_to(state["master"])
    outs = [upd(p, g, m, v, w)
            for p, g, m, v, w in zip(flat_p, flat_g, flat_m, flat_v, flat_w)]
    new_p = treedef.unflatten([o[0] for o in outs])
    new_state = {
        "step": step,
        "m": treedef.unflatten([o[1] for o in outs]),
        "v": treedef.unflatten([o[2] for o in outs]),
        "master": treedef.unflatten([o[3] for o in outs]),
    }
    return new_p, new_state


def make_train_step(loss_fn: Callable, opt_cfg: AdamWConfig):
    """loss_fn(params, batch) -> scalar. Returns jit-able step(params, state, batch)."""

    def step(params, state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        new_params, new_state = apply_updates(opt_cfg, params, grads, state)
        return new_params, new_state, loss

    return step
