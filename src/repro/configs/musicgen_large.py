"""MusicGen-large decoder backbone [arXiv:2306.05284].

48L d_model=2048 32H (kv=32, i.e. MHA) d_ff=8192, vocab=2048 EnCodec codebook.
Decoder-only transformer over EnCodec tokens; the EnCodec conv codec frontend is a
stub per the brief — ``input_specs`` provides precomputed frame embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    rope_theta=10_000.0,
    frontend="audio",
    source="arXiv:2306.05284",
)


def smoke() -> ModelConfig:
    return CONFIG.with_overrides(
        name="musicgen-large-smoke",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        head_dim=32,
        d_ff=256,
        vocab_size=256,
    )
