"""Qwen2.5-32B [hf:Qwen/Qwen2.5-0.5B family card].

64L d_model=5120 40H (GQA kv=8) d_ff=27648 vocab=152064; QKV bias (Qwen2 family).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=27_648,
    vocab_size=152_064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen2.5-0.5B",
)


def smoke() -> ModelConfig:
    return CONFIG.with_overrides(
        name="qwen2.5-32b-smoke",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=256,
    )
