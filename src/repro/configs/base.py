"""Configuration system for the FedRefine framework.

Every assigned architecture gets a module ``src/repro/configs/<id>.py`` exposing
``CONFIG`` (the exact published dims) and ``smoke()`` (a reduced variant of the same
family for CPU tests). ``get_config(name)`` resolves either.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Input shapes (assigned; see system brief).
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    """One benchmark input shape."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Model configuration.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description covering all six assigned families.

    ``block_pattern`` cycles over layers; entries are:
      "attn"  — full (causal) attention + FFN block
      "swa"   — sliding-window attention + FFN block
      "rec"   — RG-LRU recurrent block + FFN block (RecurrentGemma)
      "ssd"   — Mamba-2 SSD block (attention-free, no separate FFN)
    """

    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    source: str = ""  # provenance citation

    # --- attention options -------------------------------------------------
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1_000_000.0
    mrope_sections: Optional[Tuple[int, int, int]] = None  # qwen2-vl M-RoPE
    sliding_window: int = 0  # >0: window for "swa" layers
    tie_embeddings: bool = False

    # --- long-context variant (used only for the long_500k shape on
    # full-attention archs; see DESIGN.md §Arch-applicability) --------------
    long_context_window: int = 4_096

    # --- layer pattern ------------------------------------------------------
    block_pattern: Tuple[str, ...] = ("attn",)

    # --- MoE ----------------------------------------------------------------
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0
    router_aux_coef: float = 0.001
    moe_group_size: int = 512       # dispatch token-group (perf knob, §Perf B2)
    moe_capacity_factor: float = 1.5

    # --- RG-LRU (hybrid) ----------------------------------------------------
    rglru_width: int = 0  # recurrence width (d_rnn); 0 -> d_model
    conv_kernel: int = 4

    # --- Mamba-2 SSD (ssm) --------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_ngroups: int = 1

    # --- modality frontend (stubbed per the brief) --------------------------
    frontend: Optional[str] = None  # "audio" | "vision"

    norm_eps: float = 1e-6

    # ------------------------------------------------------------------ utils
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    @property
    def layer_types(self) -> Tuple[str, ...]:
        """Per-layer block type, cycling ``block_pattern``."""
        p = self.block_pattern
        return tuple(p[i % len(p)] for i in range(self.num_layers))

    @property
    def attention_layers(self) -> Tuple[int, ...]:
        """Indices of layers that own a KV cache (C2C attach points)."""
        return tuple(
            i for i, t in enumerate(self.layer_types) if t in ("attn", "swa")
        )

    @property
    def d_inner(self) -> int:
        """SSD inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_state else 0

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.resolved_head_dim

    def with_overrides(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Analytic parameter count (used for 6·N·D roofline sanity)."""
        d, hd = self.d_model, self.resolved_head_dim
        n = self.vocab_size * d  # embed
        if not self.tie_embeddings:
            n += self.vocab_size * d  # lm head
        for t in self.layer_types:
            if t in ("attn", "swa"):
                n += d * (self.num_heads * hd)  # wq
                n += 2 * d * (self.num_kv_heads * hd)  # wk, wv
                n += (self.num_heads * hd) * d  # wo
                if self.qkv_bias:
                    n += (self.num_heads + 2 * self.num_kv_heads) * hd
                n += self._ffn_params()
                n += 2 * d  # norms
            elif t == "rec":
                w = self.rglru_width or d
                nh = max(self.num_heads, 1)
                n += 2 * d * w + w * d  # in-projs (x, gate) + out-proj
                n += self.conv_kernel * w + w  # conv
                n += 3 * w  # Λ + gate biases
                n += 2 * nh * (w // nh) ** 2  # block-diagonal gate projections
                n += self._ffn_params()
                n += 2 * d
            elif t == "ssd":
                di, ns = self.d_inner, self.ssm_state
                nh = self.ssm_nheads
                n += d * (2 * di + 2 * self.ssm_ngroups * ns + nh)  # in_proj
                n += self.conv_kernel * (di + 2 * self.ssm_ngroups * ns)
                n += di * d  # out_proj
                n += 2 * nh  # A_log, D
                n += d  # norm
        n += d  # final norm
        return n

    def _ffn_params(self) -> int:
        d = self.d_model
        if self.num_experts:
            per_expert = 3 * d * self.moe_d_ff
            n = self.num_experts * per_expert + d * self.num_experts  # router
            if self.num_shared_experts:
                n += 3 * d * (self.moe_d_ff * self.num_shared_experts)
                n += d  # shared-expert gate
            return n
        return 3 * d * self.d_ff  # SwiGLU

    def active_param_count(self) -> int:
        """Active params per token (MoE: routed top-k + shared only)."""
        if not self.num_experts:
            return self.param_count()
        full = self.param_count()
        d = self.d_model
        per_expert = 3 * d * self.moe_d_ff
        n_moe_layers = sum(1 for t in self.layer_types if t in ("attn", "swa"))
        inactive = (
            (self.num_experts - self.num_experts_per_tok) * per_expert * n_moe_layers
        )
        return full - inactive


# ---------------------------------------------------------------------------
# Registry.
# ---------------------------------------------------------------------------

ARCH_IDS: tuple[str, ...] = (
    "qwen3_moe_30b_a3b",
    "qwen2_5_32b",
    "musicgen_large",
    "granite_20b",
    "recurrentgemma_9b",
    "qwen2_vl_72b",
    "internlm2_1_8b",
    "mamba2_130m",
    "qwen3_1_7b",
    "qwen2_moe_a2_7b",
)

# CLI-friendly aliases (the assignment uses dashed ids).
ALIASES = {
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "qwen2.5-32b": "qwen2_5_32b",
    "musicgen-large": "musicgen_large",
    "granite-20b": "granite_20b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "internlm2-1.8b": "internlm2_1_8b",
    "mamba2-130m": "mamba2_130m",
    "qwen3-1.7b": "qwen3_1_7b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
}


def canonical(name: str) -> str:
    return ALIASES.get(name, name.replace("-", "_").replace(".", "_"))


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.smoke()


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
