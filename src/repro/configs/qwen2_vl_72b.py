"""Qwen2-VL-72B language backbone [arXiv:2409.12191].

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064, M-RoPE (sections 16/24/24),
dynamic-resolution vision frontend stubbed per the brief (patch embeddings provided
by ``input_specs``).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=29_568,
    vocab_size=152_064,
    qkv_bias=True,
    mrope_sections=(16, 24, 24),
    rope_theta=1_000_000.0,
    frontend="vision",
    source="arXiv:2409.12191",
)


def smoke() -> ModelConfig:
    return CONFIG.with_overrides(
        name="qwen2-vl-72b-smoke",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=256,
        mrope_sections=(4, 6, 6),
    )
