"""Granite-20B code model backbone [arXiv:2405.04324].

52L d_model=6144 48H (MQA kv=1) d_ff=24576 vocab=49152; llama-style blocks.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    family="dense",
    num_layers=52,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    head_dim=128,
    d_ff=24_576,
    vocab_size=49_152,
    rope_theta=10_000.0,
    source="arXiv:2405.04324",
)


def smoke() -> ModelConfig:
    return CONFIG.with_overrides(
        name="granite-20b-smoke",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=1,
        head_dim=32,
        d_ff=256,
        vocab_size=256,
    )
