"""Qwen2(1.5)-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B].

24L d_model=2048 16H (GQA kv=16, i.e. MHA) expert d_ff=1408 vocab=151936,
60 routed experts top-4 plus 4 shared experts.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=0,
    moe_d_ff=1408,
    num_experts=60,
    num_experts_per_tok=4,
    num_shared_experts=4,
    vocab_size=151_936,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
)


def smoke() -> ModelConfig:
    return CONFIG.with_overrides(
        name="qwen2-moe-a2.7b-smoke",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        head_dim=32,
        moe_d_ff=64,
        num_experts=4,
        num_experts_per_tok=2,
        num_shared_experts=1,
        vocab_size=256,
    )
