"""Mamba2-130M (SSD, state-space duality) [arXiv:2405.21060].

24L d_model=768, attention-free, ssm_state=128, expand=2 (d_inner=1536),
ssd head_dim=64 (24 ssd heads), vocab=50280.

C2C applicability: the paper's KV-cache medium does not exist here — see
DESIGN.md §Arch-applicability. The arch runs WITHOUT the paper's technique;
a clearly-marked beyond-paper state-to-state fuser is available separately.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50_280,
    block_pattern=("ssd",),
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_ngroups=1,
    conv_kernel=4,
    tie_embeddings=True,
    source="arXiv:2405.21060",
)


def smoke() -> ModelConfig:
    return CONFIG.with_overrides(
        name="mamba2-130m-smoke",
        num_layers=2,
        d_model=128,
        ssm_state=32,
        ssm_head_dim=32,
        vocab_size=256,
    )
