"""RecurrentGemma-9B (Griffin) [arXiv:2402.19427].

38L d_model=4096 16H (MQA kv=1 for local attention) d_ff=12288 vocab=256000.
Pattern: two RG-LRU recurrent blocks then one local-attention block (1:2),
sliding window 2048, head_dim 256, recurrence width 4096.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12_288,
    vocab_size=256_000,
    block_pattern=("rec", "rec", "swa"),
    sliding_window=2048,
    rglru_width=4096,
    conv_kernel=4,
    rope_theta=10_000.0,
    tie_embeddings=True,
    source="arXiv:2402.19427",
)


def smoke() -> ModelConfig:
    return CONFIG.with_overrides(
        name="recurrentgemma-9b-smoke",
        num_layers=3,  # one full (rec, rec, swa) period
        d_model=128,
        num_heads=4,
        num_kv_heads=1,
        head_dim=32,
        d_ff=256,
        vocab_size=256,
        sliding_window=32,
        rglru_width=128,
    )
