"""The paper's own case-study model zoo (§Case Study, Fig. 3).

Receiver: Qwen3-0.6B. Transmitters: Qwen2.5-0.5B, Qwen2.5-0.5B-code (Qwen2.5-Coder),
Qwen2.5-1.5B, Llama-3.2-1B. Published dims from the respective model cards.

``tiny_zoo()`` returns CPU-trainable reductions of the same five *heterogeneous*
families — distinct (num_layers, d_model, kv_heads) per member, which is exactly what
exercises the heterogeneous fuser alignment — used by the simulated case study
(DESIGN.md §1: repro band 2 — pretrained checkpoints unavailable offline).
"""
from repro.configs.base import ModelConfig

QWEN3_0_6B = ModelConfig(
    name="qwen3-0.6b",
    family="dense",
    num_layers=28,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=3072,
    vocab_size=151_936,
    qk_norm=True,
    tie_embeddings=True,
    source="hf:Qwen/Qwen3-0.6B",
)

QWEN2_5_0_5B = ModelConfig(
    name="qwen2.5-0.5b",
    family="dense",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151_936,
    qkv_bias=True,
    tie_embeddings=True,
    source="hf:Qwen/Qwen2.5-0.5B",
)

QWEN2_5_0_5B_CODE = QWEN2_5_0_5B.with_overrides(
    name="qwen2.5-0.5b-code", source="hf:Qwen/Qwen2.5-Coder-0.5B"
)

QWEN2_5_1_5B = ModelConfig(
    name="qwen2.5-1.5b",
    family="dense",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151_936,
    qkv_bias=True,
    tie_embeddings=True,
    source="hf:Qwen/Qwen2.5-1.5B",
)

LLAMA_3_2_1B = ModelConfig(
    name="llama-3.2-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=32,
    num_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=128_256,
    rope_theta=500_000.0,
    tie_embeddings=True,
    source="hf:meta-llama/Llama-3.2-1B",
)

ZOO = {
    "receiver": QWEN3_0_6B,
    "transmitters": [QWEN2_5_0_5B, QWEN2_5_0_5B_CODE, QWEN2_5_1_5B, LLAMA_3_2_1B],
}


def tiny_zoo(vocab_size: int = 512) -> dict:
    """Heterogeneous CPU-scale reductions of the same five families.

    Deliberately *different* depth / width / kv layout per member so the
    LayerAlignment + fuser dimension handling is genuinely exercised.
    """
    rx = QWEN3_0_6B.with_overrides(
        name="tiny-qwen3-rx", num_layers=4, d_model=128, num_heads=4,
        num_kv_heads=2, head_dim=32, d_ff=256, vocab_size=vocab_size)
    t1 = QWEN2_5_0_5B.with_overrides(
        name="tiny-qwen25-t1", num_layers=3, d_model=96, num_heads=4,
        num_kv_heads=2, head_dim=24, d_ff=192, vocab_size=vocab_size)
    t2 = QWEN2_5_0_5B_CODE.with_overrides(
        name="tiny-qwen25code-t2", num_layers=3, d_model=96, num_heads=4,
        num_kv_heads=2, head_dim=24, d_ff=192, vocab_size=vocab_size)
    t3 = QWEN2_5_1_5B.with_overrides(
        name="tiny-qwen25-t3", num_layers=5, d_model=160, num_heads=4,
        num_kv_heads=1, head_dim=40, d_ff=320, vocab_size=vocab_size)
    t4 = LLAMA_3_2_1B.with_overrides(
        name="tiny-llama-t4", num_layers=2, d_model=192, num_heads=6,
        num_kv_heads=3, head_dim=32, d_ff=384, vocab_size=vocab_size)
    return {"receiver": rx, "transmitters": [t1, t2, t3, t4]}
