"""InternLM2-1.8B [arXiv:2403.17297].

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92544.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-1.8b",
    family="dense",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92_544,
    rope_theta=1_000_000.0,
    source="arXiv:2403.17297",
)


def smoke() -> ModelConfig:
    return CONFIG.with_overrides(
        name="internlm2-1.8b-smoke",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=256,
    )
