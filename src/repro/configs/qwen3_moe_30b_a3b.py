"""Qwen3-MoE-30B-A3B [hf:Qwen/Qwen3-30B-A3B].

48L d_model=2048 32H (GQA kv=4) expert d_ff=768, vocab 151936, 128 experts top-8,
qk_norm (Qwen3 family), explicit head_dim=128.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=0,
    moe_d_ff=768,
    num_experts=128,
    num_experts_per_tok=8,
    vocab_size=151_936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen3-30B-A3B",
)


def smoke() -> ModelConfig:
    return CONFIG.with_overrides(
        name="qwen3-moe-30b-a3b-smoke",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        head_dim=32,
        moe_d_ff=64,
        num_experts=4,
        num_experts_per_tok=2,
        vocab_size=256,
    )
