"""Shared primitive layers: norms, rotary embeddings (RoPE / M-RoPE), SwiGLU MLP.

Everything is functional: ``init_*`` builds a param pytree, ``apply_*`` consumes it.
Params live in ``param_dtype`` (bf16 at production scale); norm statistics and rotary
tables are computed in fp32 for stability, matching standard practice.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def init_rmsnorm(dim: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((dim,), dtype=dtype)}


def rmsnorm(params: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dtype)


def rmsnorm_nohead(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """qk-norm over the trailing head_dim with a learned per-dim scale."""
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings.
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies, shape (head_dim // 2,), fp32."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def rope_table(positions: jax.Array, head_dim: int, theta: float) -> Tuple[jax.Array, jax.Array]:
    """cos/sin tables for integer ``positions`` (any shape) -> (*pos, head_dim//2)."""
    inv = rope_freqs(head_dim, theta)
    ang = positions.astype(jnp.float32)[..., None] * inv  # (*pos, half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """Rotate ``x`` (..., seq, head_dim) by tables (..., seq, head_dim//2).

    Uses the half-split convention (x1 = first half, x2 = second half), matching
    Llama/Qwen reference implementations.
    """
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    x1f = x1.astype(jnp.float32)
    x2f = x2.astype(jnp.float32)
    out1 = x1f * cos - x2f * sin
    out2 = x2f * cos + x1f * sin
    return jnp.concatenate([out1, out2], axis=-1).astype(x.dtype)


def mrope_table(
    positions_3d: jax.Array,  # (3, batch, seq) — temporal / height / width ids
    head_dim: int,
    theta: float,
    sections: Tuple[int, int, int],
) -> Tuple[jax.Array, jax.Array]:
    """Multimodal RoPE (Qwen2-VL, arXiv:2409.12191).

    The head_dim//2 frequency slots are partitioned into three contiguous sections
    (temporal, height, width); each section takes its angle from the matching
    position-id stream. Returns (batch, seq, head_dim//2) cos/sin.
    """
    half = head_dim // 2
    assert sum(sections) == half, (sections, half)
    inv = rope_freqs(head_dim, theta)  # (half,)
    ang_all = positions_3d.astype(jnp.float32)[..., None] * inv  # (3, B, S, half)
    sel = jnp.repeat(
        jnp.arange(3), jnp.array(sections), total_repeat_length=half
    )  # (half,) -> which stream each slot uses
    ang = jnp.take_along_axis(
        jnp.moveaxis(ang_all, 0, -1),  # (B, S, half, 3)
        sel[None, None, :, None],
        axis=-1,
    )[..., 0]  # (B, S, half)
    return jnp.cos(ang), jnp.sin(ang)


# ---------------------------------------------------------------------------
# Dense / SwiGLU MLP
# ---------------------------------------------------------------------------


def _dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0]
    std = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def init_linear(key, d_in: int, d_out: int, *, bias: bool = False, dtype=jnp.bfloat16) -> dict:
    p = {"w": _dense_init(key, (d_in, d_out), dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(params: dict, x: jax.Array) -> jax.Array:
    y = x @ params["w"]
    if "b" in params:
        y = y + params["b"]
    return y


def init_swiglu(key, d_model: int, d_ff: int, dtype=jnp.bfloat16) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": init_linear(k1, d_model, d_ff, dtype=dtype),
        "up": init_linear(k2, d_model, d_ff, dtype=dtype),
        "down": init_linear(k3, d_ff, d_model, dtype=dtype),
    }


def swiglu(params: dict, x: jax.Array) -> jax.Array:
    g = jax.nn.silu(linear(params["gate"], x))
    u = linear(params["up"], x)
    return linear(params["down"], g * u)


def init_embedding(key, vocab: int, d_model: int, dtype=jnp.bfloat16) -> dict:
    return {"table": _dense_init(key, (vocab, d_model), dtype, scale=1.0)}


def embed(params: dict, tokens: jax.Array) -> jax.Array:
    return params["table"][tokens]


def unembed(params: dict, x: jax.Array) -> jax.Array:
    """Logits in the model dtype; the loss upcasts to fp32 shard-locally."""
    return x @ params["table"].T
