"""Mamba-2 SSD block (state-space duality, arXiv:2405.21060).

Training/prefill uses the chunked SSD algorithm — quadratic attention-like compute
inside chunks (MXU-friendly matmuls) plus a linear inter-chunk state recurrence —
which is the paper's "duality" and maps naturally onto the TPU MXU. Decode is a
constant-time state update, which is why this arch runs long_500k natively.

Layout notes: heads-per-group broadcast of B/C is materialised (ngroups=1 for the
assigned mamba2-130m); recurrent state is kept fp32.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L


def init_ssd_block(cfg: ModelConfig, key, dtype=jnp.bfloat16) -> dict:
    d, di, ns, ng, nh = (cfg.d_model, cfg.d_inner, cfg.ssm_state,
                         cfg.ssm_ngroups, cfg.ssm_nheads)
    conv_dim = di + 2 * ng * ns
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "in_proj": L.init_linear(k1, d, 2 * di + 2 * ng * ns + nh, dtype=dtype),
        "conv_w": (jax.random.normal(k2, (cfg.conv_kernel, conv_dim), jnp.float32)
                   * (cfg.conv_kernel ** -0.5)).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jax.random.uniform(k3, (nh,), jnp.float32, 1.0, 16.0)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(  # softplus^-1 of dt in [1e-3, 1e-1]
            jnp.exp(jax.random.uniform(k4, (nh,), jnp.float32,
                                       jnp.log(1e-3), jnp.log(1e-1))))),
        "norm": jnp.ones((di,), jnp.float32),
        "out_proj": L.init_linear(jax.random.fold_in(k1, 7), di, d, dtype=dtype),
    }


def _split_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    di, ns, ng, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_ngroups, cfg.ssm_nheads
    z, xBC, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * ng * ns], axis=-1)
    return z, xBC, dt  # dt: (..., nh)


def _conv_silu(params, xBC, tail):
    from repro.models.rglru import _causal_conv
    out, new_tail = _causal_conv(xBC, params["conv_w"], params["conv_b"], tail)
    return jax.nn.silu(out), new_tail


def _gated_norm(params, y: jax.Array, z: jax.Array, eps: float) -> jax.Array:
    return L.rmsnorm_nohead(y * jax.nn.silu(z), params["norm"], eps)


def ssd_chunked(
    x_dt: jax.Array,   # (b, s, nh, hd) — inputs pre-multiplied by dt
    dtA: jax.Array,    # (b, s, nh) — dt * A  (≤ 0)
    Bm: jax.Array,     # (b, s, nh, ns) — B broadcast to heads
    Cm: jax.Array,     # (b, s, nh, ns)
    h0: Optional[jax.Array] = None,  # (b, nh, hd, ns) fp32
    chunk: int = 128,
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan. Returns (y (b,s,nh,hd), h_last (b,nh,hd,ns) fp32)."""
    b, s, nh, hd = x_dt.shape
    ns = Bm.shape[-1]
    Q = min(chunk, s)
    assert s % Q == 0, (s, Q)
    nc = s // Q
    xc = x_dt.reshape(b, nc, Q, nh, hd)
    ac = dtA.reshape(b, nc, Q, nh).astype(jnp.float32)
    Bc = Bm.reshape(b, nc, Q, nh, ns)
    Cc = Cm.reshape(b, nc, Q, nh, ns)

    cum = jnp.cumsum(ac, axis=2)  # (b,nc,Q,nh)
    # --- intra-chunk (quadratic, "attention mode") ---
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (b,nc,Q,Q,nh) i,j
    Lmask = jnp.tril(jnp.ones((Q, Q), bool))
    Ldec = jnp.where(Lmask[None, None, :, :, None], jnp.exp(seg), 0.0)
    G = jnp.einsum("bcihn,bcjhn->bcijh", Cc.astype(jnp.float32),
                   Bc.astype(jnp.float32))
    M = G * Ldec
    y_diag = jnp.einsum("bcijh,bcjhp->bcihp", M, xc.astype(jnp.float32))

    # --- chunk states ---
    decay_out = jnp.exp(cum[:, :, -1:, :] - cum)  # (b,nc,Q,nh)
    S = jnp.einsum("bcqhn,bcqhp->bchpn",
                   Bc.astype(jnp.float32) * decay_out[..., None],
                   xc.astype(jnp.float32))  # (b,nc,nh,hd,ns)

    # --- inter-chunk recurrence (linear scan over nc) ---
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # (b,nc,nh)
    hinit = (jnp.zeros((b, nh, hd, ns), jnp.float32) if h0 is None
             else h0.astype(jnp.float32))

    def step(h, inp):
        dec, s_c = inp  # (b,nh), (b,nh,hd,ns)
        h_prev = h
        h = dec[:, :, None, None] * h + s_c
        return h, h_prev

    (h_last, h_prevs) = jax.lax.scan(
        step, hinit, (chunk_decay.transpose(1, 0, 2), S.transpose(1, 0, 2, 3, 4))
    )
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)  # (b,nc,nh,hd,ns)

    # --- off-diagonal contribution from carried states ---
    decay_in = jnp.exp(cum)  # (b,nc,Q,nh)
    y_off = jnp.einsum("bcqhn,bchpn->bcqhp",
                       Cc.astype(jnp.float32) * decay_in[..., None], h_prevs)

    y = (y_diag + y_off).reshape(b, s, nh, hd)
    return y, h_last


def block_forward(
    cfg: ModelConfig,
    params: dict,
    x: jax.Array,  # (B, S, d)
    state: Optional[dict] = None,  # {"h": (B,nh,hd,ns) fp32, "conv": (B,K-1,conv_dim)}
    chunk: int = 128,
) -> Tuple[jax.Array, dict]:
    """Full SSD block; returns (out (B,S,d), new_state)."""
    di, ns, ng, nh, hd = (cfg.d_inner, cfg.ssm_state, cfg.ssm_ngroups,
                          cfg.ssm_nheads, cfg.ssm_head_dim)
    Bq, S, _ = x.shape
    z, xBC, dt = _split_proj(cfg, L.linear(params["in_proj"], x))
    tail = state["conv"] if state is not None else None
    xBC, new_tail = _conv_silu(params, xBC, tail)
    xs, Bg, Cg = jnp.split(xBC, [di, di + ng * ns], axis=-1)
    xs = xs.reshape(Bq, S, nh, hd)
    rep = nh // ng
    Bm = jnp.repeat(Bg.reshape(Bq, S, ng, ns), rep, axis=2)
    Cm = jnp.repeat(Cg.reshape(Bq, S, ng, ns), rep, axis=2)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,S,nh)
    A = -jnp.exp(params["A_log"])  # (nh,)
    dtA = dt * A
    x_dt = xs.astype(jnp.float32) * dt[..., None]

    h0 = state["h"] if state is not None else None
    if S == 1 and state is not None:  # decode fast path: h' = e^{dtA} h + dt·x ⊗ B
        a = jnp.exp(dtA[:, 0])  # (B,nh)
        h_new = (a[:, :, None, None] * h0
                 + jnp.einsum("bhp,bhn->bhpn", x_dt[:, 0], Bm[:, 0].astype(jnp.float32)))
        y = jnp.einsum("bhpn,bhn->bhp", h_new, Cm[:, 0].astype(jnp.float32))[:, None]
        h_last = h_new
    else:
        y, h_last = ssd_chunked(x_dt, dtA, Bm, Cm, h0, chunk)

    y = y + params["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(Bq, S, di).astype(x.dtype)
    y = _gated_norm(params, y, z, cfg.norm_eps)
    out = L.linear(params["out_proj"], y)
    return out, {"h": h_last, "conv": new_tail}
