"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Block layout (the "recurrent block" of Griffin):
    x ── linear ──> u ── causal conv1d ──> RG-LRU ──┐
    x ── linear ──> y = GeLU(·) ────────────────────⊙──> linear ──> out

RG-LRU cell (fp32 recurrence):
    r_t = σ(W_r x_t + b_r)            (recurrence gate, block-diagonal proj)
    i_t = σ(W_i x_t + b_i)            (input gate, block-diagonal proj)
    log a_t = -c · softplus(Λ) ⊙ r_t  (c = 8)
    h_t = a_t ⊙ h_{t-1} + √(1 − a_t²) ⊙ (i_t ⊙ x_t)

Training uses ``jax.lax.associative_scan`` over the sequence (log-depth, TPU
friendly); decode is a single fused step against a (batch, width) carried state.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L

_C = 8.0


def init_rglru_block(cfg: ModelConfig, key, dtype=jnp.bfloat16) -> dict:
    d = cfg.d_model
    w = cfg.rglru_width or d
    nh = max(cfg.num_heads, 1)
    bh = w // nh  # block size of the block-diagonal gate projections
    k1, k2, k3, k4, k5, k6, k7 = jax.random.split(key, 7)
    return {
        "in_main": L.init_linear(k1, d, w, dtype=dtype),
        "in_gate": L.init_linear(k2, d, w, dtype=dtype),
        "conv_w": (jax.random.normal(k3, (cfg.conv_kernel, w), jnp.float32)
                   * (cfg.conv_kernel ** -0.5)).astype(dtype),
        "conv_b": jnp.zeros((w,), dtype),
        # block-diagonal gate projections: (nh, bh, bh)
        "w_r": (jax.random.normal(k4, (nh, bh, bh), jnp.float32) * bh**-0.5).astype(dtype),
        "b_r": jnp.zeros((w,), jnp.float32),
        "w_i": (jax.random.normal(k5, (nh, bh, bh), jnp.float32) * bh**-0.5).astype(dtype),
        "b_i": jnp.zeros((w,), jnp.float32),
        # Λ init so that a = σ(Λ)^c is spread in [0.9, 0.999] (Griffin App. A)
        "lam": jnp.log(jnp.expm1(  # softplus^-1
            -jnp.log(jax.random.uniform(k6, (w,), jnp.float32, 0.9, 0.999)) / _C
        )),
        "out": L.init_linear(k7, w, d, dtype=dtype),
    }


def _block_diag(x: jax.Array, w: jax.Array) -> jax.Array:
    """x (..., W) @ block-diagonal w (nh, bh, bh) -> (..., W)."""
    nh, bh, _ = w.shape
    xs = x.reshape(*x.shape[:-1], nh, bh)
    return jnp.einsum("...hi,hij->...hj", xs, w).reshape(*x.shape)


def _causal_conv(u: jax.Array, w: jax.Array, b: jax.Array,
                 tail: jax.Array | None = None) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv along seq. u (B,S,W), w (K,W). Returns (out, new_tail)."""
    K = w.shape[0]
    if tail is None:
        tail = jnp.zeros((u.shape[0], K - 1, u.shape[-1]), u.dtype)
    ext = jnp.concatenate([tail, u], axis=1)  # (B, S+K-1, W)
    out = sum(ext[:, i : i + u.shape[1]] * w[i] for i in range(K)) + b
    return out, ext[:, -(K - 1):] if K > 1 else tail


def _rglru_coeffs(params: dict, u: jax.Array):
    """Gate computation -> (a fp32, b fp32) of h_t = a·h + b."""
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(_block_diag(u, params["w_r"]).astype(jnp.float32)
                       + params["b_r"])
    i = jax.nn.sigmoid(_block_diag(u, params["w_i"]).astype(jnp.float32)
                       + params["b_i"])
    log_a = -_C * jax.nn.softplus(params["lam"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * uf)
    return a, b


def _assoc_scan(a: jax.Array, b: jax.Array, h0: jax.Array | None):
    if h0 is not None:
        # fold the carried state into the first step
        b = b.at[:, 0].add(a[:, 0] * h0)

    def comb(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, b1 * a2 + b2

    _, h = jax.lax.associative_scan(comb, (a, b), axis=1)
    return h


def rglru_scan(params: dict, u: jax.Array, h0: jax.Array | None = None,
               chunk: int = 1024) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence RG-LRU. u (B,S,W) -> (h (B,S,W) fp32, h_last).

    Sequence-chunked: an outer lax.scan carries the state across chunks while a
    log-depth associative scan runs inside each (rematted) chunk — the fp32
    (B, S, W) gate/state temporaries of a monolithic associative scan dominate
    HBM at 4k×4096w training otherwise (EXPERIMENTS.md §Perf)."""
    B, S, W = u.shape
    Q = min(chunk, S)
    if S % Q or S == Q:
        a, b = _rglru_coeffs(params, u)
        h = _assoc_scan(a, b, h0)
        return h, h[:, -1]
    nc = S // Q
    uc = u.reshape(B, nc, Q, W).transpose(1, 0, 2, 3)  # (nc, B, Q, W)
    hinit = (jnp.zeros((B, W), jnp.float32) if h0 is None
             else h0.astype(jnp.float32))

    @jax.checkpoint
    def chunk_body(h, u_blk):
        a, b = _rglru_coeffs(params, u_blk)
        hs = _assoc_scan(a, b, h)
        return hs[:, -1], hs

    h_last, hs = jax.lax.scan(chunk_body, hinit, uc)
    return hs.transpose(1, 0, 2, 3).reshape(B, S, W), h_last


def rglru_step(params: dict, u: jax.Array, h: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Single decode step. u (B,1,W), h (B,W) fp32 -> (out (B,1,W), new h)."""
    a, b = _rglru_coeffs(params, u)
    h_new = a[:, 0] * h + b[:, 0]
    return h_new[:, None], h_new


def block_forward(
    cfg: ModelConfig, params: dict, x: jax.Array, state: dict | None = None
) -> Tuple[jax.Array, dict]:
    """Full recurrent block. x (B,S,d); state {h (B,W) fp32, conv (B,K-1,W)} or None.

    Returns (out (B,S,d), new_state).
    """
    gate = jax.nn.gelu(L.linear(params["in_gate"], x))
    u = L.linear(params["in_main"], x)
    tail = state["conv"] if state is not None else None
    u, new_tail = _causal_conv(u, params["conv_w"], params["conv_b"], tail)
    h0 = state["h"] if state is not None else None
    if x.shape[1] == 1 and state is not None:  # decode fast path
        h_seq, h_last = rglru_step(params, u, h0)
    else:
        h_seq, h_last = rglru_scan(params, u, h0)
    out = L.linear(params["out"], h_seq.astype(x.dtype) * gate)
    return out, {"h": h_last, "conv": new_tail}
