"""Mixture-of-Experts FFN: routed top-k experts + optional always-on shared experts.

Two dispatch implementations share one router:

- **capacity-bounded one-hot einsum** (Switch/GShard style) — the training
  baseline: fully GSPMD-shardable (token dims follow ``data``, the expert dim
  shards over ``model``), capacity competition and drops included.
- **sorted-scatter dropless** (``dropless=True``, the serving path): the
  (token, slot) assignments are stably argsorted by expert id and the experts
  run as one grouped GEMM (``jax.lax.ragged_dot``); outputs scatter-add back
  per token. Memory is O(T·K) assignment rows instead of the O(g²) capacity
  buffers the one-hot dropless form needed (the §Perf follow-up the old
  docstring promised). Every routed token gets capacity, so a token's output
  depends only on itself — the invariant continuous batching needs (a slot's
  logits must not depend on its batch neighbours).

Router follows Qwen-MoE: softmax over all experts, take top-k, renormalise the
top-k probabilities. Load-balance auxiliary loss is the standard Switch form
``E · Σ_e f_e · P_e``.
"""
from __future__ import annotations

import contextlib
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L

# Optional expert-parallel sharding constraints (set by the launcher): without
# them GSPMD all-reduces the (G,E,C,d) expert buffers across the model axis —
# ~1.5 GiB/layer at prefill_32k (EXPERIMENTS.md §Perf, pair B). With them the
# dispatch/expert compute stays (G→data, E→model)-sharded and only the combine
# output needs one activation-sized all-reduce.
_MOE_MESH: list = [None]


@contextlib.contextmanager
def expert_sharding(mesh):
    _MOE_MESH[0] = mesh
    try:
        yield
    finally:
        _MOE_MESH[0] = None


def _constrain_ep(x, spec_dims):
    mesh = _MOE_MESH[0]
    if mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import batch_axes
    baxes = batch_axes(mesh)
    dims = [baxes if d == "B" else ("model" if d == "M" else None)
            for d in spec_dims]
    # divisibility guard: skip constraint when a dim doesn't divide
    for dim, d in zip(x.shape, dims):
        size = 1
        names = d if isinstance(d, tuple) else ((d,) if d else ())
        for nm in names:
            size *= mesh.shape[nm]
        if size > 1 and dim % size:
            return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*dims)))


def init_moe(cfg: ModelConfig, key, dtype=jnp.bfloat16) -> dict:
    d, f, E = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    kr, kg, ku, kd, ks, ksg = jax.random.split(key, 6)

    def expert_stack(k, shape):
        return (jax.random.normal(k, shape, jnp.float32) * (shape[1] ** -0.5)).astype(dtype)

    p = {
        "router": (jax.random.normal(kr, (d, E), jnp.float32) * d**-0.5),  # fp32 router
        "w_gate": expert_stack(kg, (E, d, f)),
        "w_up": expert_stack(ku, (E, d, f)),
        "w_down": expert_stack(kd, (E, f, d)),
    }
    if cfg.num_shared_experts:
        fs = f * cfg.num_shared_experts
        p["shared"] = L.init_swiglu(ks, d, fs, dtype=dtype)
        p["shared_gate"] = L.init_linear(ksg, d, 1, dtype=dtype)
    return p


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _dropless_sorted(params: dict, x2: jax.Array, top_p: jax.Array,
                     top_i: jax.Array, E: int) -> jax.Array:
    """Dropless dispatch via stable sort + grouped GEMM.

    x2 (T, d); top_p/top_i (T, K). Assignments are sorted by expert id so each
    expert's tokens are contiguous; ``ragged_dot`` runs all expert FFNs as one
    grouped matmul over those segments; a scatter-add combines the K weighted
    expert outputs per token. No capacity buffers, no drops.
    """
    T_, d = x2.shape
    K = top_i.shape[-1]
    e_flat = top_i.reshape(T_ * K)
    tok = jnp.arange(T_ * K, dtype=jnp.int32) // K
    order = jnp.argsort(e_flat)  # stable: ties keep token-major priority
    tok_sorted = tok[order]
    xs = jnp.take(x2, tok_sorted, axis=0)  # (T·K, d) expert-contiguous
    group_sizes = jnp.bincount(e_flat, length=E).astype(jnp.int32)
    h = jax.nn.silu(jax.lax.ragged_dot(xs, params["w_gate"], group_sizes))
    h = h * jax.lax.ragged_dot(xs, params["w_up"], group_sizes)
    ys = jax.lax.ragged_dot(h, params["w_down"], group_sizes)  # (T·K, d)
    w = top_p.reshape(T_ * K)[order].astype(ys.dtype)
    return jnp.zeros((T_, d), ys.dtype).at[tok_sorted].add(ys * w[:, None])


def moe_ffn(
    cfg: ModelConfig,
    params: dict,
    x: jax.Array,  # (B, S, d)
    *,
    group_size: int = 0,
    capacity_factor: float = 0.0,
    dropless: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,d), aux_loss scalar fp32).

    ``dropless=True`` gives every routed token capacity: routing then depends
    only on the token itself, never on how many tokens share the dispatch
    group. Serving needs this — capacity competition makes a request's logits
    depend on batch packing (prefill vs teacher-forced lengths disagree, and a
    continuous-batching slot would depend on its neighbours). The dropless
    path dispatches by sorted-scatter grouped GEMM (O(T·K) rows — see
    ``_dropless_sorted``); under an active ``expert_sharding`` mesh it falls
    back to the GSPMD-shardable one-hot C=g form (sorted dispatch needs a
    shard_map all-to-all to expert-parallelize — future §Perf work). Training
    keeps the capacity-bounded Switch/GShard baseline.
    """
    group_size = group_size or cfg.moe_group_size
    capacity_factor = capacity_factor or cfg.moe_capacity_factor
    Bq, S, d = x.shape
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    T = Bq * S
    g = min(group_size, T)
    G = T // g
    assert G * g == T, f"token count {T} not divisible by group {g}"
    xg = x.reshape(G, g, d)

    logits = (xg.astype(jnp.float32)) @ params["router"]  # (G, g, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, K)  # (G, g, K)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)  # renormalise (Qwen)

    # Sorted-scatter needs a shard_map all-to-all to stay expert-parallel;
    # under an active expert mesh keep the GSPMD-shardable one-hot dropless
    # form (C = g) so multi-chip serving doesn't silently replicate experts.
    if dropless and _MOE_MESH[0] is None:
        # Sorted-scatter grouped-GEMM dispatch: every assignment gets
        # capacity, memory O(T·K) rows (vs the O(g²) one-hot buffers).
        y = _dropless_sorted(params, x.reshape(T, d),
                             top_p.reshape(T, K), top_i.reshape(T, K), E)
        y = y.reshape(Bq, S, d).astype(x.dtype)
        # fraction routed per expert (pre-drop == post-drop: dropless)
        frac_tokens = (jnp.bincount(top_i.reshape(T * K), length=E)
                       .astype(jnp.float32) / T)
    else:
        if dropless:
            C = g  # every token keeps capacity; one-hot but drop-free
        else:
            C = _round_up(max(int(g * K / E * capacity_factor), 4), 4)
            C = min(C, g)

        # Position of each (token, slot) within its expert's capacity buffer.
        # Token-major priority: earlier tokens (and earlier top-k slots) win
        # capacity.
        onehot = jax.nn.one_hot(top_i, E, dtype=jnp.float32)  # (G, g, K, E)
        flat = onehot.reshape(G, g * K, E)
        pos_in_e = (jnp.cumsum(flat, axis=1) - flat).reshape(G, g, K, E)

        # Build dispatch/combine by accumulating over the K (small, static)
        # slots — never materialising the (G,g,K,E,C) 5-D tensor.
        dispatch = jnp.zeros((G, g, E, C), jnp.float32)
        combine = jnp.zeros((G, g, E, C), jnp.float32)
        for k in range(K):
            e_k = top_i[:, :, k]  # (G, g)
            p_k = jnp.take_along_axis(pos_in_e[:, :, k], e_k[..., None],
                                      axis=-1)[..., 0]
            keep_k = (p_k < C).astype(jnp.float32)
            eh = jax.nn.one_hot(e_k, E, dtype=jnp.float32) * keep_k[..., None]
            ph = jax.nn.one_hot(p_k.astype(jnp.int32), C, dtype=jnp.float32)
            d_k = jnp.einsum("gse,gsc->gsec", eh, ph)
            dispatch = dispatch + d_k
            combine = combine + d_k * top_p[:, :, k][..., None, None]

        # Expert compute on capacity buffers (E sharded over `model`,
        # token-groups over `data`; see expert_sharding above).
        dispatch = _constrain_ep(dispatch, ("B", None, "M", None))
        combine = _constrain_ep(combine, ("B", None, "M", None))
        xe = jnp.einsum("gsec,gsd->gecd", dispatch.astype(x.dtype), xg)
        xe = _constrain_ep(xe, ("B", "M", None, None))
        h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, params["w_gate"]))
        h = h * jnp.einsum("gecd,edf->gecf", xe, params["w_up"])
        ye = jnp.einsum("gecf,efd->gecd", h, params["w_down"])  # (G,E,C,d)
        ye = _constrain_ep(ye, ("B", "M", None, None))
        y = jnp.einsum("gsec,gecd->gsd", combine.astype(x.dtype),
                       ye).reshape(Bq, S, d)
        frac_tokens = jnp.mean(onehot.sum(2), axis=(0, 1))  # (E,) pre-drop

    # Switch load-balance aux loss.
    frac_probs = jnp.mean(probs, axis=(0, 1))  # (E,)
    aux = E * jnp.sum(frac_tokens / K * frac_probs)

    if cfg.num_shared_experts:
        gate = jax.nn.sigmoid(L.linear(params["shared_gate"], x))
        y = y + gate * L.swiglu(params["shared"], x)
    return y, aux
