"""Mixture-of-Experts FFN: routed top-k experts + optional always-on shared experts.

Baseline implementation is the classic capacity-bounded one-hot dispatch einsum
(Switch/GShard style) — fully GSPMD-shardable: token dims follow the ``data`` axis,
the expert dim shards over ``model`` (expert parallelism). The §Perf hillclimb
replaces the dispatch einsum with an explicit shard_map all-to-all (see
EXPERIMENTS.md); this module is the paper-faithful-era baseline.

Router follows Qwen-MoE: softmax over all experts, take top-k, renormalise the
top-k probabilities. Load-balance auxiliary loss is the standard Switch form
``E · Σ_e f_e · P_e``.
"""
from __future__ import annotations

import contextlib
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L

# Optional expert-parallel sharding constraints (set by the launcher): without
# them GSPMD all-reduces the (G,E,C,d) expert buffers across the model axis —
# ~1.5 GiB/layer at prefill_32k (EXPERIMENTS.md §Perf, pair B). With them the
# dispatch/expert compute stays (G→data, E→model)-sharded and only the combine
# output needs one activation-sized all-reduce.
_MOE_MESH: list = [None]


@contextlib.contextmanager
def expert_sharding(mesh):
    _MOE_MESH[0] = mesh
    try:
        yield
    finally:
        _MOE_MESH[0] = None


def _constrain_ep(x, spec_dims):
    mesh = _MOE_MESH[0]
    if mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import batch_axes
    baxes = batch_axes(mesh)
    dims = [baxes if d == "B" else ("model" if d == "M" else None)
            for d in spec_dims]
    # divisibility guard: skip constraint when a dim doesn't divide
    for dim, d in zip(x.shape, dims):
        size = 1
        names = d if isinstance(d, tuple) else ((d,) if d else ())
        for nm in names:
            size *= mesh.shape[nm]
        if size > 1 and dim % size:
            return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*dims)))


def init_moe(cfg: ModelConfig, key, dtype=jnp.bfloat16) -> dict:
    d, f, E = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    kr, kg, ku, kd, ks, ksg = jax.random.split(key, 6)

    def expert_stack(k, shape):
        return (jax.random.normal(k, shape, jnp.float32) * (shape[1] ** -0.5)).astype(dtype)

    p = {
        "router": (jax.random.normal(kr, (d, E), jnp.float32) * d**-0.5),  # fp32 router
        "w_gate": expert_stack(kg, (E, d, f)),
        "w_up": expert_stack(ku, (E, d, f)),
        "w_down": expert_stack(kd, (E, f, d)),
    }
    if cfg.num_shared_experts:
        fs = f * cfg.num_shared_experts
        p["shared"] = L.init_swiglu(ks, d, fs, dtype=dtype)
        p["shared_gate"] = L.init_linear(ksg, d, 1, dtype=dtype)
    return p


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def moe_ffn(
    cfg: ModelConfig,
    params: dict,
    x: jax.Array,  # (B, S, d)
    *,
    group_size: int = 0,
    capacity_factor: float = 0.0,
    dropless: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,d), aux_loss scalar fp32).

    ``dropless=True`` gives every routed token capacity (C = g): routing then
    depends only on the token itself, never on how many tokens share the
    dispatch group. Serving needs this — capacity competition makes a request's
    logits depend on batch packing (prefill vs teacher-forced lengths disagree,
    and a continuous-batching slot would depend on its neighbours). Training
    keeps the capacity-bounded Switch/GShard baseline. A sorted-scatter
    dropless dispatch (capacity buffers are O(g²) here) is a §Perf follow-up.
    """
    group_size = group_size or cfg.moe_group_size
    capacity_factor = capacity_factor or cfg.moe_capacity_factor
    Bq, S, d = x.shape
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    T = Bq * S
    g = min(group_size, T)
    G = T // g
    assert G * g == T, f"token count {T} not divisible by group {g}"
    xg = x.reshape(G, g, d)

    logits = (xg.astype(jnp.float32)) @ params["router"]  # (G, g, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, K)  # (G, g, K)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)  # renormalise (Qwen)

    if dropless:
        C = g
    else:
        C = _round_up(max(int(g * K / E * capacity_factor), 4), 4)
        C = min(C, g)

    # Position of each (token, slot) within its expert's capacity buffer.
    # Token-major priority: earlier tokens (and earlier top-k slots) win capacity.
    onehot = jax.nn.one_hot(top_i, E, dtype=jnp.float32)  # (G, g, K, E)
    flat = onehot.reshape(G, g * K, E)
    pos_in_e = (jnp.cumsum(flat, axis=1) - flat).reshape(G, g, K, E)  # (G,g,K,E)

    # Build dispatch/combine by accumulating over the K (small, static) slots —
    # never materialising the (G,g,K,E,C) 5-D tensor.
    dispatch = jnp.zeros((G, g, E, C), jnp.float32)
    combine = jnp.zeros((G, g, E, C), jnp.float32)
    for k in range(K):
        e_k = top_i[:, :, k]  # (G, g)
        p_k = jnp.take_along_axis(pos_in_e[:, :, k], e_k[..., None], axis=-1)[..., 0]
        keep_k = (p_k < C).astype(jnp.float32)
        eh = jax.nn.one_hot(e_k, E, dtype=jnp.float32) * keep_k[..., None]
        ph = jax.nn.one_hot(p_k.astype(jnp.int32), C, dtype=jnp.float32)
        d_k = jnp.einsum("gse,gsc->gsec", eh, ph)
        dispatch = dispatch + d_k
        combine = combine + d_k * top_p[:, :, k][..., None, None]

    # Expert compute on capacity buffers (E sharded over `model`,
    # token-groups over `data`; see expert_sharding above).
    dispatch = _constrain_ep(dispatch, ("B", None, "M", None))
    combine = _constrain_ep(combine, ("B", None, "M", None))
    xe = jnp.einsum("gsec,gsd->gecd", dispatch.astype(x.dtype), xg)  # (G,E,C,d)
    xe = _constrain_ep(xe, ("B", "M", None, None))
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, params["w_gate"]))
    h = h * jnp.einsum("gecd,edf->gecf", xe, params["w_up"])
    ye = jnp.einsum("gecf,efd->gecd", h, params["w_down"])  # (G,E,C,d)
    ye = _constrain_ep(ye, ("B", "M", None, None))
    y = jnp.einsum("gsec,gecd->gsd", combine.astype(x.dtype), ye).reshape(Bq, S, d)

    # Switch load-balance aux loss.
    frac_tokens = jnp.mean(onehot.sum(2), axis=(0, 1))  # (E,) fraction routed (pre-drop)
    frac_probs = jnp.mean(probs, axis=(0, 1))  # (E,)
    aux = E * jnp.sum(frac_tokens / K * frac_probs)

    if cfg.num_shared_experts:
        gate = jax.nn.sigmoid(L.linear(params["shared_gate"], x))
        y = y + gate * L.swiglu(params["shared"], x)
    return y, aux
