"""Modality frontend stubs (the brief's single permitted carve-out).

For [audio] (MusicGen: EnCodec conv codec) and [vlm] (Qwen2-VL: ViT + projector)
architectures we do NOT implement the encoder; ``input_specs`` supplies precomputed
frame/patch embeddings of the right shape. This module provides (a) the spec
builders and (b) deterministic synthetic embedding generators so smoke tests and
examples can run end-to-end on CPU.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def embed_spec(cfg: ModelConfig, batch: int, seq: int, dtype=jnp.bfloat16):
    """ShapeDtypeStruct for precomputed frontend embeddings."""
    return jax.ShapeDtypeStruct((batch, seq, cfg.d_model), dtype)


def mrope_position_spec(batch: int, seq: int):
    """(3, B, S) temporal/height/width position ids for M-RoPE."""
    return jax.ShapeDtypeStruct((3, batch, seq), jnp.int32)


def synth_embeddings(cfg: ModelConfig, key, batch: int, seq: int,
                     dtype=jnp.bfloat16) -> jax.Array:
    """Deterministic stand-in embeddings (unit-variance gaussian)."""
    return jax.random.normal(key, (batch, seq, cfg.d_model), jnp.float32).astype(dtype)


def synth_mrope_positions(batch: int, seq: int, *, image_patches: int = 0,
                          grid: Optional[tuple] = None) -> jax.Array:
    """3-D M-RoPE ids: an optional leading vision block laid out on a (t,h,w)
    grid, followed by text positions advancing all three streams together
    (Qwen2-VL §3.1)."""
    if image_patches and grid is None:
        side = max(int(image_patches ** 0.5), 1)
        grid = (1, side, max(image_patches // side, 1))
        image_patches = grid[0] * grid[1] * grid[2]
    t_ids, h_ids, w_ids = [], [], []
    if image_patches:
        tt, hh, ww = jnp.meshgrid(
            jnp.arange(grid[0]), jnp.arange(grid[1]), jnp.arange(grid[2]),
            indexing="ij")
        t_ids.append(tt.reshape(-1))
        h_ids.append(hh.reshape(-1))
        w_ids.append(ww.reshape(-1))
    n_text = seq - image_patches
    start = (max(grid) if image_patches else 0)
    text = jnp.arange(start, start + n_text)
    t_ids.append(text), h_ids.append(text), w_ids.append(text)
    ids = jnp.stack([jnp.concatenate(t_ids), jnp.concatenate(h_ids),
                     jnp.concatenate(w_ids)])  # (3, S)
    return jnp.broadcast_to(ids[:, None, :], (3, batch, seq)).astype(jnp.int32)
