"""GQA attention with RoPE / M-RoPE, qk-norm, QKV bias and sliding-window support.

One implementation serves every assigned family:
  - dense / moe / vlm / audio: full causal attention (``attn``)
  - recurrentgemma local layers + long-context variant of dense archs: ``swa``
  - decode paths attend over a cache, optionally the *concatenation* of the
    receiver's own cache with fused transmitter caches (the paper's Eq. 1/4) —
    ``attend`` is deliberately cache-layout agnostic so core/c2c.py can reuse it.

Layer-local contract: ``extra_kv`` here is one *per-layer slice* of a
``models/cache.FusedPrefix`` — a FusedPrefix itself, produced by
``FusedPrefix.to_extra_kv`` and consumed by attribute access
(``.k``/``.v``/``.bias``; bias may be None). Legacy ``{"k","v"[,"bias"]}``
dicts are upgraded on entry. This module never sees the whole typed prefix,
so it works unchanged for dense rows, paged gather views, and any channel
codec upstream.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L


def _ensure_prefix(extra_kv: Optional[Any]) -> Optional[Any]:
    """Upgrade a legacy extra-KV dict to a FusedPrefix slice (no-op for the
    typed path). Import is deferred — cache.py sits above this module."""
    if extra_kv is None or not isinstance(extra_kv, dict):
        return extra_kv
    from repro.models.cache import FusedPrefix

    return FusedPrefix.ensure(extra_kv)


# ------------------------------------------------------------------ params


def init_attention(cfg: ModelConfig, key, dtype=jnp.bfloat16) -> dict:
    hd = cfg.resolved_head_dim
    kq, kk, kv, ko, kn = jax.random.split(key, 5)
    p = {
        "wq": L.init_linear(kq, cfg.d_model, cfg.num_heads * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wk": L.init_linear(kk, cfg.d_model, cfg.num_kv_heads * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wv": L.init_linear(kv, cfg.d_model, cfg.num_kv_heads * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wo": L.init_linear(ko, cfg.num_heads * hd, cfg.d_model, dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


# ------------------------------------------------------------------ projection


def project_qkv(
    cfg: ModelConfig,
    params: dict,
    x: jax.Array,  # (B, S, d)
    cos: jax.Array,  # (B, S, hd//2) or (S, hd//2)
    sin: jax.Array,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns q (B, H, S, hd), k/v (B, Hkv, S, hd) with RoPE + qk-norm applied."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = L.linear(params["wq"], x).reshape(B, S, cfg.num_heads, hd)
    k = L.linear(params["wk"], x).reshape(B, S, cfg.num_kv_heads, hd)
    v = L.linear(params["wv"], x).reshape(B, S, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = L.rmsnorm_nohead(q, params["q_norm"], cfg.norm_eps)
        k = L.rmsnorm_nohead(k, params["k_norm"], cfg.norm_eps)
    if cos.ndim == 2:  # (S, half) -> broadcast over batch
        cos, sin = cos[None], sin[None]
    q = L.apply_rope(q.transpose(0, 2, 1, 3), cos[:, None], sin[:, None])
    k = L.apply_rope(k.transpose(0, 2, 1, 3), cos[:, None], sin[:, None])
    v = v.transpose(0, 2, 1, 3)
    return q, k, v


# ------------------------------------------------------------------ core attend


def attend(
    q: jax.Array,  # (B, H, Sq, hd)
    k: jax.Array,  # (B, Hkv, Sk, hd)
    v: jax.Array,  # (B, Hkv, Sk, hd)
    mask: Optional[jax.Array],  # broadcastable to (B, 1|H, Sq, Sk); True = attend
    extra_bias: Optional[jax.Array] = None,  # additive (B|1, 1, Sq|1, Sk) fp32
) -> jax.Array:
    """Grouped-query scaled-dot-product attention; softmax in fp32.

    ``extra_bias`` implements the fuser/gating attention-mass gates (logit bias on
    fused-prefix keys). Returns (B, Sq, H*hd).
    """
    B, H, Sq, hd = q.shape
    Hkv = k.shape[1]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, Sq, hd)
    # NOTE: the dot runs in the operand dtype (bf16 on TPU MXU with native fp32
    # accumulation); forcing preferred_element_type=f32 here makes XLA
    # materialise an fp32 copy of the WHOLE cache operand (2× cache HBM —
    # EXPERIMENTS.md §Dry-run notes). Softmax is fp32 regardless.
    scores = jnp.einsum("bkgsd,bktd->bkgst", qg, k).astype(jnp.float32)
    scores = scores * (hd ** -0.5)
    if extra_bias is not None:
        assert extra_bias.ndim == 4 and extra_bias.shape[1] == 1, extra_bias.shape
        scores = scores + extra_bias[:, :, None].astype(jnp.float32)
    if mask is not None:
        # (B|1, 1, Sq, Sk) -> (B|1, 1, 1, Sq, Sk), broadcast over (Hkv, G)
        assert mask.ndim == 4 and mask.shape[1] == 1, mask.shape
        scores = jnp.where(mask[:, :, None], scores, jnp.float32(-1e30))
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,bktd->bkgsd", w.astype(v.dtype), v)
    return out.reshape(B, H, Sq, hd).transpose(0, 2, 1, 3).reshape(B, Sq, H * hd)


def attend_stats(
    q: jax.Array,  # (B, H, Sq, hd)
    k: jax.Array,  # (B, Hkv, Sk, hd)
    v: jax.Array,
    mask: Optional[jax.Array],
    extra_bias: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Attention with ONLINE-SOFTMAX STATISTICS exposed: returns
    (o_unnormalised (B,H,Sq,hd) fp32, m (B,H,Sq), l (B,H,Sq)) so two attention
    segments (e.g. fused prefix ∘ own cache) can be LSE-merged WITHOUT
    concatenating their k/v — each segment keeps its own sharding."""
    B, H, Sq, hd = q.shape
    Hkv = k.shape[1]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, Sq, hd)
    # operand-dtype dot (see attend): avoids an fp32 cache materialisation
    s = (jnp.einsum("bkgsd,bktd->bkgst", qg, k).astype(jnp.float32)
         * (hd ** -0.5))
    if extra_bias is not None:
        s = s + extra_bias[:, :, None].astype(jnp.float32)
    if mask is not None:
        s = jnp.where(mask[:, :, None], s, jnp.float32(-1e30))
    m = s.max(axis=-1)
    p = jnp.exp(s - m[..., None])
    l = p.sum(axis=-1)
    o = jnp.einsum("bkgst,bktd->bkgsd", p.astype(v.dtype), v).astype(jnp.float32)
    return (o.reshape(B, H, Sq, hd), m.reshape(B, H, Sq), l.reshape(B, H, Sq))


def merge_attention(parts) -> jax.Array:
    """Merge [(o, m, l), ...] online-softmax segments -> (B, Sq, H*hd)."""
    m_star = parts[0][1]
    for _, m, _ in parts[1:]:
        m_star = jnp.maximum(m_star, m)
    o_sum = 0.0
    l_sum = 0.0
    for o, m, l in parts:
        alpha = jnp.exp(m - m_star)
        o_sum = o_sum + o * alpha[..., None]
        l_sum = l_sum + l * alpha
    out = o_sum / jnp.maximum(l_sum[..., None], 1e-30)
    B, H, Sq, hd = out.shape
    return out.transpose(0, 2, 1, 3).reshape(B, Sq, H * hd).astype(jnp.float32)


def causal_mask(Sq: int, Sk: int, *, window: int = 0) -> jax.Array:
    """(1, 1, Sq, Sk) boolean; assumes queries are the last Sq of the Sk keys."""
    qpos = jnp.arange(Sq) + (Sk - Sq)
    kpos = jnp.arange(Sk)
    m = kpos[None, :] <= qpos[:, None]
    if window > 0:
        m &= kpos[None, :] > qpos[:, None] - window
    return m[None, None]


# ------------------------------------------------------------------ flash fwd


def _flash_attend(
    q: jax.Array,  # (B, H, S, hd)
    k: jax.Array,  # (B, Hkv, Sk, hd) — may include a fused prefix
    v: jax.Array,
    key_pos: jax.Array,  # (Sk,) int32; -1 = always-visible prefix key
    key_bias: Optional[jax.Array],  # (B, Sk) fp32 additive, or None
    *,
    window: int = 0,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
) -> jax.Array:
    """Memory-efficient causal attention: q-chunked map with a REMATTED body.

    This is the jnp twin of the Pallas flash kernels. Each q chunk attends over
    the full key set with fp32 softmax; the body is jax.checkpoint'ed, so the
    backward pass recomputes each chunk's scores instead of storing them (the
    same recompute strategy real flash-attention backward uses). Live score
    memory is O(q_chunk × Sk) — bounded by an adaptive q_chunk — instead of
    O(S²); an online-softmax kv-scan variant was rejected because scan carries
    (m, l, acc) must be saved per step for backward, which at 32k keys costs
    more HBM than it saves (EXPERIMENTS.md §Perf, iteration log).

    FLOP count equals the dense einsum (masked blocks are computed then
    discarded — §Perf notes the banded-skip optimisation for SWA).
    """
    B, H, S, hd = q.shape
    Hkv = k.shape[1]
    Sk = k.shape[2]
    G = H // Hkv
    # adaptive q chunk: bound the GLOBAL fp32 score block ≈ 64 GiB (≤ 256 MiB
    # per chip on the production mesh)
    budget = 64 * 2**30
    qc = min(q_chunk, S)
    while qc > 16 and B * H * qc * Sk * 4 > budget:
        qc //= 2
    pad_q = (-S) % qc
    qp = jnp.arange(S, dtype=jnp.int32)
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
        qp = jnp.concatenate([qp, jnp.zeros((pad_q,), jnp.int32)])
    Sq_p = S + pad_q
    nq = Sq_p // qc
    qg = q.reshape(B, Hkv, G, Sq_p, hd)
    scale = hd ** -0.5

    @jax.checkpoint
    def q_block(qi):
        qblk = jax.lax.dynamic_slice_in_dim(qg, qi * qc, qc, axis=3)
        qpos = jax.lax.dynamic_slice_in_dim(qp, qi * qc, qc)
        s = jnp.einsum("bkgqd,bktd->bkgqt", qblk, k,
                       preferred_element_type=jnp.float32) * scale
        if key_bias is not None:
            s = s + key_bias[:, None, None, None, :].astype(jnp.float32)
        mask = key_pos[None, :] <= qpos[:, None]
        if window > 0:
            mask &= (key_pos[None, :] < 0) | (key_pos[None, :] > qpos[:, None] - window)
        s = jnp.where(mask[None, None, None], s, jnp.float32(-1e30))
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bkgqt,bktd->bkgqd", p.astype(v.dtype), v)
        return out.astype(q.dtype)

    outs = jax.lax.map(q_block, jnp.arange(nq))
    # outs: (nq, B, Hkv, G, qc, hd) -> (B, S, H*hd)
    out = outs.transpose(1, 2, 3, 0, 4, 5).reshape(B, Hkv, G, Sq_p, hd)
    out = out[:, :, :, :S]
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3).reshape(B, S, H * hd)


# ------------------------------------------------------------------ block fwd


def full_forward(
    cfg: ModelConfig,
    params: dict,
    x: jax.Array,
    cos: jax.Array,
    sin: jax.Array,
    *,
    window: int = 0,
    extra_kv: Optional[Any] = None,  # per-layer FusedPrefix slice (C2C): k/v (B,Hkv,Sf,hd)
    flash_threshold: int = 2048,  # above this S, use the chunked flash path
) -> Tuple[jax.Array, dict]:
    """Training/prefill attention over the whole sequence.

    Returns (out (B,S,d), kv dict with k/v (B,Hkv,S,hd)) — the kv dict is what
    prefill stores into the cache and what C2C projects. ``extra_kv`` (the paper's
    C(F_ij, M_i) term) is prepended sequence-wise and visible to every query.
    """
    S = x.shape[1]
    B = x.shape[0]
    extra_kv = _ensure_prefix(extra_kv)
    q, k, v = project_qkv(cfg, params, x, cos, sin)

    if S > flash_threshold:  # memory-efficient path (train_4k / prefill_32k)
        k_all, v_all = k, v
        key_pos = jnp.arange(S, dtype=jnp.int32)
        key_bias = None
        if extra_kv is not None:
            Sf = extra_kv.k.shape[-2]
            k_all = jnp.concatenate([extra_kv.k.astype(k.dtype), k], axis=-2)
            v_all = jnp.concatenate([extra_kv.v.astype(v.dtype), v], axis=-2)
            key_pos = jnp.concatenate(
                [jnp.full((Sf,), -1, jnp.int32), key_pos])  # prefix: always visible
            if extra_kv.bias is not None:
                key_bias = jnp.concatenate(
                    [extra_kv.bias.astype(jnp.float32),
                     jnp.zeros((B, S), jnp.float32)], axis=-1)
        out = _flash_attend(q, k_all, v_all, key_pos, key_bias, window=window)
        return L.linear(params["wo"], out), {"k": k, "v": v}

    mask = causal_mask(S, S, window=window)
    extra_bias = None
    if extra_kv is not None:
        Sf = extra_kv.k.shape[-2]
        k = jnp.concatenate([extra_kv.k.astype(k.dtype), k], axis=-2)
        v = jnp.concatenate([extra_kv.v.astype(v.dtype), v], axis=-2)
        pre = jnp.ones((1, 1, S, Sf), bool)
        mask = jnp.concatenate([pre, jnp.broadcast_to(mask, (1, 1, S, S))], axis=-1)
        if extra_kv.bias is not None:  # per-position gate bias on the fused prefix
            eb = jnp.broadcast_to(extra_kv.bias[:, None, None, :], (B, 1, 1, Sf))
            extra_bias = jnp.concatenate(
                [eb, jnp.zeros((B, 1, 1, S), jnp.float32)], axis=-1)
    out = attend(q, k, v, mask, extra_bias)
    return L.linear(params["wo"], out), {"k": k[..., -S:, :], "v": v[..., -S:, :]}


def decode_forward(
    cfg: ModelConfig,
    params: dict,
    x: jax.Array,  # (B, 1, d)
    cos: jax.Array,  # (B, 1, hd//2)
    sin: jax.Array,
    kv: dict,  # full: k/v (B,Hkv,S,hd); swa ring: + slot_pos (B,W)
    pos: jax.Array,  # int32 — current absolute position: scalar or per-slot (B,)
    *,
    window: int = 0,
    extra_kv: Optional[Any] = None,  # per-layer FusedPrefix slice (C2C), always visible
    extra_kv_mode: str = "concat",  # "concat" (Eq. 1 literal) | "split" (LSE merge)
) -> Tuple[jax.Array, dict]:
    """Single-token decode against a cache; returns (out (B,1,d), updated kv).

    ``pos`` may be a scalar (lockstep batch: every row at the same position) or a
    per-row (B,) vector (continuous batching: each slot decodes at its own
    position — launch/engine.py). The vector path vmaps the cache write over the
    batch and masks keys per row.
    """
    B = x.shape[0]
    per_slot = pos.ndim == 1
    extra_kv = _ensure_prefix(extra_kv)
    q, k_new, v_new = project_qkv(cfg, params, x, cos, sin)
    k_new = k_new.astype(kv["k"].dtype)
    v_new = v_new.astype(kv["v"].dtype)

    if "slot_pos" in kv:  # sliding-window ring buffer
        W = kv["k"].shape[-2]
        slot = pos % W
        if per_slot:
            upd = jax.vmap(
                lambda c, n, s: jax.lax.dynamic_update_slice(c, n, (0, s, 0)))
            k = upd(kv["k"], k_new, slot)
            v = upd(kv["v"], v_new, slot)
            slot_pos = jax.vmap(
                lambda sp, s, p: jax.lax.dynamic_update_slice(sp, p[None], (s,))
            )(kv["slot_pos"], slot, pos.astype(jnp.int32))
        else:
            k = jax.lax.dynamic_update_slice(kv["k"], k_new, (0, 0, slot, 0))
            v = jax.lax.dynamic_update_slice(kv["v"], v_new, (0, 0, slot, 0))
            slot_pos = jax.lax.dynamic_update_slice(
                kv["slot_pos"], jnp.broadcast_to(pos, (B, 1)).astype(jnp.int32),
                (0, slot))
        p = pos[:, None] if per_slot else pos
        valid = (slot_pos >= 0) & (slot_pos > p - (window or W)) & (slot_pos <= p)
        mask = valid[:, None, None, :]  # (B,1,1,W)
        new_kv = {"k": k, "v": v, "slot_pos": slot_pos}
    else:  # full cache
        S = kv["k"].shape[-2]
        if per_slot:
            upd = jax.vmap(
                lambda c, n, p: jax.lax.dynamic_update_slice(c, n, (0, p, 0)))
            k = upd(kv["k"], k_new, pos)
            v = upd(kv["v"], v_new, pos)
            mask = (jnp.arange(S)[None, :] <= pos[:, None])[:, None, None, :]
        else:
            k = jax.lax.dynamic_update_slice(kv["k"], k_new, (0, 0, pos, 0))
            v = jax.lax.dynamic_update_slice(kv["v"], v_new, (0, 0, pos, 0))
            mask = (jnp.arange(S) <= pos)[None, None, None, :]
        new_kv = {"k": k, "v": v}

    if extra_kv is not None and extra_kv_mode == "split":
        # LSE-merged split attention: own cache and fused prefix attend
        # separately (each under its own sharding), merged by online-softmax
        # statistics — no concatenated 2S cache is ever formed (§Perf, pair C).
        own = attend_stats(q, k, v, mask)
        pb = (extra_kv.bias[:, None, None, :]
              if extra_kv.bias is not None else None)
        pre = attend_stats(q, extra_kv.k.astype(k.dtype),
                           extra_kv.v.astype(v.dtype), None, pb)
        out = merge_attention([own, pre]).astype(x.dtype)
        return L.linear(params["wo"], out), new_kv

    extra_bias = None
    if extra_kv is not None:
        Sf = extra_kv.k.shape[-2]
        k = jnp.concatenate([extra_kv.k.astype(k.dtype), k], axis=-2)
        v = jnp.concatenate([extra_kv.v.astype(v.dtype), v], axis=-2)
        fmask = jnp.ones((1, 1, 1, Sf), bool)
        mask = jnp.concatenate([jnp.broadcast_to(fmask, (*mask.shape[:3], Sf)), mask],
                               axis=-1)
        if extra_kv.bias is not None:
            Sk = new_kv["k"].shape[-2]
            eb = jnp.broadcast_to(extra_kv.bias[:, None, None, :], (B, 1, 1, Sf))
            extra_bias = jnp.concatenate(
                [eb, jnp.zeros((B, 1, 1, Sk), jnp.float32)], axis=-1)

    out = attend(q, k, v, mask, extra_bias)
    return L.linear(params["wo"], out), new_kv


def prefill_chunk_forward(
    cfg: ModelConfig,
    params: dict,
    x: jax.Array,  # (1, C, d) — one packed chunk of prompt tokens
    cos: jax.Array,  # (1, C, hd//2) at absolute positions pos_offset + [0, C)
    sin: jax.Array,
    entry: dict,  # {"k","v"} page pools (num_pages, Hkv, page_size, hd)
    page_row: jax.Array,  # (pages_per_slot,) int32 — the slot's lease pages
    block_seq: jax.Array,  # (C//block_q,) int32 0 = live block, -1 = pad
    block_pos: jax.Array,  # (C//block_q,) int32 absolute first-query position
    block_len: jax.Array,  # (C//block_q,) int32 live rows per block
    phys: jax.Array,  # (C,) int32 physical page per token (INVALID = drop)
    off: jax.Array,  # (C,) int32 in-page offset per token
    *,
    block_q: int,
    extra_kv: Optional[Any] = None,  # per-layer FusedPrefix slice, always visible
) -> Tuple[jax.Array, dict]:
    """One chunk of token-budget prefill straight against the paged pool.

    The chunk's K/V scatter to their physical pages first (per-token phys/off,
    the same advanced-indexing scatter as SlotTable.insert_suffix; rows past
    the live count carry INVALID phys and drop), then the ragged flash-prefill
    kernel attends over the slot's page row — radix-shared prefix pages,
    earlier chunks and the current chunk uniformly under absolute-position
    causality. No dense staging cache is ever materialised: a partially
    prefilled slot holds real pool pages only. A fused C2C prefix is LSE-merged
    from the kernel's online-softmax statistics.

    Returns (out (1, C, d), updated {"k","v"} pools)."""
    from repro.kernels import ops

    extra_kv = _ensure_prefix(extra_kv)
    q, k_new, v_new = project_qkv(cfg, params, x, cos, sin)  # q (1,H,C,hd)

    def scatter(pool, new):
        # (1, Hkv, C, hd) -> per-token (C, Hkv, hd), the shape advanced
        # indexing wants for pool.at[phys, :, off]
        tok = new[0].transpose(1, 0, 2)
        return pool.at[phys, :, off].set(tok.astype(pool.dtype), mode="drop")

    k_pool = scatter(entry["k"], k_new)
    v_pool = scatter(entry["v"], v_new)
    o, m, l = ops.ragged_prefill_attention(
        q[0].transpose(1, 0, 2), k_pool, v_pool, block_seq, block_pos,
        block_len, page_row[None], block_q=block_q)
    new_kv = {"k": k_pool, "v": v_pool}
    if extra_kv is not None:
        # (C, H, ...) kernel outputs -> the (1, H, C, ...) part layout
        # merge_attention expects; dead rows (l == 0) take the prefix part
        # only, which is garbage confined to rows nothing ever reads
        own = ((o.astype(jnp.float32) * l[..., None]).transpose(1, 0, 2)[None],
               m.T[None], l.T[None])
        pb = (extra_kv.bias[:, None, None, :]
              if extra_kv.bias is not None else None)
        pre = attend_stats(q, extra_kv.k.astype(k_pool.dtype),
                           extra_kv.v.astype(v_pool.dtype), None, pb)
        out = merge_attention([own, pre]).astype(x.dtype)
    else:
        C, H, hd = o.shape
        out = o.reshape(1, C, H * hd)
    return L.linear(params["wo"], out), new_kv


def decode_forward_paged(
    cfg: ModelConfig,
    params: dict,
    x: jax.Array,  # (slots, 1, d)
    cos: jax.Array,  # (slots, 1, hd//2)
    sin: jax.Array,
    entry: dict,  # {"k","v"} page pools (num_pages, Hkv, page_size, hd)
    page_map: jax.Array,  # (slots, pages_per_slot) int32
    pos: jax.Array,  # (slots,) int32 per-slot decode position
    *,
    page_size: int,
    extra_kv: Optional[Any] = None,  # per-layer FusedPrefix slice, always visible
) -> Tuple[jax.Array, dict]:
    """Single-token decode straight against a paged page pool — the hot loop
    never gathers a dense view. The new token's k/v scatter to their physical
    page (SlotTable.write_token), the paged Pallas kernel walks the page map
    in place, and a fused prefix is LSE-merged from the kernel's online
    softmax statistics (no concatenated cache is ever formed).

    Returns (out (slots, 1, d), updated {"k","v"} pools)."""
    from repro.models.cache import SlotTable

    extra_kv = _ensure_prefix(extra_kv)
    q, k_new, v_new = project_qkv(cfg, params, x, cos, sin)  # q (B,H,1,hd)
    k_pool = SlotTable.write_token(entry["k"], k_new[:, :, 0], page_map, pos,
                                   page_size)
    v_pool = SlotTable.write_token(entry["v"], v_new[:, :, 0], page_map, pos,
                                   page_size)
    o, m, l = SlotTable.attend(q[:, :, 0], k_pool, v_pool, page_map, pos + 1)
    new_kv = {"k": k_pool, "v": v_pool}
    if extra_kv is not None:
        own = (o.astype(jnp.float32) * l[..., None])[:, :, None, :]
        pb = (extra_kv.bias[:, None, None, :]
              if extra_kv.bias is not None else None)
        pre = attend_stats(q, extra_kv.k.astype(k_pool.dtype),
                           extra_kv.v.astype(v_pool.dtype), None, pb)
        out = merge_attention([(own, m[:, :, None], l[:, :, None]), pre])
        out = out.astype(x.dtype)
    else:
        B, H, hd = o.shape
        out = o.reshape(B, 1, H * hd)
    return L.linear(params["wo"], out), new_kv
