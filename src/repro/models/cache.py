"""Decode-state pytrees: KV caches (full + sliding-window ring) and recurrent states.

The cache is the *medium of federation* in this paper (C2C communicates KV caches),
so its layout is a first-class design object:

- ``full`` attention layers: k/v of shape (batch, kv_heads, max_seq, head_dim);
  valid entries are positions [0, pos).
- ``swa`` layers: ring buffer of length ``window`` — slot = position % window, plus a
  per-slot ``slot_pos`` array so masking survives wrap-around. This is what makes
  long_500k (524 288-token decode) memory-feasible for windowed layers.
- ``rec`` layers (RG-LRU): hidden state (batch, width) + conv tail (batch, K-1, width).
- ``ssd`` layers (Mamba-2): state (batch, nheads, head_dim, d_state) + conv tail.

A model cache is ``{"pos": int32[], "layers": [per-pattern-position stacked pytrees]}``
— stacked along a leading cycle axis to match the scan-over-layers execution
(see transformer.py).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


# -------------------------------------------------------------------- builders


def init_attn_kv(
    cycles: int, batch: int, kv_heads: int, max_seq: int, head_dim: int, dtype
) -> dict:
    return {
        "k": jnp.zeros((cycles, batch, kv_heads, max_seq, head_dim), dtype),
        "v": jnp.zeros((cycles, batch, kv_heads, max_seq, head_dim), dtype),
    }


def init_swa_kv(
    cycles: int, batch: int, kv_heads: int, window: int, head_dim: int, dtype
) -> dict:
    return {
        "k": jnp.zeros((cycles, batch, kv_heads, window, head_dim), dtype),
        "v": jnp.zeros((cycles, batch, kv_heads, window, head_dim), dtype),
        # absolute position held by each ring slot; -1 = empty
        "slot_pos": jnp.full((cycles, batch, window), -1, jnp.int32),
    }


def init_rec_state(cycles: int, batch: int, width: int, conv_k: int, dtype) -> dict:
    return {
        "h": jnp.zeros((cycles, batch, width), jnp.float32),  # recurrence kept fp32
        "conv": jnp.zeros((cycles, batch, conv_k - 1, width), dtype),
    }


def init_ssd_state(
    cycles: int, batch: int, nheads: int, head_dim: int, d_state: int,
    conv_dim: int, conv_k: int, dtype
) -> dict:
    return {
        "h": jnp.zeros((cycles, batch, nheads, head_dim, d_state), jnp.float32),
        "conv": jnp.zeros((cycles, batch, conv_k - 1, conv_dim), dtype),
    }


def init_cache(
    cfg: ModelConfig,
    batch: int,
    max_seq: int,
    dtype=jnp.bfloat16,
    *,
    window_override: Optional[int] = None,
) -> dict:
    """Build the full decode cache for ``cfg`` (see transformer.py layer grouping)."""
    from repro.models.transformer import layer_grouping  # cycle structure

    cycles, pattern, tail = layer_grouping(cfg)
    hd = cfg.resolved_head_dim
    layers = []
    for pos, kind in enumerate(pattern + tail):
        n = cycles if pos < len(pattern) else 1
        if kind == "attn":
            layers.append(init_attn_kv(n, batch, cfg.num_kv_heads, max_seq, hd, dtype))
        elif kind == "swa":
            w = min(window_override or cfg.sliding_window or cfg.long_context_window,
                    max_seq)
            layers.append(init_swa_kv(n, batch, cfg.num_kv_heads, w, hd, dtype))
        elif kind == "rec":
            width = cfg.rglru_width or cfg.d_model
            layers.append(init_rec_state(n, batch, width, cfg.conv_kernel, dtype))
        elif kind == "ssd":
            conv_dim = cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state
            layers.append(
                init_ssd_state(n, batch, cfg.ssm_nheads, cfg.ssm_head_dim,
                               cfg.ssm_state, conv_dim, cfg.conv_kernel, dtype)
            )
        else:
            raise ValueError(f"unknown layer kind {kind!r}")
    return {"pos": jnp.zeros((), jnp.int32), "layers": layers}


# ----------------------------------------------------------------- concat (C2C)


def concat_kv(own: dict, fused: dict) -> dict:
    """Sequence-wise concatenation ``C(F_ij, M_i) ∘ C(M_j)`` of Eq. 1/4.

    Both operands are per-layer full-attention KV dicts with k/v of shape
    (..., kv_heads, seq, head_dim); the fused (projected transmitter) cache is
    *prepended*, matching the paper's decode equation where the receiver's own
    running cache stays contiguous at the tail.
    """
    return {
        "k": jnp.concatenate([fused["k"], own["k"]], axis=-2),
        "v": jnp.concatenate([fused["v"], own["v"]], axis=-2),
    }


def attn_kv_stack(cfg: ModelConfig, cache: dict, length: int | None = None) -> dict:
    """Collect all attention-layer k/v into one stack (n_attn, B, Hkv, S, hd).

    This is the tensor C2C communicates: the transmitter exports it, the fuser
    projects it, the receiver prepends it. Pattern positions + tail are
    concatenated in layer order along the leading axis.
    """
    from repro.models.transformer import layer_grouping

    cycles, pattern, tail = layer_grouping(cfg)
    ks, vs = [], []
    for i, kind in enumerate(pattern + tail):
        if kind in ("attn", "swa"):
            e = cache["layers"][i]
            ks.append(e["k"])
            vs.append(e["v"])
    k = jnp.concatenate(ks, axis=0)
    v = jnp.concatenate(vs, axis=0)
    if length is not None:
        k, v = k[..., :length, :], v[..., :length, :]
    return {"k": k, "v": v}


def extra_kv_layers(cfg: ModelConfig, fused_stack: dict) -> list:
    """Turn a fused stack (n_attn, B, Hkv, Sf, hd) into the per-position
    ``extra_kv`` list that transformer.forward / decode_step consume."""
    from repro.models.transformer import layer_grouping

    cycles, pattern, tail = layer_grouping(cfg)
    out = []
    off = 0

    def slice_at(o, n):
        e = {"k": fused_stack["k"][o : o + n], "v": fused_stack["v"][o : o + n]}
        if "bias" in fused_stack:
            e["bias"] = fused_stack["bias"][o : o + n]
        return e

    for i, kind in enumerate(pattern):
        if kind in ("attn", "swa"):
            out.append(slice_at(off, cycles))
            off += cycles
        else:
            out.append(None)
    for kind in tail:
        if kind in ("attn", "swa"):
            out.append(slice_at(off, 1))
            off += 1
        else:
            out.append(None)
    return out


# ------------------------------------------------------- slot table (engine)

# Additive attention-logit bias that masks an absent/inactive fused-prefix key.
# exp(PREFIX_MASK_BIAS - m) underflows to exactly 0 in fp32 softmax, so a fully
# masked prefix is *identical* to decoding with no prefix at all — the property
# that lets launch/engine.py keep one fixed-shape fused bucket per slot.
PREFIX_MASK_BIAS = -1e30


def init_slot_cache(
    cfg: ModelConfig,
    slots: int,
    max_seq: int,
    dtype=jnp.bfloat16,
    *,
    window_override: Optional[int] = None,
) -> dict:
    """A decode cache whose batch axis is a *slot table*: ``pos`` is per-slot
    (slots,) int32 so every slot decodes at its own position (continuous
    batching — launch/engine.py). Consumed by transformer.decode_step's
    vector-``pos`` path."""
    c = init_cache(cfg, slots, max_seq, dtype, window_override=window_override)
    c["pos"] = jnp.zeros((slots,), jnp.int32)
    return c


def _insert_slot_leaf(table_leaf: jax.Array, req_leaf: jax.Array,
                      slot: jax.Array) -> jax.Array:
    # every cache leaf is (cycles, batch, ...): scatter the request's batch=1
    # block at batch index ``slot``
    start = (jnp.zeros((), jnp.int32), slot) + tuple(
        jnp.zeros((), jnp.int32) for _ in range(table_leaf.ndim - 2))
    return jax.lax.dynamic_update_slice(
        table_leaf, req_leaf.astype(table_leaf.dtype), start)


def cache_insert_slot(table: dict, slot: jax.Array, req: dict,
                      length: jax.Array) -> dict:
    """Insert a single-request cache (batch 1, same ``max_seq``) into slot
    ``slot`` of a slot-table cache and set that slot's position to ``length``.

    Stale K/V beyond ``length`` (from a previous occupant) never need zeroing:
    the per-slot position mask hides them, and decode overwrites each index
    before it first becomes visible."""
    slot = jnp.asarray(slot, jnp.int32)
    layers = [
        jax.tree.map(lambda t, r: _insert_slot_leaf(t, r, slot), tl, rl)
        for tl, rl in zip(table["layers"], req["layers"])
    ]
    pos = table["pos"].at[slot].set(jnp.asarray(length, jnp.int32))
    return {"pos": pos, "layers": layers}


def cache_evict_slot(table: dict, slot) -> dict:
    """Free a slot immediately: reset its position (stale K/V stay but are
    masked — see cache_insert_slot)."""
    return {"pos": table["pos"].at[jnp.asarray(slot, jnp.int32)].set(0),
            "layers": table["layers"]}


def empty_fused_stack(cfg: ModelConfig, batch: int, max_prefix: int,
                      dtype=jnp.float32) -> dict:
    """All-masked fused-prefix stack: k/v zeros (n_attn, batch, Hkv, max_prefix,
    hd) and bias PREFIX_MASK_BIAS everywhere. Decoding against it equals
    standalone decoding exactly."""
    n = len(cfg.attention_layers)
    hd = cfg.resolved_head_dim
    shape = (n, batch, cfg.num_kv_heads, max_prefix, hd)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "bias": jnp.full((n, batch, max_prefix), PREFIX_MASK_BIAS, jnp.float32),
    }


def pad_fused_stack(fused: dict, max_prefix: int) -> dict:
    """Right-pad a fused prefix stack to the fixed ``max_prefix`` bucket; padded
    positions get bias PREFIX_MASK_BIAS (zero attention mass). This is what
    keeps the engine's decode step shape-stable across request mixes."""
    n, B, H, S, hd = fused["k"].shape
    if S > max_prefix:
        raise ValueError(f"fused prefix length {S} exceeds max_prefix {max_prefix}")
    pad = max_prefix - S
    bias = fused.get("bias")
    if bias is None:
        bias = jnp.zeros((n, B, S), jnp.float32)
    return {
        "k": jnp.pad(fused["k"], ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0))),
        "v": jnp.pad(fused["v"], ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0))),
        "bias": jnp.pad(bias.astype(jnp.float32), ((0, 0), (0, 0), (0, pad)),
                        constant_values=PREFIX_MASK_BIAS),
    }


def fused_stack_insert_slot(table: dict, slot, req: dict) -> dict:
    """Scatter a single request's padded fused stack (n_attn, 1, Hkv, P, hd)
    into batch index ``slot`` of the engine's per-slot fused table."""
    slot = jnp.asarray(slot, jnp.int32)
    z = jnp.zeros((), jnp.int32)
    out = {}
    for name in ("k", "v"):
        out[name] = jax.lax.dynamic_update_slice(
            table[name], req[name].astype(table[name].dtype),
            (z, slot, z, z, z))
    out["bias"] = jax.lax.dynamic_update_slice(
        table["bias"], req["bias"].astype(jnp.float32), (z, slot, z))
    return out


def n_attn_layers(cfg: ModelConfig) -> int:
    return len(cfg.attention_layers)


def cache_bytes_per_token(cfg: ModelConfig, dtype_bytes: int = 2) -> int:
    """Communication load of C2C per generated/cached token (paper: 88 KB/token
    for the 4-transmitter case-study zoo). Counts k+v over all attention layers."""
    hd = cfg.resolved_head_dim
    n_attn = len(cfg.attention_layers)
    return 2 * n_attn * cfg.num_kv_heads * hd * dtype_bytes
