"""Typed decode-state pytrees: KV caches, communicated stacks, fused prefixes,
and the paged slot table.

The cache is the *medium of federation* in this paper (C2C communicates KV
caches), so its layout is a first-class design object. This module defines the
four typed pytrees the whole stack is built on (each registered with
``jax.tree_util`` so it jits/vmaps/scans like any dict, but with a closed,
documented field set):

- :class:`KVCache`   — a model's full decode state (``pos`` + per-layer
  entries). Subsumes the old free functions ``init_cache``/``attn_kv_stack``/
  ``cache_insert_slot``/``cache_evict_slot``/``init_slot_cache``.
- :class:`KVStack`   — the tensor C2C communicates: all attention-layer k/v
  collected into one (n_attn, B, Hkv, S, hd) stack. Subsumes ``concat_kv``.
- :class:`FusedPrefix` — a projected (receiver-space) stack plus its
  attention-logit bias. Subsumes ``empty_fused_stack``/``pad_fused_stack``/
  ``fused_stack_insert_slot``/``extra_kv_layers``.
- :class:`SlotTable` — a *paged* engine slot table: fixed-size KV pages in a
  shared pool plus a per-slot page map, so concurrent slot capacity is bound
  by pages actually used, not by ``slots × max_seq`` padding.
- :class:`PageAllocator` / :class:`PageLease` — the host-side authority over
  the page pool: refcounted alloc/share/release plus the copy-on-write fault
  path, so identical prefixes can resolve to the *same* physical pages
  (launch/prefix_cache.py builds the radix prefix index on top of it).

Per-layer entry layouts (unchanged from the dict era — entries stay plain
dicts because they are heterogeneous by block kind):

- ``full`` attention layers: k/v of shape (batch, kv_heads, max_seq, head_dim);
  valid entries are positions [0, pos).
- ``swa`` layers: ring buffer of length ``window`` — slot = position % window,
  plus a per-slot ``slot_pos`` array so masking survives wrap-around.
- ``rec`` layers (RG-LRU): hidden state (batch, width) + conv tail.
- ``ssd`` layers (Mamba-2): state (batch, nheads, head_dim, d_state) + conv.

Entries are stacked along a leading cycle axis to match the scan-over-layers
execution (see transformer.py).
"""
from __future__ import annotations

import dataclasses
import hashlib
import warnings
from dataclasses import dataclass
from functools import partial
from typing import Callable, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

# Additive attention-logit bias that masks an absent/inactive fused-prefix key.
# exp(PREFIX_MASK_BIAS - m) underflows to exactly 0 in fp32 softmax, so a fully
# masked prefix is *identical* to decoding with no prefix at all — the property
# that lets launch/engine.py keep one fixed-shape fused bucket per slot.
PREFIX_MASK_BIAS = -1e30


def pytree_dataclass(data_fields: Sequence[str], meta_fields: Sequence[str] = ()):
    """Register a dataclass as a jax pytree (data vs. static fields)."""
    return partial(jax.tree_util.register_dataclass,
                   data_fields=list(data_fields),
                   meta_fields=list(meta_fields))


def tree_bytes(tree) -> int:
    """Total bytes of every array leaf in ``tree`` (HBM/wire accounting)."""
    return sum(leaf.size * leaf.dtype.itemsize
               for leaf in jax.tree.leaves(tree)
               if hasattr(leaf, "dtype"))


# ------------------------------------------------------- per-layer builders


def init_attn_kv(
    cycles: int, batch: int, kv_heads: int, max_seq: int, head_dim: int, dtype
) -> dict:
    return {
        "k": jnp.zeros((cycles, batch, kv_heads, max_seq, head_dim), dtype),
        "v": jnp.zeros((cycles, batch, kv_heads, max_seq, head_dim), dtype),
    }


def init_swa_kv(
    cycles: int, batch: int, kv_heads: int, window: int, head_dim: int, dtype
) -> dict:
    return {
        "k": jnp.zeros((cycles, batch, kv_heads, window, head_dim), dtype),
        "v": jnp.zeros((cycles, batch, kv_heads, window, head_dim), dtype),
        # absolute position held by each ring slot; -1 = empty
        "slot_pos": jnp.full((cycles, batch, window), -1, jnp.int32),
    }


def init_rec_state(cycles: int, batch: int, width: int, conv_k: int, dtype) -> dict:
    return {
        "h": jnp.zeros((cycles, batch, width), jnp.float32),  # recurrence fp32
        "conv": jnp.zeros((cycles, batch, conv_k - 1, width), dtype),
    }


def init_ssd_state(
    cycles: int, batch: int, nheads: int, head_dim: int, d_state: int,
    conv_dim: int, conv_k: int, dtype
) -> dict:
    return {
        "h": jnp.zeros((cycles, batch, nheads, head_dim, d_state), jnp.float32),
        "conv": jnp.zeros((cycles, batch, conv_k - 1, conv_dim), dtype),
    }


def _grouping(cfg: ModelConfig):
    from repro.models.transformer import layer_grouping

    return layer_grouping(cfg)


# ----------------------------------------------------------------- KVStack


@pytree_dataclass(["k", "v"])
@dataclass
class KVStack:
    """The communicated KV tensor: k/v of shape (n_attn, B, Hkv, S, hd).

    This is what C2C ships over the wire: the transmitter exports it
    (:meth:`KVCache.export_stack`), a channel encodes it (core/transport.py),
    the fuser projects it, the receiver prepends it.
    """

    k: jax.Array
    v: jax.Array

    def __getitem__(self, key: str) -> jax.Array:
        warnings.warn(
            "KVStack[...] dict-style access is deprecated; use attribute "
            "access (stack.k / stack.v)", DeprecationWarning, stacklevel=2)
        return getattr(self, key)

    @property
    def seq_len(self) -> int:
        return self.k.shape[-2]

    @property
    def nbytes(self) -> int:
        return tree_bytes(self)

    def astype(self, dtype) -> "KVStack":
        return KVStack(self.k.astype(dtype), self.v.astype(dtype))

    def slice_length(self, length: int) -> "KVStack":
        return KVStack(self.k[..., :length, :], self.v[..., :length, :])

    def prepend(self, fused: "KVStack") -> "KVStack":
        """Sequence-wise concatenation ``C(F_ij, M_i) ∘ C(M_j)`` of Eq. 1/4:
        the fused (projected transmitter) stack is *prepended*, matching the
        paper's decode equation where the receiver's own running cache stays
        contiguous at the tail."""
        return KVStack(
            k=jnp.concatenate([fused.k, self.k], axis=-2),
            v=jnp.concatenate([fused.v, self.v], axis=-2),
        )

    @classmethod
    def ensure(cls, obj) -> "KVStack":
        if isinstance(obj, cls):
            return obj
        if isinstance(obj, dict):
            return cls(k=obj["k"], v=obj["v"])
        return cls(k=obj.k, v=obj.v)  # e.g. FusedPrefix (drops bias)


# -------------------------------------------------------------- FusedPrefix


@pytree_dataclass(["k", "v", "bias"])
@dataclass
class FusedPrefix:
    """A receiver-space fused prefix: k/v (n_rx, B, Hkv, Sf, hd) plus a
    per-layer, per-position attention-logit ``bias`` (n_rx, B, Sf) fp32.

    The bias carries the fuser/gating attention-mass gates AND the padding
    mask: a position with bias :data:`PREFIX_MASK_BIAS` contributes exactly
    zero attention mass, which is what keeps the engine's fixed-bucket decode
    step exact for any request mix."""

    k: jax.Array
    v: jax.Array
    bias: Optional[jax.Array] = None

    def __getitem__(self, key: str) -> jax.Array:
        warnings.warn(
            "FusedPrefix[...] dict-style access is deprecated; use attribute "
            "access (fused.k / fused.v / fused.bias)",
            DeprecationWarning, stacklevel=2)
        return getattr(self, key)

    @property
    def seq_len(self) -> int:
        return self.k.shape[-2]

    @property
    def nbytes(self) -> int:
        return tree_bytes(self)

    def with_bias(self, bias: jax.Array) -> "FusedPrefix":
        return dataclasses.replace(self, bias=bias)

    def _bias_or_zero(self) -> jax.Array:
        if self.bias is not None:
            return self.bias.astype(jnp.float32)
        n, B, _, S, _ = self.k.shape
        return jnp.zeros((n, B, S), jnp.float32)

    @classmethod
    def ensure(cls, obj) -> "FusedPrefix":
        if isinstance(obj, cls):
            return obj
        if isinstance(obj, dict):
            return cls(k=obj["k"], v=obj["v"], bias=obj.get("bias"))
        return cls(k=obj.k, v=obj.v, bias=getattr(obj, "bias", None))

    # ----------------------------------------------------------- builders
    @classmethod
    def empty(cls, cfg: ModelConfig, batch: int, max_prefix: int,
              dtype=jnp.float32) -> "FusedPrefix":
        """All-masked prefix: k/v zeros and bias PREFIX_MASK_BIAS everywhere.
        Decoding against it equals standalone decoding exactly."""
        n = len(cfg.attention_layers)
        hd = cfg.resolved_head_dim
        shape = (n, batch, cfg.num_kv_heads, max_prefix, hd)
        return cls(
            k=jnp.zeros(shape, dtype),
            v=jnp.zeros(shape, dtype),
            bias=jnp.full((n, batch, max_prefix), PREFIX_MASK_BIAS, jnp.float32),
        )

    @classmethod
    def concat(cls, prefixes: Sequence["FusedPrefix"]) -> "FusedPrefix":
        """Eq. 4's sequence-wise concatenation C(F_{j1 i}) ∘ … ∘ C(F_{js i})."""
        ps = [cls.ensure(p) for p in prefixes]
        return cls(
            k=jnp.concatenate([p.k for p in ps], axis=-2),
            v=jnp.concatenate([p.v for p in ps], axis=-2),
            bias=jnp.concatenate([p._bias_or_zero() for p in ps], axis=-1),
        )

    # --------------------------------------------------------- transforms
    def pad(self, max_prefix: int) -> "FusedPrefix":
        """Right-pad to the fixed ``max_prefix`` bucket; padded positions get
        bias PREFIX_MASK_BIAS (zero attention mass). This is what keeps the
        engine's decode step shape-stable across request mixes."""
        n, B, H, S, hd = self.k.shape
        if S > max_prefix:
            raise ValueError(
                f"fused prefix length {S} exceeds max_prefix {max_prefix}")
        pad = max_prefix - S
        return FusedPrefix(
            k=jnp.pad(self.k, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0))),
            v=jnp.pad(self.v, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0))),
            bias=jnp.pad(self._bias_or_zero(), ((0, 0), (0, 0), (0, pad)),
                         constant_values=PREFIX_MASK_BIAS),
        )

    def insert_slot(self, slot, req: "FusedPrefix") -> "FusedPrefix":
        """Scatter a single request's padded prefix (n, 1, Hkv, P, hd) into
        batch index ``slot`` of a per-slot fused table."""
        slot = jnp.asarray(slot, jnp.int32)
        z = jnp.zeros((), jnp.int32)
        req = FusedPrefix.ensure(req)
        return FusedPrefix(
            k=jax.lax.dynamic_update_slice(
                self.k, req.k.astype(self.k.dtype), (z, slot, z, z, z)),
            v=jax.lax.dynamic_update_slice(
                self.v, req.v.astype(self.v.dtype), (z, slot, z, z, z)),
            bias=jax.lax.dynamic_update_slice(
                self._bias_or_zero(), req._bias_or_zero(), (z, slot, z)),
        )

    def to_extra_kv(self, cfg: ModelConfig) -> list:
        """Slice into the per-position ``extra_kv`` list that
        transformer.forward / decode_step consume (one stacked
        :class:`FusedPrefix` entry per pattern position, then tail positions;
        non-attention positions None)."""
        cycles, pattern, tail = _grouping(cfg)
        bias = self.bias
        out: List[Optional[FusedPrefix]] = []
        off = 0

        def slice_at(o, n):
            return FusedPrefix(
                k=self.k[o: o + n], v=self.v[o: o + n],
                bias=None if bias is None else bias[o: o + n])

        for kind in pattern:
            if kind in ("attn", "swa"):
                out.append(slice_at(off, cycles))
                off += cycles
            else:
                out.append(None)
        for kind in tail:
            if kind in ("attn", "swa"):
                out.append(slice_at(off, 1))
                off += 1
            else:
                out.append(None)
        return out


def extra_kv_layers(cfg: ModelConfig, fused) -> list:
    """Back-compat shim: ``FusedPrefix.ensure(fused).to_extra_kv(cfg)``."""
    return FusedPrefix.ensure(fused).to_extra_kv(cfg)


def fused_digest(fused) -> str:
    """Content digest of a fused prefix (sha1 over shapes, dtypes and bytes).

    This is the identity under which a C2C prefix is shared: the engine keys
    its fused-row table and the radix prefix index on it, so a prefix a peer
    transmitted *once* is inserted once and every later request fusing the
    same digest reuses that row — and prompt pages are only shared between
    requests that attended the same fused prefix during prefill."""
    f = FusedPrefix.ensure(fused)
    h = hashlib.sha1()
    for leaf in (f.k, f.v, f._bias_or_zero()):
        arr = np.asarray(leaf)
        h.update(str(arr.shape).encode())
        h.update(str(arr.dtype).encode())
        h.update(arr.tobytes())
    return h.hexdigest()


# ------------------------------------------------------------------ KVCache


def _insert_slot_leaf(table_leaf: jax.Array, req_leaf: jax.Array,
                      slot: jax.Array, batch_index: jax.Array) -> jax.Array:
    # every cache leaf is (cycles, batch, ...): scatter the request's block at
    # batch index ``batch_index`` of ``req_leaf`` into row ``slot``
    blk = jax.lax.dynamic_slice_in_dim(req_leaf, batch_index, 1, axis=1)
    start = (jnp.zeros((), jnp.int32), slot) + tuple(
        jnp.zeros((), jnp.int32) for _ in range(table_leaf.ndim - 2))
    return jax.lax.dynamic_update_slice(
        table_leaf, blk.astype(table_leaf.dtype), start)


@pytree_dataclass(["pos", "layers"])
@dataclass
class KVCache:
    """A model's decode state: ``pos`` (scalar, or per-slot (B,) vector for
    continuous batching) + per-pattern-position stacked layer entries."""

    pos: jax.Array
    layers: Tuple

    def __getitem__(self, key: str):
        warnings.warn(
            "KVCache[...] dict-style access is deprecated; use attribute "
            "access (cache.pos / cache.layers)",
            DeprecationWarning, stacklevel=2)
        return getattr(self, key)

    @property
    def nbytes(self) -> int:
        return tree_bytes(self)

    def with_pos(self, pos) -> "KVCache":
        return KVCache(pos=jnp.asarray(pos, jnp.int32), layers=self.layers)

    @classmethod
    def ensure(cls, obj) -> "KVCache":
        if isinstance(obj, cls):
            return obj
        if isinstance(obj, dict):
            return cls(pos=obj["pos"], layers=tuple(obj["layers"]))
        return cls(pos=obj.pos, layers=tuple(obj.layers))

    # ----------------------------------------------------------- builders
    @classmethod
    def init(
        cls,
        cfg: ModelConfig,
        batch: int,
        max_seq: int,
        dtype=jnp.bfloat16,
        *,
        window_override: Optional[int] = None,
    ) -> "KVCache":
        """Build the full decode cache for ``cfg`` (transformer.py grouping)."""
        cycles, pattern, tail = _grouping(cfg)
        hd = cfg.resolved_head_dim
        layers = []
        for pos, kind in enumerate(pattern + tail):
            n = cycles if pos < len(pattern) else 1
            if kind == "attn":
                layers.append(
                    init_attn_kv(n, batch, cfg.num_kv_heads, max_seq, hd, dtype))
            elif kind == "swa":
                w = min(window_override or cfg.sliding_window
                        or cfg.long_context_window, max_seq)
                layers.append(
                    init_swa_kv(n, batch, cfg.num_kv_heads, w, hd, dtype))
            elif kind == "rec":
                width = cfg.rglru_width or cfg.d_model
                layers.append(
                    init_rec_state(n, batch, width, cfg.conv_kernel, dtype))
            elif kind == "ssd":
                conv_dim = cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state
                layers.append(
                    init_ssd_state(n, batch, cfg.ssm_nheads, cfg.ssm_head_dim,
                                   cfg.ssm_state, conv_dim, cfg.conv_kernel,
                                   dtype))
            else:
                raise ValueError(f"unknown layer kind {kind!r}")
        return cls(pos=jnp.zeros((), jnp.int32), layers=tuple(layers))

    @classmethod
    def init_slots(
        cls,
        cfg: ModelConfig,
        slots: int,
        max_seq: int,
        dtype=jnp.bfloat16,
        *,
        window_override: Optional[int] = None,
    ) -> "KVCache":
        """A decode cache whose batch axis is a *dense slot table*: ``pos`` is
        per-slot (slots,) int32 so every slot decodes at its own position
        (continuous batching — launch/engine.py; the paged alternative is
        :class:`SlotTable`). Consumed by transformer.decode_step's
        vector-``pos`` path."""
        c = cls.init(cfg, slots, max_seq, dtype, window_override=window_override)
        return c.with_pos(jnp.zeros((slots,), jnp.int32))

    # ------------------------------------------------------------- export
    def export_stack(self, cfg: ModelConfig,
                     length: Optional[int] = None) -> KVStack:
        """Collect all attention-layer k/v into one (n_attn, B, Hkv, S, hd)
        stack — the tensor C2C communicates. Pattern positions + tail are
        concatenated in layer order along the leading axis."""
        cycles, pattern, tail = _grouping(cfg)
        ks, vs = [], []
        for i, kind in enumerate(pattern + tail):
            if kind in ("attn", "swa"):
                e = self.layers[i]
                ks.append(e["k"])
                vs.append(e["v"])
        stack = KVStack(k=jnp.concatenate(ks, axis=0),
                        v=jnp.concatenate(vs, axis=0))
        if length is not None:
            stack = stack.slice_length(length)
        return stack

    # ------------------------------------------------- dense slot lifecycle
    def insert_slot(self, slot, req: "KVCache", length, lease=None, *,
                    batch_index=0) -> "KVCache":
        """Insert one request of a (possibly batched) prefill cache into slot
        ``slot`` and set that slot's position to ``length``.

        ``lease`` is accepted (and ignored) so engine call sites are
        polymorphic over paged vs dense: :meth:`SlotTable.insert_slot` takes an
        allocator-issued :class:`PageLease` in the same positional slot.

        Stale K/V beyond ``length`` (from a previous occupant) never need
        zeroing: the per-slot position mask hides them, and decode overwrites
        each index before it first becomes visible."""
        del lease  # dense slots own a full row; nothing to map
        slot = jnp.asarray(slot, jnp.int32)
        bi = jnp.asarray(batch_index, jnp.int32)
        req = KVCache.ensure(req)
        layers = tuple(
            jax.tree.map(lambda t, r: _insert_slot_leaf(t, r, slot, bi), tl, rl)
            for tl, rl in zip(self.layers, req.layers)
        )
        pos = self.pos.at[slot].set(jnp.asarray(length, jnp.int32))
        return KVCache(pos=pos, layers=layers)

    def evict_slot(self, slot) -> "KVCache":
        """Free a slot immediately: reset its position (stale K/V stay but are
        masked — see insert_slot)."""
        return self.with_pos(
            self.pos.at[jnp.asarray(slot, jnp.int32)].set(0))


# ---------------------------------------------------------------- SlotTable


@pytree_dataclass(["pos", "page_map", "layers"], ["page_size"])
@dataclass
class SlotTable:
    """Paged engine slot table: block/paged KV layout.

    Instead of a dense (slots, max_seq) row per slot, attention K/V live in a
    shared *page pool* of fixed-size pages — per layer entry,
    k/v: (n, num_pages, Hkv, page_size, hd) — and each slot owns an ordered
    ``page_map`` row (slots, pages_per_slot) of physical page ids. A slot's
    HBM cost is the pages it actually needs (ceil(tokens/page_size)), so at a
    fixed pool budget the table sustains far more concurrent slots than the
    dense layout whenever requests are shorter than ``max_seq``.

    ``INVALID_PAGE`` (== num_pages, an out-of-bounds id) marks unallocated
    map entries: scatters through it are dropped and gathers clamp to an
    arbitrary page whose content is hidden by the per-slot position mask —
    exactly the mask that already hides a dense slot's stale K/V, so paged
    decode is *byte-identical* to dense decode (engine_bench verifies).

    Page allocation/free is host-side policy owned by :class:`PageAllocator`
    (refcounts, sharing, CoW); this class only does the device-side
    scatter/gather, including the CoW fault's :meth:`copy_page` and the
    prefix-cache :meth:`prefix_extra_kv`/:meth:`insert_suffix` pair.
    """

    pos: jax.Array  # (slots,) int32
    page_map: jax.Array  # (slots, pages_per_slot) int32 physical page ids
    layers: Tuple  # per position: {"k","v"} pools (n, num_pages, Hkv, pg, hd)
    page_size: int

    @property
    def num_slots(self) -> int:
        return self.pos.shape[0]

    @property
    def pages_per_slot(self) -> int:
        return self.page_map.shape[1]

    @property
    def num_pages(self) -> int:
        return self.layers[0]["k"].shape[1]

    @property
    def view_seq(self) -> int:
        """Per-slot logical sequence length of the gathered dense view."""
        return self.pages_per_slot * self.page_size

    @property
    def invalid_page(self) -> int:
        return self.num_pages

    @property
    def nbytes(self) -> int:
        return tree_bytes(self)

    def with_pos(self, pos) -> "SlotTable":
        return dataclasses.replace(self, pos=jnp.asarray(pos, jnp.int32))

    @classmethod
    def init(
        cls,
        cfg: ModelConfig,
        slots: int,
        max_seq: int,
        dtype=jnp.bfloat16,
        *,
        page_size: int = 16,
        num_pages: Optional[int] = None,
    ) -> "SlotTable":
        """Pool-backed slot table. Requires a pure full-attention model (ring
        buffers and recurrent state have O(1)-per-slot cost and no paging
        upside; they keep the dense layout)."""
        if any(k != "attn" for k in cfg.block_pattern):
            raise ValueError(
                f"paged SlotTable requires a pure full-attention pattern; "
                f"{cfg.name} has {cfg.block_pattern}")
        if max_seq % page_size:
            raise ValueError(f"max_seq={max_seq} not divisible by "
                             f"page_size={page_size}")
        pages_per_slot = max_seq // page_size
        num_pages = num_pages if num_pages is not None else slots * pages_per_slot
        cycles, pattern, tail = _grouping(cfg)
        hd = cfg.resolved_head_dim
        layers = []
        for pos, _ in enumerate(pattern + tail):
            n = cycles if pos < len(pattern) else 1
            shape = (n, num_pages, cfg.num_kv_heads, page_size, hd)
            layers.append({"k": jnp.zeros(shape, dtype),
                           "v": jnp.zeros(shape, dtype)})
        return cls(
            pos=jnp.zeros((slots,), jnp.int32),
            page_map=jnp.full((slots, pages_per_slot), num_pages, jnp.int32),
            layers=tuple(layers),
            page_size=page_size,
        )

    # ----------------------------------------------------- paged attention
    @staticmethod
    def write_token(pool: jax.Array, tok: jax.Array, page_map: jax.Array,
                    pos: jax.Array, page_size: int) -> jax.Array:
        """Scatter one new K (or V) token per slot straight into its physical
        page — the in-place write the paged decode path uses instead of
        writing into a gathered view and committing back.

        ``pool`` (num_pages, Hkv, page_size, hd); ``tok`` (slots, Hkv, hd);
        ``pos`` (slots,) absolute write position. Slots whose covering page
        map entry is INVALID_PAGE (inactive/evicted) are dropped by the
        scatter, so they can never corrupt pages reassigned to others."""
        pps = page_map.shape[1]
        page_idx = jnp.clip(pos // page_size, 0, pps - 1)
        phys = jnp.take_along_axis(page_map, page_idx[:, None], axis=1)[:, 0]
        off = pos % page_size
        return pool.at[phys, :, off].set(tok.astype(pool.dtype), mode="drop")

    @staticmethod
    def attend(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
               page_map: jax.Array, lengths: jax.Array):
        """GQA flash-decode over the page pool *in place* — the hot-loop path
        that replaces ``dense_view()`` gathering (kernels/paged_attention.py
        walks the page map with scalar prefetch and skips INVALID pages).

        q (slots, H, hd); pools (num_pages, Hkv, page_size, hd); lengths
        (slots,) live tokens. Returns (out (slots, H, hd), m, l) online
        softmax stats so callers can LSE-merge a fused C2C prefix segment."""
        from repro.kernels import ops

        return ops.paged_decode_attention(q, k_pool, v_pool, page_map, lengths)

    # ------------------------------------------------------------- views
    def dense_view(self) -> KVCache:
        """Gather each slot's pages into a contiguous per-slot cache
        (n, slots, Hkv, view_seq, hd) — the layout transformer.decode_step's
        dense path consumes. Unallocated pages clamp to an arbitrary pool
        page; the per-slot position mask hides their content (exact-zero
        attention mass), so the view decodes byte-identically to a dense
        table. The decode hot loop now attends in place (:meth:`attend`);
        this gather survives for export, debugging and parity checks."""
        pm = jnp.minimum(self.page_map, self.num_pages - 1)  # clamp sentinel
        slots, pps = pm.shape

        def gather(pool):
            n, _, H, pg, hd = pool.shape
            v = pool[:, pm]  # (n, slots, pps, Hkv, pg, hd)
            v = v.transpose(0, 1, 3, 2, 4, 5)
            return v.reshape(n, slots, H, pps * pg, hd)

        layers = tuple({"k": gather(e["k"]), "v": gather(e["v"])}
                       for e in self.layers)
        return KVCache(pos=self.pos, layers=layers)

    # --------------------------------------------------------- lifecycle
    def insert_slot(self, slot, req: KVCache, length, lease,
                    *, batch_index=0) -> "SlotTable":
        """Insert one request of a prefill cache (row layout, seq length ==
        ``view_seq``) into slot ``slot``: scatter its pages into the pool at
        the leased page ids and point the slot's page map at them.

        ``lease`` is an allocator-issued :class:`PageLease` — or, for jitted
        call sites, its pre-built page row ((pages_per_slot,) int32,
        INVALID_PAGE-padded beyond the allocated count). Same positional slot
        as :meth:`KVCache.insert_slot`'s ignored ``lease``."""
        slot = jnp.asarray(slot, jnp.int32)
        bi = jnp.asarray(batch_index, jnp.int32)
        if isinstance(lease, PageLease):
            lease = lease.page_row(self.pages_per_slot, self.invalid_page)
        page_ids = jnp.asarray(lease, jnp.int32)
        req = KVCache.ensure(req)
        pps, pg = self.pages_per_slot, self.page_size

        def scatter(pool, row):
            # row: (n, B, Hkv, view_seq, hd) -> request bi's pages
            n, _, H, S, hd = row.shape
            blk = jax.lax.dynamic_slice_in_dim(row, bi, 1, axis=1)[:, 0]
            pages = blk.reshape(n, H, pps, pg, hd).transpose(0, 2, 1, 3, 4)
            # scatter (n, pps, Hkv, pg, hd) at pool axis 1; INVALID ids drop
            return pool.at[:, page_ids].set(pages.astype(pool.dtype),
                                            mode="drop")

        layers = tuple(
            {"k": scatter(e["k"], r["k"]), "v": scatter(e["v"], r["v"])}
            for e, r in zip(self.layers, req.layers)
        )
        return SlotTable(
            pos=self.pos.at[slot].set(jnp.asarray(length, jnp.int32)),
            page_map=self.page_map.at[slot].set(page_ids),
            layers=layers,
            page_size=self.page_size,
        )

    def insert_suffix(self, slot, req: KVCache, phys, off, lease_row,
                      length) -> "SlotTable":
        """Insert a *suffix* prefill: the prompt's first ``P`` tokens were
        served from shared pages (radix prefix-cache hit), so ``req`` holds
        K/V only for positions [P, S) in rows [0, S-P). Scatter token ``i``
        to pool page ``phys[i]`` at in-page offset ``off[i]`` (INVALID ids
        drop — padded rows), adopt the slot's full page row (shared prefix
        pages + freshly written suffix pages) and set its position to
        ``length`` (= S). CoW happened before this call: any shared page the
        suffix writes into was already copied (:meth:`copy_page`), so
        ``phys`` only ever targets pages this slot owns."""
        slot = jnp.asarray(slot, jnp.int32)
        phys = jnp.asarray(phys, jnp.int32)
        off = jnp.asarray(off, jnp.int32)
        if isinstance(lease_row, PageLease):
            lease_row = lease_row.page_row(self.pages_per_slot,
                                           self.invalid_page)
        lease_row = jnp.asarray(lease_row, jnp.int32)
        req = KVCache.ensure(req)

        def scatter(pool, row):
            # row (n, 1, Hkv, Ssuf, hd) -> per-token (Ssuf, n, Hkv, hd), the
            # shape advanced indexing wants for pool.at[:, phys, :, off]
            tok = row[:, 0].transpose(2, 0, 1, 3)
            return pool.at[:, phys, :, off].set(tok.astype(pool.dtype),
                                                mode="drop")

        layers = tuple(
            {"k": scatter(e["k"], r["k"]), "v": scatter(e["v"], r["v"])}
            for e, r in zip(self.layers, req.layers)
        )
        return SlotTable(
            pos=self.pos.at[slot].set(jnp.asarray(length, jnp.int32)),
            page_map=self.page_map.at[slot].set(lease_row),
            layers=layers,
            page_size=self.page_size,
        )

    def adopt_slot(self, slot, page_row, length) -> "SlotTable":
        """Activate a slot whose pages were already filled out-of-band.

        Chunked prefill (transformer.prefill_chunk) scatters K/V through
        per-token phys/off while the slot's page-map row stays INVALID — so
        decode's :meth:`write_token` cannot touch the in-flight pages and the
        slot is invisible to the batch. On the prompt's final chunk the engine
        adopts the lease's row and sets the live length; the next decode step
        sees a fully prefilled slot. No pool data moves."""
        slot = jnp.asarray(slot, jnp.int32)
        if isinstance(page_row, PageLease):
            page_row = page_row.page_row(self.pages_per_slot,
                                         self.invalid_page)
        return dataclasses.replace(
            self,
            pos=self.pos.at[slot].set(jnp.asarray(length, jnp.int32)),
            page_map=self.page_map.at[slot].set(
                jnp.asarray(page_row, jnp.int32)),
        )

    def copy_page(self, src, dst) -> "SlotTable":
        """Copy one physical page's K/V (every layer entry) ``src`` → ``dst``:
        the device half of the allocator's copy-on-write fault. The host side
        (:meth:`PageAllocator.cow`) re-points the faulting slot's lease at
        ``dst`` so the write that triggered the fault lands in the copy."""
        src = jnp.asarray(src, jnp.int32)
        dst = jnp.asarray(dst, jnp.int32)

        def cp(pool):
            page = jax.lax.dynamic_slice_in_dim(pool, src, 1, axis=1)
            return jax.lax.dynamic_update_slice_in_dim(pool, page, dst, axis=1)

        layers = tuple({"k": cp(e["k"]), "v": cp(e["v"])}
                       for e in self.layers)
        return dataclasses.replace(self, layers=layers)

    def prefix_extra_kv(self, page_ids, length) -> list:
        """Gather already-cached prefix pages into the per-position
        ``extra_kv`` list transformer.prefill consumes, so a radix-hit
        admission prefills only the suffix while attending the cached prefix.

        ``page_ids`` ((n_prefix_pages,) int32, INVALID-padded — fixed length
        keeps one trace) select pool pages; positions ≥ ``length`` (a traced
        scalar: the matched-prefix token count) get bias PREFIX_MASK_BIAS, so
        padding and the stale tail of a partially-matched page contribute
        exactly zero attention mass."""
        page_ids = jnp.asarray(page_ids, jnp.int32)
        pm = jnp.minimum(page_ids, self.num_pages - 1)  # clamp sentinel
        npp = page_ids.shape[0]
        pg = self.page_size
        mask = jnp.where(jnp.arange(npp * pg)[None, None, :]
                         < jnp.asarray(length, jnp.int32),
                         0.0, PREFIX_MASK_BIAS).astype(jnp.float32)

        def gather(pool):
            n, _, H, _, hd = pool.shape
            v = pool[:, pm]  # (n, npp, Hkv, pg, hd)
            v = v.transpose(0, 2, 1, 3, 4).reshape(n, H, npp * pg, hd)
            return v[:, None]  # (n, 1, Hkv, npp*pg, hd)

        out = []
        for e in self.layers:
            k = gather(e["k"])
            out.append(FusedPrefix(
                k=k, v=gather(e["v"]),
                bias=jnp.broadcast_to(mask, (k.shape[0], 1, npp * pg))))
        return out

    def evict_slot(self, slot) -> "SlotTable":
        """Free a slot: reset its position and unmap its pages. (Returning the
        physical pages to the free pool is the host-side allocator's job.)"""
        slot = jnp.asarray(slot, jnp.int32)
        return SlotTable(
            pos=self.pos.at[slot].set(0),
            page_map=self.page_map.at[slot].set(self.invalid_page),
            layers=self.layers,
            page_size=self.page_size,
        )

    def commit(self, new_view: KVCache, pos_out: jax.Array) -> "SlotTable":
        """Fold one decode step back into the pool: decode_step wrote exactly
        one token per slot (at the slot's pre-step position) into the gathered
        dense view; scatter those tokens to their physical pages and adopt
        ``pos_out`` (the engine's activity-masked position vector). Slots
        whose page map entry is INVALID_PAGE (inactive/evicted) are dropped by
        the scatter, so they can never corrupt pages reassigned to others."""
        old_pos = self.pos  # position each slot's new token was written at
        slots = self.num_slots
        page_idx = jnp.clip(old_pos // self.page_size, 0,
                            self.pages_per_slot - 1)
        phys = jnp.take_along_axis(self.page_map, page_idx[:, None],
                                   axis=1)[:, 0]  # (slots,)
        off = old_pos % self.page_size
        rows = jnp.arange(slots)

        def scatter(pool, view):
            # token written this step: view[(n, slots, Hkv, view_seq, hd)] at
            # [:, s, :, old_pos[s], :] -> (slots, n, Hkv, hd) (adv-idx moves
            # the indexed axes to the front)
            tok = view[:, rows, :, old_pos, :]
            return pool.at[:, phys, :, off].set(tok.astype(pool.dtype),
                                                mode="drop")

        layers = tuple(
            {"k": scatter(e["k"], ve["k"]), "v": scatter(e["v"], ve["v"])}
            for e, ve in zip(self.layers, new_view.layers)
        )
        return SlotTable(pos=pos_out, page_map=self.page_map, layers=layers,
                         page_size=self.page_size)


# ------------------------------------------------------------ PageAllocator


@dataclass
class PageLease:
    """An allocator-issued grant of physical pages to one slot, in slot order.

    ``owned[i]`` marks exclusivity: the slot may write into page
    ``page_ids[i]`` only when True. Shared (``owned`` False) pages are
    read-only for this slot — a write there must go through the allocator's
    CoW fault (:meth:`PageAllocator.cow`) first, which re-points the lease at
    a private copy. Leases are host-side handles (numpy), never traced;
    :meth:`page_row` builds the INVALID-padded device row jitted call sites
    take."""

    page_ids: np.ndarray  # (n,) int32 physical page ids, slot order
    owned: np.ndarray     # (n,) bool, True = exclusive/writable

    @property
    def num_pages(self) -> int:
        return int(self.page_ids.size)

    def ids(self) -> List[int]:
        return [int(p) for p in self.page_ids]

    def shared_ids(self) -> List[int]:
        return [int(p) for p, o in zip(self.page_ids, self.owned) if not o]

    def page_row(self, pages_per_slot: int, invalid: int) -> np.ndarray:
        """The slot's (pages_per_slot,) page-map row, INVALID-padded."""
        if self.num_pages > pages_per_slot:
            raise ValueError(f"lease of {self.num_pages} pages exceeds "
                             f"pages_per_slot={pages_per_slot}")
        row = np.full(pages_per_slot, invalid, np.int32)
        row[: self.num_pages] = self.page_ids
        return row


class PageAllocator:
    """Host-side refcounted authority over a :class:`SlotTable`'s page pool —
    the *only* way pages are granted, shared or returned (the engine holds
    :class:`PageLease` handles, never raw page-id lists).

    A page's refcount counts every holder: each slot lease mapping it plus
    each prefix-index pin (:meth:`retain`). ``alloc`` grants exclusive pages
    at refcount 1; ``share`` increfs pages another holder already owns;
    ``release`` decrefs and returns a page to the free list exactly when its
    count reaches zero — so evicting one sharer can never free pages another
    slot still maps. ``cow`` is the copy-on-write fault path: the faulting
    lease swaps its share of a page for a fresh exclusive one (the caller
    performs the device copy via :meth:`SlotTable.copy_page`).

    Double-free and free-page sharing raise instead of corrupting state;
    :meth:`assert_consistent` is the property-test hook."""

    def __init__(self, num_pages: int):
        if num_pages < 0:
            raise ValueError("num_pages must be >= 0")
        self.num_pages = num_pages
        self._refcounts = np.zeros(num_pages, np.int64)
        self._free: List[int] = list(range(num_pages))
        # optional () -> str callback naming the current holders (per-slot
        # page counts, pinned digests, sanitizer provenance); its output is
        # appended to pool-exhaustion errors so they are actionable
        self.holders_hook: Optional[Callable[[], str]] = None

    def _exhausted(self, requested: int, what: str) -> RuntimeError:
        msg = (f"page pool exhausted: requested {requested} {what}, "
               f"free {len(self._free)} of {self.num_pages} "
               f"({self.pages_in_use} in use)")
        if self.holders_hook is not None:
            detail = self.holders_hook()
            if detail:
                msg += "\ncurrent holders:\n" + detail
        return RuntimeError(msg)

    # ------------------------------------------------------------ queries
    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return self.num_pages - len(self._free)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def refcount(self, page_id: int) -> int:
        return int(self._refcounts[page_id])

    # ---------------------------------------------------------- lifecycle
    def alloc(self, n: int) -> List[int]:
        """Grant ``n`` exclusive pages (refcount 1 each)."""
        if n > len(self._free):
            raise self._exhausted(n, "pages")
        ids = [self._free.pop() for _ in range(n)]
        self._refcounts[ids] += 1
        return ids

    def share(self, page_ids: Sequence[int]) -> List[int]:
        """Add a reference to pages some other holder already owns."""
        ids = [int(p) for p in page_ids]
        for p in ids:
            if self._refcounts[p] <= 0:
                raise ValueError(f"cannot share free page {p}")
        self._refcounts[ids] += 1
        return ids

    def retain(self, page_id: int) -> None:
        """Pin a single live page (prefix-index references use this)."""
        self.share([page_id])

    def release(self, pages: Union["PageLease", Sequence[int]]) -> None:
        """Drop one reference per page; free pages whose count hits zero."""
        ids = pages.ids() if isinstance(pages, PageLease) else \
            [int(p) for p in pages]
        for p in ids:
            if self._refcounts[p] <= 0:
                raise ValueError(f"refcount underflow: page {p} already free")
            self._refcounts[p] -= 1
            if self._refcounts[p] == 0:
                self._free.append(p)

    def lease(self, *, shared: Sequence[int] = (), fresh: int = 0) -> PageLease:
        """Issue a slot's lease: incref ``shared`` prefix pages (in order)
        followed by ``fresh`` newly-allocated exclusive pages."""
        if fresh > len(self._free):
            raise self._exhausted(fresh, "fresh pages")
        s = self.share(shared)
        f = self.alloc(fresh)
        return PageLease(
            page_ids=np.asarray(s + f, np.int32),
            owned=np.asarray([False] * len(s) + [True] * fresh, bool),
        )

    def cow(self, lease: PageLease, index: int) -> Tuple[int, int]:
        """Copy-on-write fault: the slot is about to write into shared page
        ``lease.page_ids[index]``. Allocate a private copy target, swap it
        into the lease (now owned) and drop the share of the source. Returns
        ``(src, dst)`` — the caller must copy the page's bytes on device
        (:meth:`SlotTable.copy_page`) before writing."""
        if lease.owned[index]:
            raise ValueError(f"page at lease index {index} is already owned; "
                             f"CoW fault is only valid on shared pages")
        src = int(lease.page_ids[index])
        dst = self.alloc(1)[0]
        self.release([src])
        lease.page_ids[index] = dst
        lease.owned[index] = True
        return src, dst

    # ------------------------------------------------------------- checks
    def assert_consistent(self) -> None:
        """Invariants the property tests lean on: counts never negative, the
        free list is exactly the zero-refcount pages, no duplicates."""
        assert (self._refcounts >= 0).all(), "negative refcount"
        free = set(self._free)
        assert len(free) == len(self._free), "duplicate page in free list"
        zero = {i for i in range(self.num_pages) if self._refcounts[i] == 0}
        assert free == zero, f"free list {free} != zero-refcount pages {zero}"


# ----------------------------------------------------------------- helpers


def n_attn_layers(cfg: ModelConfig) -> int:
    return len(cfg.attention_layers)


def cache_bytes_per_token(cfg: ModelConfig, dtype_bytes: int = 2) -> int:
    """Communication load of C2C per generated/cached token (paper: 88 KB/token
    for the 4-transmitter case-study zoo). Counts k+v over all attention layers."""
    hd = cfg.resolved_head_dim
    n_attn = len(cfg.attention_layers)
    return 2 * n_attn * cfg.num_kv_heads * hd * dtype_bytes
