"""Typed decode-state pytrees: KV caches, communicated stacks, fused prefixes,
and the paged slot table.

The cache is the *medium of federation* in this paper (C2C communicates KV
caches), so its layout is a first-class design object. This module defines the
four typed pytrees the whole stack is built on (each registered with
``jax.tree_util`` so it jits/vmaps/scans like any dict, but with a closed,
documented field set):

- :class:`KVCache`   — a model's full decode state (``pos`` + per-layer
  entries). Subsumes the old free functions ``init_cache``/``attn_kv_stack``/
  ``cache_insert_slot``/``cache_evict_slot``/``init_slot_cache``.
- :class:`KVStack`   — the tensor C2C communicates: all attention-layer k/v
  collected into one (n_attn, B, Hkv, S, hd) stack. Subsumes ``concat_kv``.
- :class:`FusedPrefix` — a projected (receiver-space) stack plus its
  attention-logit bias. Subsumes ``empty_fused_stack``/``pad_fused_stack``/
  ``fused_stack_insert_slot``/``extra_kv_layers``.
- :class:`SlotTable` — a *paged* engine slot table: fixed-size KV pages in a
  shared pool plus a per-slot page map, so concurrent slot capacity is bound
  by pages actually used, not by ``slots × max_seq`` padding.

Per-layer entry layouts (unchanged from the dict era — entries stay plain
dicts because they are heterogeneous by block kind):

- ``full`` attention layers: k/v of shape (batch, kv_heads, max_seq, head_dim);
  valid entries are positions [0, pos).
- ``swa`` layers: ring buffer of length ``window`` — slot = position % window,
  plus a per-slot ``slot_pos`` array so masking survives wrap-around.
- ``rec`` layers (RG-LRU): hidden state (batch, width) + conv tail.
- ``ssd`` layers (Mamba-2): state (batch, nheads, head_dim, d_state) + conv.

Entries are stacked along a leading cycle axis to match the scan-over-layers
execution (see transformer.py).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

# Additive attention-logit bias that masks an absent/inactive fused-prefix key.
# exp(PREFIX_MASK_BIAS - m) underflows to exactly 0 in fp32 softmax, so a fully
# masked prefix is *identical* to decoding with no prefix at all — the property
# that lets launch/engine.py keep one fixed-shape fused bucket per slot.
PREFIX_MASK_BIAS = -1e30


def pytree_dataclass(data_fields: Sequence[str], meta_fields: Sequence[str] = ()):
    """Register a dataclass as a jax pytree (data vs. static fields)."""
    return partial(jax.tree_util.register_dataclass,
                   data_fields=list(data_fields),
                   meta_fields=list(meta_fields))


def tree_bytes(tree) -> int:
    """Total bytes of every array leaf in ``tree`` (HBM/wire accounting)."""
    return sum(leaf.size * leaf.dtype.itemsize
               for leaf in jax.tree.leaves(tree)
               if hasattr(leaf, "dtype"))


# ------------------------------------------------------- per-layer builders


def init_attn_kv(
    cycles: int, batch: int, kv_heads: int, max_seq: int, head_dim: int, dtype
) -> dict:
    return {
        "k": jnp.zeros((cycles, batch, kv_heads, max_seq, head_dim), dtype),
        "v": jnp.zeros((cycles, batch, kv_heads, max_seq, head_dim), dtype),
    }


def init_swa_kv(
    cycles: int, batch: int, kv_heads: int, window: int, head_dim: int, dtype
) -> dict:
    return {
        "k": jnp.zeros((cycles, batch, kv_heads, window, head_dim), dtype),
        "v": jnp.zeros((cycles, batch, kv_heads, window, head_dim), dtype),
        # absolute position held by each ring slot; -1 = empty
        "slot_pos": jnp.full((cycles, batch, window), -1, jnp.int32),
    }


def init_rec_state(cycles: int, batch: int, width: int, conv_k: int, dtype) -> dict:
    return {
        "h": jnp.zeros((cycles, batch, width), jnp.float32),  # recurrence fp32
        "conv": jnp.zeros((cycles, batch, conv_k - 1, width), dtype),
    }


def init_ssd_state(
    cycles: int, batch: int, nheads: int, head_dim: int, d_state: int,
    conv_dim: int, conv_k: int, dtype
) -> dict:
    return {
        "h": jnp.zeros((cycles, batch, nheads, head_dim, d_state), jnp.float32),
        "conv": jnp.zeros((cycles, batch, conv_k - 1, conv_dim), dtype),
    }


def _grouping(cfg: ModelConfig):
    from repro.models.transformer import layer_grouping

    return layer_grouping(cfg)


# ----------------------------------------------------------------- KVStack


@pytree_dataclass(["k", "v"])
@dataclass
class KVStack:
    """The communicated KV tensor: k/v of shape (n_attn, B, Hkv, S, hd).

    This is what C2C ships over the wire: the transmitter exports it
    (:meth:`KVCache.export_stack`), a channel encodes it (core/transport.py),
    the fuser projects it, the receiver prepends it.
    """

    k: jax.Array
    v: jax.Array

    def __getitem__(self, key: str) -> jax.Array:  # legacy dict interop
        return getattr(self, key)

    @property
    def seq_len(self) -> int:
        return self.k.shape[-2]

    @property
    def nbytes(self) -> int:
        return tree_bytes(self)

    def astype(self, dtype) -> "KVStack":
        return KVStack(self.k.astype(dtype), self.v.astype(dtype))

    def slice_length(self, length: int) -> "KVStack":
        return KVStack(self.k[..., :length, :], self.v[..., :length, :])

    def prepend(self, fused: "KVStack") -> "KVStack":
        """Sequence-wise concatenation ``C(F_ij, M_i) ∘ C(M_j)`` of Eq. 1/4:
        the fused (projected transmitter) stack is *prepended*, matching the
        paper's decode equation where the receiver's own running cache stays
        contiguous at the tail."""
        return KVStack(
            k=jnp.concatenate([fused.k, self.k], axis=-2),
            v=jnp.concatenate([fused.v, self.v], axis=-2),
        )

    @classmethod
    def ensure(cls, obj) -> "KVStack":
        if isinstance(obj, cls):
            return obj
        return cls(k=obj["k"], v=obj["v"])


# -------------------------------------------------------------- FusedPrefix


@pytree_dataclass(["k", "v", "bias"])
@dataclass
class FusedPrefix:
    """A receiver-space fused prefix: k/v (n_rx, B, Hkv, Sf, hd) plus a
    per-layer, per-position attention-logit ``bias`` (n_rx, B, Sf) fp32.

    The bias carries the fuser/gating attention-mass gates AND the padding
    mask: a position with bias :data:`PREFIX_MASK_BIAS` contributes exactly
    zero attention mass, which is what keeps the engine's fixed-bucket decode
    step exact for any request mix."""

    k: jax.Array
    v: jax.Array
    bias: Optional[jax.Array] = None

    def __getitem__(self, key: str) -> jax.Array:  # legacy dict interop
        return getattr(self, key)

    @property
    def seq_len(self) -> int:
        return self.k.shape[-2]

    @property
    def nbytes(self) -> int:
        return tree_bytes(self)

    def with_bias(self, bias: jax.Array) -> "FusedPrefix":
        return dataclasses.replace(self, bias=bias)

    def _bias_or_zero(self) -> jax.Array:
        if self.bias is not None:
            return self.bias.astype(jnp.float32)
        n, B, _, S, _ = self.k.shape
        return jnp.zeros((n, B, S), jnp.float32)

    @classmethod
    def ensure(cls, obj) -> "FusedPrefix":
        if isinstance(obj, cls):
            return obj
        if isinstance(obj, KVStack):
            return cls(k=obj.k, v=obj.v)
        return cls(k=obj["k"], v=obj["v"], bias=obj.get("bias"))

    # ----------------------------------------------------------- builders
    @classmethod
    def empty(cls, cfg: ModelConfig, batch: int, max_prefix: int,
              dtype=jnp.float32) -> "FusedPrefix":
        """All-masked prefix: k/v zeros and bias PREFIX_MASK_BIAS everywhere.
        Decoding against it equals standalone decoding exactly."""
        n = len(cfg.attention_layers)
        hd = cfg.resolved_head_dim
        shape = (n, batch, cfg.num_kv_heads, max_prefix, hd)
        return cls(
            k=jnp.zeros(shape, dtype),
            v=jnp.zeros(shape, dtype),
            bias=jnp.full((n, batch, max_prefix), PREFIX_MASK_BIAS, jnp.float32),
        )

    @classmethod
    def concat(cls, prefixes: Sequence["FusedPrefix"]) -> "FusedPrefix":
        """Eq. 4's sequence-wise concatenation C(F_{j1 i}) ∘ … ∘ C(F_{js i})."""
        ps = [cls.ensure(p) for p in prefixes]
        return cls(
            k=jnp.concatenate([p.k for p in ps], axis=-2),
            v=jnp.concatenate([p.v for p in ps], axis=-2),
            bias=jnp.concatenate([p._bias_or_zero() for p in ps], axis=-1),
        )

    # --------------------------------------------------------- transforms
    def pad(self, max_prefix: int) -> "FusedPrefix":
        """Right-pad to the fixed ``max_prefix`` bucket; padded positions get
        bias PREFIX_MASK_BIAS (zero attention mass). This is what keeps the
        engine's decode step shape-stable across request mixes."""
        n, B, H, S, hd = self.k.shape
        if S > max_prefix:
            raise ValueError(
                f"fused prefix length {S} exceeds max_prefix {max_prefix}")
        pad = max_prefix - S
        return FusedPrefix(
            k=jnp.pad(self.k, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0))),
            v=jnp.pad(self.v, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0))),
            bias=jnp.pad(self._bias_or_zero(), ((0, 0), (0, 0), (0, pad)),
                         constant_values=PREFIX_MASK_BIAS),
        )

    def insert_slot(self, slot, req: "FusedPrefix") -> "FusedPrefix":
        """Scatter a single request's padded prefix (n, 1, Hkv, P, hd) into
        batch index ``slot`` of a per-slot fused table."""
        slot = jnp.asarray(slot, jnp.int32)
        z = jnp.zeros((), jnp.int32)
        req = FusedPrefix.ensure(req)
        return FusedPrefix(
            k=jax.lax.dynamic_update_slice(
                self.k, req.k.astype(self.k.dtype), (z, slot, z, z, z)),
            v=jax.lax.dynamic_update_slice(
                self.v, req.v.astype(self.v.dtype), (z, slot, z, z, z)),
            bias=jax.lax.dynamic_update_slice(
                self._bias_or_zero(), req._bias_or_zero(), (z, slot, z)),
        )

    def to_extra_kv(self, cfg: ModelConfig) -> list:
        """Slice into the per-position ``extra_kv`` list that
        transformer.forward / decode_step consume (one stacked entry per
        pattern position, then tail positions; non-attention positions None).
        """
        cycles, pattern, tail = _grouping(cfg)
        bias = self.bias
        out: List[Optional[dict]] = []
        off = 0

        def slice_at(o, n):
            e = {"k": self.k[o: o + n], "v": self.v[o: o + n]}
            if bias is not None:
                e["bias"] = bias[o: o + n]
            return e

        for kind in pattern:
            if kind in ("attn", "swa"):
                out.append(slice_at(off, cycles))
                off += cycles
            else:
                out.append(None)
        for kind in tail:
            if kind in ("attn", "swa"):
                out.append(slice_at(off, 1))
                off += 1
            else:
                out.append(None)
        return out


def extra_kv_layers(cfg: ModelConfig, fused) -> list:
    """Back-compat shim: ``FusedPrefix.ensure(fused).to_extra_kv(cfg)``."""
    return FusedPrefix.ensure(fused).to_extra_kv(cfg)


# ------------------------------------------------------------------ KVCache


def _insert_slot_leaf(table_leaf: jax.Array, req_leaf: jax.Array,
                      slot: jax.Array, batch_index: jax.Array) -> jax.Array:
    # every cache leaf is (cycles, batch, ...): scatter the request's block at
    # batch index ``batch_index`` of ``req_leaf`` into row ``slot``
    blk = jax.lax.dynamic_slice_in_dim(req_leaf, batch_index, 1, axis=1)
    start = (jnp.zeros((), jnp.int32), slot) + tuple(
        jnp.zeros((), jnp.int32) for _ in range(table_leaf.ndim - 2))
    return jax.lax.dynamic_update_slice(
        table_leaf, blk.astype(table_leaf.dtype), start)


@pytree_dataclass(["pos", "layers"])
@dataclass
class KVCache:
    """A model's decode state: ``pos`` (scalar, or per-slot (B,) vector for
    continuous batching) + per-pattern-position stacked layer entries."""

    pos: jax.Array
    layers: Tuple

    def __getitem__(self, key: str):  # legacy dict interop
        return getattr(self, key)

    @property
    def nbytes(self) -> int:
        return tree_bytes(self)

    def with_pos(self, pos) -> "KVCache":
        return KVCache(pos=jnp.asarray(pos, jnp.int32), layers=self.layers)

    @classmethod
    def ensure(cls, obj) -> "KVCache":
        if isinstance(obj, cls):
            return obj
        return cls(pos=obj["pos"], layers=tuple(obj["layers"]))

    # ----------------------------------------------------------- builders
    @classmethod
    def init(
        cls,
        cfg: ModelConfig,
        batch: int,
        max_seq: int,
        dtype=jnp.bfloat16,
        *,
        window_override: Optional[int] = None,
    ) -> "KVCache":
        """Build the full decode cache for ``cfg`` (transformer.py grouping)."""
        cycles, pattern, tail = _grouping(cfg)
        hd = cfg.resolved_head_dim
        layers = []
        for pos, kind in enumerate(pattern + tail):
            n = cycles if pos < len(pattern) else 1
            if kind == "attn":
                layers.append(
                    init_attn_kv(n, batch, cfg.num_kv_heads, max_seq, hd, dtype))
            elif kind == "swa":
                w = min(window_override or cfg.sliding_window
                        or cfg.long_context_window, max_seq)
                layers.append(
                    init_swa_kv(n, batch, cfg.num_kv_heads, w, hd, dtype))
            elif kind == "rec":
                width = cfg.rglru_width or cfg.d_model
                layers.append(
                    init_rec_state(n, batch, width, cfg.conv_kernel, dtype))
            elif kind == "ssd":
                conv_dim = cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state
                layers.append(
                    init_ssd_state(n, batch, cfg.ssm_nheads, cfg.ssm_head_dim,
                                   cfg.ssm_state, conv_dim, cfg.conv_kernel,
                                   dtype))
            else:
                raise ValueError(f"unknown layer kind {kind!r}")
        return cls(pos=jnp.zeros((), jnp.int32), layers=tuple(layers))

    @classmethod
    def init_slots(
        cls,
        cfg: ModelConfig,
        slots: int,
        max_seq: int,
        dtype=jnp.bfloat16,
        *,
        window_override: Optional[int] = None,
    ) -> "KVCache":
        """A decode cache whose batch axis is a *dense slot table*: ``pos`` is
        per-slot (slots,) int32 so every slot decodes at its own position
        (continuous batching — launch/engine.py; the paged alternative is
        :class:`SlotTable`). Consumed by transformer.decode_step's
        vector-``pos`` path."""
        c = cls.init(cfg, slots, max_seq, dtype, window_override=window_override)
        return c.with_pos(jnp.zeros((slots,), jnp.int32))

    # ------------------------------------------------------------- export
    def export_stack(self, cfg: ModelConfig,
                     length: Optional[int] = None) -> KVStack:
        """Collect all attention-layer k/v into one (n_attn, B, Hkv, S, hd)
        stack — the tensor C2C communicates. Pattern positions + tail are
        concatenated in layer order along the leading axis."""
        cycles, pattern, tail = _grouping(cfg)
        ks, vs = [], []
        for i, kind in enumerate(pattern + tail):
            if kind in ("attn", "swa"):
                e = self.layers[i]
                ks.append(e["k"])
                vs.append(e["v"])
        stack = KVStack(k=jnp.concatenate(ks, axis=0),
                        v=jnp.concatenate(vs, axis=0))
        if length is not None:
            stack = stack.slice_length(length)
        return stack

    # ------------------------------------------------- dense slot lifecycle
    def insert_slot(self, slot, req: "KVCache", length, *,
                    batch_index=0) -> "KVCache":
        """Insert one request of a (possibly batched) prefill cache into slot
        ``slot`` and set that slot's position to ``length``.

        Stale K/V beyond ``length`` (from a previous occupant) never need
        zeroing: the per-slot position mask hides them, and decode overwrites
        each index before it first becomes visible."""
        slot = jnp.asarray(slot, jnp.int32)
        bi = jnp.asarray(batch_index, jnp.int32)
        req = KVCache.ensure(req)
        layers = tuple(
            jax.tree.map(lambda t, r: _insert_slot_leaf(t, r, slot, bi), tl, rl)
            for tl, rl in zip(self.layers, req.layers)
        )
        pos = self.pos.at[slot].set(jnp.asarray(length, jnp.int32))
        return KVCache(pos=pos, layers=layers)

    def evict_slot(self, slot) -> "KVCache":
        """Free a slot immediately: reset its position (stale K/V stay but are
        masked — see insert_slot)."""
        return self.with_pos(
            self.pos.at[jnp.asarray(slot, jnp.int32)].set(0))


# ---------------------------------------------------------------- SlotTable


@pytree_dataclass(["pos", "page_map", "layers"], ["page_size"])
@dataclass
class SlotTable:
    """Paged engine slot table: block/paged KV layout.

    Instead of a dense (slots, max_seq) row per slot, attention K/V live in a
    shared *page pool* of fixed-size pages — per layer entry,
    k/v: (n, num_pages, Hkv, page_size, hd) — and each slot owns an ordered
    ``page_map`` row (slots, pages_per_slot) of physical page ids. A slot's
    HBM cost is the pages it actually needs (ceil(tokens/page_size)), so at a
    fixed pool budget the table sustains far more concurrent slots than the
    dense layout whenever requests are shorter than ``max_seq``.

    ``INVALID_PAGE`` (== num_pages, an out-of-bounds id) marks unallocated
    map entries: scatters through it are dropped and gathers clamp to an
    arbitrary page whose content is hidden by the per-slot position mask —
    exactly the mask that already hides a dense slot's stale K/V, so paged
    decode is *byte-identical* to dense decode (engine_bench verifies).

    Page allocation/free is host-side policy (launch/engine.py keeps the free
    list); this class only does the device-side scatter/gather.
    """

    pos: jax.Array  # (slots,) int32
    page_map: jax.Array  # (slots, pages_per_slot) int32 physical page ids
    layers: Tuple  # per position: {"k","v"} pools (n, num_pages, Hkv, pg, hd)
    page_size: int

    @property
    def num_slots(self) -> int:
        return self.pos.shape[0]

    @property
    def pages_per_slot(self) -> int:
        return self.page_map.shape[1]

    @property
    def num_pages(self) -> int:
        return self.layers[0]["k"].shape[1]

    @property
    def view_seq(self) -> int:
        """Per-slot logical sequence length of the gathered dense view."""
        return self.pages_per_slot * self.page_size

    @property
    def invalid_page(self) -> int:
        return self.num_pages

    @property
    def nbytes(self) -> int:
        return tree_bytes(self)

    def with_pos(self, pos) -> "SlotTable":
        return dataclasses.replace(self, pos=jnp.asarray(pos, jnp.int32))

    @classmethod
    def init(
        cls,
        cfg: ModelConfig,
        slots: int,
        max_seq: int,
        dtype=jnp.bfloat16,
        *,
        page_size: int = 16,
        num_pages: Optional[int] = None,
    ) -> "SlotTable":
        """Pool-backed slot table. Requires a pure full-attention model (ring
        buffers and recurrent state have O(1)-per-slot cost and no paging
        upside; they keep the dense layout)."""
        if any(k != "attn" for k in cfg.block_pattern):
            raise ValueError(
                f"paged SlotTable requires a pure full-attention pattern; "
                f"{cfg.name} has {cfg.block_pattern}")
        if max_seq % page_size:
            raise ValueError(f"max_seq={max_seq} not divisible by "
                             f"page_size={page_size}")
        pages_per_slot = max_seq // page_size
        num_pages = num_pages if num_pages is not None else slots * pages_per_slot
        cycles, pattern, tail = _grouping(cfg)
        hd = cfg.resolved_head_dim
        layers = []
        for pos, _ in enumerate(pattern + tail):
            n = cycles if pos < len(pattern) else 1
            shape = (n, num_pages, cfg.num_kv_heads, page_size, hd)
            layers.append({"k": jnp.zeros(shape, dtype),
                           "v": jnp.zeros(shape, dtype)})
        return cls(
            pos=jnp.zeros((slots,), jnp.int32),
            page_map=jnp.full((slots, pages_per_slot), num_pages, jnp.int32),
            layers=tuple(layers),
            page_size=page_size,
        )

    # ----------------------------------------------------- paged attention
    @staticmethod
    def write_token(pool: jax.Array, tok: jax.Array, page_map: jax.Array,
                    pos: jax.Array, page_size: int) -> jax.Array:
        """Scatter one new K (or V) token per slot straight into its physical
        page — the in-place write the paged decode path uses instead of
        writing into a gathered view and committing back.

        ``pool`` (num_pages, Hkv, page_size, hd); ``tok`` (slots, Hkv, hd);
        ``pos`` (slots,) absolute write position. Slots whose covering page
        map entry is INVALID_PAGE (inactive/evicted) are dropped by the
        scatter, so they can never corrupt pages reassigned to others."""
        pps = page_map.shape[1]
        page_idx = jnp.clip(pos // page_size, 0, pps - 1)
        phys = jnp.take_along_axis(page_map, page_idx[:, None], axis=1)[:, 0]
        off = pos % page_size
        return pool.at[phys, :, off].set(tok.astype(pool.dtype), mode="drop")

    @staticmethod
    def attend(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
               page_map: jax.Array, lengths: jax.Array):
        """GQA flash-decode over the page pool *in place* — the hot-loop path
        that replaces ``dense_view()`` gathering (kernels/paged_attention.py
        walks the page map with scalar prefetch and skips INVALID pages).

        q (slots, H, hd); pools (num_pages, Hkv, page_size, hd); lengths
        (slots,) live tokens. Returns (out (slots, H, hd), m, l) online
        softmax stats so callers can LSE-merge a fused C2C prefix segment."""
        from repro.kernels import ops

        return ops.paged_decode_attention(q, k_pool, v_pool, page_map, lengths)

    # ------------------------------------------------------------- views
    def dense_view(self) -> KVCache:
        """Gather each slot's pages into a contiguous per-slot cache
        (n, slots, Hkv, view_seq, hd) — the layout transformer.decode_step's
        dense path consumes. Unallocated pages clamp to an arbitrary pool
        page; the per-slot position mask hides their content (exact-zero
        attention mass), so the view decodes byte-identically to a dense
        table. The decode hot loop now attends in place (:meth:`attend`);
        this gather survives for export, debugging and parity checks."""
        pm = jnp.minimum(self.page_map, self.num_pages - 1)  # clamp sentinel
        slots, pps = pm.shape

        def gather(pool):
            n, _, H, pg, hd = pool.shape
            v = pool[:, pm]  # (n, slots, pps, Hkv, pg, hd)
            v = v.transpose(0, 1, 3, 2, 4, 5)
            return v.reshape(n, slots, H, pps * pg, hd)

        layers = tuple({"k": gather(e["k"]), "v": gather(e["v"])}
                       for e in self.layers)
        return KVCache(pos=self.pos, layers=layers)

    # --------------------------------------------------------- lifecycle
    def insert_slot(self, slot, req: KVCache, length, page_ids,
                    *, batch_index=0) -> "SlotTable":
        """Insert one request of a prefill cache (row layout, seq length ==
        ``view_seq``) into slot ``slot``: scatter its pages into the pool at
        ``page_ids`` ((pages_per_slot,) int32, INVALID_PAGE-padded beyond the
        allocated count) and point the slot's page map at them."""
        slot = jnp.asarray(slot, jnp.int32)
        bi = jnp.asarray(batch_index, jnp.int32)
        page_ids = jnp.asarray(page_ids, jnp.int32)
        req = KVCache.ensure(req)
        pps, pg = self.pages_per_slot, self.page_size

        def scatter(pool, row):
            # row: (n, B, Hkv, view_seq, hd) -> request bi's pages
            n, _, H, S, hd = row.shape
            blk = jax.lax.dynamic_slice_in_dim(row, bi, 1, axis=1)[:, 0]
            pages = blk.reshape(n, H, pps, pg, hd).transpose(0, 2, 1, 3, 4)
            # scatter (n, pps, Hkv, pg, hd) at pool axis 1; INVALID ids drop
            return pool.at[:, page_ids].set(pages.astype(pool.dtype),
                                            mode="drop")

        layers = tuple(
            {"k": scatter(e["k"], r["k"]), "v": scatter(e["v"], r["v"])}
            for e, r in zip(self.layers, req.layers)
        )
        return SlotTable(
            pos=self.pos.at[slot].set(jnp.asarray(length, jnp.int32)),
            page_map=self.page_map.at[slot].set(page_ids),
            layers=layers,
            page_size=self.page_size,
        )

    def evict_slot(self, slot) -> "SlotTable":
        """Free a slot: reset its position and unmap its pages. (Returning the
        physical pages to the free pool is the host-side allocator's job.)"""
        slot = jnp.asarray(slot, jnp.int32)
        return SlotTable(
            pos=self.pos.at[slot].set(0),
            page_map=self.page_map.at[slot].set(self.invalid_page),
            layers=self.layers,
            page_size=self.page_size,
        )

    def commit(self, new_view: KVCache, pos_out: jax.Array) -> "SlotTable":
        """Fold one decode step back into the pool: decode_step wrote exactly
        one token per slot (at the slot's pre-step position) into the gathered
        dense view; scatter those tokens to their physical pages and adopt
        ``pos_out`` (the engine's activity-masked position vector). Slots
        whose page map entry is INVALID_PAGE (inactive/evicted) are dropped by
        the scatter, so they can never corrupt pages reassigned to others."""
        old_pos = self.pos  # position each slot's new token was written at
        slots = self.num_slots
        page_idx = jnp.clip(old_pos // self.page_size, 0,
                            self.pages_per_slot - 1)
        phys = jnp.take_along_axis(self.page_map, page_idx[:, None],
                                   axis=1)[:, 0]  # (slots,)
        off = old_pos % self.page_size
        rows = jnp.arange(slots)

        def scatter(pool, view):
            # token written this step: view[(n, slots, Hkv, view_seq, hd)] at
            # [:, s, :, old_pos[s], :] -> (slots, n, Hkv, hd) (adv-idx moves
            # the indexed axes to the front)
            tok = view[:, rows, :, old_pos, :]
            return pool.at[:, phys, :, off].set(tok.astype(pool.dtype),
                                                mode="drop")

        layers = tuple(
            {"k": scatter(e["k"], ve["k"]), "v": scatter(e["v"], ve["v"])}
            for e, ve in zip(self.layers, new_view.layers)
        )
        return SlotTable(pos=pos_out, page_map=self.page_map, layers=layers,
                         page_size=self.page_size)


# ----------------------------------------------------------------- helpers


def n_attn_layers(cfg: ModelConfig) -> int:
    return len(cfg.attention_layers)


def cache_bytes_per_token(cfg: ModelConfig, dtype_bytes: int = 2) -> int:
    """Communication load of C2C per generated/cached token (paper: 88 KB/token
    for the 4-transmitter case-study zoo). Counts k+v over all attention layers."""
    hd = cfg.resolved_head_dim
    n_attn = len(cfg.attention_layers)
    return 2 * n_attn * cfg.num_kv_heads * hd * dtype_bytes
