"""Decoder assembly for all six assigned families.

Compile-time scaling: layers are executed with ``jax.lax.scan`` over *pattern
cycles* — for a block pattern of period p (e.g. RecurrentGemma's (rec, rec, swa)),
parameters are stacked per pattern position across the ``num_layers // p`` full
cycles and scanned, with the ``num_layers % p`` leftover layers applied unstacked.
This keeps HLO size O(p) instead of O(num_layers), which is what makes compiling
80-layer models against a 512-device mesh tractable (and is standard practice in
production JAX LLM stacks).

Entry points:
  init_params      — build the parameter pytree
  forward          — teacher-forced full-sequence forward (train / eval)
  prefill          — full forward that also fills a decode cache
  decode_step      — one-token step against a cache (serve_step target)
  loss_fn          — LM cross-entropy (+ MoE aux)
"""
from __future__ import annotations

import contextlib
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import rglru as RG
from repro.models import ssd as SSD
from repro.models.cache import FusedPrefix, KVCache, SlotTable


# ------------------------------------------------------------ act sharding

# Optional boundary sharding for the layer-scan carry (set by the launcher):
# Megatron-style sequence parallelism — x is pinned to (batch→data, seq→model)
# between blocks, so the 1-per-cycle rematted carries shrink by the model-axis
# size; GSPMD inserts the all-gathers inside the blocks.
_ACT_SPEC: list = [None]  # (NamedSharding, seq_divisor) | None


@contextlib.contextmanager
def activation_sharding(sharding, seq_divisor: int):
    _ACT_SPEC[0] = (sharding, seq_divisor)
    try:
        yield
    finally:
        _ACT_SPEC[0] = None


def _constrain(x):
    if _ACT_SPEC[0] is not None and x.ndim == 3:
        sharding, div = _ACT_SPEC[0]
        if x.shape[1] % max(div, 1) == 0 and x.shape[1] >= div:
            x = jax.lax.with_sharding_constraint(x, sharding)
    return x


def _remat_groups(cycles: int) -> int:
    """Divisor of ``cycles`` nearest √cycles (hierarchical remat: carry memory
    scales with G + cycles/G instead of cycles)."""
    best = 1
    for g in range(1, cycles + 1):
        if cycles % g == 0 and abs(g - math.isqrt(cycles)) < abs(
                best - math.isqrt(cycles)):
            best = g
    return best


# ---------------------------------------------------------------- grouping


def layer_grouping(cfg: ModelConfig) -> Tuple[int, Tuple[str, ...], Tuple[str, ...]]:
    """(num_full_cycles, pattern, tail_types)."""
    p = cfg.block_pattern
    cycles = cfg.num_layers // len(p)
    tail = p[: cfg.num_layers % len(p)]
    return cycles, p, tail


# ---------------------------------------------------------------- init


def init_layer(cfg: ModelConfig, kind: str, key, dtype) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    if kind in ("attn", "swa"):
        p = {
            "norm1": L.init_rmsnorm(cfg.d_model),
            "attn": A.init_attention(cfg, k1, dtype),
            "norm2": L.init_rmsnorm(cfg.d_model),
        }
        if cfg.num_experts:
            p["ffn"] = MOE.init_moe(cfg, k2, dtype)
        else:
            p["ffn"] = L.init_swiglu(k2, cfg.d_model, cfg.d_ff, dtype)
        return p
    if kind == "rec":
        return {
            "norm1": L.init_rmsnorm(cfg.d_model),
            "rec": RG.init_rglru_block(cfg, k3, dtype),
            "norm2": L.init_rmsnorm(cfg.d_model),
            "ffn": L.init_swiglu(k2, cfg.d_model, cfg.d_ff, dtype),
        }
    if kind == "ssd":
        return {
            "norm1": L.init_rmsnorm(cfg.d_model),
            "ssd": SSD.init_ssd_block(cfg, k4, dtype),
        }
    raise ValueError(kind)


def init_params(cfg: ModelConfig, key, dtype=jnp.bfloat16) -> dict:
    cycles, pattern, tail = layer_grouping(cfg)
    ke, kh, kl = jax.random.split(key, 3)
    params: dict = {
        "embed": L.init_embedding(ke, cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": L.init_rmsnorm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.init_linear(kh, cfg.d_model, cfg.vocab_size, dtype=dtype)

    def stack_init(kind: str, pos: int):
        keys = jax.random.split(jax.random.fold_in(kl, pos), cycles)
        return jax.vmap(lambda k: init_layer(cfg, kind, k, dtype))(keys)

    params["cycle"] = [stack_init(kind, i) for i, kind in enumerate(pattern)]
    params["tail"] = [
        init_layer(cfg, kind, jax.random.fold_in(kl, 1000 + i), dtype)
        for i, kind in enumerate(tail)
    ]
    return params


# ---------------------------------------------------------------- rope tables


def rope_tables(cfg: ModelConfig, positions: jax.Array,
                positions_3d: Optional[jax.Array] = None):
    """cos/sin (B, S, hd//2) fp32. ``positions`` is (B, S) int32."""
    if not cfg.attention_layers:  # attention-free (pure SSM): no rope needed
        z = jnp.zeros((*positions.shape, 1), jnp.float32)
        return z, z
    hd = cfg.resolved_head_dim
    if cfg.mrope_sections is not None:
        if positions_3d is None:  # text-only: all three streams equal
            positions_3d = jnp.broadcast_to(positions[None], (3, *positions.shape))
        return L.mrope_table(positions_3d, hd, cfg.rope_theta, cfg.mrope_sections)
    return L.rope_table(positions, hd, cfg.rope_theta)


# ---------------------------------------------------------------- layer apply


def _apply_layer_full(cfg, kind, p, x, cos, sin, window, aux, state=None,
                      extra_kv=None, moe_dropless=True):
    """Full-sequence layer. Returns (x, kv_or_state, aux)."""
    kv = None
    new_state = None
    if kind in ("attn", "swa"):
        w = window if kind == "swa" else 0
        h, kv = A.full_forward(cfg, p["attn"], L.rmsnorm(p["norm1"], x, cfg.norm_eps),
                               cos, sin, window=w, extra_kv=extra_kv)
        x = x + h
        h2 = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
        if cfg.num_experts:
            y, a = MOE.moe_ffn(cfg, p["ffn"], h2, dropless=moe_dropless)
            aux = aux + a
        else:
            y = L.swiglu(p["ffn"], h2)
        x = x + y
    elif kind == "rec":
        h, new_state = RG.block_forward(cfg, p["rec"],
                                        L.rmsnorm(p["norm1"], x, cfg.norm_eps),
                                        state)
        x = x + h
        x = x + L.swiglu(p["ffn"], L.rmsnorm(p["norm2"], x, cfg.norm_eps))
    elif kind == "ssd":
        h, new_state = SSD.block_forward(cfg, p["ssd"],
                                         L.rmsnorm(p["norm1"], x, cfg.norm_eps),
                                         state)
        x = x + h
    else:
        raise ValueError(kind)
    return x, kv, new_state, aux


def _write_prefill_kv(entry: dict, kv: dict, window: int) -> dict:
    """Store prefill k/v (B,Hkv,S,hd) into a preallocated cache entry."""
    S = kv["k"].shape[-2]
    if "slot_pos" in entry:  # ring buffer
        W = entry["k"].shape[-2]
        n = min(S, W)
        pos = jnp.arange(S - n, S)
        slots = pos % W
        k = entry["k"].at[:, :, slots].set(kv["k"][:, :, -n:])
        v = entry["v"].at[:, :, slots].set(kv["v"][:, :, -n:])
        sp = entry["slot_pos"].at[:, slots].set(
            jnp.broadcast_to(pos, (entry["slot_pos"].shape[0], n)).astype(jnp.int32))
        return {"k": k, "v": v, "slot_pos": sp}
    k = jax.lax.dynamic_update_slice(entry["k"], kv["k"], (0, 0, 0, 0))
    v = jax.lax.dynamic_update_slice(entry["v"], kv["v"], (0, 0, 0, 0))
    return {"k": k, "v": v}


def _apply_layer_decode(cfg, kind, p, x, cos, sin, entry, pos, window,
                        extra_kv=None, extra_kv_mode="concat", paged=None):
    if kind in ("attn", "swa"):
        w = window if kind == "swa" else 0
        xn = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
        if paged is not None:  # entry is a page pool; attend in place
            page_map, page_size = paged
            h, new_kv = A.decode_forward_paged(cfg, p["attn"], xn, cos, sin,
                                               entry, page_map, pos,
                                               page_size=page_size,
                                               extra_kv=extra_kv)
        else:
            h, new_kv = A.decode_forward(cfg, p["attn"], xn,
                                         cos, sin, entry, pos, window=w,
                                         extra_kv=extra_kv,
                                         extra_kv_mode=extra_kv_mode)
        x = x + h
        h2 = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
        if cfg.num_experts:
            # dropless: a slot's routing must not depend on its batch
            # neighbours (continuous batching packs unrelated requests)
            y, _ = MOE.moe_ffn(cfg, p["ffn"], h2, dropless=True)
        else:
            y = L.swiglu(p["ffn"], h2)
        return x + y, new_kv
    if kind == "rec":
        h, st = RG.block_forward(cfg, p["rec"],
                                 L.rmsnorm(p["norm1"], x, cfg.norm_eps), entry)
        x = x + h
        return x + L.swiglu(p["ffn"], L.rmsnorm(p["norm2"], x, cfg.norm_eps)), st
    if kind == "ssd":
        h, st = SSD.block_forward(cfg, p["ssd"],
                                  L.rmsnorm(p["norm1"], x, cfg.norm_eps), entry)
        return x + h, st
    raise ValueError(kind)


# ---------------------------------------------------------------- forward


def _embed_in(cfg, params, tokens, embeds):
    if embeds is not None:
        return embeds
    return L.embed(params["embed"], tokens)


def _logits_out(cfg, params, x):
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        return L.unembed(params["embed"], x)
    return L.linear(params["lm_head"], x)


def forward(
    cfg: ModelConfig,
    params: dict,
    tokens: Optional[jax.Array] = None,  # (B, S) int32
    embeds: Optional[jax.Array] = None,  # (B, S, d) — vlm/audio frontends
    positions_3d: Optional[jax.Array] = None,  # (3, B, S) for M-RoPE
    *,
    window_override: int = 0,
    remat: bool = False,
    extra_kv: Optional[list] = None,  # per pattern+tail position: stacked kv | None
    unroll: bool = False,  # python-loop the cycles (dry-run cost accounting)
    return_hidden: bool = False,  # skip unembed (chunked-CE path)
    moe_dropless: bool = True,  # inference default; training sets False (moe.py)
) -> Tuple[jax.Array, jax.Array]:
    """Teacher-forced forward. Returns (logits (B,S,V), moe_aux scalar).

    ``extra_kv`` is the C2C fused-cache prefix (Eq. 1/4): a list with one entry
    per pattern position (then tail positions); attention entries are stacked
    per-layer ``FusedPrefix`` slices with k/v (cycles, B, Hkv, Sf, hd)
    (legacy {"k","v"} dicts still accepted), others None.
    """
    cycles, pattern, tail = layer_grouping(cfg)
    x = _embed_in(cfg, params, tokens, embeds)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    cos, sin = rope_tables(cfg, positions, positions_3d)
    window = window_override or cfg.sliding_window
    ek = extra_kv or [None] * (len(pattern) + len(tail))
    # scan xs must be a uniform pytree: dummy zeros for positions without a prefix
    ek_cycle = tuple(
        ek[i] if ek[i] is not None else jnp.zeros((cycles,), jnp.float32)
        for i in range(len(pattern))
    )

    def cycle_body(carry, xs):
        x, aux = carry
        x = _constrain(x)
        p_stack, ekx = xs
        for i, kind in enumerate(pattern):
            e = ekx[i] if isinstance(ekx[i], (dict, FusedPrefix)) else None
            x, _, _, aux = _apply_layer_full(cfg, kind, p_stack[i], x, cos, sin,
                                             window, aux, extra_kv=e,
                                             moe_dropless=moe_dropless)
        return (_constrain(x), aux), None

    aux = jnp.zeros((), jnp.float32)
    if cycles > 0:
        xs_all = (tuple(params["cycle"]), ek_cycle)
        if unroll:
            body = jax.checkpoint(cycle_body) if remat else cycle_body
            for c in range(cycles):
                (x, aux), _ = body((x, aux), jax.tree.map(lambda a: a[c], xs_all))
        elif remat and cycles > 3:
            # Hierarchical remat: remat at BOTH levels. The outer checkpoint
            # stops the forward pass storing inner-scan carries (only G group
            # carries survive); the inner checkpoint keeps backward transients
            # to one cycle's intermediates. Live carry memory: G + cycles/G
            # instead of cycles.
            G = _remat_groups(cycles)
            xs_g = jax.tree.map(
                lambda a: a.reshape(G, cycles // G, *a.shape[1:]), xs_all)

            @jax.checkpoint
            def group_body(carry, xs_grp):
                return jax.lax.scan(jax.checkpoint(cycle_body), carry, xs_grp)

            (x, aux), _ = jax.lax.scan(group_body, (x, aux), xs_g)
        else:
            body = jax.checkpoint(cycle_body) if remat else cycle_body
            (x, aux), _ = jax.lax.scan(body, (x, aux), xs_all)
    for i, kind in enumerate(tail):
        e = ek[len(pattern) + i]
        e = jax.tree.map(lambda a: a[0], e) if e is not None else None
        x, _, _, aux = _apply_layer_full(cfg, kind, params["tail"][i], x, cos, sin,
                                         window, aux, extra_kv=e,
                                         moe_dropless=moe_dropless)
    if return_hidden:
        return x, aux
    return _logits_out(cfg, params, x), aux


def prefill(
    cfg: ModelConfig,
    params: dict,
    tokens: Optional[jax.Array] = None,
    embeds: Optional[jax.Array] = None,
    positions_3d: Optional[jax.Array] = None,
    *,
    max_seq: int,
    cache_dtype=jnp.bfloat16,
    window_override: int = 0,
    extra_kv: Optional[list] = None,  # C2C fused prefix, as in ``forward``
    unroll: bool = False,
    pos_offset=0,
) -> Tuple[jax.Array, KVCache]:
    """Full forward that also fills a decode cache. Returns (logits, cache).

    ``pos_offset`` (int or traced scalar) shifts RoPE positions to
    ``pos_offset + [0, S)`` — the suffix-prefill path of the engine's prefix
    cache, where the prompt's first ``pos_offset`` tokens are served from
    already-cached pages passed in via ``extra_kv``. The causal mask is
    relative, so only the rotary tables see the offset; cache rows still fill
    [0, S) and the caller re-maps them (SlotTable.insert_suffix)."""
    cycles, pattern, tail = layer_grouping(cfg)
    x = _embed_in(cfg, params, tokens, embeds)
    B, S = x.shape[:2]
    positions = jnp.asarray(pos_offset, jnp.int32) + jnp.broadcast_to(
        jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    cos, sin = rope_tables(cfg, positions, positions_3d)
    window = window_override or cfg.sliding_window
    cache = KVCache.init(cfg, B, max_seq, cache_dtype,
                         window_override=window_override or None)
    ek = extra_kv or [None] * (len(pattern) + len(tail))
    ek_cycle = tuple(
        ek[i] if ek[i] is not None else jnp.zeros((max(cycles, 1),), jnp.float32)
        for i in range(len(pattern))
    )

    def cycle_body(carry, xs):
        x, aux = carry
        p_stack, entries, ekx = xs
        new_entries = []
        for i, kind in enumerate(pattern):
            e = ekx[i] if isinstance(ekx[i], (dict, FusedPrefix)) else None
            x, kv, st, aux = _apply_layer_full(
                cfg, kind, p_stack[i], x, cos, sin, window, aux,
                state=None, extra_kv=e)
            if kind in ("attn", "swa"):
                new_entries.append(
                    _write_prefill_kv(entries[i],
                                      {k: v.astype(cache_dtype) for k, v in kv.items()},
                                      window))
            else:
                new_entries.append(st)
        return (x, aux), tuple(new_entries)

    aux = jnp.zeros((), jnp.float32)
    if cycles > 0:
        xs_all = (tuple(params["cycle"]), tuple(cache.layers[: len(pattern)]),
                  ek_cycle)
        if unroll:
            ys = []
            carry = (x, aux)
            for c in range(cycles):
                carry, y = cycle_body(carry, jax.tree.map(lambda a: a[c], xs_all))
                ys.append(y)
            (x, aux) = carry
            new_layers = list(jax.tree.map(lambda *a: jnp.stack(a), *ys))
        else:
            (x, aux), new_layers = jax.lax.scan(cycle_body, (x, aux), xs_all)
            new_layers = list(new_layers)
    else:
        new_layers = []
    for i, kind in enumerate(tail):
        entry = jax.tree.map(lambda a: a[0], cache.layers[len(pattern) + i])
        e = ek[len(pattern) + i]
        e = jax.tree.map(lambda a: a[0], e) if e is not None else None
        x, kv, st, aux = _apply_layer_full(cfg, kind, params["tail"][i], x, cos,
                                           sin, window, aux, extra_kv=e)
        if kind in ("attn", "swa"):
            new_e = _write_prefill_kv(entry,
                                      {k: v.astype(cache_dtype) for k, v in kv.items()},
                                      window)
        else:
            new_e = st
        new_layers.append(jax.tree.map(lambda a: a[None], new_e))
    return _logits_out(cfg, params, x), KVCache(
        pos=jnp.asarray(S, jnp.int32), layers=tuple(new_layers))


def _apply_layer_chunk(cfg, kind, p, x, cos, sin, entry, chunk, extra_kv=None):
    """One chunked-prefill layer step (paged attention only)."""
    if kind != "attn":
        raise ValueError(
            f"chunked prefill requires a pure full-attention pattern; "
            f"got {kind!r}")
    page_row, bs, bp, bl, phys, off, block_q = chunk
    xn = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
    h, new_kv = A.prefill_chunk_forward(cfg, p["attn"], xn, cos, sin, entry,
                                        page_row, bs, bp, bl, phys, off,
                                        block_q=block_q, extra_kv=extra_kv)
    x = x + h
    h2 = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
    if cfg.num_experts:
        # dropless: per-token routing — padded chunk rows must not affect
        # live rows' expert capacity
        y, _ = MOE.moe_ffn(cfg, p["ffn"], h2, dropless=True)
    else:
        y = L.swiglu(p["ffn"], h2)
    return x + y, new_kv


def prefill_chunk(
    cfg: ModelConfig,
    params: dict,
    table: SlotTable,
    tokens: jax.Array,  # (1, C) int32 — one fixed-width token-budget chunk
    pos_offset: jax.Array,  # traced scalar: absolute position of tokens[0, 0]
    n_live: jax.Array,  # traced scalar: live tokens in the chunk (<= C)
    page_row: jax.Array,  # (pages_per_slot,) int32 — the slot's lease pages
    *,
    block_q: int,
    extra_kv: Optional[list] = None,  # C2C fused prefix, as in ``forward``
) -> Tuple[jax.Array, SlotTable]:
    """Prefill ONE token-budget chunk of a prompt straight into pool pages.

    The chunked twin of :func:`prefill`: instead of one monolithic forward
    over a padded prompt bucket, the engine feeds fixed-width ``C``-token
    chunks (C = its prefill token budget — one trace per chunk signature, not
    per prompt length) at position offset ``pos_offset``. Every layer scatters
    the chunk's K/V to the physical pages named by ``page_row`` and ragged-
    flash-attends over that row, so causality uniformly covers radix-shared
    prefix pages, earlier chunks, and the current chunk — there is no dense
    staging cache and no ``prefix_extra_kv`` gather. Rows past ``n_live`` are
    padding: their writes drop through INVALID page ids and their outputs are
    exact zeros at the attention (per-token FFN keeps them confined).

    The table's ``pos``/``page_map`` are deliberately left untouched — the
    slot stays invisible to decode until its *final* chunk, when the engine
    adopts the row (:meth:`SlotTable.adopt_slot`). Returns
    (logits (1, C, V), table with updated pools)."""
    cycles, pattern, tail = layer_grouping(cfg)
    if any(k != "attn" for k in pattern + tail):
        raise ValueError(
            f"chunked prefill requires a pure full-attention pattern; "
            f"{cfg.name} has {cfg.block_pattern}")
    C = tokens.shape[1]
    if C % block_q:
        raise ValueError(f"chunk width C={C} not divisible by "
                         f"block_q={block_q}")
    pg, pps = table.page_size, table.pages_per_slot
    pos_offset = jnp.asarray(pos_offset, jnp.int32)
    n_live = jnp.asarray(n_live, jnp.int32)
    positions = pos_offset + jnp.arange(C, dtype=jnp.int32)[None]
    cos, sin = rope_tables(cfg, positions)
    # per-block ragged metadata (kernels/prefill_attention.py contract)
    i = jnp.arange(C // block_q, dtype=jnp.int32)
    bl = jnp.clip(n_live - i * block_q, 0, block_q)
    bs = jnp.where(bl > 0, 0, -1).astype(jnp.int32)
    bp = pos_offset + i * block_q
    # per-token scatter targets: INVALID past the live count (writes drop)
    abs_pos = pos_offset + jnp.arange(C, dtype=jnp.int32)
    page_idx = jnp.clip(abs_pos // pg, 0, pps - 1)
    phys = jnp.where(jnp.arange(C) < n_live, page_row[page_idx],
                     table.invalid_page).astype(jnp.int32)
    off = abs_pos % pg
    chunk = (jnp.asarray(page_row, jnp.int32), bs, bp, bl, phys, off, block_q)
    x = _embed_in(cfg, params, tokens, None)
    ek = extra_kv or [None] * (len(pattern) + len(tail))
    ek_cycle = tuple(
        ek[i] if ek[i] is not None else jnp.zeros((max(cycles, 1),), jnp.float32)
        for i in range(len(pattern))
    )

    def cycle_body(x, xs):
        p_stack, entries, ekx = xs
        new_entries = []
        for j, kind in enumerate(pattern):
            e = ekx[j] if isinstance(ekx[j], (dict, FusedPrefix)) else None
            x, new_e = _apply_layer_chunk(cfg, kind, p_stack[j], x, cos, sin,
                                          entries[j], chunk, extra_kv=e)
            new_entries.append(new_e)
        return x, tuple(new_entries)

    if cycles > 0:
        xs_all = (tuple(params["cycle"]), tuple(table.layers[: len(pattern)]),
                  ek_cycle)
        x, new_layers = jax.lax.scan(cycle_body, x, xs_all)
        new_layers = list(new_layers)
    else:
        new_layers = []
    for j, kind in enumerate(tail):
        entry = jax.tree.map(lambda a: a[0], table.layers[len(pattern) + j])
        e = ek[len(pattern) + j]
        e = jax.tree.map(lambda a: a[0], e) if e is not None else None
        x, new_e = _apply_layer_chunk(cfg, kind, params["tail"][j], x, cos,
                                      sin, entry, chunk, extra_kv=e)
        new_layers.append(jax.tree.map(lambda a: a[None], new_e))
    return _logits_out(cfg, params, x), SlotTable(
        pos=table.pos, page_map=table.page_map, layers=tuple(new_layers),
        page_size=table.page_size)


def decode_step(
    cfg: ModelConfig,
    params: dict,
    cache: KVCache,
    token: jax.Array,  # (B,) int32 — last generated token
    *,
    window_override: int = 0,
    extra_kv: Optional[list] = None,  # C2C fused prefix, as in ``forward``
    extra_kv_mode: str = "concat",  # "concat" (Eq.1 literal) | "split" (LSE)
    unroll: bool = False,
) -> Tuple[jax.Array, KVCache]:
    """One decode step (the serve_step the decode shapes lower).

    ``cache.pos`` may be a scalar (lockstep batch) or a per-row (B,) vector
    (continuous batching: each slot at its own position — launch/engine.py).
    ``cache`` may also be a paged :class:`repro.models.cache.SlotTable`; the
    step then dispatches to the in-place paged-attention path (per-layer page
    pools + page map, no ``dense_view()`` gather on the hot loop).

    Returns (logits (B, V), updated cache)."""
    cycles, pattern, tail = layer_grouping(cfg)
    paged = isinstance(cache, SlotTable)
    paged_info = (cache.page_map, cache.page_size) if paged else None
    if not paged:
        cache = KVCache.ensure(cache)  # accepts legacy {"pos","layers"} dicts
    pos = cache.pos
    x = L.embed(params["embed"], token[:, None])
    B = x.shape[0]
    if pos.ndim == 1:  # per-slot positions
        positions = pos[:, None].astype(jnp.int32)
    else:
        positions = jnp.broadcast_to(pos[None, None], (B, 1)).astype(jnp.int32)
    cos, sin = rope_tables(cfg, positions)
    window = window_override or cfg.sliding_window
    ek = extra_kv or [None] * (len(pattern) + len(tail))
    ek_cycle = tuple(
        ek[i] if ek[i] is not None else jnp.zeros((max(cycles, 1),), jnp.float32)
        for i in range(len(pattern))
    )

    def cycle_body(x, xs):
        p_stack, entries, ekx = xs
        new_entries = []
        for i, kind in enumerate(pattern):
            e = ekx[i] if isinstance(ekx[i], (dict, FusedPrefix)) else None
            x, new_e = _apply_layer_decode(cfg, kind, p_stack[i], x, cos, sin,
                                           entries[i], pos, window, extra_kv=e,
                                           extra_kv_mode=extra_kv_mode,
                                           paged=paged_info)
            new_entries.append(new_e)
        return x, tuple(new_entries)

    if cycles > 0:
        xs_all = (tuple(params["cycle"]), tuple(cache.layers[: len(pattern)]),
                  ek_cycle)
        if unroll:
            ys = []
            for c in range(cycles):
                x, y = cycle_body(x, jax.tree.map(lambda a: a[c], xs_all))
                ys.append(y)
            new_layers = list(jax.tree.map(lambda *a: jnp.stack(a), *ys))
        else:
            x, new_layers = jax.lax.scan(cycle_body, x, xs_all)
            new_layers = list(new_layers)
    else:
        new_layers = []
    for i, kind in enumerate(tail):
        entry = jax.tree.map(lambda a: a[0], cache.layers[len(pattern) + i])
        e = ek[len(pattern) + i]
        e = jax.tree.map(lambda a: a[0], e) if e is not None else None
        x, new_e = _apply_layer_decode(cfg, kind, params["tail"][i], x, cos, sin,
                                       entry, pos, window, extra_kv=e,
                                       extra_kv_mode=extra_kv_mode,
                                       paged=paged_info)
        new_layers.append(jax.tree.map(lambda a: a[None], new_e))
    logits = _logits_out(cfg, params, x)[:, 0]
    if paged:
        return logits, SlotTable(pos=pos + 1, page_map=cache.page_map,
                                 layers=tuple(new_layers),
                                 page_size=cache.page_size)
    return logits, KVCache(pos=pos + 1, layers=tuple(new_layers))


# ---------------------------------------------------------------- loss


def loss_fn(
    cfg: ModelConfig,
    params: dict,
    tokens: Optional[jax.Array] = None,
    labels: jax.Array = None,  # (B, S) int32; -100 = ignore
    embeds: Optional[jax.Array] = None,
    positions_3d: Optional[jax.Array] = None,
    *,
    remat: bool = True,
    unroll: bool = False,
) -> jax.Array:
    hidden, aux = forward(cfg, params, tokens, embeds, positions_3d, remat=remat,
                          unroll=unroll, return_hidden=True,
                          moe_dropless=False)  # capacity-bounded training baseline
    hidden = L.rmsnorm(params["final_norm"], hidden, cfg.norm_eps)

    def unembed(xb):
        if cfg.tie_embeddings:
            return L.unembed(params["embed"], xb)
        return L.linear(params["lm_head"], xb)

    # Chunked cross-entropy: the (B, S, V) fp32 logits of a 150k–256k vocab are
    # several GiB/device — never materialise them. Each (rematted) chunk
    # unembeds, reduces to (nll_sum, count), and is recomputed in backward.
    # One-hot contraction instead of take_along_axis: a gather along the
    # vocab-SHARDED axis would make GSPMD replicate the full logits.
    B, S, _ = hidden.shape
    Q = S
    for cand in (512, 256, 128):
        if S % cand == 0 and S > cand:
            Q = cand
            break
    nc = S // Q
    xc = hidden.reshape(B, nc, Q, -1).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, nc, Q).transpose(1, 0, 2)

    @jax.checkpoint
    def chunk_ce(carry, xs):
        xb, lb = xs
        logits = unembed(xb).astype(jnp.float32)
        valid = lb >= 0
        safe = jnp.where(valid, lb, 0)
        lse = jax.nn.logsumexp(logits, axis=-1)
        onehot = jax.nn.one_hot(safe, cfg.vocab_size, dtype=logits.dtype)
        picked = jnp.sum(logits * onehot, axis=-1)
        nll = (lse - picked) * valid
        return (carry[0] + jnp.sum(nll), carry[1] + jnp.sum(valid)), None

    (nll_sum, count), _ = jax.lax.scan(
        chunk_ce, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (xc, lc))
    loss = nll_sum / jnp.maximum(count, 1)
    return loss + cfg.router_aux_coef * aux
