"""Three-term roofline analysis from a compiled (dry-run) executable.

    compute_s    = HLO_FLOPs / (chips × 197 TFLOP/s)
    memory_s     = HLO_bytes / (chips × 819 GB/s)
    collective_s = collective_bytes / (chips × 50 GB/s per ICI link)

``cost_analysis`` supplies FLOPs and bytes; collective bytes are NOT in
cost_analysis, so we parse the post-SPMD optimized HLO (``compiled.as_text()``)
and sum operand/result sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute. Post-partitioning HLO shapes are PER-DEVICE, so
the parsed bytes are per-chip wire bytes (ring-factor approximations noted
per-op below); cost_analysis of a partitioned module is likewise per-device.
"""
from __future__ import annotations

import re
from dataclasses import asdict, dataclass, field
from typing import Optional

# TPU v5e-class constants (per chip) — single source shared with
# core/protocol.py so latency estimates can't diverge from these tables.
from repro.hw import HBM_BW, ICI_BW, PEAK_FLOPS  # noqa: F401

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLL_RE = re.compile(
    r"^\s*(?:%|)(?P<name>[\w.\-]*)\s*=\s*(?P<rshape>[^=]*?)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
    re.M,
)

_SHAPE_RE = re.compile(r"(?P<dt>\w+\d*)\[(?P<dims>[\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Sum bytes over (possibly tuple) shape strings like '(bf16[8,128], f32[4])'."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt = m.group("dt")
        if dt not in _DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


# Wire-cost multiplier per collective (ring algorithm, n→∞ limit):
#   all-reduce = 2× payload (reduce-scatter + all-gather phases)
#   all-gather / reduce-scatter / all-to-all / collective-permute ≈ 1× payload
_OP_FACTOR = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


@dataclass
class CollectiveStats:
    counts: dict = field(default_factory=dict)
    bytes_by_op: dict = field(default_factory=dict)
    total_bytes: float = 0.0  # wire bytes per device (factor-weighted)


def parse_collectives(hlo_text: str) -> CollectiveStats:
    st = CollectiveStats()
    for m in _COLL_RE.finditer(hlo_text):
        op = m.group("op")
        b = _shape_bytes(m.group("rshape"))
        st.counts[op] = st.counts.get(op, 0) + 1
        st.bytes_by_op[op] = st.bytes_by_op.get(op, 0) + b
        st.total_bytes += b * _OP_FACTOR[op]
    return st


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    analytic_flops: float  # global, all chips (closed-form; see flops_analytic)
    hlo_flops_raw: float  # per device, uncorrected cost_analysis (scan bodies ×1)
    hlo_bytes: float  # per device, cycle-extrapolated
    hlo_bytes_raw: float
    collective_bytes: float  # per device (wire, factor-weighted, extrapolated)
    collective_bytes_raw: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float  # 6·N(active)·D global
    useful_ratio: float  # model_flops / analytic_flops
    collectives: dict = field(default_factory=dict)
    memory_per_device: Optional[dict] = None

    def to_json(self) -> dict:
        return asdict(self)


def cost_of(compiled) -> dict:
    """(flops, bytes, collective wire bytes) of one compiled executable,
    per device, as reported (no loop correction)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, list):  # some jax versions return [dict]
        ca = ca[0]
    coll = parse_collectives(compiled.as_text())
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll_bytes": coll.total_bytes,
        "coll_counts": coll.counts,
        "coll_by_op": coll.bytes_by_op,
    }


def analyze(
    *,
    arch: str,
    shape_name: str,
    mesh_name: str,
    chips: int,
    compiled,
    model_flops: float,
    analytic_flops: float,
    bytes_corrected: Optional[float] = None,
    coll_corrected: Optional[float] = None,
    ici_links: int = 1,
) -> Roofline:
    raw = cost_of(compiled)
    bytes_ = bytes_corrected if bytes_corrected is not None else raw["bytes"]
    coll_b = coll_corrected if coll_corrected is not None else raw["coll_bytes"]

    compute_s = analytic_flops / (chips * PEAK_FLOPS)
    memory_s = bytes_ / HBM_BW
    collective_s = coll_b / (ICI_BW * ici_links)
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)

    mem = None
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
            "output_bytes": getattr(ma, "output_size_in_bytes", None),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
            "peak_bytes": getattr(ma, "peak_memory_in_bytes", None),
        }
    except Exception:
        pass

    return Roofline(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        analytic_flops=analytic_flops,
        hlo_flops_raw=raw["flops"],
        hlo_bytes=bytes_, hlo_bytes_raw=raw["bytes"],
        collective_bytes=coll_b, collective_bytes_raw=raw["coll_bytes"],
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        bottleneck=bottleneck,
        model_flops=model_flops,
        useful_ratio=(model_flops / analytic_flops) if analytic_flops else 0.0,
        collectives={"counts": raw["coll_counts"], "bytes": raw["coll_by_op"]},
        memory_per_device=mem,
    )


def flops_analytic(cfg, shape, kind: str, *, remat: bool = True,
                   window_override: int = 0,
                   moe_group: int = 0, moe_cap: float = 0.0) -> float:
    """Exact closed-form FLOPs of the model AS WRITTEN (global, all chips).

    Why analytic: XLA's HLO cost analysis counts while-loop (scan) bodies ONCE,
    not × trip-count (verified empirically — see EXPERIMENTS.md §Dry-run), and
    both the layer scan and the flash-attention chunk scans are loops. We control
    every einsum in the model, so the closed form is exact; the raw
    cost_analysis numbers are reported alongside for transparency.

    Conventions: FLOPs = 2·MACs; flash attention computes full S per query
    (masked blocks included — that's the real chip work); train multiplier ×4 on
    layers (fwd 1, bwd 2, remat re-forward 1; ×3 without remat), ×3 on lm_head
    (never rematerialised).
    """
    B, S = shape.global_batch, shape.seq_len
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, Hkv = cfg.num_heads, cfg.num_kv_heads
    if kind == "train":
        tokens, s_att = B * S, S
    elif kind == "prefill":
        tokens, s_att = B * S, S
    else:  # decode: one token per sequence; attends over the whole cache
        tokens, s_att = B, S

    per_tok = 0.0
    win = window_override or cfg.sliding_window
    for t in cfg.layer_types:
        if t in ("attn", "swa"):
            s_eff = min(win, s_att) if (t == "swa" and win) else s_att
            per_tok += 2 * d * (2 * H * hd + 2 * Hkv * hd)  # qkvo projections
            per_tok += 2 * 2 * H * hd * s_eff  # scores + AV
            if cfg.num_experts:
                E, K, f = cfg.num_experts, cfg.num_experts_per_tok, cfg.moe_d_ff
                g = min(moe_group or cfg.moe_group_size, tokens)
                moe_cap = moe_cap or cfg.moe_capacity_factor
                C = min(max(int(g * K / E * moe_cap), 4) + 3 & ~3, g)
                per_tok += 2 * 3 * d * f * K  # routed experts
                per_tok += 2 * 2 * E * C * d  # dispatch + combine einsums
                per_tok += 2 * d * E  # router
                if cfg.num_shared_experts:
                    per_tok += 2 * 3 * d * (f * cfg.num_shared_experts)
            else:
                per_tok += 2 * 3 * d * cfg.d_ff
        elif t == "rec":
            W = cfg.rglru_width or d
            nh = max(cfg.num_heads, 1)
            per_tok += 2 * 2 * d * W + 2 * W * d  # in projs + out proj
            per_tok += 2 * cfg.conv_kernel * W
            per_tok += 2 * 2 * W * (W // nh)  # block-diagonal gates
            per_tok += 2 * 3 * d * cfg.d_ff
        elif t == "ssd":
            di, ns, ng = cfg.d_inner, cfg.ssm_state, cfg.ssm_ngroups
            nh, p = cfg.ssm_nheads, cfg.ssm_head_dim
            Q = min(128, s_att)
            per_tok += 2 * d * (2 * di + 2 * ng * ns + nh)  # in_proj
            per_tok += 2 * cfg.conv_kernel * (di + 2 * ng * ns)
            if kind == "decode":
                per_tok += 2 * nh * p * ns * 2  # state update + readout
            else:
                per_tok += 2 * Q * nh * ns + 2 * Q * nh * p  # intra-chunk
                per_tok += 2 * 2 * nh * p * ns  # states + off-diag
            per_tok += 2 * di * d  # out_proj
    head = 2 * d * cfg.vocab_size  # lm_head / tied unembed, per token

    if kind == "train":
        mult_layers = 4.0 if remat else 3.0
        return tokens * (per_tok * mult_layers + head * 3.0)
    return tokens * (per_tok + head)


def model_flops_for(cfg, shape, kind: str) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE); decode counts D=1 new token/seq."""
    n = cfg.active_param_count()
    if kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch
