"""Composable federation-transport channels.

The paper treats the KV cache as a *communicated object* — quantised,
privacy-filtered, and scheduled under QoS — so the wire gets its own
abstraction. A :class:`Channel` turns a :class:`Message` (KV stack and/or
token ids) into its on-the-wire form and back:

    encode(msg) -> wire msg        (what the transmitter ships)
    decode(wire msg) -> msg        (what the receiver reconstructs)
    bytes_on_wire(wire msg) -> int (what the link model charges)

Channels compose with :class:`Pipeline` (encode left→right, decode
right→left), so ``Pipeline([RephraseChannel(...), QuantChannel()])`` is
"privacy-rephrase the tokens, then int8-compress the KV stack" — the full
FedRefine wire stack in one object. Byte accounting is derived from the
encoded message itself (every array leaf's nbytes), which makes
core/commload.py's analytic per-token numbers a *checked* property
(tests/test_transport.py) instead of a parallel bookkeeping system.

Lossiness is part of the contract: ``QuantChannel`` round-trips values only
approximately (int8), ``RephraseChannel`` deliberately does not invert (the
privacy point of rephrasing) — but every channel must round-trip *shapes and
dtypes* exactly, the invariant the property tests pin.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.core.privacy import ParaphraseChannel
from repro.models.cache import KVStack, pytree_dataclass, tree_bytes

# Wire cost of one token id (the paper counts 4 B/token/model; commload.py).
TOKEN_WIRE_BYTES = 4


# ------------------------------------------------------------------ message


@pytree_dataclass(["stack", "tokens", "payload"])
@dataclass
class Message:
    """One federation transmission: an optional KV ``stack`` (the C2C medium),
    optional ``tokens`` (the T2T / prompt medium), and a ``payload`` dict of
    codec-specific wire tensors (e.g. the int8 form the stack was encoded to).
    """

    stack: Optional[KVStack] = None
    tokens: Optional[jax.Array] = None
    payload: dict = field(default_factory=dict)

    @property
    def nbytes(self) -> int:
        """Wire bytes of this message as-is: every array leaf at its dtype
        width (int32 token ids are exactly commload's 4 B/token)."""
        return tree_bytes(self)

    def replace(self, **kw: Any) -> "Message":
        return dataclasses.replace(self, **kw)


# ------------------------------------------------------------------ channels


class Channel:
    """Transport codec interface. Subclasses override encode/decode; both
    must preserve the shapes/dtypes of whatever they reconstruct."""

    def encode(self, msg: Message) -> Message:
        return msg

    def decode(self, msg: Message) -> Message:
        return msg

    def bytes_on_wire(self, msg: Message) -> int:
        """Bytes the link carries for an already-``encode``-d message."""
        return msg.nbytes

    def transmit(self, msg: Message) -> Tuple[Message, int]:
        """Convenience: encode, account, decode. Returns (received, bytes)."""
        wire = self.encode(msg)
        return self.decode(wire), self.bytes_on_wire(wire)


class IdentityChannel(Channel):
    """Raw transmission: stacks ship at their storage dtype, tokens at
    TOKEN_WIRE_BYTES each. bytes_on_wire reproduces commload.py's analytic
    c2c/t2t numbers exactly (pinned by tests/test_transport.py)."""


class QuantChannel(Channel):
    """int8 KV-stack codec (wraps core/quant.py): the stack is replaced on the
    wire by its int8 payload + fp32 scales; decode reconstructs a stack of the
    original shape AND dtype (the source dtype rides along as a zero-byte
    marker array; pass ``dtype=`` to force a different reconstruction dtype).
    Tokens and other payload pass through."""

    def __init__(self, dtype: Any = None) -> None:
        self.dtype = dtype

    def encode(self, msg: Message) -> Message:
        if msg.stack is None:
            return msg
        q = quant.quantize_stack(msg.stack)
        marker = jnp.zeros((0,), msg.stack.k.dtype)  # 0 wire bytes
        return msg.replace(stack=None,
                           payload={**msg.payload, "kv_int8": q,
                                    "kv_dtype": marker})

    def decode(self, msg: Message) -> Message:
        q = msg.payload.get("kv_int8")
        if q is None:
            return msg
        dtype = self.dtype
        if dtype is None:
            marker = msg.payload.get("kv_dtype")
            dtype = marker.dtype if marker is not None else jnp.bfloat16
        payload = {k: v for k, v in msg.payload.items()
                   if k not in ("kv_int8", "kv_dtype")}
        return msg.replace(stack=quant.dequantize_stack(q, dtype),
                           payload=payload)


class RephraseChannel(Channel):
    """Privacy transform on the token medium (wraps core/privacy.py): tokens
    are rephrased *before* transmission so raw user intent never crosses the
    link. Deliberately non-invertible — decode is the identity; what the
    receiver gets IS the privacy-filtered surface form. Shape/dtype and
    synonym-class semantics are preserved (privacy.py invariants).

    Stateful by design: each encode folds a call counter into the base key,
    so repeated transmissions (and different transmitters sharing one
    pipeline) draw *distinct* rephrasings — reusing one draw would collapse
    the transmitter diversity the gating network is trained against."""

    def __init__(self, paraphraser: ParaphraseChannel, key: jax.Array) -> None:
        self.paraphraser = paraphraser
        self.key = key
        self._calls = 0

    def encode(self, msg: Message) -> Message:
        if msg.tokens is None:
            return msg
        self._calls += 1
        key = jax.random.fold_in(self.key, self._calls)
        return msg.replace(tokens=self.paraphraser.rephrase(msg.tokens, key))


class Pipeline(Channel):
    """Channel composition: encode applies channels left→right, decode
    right→left (codec nesting order). bytes_on_wire is the final encoded
    message's — i.e. what actually crosses the link."""

    def __init__(self, channels: Sequence[Channel]) -> None:
        self.channels = list(channels)

    def encode(self, msg: Message) -> Message:
        for ch in self.channels:
            msg = ch.encode(msg)
        return msg

    def decode(self, msg: Message) -> Message:
        for ch in reversed(self.channels):
            msg = ch.decode(msg)
        return msg


# ------------------------------------------------------------------ helpers


def stack_message(stack: Any) -> Message:
    return Message(stack=KVStack.ensure(stack))


def token_message(tokens: jax.Array) -> Message:
    return Message(tokens=jnp.asarray(tokens, jnp.int32))


# ------------------------------------------------------------- codec registry


def _rephrase_codec(*, vocab: int, class_width: int,
                    key: jax.Array) -> Channel:
    from repro.core.privacy import synonym_channel

    return RephraseChannel(synonym_channel(vocab, class_width, key), key)


# Named wire codecs (every entry is round-trip- and byte-tested by
# tests/test_transport.py against commload's analytic numbers).
CODECS: Dict[str, Callable[..., Channel]] = {
    "identity": lambda **kw: IdentityChannel(),
    "int8": lambda **kw: QuantChannel(),
    "rephrase": lambda **kw: _rephrase_codec(**kw),
    "rephrase+int8": lambda **kw: Pipeline([_rephrase_codec(**kw),
                                            QuantChannel()]),
}


def make_codec(name: str, *, vocab: int = 256, class_width: int = 4,
               key: Optional[jax.Array] = None) -> Channel:
    """Build a named wire codec. ``vocab``/``class_width``/``key`` feed the
    rephrase stage (ignored by purely tensor codecs)."""
    if name not in CODECS:
        raise ValueError(f"unknown codec {name!r}; have {sorted(CODECS)}")
    if key is None:
        key = jax.random.PRNGKey(0)
    return CODECS[name](vocab=vocab, class_width=class_width, key=key)
