"""Text-to-text (T2T) collaboration baseline.

The transmitter *generates tokens* from its (rephrased) prompt; those tokens are
shipped as text and the receiver must re-prefill them — rebuilding a KV cache from
scratch, which is exactly the latency the paper's C2C avoids. Accuracy-wise T2T
loses the transmitter's internal (cache-level) semantics; the case study measures
both effects.

These are the generation primitives; the end-to-end request path (latency
model + transmit + combined-prompt construction) is ``core/protocol.T2T``.
"""
from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.c2c import generate
from repro.models import transformer as T


def t2t_exchange(
    cfg_tx: ModelConfig,
    params_tx: dict,
    tx_prompt: jax.Array,  # (B, S_t) transmitter-side (rephrased) prompt
    gen_steps: int,
) -> jax.Array:
    """Transmitter produces its contribution as tokens. Returns (B, gen_steps)."""
    return generate(cfg_tx, params_tx, tx_prompt, gen_steps)


def t2t_forward(
    cfg_rx: ModelConfig,
    params_rx: dict,
    rx_prompt: jax.Array,  # (B, S_r)
    shared_tokens: List[jax.Array],  # per transmitter: (B, S_shared)
) -> Tuple[jax.Array, jax.Array]:
    """Receiver re-prefills [tx outputs ‖ own prompt] — the full-prefill cost is
    incurred here. Returns (logits over combined seq, combined tokens)."""
    combined = jnp.concatenate([*shared_tokens, rx_prompt], axis=1)
    logits, _ = T.forward(cfg_rx, params_rx, combined)
    return logits, combined


def t2t_generate(
    cfg_rx: ModelConfig,
    params_rx: dict,
    rx_prompt: jax.Array,
    shared_tokens: List[jax.Array],
    steps: int,
) -> jax.Array:
    combined = jnp.concatenate([*shared_tokens, rx_prompt], axis=1)
    return generate(cfg_rx, params_rx, combined, steps)


def t2t_prefill_tokens(rx_prompt_len: int, shared_lens: List[int]) -> int:
    """Receiver-side prefill length (the latency term C2C skips)."""
    return rx_prompt_len + sum(shared_lens)
