"""FedRefine — the paper's federated-inference orchestrator (Fig. 2, Eq. 4).

A ``FedRefineSystem`` holds N heterogeneous participants, the server-side fuser
registry, per-receiver gating networks, and a task-affinity scheduler ("the
receiver model selects different model combinations according to the different
tasks", §Case Study). One refined inference:

  1. privacy: every participant receives its own rephrased prompt,
  2. transmitters prefill locally and export their KV stacks,
  3. the stacks cross the federation ``wire`` (core/transport.py channel:
     identity, int8, or a composed pipeline) — byte-accounted per request,
  4. the server (here: receiver-side) projects each stack through F_{j,i},
  5. gating weighs each fused cache,
  6. the receiver decodes per Eq. 4 over [fused_1 ∘ … ∘ fused_s ∘ own].

Protocol mechanics (how a request becomes engine inputs) live in
``core/protocol.PROTOCOLS`` — this orchestrator only schedules transmitters,
owns the participants/registry/wire, and drives the engines.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import c2c
from repro.core import transport as TR
from repro.core.privacy import ParaphraseChannel
from repro.core.protocol import PROTOCOLS
from repro.core.registry import FuserRegistry
from repro.models import transformer as T
from repro.models.cache import FusedPrefix, KVStack


@dataclass
class Participant:
    name: str
    cfg: ModelConfig
    params: dict


@dataclass
class FedRefineSystem:
    participants: Dict[str, Participant]
    registry: FuserRegistry
    channel: Optional[ParaphraseChannel] = None
    # on-the-wire codec for transmitted KV stacks (core/transport.py);
    # IdentityChannel ships raw bf16/fp32, QuantChannel ships int8+scales.
    wire: TR.Channel = field(default_factory=TR.IdentityChannel)
    # task -> preferred transmitter names, best first (the case-study prior)
    task_affinity: Dict[str, List[str]] = field(default_factory=dict)
    # receiver name -> continuous-batching engine (see make_engine/submit/drain)
    engines: Dict[str, object] = field(default_factory=dict)

    # ------------------------------------------------------------- setup
    @classmethod
    def build(cls, members: Sequence[Participant],
              channel: Optional[ParaphraseChannel] = None,
              wire: Optional[TR.Channel] = None, *,
              audit_wire: bool = False,
              wire_schemas: Optional[dict] = None) -> "FedRefineSystem":
        """``audit_wire=True`` wraps the wire in a
        :class:`~repro.analysis.wire_audit.WireAuditor`: every transmitted
        message is verified against the protocol's declared WireSchema
        (media, dtypes, codec stages, commload byte accounting) and
        violations raise naming the producing call site. ``wire_schemas``
        overrides the registry defaults (else they are derived from the
        wire's codec composition)."""
        reg = FuserRegistry({m.name: m.cfg for m in members})
        reg.ensure_all_pairs()
        wire = wire or TR.IdentityChannel()
        if audit_wire:
            from repro.analysis.wire_audit import WireAuditor

            wire = WireAuditor(wire, schemas=wire_schemas)
        return cls({m.name: m for m in members}, reg, channel, wire)

    # ------------------------------------------------------------- scheduling
    def schedule(self, task: str, receiver: str, n_tx: int) -> List[str]:
        """Pick transmitters for ``task`` (affinity order, else registry order)."""
        prefs = self.task_affinity.get(task, [])
        cands = [n for n in prefs if n != receiver and n in self.participants]
        cands += [n for n in self.participants
                  if n != receiver and n not in cands
                  and (n, receiver) in self.registry.fusers]
        return [n for n in cands if (n, receiver) in self.registry.fusers][:n_tx]

    # ------------------------------------------------------------- inference
    def rephrase(self, tokens: jax.Array, key) -> jax.Array:
        if self.channel is None:
            return tokens
        return self.channel.rephrase(tokens, key)

    def transmit_stacks(self, tx_names: List[str],
                        prompts: Dict[str, jax.Array]
                        ) -> Tuple[List[KVStack], int]:
        """Steps 2–3: local prefill at each transmitter; export KV stacks and
        ship them through the wire channel. Returns (received stacks, total
        bytes the link carried)."""
        if hasattr(self.wire, "expect"):  # WireAuditor: declare the protocol
            self.wire.expect(protocol="c2c")
        stacks, wire_bytes = [], 0
        for n in tx_names:
            p = self.participants[n]
            S = prompts[n].shape[1]
            _, cache = T.prefill(p.cfg, p.params, prompts[n], max_seq=S)
            msg = TR.stack_message(cache.export_stack(p.cfg, length=S))
            received, nbytes = self.wire.transmit(msg)
            stacks.append(received.stack)
            wire_bytes += nbytes
        return stacks, wire_bytes

    def fused_prefix(self, receiver: str, tx_names: List[str],
                     stacks: List[KVStack], *, gated: bool = True,
                     use_kernel: bool = False) -> FusedPrefix:
        rxp = self.participants[receiver]
        fusers = [self.registry.get(n, receiver) for n in tx_names]
        cfg_txs = [self.participants[n].cfg for n in tx_names]
        gating = self.registry.ensure_gating(receiver) if gated else None
        return c2c.fused_prefix(fusers, cfg_txs, rxp.cfg, stacks,
                                gating=gating, use_kernel=use_kernel)

    def refine_generate(
        self,
        receiver: str,
        prompt: jax.Array,  # receiver-side (already rephrased) prompt (B, S)
        steps: int,
        *,
        task: str = "default",
        n_tx: int = 1,
        tx_prompts: Optional[Dict[str, jax.Array]] = None,
        key: Optional[jax.Array] = None,
        gated: bool = True,
    ) -> dict:
        """Full FedRefine inference (Eq. 4). Returns tokens + diagnostics."""
        key = key if key is not None else jax.random.PRNGKey(0)
        tx_names = self.schedule(task, receiver, n_tx)
        rxp = self.participants[receiver]
        proto = PROTOCOLS["c2c" if tx_names else "standalone"]
        prep = proto.prepare(self, receiver, prompt, tx_names, steps=steps,
                             key=key, gated=gated, tx_prompts=tx_prompts)
        toks = c2c.generate(rxp.cfg, rxp.params, prep.prompt, steps,
                            fused=prep.fused)
        return {
            "tokens": toks,
            "transmitters": tx_names,
            "c2c_bytes": prep.wire_bytes,
        }

    # ------------------------------------------------- continuous serving
    def make_engine(self, receiver: str, *, max_slots: int = 8,
                    max_seq: int = 128, max_prefix: int = 32,
                    cache_dtype=None, prompt_bucket: Optional[int] = None,
                    **engine_kw):
        """Build (and register) the receiver's continuous-batching engine.

        All protocols share it: standalone and T2T requests decode alongside
        C2C-fused ones in the same slot table (launch/engine.py). Extra
        keywords (``paged=True``, ``page_size=``, ``num_pages=``,
        ``admit_batch=``, ``sanitize=True`` for the page-lifecycle
        sanitizer) reach the engine unchanged."""
        from repro.launch.engine import ContinuousBatchingEngine

        rxp = self.participants[receiver]
        eng = ContinuousBatchingEngine(
            rxp.cfg, rxp.params, max_slots=max_slots, max_seq=max_seq,
            max_prefix=max_prefix,
            cache_dtype=cache_dtype if cache_dtype is not None else jnp.float32,
            prompt_bucket=prompt_bucket, **engine_kw)
        self.engines[receiver] = eng
        return eng

    def submit(self, receiver: str, prompt: jax.Array, steps: int, *,
               protocol: str = "c2c", task: str = "default", n_tx: int = 1,
               tx_prompts: Optional[Dict[str, jax.Array]] = None,
               key: Optional[jax.Array] = None, gated: bool = True) -> int:
        """Queue one request (B=1) into the receiver's engine; returns its rid.

        ``prompt`` is the receiver-side (already rephrased) prompt, as in
        refine_generate; pass ``tx_prompts`` to give each transmitter its own
        rephrasing of the *original* prompt (otherwise the receiver prompt is
        re-rephrased, compounding paraphrase noise on non-idempotent channels).

        ``protocol`` names an entry of core/protocol.PROTOCOLS ("c2c", "t2t",
        "standalone"). An explicit protocol that needs transmitters but has no
        schedulable one raises rather than silently degrading to standalone.
        Requests of all kinds coexist in one decode batch; drain() (or
        engine.step()) runs them to completion."""
        if protocol not in PROTOCOLS:
            raise ValueError(f"unknown protocol {protocol!r}; "
                             f"have {sorted(PROTOCOLS)}")
        proto = PROTOCOLS[protocol]
        eng = self.engines.get(receiver) or self.make_engine(receiver)
        key = key if key is not None else jax.random.PRNGKey(0)
        prompt = jnp.asarray(prompt, jnp.int32)
        if prompt.ndim == 1:
            prompt = prompt[None]
        tx_names = (self.schedule(task, receiver, n_tx)
                    if proto.needs_transmitters() else [])
        if proto.needs_transmitters() and not tx_names:
            raise ValueError(
                f"protocol {protocol!r} requested but no transmitter with a "
                f"fuser for receiver {receiver!r} is schedulable; submit with "
                f"protocol='standalone' to run unrefined")
        prep = proto.prepare(self, receiver, prompt, tx_names, steps=steps,
                             key=key, gated=gated, tx_prompts=tx_prompts)
        return eng.submit(prep.prompt, steps, fused=prep.fused,
                          protocol=proto.name,
                          meta={"transmitters": tx_names,
                                "wire_bytes": prep.wire_bytes}
                          if tx_names else {})

    def drain(self, receiver: str) -> Dict[int, dict]:
        """Run the receiver's engine until idle; {rid: completion dict}."""
        eng = self.engines[receiver]
        return {
            c.rid: {"tokens": c.tokens, "protocol": c.protocol, **c.meta}
            for c in eng.drain()
        }

    # ---------------------------------------------------- opportunistic serve
    def serve_opportunistic(
        self,
        receiver: str,
        prompt: jax.Array,
        steps: int,
        *,
        link,  # core.protocol.LinkModel
        qos,  # core.protocol.QoS
        task: str = "default",
        n_tx: int = 1,
        key: Optional[jax.Array] = None,
    ) -> dict:
        """Paper §Possible Variants: pick C2C vs T2T vs standalone per the
        current link + QoS, then execute that protocol end to end."""
        from repro.core import protocol as P

        key = key if key is not None else jax.random.PRNGKey(0)
        tx_names = self.schedule(task, receiver, n_tx)
        rxp = self.participants[receiver]
        cfg_txs = [self.participants[n].cfg for n in tx_names]
        decision = P.choose_protocol(
            cfg_txs, rxp.cfg, seq=int(prompt.shape[1]), gen_steps=steps,
            link=link, qos=qos)
        proto = PROTOCOLS[decision["protocol"] if tx_names else "standalone"]
        if hasattr(self.wire, "set_budget"):  # WireAuditor: QoS byte ceiling
            budget = link.bandwidth_bps * qos.max_latency_s
            self.wire.set_budget(
                int(budget) if math.isfinite(budget) else None)
        prep = proto.prepare(self, receiver, prompt, tx_names, steps=steps,
                             key=key)
        toks = c2c.generate(rxp.cfg, rxp.params, prep.prompt, steps,
                            fused=prep.fused)
        return {"tokens": toks, "protocol": proto.name, "decision": decision,
                "transmitters": prep.transmitters,
                "wire_bytes": prep.wire_bytes}
