"""FedRefine — the paper's federated-inference orchestrator (Fig. 2, Eq. 4).

A ``FedRefineSystem`` holds N heterogeneous participants, the server-side fuser
registry, per-receiver gating networks, and a task-affinity scheduler ("the
receiver model selects different model combinations according to the different
tasks", §Case Study). One refined inference:

  1. privacy: every participant receives its own rephrased prompt,
  2. transmitters prefill locally and export their KV stacks,
  3. the server (here: receiver-side) projects each stack through F_{j,i},
  4. gating weighs each fused cache,
  5. the receiver decodes per Eq. 4 over [fused_1 ∘ … ∘ fused_s ∘ own].
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import c2c
from repro.core.privacy import ParaphraseChannel
from repro.core.registry import FuserRegistry
from repro.models import transformer as T
from repro.models.cache import attn_kv_stack


@dataclass
class Participant:
    name: str
    cfg: ModelConfig
    params: dict


@dataclass
class FedRefineSystem:
    participants: Dict[str, Participant]
    registry: FuserRegistry
    channel: Optional[ParaphraseChannel] = None
    # task -> preferred transmitter names, best first (the case-study prior)
    task_affinity: Dict[str, List[str]] = field(default_factory=dict)
    # receiver name -> continuous-batching engine (see make_engine/submit/drain)
    engines: Dict[str, object] = field(default_factory=dict)

    # ------------------------------------------------------------- setup
    @classmethod
    def build(cls, members: Sequence[Participant],
              channel: Optional[ParaphraseChannel] = None) -> "FedRefineSystem":
        reg = FuserRegistry({m.name: m.cfg for m in members})
        reg.ensure_all_pairs()
        return cls({m.name: m for m in members}, reg, channel)

    # ------------------------------------------------------------- scheduling
    def schedule(self, task: str, receiver: str, n_tx: int) -> List[str]:
        """Pick transmitters for ``task`` (affinity order, else registry order)."""
        prefs = self.task_affinity.get(task, [])
        cands = [n for n in prefs if n != receiver and n in self.participants]
        cands += [n for n in self.participants
                  if n != receiver and n not in cands
                  and (n, receiver) in self.registry.fusers]
        return [n for n in cands if (n, receiver) in self.registry.fusers][:n_tx]

    # ------------------------------------------------------------- inference
    def rephrase(self, tokens: jax.Array, key) -> jax.Array:
        if self.channel is None:
            return tokens
        return self.channel.rephrase(tokens, key)

    def transmit_stacks(self, tx_names: List[str], prompts: Dict[str, jax.Array]):
        """Step 2: local prefill at each transmitter; export KV stacks."""
        stacks = []
        for n in tx_names:
            p = self.participants[n]
            S = prompts[n].shape[1]
            _, cache = T.prefill(p.cfg, p.params, prompts[n], max_seq=S)
            stacks.append(attn_kv_stack(p.cfg, cache, length=S))
        return stacks

    def fused_prefix(self, receiver: str, tx_names: List[str],
                     stacks: List[dict], *, gated: bool = True,
                     use_kernel: bool = False) -> dict:
        rxp = self.participants[receiver]
        fusers = [self.registry.get(n, receiver) for n in tx_names]
        cfg_txs = [self.participants[n].cfg for n in tx_names]
        gating = self.registry.ensure_gating(receiver) if gated else None
        return c2c.fused_prefix(fusers, cfg_txs, rxp.cfg, stacks,
                                gating=gating, use_kernel=use_kernel)

    def refine_generate(
        self,
        receiver: str,
        prompt: jax.Array,  # receiver-side (already rephrased) prompt (B, S)
        steps: int,
        *,
        task: str = "default",
        n_tx: int = 1,
        tx_prompts: Optional[Dict[str, jax.Array]] = None,
        key: Optional[jax.Array] = None,
        gated: bool = True,
    ) -> dict:
        """Full FedRefine inference (Eq. 4). Returns tokens + diagnostics."""
        key = key if key is not None else jax.random.PRNGKey(0)
        tx_names = self.schedule(task, receiver, n_tx)
        if tx_prompts is None:
            tx_prompts = {
                n: self.rephrase(prompt, jax.random.fold_in(key, i))
                for i, n in enumerate(tx_names)
            }
        stacks = self.transmit_stacks(tx_names, tx_prompts)
        rxp = self.participants[receiver]
        if tx_names:
            fused = self.fused_prefix(receiver, tx_names, stacks, gated=gated)
            toks = c2c.generate(rxp.cfg, rxp.params, prompt, steps, fused=fused)
        else:
            toks = c2c.generate(rxp.cfg, rxp.params, prompt, steps)
        from repro.core import commload
        return {
            "tokens": toks,
            "transmitters": tx_names,
            "c2c_bytes": sum(
                commload.c2c_bytes_per_token(self.participants[n].cfg)
                for n in tx_names),
        }

    # ------------------------------------------------- continuous serving
    def make_engine(self, receiver: str, *, max_slots: int = 8,
                    max_seq: int = 128, max_prefix: int = 32,
                    cache_dtype=None, prompt_bucket: Optional[int] = None):
        """Build (and register) the receiver's continuous-batching engine.

        All protocols share it: standalone and T2T requests decode alongside
        C2C-fused ones in the same slot table (launch/engine.py)."""
        import jax.numpy as jnp
        from repro.launch.engine import ContinuousBatchingEngine

        rxp = self.participants[receiver]
        eng = ContinuousBatchingEngine(
            rxp.cfg, rxp.params, max_slots=max_slots, max_seq=max_seq,
            max_prefix=max_prefix,
            cache_dtype=cache_dtype if cache_dtype is not None else jnp.float32,
            prompt_bucket=prompt_bucket)
        self.engines[receiver] = eng
        return eng

    def submit(self, receiver: str, prompt: jax.Array, steps: int, *,
               protocol: str = "c2c", task: str = "default", n_tx: int = 1,
               tx_prompts: Optional[Dict[str, jax.Array]] = None,
               key: Optional[jax.Array] = None, gated: bool = True) -> int:
        """Queue one request (B=1) into the receiver's engine; returns its rid.

        ``prompt`` is the receiver-side (already rephrased) prompt, as in
        refine_generate; pass ``tx_prompts`` to give each transmitter its own
        rephrasing of the *original* prompt (otherwise the receiver prompt is
        re-rephrased, compounding paraphrase noise on non-idempotent channels).

        ``protocol``: "c2c" (transmit + fuse a KV prefix), "t2t" (transmitters
        answer as text, prepended to the receiver prompt), or "standalone".
        An explicit "c2c"/"t2t" request with no schedulable transmitter raises
        rather than silently degrading to standalone. Requests of all three
        kinds coexist in one decode batch; drain() (or engine.step()) runs
        them to completion."""
        from repro.core import t2t

        eng = self.engines.get(receiver) or self.make_engine(receiver)
        key = key if key is not None else jax.random.PRNGKey(0)
        prompt = jnp.asarray(prompt, jnp.int32)
        if prompt.ndim == 1:
            prompt = prompt[None]
        tx_names = (self.schedule(task, receiver, n_tx)
                    if protocol != "standalone" else [])
        if protocol != "standalone" and not tx_names:
            raise ValueError(
                f"protocol {protocol!r} requested but no transmitter with a "
                f"fuser for receiver {receiver!r} is schedulable; submit with "
                f"protocol='standalone' to run unrefined")
        if protocol == "c2c":
            if tx_prompts is None:
                tx_prompts = {
                    n: self.rephrase(prompt, jax.random.fold_in(key, i))
                    for i, n in enumerate(tx_names)
                }
            stacks = self.transmit_stacks(tx_names, tx_prompts)
            fused = self.fused_prefix(receiver, tx_names, stacks, gated=gated)
            return eng.submit(prompt, steps, fused=fused, protocol="c2c",
                              meta={"transmitters": tx_names})
        if protocol == "t2t":
            shared = []
            for i, n in enumerate(tx_names):
                p = self.participants[n]
                tp = (tx_prompts[n] if tx_prompts is not None
                      else self.rephrase(prompt, jax.random.fold_in(key, i)))
                shared.append(t2t.t2t_exchange(p.cfg, p.params, tp, steps))
            combined = jnp.concatenate([*shared, prompt], axis=1)
            return eng.submit(combined, steps, protocol="t2t",
                              meta={"transmitters": tx_names})
        return eng.submit(prompt, steps, protocol="standalone")

    def drain(self, receiver: str) -> Dict[int, dict]:
        """Run the receiver's engine until idle; {rid: completion dict}."""
        eng = self.engines[receiver]
        return {
            c.rid: {"tokens": c.tokens, "protocol": c.protocol, **c.meta}
            for c in eng.drain()
        }

    # ---------------------------------------------------- opportunistic serve
    def serve_opportunistic(
        self,
        receiver: str,
        prompt: jax.Array,
        steps: int,
        *,
        link,  # core.protocol.LinkModel
        qos,  # core.protocol.QoS
        task: str = "default",
        n_tx: int = 1,
        key: Optional[jax.Array] = None,
    ) -> dict:
        """Paper §Possible Variants: pick C2C vs T2T vs standalone per the
        current link + QoS, then execute that protocol end to end."""
        from repro.core import protocol, t2t

        key = key if key is not None else jax.random.PRNGKey(0)
        tx_names = self.schedule(task, receiver, n_tx)
        rxp = self.participants[receiver]
        cfg_txs = [self.participants[n].cfg for n in tx_names]
        decision = protocol.choose_protocol(
            cfg_txs, rxp.cfg, seq=int(prompt.shape[1]), gen_steps=steps,
            link=link, qos=qos)
        proto = decision["protocol"] if tx_names else "standalone"

        if proto == "c2c":
            out = self.refine_generate(receiver, prompt, steps, task=task,
                                       n_tx=n_tx, key=key)
            toks = out["tokens"]
        elif proto == "t2t":
            shared = []
            for i, n in enumerate(tx_names):
                p = self.participants[n]
                tp = self.rephrase(prompt, jax.random.fold_in(key, i))
                shared.append(t2t.t2t_exchange(p.cfg, p.params, tp, steps))
            toks = t2t.t2t_generate(rxp.cfg, rxp.params, prompt, shared, steps)
        else:
            toks = c2c.generate(rxp.cfg, rxp.params, prompt, steps)
        return {"tokens": toks, "protocol": proto, "decision": decision,
                "transmitters": tx_names if proto != "standalone" else []}
