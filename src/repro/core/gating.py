"""Receiver-side gating network (paper: "a gating network is required for each LLM
to select the data from its own model or other fusers").

The gate scores each candidate fused cache from pooled (k̂, v̂) features and emits a
per-transmitter sigmoid weight in [0, 1]; weights scale the fused *value* pathway,
so a closed gate (w→0) reduces exactly to standalone inference — a property the
tests pin down. The receiver's own cache is the implicit unit-weight reference.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.cache import FusedPrefix


def init_gating(cfg_rx: ModelConfig, key, hidden: int = 128,
                dtype=jnp.float32) -> dict:
    d_feat = 2 * cfg_rx.kv_dim  # pooled k̂‖v̂ in receiver space
    k1, k2 = jax.random.split(key)
    return {
        "w1": L.init_linear(k1, d_feat, hidden, bias=True, dtype=dtype),
        "w2": L.init_linear(k2, hidden, 1, bias=True, dtype=dtype),
    }


def gate_weight(params: dict, fused) -> jax.Array:
    """Score one fused prefix/stack (n_rx, B, Hkv, S, hd) -> weight (B,)."""
    fused = FusedPrefix.ensure(fused)
    n, B, H, S, hd = fused.k.shape
    feat = jnp.concatenate(
        [
            fused.k.transpose(1, 0, 3, 2, 4).reshape(B, n, S, H * hd),
            fused.v.transpose(1, 0, 3, 2, 4).reshape(B, n, S, H * hd),
        ],
        axis=-1,
    ).mean(axis=(1, 2))  # (B, 2*kv_dim) pooled over layers and positions
    h = jax.nn.tanh(L.linear(params["w1"], feat.astype(jnp.float32)))
    return jax.nn.sigmoid(L.linear(params["w2"], h))[:, 0]  # (B,)


def apply_gates(params: dict, fused_stacks: List) -> List[FusedPrefix]:
    """Fold each transmitter's gate into its attention-logit bias: the fused
    tokens' attention mass is scaled by w (log-additive with the per-layer
    fuser gate); w→0 removes the transmitter exactly."""
    out = []
    for st in fused_stacks:
        st = FusedPrefix.ensure(st)
        w = gate_weight(params, st)  # (B,)
        log_w = jnp.log(jnp.maximum(w, 1e-30))[None, :, None]  # (1, B, 1)
        out.append(st.with_bias(st._bias_or_zero() + log_w))
    return out
