"""Fuser (+ gating) pre-training — "the pre-training of each fuser is conducted
separately for each pair of LLM collaboration" (paper §FedRefine, ref. Fu et al.).

Both endpoint models are FROZEN; only the fuser MLPs, per-layer gates and the
receiver's gating network train. The objective is teacher-forced LM loss of the
*receiver* decoding with the fused prefix visible:

    L(F_ij) = CE( P_j( y | C(F_ij, M_i) ∘ C(M_j) ), y* )

computed on a general corpus (paper: OpenHermes-2.5; here the synthetic
knowledge-partitioned stream). Because the transmitter prefill is loss-free, the
tx KV stack is computed under ``stop_gradient`` once per batch.
"""
from __future__ import annotations

from typing import Iterator, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import c2c
from repro.core import fuser as F
from repro.models import transformer as T
from repro.optim.adamw import AdamWConfig, apply_updates, init_opt_state


def fused_loss(
    fuser: dict,
    cfg_tx: ModelConfig,
    cfg_rx: ModelConfig,
    params_rx: dict,
    tx_stack: dict,
    tokens: jax.Array,
    labels: jax.Array,
    gating: Optional[dict] = None,
) -> jax.Array:
    """CE of the receiver with the fused prefix (models frozen)."""
    fused = F.project_cache(fuser, cfg_tx, cfg_rx, tx_stack)
    if gating is not None:
        from repro.core.gating import apply_gates
        fused = apply_gates(gating, [fused])[0]
    logits, _ = c2c.c2c_forward(cfg_rx, jax.lax.stop_gradient(params_rx),
                                tokens, fused)
    logits = logits.astype(jnp.float32)
    valid = labels >= 0
    safe = jnp.where(valid, labels, 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    return jnp.sum(nll * valid) / jnp.maximum(jnp.sum(valid), 1)


def make_fuser_train_step(cfg_tx: ModelConfig, cfg_rx: ModelConfig,
                          params_tx: dict, params_rx: dict,
                          opt_cfg: AdamWConfig, *, train_gating: bool = False):
    """Returns jit-ed ``step((fuser, gating), opt_state, batch) -> (..., loss)``.

    ``batch`` = {"tx_tokens", "rx_tokens", "labels"} — tx/rx see *different
    rephrasings* of the same example (the privacy-preserving regime)."""

    def loss_fn(trainable, batch):
        fuser, gating = trainable
        S = batch["tx_tokens"].shape[1]
        _, tx_cache = T.prefill(cfg_tx, jax.lax.stop_gradient(params_tx),
                                batch["tx_tokens"], max_seq=S)
        tx_stack = jax.lax.stop_gradient(tx_cache.export_stack(cfg_tx, length=S))
        return fused_loss(fuser, cfg_tx, cfg_rx, params_rx, tx_stack,
                          batch["rx_tokens"], batch["labels"],
                          gating if train_gating else None)

    @jax.jit
    def step(trainable, opt_state, batch):
        # allow_int: the fuser carries an int32 alignment table (non-trainable;
        # the optimizer skips non-float leaves)
        loss, grads = jax.value_and_grad(loss_fn, allow_int=True)(trainable, batch)
        new_t, new_s = apply_updates(opt_cfg, trainable, grads, opt_state)
        return new_t, new_s, loss

    return step


def train_fuser(
    cfg_tx: ModelConfig,
    cfg_rx: ModelConfig,
    params_tx: dict,
    params_rx: dict,
    batches: Iterator[dict],
    steps: int,
    *,
    key=None,
    lr: float = 3e-4,
    gating: Optional[dict] = None,
    log_every: int = 50,
    verbose: bool = False,
) -> Tuple[dict, Optional[dict], list]:
    """Convenience driver. Returns (fuser, gating, loss history)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    fuser = F.init_fuser(cfg_tx, cfg_rx, key)
    opt_cfg = AdamWConfig(lr=lr, schedule="cosine", total_steps=steps)
    trainable = (fuser, gating)
    opt_state = init_opt_state(trainable)
    step_fn = make_fuser_train_step(cfg_tx, cfg_rx, params_tx, params_rx,
                                    opt_cfg, train_gating=gating is not None)
    hist = []
    for i in range(steps):
        batch = next(batches)
        trainable, opt_state, loss = step_fn(trainable, opt_state, batch)
        hist.append(float(loss))
        if verbose and (i % log_every == 0 or i == steps - 1):
            print(f"  fuser[{cfg_tx.name}->{cfg_rx.name}] step {i:4d} loss {loss:.4f}")
    return trainable[0], trainable[1], hist
