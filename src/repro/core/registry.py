"""Server-side fuser registry (paper Fig. 2: "the server maintains all pre-trained
fusers {F_12, F_21, …, F_1N, F_N1}").

Keys are ordered (transmitter, receiver) name pairs; ``ensure_pair`` materialises a
bidirectional link i↔j by creating both F_ij and F_ji (Co-C2C). Checkpointing uses
checkpoint/checkpoint.py so a deployment can restart with its trained fusers.
"""
from __future__ import annotations

from typing import Dict, Iterable, Optional, Tuple

import jax

from repro.configs.base import ModelConfig
from repro.core import fuser as F


class FuserRegistry:
    def __init__(self, models: Dict[str, ModelConfig]):
        self.models = dict(models)
        self.fusers: Dict[Tuple[str, str], dict] = {}
        self.gating: Dict[str, dict] = {}  # per receiver

    # ------------------------------------------------------------- creation
    def ensure_fuser(self, tx: str, rx: str, key=None, **kw) -> dict:
        if (tx, rx) not in self.fusers:
            key = key if key is not None else jax.random.PRNGKey(hash((tx, rx)) % (2**31))
            self.fusers[(tx, rx)] = F.init_fuser(self.models[tx], self.models[rx],
                                                 key, **kw)
        return self.fusers[(tx, rx)]

    def ensure_pair(self, i: str, j: str, key=None, **kw) -> Tuple[dict, dict]:
        """Bidirectional link i↔j (Co-C2C needs both directions)."""
        return self.ensure_fuser(i, j, key, **kw), self.ensure_fuser(j, i, key, **kw)

    def ensure_all_pairs(self, names: Optional[Iterable[str]] = None, **kw) -> None:
        """Full N·(N−1) fuser matrix of Fig. 2."""
        names = list(names or self.models)
        for i in names:
            for j in names:
                if i != j:
                    try:
                        self.ensure_fuser(i, j, **kw)
                    except F.InapplicableError:
                        pass  # attention-free members simply have no KV links

    def ensure_gating(self, rx: str, key=None) -> dict:
        from repro.core.gating import init_gating
        if rx not in self.gating:
            key = key if key is not None else jax.random.PRNGKey(hash(rx) % (2**31))
            self.gating[rx] = init_gating(self.models[rx], key)
        return self.gating[rx]

    # ------------------------------------------------------------- access
    def get(self, tx: str, rx: str) -> dict:
        return self.fusers[(tx, rx)]

    def links(self) -> list:
        return sorted(self.fusers)

    # ------------------------------------------------------------- persistence
    def save(self, path: str) -> None:
        from repro.checkpoint.checkpoint import save_pytree
        blob = {
            "fusers": {f"{t}␟{r}": p for (t, r), p in self.fusers.items()},
            "gating": self.gating,
        }
        save_pytree(path, blob)

    def load(self, path: str) -> None:
        from repro.checkpoint.checkpoint import load_pytree
        blob = load_pytree(path)
        self.fusers = {tuple(k.split("␟")): v
                       for k, v in blob.get("fusers", {}).items()}
        self.gating = blob.get("gating", {})
