"""BEYOND-PAPER EXTENSION — int8-quantised cache communication.

The paper's central cost asymmetry is 88 KB/token (C2C) vs 16 B/token (T2T).
Symmetric per-(layer, head, dim)-channel int8 quantisation of the transmitted
KV stack halves the wire bytes (bf16 → int8 + amortised fp32 scales) AND halves
the receiver-side HBM reads of the fused prefix during decode — the dominant
roofline term after the C1/C2 optimisations (EXPERIMENTS.md §Perf pair C).

Scales are computed over the sequence axis (the only axis that grows), so the
per-token overhead is O(1/S) and the asymptotic compression is exactly 2×.
Accuracy impact is measured in the case study (tests/test_quant.py pins the
round-trip error; benchmarks report the end-task delta).

This module is the *codec backend*; the composable wire abstraction lives in
core/transport.py (``QuantChannel`` wraps these functions into the ``Channel``
protocol).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.cache import KVStack, pytree_dataclass, tree_bytes


@pytree_dataclass(["k_q", "v_q", "k_scale", "v_scale"])
@dataclass
class QuantizedKV:
    """int8 wire representation of a :class:`KVStack`: int8 payload + fp32
    per-(layer, head, dim)-channel scales (n, B, H, 1, hd)."""

    k_q: jax.Array
    v_q: jax.Array
    k_scale: jax.Array
    v_scale: jax.Array

    def __getitem__(self, key: str) -> jax.Array:  # legacy dict interop
        import warnings

        warnings.warn(
            "QuantizedKV dict-style access is deprecated; use attribute "
            "access (qkv.k_q) instead", DeprecationWarning, stacklevel=2)
        return getattr(self, key)

    @property
    def nbytes(self) -> int:
        return tree_bytes(self)


def quantize_stack(stack: Any) -> QuantizedKV:
    """Quantise a KV stack (n, B, H, S, hd) to int8 + fp32 scales."""
    stack = KVStack.ensure(stack)
    out = {}
    for name in ("k", "v"):
        x = getattr(stack, name).astype(jnp.float32)
        if x.shape[-2] == 0:
            # empty stack: nothing to scale over the (empty) sequence axis —
            # unit scales keep the wire layout (and its byte accounting)
            # identical to the non-empty case
            scale = jnp.ones(x.shape[:-2] + (1,) + x.shape[-1:], jnp.float32)
        else:
            scale = jnp.max(jnp.abs(x), axis=-2, keepdims=True) / 127.0
            scale = jnp.maximum(scale, 1e-8)
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        out[f"{name}_q"] = q
        out[f"{name}_scale"] = scale
    return QuantizedKV(**out)


def dequantize_stack(qstack: QuantizedKV, dtype: Any = jnp.bfloat16
                     ) -> KVStack:
    return KVStack(
        k=(qstack.k_q.astype(jnp.float32) * qstack.k_scale).astype(dtype),
        v=(qstack.v_q.astype(jnp.float32) * qstack.v_scale).astype(dtype),
    )


def quantized_bytes(stack: Any) -> int:
    """Wire bytes of the quantised stack (int8 payload + fp32 scales)."""
    stack = KVStack.ensure(stack)
    n, B, H, S, hd = stack.k.shape
    payload = 2 * n * B * H * S * hd  # k+v int8
    scales = 2 * n * B * H * hd * 4
    return payload + scales


def c2c_bytes_per_token_quantized(cfg: ModelConfig) -> float:
    """Asymptotic (S→∞) per-token wire bytes with int8 C2C."""
    hd = cfg.resolved_head_dim
    n_attn = len(cfg.attention_layers)
    return 2.0 * n_attn * cfg.num_kv_heads * hd  # 1 byte per element


def roundtrip_error(stack: Any) -> float:
    """Max relative L2 error of the quantisation round trip (diagnostics)."""
    stack = KVStack.ensure(stack)
    dq = dequantize_stack(quantize_stack(stack), jnp.float32)
    num = den = 0.0
    for name in ("k", "v"):
        a = getattr(stack, name).astype(jnp.float32)
        num += float(jnp.sum((a - getattr(dq, name)) ** 2))
        den += float(jnp.sum(a ** 2))
    return (num / max(den, 1e-30)) ** 0.5
