"""Per-layer KV-cache fusers F_ij — the paper's central mechanism.

A fuser projects the transmitter's KV cache into the receiver's KV space,
layer-by-layer "from the bottom up" (paper §Case Study): receiver attention layer
r is paired with a transmitter attention layer via a ``LayerAlignment``; a
three-layer MLP (per receiver layer) maps each cached token's concatenated
(k, v) vector from transmitter dims (2·Hkv_t·hd_t) to receiver dims
(2·Hkv_r·hd_r). All receiver layers share one stacked parameter pytree and are
applied with vmap — on TPU the projection runs through the fused Pallas kernel
(kernels/fuser_mlp.py); this module is the reference/jnp path and the owner of
parameter/alignment logic.

Heterogeneity handling (the paper's "model-agnostic" claim):
  * different layer counts  -> alignment map (bottom-up clip or proportional)
  * different kv dims/heads -> MLP input/output dims differ per model pair
  * attention-free models   -> ``InapplicableError`` (DESIGN.md §Arch-applicability)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Literal, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.cache import FusedPrefix, KVStack


class InapplicableError(TypeError):
    """The paper's KV medium does not exist for this architecture family."""


# ------------------------------------------------------------------ alignment


@dataclass(frozen=True)
class LayerAlignment:
    """Map receiver attention-layer rank -> transmitter attention-layer rank."""

    rx_layers: int
    tx_layers: int
    mode: Literal["bottom_up", "proportional"] = "bottom_up"

    @property
    def table(self) -> Tuple[int, ...]:
        if self.mode == "bottom_up":
            # paper: align layer-by-layer from the bottom; clip at tx depth
            return tuple(min(r, self.tx_layers - 1) for r in range(self.rx_layers))
        return tuple(
            min(r * self.tx_layers // self.rx_layers, self.tx_layers - 1)
            for r in range(self.rx_layers)
        )


def make_alignment(cfg_tx: ModelConfig, cfg_rx: ModelConfig,
                   mode: str = "bottom_up") -> LayerAlignment:
    n_tx, n_rx = len(cfg_tx.attention_layers), len(cfg_rx.attention_layers)
    if n_tx == 0:
        raise InapplicableError(
            f"{cfg_tx.name} is attention-free ({cfg_tx.family}); it has no KV cache "
            "to transmit — the paper's C2C medium is inapplicable "
            "(DESIGN.md §Arch-applicability).")
    if n_rx == 0:
        raise InapplicableError(
            f"{cfg_rx.name} is attention-free ({cfg_rx.family}); it cannot consume "
            "a fused KV cache.")
    return LayerAlignment(n_rx, n_tx, mode)  # type: ignore[arg-type]


# ------------------------------------------------------------------ params


def fuser_dims(cfg_tx: ModelConfig, cfg_rx: ModelConfig,
               hidden: int = 0) -> Tuple[int, int, int]:
    d_in = 2 * cfg_tx.kv_dim
    d_out = 2 * cfg_rx.kv_dim
    d_h = hidden or max(d_in, d_out)
    return d_in, d_h, d_out


def init_fuser(cfg_tx: ModelConfig, cfg_rx: ModelConfig, key, *,
               hidden: int = 0, alignment: str = "bottom_up",
               dtype=jnp.float32) -> dict:
    """Stacked 3-layer MLPs: one per receiver attention layer, + per-layer gates."""
    align = make_alignment(cfg_tx, cfg_rx, alignment)
    d_in, d_h, d_out = fuser_dims(cfg_tx, cfg_rx, hidden)
    n = align.rx_layers

    def one(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "w1": L.init_linear(k1, d_in, d_h, bias=True, dtype=dtype),
            "w2": L.init_linear(k2, d_h, d_h, bias=True, dtype=dtype),
            "w3": L.init_linear(k3, d_h, d_out, bias=True, dtype=dtype),
        }

    mlps = jax.vmap(one)(jax.random.split(key, n))
    return {
        "mlp": mlps,  # stacked over rx attention layers
        # per-layer scalar gate, pre-sigmoid; init -1 => gate ≈ 0.27 (gentle start)
        "gate": jnp.full((n,), -1.0, jnp.float32),
        # alignment table as an int32 leaf so the whole fuser is one jit-able pytree
        "align": jnp.asarray(align.table, jnp.int32),
    }


# ------------------------------------------------------------------ apply


def _mlp(p, x):
    h = jax.nn.silu(L.linear(p["w1"], x))
    h = jax.nn.silu(L.linear(p["w2"], h))
    return L.linear(p["w3"], h)


def project_cache(
    fuser: dict,
    cfg_tx: ModelConfig,
    cfg_rx: ModelConfig,
    tx_stack,  # KVStack: k/v (n_tx, B, Hkv_t, S, hd_t)
    *,
    use_kernel: bool = False,
) -> FusedPrefix:
    """Project a transmitter KV stack into receiver space: Eq. 1's C(F_ij, M_i).

    Returns a FusedPrefix: k/v (n_rx, B, Hkv_r, S, hd_r) plus a per-layer,
    per-position attention-logit bias (n_rx, B, S) = log σ(gate). The gate acts
    multiplicatively on the *attention mass* of fused tokens: gate→0 recovers
    standalone inference exactly (a property tests pin down), gate→1 recovers the
    paper's plain concatenation.
    """
    tx_stack = KVStack.ensure(tx_stack)
    n_tx, B, Ht, S, hdt = tx_stack.k.shape
    align = fuser["align"]  # (n_rx,)
    # gather transmitter layers for each receiver layer
    k_sel = tx_stack.k[align]  # (n_rx, B, Ht, S, hdt)
    v_sel = tx_stack.v[align]
    x = jnp.concatenate(
        [
            k_sel.transpose(0, 1, 3, 2, 4).reshape(len(align), B, S, Ht * hdt),
            v_sel.transpose(0, 1, 3, 2, 4).reshape(len(align), B, S, Ht * hdt),
        ],
        axis=-1,
    )  # (n_rx, B, S, 2*kv_t)

    if use_kernel:
        from repro.kernels.ops import fuser_mlp
        y = jax.vmap(fuser_mlp)(fuser["mlp"], x)
    else:
        y = jax.vmap(_mlp)(fuser["mlp"], x)  # (n_rx, B, S, 2*kv_r)

    Hr, hdr = cfg_rx.num_kv_heads, cfg_rx.resolved_head_dim
    k_hat, v_hat = jnp.split(y, 2, axis=-1)
    k_hat = k_hat.reshape(len(align), B, S, Hr, hdr).transpose(0, 1, 3, 2, 4)
    v_hat = v_hat.reshape(len(align), B, S, Hr, hdr).transpose(0, 1, 3, 2, 4)
    # log σ(gate) = -softplus(-gate): numerically safe even for very closed gates
    log_g = -jax.nn.softplus(-fuser["gate"].astype(jnp.float32))
    bias = jnp.broadcast_to(log_g[:, None, None], (len(align), B, S))
    return FusedPrefix(k=k_hat, v=v_hat, bias=bias)


def mix_cache(
    fuser: dict,
    cfg_tx: ModelConfig,
    cfg_rx: ModelConfig,
    tx_stack,
    rx_stack,  # receiver's own KVStack, same S
    *,
    use_kernel: bool = False,
) -> KVStack:
    """Per-position gated mixing (the case-study variant: "the receiver mixes the
    projected KV cache with its own"). Requires equal cached lengths.

    k' = (1-g)·k_own + g·k̂ ; v' likewise. Returns receiver-shaped stack.
    """
    rx_stack = KVStack.ensure(rx_stack)
    proj = project_cache(fuser, cfg_tx, cfg_rx, tx_stack, use_kernel=use_kernel)
    g = jax.nn.sigmoid(fuser["gate"].astype(jnp.float32))[:, None, None, None, None]
    g = g.astype(rx_stack.k.dtype)
    return KVStack(
        k=(1 - g) * rx_stack.k + g * proj.k,
        v=(1 - g) * rx_stack.v + g * proj.v,
    )
