"""Cache-to-Cache decode (Eqs. 1–2): unidirectional and bidirectional C2C.

The receiver decodes conditioned on C(F_ij, M_i) ∘ C(M_j): the transmitter's KV
cache, projected through the fuser, prepended sequence-wise to the receiver's own
cache. Because the fused cache arrives *as a cache* (not as tokens), the receiver
skips the prefill that T2T would need — the paper's central latency claim, which
benchmarks/fig3c_latency.py quantifies.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import fuser as F
from repro.models import transformer as T
from repro.models.cache import FusedPrefix, KVCache


def fused_prefix(
    fusers: List[dict],
    cfg_txs: List[ModelConfig],
    cfg_rx: ModelConfig,
    tx_stacks: List,
    *,
    gating: Optional[dict] = None,
    use_kernel: bool = False,
) -> FusedPrefix:
    """Project every transmitter stack into receiver space and concatenate
    sequence-wise (Eq. 4's C(F_{j1 i}) ∘ … ∘ C(F_{js i}))."""
    from repro.core.gating import apply_gates

    projected = [
        F.project_cache(fz, ct, cfg_rx, st, use_kernel=use_kernel)
        for fz, ct, st in zip(fusers, cfg_txs, tx_stacks)
    ]
    if gating is not None:
        projected = apply_gates(gating, projected)
    return FusedPrefix.concat(projected)


def c2c_forward(
    cfg_rx: ModelConfig,
    params_rx: dict,
    tokens: jax.Array,
    fused,  # FusedPrefix (n_rx, B, Hkv, Sf, hd)
) -> Tuple[jax.Array, jax.Array]:
    """Teacher-forced receiver forward with a fused-cache prefix (fuser training
    and accuracy eval both use this). Returns (logits, aux)."""
    return T.forward(cfg_rx, params_rx, tokens,
                     extra_kv=FusedPrefix.ensure(fused).to_extra_kv(cfg_rx))


def c2c_decode_step(
    cfg_rx: ModelConfig,
    params_rx: dict,
    cache: KVCache,
    token: jax.Array,
    fused,
) -> Tuple[jax.Array, KVCache]:
    """Eq. 1: one receiver decode step attending over fused ∘ own caches."""
    return T.decode_step(cfg_rx, params_rx, cache, token,
                         extra_kv=FusedPrefix.ensure(fused).to_extra_kv(cfg_rx))


def generate(
    cfg: ModelConfig,
    params: dict,
    prompt: jax.Array,  # (B, S) int32
    steps: int,
    *,
    fused=None,
    max_seq: Optional[int] = None,
) -> jax.Array:
    """Greedy generation, optionally C2C-refined. Returns (B, steps) tokens."""
    B, S = prompt.shape
    max_seq = max_seq or S + steps
    ek = (FusedPrefix.ensure(fused).to_extra_kv(cfg)
          if fused is not None else None)
    logits, cache = T.prefill(cfg, params, prompt, max_seq=max_seq, extra_kv=ek)
    tok = jnp.argmax(logits[:, -1], axis=-1)
    out = [tok]
    for _ in range(steps - 1):
        lg, cache = T.decode_step(cfg, params, cache, tok, extra_kv=ek)
        tok = jnp.argmax(lg, axis=-1)
        out.append(tok)
    return jnp.stack(out, axis=1)


def bidirectional_step(
    cfg_i: ModelConfig, params_i: dict, cache_i: KVCache, tok_i: jax.Array,
    cfg_j: ModelConfig, params_j: dict, cache_j: KVCache, tok_j: jax.Array,
    fuser_ij: dict, fuser_ji: dict,
) -> Tuple[Tuple[jax.Array, KVCache], Tuple[jax.Array, KVCache]]:
    """Co-C2C (Eq. 2/3): both models decode one token, each refined by the
    other's *current* cache — the dual-role transmitter/receiver step."""
    stack_i = KVCache.ensure(cache_i).export_stack(cfg_i)
    stack_j = KVCache.ensure(cache_j).export_stack(cfg_j)
    fused_for_j = F.project_cache(fuser_ij, cfg_i, cfg_j, stack_i)
    fused_for_i = F.project_cache(fuser_ji, cfg_j, cfg_i, stack_j)
    out_j = c2c_decode_step(cfg_j, params_j, cache_j, tok_j, fused_for_j)
    out_i = c2c_decode_step(cfg_i, params_i, cache_i, tok_i, fused_for_i)
    return out_i, out_j
