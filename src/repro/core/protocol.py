"""Federation protocols and opportunistic protocol selection.

Paper §Possible Variants: "the decision to use cache or token communication
could be dynamically determined based on both the current network status and
the specific QoS requirements".

Each way participants can collaborate is a :class:`FederationProtocol`
(Standalone / C2C / T2T) bundling the three things that were previously
scattered across ``choose_protocol`` + ``fedrefine.submit`` +
``fedrefine.serve_opportunistic``:

  * an analytic **latency estimate** per link (the QoS input),
  * a **quality rank** (paper Fig. 3a: c2c > t2t > standalone),
  * **prepare()** — the transmit/prefix construction that turns a raw request
    into what the receiver's engine decodes (a fused KV prefix for C2C, a
    combined shared-token prompt for T2T, the prompt itself standalone).

``FedRefineSystem`` and ``launch/engine.py`` consume protocols only through
this interface, so adding a protocol variant is additive (register it in
``PROTOCOLS``), not a cross-module edit.

Latency model per link:

  latency_c2c = kv_bytes(seq)/bw + rtt + fuser_time + decode_time
  latency_t2t = tx_gen_time + text_bytes/bw + rtt + rx_prefill_time + decode_time

Compute-time terms come from the TPU-v5e roofline constants (repro/hw.py — one
shared source with roofline.py), so the protocol's decisions stay consistent
with the §Roofline tables. Properties pinned by tests: decisions are monotone
in bandwidth (more bandwidth never flips C2C→T2T) and respect QoS feasibility.
"""
from __future__ import annotations

import abc
from dataclasses import dataclass, field
from typing import (Any, Dict, FrozenSet, List, Literal, Optional, Tuple)

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import commload
from repro.hw import HBM_BW, PEAK_FLOPS  # shared with roofline.py  # noqa: F401
from repro.models.cache import FusedPrefix


@dataclass(frozen=True)
class LinkModel:
    bandwidth_bps: float  # bytes/s on the federation link
    rtt_s: float = 0.0

    def transfer_time(self, nbytes: float) -> float:
        return nbytes / self.bandwidth_bps + self.rtt_s


@dataclass(frozen=True)
class QoS:
    max_latency_s: float = float("inf")
    min_quality: Literal["standalone", "t2t", "c2c"] = "standalone"


def _prefill_time(cfg: ModelConfig, seq: int, mfu: float = 0.4) -> float:
    return 2.0 * cfg.active_param_count() * seq / (PEAK_FLOPS * mfu)


def _decode_time(cfg: ModelConfig, steps: int, hbm_frac: float = 0.6) -> float:
    # decode is memory-bound: one full weight read per token
    return steps * 2.0 * cfg.active_param_count() / (HBM_BW * hbm_frac)


def _fuser_time(cfg_tx: ModelConfig, cfg_rx: ModelConfig, seq: int,
                mfu: float = 0.4) -> float:
    d_in = 2 * cfg_tx.kv_dim
    d_out = 2 * cfg_rx.kv_dim
    d_h = max(d_in, d_out)
    n = len(cfg_rx.attention_layers)
    flops = 2.0 * seq * n * (d_in * d_h + d_h * d_h + d_h * d_out)
    return flops / (PEAK_FLOPS * mfu)


# ------------------------------------------------------------ prepared form


@dataclass
class PreparedRequest:
    """A protocol's output: exactly what the receiver engine decodes."""

    prompt: jax.Array  # receiver-side tokens (B, S) — combined for T2T
    protocol: str
    fused: Optional[FusedPrefix] = None  # C2C prefix (None otherwise)
    transmitters: List[str] = field(default_factory=list)
    wire_bytes: int = 0  # bytes this request put on the federation link
    meta: dict = field(default_factory=dict)


# ---------------------------------------------------------------- protocols


class FederationProtocol(abc.ABC):
    """One way participants collaborate on a request."""

    name: str = "?"
    quality: int = 0  # higher = better answer quality (paper Fig. 3a)

    @abc.abstractmethod
    def estimate_latency(self, cfg_txs: List[ModelConfig], cfg_rx: ModelConfig,
                         seq: int, gen_steps: int, link: LinkModel, *,
                         shared_tokens: int = 64) -> float:
        """End-to-end latency of one request under this protocol."""

    @abc.abstractmethod
    def prepare(self, system: Any, receiver: str, prompt: jax.Array,
                tx_names: List[str], *, steps: int, key: jax.Array,
                gated: bool = True,
                tx_prompts: Optional[Dict[str, jax.Array]] = None
                ) -> PreparedRequest:
        """Run the transmit side and build the receiver's decode inputs.
        ``system`` is a FedRefineSystem (duck-typed to avoid a cycle)."""

    def needs_transmitters(self) -> bool:
        return True


class Standalone(FederationProtocol):
    name = "standalone"
    quality = 0

    def estimate_latency(self, cfg_txs: List[ModelConfig],
                         cfg_rx: ModelConfig, seq: int, gen_steps: int,
                         link: LinkModel, *,
                         shared_tokens: int = 64) -> float:
        return _prefill_time(cfg_rx, seq) + _decode_time(cfg_rx, gen_steps)

    def prepare(self, system: Any, receiver: str, prompt: jax.Array,
                tx_names: List[str], *, steps: int, key: jax.Array,
                gated: bool = True,
                tx_prompts: Optional[Dict[str, jax.Array]] = None
                ) -> PreparedRequest:
        return PreparedRequest(prompt=prompt, protocol=self.name)

    def needs_transmitters(self) -> bool:
        return False


class C2C(FederationProtocol):
    """Cache-to-cache: transmitters prefill locally, ship their KV stacks
    through the system's wire channel, the fuser projects them into receiver
    space, the receiver decodes over [fused ∘ own] (Eq. 4)."""

    name = "c2c"
    quality = 2

    def estimate_latency(self, cfg_txs: List[ModelConfig],
                         cfg_rx: ModelConfig, seq: int, gen_steps: int,
                         link: LinkModel, *,
                         shared_tokens: int = 64) -> float:
        xfer = link.transfer_time(commload.c2c_bytes_total(cfg_txs, seq))
        fuse = sum(_fuser_time(t, cfg_rx, seq) for t in cfg_txs)
        return xfer + fuse + _decode_time(cfg_rx, gen_steps)

    def prepare(self, system: Any, receiver: str, prompt: jax.Array,
                tx_names: List[str], *, steps: int, key: jax.Array,
                gated: bool = True,
                tx_prompts: Optional[Dict[str, jax.Array]] = None
                ) -> PreparedRequest:
        if tx_prompts is None:
            tx_prompts = {
                n: system.rephrase(prompt, jax.random.fold_in(key, i))
                for i, n in enumerate(tx_names)
            }
        stacks, wire_bytes = system.transmit_stacks(tx_names, tx_prompts)
        fused = system.fused_prefix(receiver, tx_names, stacks, gated=gated)
        return PreparedRequest(prompt=prompt, protocol=self.name, fused=fused,
                               transmitters=list(tx_names),
                               wire_bytes=wire_bytes)


class T2T(FederationProtocol):
    """Text-to-text: transmitters answer as generated tokens; the receiver
    re-prefills [shared ∘ own prompt] — the prefill rebuild C2C avoids."""

    name = "t2t"
    quality = 1

    def estimate_latency(self, cfg_txs: List[ModelConfig],
                         cfg_rx: ModelConfig, seq: int, gen_steps: int,
                         link: LinkModel, *,
                         shared_tokens: int = 64) -> float:
        tx_gen = (max(_decode_time(t, shared_tokens) for t in cfg_txs)
                  if cfg_txs else 0.0)
        xfer = link.transfer_time(
            commload.t2t_bytes_total(len(cfg_txs), shared_tokens))
        rx_prefill = _prefill_time(cfg_rx, seq + shared_tokens * len(cfg_txs))
        return tx_gen + xfer + rx_prefill + _decode_time(cfg_rx, gen_steps)

    def prepare(self, system: Any, receiver: str, prompt: jax.Array,
                tx_names: List[str], *, steps: int, key: jax.Array,
                gated: bool = True,
                tx_prompts: Optional[Dict[str, jax.Array]] = None
                ) -> PreparedRequest:
        from repro.core import t2t

        shared: List[jax.Array] = []
        wire_bytes = 0
        for i, n in enumerate(tx_names):
            p = system.participants[n]
            tp = (tx_prompts[n] if tx_prompts is not None
                  else system.rephrase(prompt, jax.random.fold_in(key, i)))
            toks = t2t.t2t_exchange(p.cfg, p.params, tp, steps)
            shared.append(toks)
            wire_bytes += int(toks.size) * commload.t2t_bytes_per_token()
        combined = jnp.concatenate([*shared, prompt], axis=1)
        return PreparedRequest(prompt=combined, protocol=self.name,
                               transmitters=list(tx_names),
                               wire_bytes=wire_bytes)


#: Registry consumed by FedRefineSystem / ContinuousBatchingEngine. Adding a
#: protocol variant == adding an entry here.
PROTOCOLS: Dict[str, FederationProtocol] = {
    p.name: p for p in (C2C(), T2T(), Standalone())
}

#: Names in quality order, best first (paper Fig. 3a).
QUALITY_ORDER: List[str] = sorted(
    PROTOCOLS, key=lambda n: -PROTOCOLS[n].quality)


# ------------------------------------------------------------ wire contracts


#: Wire dtypes no schema may carry: int64/uint64 token ids double the wire
#: bytes for no information, float64 stacks quadruple them, and object
#: payloads are not tensors at all. The WireAuditor rejects these regardless
#: of the per-protocol schema.
FORBIDDEN_WIRE_DTYPES: FrozenSet[str] = frozenset(
    {"int64", "uint64", "float64"})


@dataclass(frozen=True)
class WireSchema:
    """Declared wire contract of one protocol: which media may cross the
    federation link, at which dtypes, through which codec stages.

    The static pass (repro.analysis.wire, WIR004) cross-checks declared
    ``stages`` against codec ``Pipeline`` literals; the runtime twin
    (repro.analysis.wire_audit.WireAuditor) verifies every encoded
    :class:`~repro.core.transport.Message` against the schema and its byte
    estimate. ``Message`` is duck-typed here so protocol.py keeps its
    layering (it never imports transport.py)."""

    protocol: str
    #: media allowed on the wire — subset of {"stack", "tokens"}
    media: FrozenSet[str] = frozenset()
    #: dtypes a *dense* stack may ship at; empty = any non-forbidden dtype
    stack_dtypes: FrozenSet[str] = frozenset()
    #: codec stages the wire pipeline must apply ("quant", "rephrase", ...)
    stages: Tuple[str, ...] = ()
    #: relative slack between measured bytes_on_wire and the estimate
    tolerance: float = 0.0
    #: hard per-message byte ceiling (None = only the QoS budget applies)
    max_message_bytes: Optional[int] = None

    def estimate_wire_bytes(self, msg: Any) -> int:
        """commload-analytic wire bytes of a *pre-encode* message under this
        schema's declared stages: an int8-quantised stack costs exactly
        ``quant.quantized_bytes``, a dense one ``commload.measured_bytes``,
        tokens ``t2t_bytes_per_token`` each."""
        total = 0
        stack = getattr(msg, "stack", None)
        if stack is not None:
            if "quant" in self.stages:
                from repro.core import quant

                total += quant.quantized_bytes(stack)
            else:
                total += commload.measured_bytes(stack)
        tokens = getattr(msg, "tokens", None)
        if tokens is not None:
            total += int(tokens.size) * commload.t2t_bytes_per_token()
        payload = getattr(msg, "payload", None)
        if payload:
            total += commload.measured_bytes(payload)
        return total


#: Per-protocol wire contracts, keyed like PROTOCOLS. The defaults describe
#: the in-tree wire (FedRefineSystem defaults to an IdentityChannel, so a
#: dense stack at a working dtype is legal for C2C); tests and deployments
#: pass stricter schemas (e.g. stages=("quant",) + stack_dtypes={"int8"}) to
#: the WireAuditor to *forbid* dense KV on the link.
WIRE_SCHEMAS: Dict[str, WireSchema] = {
    "c2c": WireSchema(
        protocol="c2c", media=frozenset({"stack"}),
        stack_dtypes=frozenset({"bfloat16", "float16", "float32", "int8"})),
    "t2t": WireSchema(protocol="t2t", media=frozenset({"tokens"})),
    "standalone": WireSchema(protocol="standalone"),
}


# --------------------------------------------------- legacy latency wrappers


def latency_c2c(cfg_txs: List[ModelConfig], cfg_rx: ModelConfig, seq: int,
                gen_steps: int, link: LinkModel) -> float:
    return PROTOCOLS["c2c"].estimate_latency(cfg_txs, cfg_rx, seq, gen_steps,
                                             link)


def latency_t2t(cfg_txs: List[ModelConfig], cfg_rx: ModelConfig, seq: int,
                gen_steps: int, link: LinkModel, shared_tokens: int) -> float:
    return PROTOCOLS["t2t"].estimate_latency(cfg_txs, cfg_rx, seq, gen_steps,
                                             link, shared_tokens=shared_tokens)


def latency_standalone(cfg_rx: ModelConfig, seq: int, gen_steps: int) -> float:
    return PROTOCOLS["standalone"].estimate_latency([], cfg_rx, seq, gen_steps,
                                                    LinkModel(1.0))


# ------------------------------------------------------------------ chooser


def choose_protocol(
    cfg_txs: List[ModelConfig],
    cfg_rx: ModelConfig,
    seq: int,
    gen_steps: int,
    link: LinkModel,
    qos: QoS,
    *,
    shared_tokens: int = 64,
) -> dict:
    """Pick the highest-quality protocol that satisfies the QoS latency budget.

    Quality order (paper Fig. 3a): c2c > t2t > standalone.
    """
    cands = {
        name: PROTOCOLS[name].estimate_latency(
            cfg_txs, cfg_rx, seq, gen_steps, link, shared_tokens=shared_tokens)
        for name in QUALITY_ORDER
    }
    floor = QUALITY_ORDER.index(qos.min_quality)
    # best quality, down to (and including) the QoS quality floor, that fits
    for name in QUALITY_ORDER[: floor + 1]:
        if cands[name] <= qos.max_latency_s:
            return {"protocol": name, "latencies": cands, "qos_met": True}
    # infeasible QoS: degrade to the fastest candidate and flag it
    fastest = min(cands, key=lambda n: cands[n])
    return {"protocol": fastest, "latencies": cands, "qos_met": False}
