"""Opportunistic protocol selection (paper §Possible Variants: "the decision to
use cache or token communication could be dynamically determined based on both
the current network status and the specific QoS requirements").

An analytic latency/accuracy model per link decides C2C vs T2T vs standalone:

  latency_c2c = kv_bytes(seq)/bw + rtt + fuser_time + decode_time
  latency_t2t = tx_gen_time + text_bytes/bw + rtt + rx_prefill_time + decode_time

Compute-time terms come from the same TPU-v5e roofline constants the dry-run
analysis uses (roofline.py), so the protocol's decisions are consistent with the
§Roofline tables. Properties pinned by tests: decisions are monotone in bandwidth
(more bandwidth never flips C2C→T2T) and respect QoS feasibility.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Literal

from repro.configs.base import ModelConfig
from repro.core import commload

# TPU-v5e-class compute constants (shared with roofline.py)
PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s


@dataclass(frozen=True)
class LinkModel:
    bandwidth_bps: float  # bytes/s on the federation link
    rtt_s: float = 0.0


@dataclass(frozen=True)
class QoS:
    max_latency_s: float = float("inf")
    min_quality: Literal["standalone", "t2t", "c2c"] = "standalone"


def _prefill_time(cfg: ModelConfig, seq: int, mfu: float = 0.4) -> float:
    return 2.0 * cfg.active_param_count() * seq / (PEAK_FLOPS * mfu)


def _decode_time(cfg: ModelConfig, steps: int, hbm_frac: float = 0.6) -> float:
    # decode is memory-bound: one full weight read per token
    return steps * 2.0 * cfg.active_param_count() / (HBM_BW * hbm_frac)


def _fuser_time(cfg_tx: ModelConfig, cfg_rx: ModelConfig, seq: int,
                mfu: float = 0.4) -> float:
    d_in = 2 * cfg_tx.kv_dim
    d_out = 2 * cfg_rx.kv_dim
    d_h = max(d_in, d_out)
    n = len(cfg_rx.attention_layers)
    flops = 2.0 * seq * n * (d_in * d_h + d_h * d_h + d_h * d_out)
    return flops / (PEAK_FLOPS * mfu)


def latency_c2c(cfg_txs: List[ModelConfig], cfg_rx: ModelConfig, seq: int,
                gen_steps: int, link: LinkModel) -> float:
    xfer = commload.c2c_bytes_total(cfg_txs, seq) / link.bandwidth_bps
    fuse = sum(_fuser_time(t, cfg_rx, seq) for t in cfg_txs)
    return xfer + link.rtt_s + fuse + _decode_time(cfg_rx, gen_steps)


def latency_t2t(cfg_txs: List[ModelConfig], cfg_rx: ModelConfig, seq: int,
                gen_steps: int, link: LinkModel, shared_tokens: int) -> float:
    tx_gen = max(_decode_time(t, shared_tokens) for t in cfg_txs) if cfg_txs else 0.0
    xfer = commload.t2t_bytes_total(len(cfg_txs), shared_tokens) / link.bandwidth_bps
    rx_prefill = _prefill_time(cfg_rx, seq + shared_tokens * len(cfg_txs))
    return tx_gen + xfer + link.rtt_s + rx_prefill + _decode_time(cfg_rx, gen_steps)


def latency_standalone(cfg_rx: ModelConfig, seq: int, gen_steps: int) -> float:
    return _prefill_time(cfg_rx, seq) + _decode_time(cfg_rx, gen_steps)


def choose_protocol(
    cfg_txs: List[ModelConfig],
    cfg_rx: ModelConfig,
    seq: int,
    gen_steps: int,
    link: LinkModel,
    qos: QoS,
    *,
    shared_tokens: int = 64,
) -> dict:
    """Pick the highest-quality protocol that satisfies the QoS latency budget.

    Quality order (paper Fig. 3a): c2c > t2t > standalone.
    """
    cands = {
        "c2c": latency_c2c(cfg_txs, cfg_rx, seq, gen_steps, link),
        "t2t": latency_t2t(cfg_txs, cfg_rx, seq, gen_steps, link, shared_tokens),
        "standalone": latency_standalone(cfg_rx, seq, gen_steps),
    }
    order = ["c2c", "t2t", "standalone"]  # best -> worst quality
    floor = order.index(qos.min_quality)
    # best quality, down to (and including) the QoS quality floor, that fits
    for name in order[: floor + 1]:
        if cands[name] <= qos.max_latency_s:
            return {"protocol": name, "latencies": cands, "qos_met": True}
    # infeasible QoS: degrade to the fastest candidate and flag it
    fastest = min(cands, key=cands.get)
    return {"protocol": fastest, "latencies": cands, "qos_met": False}
