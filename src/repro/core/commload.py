"""Communication-load accounting (paper §Case Study: "transmitting the KV cache
for a single token requires 88 KB, whereas T2T requires only 16 bytes").

These are the byte counts the opportunistic protocol (protocol.py) trades against
latency, and the quantities the ICI roofline term measures when federation
participants are mapped onto mesh slices (DESIGN.md §2).

The *analytic* numbers here are cross-checked against the transport layer's
measured accounting: ``IdentityChannel.bytes_on_wire`` over a concrete
:class:`~repro.models.cache.KVStack` must equal :func:`c2c_bytes_total`, and a
token message must cost :func:`t2t_bytes_per_token` per id
(tests/test_transport.py pins both)."""
from __future__ import annotations

from typing import List

from repro.configs.base import ModelConfig
from repro.models.cache import cache_bytes_per_token, tree_bytes


def c2c_bytes_per_token(cfg_tx: ModelConfig, dtype_bytes: int = 2) -> int:
    """KV bytes one transmitter ships per cached token (k + v, all attn layers)."""
    return cache_bytes_per_token(cfg_tx, dtype_bytes)


def c2c_bytes_total(cfg_txs: List[ModelConfig], seq_len: int,
                    dtype_bytes: int = 2) -> int:
    return sum(c2c_bytes_per_token(c, dtype_bytes) for c in cfg_txs) * seq_len


def t2t_bytes_per_token(token_bytes: int = 4) -> int:
    """A token id on the wire (the paper counts 4 B/token/model; 4 models = 16 B)."""
    return token_bytes


def t2t_bytes_total(n_tx: int, tokens_per_tx: int, token_bytes: int = 4) -> int:
    return n_tx * tokens_per_tx * token_bytes


def measured_bytes(obj) -> int:
    """Measured wire bytes of any message/stack pytree (array-leaf nbytes) —
    the quantity ``Channel.bytes_on_wire`` reports; see module docstring."""
    return tree_bytes(obj)


def paper_case_study_bytes(dtype_bytes: int = 2) -> dict:
    """Reproduces the paper's 88 KB-vs-16 B comparison from the published dims."""
    from repro.configs.case_study import ZOO

    per_tx = {c.name: c2c_bytes_per_token(c, dtype_bytes) for c in ZOO["transmitters"]}
    return {
        "per_transmitter_bytes": per_tx,
        "c2c_total_per_token": sum(per_tx.values()),
        "t2t_total_per_token": t2t_bytes_per_token() * len(per_tx),
    }
