"""Iterative refinement loops (paper §Possible Variants and Future Trends:
"iterative local refinement" and "continuous global federation iterations").

Two mechanisms on top of the one-shot FedRefine decode:

1. ``iterative_c2c_refine`` — multi-ROUND cache communication: the receiver
   drafts an answer, every transmitter re-prefills with the receiver's draft
   appended to its own (rephrased) context, exports a REFRESHED cache, and the
   receiver decodes again over the refreshed fused prefixes. Each round the
   transmitters' caches become conditioned on the receiver's current belief —
   the paper's "multi-iteration cache communication as a mechanism to achieve
   continuous, system-wide LLM refinement".

2. ``self_refine_with_c2c`` — the hybrid of Self-Refine and C2C: local
   iterative refinement where each round ALSO consumes the (static) fused
   caches — isolating how much external caches add over pure self-refinement.

Both are jit-compatible per round (python drives the round loop; each round's
compute is traced once per shape).
"""
from __future__ import annotations

from typing import List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import c2c
from repro.models import transformer as T


def iterative_c2c_refine(
    cfg_rx: ModelConfig,
    params_rx: dict,
    fusers: List[dict],
    cfg_txs: List[ModelConfig],
    params_txs: List[dict],
    rx_prompt: jax.Array,  # (B, S)
    tx_prompts: List[jax.Array],  # per transmitter (B, S_t)
    *,
    rounds: int = 2,
    steps: int = 8,
    gating: Optional[dict] = None,
    sep_token: int = 3,
) -> dict:
    """Multi-round federated refinement. Returns {"tokens", "rounds": [...]}. """
    B = rx_prompt.shape[0]
    sep = jnp.full((B, 1), sep_token, rx_prompt.dtype)
    draft = None
    history = []
    for r in range(rounds):
        stacks = []
        for cfg_t, p_t, tp in zip(cfg_txs, params_txs, tx_prompts):
            ctx = tp if draft is None else jnp.concatenate(
                [tp, sep, draft], axis=1)
            S = ctx.shape[1]
            _, cache = T.prefill(cfg_t, p_t, ctx, max_seq=S,
                                 cache_dtype=jnp.float32)
            stacks.append(cache.export_stack(cfg_t, length=S))
        fused = c2c.fused_prefix(fusers, cfg_txs, cfg_rx, stacks,
                                 gating=gating)
        rx_ctx = rx_prompt if draft is None else jnp.concatenate(
            [rx_prompt, sep, draft], axis=1)
        draft = c2c.generate(cfg_rx, params_rx, rx_ctx, steps, fused=fused)
        history.append(draft)
    return {"tokens": draft, "rounds": history}


def self_refine_with_c2c(
    cfg_rx: ModelConfig,
    params_rx: dict,
    fused: Optional[dict],
    prompt: jax.Array,
    *,
    rounds: int = 2,
    steps: int = 8,
    sep_token: int = 3,
) -> jax.Array:
    """Self-Refine where every round also sees the (static) fused prefix."""
    B = prompt.shape[0]
    sep = jnp.full((B, 1), sep_token, prompt.dtype)
    ans = c2c.generate(cfg_rx, params_rx, prompt, steps, fused=fused)
    for _ in range(rounds - 1):
        ctx = jnp.concatenate([prompt, sep, ans], axis=1)
        ans = c2c.generate(cfg_rx, params_rx, ctx, steps, fused=fused)
    return ans
