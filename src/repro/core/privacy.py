"""Privacy-preserving input rephrasing.

The paper sends each participant a *rephrased* version of the query so raw user
intent never leaves the device ("LLMs will perform inference with rephrased input
tokens to ensure privacy protection without any intent leakage"), and measures a
~3% accuracy cost. Offline (repro band 2 — no instruction-tuned rephraser
checkpoint) we implement two channels with the same interface:

1. ``ParaphraseChannel`` — a calibrated surface-form rewrite: the synthetic corpus
   (data/synthetic.py) defines synonym classes; rephrasing resamples each content
   token within its class and permutes filler tokens. Semantics (the QA answer) are
   invariant by construction, surface form is not — which is precisely the property
   a rephraser must have, and it gives a *deterministic, measurable* privacy
   transform (token overlap ↓, answer invariant).
2. ``model_rephrase`` — the paper's own mechanism (receiver model rewrites the
   query) for when a trained rephraser LM is available.

As a *wire* transform (rephrase-before-transmit), this module is adapted into
the composable channel pipeline by ``core/transport.RephraseChannel``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class ParaphraseChannel:
    """Vocabulary-level paraphraser over synonym classes.

    ``class_of[v]`` = synonym-class id of token v; ``members`` (n_classes, width) =
    token ids per class (padded by repetition). Rephrasing maps each token to a
    random member of its class.
    """

    class_of: jax.Array  # (V,) int32
    members: jax.Array  # (n_classes, width) int32

    def rephrase(self, tokens: jax.Array, key: jax.Array) -> jax.Array:
        width = self.members.shape[1]
        cls = self.class_of[tokens]  # (B, S)
        pick = jax.random.randint(key, tokens.shape, 0, width)
        return self.members[cls, pick]

    def overlap(self, a: jax.Array, b: jax.Array) -> jax.Array:
        """Surface overlap fraction — the privacy metric (lower = more private)."""
        return jnp.mean((a == b).astype(jnp.float32))


def identity_channel(vocab: int) -> ParaphraseChannel:
    ids = jnp.arange(vocab, dtype=jnp.int32)
    return ParaphraseChannel(class_of=ids, members=ids[:, None])


def synonym_channel(vocab: int, class_width: int, key) -> ParaphraseChannel:
    """Random partition of the vocabulary into synonym classes of ``class_width``."""
    perm = jax.random.permutation(key, vocab)
    n_classes = vocab // class_width
    members = perm[: n_classes * class_width].reshape(n_classes, class_width)
    class_of = jnp.zeros((vocab,), jnp.int32)
    class_of = class_of.at[members.reshape(-1)].set(
        jnp.repeat(jnp.arange(n_classes, dtype=jnp.int32), class_width))
    return ParaphraseChannel(class_of=class_of, members=members.astype(jnp.int32))


def model_rephrase(
    cfg: ModelConfig,
    params: dict,
    tokens: jax.Array,  # (B, S)
    *,
    steps: Optional[int] = None,
    temperature: float = 0.8,
    key: Optional[jax.Array] = None,
) -> jax.Array:
    """Paper-faithful rephrasing: the receiver model rewrites the query by
    sampled continuation (the case study uses Qwen3-0.6B for this role)."""
    from repro.models import transformer as T

    B, S = tokens.shape
    steps = steps or S
    key = key if key is not None else jax.random.PRNGKey(0)
    logits, cache = T.prefill(cfg, params, tokens, max_seq=S + steps)
    tok = jax.random.categorical(key, logits[:, -1] / temperature)
    out = [tok]
    for i in range(steps - 1):
        key = jax.random.fold_in(key, i)
        lg, cache = T.decode_step(cfg, params, cache, tok)
        tok = jax.random.categorical(key, lg / temperature)
        out.append(tok)
    return jnp.stack(out, axis=1)
