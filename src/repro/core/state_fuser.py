"""BEYOND-PAPER EXTENSION — state-to-state fusers for attention-free models.

The paper's C2C medium is the KV cache, which SSM/recurrent architectures
(mamba2-130m; RecurrentGemma's RG-LRU layers) do not have — DESIGN.md
§Arch-applicability documents the inapplicability and core/fuser.py raises
``InapplicableError``. This module is the natural extension the paper's
"Future Trends" invites: the analogous *compressed-state* medium. A
transmitter's recurrent state (Mamba-2: (nh, hd, ns) per layer; RG-LRU: (W,)
per layer) is projected by a per-layer MLP into the receiver's state space and
gate-mixed into the receiver's initial decode state:

    h0' = (1 − σ(g)) · h0_rx + σ(g) · F_state(h_tx)

Unlike KV C2C the message size is CONSTANT in sequence length — for
mamba2-130m it is 24·24·64·128·4 B ≈ 18.9 MB total (vs ~3 GB for a 32k-token
KV cache of a comparable dense model), the state-space analogue of the paper's
88 KB-vs-16 B trade.

This is clearly marked as ours, not the paper's; benchmarks report it
separately.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.fuser import LayerAlignment
from repro.models import layers as L


class StateInapplicableError(TypeError):
    pass


def _state_layers(cfg: ModelConfig) -> Tuple[int, ...]:
    return tuple(i for i, t in enumerate(cfg.layer_types) if t in ("ssd", "rec"))


def state_dim(cfg: ModelConfig) -> int:
    """Flattened per-layer recurrent state width."""
    kinds = set(cfg.layer_types)
    if "ssd" in kinds:
        return cfg.ssm_nheads * cfg.ssm_head_dim * cfg.ssm_state
    if "rec" in kinds:
        return cfg.rglru_width or cfg.d_model
    raise StateInapplicableError(
        f"{cfg.name} has no recurrent state (family {cfg.family})")


def make_state_alignment(cfg_tx: ModelConfig, cfg_rx: ModelConfig) -> LayerAlignment:
    n_tx, n_rx = len(_state_layers(cfg_tx)), len(_state_layers(cfg_rx))
    if n_tx == 0 or n_rx == 0:
        raise StateInapplicableError(
            f"state fuser needs recurrent layers on both ends "
            f"({cfg_tx.name}: {n_tx}, {cfg_rx.name}: {n_rx})")
    return LayerAlignment(n_rx, n_tx, "bottom_up")


def init_state_fuser(cfg_tx: ModelConfig, cfg_rx: ModelConfig, key, *,
                     hidden: int = 0, dtype=jnp.float32) -> dict:
    align = make_state_alignment(cfg_tx, cfg_rx)
    d_in, d_out = state_dim(cfg_tx), state_dim(cfg_rx)
    d_h = hidden or min(max(d_in, d_out), 4096)
    n = align.rx_layers

    def one(k):
        k1, k2 = jax.random.split(k)
        return {
            "w1": L.init_linear(k1, d_in, d_h, bias=True, dtype=dtype),
            "w2": L.init_linear(k2, d_h, d_out, bias=True, dtype=dtype),
        }

    return {
        "mlp": jax.vmap(one)(jax.random.split(key, n)),
        "gate": jnp.full((n,), -1.0, jnp.float32),
        "align": jnp.asarray(align.table, jnp.int32),
    }


def _states_stack(cfg: ModelConfig, cache) -> jax.Array:
    """Flatten all recurrent-layer states to (n_state_layers, B, state_dim)."""
    from repro.models.cache import KVCache
    from repro.models.transformer import layer_grouping
    cycles, pattern, tail = layer_grouping(cfg)
    cache = KVCache.ensure(cache)
    outs = []
    for i, kind in enumerate(pattern + tail):
        if kind in ("ssd", "rec"):
            h = cache.layers[i]["h"]  # (C, B, ...) fp32
            outs.append(h.reshape(h.shape[0], h.shape[1], -1))
    return jnp.concatenate(outs, axis=0)


def fuse_states(fuser: dict, cfg_tx: ModelConfig, cfg_rx: ModelConfig,
                tx_cache, rx_cache):
    """Gate-mix projected transmitter states into the receiver's decode cache."""
    from repro.models.cache import KVCache
    from repro.models.transformer import layer_grouping

    rx_cache = KVCache.ensure(rx_cache)

    tx_states = _states_stack(cfg_tx, tx_cache)  # (n_tx, B, d_in)
    sel = tx_states[fuser["align"]]  # (n_rx, B, d_in)

    def mlp(p, x):
        h = jax.nn.silu(L.linear(p["w1"], x))
        return L.linear(p["w2"], h)

    proj = jax.vmap(mlp)(fuser["mlp"], sel)  # (n_rx, B, d_out)
    g = jax.nn.sigmoid(fuser["gate"])[:, None, None]

    cycles, pattern, tail = layer_grouping(cfg_rx)
    new_layers = list(rx_cache.layers)
    off = 0
    for i, kind in enumerate(pattern + tail):
        if kind in ("ssd", "rec"):
            e = dict(new_layers[i])
            h = e["h"]
            n = h.shape[0]
            p_i = proj[off : off + n].reshape(h.shape).astype(h.dtype)
            g_i = g[off : off + n].reshape((n,) + (1,) * (h.ndim - 1))
            e["h"] = (1 - g_i) * h + g_i * p_i
            new_layers[i] = e
            off += n
    return KVCache(pos=rx_cache.pos, layers=tuple(new_layers))


def state_bytes(cfg: ModelConfig, dtype_bytes: int = 4) -> int:
    """Communication load of state-to-state federation (constant in seq len)."""
    return len(_state_layers(cfg)) * state_dim(cfg) * dtype_bytes
