"""Self-Refine baseline (Madaan et al. 2023): a single model iteratively
re-conditions on its own previous output.

The paper positions FedRefine as the *collaborative* generalisation of this
("iterative local refinement" is limited by the model's internal knowledge);
this module provides the standalone baseline the case study compares against.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.c2c import generate


def self_refine(
    cfg: ModelConfig,
    params: dict,
    prompt: jax.Array,  # (B, S)
    steps: int,
    *,
    rounds: int = 2,
    sep_token: int = 0,
) -> jax.Array:
    """Iterative refinement: each round re-prefixes the previous answer.

    prompt_r = [prompt ‖ sep ‖ answer_{r-1}] ; answer_r = generate(prompt_r).
    Returns the final round's (B, steps) tokens.
    """
    B = prompt.shape[0]
    sep = jnp.full((B, 1), sep_token, prompt.dtype)
    ctx = prompt
    ans = generate(cfg, params, ctx, steps)
    for _ in range(rounds - 1):
        ctx = jnp.concatenate([prompt, sep, ans], axis=1)
        ans = generate(cfg, params, ctx, steps)
    return ans
